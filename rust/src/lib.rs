//! # BWADE — Bit-Width-Aware Design Environment
//!
//! Reproduction of "Bit-Width-Aware Design Environment for Few-Shot
//! Learning on Edge AI Hardware" (ISCAS).  See DESIGN.md for the system
//! inventory and EXPERIMENTS.md for paper-vs-measured results.
//!
//! Layering (three-layer rust+JAX stack, python never on the request path):
//! * L1/L2 live in `python/compile/` (Pallas MVAU kernel, ResNet-9 QAT
//!   model) and are AOT-lowered to `artifacts/*.hlo.txt` by `make
//!   artifacts`;
//! * L3 is this crate: the FINN-style compiler ([`graph`], [`transforms`],
//!   [`hw`]), the dataflow + systolic simulators ([`dataflow`],
//!   [`systolic`]), the PJRT runtime ([`runtime`]) and the serving
//!   coordinator ([`coordinator`]), all driven by the design-environment
//!   pipeline in [`build`].
pub mod artifacts;
pub mod benchutil;
pub mod build;
pub mod cli;
pub mod coordinator;
pub mod dataflow;
pub mod fewshot;
pub mod fixedpoint;
pub mod graph;
pub mod hw;
pub mod json;
pub mod ops;
pub mod resources;
pub mod rng;
pub mod runtime;
pub mod systolic;
pub mod tensor;
pub mod transforms;
