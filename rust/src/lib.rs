//! # BWADE — Bit-Width-Aware Design Environment
//!
//! Reproduction of "Bit-Width-Aware Design Environment for Few-Shot
//! Learning on Edge AI Hardware" (ISCAS).  See DESIGN.md for the system
//! inventory and EXPERIMENTS.md for paper-vs-measured results.
//!
//! Layering (three-layer rust+JAX stack, python never on the request path):
//! * L1/L2 live in `python/compile/` (Pallas MVAU kernel, ResNet-9 QAT
//!   model) and are AOT-lowered to `artifacts/*.hlo.txt` by `make
//!   artifacts`;
//! * L3 is this crate, split along the compile/execute seam:
//!   - **compile time** — the FINN-style compiler ([`graph`],
//!     [`transforms`], [`hw`]), the folding search and design-environment
//!     pipeline in [`build`], and the dataflow + systolic simulators
//!     ([`dataflow`], [`systolic`]);
//!   - **request time** — the compiled-plan engine ([`plan`]): a [`graph`]
//!     is compiled ONCE into an `ExecutionPlan` (toposort resolved at
//!     build time, tensor names interned to dense slot ids, initializers
//!     bound up front, liveness-driven buffer arena), then executed with
//!     zero graph work per call.  Plans compile for one of two datapaths:
//!     the f32 simulation, or the **bit-true integer datapath**
//!     (`plan::Datapath::BitTrue`) that executes the lowered HW graph on
//!     packed fixed-point codes (each tensor in the narrowest i8/i16/i32
//!     container its format permits, kernels monomorphized per container)
//!     — bit-exactly what the FPGA computes *and* the bytes its narrow
//!     datapath streams, with f32 only at the ingress quantizer and the
//!     egress dequantization.  `ops::execute` is a thin compatibility
//!     wrapper over it; the old string-keyed interpreter survives only as
//!     `ops::execute_interpreted` for differential tests and benchmarks.
//!   - **serving** — the coordinator ([`coordinator`]) drives any
//!     `FeatureExtractor`: the PJRT runtime ([`runtime`], `pjrt` feature)
//!     or the plan engine's `PlanRunner`, plus the CPU-side few-shot
//!     classifier ([`fewshot`]).  One compiled plan serves many cores:
//!     `PlanRunner::replicate()` clones the `Arc<ExecutionPlan>` with a
//!     fresh scratch arena, and `coordinator::serve_pool` runs N such
//!     replicas behind a work-stealing queue with deadline-driven
//!     batching, fed by M concurrent frame streams (`bwade serve
//!     --replicas N --streams M`, DESIGN.md §10);
//!   - **exploration** — the design-space exploration engine ([`dse`]):
//!     a parallel sweep over quantization × utilization-cap grids with
//!     Pareto extraction, a content-hashed result cache and a
//!     deterministic `EXPERIMENTS.md` report (`bwade dse`).
// Crate-wide lint posture for the CI clippy job (-D warnings): the
// kernel/simulator code indexes flat buffers with explicit loop nests on
// purpose (the loops mirror the hardware's stream order), and several
// builder APIs legitimately take many scalar knobs.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::comparison_chain,
    clippy::manual_range_contains,
    clippy::field_reassign_with_default,
    clippy::new_without_default,
    clippy::len_without_is_empty,
    clippy::should_implement_trait,
    clippy::result_large_err,
    clippy::large_enum_variant
)]

pub mod artifacts;
pub mod benchutil;
pub mod build;
pub mod cli;
pub mod coordinator;
pub mod dataflow;
pub mod dse;
pub mod fewshot;
pub mod fixedpoint;
pub mod graph;
pub mod hw;
pub mod json;
pub mod ops;
pub mod plan;
pub mod resources;
pub mod rng;
pub mod runtime;
pub mod systolic;
pub mod telemetry;
pub mod tensor;
pub mod transforms;
