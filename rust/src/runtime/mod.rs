//! PJRT runtime — loads the AOT-compiled HLO artifacts and executes them
//! on the request path.  Python is never involved here (DESIGN.md §4).
//!
//! The real implementation needs the `xla` crate, which is not part of
//! the offline crate set — it is gated behind the `pjrt` cargo feature
//! (see Cargo.toml's header note for how to enable it).  Without the
//! feature this module exposes an API-identical stub whose constructors
//! return errors, so every caller compiles and the artifact-gated tests
//! skip exactly as they do when `make artifacts` has not run.  The
//! python-free request path without PJRT is the compiled-plan engine:
//! [`crate::plan::PlanRunner`].
//!
//! The interchange format is HLO *text*: jax >= 0.5 serializes protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! [`BackboneRunner`] is the deployed feature extractor of Fig. 5: it
//! holds the compiled executable for one batch size plus the PTQ'd
//! weight literals for one bit-width config, and turns image batches
//! into feature vectors.  The activation bit-width parameters are fed as
//! runtime scalars, so ONE executable serves every Table-II activation
//! format; the weight quantization is re-done in rust per config via
//! [`crate::fixedpoint`].

#[cfg(feature = "pjrt")]
mod imp {
    use std::path::Path;

    use anyhow::{anyhow, bail, Context, Result};

    use crate::artifacts::ModelBundle;
    use crate::fixedpoint::QuantConfig;

    /// Shared PJRT CPU client (compile + execute).
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        pub fn new() -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
            Ok(Self { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO text artifact.
        pub fn compile_hlo(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))
        }
    }

    /// A deployed backbone: executable + quantized weights for one config.
    pub struct BackboneRunner {
        exe: xla::PjRtLoadedExecutable,
        /// PTQ'd weights in HLO argument order (weights, then act params).
        weight_literals: Vec<xla::Literal>,
        act_scale: xla::Literal,
        act_qmax: xla::Literal,
        pub batch: usize,
        pub img: usize,
        pub feature_dim: usize,
        pub config: QuantConfig,
    }

    impl BackboneRunner {
        /// Build from a model bundle + HLO path for `batch`, quantizing the
        /// float weights to `config` (the request-path bit-width knob).
        pub fn new(
            runtime: &Runtime,
            bundle: &ModelBundle,
            hlo_path: &Path,
            batch: usize,
            config: QuantConfig,
        ) -> Result<Self> {
            let exe = runtime.compile_hlo(hlo_path)?;
            let quantized = bundle.quantized_args(config.weight, config.acc_format());
            let mut weight_literals = Vec::with_capacity(quantized.len());
            for (tensor, arg) in quantized.iter().zip(&bundle.args) {
                let dims: Vec<i64> = arg.shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(tensor.data());
                let lit = if dims.is_empty() {
                    lit
                } else {
                    lit.reshape(&dims)
                        .map_err(|e| anyhow!("reshaping {}: {e:?}", arg.name))?
                };
                weight_literals.push(lit);
            }
            Ok(Self {
                exe,
                weight_literals,
                act_scale: xla::Literal::from(config.act.scale() as f32),
                act_qmax: xla::Literal::from(config.act.qmax() as f32),
                batch,
                img: bundle.img,
                feature_dim: bundle.feature_dim,
                config,
            })
        }

    }

    /// The serving contract lives on the trait — batching / tail padding
    /// come from `FeatureExtractor`'s defaults, only the raw batch
    /// execution is PJRT-specific.
    impl crate::coordinator::FeatureExtractor for BackboneRunner {
        fn batch(&self) -> usize {
            self.batch
        }

        fn img(&self) -> usize {
            self.img
        }

        fn feature_dim(&self) -> usize {
            self.feature_dim
        }

        /// Run one batch of NHWC images (flat, `input_elems()` long),
        /// return `batch * feature_dim` features.
        fn extract(&self, images: &[f32]) -> Result<Vec<f32>> {
            if images.len() != self.input_elems() {
                bail!(
                    "expected {} input elements, got {}",
                    self.input_elems(),
                    images.len()
                );
            }
            let x = xla::Literal::vec1(images)
                .reshape(&[self.batch as i64, self.img as i64, self.img as i64, 3])
                .map_err(|e| anyhow!("image literal: {e:?}"))?;
            let mut args: Vec<xla::Literal> = self.weight_literals.clone();
            args.push(self.act_scale.clone());
            args.push(self.act_qmax.clone());
            args.push(x);
            let result = self
                .exe
                .execute::<xla::Literal>(&args)
                .map_err(|e| anyhow!("execute: {e:?}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            // Lowered with return_tuple=True -> 1-tuple.
            let out = lit.to_tuple1().map_err(|e| anyhow!("tuple: {e:?}"))?;
            let feats = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
            if feats.len() != self.batch * self.feature_dim {
                bail!(
                    "feature count {} != batch {} x dim {}",
                    feats.len(),
                    self.batch,
                    self.feature_dim
                );
            }
            Ok(feats)
        }
    }

    /// Compile-and-run helper for tests: the tiny MVAU artifact
    /// (artifacts/test_mvau.hlo.txt, shapes fixed at x[8,12] w[12,5]).
    pub fn run_test_mvau(
        runtime: &Runtime,
        path: &Path,
        x: &[f32],
        w: &[f32],
        b: &[f32],
        act_scale: f32,
        act_qmax: f32,
    ) -> Result<Vec<f32>> {
        let exe = runtime.compile_hlo(path)?;
        let xl = xla::Literal::vec1(x)
            .reshape(&[8, 12])
            .map_err(|e| anyhow!("{e:?}"))?;
        let wl = xla::Literal::vec1(w)
            .reshape(&[12, 5])
            .map_err(|e| anyhow!("{e:?}"))?;
        let bl = xla::Literal::vec1(b);
        let sl = xla::Literal::from(act_scale);
        let ql = xla::Literal::from(act_qmax);
        let out = exe
            .execute::<xla::Literal>(&[xl, wl, bl, sl, ql])
            .map_err(|e| anyhow!("{e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        let t = out.to_tuple1().map_err(|e| anyhow!("{e:?}"))?;
        t.to_vec::<f32>()
            .map_err(|e| anyhow!("{e:?}"))
            .context("reading MVAU output")
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use std::path::Path;

    use anyhow::{bail, Result};

    use crate::artifacts::ModelBundle;
    use crate::fixedpoint::QuantConfig;

    const UNAVAILABLE: &str = "PJRT runtime unavailable: built without the `pjrt` feature \
         (the offline crate set has no `xla`); use the compiled-plan engine \
         (`--engine plan` / plan::PlanRunner), or add the vendored `xla` crate \
         to Cargo.toml (see its header note) and rebuild with --features pjrt";

    /// Stub PJRT client: construction always fails with a pointer at the
    /// plan-engine fallback.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        pub fn new() -> Result<Self> {
            bail!(UNAVAILABLE)
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }
    }

    /// Stub backbone runner: same fields and trait surface as the real
    /// one so every call site compiles; `new` always fails.
    pub struct BackboneRunner {
        pub batch: usize,
        pub img: usize,
        pub feature_dim: usize,
        pub config: QuantConfig,
    }

    impl BackboneRunner {
        pub fn new(
            _runtime: &Runtime,
            _bundle: &ModelBundle,
            _hlo_path: &Path,
            _batch: usize,
            _config: QuantConfig,
        ) -> Result<Self> {
            bail!(UNAVAILABLE)
        }
    }

    impl crate::coordinator::FeatureExtractor for BackboneRunner {
        fn batch(&self) -> usize {
            self.batch
        }

        fn img(&self) -> usize {
            self.img
        }

        fn feature_dim(&self) -> usize {
            self.feature_dim
        }

        fn extract(&self, _images: &[f32]) -> Result<Vec<f32>> {
            bail!(UNAVAILABLE)
        }
    }

    pub fn run_test_mvau(
        _runtime: &Runtime,
        _path: &Path,
        _x: &[f32],
        _w: &[f32],
        _b: &[f32],
        _act_scale: f32,
        _act_qmax: f32,
    ) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }
}

pub use imp::{run_test_mvau, BackboneRunner, Runtime};
