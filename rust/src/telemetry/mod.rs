//! Process-wide telemetry: atomic counters, gauges, and lock-free
//! log2-bucketed histograms behind a named metric registry with
//! deterministic JSON exposition (schema [`TELEMETRY_SCHEMA`],
//! DESIGN.md §11).
//!
//! Zero dependencies by construction (DESIGN.md §2): recording is a
//! handful of `Relaxed` atomic adds on pre-resolved `Arc` handles —
//! nothing on a hot path ever takes the registry lock or formats a
//! string.  Snapshots are read-side copies: a [`HistogramSnapshot`] is
//! not a consistent cut across concurrent writers (count/sum/buckets
//! are read independently), which is the usual and acceptable contract
//! for monitoring data.
//!
//! Consumers:
//! * `coordinator::pool` exports queue-depth / steal / batch-close
//!   metrics through [`Registry::global`] (`bwade serve --metrics-json`);
//! * `dse::run_sweep` counts cache hits/misses and per-point timing;
//! * the periodic [`StderrEmitter`] prints a one-line summary while a
//!   serve run is in flight.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::json::{self, Json};

/// Schema id stamped into every exported telemetry document.
pub const TELEMETRY_SCHEMA: &str = "bwade/telemetry/v1";

/// Number of histogram buckets: bucket 0 holds exact zeros, bucket `b`
/// (1..=38) holds values with bit length `b` (i.e. `[2^(b-1), 2^b-1]`),
/// and the last bucket is the explicit overflow bucket for values
/// `>= 2^38` (~76 hours when recording microseconds).
pub const HIST_BUCKETS: usize = 40;

/// Largest quantile value the bucketed histogram can report as a real
/// measurement: the inclusive upper bound of the last finite bucket
/// (`2^38 - 1`).  A rank landing in the explicit overflow bucket has no
/// finite upper bound — exposition layers clamp to this value and flag
/// it instead of passing `u64::MAX` off as a measurement.
pub const HIST_MAX_FINITE: u64 = (1u64 << (HIST_BUCKETS - 2)) - 1;

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed level (queue depth, in-flight frames).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge(AtomicI64::new(0))
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Lock-free log2-bucketed histogram of `u64` samples (latencies in
/// microseconds, queue depths, byte counts — unit is the caller's).
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

/// Bucket index for a sample (see [`HIST_BUCKETS`]).
fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        let bit_len = (64 - v.leading_zeros()) as usize;
        bit_len.min(HIST_BUCKETS - 1)
    }
}

/// Inclusive upper bound of a bucket (used as the quantile estimate).
fn bucket_upper(b: usize) -> u64 {
    if b == 0 {
        0
    } else if b >= HIST_BUCKETS - 1 {
        u64::MAX
    } else {
        (1u64 << b) - 1
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one sample: three relaxed atomic adds, no locks.
    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

/// Read-side copy of a [`Histogram`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistogramSnapshot {
    /// Per-bucket counts, `HIST_BUCKETS` long.
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: u64,
}

impl Default for HistogramSnapshot {
    fn default() -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: vec![0; HIST_BUCKETS],
            count: 0,
            sum: 0,
        }
    }
}

impl HistogramSnapshot {
    /// Bucket-wise sum of two snapshots (commutative and associative —
    /// asserted in `integration_telemetry`).
    pub fn merge(&self, other: &HistogramSnapshot) -> HistogramSnapshot {
        let mut buckets = self.buckets.clone();
        for (b, o) in buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        HistogramSnapshot {
            buckets,
            count: self.count + other.count,
            sum: self.sum + other.sum,
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Count in the explicit overflow bucket.
    pub fn overflow(&self) -> u64 {
        *self.buckets.last().unwrap_or(&0)
    }

    /// Nearest-rank quantile estimate for `p` percent: the inclusive
    /// upper bound of the bucket holding the ranked sample (0 when
    /// empty).  Same rank convention as `benchutil::nearest_rank_index`.
    pub fn quantile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let p = if p.is_finite() {
            p.clamp(0.0, 100.0)
        } else {
            100.0
        };
        let rank = ((p / 100.0 * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (b, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return bucket_upper(b);
            }
        }
        bucket_upper(HIST_BUCKETS - 1)
    }

    /// [`Self::quantile`] with overflow made explicit: `(value,
    /// saturated)`.  A rank landing in the overflow bucket clamps to
    /// [`HIST_MAX_FINITE`] with `saturated = true` — the true value is
    /// only known to be *at least* that, and `u64::MAX` must never be
    /// reported as if it were measured.
    pub fn quantile_clamped(&self, p: f64) -> (u64, bool) {
        let v = self.quantile(p);
        if v > HIST_MAX_FINITE {
            (HIST_MAX_FINITE, true)
        } else {
            (v, false)
        }
    }

    fn to_json(&self) -> Json {
        // Trim trailing empty buckets — deterministic and keeps the
        // document readable; count/sum preserve the full information.
        let last = self.buckets.iter().rposition(|&n| n != 0).map_or(0, |i| i + 1);
        let (p50, s50) = self.quantile_clamped(50.0);
        let (p95, s95) = self.quantile_clamped(95.0);
        let (p99, s99) = self.quantile_clamped(99.0);
        json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("sum", Json::num(self.sum as f64)),
            ("mean", Json::num(self.mean())),
            ("p50", Json::num(p50 as f64)),
            ("p95", Json::num(p95 as f64)),
            ("p99", Json::num(p99 as f64)),
            // True when any quantile above ranked into the overflow
            // bucket: those fields are clamped floors, not measurements.
            ("quantiles_saturated", Json::Bool(s50 || s95 || s99)),
            ("overflow", Json::num(self.overflow() as f64)),
            (
                "buckets",
                Json::Arr(
                    self.buckets[..last]
                        .iter()
                        .map(|&n| Json::num(n as f64))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Named metric registry.  `counter`/`gauge`/`histogram` get-or-create
/// and hand back `Arc` handles to record through; the registry lock is
/// only taken at resolve and snapshot time.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

static GLOBAL: OnceLock<Registry> = OnceLock::new();

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// The process-wide registry (`bwade serve --metrics-json` exports
    /// it; library code may record into it unconditionally — recording
    /// into an unexported registry costs a few relaxed atomics).
    pub fn global() -> &'static Registry {
        GLOBAL.get_or_init(Registry::new)
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.counters.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.gauges.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.histograms.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// Deterministic snapshot: metrics sorted by name (`BTreeMap`
    /// ordering), values read relaxed.
    pub fn snapshot(&self) -> RegistrySnapshot {
        RegistrySnapshot {
            counters: self
                .counters
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            gauges: self
                .gauges
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            histograms: self
                .histograms
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.snapshot()))
                .collect(),
        }
    }
}

/// Point-in-time copy of a whole [`Registry`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RegistrySnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub histograms: BTreeMap<String, HistogramSnapshot>,
}

impl RegistrySnapshot {
    /// Merge two snapshots (e.g. per-replica registries): counters and
    /// gauges sum, histograms merge bucket-wise.
    pub fn merge(&self, other: &RegistrySnapshot) -> RegistrySnapshot {
        let mut out = self.clone();
        for (k, v) in &other.counters {
            *out.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            *out.gauges.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.histograms {
            let merged = match out.histograms.get(k) {
                Some(mine) => mine.merge(v),
                None => v.clone(),
            };
            out.histograms.insert(k.clone(), merged);
        }
        out
    }

    /// The `bwade/telemetry/v1` document: metric names sorted, bucket
    /// arrays trimmed of trailing zeros.
    pub fn to_json(&self) -> Json {
        let counters: BTreeMap<String, Json> = self
            .counters
            .iter()
            .map(|(k, &v)| (k.clone(), Json::num(v as f64)))
            .collect();
        let gauges: BTreeMap<String, Json> = self
            .gauges
            .iter()
            .map(|(k, &v)| (k.clone(), Json::num(v as f64)))
            .collect();
        let histograms: BTreeMap<String, Json> = self
            .histograms
            .iter()
            .map(|(k, v)| (k.clone(), v.to_json()))
            .collect();
        json::obj(vec![
            ("schema", Json::str(TELEMETRY_SCHEMA)),
            ("counters", json::obj_sorted(counters)),
            ("gauges", json::obj_sorted(gauges)),
            ("histograms", json::obj_sorted(histograms)),
        ])
    }

    /// One-line summary for the periodic stderr emitter:
    /// `telemetry: a=1 b=-2 h{n=3 mean=41 p95=63}`.
    pub fn summary_line(&self) -> String {
        let mut parts = Vec::new();
        for (k, v) in &self.counters {
            parts.push(format!("{k}={v}"));
        }
        for (k, v) in &self.gauges {
            parts.push(format!("{k}={v}"));
        }
        for (k, v) in &self.histograms {
            // A saturated p95 is a floor, not a measurement — print it
            // as `p95>=` so the log never passes u64::MAX off as real.
            let (p95, saturated) = v.quantile_clamped(95.0);
            let cmp = if saturated { ">=" } else { "=" };
            parts.push(format!(
                "{k}{{n={} mean={:.0} p95{cmp}{p95}}}",
                v.count,
                v.mean(),
            ));
        }
        if parts.is_empty() {
            "telemetry: (no metrics)".to_string()
        } else {
            format!("telemetry: {}", parts.join(" "))
        }
    }
}

/// Write a snapshot as a pretty-printed `bwade/telemetry/v1` document.
pub fn write_metrics_json(path: &Path, snap: &RegistrySnapshot) -> Result<()> {
    std::fs::write(path, snap.to_json().to_string_pretty() + "\n")
        .with_context(|| format!("writing {}", path.display()))
}

/// Background thread printing `summary_line()` to stderr every
/// `interval` while a serve run is in flight; prints one final line on
/// `stop()` (or drop) so short runs still surface their metrics.
pub struct StderrEmitter {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl StderrEmitter {
    pub fn spawn(registry: &'static Registry, interval: Duration) -> StderrEmitter {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            let mut last = Instant::now();
            while !stop2.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(20));
                if last.elapsed() >= interval {
                    eprintln!("{}", registry.snapshot().summary_line());
                    last = Instant::now();
                }
            }
            eprintln!("{}", registry.snapshot().summary_line());
        });
        StderrEmitter {
            stop,
            handle: Some(handle),
        }
    }

    /// Stop the emitter and wait for its final line.
    pub fn stop(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for StderrEmitter {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_rule() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(7), 3);
        assert_eq!(bucket_of(8), 4);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
        // Each non-overflow bucket's upper bound lands in that bucket.
        for b in 0..HIST_BUCKETS - 1 {
            assert_eq!(bucket_of(bucket_upper(b)), b);
        }
    }

    #[test]
    fn counter_and_gauge() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.set(7);
        g.add(-10);
        assert_eq!(g.get(), -3);
    }

    #[test]
    fn histogram_records_and_quantiles() {
        let h = Histogram::new();
        for v in [1u64, 2, 3, 100, 1000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum, 1106);
        assert!((s.mean() - 221.2).abs() < 1e-9);
        // p50 ranks to the 3rd sample (value 3, bucket [2,3] → upper 3).
        assert_eq!(s.quantile(50.0), 3);
        // p100 ranks to the last sample (1000, bucket [512,1023]).
        assert_eq!(s.quantile(100.0), 1023);
        assert_eq!(s.overflow(), 0);
    }

    #[test]
    fn overflow_quantiles_clamp_and_flag() {
        let h = Histogram::new();
        h.record(3);
        h.record(3);
        h.record(u64::MAX);
        let s = h.snapshot();
        // p50 ranks inside a finite bucket: clamping is a no-op.
        assert_eq!(s.quantile_clamped(50.0), (3, false));
        // p100 ranks into the overflow bucket: the raw estimate still
        // saturates to u64::MAX, but the clamped view reports the last
        // finite bucket's upper bound and flags it.
        assert_eq!(s.quantile(100.0), u64::MAX);
        assert_eq!(s.quantile_clamped(100.0), (HIST_MAX_FINITE, true));
        assert_eq!(HIST_MAX_FINITE, (1u64 << 38) - 1);

        // The JSON exposition uses the clamped values and carries the
        // saturation flag so consumers can tell floor from measurement.
        let r = Registry::new();
        r.histogram("lat").record(3);
        r.histogram("lat").record(3);
        r.histogram("lat").record(u64::MAX);
        let doc = r.snapshot().to_json();
        let lat = doc.get("histograms").unwrap().get("lat").unwrap();
        assert_eq!(lat.get("p99").unwrap().as_f64().unwrap(), HIST_MAX_FINITE as f64);
        assert!(lat.get("quantiles_saturated").unwrap().as_bool().unwrap());

        // A histogram with no overflow samples reports the flag false.
        let r2 = Registry::new();
        r2.histogram("ok").record(5);
        let doc2 = r2.snapshot().to_json();
        let ok = doc2.get("histograms").unwrap().get("ok").unwrap();
        assert!(!ok.get("quantiles_saturated").unwrap().as_bool().unwrap());
    }

    #[test]
    fn summary_line_flags_saturated_quantiles() {
        let r = Registry::new();
        r.histogram("lat").record(u64::MAX);
        let line = r.snapshot().summary_line();
        assert!(
            line.contains("p95>=274877906943"),
            "saturated p95 must print as a flagged floor: {line}"
        );
        let r2 = Registry::new();
        r2.histogram("lat").record(100);
        let line2 = r2.snapshot().summary_line();
        assert!(line2.contains("p95=127"), "finite p95 prints plainly: {line2}");
    }

    #[test]
    fn registry_handles_are_shared() {
        let r = Registry::new();
        let a = r.counter("x");
        let b = r.counter("x");
        a.inc();
        b.inc();
        assert_eq!(r.snapshot().counters["x"], 2);
    }

    #[test]
    fn snapshot_json_shape() {
        let r = Registry::new();
        r.counter("pool.steals").add(3);
        r.gauge("pool.inflight").set(2);
        r.histogram("pool.queue_depth").record(5);
        let doc = r.snapshot().to_json().to_string_pretty();
        let parsed = Json::parse(&doc).expect("telemetry document parses");
        assert_eq!(
            parsed.get("schema").unwrap().as_str().unwrap(),
            TELEMETRY_SCHEMA
        );
        assert_eq!(
            parsed
                .get("counters")
                .unwrap()
                .get("pool.steals")
                .unwrap()
                .as_usize()
                .unwrap(),
            3
        );
        let h = parsed.get("histograms").unwrap().get("pool.queue_depth").unwrap();
        assert_eq!(h.get("count").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn snapshot_merge_sums() {
        let a = Registry::new();
        a.counter("c").add(1);
        a.histogram("h").record(10);
        let b = Registry::new();
        b.counter("c").add(2);
        b.counter("only_b").add(5);
        b.histogram("h").record(20);
        let m = a.snapshot().merge(&b.snapshot());
        assert_eq!(m.counters["c"], 3);
        assert_eq!(m.counters["only_b"], 5);
        assert_eq!(m.histograms["h"].count, 2);
        assert_eq!(m.histograms["h"].sum, 30);
    }
}
