//! Few-shot learning over extracted features: episode sampling and the
//! Nearest-Class-Mean classifier (Fig. 1 steps 2-3, Fig. 5's CPU side).
//!
//! The backbone (FPGA side / PJRT executable) turns images into feature
//! vectors; the NCM classifier here builds class prototypes from the
//! support set and classifies queries by nearest prototype.  Following
//! the EASY recipe, features are L2-normalized before prototype
//! computation — this is what PEFSL runs on the ARM core.

use anyhow::{bail, Result};

use crate::rng::Rng;

/// An n-way k-shot episode over a class-major image bank.
#[derive(Debug, Clone)]
pub struct Episode {
    /// Bank image indices of the support set.
    pub support: Vec<usize>,
    /// Episode-local labels (0..n_way) aligned with `support`.
    pub support_labels: Vec<usize>,
    pub query: Vec<usize>,
    pub query_labels: Vec<usize>,
    pub n_way: usize,
}

/// Sample one episode from a class-major bank (`per_class` images per
/// class, image i has class i / per_class).
pub fn sample_episode(
    rng: &mut Rng,
    num_classes: usize,
    per_class: usize,
    n_way: usize,
    k_shot: usize,
    n_query: usize,
) -> Result<Episode> {
    if n_way > num_classes {
        bail!("n_way {n_way} > classes {num_classes}");
    }
    if k_shot + n_query > per_class {
        bail!("k_shot + n_query {} > per_class {per_class}", k_shot + n_query);
    }
    let classes = rng.choose_k(num_classes, n_way);
    let mut ep = Episode {
        support: Vec::with_capacity(n_way * k_shot),
        support_labels: Vec::with_capacity(n_way * k_shot),
        query: Vec::with_capacity(n_way * n_query),
        query_labels: Vec::with_capacity(n_way * n_query),
        n_way,
    };
    for (label, &cls) in classes.iter().enumerate() {
        let picks = rng.choose_k(per_class, k_shot + n_query);
        for (j, &p) in picks.iter().enumerate() {
            let idx = cls * per_class + p;
            if j < k_shot {
                ep.support.push(idx);
                ep.support_labels.push(label);
            } else {
                ep.query.push(idx);
                ep.query_labels.push(label);
            }
        }
    }
    Ok(ep)
}

/// L2-normalize a feature vector in place (EASY preprocessing).
pub fn l2_normalize(v: &mut [f32]) {
    let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    if norm > 1e-12 {
        for x in v {
            *x /= norm;
        }
    }
}

/// Nearest-Class-Mean classifier.
#[derive(Debug, Clone)]
pub struct NcmClassifier {
    pub prototypes: Vec<Vec<f32>>,
    pub dim: usize,
}

impl NcmClassifier {
    /// Fit from support features (`n_way` classes, episode-local labels).
    /// Features are L2-normalized before averaging.
    pub fn fit(
        features: &[f32],
        dim: usize,
        labels: &[usize],
        n_way: usize,
    ) -> Result<Self> {
        if features.len() != labels.len() * dim {
            bail!("feature buffer size mismatch");
        }
        let mut protos = vec![vec![0.0f32; dim]; n_way];
        let mut counts = vec![0usize; n_way];
        for (i, &label) in labels.iter().enumerate() {
            if label >= n_way {
                bail!("label {label} out of range");
            }
            let mut f = features[i * dim..(i + 1) * dim].to_vec();
            l2_normalize(&mut f);
            for (p, x) in protos[label].iter_mut().zip(&f) {
                *p += x;
            }
            counts[label] += 1;
        }
        for (proto, &count) in protos.iter_mut().zip(&counts) {
            if count == 0 {
                bail!("class with no support samples");
            }
            for p in proto.iter_mut() {
                *p /= count as f32;
            }
        }
        Ok(Self {
            prototypes: protos,
            dim,
        })
    }

    /// Classify one feature vector (L2-normalized internally): nearest
    /// prototype by Euclidean distance.
    pub fn predict(&self, feature: &[f32]) -> usize {
        let mut f = feature.to_vec();
        l2_normalize(&mut f);
        let mut best = 0;
        let mut best_d = f32::MAX;
        for (c, proto) in self.prototypes.iter().enumerate() {
            let d: f32 = proto
                .iter()
                .zip(&f)
                .map(|(p, x)| (p - x) * (p - x))
                .sum();
            if d < best_d {
                best_d = d;
                best = c;
            }
        }
        best
    }
}

/// Accuracy of one episode given per-image features of the whole bank.
pub fn episode_accuracy(
    bank_features: &[f32],
    dim: usize,
    ep: &Episode,
) -> Result<f64> {
    let gather = |idxs: &[usize]| -> Vec<f32> {
        let mut out = Vec::with_capacity(idxs.len() * dim);
        for &i in idxs {
            out.extend_from_slice(&bank_features[i * dim..(i + 1) * dim]);
        }
        out
    };
    let support = gather(&ep.support);
    let ncm = NcmClassifier::fit(&support, dim, &ep.support_labels, ep.n_way)?;
    let mut correct = 0usize;
    for (qi, &idx) in ep.query.iter().enumerate() {
        let pred = ncm.predict(&bank_features[idx * dim..(idx + 1) * dim]);
        if pred == ep.query_labels[qi] {
            correct += 1;
        }
    }
    Ok(correct as f64 / ep.query.len() as f64)
}

/// Mean accuracy with 95% confidence interval over many episodes.
#[derive(Debug, Clone, Copy)]
pub struct AccuracyReport {
    pub mean: f64,
    pub ci95: f64,
    pub episodes: usize,
}

pub fn evaluate(
    bank_features: &[f32],
    dim: usize,
    episodes: &[Episode],
) -> Result<AccuracyReport> {
    if episodes.is_empty() {
        bail!("no episodes");
    }
    let accs: Vec<f64> = episodes
        .iter()
        .map(|ep| episode_accuracy(bank_features, dim, ep))
        .collect::<Result<_>>()?;
    let n = accs.len() as f64;
    let mean = accs.iter().sum::<f64>() / n;
    let var = accs.iter().map(|a| (a - mean) * (a - mean)).sum::<f64>() / (n - 1.0).max(1.0);
    Ok(AccuracyReport {
        mean,
        ci95: 1.96 * (var / n).sqrt(),
        episodes: accs.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn episode_sampling_valid() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let ep = sample_episode(&mut rng, 20, 40, 5, 5, 15).unwrap();
            assert_eq!(ep.support.len(), 25);
            assert_eq!(ep.query.len(), 75);
            // No overlap between support and query.
            for q in &ep.query {
                assert!(!ep.support.contains(q));
            }
            // Labels consistent with bank layout.
            for (i, &idx) in ep.support.iter().enumerate() {
                let cls_in_bank = idx / 40;
                let same_label: Vec<usize> = ep
                    .support
                    .iter()
                    .enumerate()
                    .filter(|(j, _)| ep.support_labels[*j] == ep.support_labels[i])
                    .map(|(_, &x)| x / 40)
                    .collect();
                assert!(same_label.iter().all(|&c| c == cls_in_bank));
            }
        }
    }

    #[test]
    fn episode_rejects_impossible_requests() {
        let mut rng = Rng::new(2);
        assert!(sample_episode(&mut rng, 4, 40, 5, 5, 15).is_err());
        assert!(sample_episode(&mut rng, 20, 10, 5, 5, 15).is_err());
    }

    #[test]
    fn ncm_separates_clean_clusters() {
        // 3 well-separated prototypes in 8 dims.
        let dim = 8;
        let mut rng = Rng::new(3);
        let mut features = Vec::new();
        let mut labels = Vec::new();
        for c in 0..3 {
            for _ in 0..4 {
                let mut f = vec![0.1f32; dim];
                f[c] = 5.0 + rng.next_f32();
                features.extend_from_slice(&f);
                labels.push(c);
            }
        }
        let ncm = NcmClassifier::fit(&features, dim, &labels, 3).unwrap();
        let mut probe = vec![0.1f32; dim];
        probe[2] = 4.0;
        assert_eq!(ncm.predict(&probe), 2);
        probe[2] = 0.1;
        probe[0] = 9.0;
        assert_eq!(ncm.predict(&probe), 0);
    }

    #[test]
    fn l2_normalize_unit_norm() {
        let mut v = vec![3.0, 4.0];
        l2_normalize(&mut v);
        let n = (v[0] * v[0] + v[1] * v[1]).sqrt();
        assert!((n - 1.0).abs() < 1e-6);
        let mut z = vec![0.0, 0.0];
        l2_normalize(&mut z); // must not NaN
        assert_eq!(z, vec![0.0, 0.0]);
    }

    #[test]
    fn evaluate_perfect_features_give_full_accuracy() {
        // Bank: 4 classes x 10 images; features = one-hot of the class.
        let dim = 4;
        let per = 10;
        let mut bank = Vec::new();
        for c in 0..4 {
            for _ in 0..per {
                let mut f = vec![0.0f32; dim];
                f[c] = 1.0;
                bank.extend_from_slice(&f);
            }
        }
        let mut rng = Rng::new(4);
        let eps: Vec<Episode> = (0..20)
            .map(|_| sample_episode(&mut rng, 4, per, 2, 2, 4).unwrap())
            .collect();
        let report = evaluate(&bank, dim, &eps).unwrap();
        assert_eq!(report.mean, 1.0);
        assert_eq!(report.episodes, 20);
    }

    #[test]
    fn evaluate_random_features_near_chance() {
        let dim = 16;
        let per = 20;
        let mut rng = Rng::new(5);
        let mut bank = Vec::new();
        for _ in 0..5 * per {
            for _ in 0..dim {
                bank.push(rng.normal());
            }
        }
        let eps: Vec<Episode> = (0..100)
            .map(|_| sample_episode(&mut rng, 5, per, 5, 5, 10).unwrap())
            .collect();
        let report = evaluate(&bank, dim, &eps).unwrap();
        assert!((report.mean - 0.2).abs() < 0.08, "mean {}", report.mean);
    }

    #[test]
    fn deterministic_episodes_for_same_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let e1 = sample_episode(&mut a, 10, 10, 3, 2, 3).unwrap();
        let e2 = sample_episode(&mut b, 10, 10, 3, 2, 3).unwrap();
        assert_eq!(e1.support, e2.support);
        assert_eq!(e1.query, e2.query);
    }
}
