//! bwade CLI — leader entrypoint for the design environment and the
//! serving runtime.  `bwade help` for usage.

#![allow(clippy::too_many_arguments, clippy::field_reassign_with_default)]

use std::path::Path;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use bwade::artifacts::{ArtifactPaths, FewshotBank, ModelBundle};
use bwade::benchutil::{write_serving_json, ServingRow};
use bwade::build::{
    build, implement_lowered, lower_bit_true, requantize_graph, synth_backbone_graph, DesignConfig,
};
use bwade::cli::{parse_config, parse_config_list, parse_f64_list, parse_topology, Args, USAGE};
use bwade::coordinator::{
    serve, serve_pool_with, BatchPolicy, Classified, FeatureExtractor, Frame, FrameSource, Metrics,
    PipelineReplica,
};
use bwade::dse::{run_sweep_with, write_report_with_telemetry, ResultCache, SweepOptions, SweepSpec};
use bwade::fewshot::{evaluate, sample_episode, NcmClassifier};
use bwade::fixedpoint::{baseline16_config, table2_configs, QuantConfig};
use bwade::graph::Graph;
use bwade::json::{self, Json};
use bwade::plan::elastic::{rebalance, sample_stages, ElasticPolicy};
use bwade::plan::pipeline::{PipelineSpec, PlanPipeline};
use bwade::plan::{Datapath, PlanRunner};
use bwade::resources::{utilization_line, Device};
use bwade::rng::Rng;
use bwade::runtime::{BackboneRunner, Runtime};
use bwade::systolic::{layers_from_meta, simulate, SystolicConfig};
use bwade::telemetry::{write_metrics_json, Registry, StderrEmitter};
use bwade::transforms::{convert_to_hw, run_default_pipeline};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "build" => cmd_build(&args),
        "dse" => cmd_dse(&args),
        "compare" => cmd_compare(&args),
        "table2" => cmd_table2(&args),
        "serve" => cmd_serve(&args),
        "profile" => cmd_profile(&args),
        "episodes" => cmd_episodes(&args),
        "info" => cmd_info(&args),
        "help" | "" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?} (try `bwade help`)"),
    }
}

fn load_graph(paths: &ArtifactPaths) -> Result<Graph> {
    Graph::load(&paths.graph_json(), &paths.graph_weights())
        .context("loading artifacts/graph.json — run `make artifacts` first")
}

/// Default backbone engine: PJRT when compiled in, else the plan engine.
fn default_engine() -> &'static str {
    if cfg!(feature = "pjrt") {
        "pjrt"
    } else {
        "plan"
    }
}

/// Backbone engine factory (`--engine pjrt|plan`): loads the shared state
/// once — the PJRT client for `pjrt`, the float compiler graph for `plan`
/// — and builds one extractor per bit-width config.
///
/// Declare the factory BEFORE the extractors it produces: locals drop in
/// reverse declaration order, so the PJRT client outlives every
/// executable built from it.
struct EngineFactory {
    engine: String,
    datapath: Datapath,
    runtime: Option<Runtime>,
    graph: Option<Graph>,
}

impl EngineFactory {
    fn new(engine: &str, datapath: Datapath, paths: &ArtifactPaths) -> Result<Self> {
        if datapath == Datapath::BitTrue && engine != "plan" {
            bail!("--datapath bit-true requires --engine plan (the PJRT executable is f32-only)");
        }
        let (runtime, graph) = match engine {
            "pjrt" => (Some(Runtime::new()?), None),
            // The compiled-plan engine executes the exported compiler
            // graph directly — no XLA, no python, weights PTQ'd in rust.
            "plan" => (None, Some(load_graph(paths)?)),
            other => bail!("unknown engine {other:?} (use pjrt or plan)"),
        };
        Ok(Self {
            engine: engine.to_string(),
            datapath,
            runtime,
            graph,
        })
    }

    /// A plan-engine factory over the dse's synthetic backbone — the
    /// artifact-free serving path (`bwade serve --synth`, the CI smoke
    /// job): same graph the dse sweeps, so it needs no `make artifacts`.
    fn new_synth(datapath: Datapath, spec: &SweepSpec, cfg: &QuantConfig) -> Self {
        let graph = synth_backbone_graph(spec.widths, spec.img, cfg.act.bits, cfg.act.frac_bits);
        Self {
            engine: "plan".to_string(),
            datapath,
            runtime: None,
            graph: Some(graph),
        }
    }

    fn make(
        &self,
        paths: &ArtifactPaths,
        bundle: Option<&ModelBundle>,
        batch: usize,
        cfg: QuantConfig,
    ) -> Result<Box<dyn FeatureExtractor>> {
        match self.engine.as_str() {
            "pjrt" => {
                let runtime = self.runtime.as_ref().expect("pjrt factory has a client");
                let bundle = bundle.ok_or_else(|| anyhow!("pjrt engine needs the model bundle"))?;
                Ok(Box::new(BackboneRunner::new(
                    runtime,
                    bundle,
                    &paths.backbone_hlo(batch),
                    batch,
                    cfg,
                )?))
            }
            _ => Ok(Box::new(self.make_plan(batch, cfg)?)),
        }
    }

    /// The plan-engine path of [`EngineFactory::make`], concretely typed:
    /// the multi-replica serving tier needs the `PlanRunner` itself so it
    /// can `replicate()` the compiled plan across pool threads.
    fn make_plan(&self, batch: usize, cfg: QuantConfig) -> Result<PlanRunner> {
        // A fresh copy of the float import per config.
        let mut graph = self.graph.clone().expect("plan factory has a graph");
        match self.datapath {
            // PTQ only: the f32 simulation of the quantized net.
            Datapath::F32 => {
                requantize_graph(&mut graph, &cfg)?;
                PlanRunner::new(&graph, batch)
            }
            // PTQ + full lowering + format annotation: the
            // bit-exact integer datapath of the deployed design.
            Datapath::BitTrue => {
                lower_bit_true(&mut graph, &cfg)?;
                PlanRunner::new_bit_true(&graph, batch)
            }
        }
    }
}

fn cmd_build(args: &Args) -> Result<()> {
    let paths = ArtifactPaths::default_dir();
    let mut graph = load_graph(&paths)?;
    let cfg = DesignConfig {
        quant: parse_config(args.get_or("config", "b6_c1.5_r2.2"))?,
        target_fps: Some(args.get_f64("target-fps", 60.0)?),
        max_utilization: args.get_f64("max-util", 0.85)?,
        verify: args.has_flag("verify"),
    };
    let device = Device::pynq_z1();
    println!("building {} for {} ...", graph.name, device.name);
    let report = build(&mut graph, &cfg, &device)?;
    println!("\n== transform stages ==");
    for s in &report.stages {
        println!(
            "  {:<42} x{:<3} nodes {:<3} {}",
            s.transform,
            s.applications,
            s.nodes_after,
            s.max_divergence
                .map(|d| format!("max div {d:.2e}"))
                .unwrap_or_default()
        );
    }
    println!("\n== node census ==");
    let mut before: Vec<_> = report.census_before.iter().collect();
    before.sort();
    println!("  before: {before:?}");
    let mut after: Vec<_> = report.census_after.iter().collect();
    after.sort();
    println!("  after:  {after:?}");
    println!("\n== per-layer ==");
    for m in &report.models {
        println!(
            "  {:<28} {:<26} cycles {:>9}  {}",
            m.name, m.op, m.cycles, m.resources
        );
    }
    println!("\n== result ==\n{}", report.summary());
    Ok(())
}

fn cmd_dse(args: &Args) -> Result<()> {
    let mut spec = SweepSpec::default();
    spec.episodes = args.get_usize("episodes", spec.episodes)?;
    spec.seed = args.get_usize("seed", spec.seed as usize)? as u64;
    spec.img = args.get_usize("img", spec.img)?;
    if let Some(caps) = args.get("caps") {
        spec.caps = parse_f64_list(caps)?;
    }
    if let Some(configs) = args.get("configs") {
        spec.configs = parse_config_list(configs)?;
    }
    if args.get("target-fps").is_some() {
        spec.target_fps = Some(args.get_f64("target-fps", 0.0)?);
    }
    spec.datapath = Datapath::parse(args.get_or("datapath", "f32"))?;
    let workers = args.get_usize("workers", 4)?;
    let cache = match args.get("cache") {
        Some(dir) => Some(ResultCache::open(dir)?),
        None if args.has_flag("cache") => Some(ResultCache::open(".dse-cache")?),
        None => None,
    };
    let out = args.get_or("out", "EXPERIMENTS.md").to_string();

    println!(
        "dse: {} configs x {} caps = {} design points on {}  ({} workers, {} episodes/point, datapath {}, cache: {})",
        spec.configs.len(),
        spec.caps.len(),
        spec.configs.len() * spec.caps.len(),
        spec.device.name,
        workers,
        spec.episodes,
        spec.datapath.describe(),
        cache
            .as_ref()
            .map(|c| c.dir().display().to_string())
            .unwrap_or_else(|| "off".to_string()),
    );
    let result = run_sweep_with(&spec, workers, cache.as_ref(), SweepOptions { progress: true })?;

    println!(
        "\n{:<16} {:>5} {:>9} {:>8} {:>10} {:>9} {:>9} {:>7}",
        "config", "cap", "acc[%]", "util[%]", "fps", "lat[ms]", "KiB/f", ""
    );
    for (i, o) in result.outcomes.iter().enumerate() {
        println!(
            "{:<16} {:>5.2} {:>8.2}% {:>7.1}% {:>10.1} {:>9.3} {:>9.1} {:>7}{}",
            o.point.name,
            o.point.max_utilization,
            o.metrics.acc_mean * 100.0,
            o.metrics.utilization * 100.0,
            o.metrics.fps,
            o.metrics.latency_ms,
            o.metrics.bytes_per_frame as f64 / 1024.0,
            match (o.cached, result.pareto.contains(&i)) {
                (true, true) => "cached*",
                (true, false) => "cached",
                (false, true) => "*",
                (false, false) => "",
            },
            if o.metrics.non_dyadic_scales > 0 {
                "  ⚠ non-dyadic scales"
            } else {
                ""
            },
        );
    }
    write_report_with_telemetry(Path::new(&out), &spec, &result)?;
    println!(
        "\nPareto frontier (* above): {} of {} points",
        result.pareto.len(),
        result.outcomes.len()
    );
    println!(
        "evaluated {} points, {} cache hits; report -> {}",
        result.evaluated, result.cached, out
    );
    println!(
        "sweep wall {:.1} s, mean point build {:.2} s{}",
        result.timing.wall_s,
        result.timing.mean_point_s(),
        result
            .timing
            .max_point()
            .map(|(i, s)| format!(
                ", slowest {:.2} s ({} @ cap {:.2})",
                s, result.outcomes[i].point.name, result.outcomes[i].point.max_utilization
            ))
            .unwrap_or_default()
    );
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let _ = args;
    let paths = ArtifactPaths::default_dir();
    let bundle = paths.model_bundle()?;
    let device = Device::pynq_z1();
    let cfg = DesignConfig {
        target_fps: None,
        max_utilization: 0.70,
        ..DesignConfig::default()
    };
    let sys_cfg = SystolicConfig::tensil_pynq_z1();

    let row = |name: &str,
               prec: u8,
               r: &bwade::resources::Resources,
               latency_ms: f64,
               fps: f64| {
        println!(
            "{:<26} {:>6} {:>9.0} {:>8.1} {:>8.0} {:>6.0} {:>12.2} {:>9.1}",
            name, prec, r.lut, r.bram36, r.ff, r.dsp, latency_ms, fps
        );
    };

    println!("== Table III: CIFAR-10-like inference on PYNQ-Z1 (simulated) ==");
    println!(
        "{:<26} {:>6} {:>9} {:>8} {:>8} {:>6} {:>12} {:>9}",
        "work", "prec", "LUT", "BRAM36", "FF", "DSP", "latency[ms]", "fps"
    );

    // --- Deployed model scale (the trained artifact, widths 8..64). ---
    let mut graph = load_graph(&paths)?;
    let finn = build(&mut graph, &cfg, &device)?;
    let layers = layers_from_meta(&bundle.layers, bundle.img);
    let tensil = simulate(&sys_cfg, &baseline16_config(), &layers);
    row(
        "Tensil/PEFSL (deployed)",
        16,
        &tensil.resources,
        device.cycles_to_ms(tensil.total_cycles),
        device.fps(tensil.total_cycles),
    );
    row(
        "FINN/ours (deployed)",
        finn.config.weight.bits,
        &finn.total_resources,
        finn.latency_ms,
        finn.fps,
    );

    // --- Paper model scale (PEFSL widths 16/32/64/128) — the Table III
    //     reproduction proper; shapes only, no trained weights needed. ---
    let mut big = bwade::build::synth_backbone_graph([16, 32, 64, 128], 32, 4, 2);
    // The paper deployed its FINN build at the 61.5 fps operating point
    // (Fig. 5), not at maximum folding — fold to that target.
    let paper_point = DesignConfig {
        target_fps: Some(61.5),
        ..cfg.clone()
    };
    let finn_big = build(&mut big, &paper_point, &device)?;
    let big_metas: Vec<bwade::artifacts::LayerMeta> = bundle
        .layers
        .iter()
        .map(|l| bwade::artifacts::LayerMeta {
            name: l.name.clone(),
            cin: if l.cin == 3 { 3 } else { l.cin * 2 },
            cout: l.cout * 2,
            pool: l.pool,
            res_begin: l.res_begin,
            res_add: l.res_add,
        })
        .collect();
    let tensil_big = simulate(
        &sys_cfg,
        &baseline16_config(),
        &layers_from_meta(&big_metas, bundle.img),
    );
    row(
        "Tensil/PEFSL (paper scale)",
        16,
        &tensil_big.resources,
        device.cycles_to_ms(tensil_big.total_cycles),
        device.fps(tensil_big.total_cycles),
    );
    row(
        "FINN/ours (paper scale)",
        finn_big.config.weight.bits,
        &finn_big.total_resources,
        finn_big.latency_ms,
        finn_big.fps,
    );

    println!("\npaper:   PEFSL 16b: 15667 LUT / 59 BRAM / 9819 FF / 159 DSP / 35.9 ms");
    println!("paper:   ours   6b: 37263 LUT / 131.5 BRAM / 44617 FF / 22 DSP / 16.3 ms (61.5 fps)");
    println!(
        "\nspeedup dataflow vs systolic:  deployed {:.2}x, paper scale {:.2}x   (paper: {:.2}x)",
        tensil.total_cycles as f64 / finn.latency_cycles.max(1) as f64,
        tensil_big.total_cycles as f64 / finn_big.latency_cycles.max(1) as f64,
        35.9 / 16.3
    );
    println!(
        "DRAM traffic (Tensil): {:.2} MiB/frame deployed, {:.2} MiB/frame paper scale — FINN: 0 (weights in BRAM, Table I)",
        tensil.total_dram_bytes as f64 / (1024.0 * 1024.0),
        tensil_big.total_dram_bytes as f64 / (1024.0 * 1024.0)
    );
    Ok(())
}

fn cmd_table2(args: &Args) -> Result<()> {
    let episodes = args.get_usize("episodes", 200)?;
    let engine = args.get_or("engine", default_engine()).to_string();
    let datapath = Datapath::parse(args.get_or("datapath", "f32"))?;
    let paths = ArtifactPaths::default_dir();
    let bundle = paths.model_bundle()?;
    let bank = FewshotBank::load(&paths.fewshot_bank())?;
    let batch = *bundle.batch_sizes.iter().max().unwrap_or(&1);
    let factory = EngineFactory::new(&engine, datapath, &paths)?;

    println!(
        "== Table II: accuracy on the synthetic novel split (5-way 5-shot, engine {engine}, datapath {}) ==",
        datapath.describe()
    );
    println!("{:<16} {:>8} {:>12} {:>10}", "config", "max bits", "acc [%]", "ci95");
    let mut rng = Rng::new(0xEE);
    let eps: Vec<_> = (0..episodes)
        .map(|_| sample_episode(&mut rng, bank.num_classes, bank.per_class, 5, 5, 15))
        .collect::<Result<_>>()?;
    for (name, cfg) in table2_configs() {
        let runner = factory.make(&paths, Some(&bundle), batch, cfg)?;
        let feats = runner.extract_all(&bank.images, bank.num_images())?;
        let report = evaluate(&feats, bundle.feature_dim, &eps)?;
        println!(
            "{:<16} {:>8} {:>11.2}% {:>9.2}%",
            name,
            cfg.max_bits(),
            report.mean * 100.0,
            report.ci95 * 100.0
        );
    }
    println!("\npaper (CIFAR-10): 44.89 / 59.70 / 44.72 / 60.92 / 62.58 / 62.69 / 62.47 / 62.78");
    Ok(())
}

/// Spawn `streams` concurrent camera sources onto one bounded channel
/// with disjoint frame-id blocks partitioning `0..frames`.
fn spawn_streams(frames: usize, streams: usize, rate: f64, img: usize) -> mpsc::Receiver<Frame> {
    let streams = streams.max(1);
    let (tx, rx) = mpsc::sync_channel(64.max(streams * 8));
    let mut id_base = 0u64;
    for s in 0..streams {
        let count = frames / streams + usize::from(s < frames % streams);
        let src = FrameSource {
            count,
            rate_fps: if rate > 0.0 { Some(rate) } else { None },
            img,
            seed: 11 + s as u64 * 7919,
        };
        src.spawn_into(tx.clone(), id_base);
        id_base += count as u64;
    }
    rx
}

/// Tee a frame stream in two: the first `head` frames go to the first
/// receiver, the remainder to the second.  The forwarder drops the head
/// sender the moment the head is delivered, so a consumer draining the
/// head channel sees it close and finishes while the tail buffers behind
/// a bounded channel — the seam the two-phase `--elastic` serve (warmup
/// window, then the rebalanced topology) hangs off.
fn split_stream(
    rx: mpsc::Receiver<Frame>,
    head: usize,
) -> (mpsc::Receiver<Frame>, mpsc::Receiver<Frame>) {
    let (tx_head, rx_head) = mpsc::sync_channel::<Frame>(16);
    let (tx_rest, rx_rest) = mpsc::sync_channel::<Frame>(16);
    std::thread::spawn(move || {
        let mut tx_head = Some(tx_head);
        for (i, frame) in rx.into_iter().enumerate() {
            if i < head {
                let tx = tx_head.as_ref().expect("head sender live while i < head");
                if tx.send(frame).is_err() {
                    return;
                }
                if i + 1 == head {
                    tx_head = None;
                }
            } else if tx_rest.send(frame).is_err() {
                return;
            }
        }
    });
    (rx_head, rx_rest)
}

/// Frame-conservation check + the machine-greppable smoke line the CI
/// `serve-smoke` job asserts on: every source frame classified exactly
/// once, aggregate fps nonzero.
fn report_conservation(frames_in: usize, results: &[Classified], metrics: &Metrics) -> Result<()> {
    let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    let conserved = ids.iter().enumerate().all(|(i, &id)| id == i as u64) && ids.len() == frames_in;
    println!(
        "frame conservation: {}/{} classified exactly once [{}]",
        results.len(),
        frames_in,
        if conserved { "OK" } else { "VIOLATED" }
    );
    println!(
        "serve: frames_in={} frames_out={} fps={:.1}",
        frames_in,
        results.len(),
        metrics.fps()
    );
    if !conserved {
        bail!("frame conservation violated: {} in, {} out", frames_in, results.len());
    }
    Ok(())
}

/// Lower the factory's graph to its HW form on BOTH datapaths (the f32
/// plan must also compile over HW nodes so its step names equal the
/// DataflowSim actor names — `EngineFactory::make_plan`'s f32 path only
/// requantizes), run the folding search + FIFO sizing on a clone, and
/// partition a fresh runner into `stages` pipeline workers balanced by
/// the per-actor cycle model.  `stages == 0` means auto (4, clamped to
/// the plan's step count by the partitioner).  An explicit `topology`
/// (from `--topology SxR,...`) pins both the stage count and the
/// per-stage worker counts — the reproducible override the elastic
/// path's measured decision replaces.
fn make_pipeline(
    factory: &EngineFactory,
    cfg: QuantConfig,
    stages: usize,
    topology: Option<&[usize]>,
    device: &Device,
) -> Result<(PlanRunner, PlanPipeline, bwade::build::BuildReport)> {
    let mut graph = factory
        .graph
        .clone()
        .ok_or_else(|| anyhow!("pipeline serving requires the plan engine's compiler graph"))?;
    match factory.datapath {
        Datapath::F32 => {
            requantize_graph(&mut graph, &cfg)?;
            run_default_pipeline(&mut graph, None, 0.0)?;
            if !convert_to_hw::is_fully_hw(&graph) {
                bail!("pipeline lowering left non-HW ops in the graph: {:?}", graph.op_census());
            }
        }
        Datapath::BitTrue => lower_bit_true(&mut graph, &cfg)?,
    }
    let build_cfg = DesignConfig {
        quant: cfg,
        target_fps: None,
        max_utilization: 0.85,
        verify: false,
    };
    let mut hw = graph.clone();
    let report = implement_lowered(&mut hw, &build_cfg, device)?;
    let runner = PlanRunner::with_datapath(&graph, 8, factory.datapath)?;
    let stages = topology.map(|t| t.len()).unwrap_or(if stages > 0 { stages } else { 4 });
    let mut spec = PipelineSpec::from_models(stages, &report.models, &report.fifo_depths);
    if let Some(t) = topology {
        spec = spec.with_replicas(t.to_vec());
    }
    let pipe = PlanPipeline::new(&runner, &spec)?;
    Ok((runner, pipe, report))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let frames = args.get_usize("frames", 256)?;
    let batch_opt = args.get_usize("batch", 0)?;
    let rate = args.get_f64("rate", 0.0)?;
    let replicas = args.get_usize("replicas", 1)?.max(1);
    let streams = args.get_usize("streams", 1)?.max(1);
    let synth = args.has_flag("synth");
    // --synth serves the dse's synthetic backbone + bank (no artifacts
    // needed), which only the plan engine can execute.
    let engine = if synth {
        "plan".to_string()
    } else {
        args.get_or("engine", default_engine()).to_string()
    };
    let datapath = Datapath::parse(args.get_or("datapath", "f32"))?;
    let cfg = parse_config(args.get_or("config", "b6_c1.5_r2.2"))?;
    let pipeline = args.has_flag("pipeline");
    let stages_req = args.get_usize("stages", 0)?;
    let topology = args.get("topology").map(parse_topology).transpose()?;
    let elastic = args.has_flag("elastic");
    if replicas > 1 && engine != "plan" {
        bail!(
            "--replicas > 1 requires --engine plan: compiled plans are compile-once/run-many \
             (shared behind an Arc), a PJRT executable is not replicable"
        );
    }
    if pipeline && engine != "plan" {
        bail!("--pipeline requires --engine plan: stages partition a compiled plan");
    }
    if (topology.is_some() || elastic) && !pipeline {
        bail!("--topology and --elastic shape the staged executor: add --pipeline");
    }
    if elastic && topology.is_some() {
        bail!(
            "--elastic and --topology are mutually exclusive: --topology is the reproducible \
             override, --elastic measures its own from the warmup window's stall telemetry"
        );
    }

    // Geometry, support bank and engine factory — artifact-backed or
    // synthesized.  `bundle` exists only on the artifact path (pjrt
    // needs it; the synthetic path never touches `make artifacts`).
    let paths = ArtifactPaths::default_dir();
    let spec = SweepSpec::default();
    let (factory, bundle, img, bank_images, bank_classes, bank_per_class) = if synth {
        (
            EngineFactory::new_synth(datapath, &spec, &cfg),
            None,
            spec.img,
            spec.make_bank(),
            spec.num_classes,
            spec.per_class,
        )
    } else {
        let factory = EngineFactory::new(&engine, datapath, &paths)?;
        let b = paths.model_bundle()?;
        let bank = FewshotBank::load(&paths.fewshot_bank())?;
        let img = b.img;
        (factory, Some(b), img, bank.images, bank.num_classes, bank.per_class)
    };
    // PJRT executables exist only at the exported batch sizes; the plan
    // engine batches at any size.
    let exec_batch = if engine == "plan" {
        if batch_opt > 0 { batch_opt } else { 8 }
    } else {
        let b = bundle.as_ref().expect("pjrt path loads the bundle");
        let max = *b.batch_sizes.iter().max().unwrap_or(&1);
        if batch_opt > 0 {
            // Smallest exported size that fits the request, else the max.
            *b.batch_sizes.iter().filter(|&&x| x >= batch_opt).min().unwrap_or(&max)
        } else {
            max
        }
    };

    // Prototypes from the bank (5-way support) so classification is real.
    let support = {
        let mut rng = Rng::new(7);
        let ep = sample_episode(&mut rng, bank_classes, bank_per_class, 5, 5, 1)?;
        let per = img * img * 3;
        let mut sup = Vec::new();
        for &i in &ep.support {
            sup.extend_from_slice(&bank_images[i * per..(i + 1) * per]);
        }
        (sup, ep.support_labels, ep.support.len())
    };

    let policy = BatchPolicy {
        max_batch: if batch_opt > 0 { batch_opt } else { exec_batch },
        max_wait: Duration::from_millis(args.get_usize("max-wait-ms", 5)? as u64),
    };
    // --metrics-json turns the process-wide telemetry registry on: the
    // pool exports its counters there, a background emitter prints a
    // summary line to stderr while serving, and the final snapshot lands
    // in the given file (schema bwade/telemetry/v1).
    let metrics_json = args.get("metrics-json").map(|s| s.to_string());
    let registry: Option<&'static Registry> = metrics_json.as_ref().map(|_| Registry::global());
    let emitter = registry.map(|reg| StderrEmitter::spawn(reg, Duration::from_millis(500)));
    println!(
        "serving {frames} frames (engine {engine}, datapath {}, config {}, {replicas} replica(s), \
         {streams} stream(s), exec batch {exec_batch}, policy batch {}{}) ...",
        datapath.describe(),
        cfg.describe(),
        policy.max_batch,
        if synth { ", synthetic backbone" } else { "" }
    );

    let (metrics, results, bytes_per_frame) = if pipeline {
        // Streaming pipelined executor: stage workers on bounded FIFOs,
        // frames in flight across layers (DESIGN.md §12).  Stage
        // replication, pipeline×pool composition (--replicas P hosts P
        // whole pipelines behind the work-stealing pool) and the
        // telemetry-driven --elastic rebalance are DESIGN.md §13.
        let device = Device::pynq_z1();
        let (runner, mut pipe, report) =
            make_pipeline(&factory, cfg, stages_req, topology.as_deref(), &device)?;
        let sup_feats = runner.extract_all(&support.0, support.2)?;
        let ncm = NcmClassifier::fit(&sup_feats, runner.feature_dim(), &support.1, 5)?;
        let bytes = runner.bytes_moved_per_frame();
        for (s, row) in pipe.stage_table().iter().enumerate() {
            println!(
                "  stage {s}: {} .. {}  ({} steps, {} cycles, in-capacity {} frames, {} worker(s))",
                row.first_step, row.last_step, row.steps, row.cycles, row.capacity, row.replicas
            );
        }
        let rx = spawn_streams(frames, streams, rate, img);
        let serve_t0 = Instant::now();

        // --elastic: serve a warmup head on the seeded topology against a
        // private registry, read the per-stage stall counters out of it,
        // and adopt the promoted topology for the rest of the stream.
        let mut warm: Option<(Metrics, Vec<Classified>)> = None;
        let rx = if elastic {
            let head = (frames / 4).clamp(1, 32);
            let (rx_head, rx_rest) = split_stream(rx, head.min(frames));
            let warm_reg = Registry::new();
            let (m_head, r_head, _) = pipe.serve(&ncm, rx_head, Some(&warm_reg))?;
            let samples = sample_stages(&warm_reg.snapshot(), pipe.stages(), pipe.replicas());
            let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            // Budget of one worker per core, but never below stages+1 so
            // a promotion from the all-1 seed is always possible — the
            // decision is deterministic for CI regardless of host width.
            let policy = ElasticPolicy {
                warmup_frames: head,
                max_workers: host.max(pipe.stages() + 1),
            };
            let decision = rebalance(&policy, &samples, m_head.wall);
            println!(
                "  elastic rebalance: {}{}",
                decision.describe(),
                if decision.changed() { " [ADOPTED]" } else { " [UNCHANGED]" }
            );
            if decision.changed() {
                pipe = pipe.with_replicas(&decision.after);
            }
            warm = Some((m_head, r_head));
            rx_rest
        } else {
            rx
        };

        let (metrics, results) = if replicas > 1 {
            // P whole pipelines behind the work-stealing pool: the
            // composed P × S × R topology.
            println!("  topology: {replicas} pipeline(s) x [{}]", pipe.topology());
            let mut runners: Vec<Box<dyn FeatureExtractor + Send>> = Vec::with_capacity(replicas);
            for _ in 1..replicas {
                let rep = PipelineReplica::new(pipe.replicate(), policy.max_batch, registry);
                runners.push(Box::new(rep));
            }
            runners.insert(0, Box::new(PipelineReplica::new(pipe, policy.max_batch, registry)));
            let (pool_report, results) = serve_pool_with(runners, &ncm, rx, policy, registry)?;
            for (i, m) in pool_report.replicas.iter().enumerate() {
                println!(
                    "  pipeline replica {i}: {}  (stolen {})",
                    m.summary(),
                    pool_report.stolen[i]
                );
            }
            println!("  pool steal total: {} frames", pool_report.total_stolen());
            (pool_report.aggregate, results)
        } else {
            println!("  topology: 1 pipeline(s) x [{}]", pipe.topology());
            let (metrics, results, stats) = pipe.serve(&ncm, rx, registry)?;
            println!(
                "  pipeline steady-state: measured {:.3} ms/frame vs DataflowSim predicted \
                 {:.3} ms (fill latency {:.3} ms over {} stages)",
                stats.steady_interval.as_secs_f64() * 1e3,
                device.cycles_to_ms(report.steady_cycles),
                stats.first_frame_latency.as_secs_f64() * 1e3,
                pipe.stages()
            );
            (metrics, results)
        };

        // Stitch the warmup window back on: latencies and counts merge,
        // the wall clock spans both phases, and the warmup's classified
        // frames lead the tail's so conservation sees every id once.
        let (metrics, results) = match warm {
            Some((m_head, mut r_head)) => {
                let mut m = Metrics::merge(&[m_head, metrics]);
                m.wall = serve_t0.elapsed();
                r_head.extend(results);
                (m, r_head)
            }
            None => (metrics, results),
        };
        // The sink thread asserts contiguous frame seqs on every run
        // (run_stream errors out on a gap), so reaching here IS the
        // in-order guarantee; this line just makes it greppable.
        println!("pipeline egress in-order: {} frames [OK]", results.len());
        (metrics, results, Some(bytes))
    } else if replicas == 1 {
        let runner = factory.make(&paths, bundle.as_ref(), exec_batch, cfg)?;
        let sup_feats = runner.extract_all(&support.0, support.2)?;
        let ncm = NcmClassifier::fit(&sup_feats, runner.feature_dim(), &support.1, 5)?;
        let bytes = runner.bytes_moved_per_frame();
        let rx = spawn_streams(frames, streams, rate, img);
        let (metrics, results) = serve(runner.as_ref(), &ncm, rx, policy)?;
        (metrics, results, bytes)
    } else {
        // One compiled plan, N replicas: the base runner compiles, the
        // rest share its plan (`Arc`) with private scratch arenas.
        let base = factory.make_plan(exec_batch, cfg)?;
        let sup_feats = base.extract_all(&support.0, support.2)?;
        let ncm = NcmClassifier::fit(&sup_feats, base.feature_dim(), &support.1, 5)?;
        let bytes = base.bytes_moved_per_frame();
        let mut runners: Vec<Box<dyn FeatureExtractor + Send>> = Vec::with_capacity(replicas);
        for _ in 1..replicas {
            runners.push(Box::new(base.replicate()));
        }
        runners.insert(0, Box::new(base));
        let rx = spawn_streams(frames, streams, rate, img);
        let (report, results) = serve_pool_with(runners, &ncm, rx, policy, registry)?;
        for (i, m) in report.replicas.iter().enumerate() {
            println!("  replica {i}: {}  (stolen {})", m.summary(), report.stolen[i]);
        }
        println!("  pool steal total: {} frames", report.total_stolen());
        (report.aggregate, results, Some(bytes))
    };

    if let Some(bytes) = bytes_per_frame {
        println!(
            "backbone kernel traffic: {:.1} KiB/frame at the plan's container widths (packed codes on bit-true)",
            bytes as f64 / 1024.0
        );
    }
    println!("{}", metrics.summary());
    report_conservation(frames, &results, &metrics)?;
    // Serve-level aggregates go into the registry on BOTH replica paths,
    // so the snapshot is never empty even when the pool (and its own
    // exports) is bypassed at --replicas 1.
    if let Some(reg) = registry {
        reg.counter("serve.frames").add(metrics.frames as u64);
        reg.counter("serve.batches").add(metrics.batches as u64);
        reg.gauge("serve.wall_ms").set(metrics.wall.as_millis() as i64);
        let lat = reg.histogram("serve.latency_us");
        for &us in &metrics.latencies_us {
            lat.record(us);
        }
    }
    if let Some(em) = emitter {
        em.stop();
    }
    if let Some(path) = &metrics_json {
        let snap = Registry::global().snapshot();
        write_metrics_json(Path::new(path), &snap)?;
        println!("recorded telemetry snapshot -> {path}");
    }
    if let Some(out) = args.get("json") {
        let row = ServingRow {
            config: cfg.describe(),
            datapath: datapath.describe().to_string(),
            replicas,
            streams,
            frames,
            fps: metrics.fps(),
            p50_ms: metrics.percentile_ms(50.0),
            p95_ms: metrics.percentile_ms(95.0),
            p99_ms: metrics.percentile_ms(99.0),
            bytes_per_frame: bytes_per_frame.unwrap_or(0),
        };
        let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        write_serving_json(Path::new(out), host, &[row])?;
        println!("recorded 1 serving row -> {out}");
    }
    println!("paper Fig. 5 reference: 16.3 ms backbone latency, 61.5 fps");
    Ok(())
}

/// One joined row of the measured-vs-predicted table: a DataflowSim
/// actor matched by name to the plan step that executes it.
struct ProfileRow {
    name: String,
    op: String,
    variant: &'static str,
    calls: u64,
    meas_ms: f64,
    meas_share: f64,
    cycles: u64,
    pred_ms: f64,
    pred_share: f64,
    err_pp: f64,
}

/// The measured-vs-predicted steady-state join (the pipelined half of
/// `bwade profile`): the per-step sequential measurement above it is a
/// *sum* of layer times, this is the egress inter-frame interval with
/// frames in flight across the stage workers.
struct SteadyState {
    stages: usize,
    measured_steady_ms: f64,
    /// Sequential per-frame wall (matched actors + host ingress).
    sequential_ms: f64,
    predicted_steady_ms: f64,
    /// measured_steady_ms / sequential_ms.
    measured_bottleneck_share: f64,
    /// Slowest stage's share of total predicted cycles.
    predicted_bottleneck_share: f64,
    /// (measured − predicted bottleneck share) in percentage points.
    err_pp: f64,
    /// Actors whose sequential share diverges >5 pp from prediction.
    flagged: Vec<String>,
}

/// `bwade profile` — run one compiled design per-step and join measured
/// wall time against the DataflowSim per-actor cycle prediction
/// (DESIGN.md §11).  Both sides come from the SAME lowered HW graph, so
/// plan step names equal `HwNodeModel` names and the join is exact:
/// every DataflowSim actor must be matched by a plan step (coverage is
/// asserted), while plan-only steps (the host-side ingress quant/layout
/// conversions the FPGA never times) are listed separately.
fn cmd_profile(args: &Args) -> Result<()> {
    let synth = args.has_flag("synth");
    let datapath = Datapath::parse(args.get_or("datapath", "bit-true"))?;
    let cfg = parse_config(args.get_or("config", "b6_c1.5_r2.2"))?;
    let frames = args.get_usize("frames", 16)?.max(1);
    let out = args.get_or("out", "PROFILE.md").to_string();
    let device = Device::pynq_z1();
    let spec = SweepSpec::default();

    let mut graph = if synth {
        synth_backbone_graph(spec.widths, spec.img, cfg.act.bits, cfg.act.frac_bits)
    } else {
        load_graph(&ArtifactPaths::default_dir())?
    };
    // Lower to the HW graph FIRST on both datapaths: the plan then
    // compiles over HW nodes, so its step names ARE the actor names.
    match datapath {
        Datapath::F32 => {
            requantize_graph(&mut graph, &cfg)?;
            run_default_pipeline(&mut graph, None, 0.0)?;
            if !convert_to_hw::is_fully_hw(&graph) {
                bail!("profile lowering left non-HW ops in the graph: {:?}", graph.op_census());
            }
        }
        Datapath::BitTrue => lower_bit_true(&mut graph, &cfg)?,
    }
    let per: usize = graph.shape_of(&graph.inputs[0])?.iter().product();

    // Predicted side: folding search + bounded dataflow sim on a clone
    // (folding mutates node attrs; the plan compiler never reads them).
    let build_cfg = DesignConfig {
        quant: cfg,
        target_fps: None,
        max_utilization: args.get_f64("max-util", 0.85)?,
        verify: false,
    };
    let mut hw = graph.clone();
    let report = implement_lowered(&mut hw, &build_cfg, &device)?;

    // Measured side: per-frame execution with the per-step profiler on.
    println!(
        "profiling {} frames (datapath {}, config {}, {} DataflowSim actors{}) ...",
        frames,
        datapath.describe(),
        cfg.describe(),
        report.models.len(),
        if synth { ", synthetic backbone" } else { "" }
    );
    let runner = PlanRunner::with_datapath(&graph, 1, datapath)?;
    let mut rng = Rng::new(0x5EED);
    let mut images = vec![0f32; frames * per];
    for v in images.iter_mut() {
        *v = rng.next_f32();
    }
    // Warmup run: the first frame pays arena growth; keep it out of the
    // measured profile.
    let mut warm = runner.new_profile();
    runner.profile_frames(&images[..per], 1, &mut warm)?;
    let mut profile = runner.new_profile();
    runner.profile_frames(&images, frames, &mut profile)?;

    // Join by node name, in plan-step (topological) order.
    let mut pred: std::collections::BTreeMap<&str, u64> =
        report.models.iter().map(|m| (m.name.as_str(), m.cycles)).collect();
    let mut rows: Vec<ProfileRow> = Vec::new();
    let mut ingress: Vec<(String, String, &'static str, f64)> = Vec::new();
    for s in profile.steps() {
        let meas_ms = s.nanos as f64 / 1e6 / frames as f64;
        match pred.remove(s.name.as_str()) {
            Some(cycles) => rows.push(ProfileRow {
                name: s.name.clone(),
                op: s.op.clone(),
                variant: s.variant,
                calls: s.calls,
                meas_ms,
                meas_share: 0.0,
                cycles,
                pred_ms: device.cycles_to_ms(cycles),
                pred_share: 0.0,
                err_pp: 0.0,
            }),
            None => ingress.push((s.name.clone(), s.op.clone(), s.variant, meas_ms)),
        }
    }
    println!("coverage: {}/{} DataflowSim actors matched", rows.len(), report.models.len());
    if !pred.is_empty() {
        let missing: Vec<&str> = pred.keys().copied().collect();
        bail!("DataflowSim actors without a plan step: {missing:?}");
    }

    // Shares over the MATCHED sets only, so the two sides distribute the
    // same 100% and the error is a pure shape comparison.
    let meas_total_ms: f64 = rows.iter().map(|r| r.meas_ms).sum();
    let pred_total_cycles: u64 = rows.iter().map(|r| r.cycles).sum();
    if meas_total_ms <= 0.0 || pred_total_cycles == 0 {
        bail!(
            "degenerate profile: measured {meas_total_ms} ms, predicted {pred_total_cycles} cycles"
        );
    }
    for r in rows.iter_mut() {
        r.meas_share = r.meas_ms / meas_total_ms;
        r.pred_share = r.cycles as f64 / pred_total_cycles as f64;
        r.err_pp = (r.meas_share - r.pred_share) * 100.0;
    }
    let mean_abs = rows.iter().map(|r| r.err_pp.abs()).sum::<f64>() / rows.len() as f64;
    let max_abs = rows.iter().map(|r| r.err_pp.abs()).fold(0.0f64, f64::max);
    if !mean_abs.is_finite() || !max_abs.is_finite() {
        bail!("per-layer error is not finite (mean {mean_abs}, max {max_abs})");
    }

    println!(
        "\n{:<28} {:<14} {:>10} {:>7} {:>10} {:>10} {:>7} {:>8}",
        "actor", "kernel", "meas[ms]", "meas%", "cycles", "pred[ms]", "pred%", "err[pp]"
    );
    for r in &rows {
        println!(
            "{:<28} {:<14} {:>10.4} {:>6.1}% {:>10} {:>10.4} {:>6.1}% {:>+8.2}{}",
            r.name,
            r.variant,
            r.meas_ms,
            r.meas_share * 100.0,
            r.cycles,
            r.pred_ms,
            r.pred_share * 100.0,
            r.err_pp,
            if r.err_pp.abs() > 5.0 { "  ⚠ >5pp" } else { "" }
        );
    }
    for (name, _op, variant, ms) in &ingress {
        println!("{name:<28} {variant:<14} {ms:>10.4}   (host ingress, not simulated)");
    }
    println!("per-layer error: mean {mean_abs:.2} pp, max {max_abs:.2} pp");
    println!(
        "measured {:.3} ms/frame over {} frames; predicted steady-state {:.3} ms ({:.1} fps)",
        meas_total_ms,
        frames,
        device.cycles_to_ms(report.steady_cycles),
        report.fps
    );

    // Pipelined steady-state: partition the SAME plan into stage workers
    // (balanced by the DataflowSim cycle model, channels from its sized
    // FIFOs) and measure the egress inter-frame interval — the per-step
    // numbers above are sequential sums, this is the streaming quantity
    // the simulator's II actually predicts.
    let stages_req = args.get_usize("stages", 4)?.max(1);
    let spec = PipelineSpec::from_models(stages_req, &report.models, &report.fifo_depths);
    let pipe = PlanPipeline::new(&runner, &spec)?;
    let (_, stats) = pipe.extract_stream(&images, frames, None)?;
    let ingress_ms: f64 = ingress.iter().map(|(_, _, _, ms)| ms).sum();
    let sequential_ms = meas_total_ms + ingress_ms;
    let measured_bottleneck_share = stats.steady_interval.as_secs_f64() * 1e3 / sequential_ms;
    let predicted_bottleneck_share = pipe.predicted_bottleneck_share();
    let steady = SteadyState {
        stages: pipe.stages(),
        measured_steady_ms: stats.steady_interval.as_secs_f64() * 1e3,
        sequential_ms,
        predicted_steady_ms: device.cycles_to_ms(report.steady_cycles),
        measured_bottleneck_share,
        predicted_bottleneck_share,
        err_pp: (measured_bottleneck_share - predicted_bottleneck_share) * 100.0,
        flagged: rows
            .iter()
            .filter(|r| r.err_pp.abs() > 5.0)
            .map(|r| r.name.clone())
            .collect(),
    };
    println!(
        "pipelined steady-state ({} stages): measured {:.3} ms/frame = {:.1}% of the {:.3} ms \
         sequential frame; predicted bottleneck share {:.1}% ({:+.2} pp)",
        steady.stages,
        steady.measured_steady_ms,
        steady.measured_bottleneck_share * 100.0,
        steady.sequential_ms,
        steady.predicted_bottleneck_share * 100.0,
        steady.err_pp
    );
    if steady.flagged.is_empty() {
        println!("no actor diverges more than 5 pp from its predicted share");
    } else {
        println!("⚠ actors diverging >5 pp from predicted share: {}", steady.flagged.join(", "));
    }

    write_profile_md(
        Path::new(&out),
        &cfg,
        datapath,
        frames,
        &device,
        &report,
        &rows,
        &ingress,
        (meas_total_ms, mean_abs, max_abs),
        &steady,
    )?;
    println!("profile report -> {out}");
    if let Some(jpath) = args.get("json") {
        let doc = profile_json(
            &cfg,
            datapath,
            frames,
            &device,
            &report,
            &rows,
            &ingress,
            (meas_total_ms, mean_abs, max_abs),
            &steady,
        );
        std::fs::write(jpath, doc.to_string_pretty() + "\n")
            .with_context(|| format!("writing {jpath}"))?;
        println!("profile json -> {jpath}");
    }
    Ok(())
}

fn write_profile_md(
    path: &Path,
    cfg: &QuantConfig,
    datapath: Datapath,
    frames: usize,
    device: &Device,
    report: &bwade::build::BuildReport,
    rows: &[ProfileRow],
    ingress: &[(String, String, &'static str, f64)],
    (meas_total_ms, mean_abs, max_abs): (f64, f64, f64),
    steady: &SteadyState,
) -> Result<()> {
    let mut md = String::new();
    md.push_str("# Measured vs predicted — per-actor profile\n\n");
    md.push_str(&format!(
        "- config {}, datapath {}, {} frames measured on the compiled-plan engine\n",
        cfg.describe(),
        datapath.describe(),
        frames
    ));
    md.push_str(&format!(
        "- predicted side: DataflowSim per-actor cycles on {} @ {:.0} MHz\n",
        device.name, device.clock_mhz
    ));
    md.push_str(
        "- shares are over the matched actors on each side; err = measured share − \
         predicted share (percentage points)\n\n",
    );
    md.push_str(
        "| actor | op | kernel | meas [ms/frame] | meas % | pred [cycles] | pred [ms/frame] \
         | pred % | err [pp] |\n",
    );
    md.push_str("|---|---|---|---|---|---|---|---|---|\n");
    for r in rows {
        md.push_str(&format!(
            "| {} | {} | {} | {:.4} | {:.1}% | {} | {:.4} | {:.1}% | {:+.2} |\n",
            r.name,
            r.op,
            r.variant,
            r.meas_ms,
            r.meas_share * 100.0,
            r.cycles,
            r.pred_ms,
            r.pred_share * 100.0,
            r.err_pp
        ));
    }
    if !ingress.is_empty() {
        md.push_str("\nPlan-only steps (host ingress, no DataflowSim actor):\n\n");
        md.push_str("| step | op | kernel | meas [ms/frame] |\n|---|---|---|---|\n");
        for (name, op, variant, ms) in ingress {
            md.push_str(&format!("| {name} | {op} | {variant} | {ms:.4} |\n"));
        }
    }
    md.push_str(&format!(
        "\n- coverage: {}/{} DataflowSim actors matched\n",
        rows.len(),
        report.models.len()
    ));
    md.push_str(&format!("- per-layer error: mean {mean_abs:.2} pp, max {max_abs:.2} pp\n"));
    md.push_str(&format!(
        "- measured {:.3} ms/frame; predicted first-frame {:.3} ms, steady-state {:.3} ms \
         ({:.1} fps)\n",
        meas_total_ms,
        device.cycles_to_ms(report.latency_cycles),
        device.cycles_to_ms(report.steady_cycles),
        report.fps
    ));
    md.push_str(&format!(
        "- pipelined steady-state ({} stages): measured {:.3} ms/frame = {:.1}% of the \
         {:.3} ms sequential frame; predicted bottleneck share {:.1}% ({:+.2} pp)\n",
        steady.stages,
        steady.measured_steady_ms,
        steady.measured_bottleneck_share * 100.0,
        steady.sequential_ms,
        steady.predicted_bottleneck_share * 100.0,
        steady.err_pp
    ));
    if !steady.flagged.is_empty() {
        md.push_str(&format!(
            "- ⚠ actors diverging >5 pp from predicted share: {}\n",
            steady.flagged.join(", ")
        ));
    }
    std::fs::write(path, md).with_context(|| format!("writing {}", path.display()))
}

fn profile_json(
    cfg: &QuantConfig,
    datapath: Datapath,
    frames: usize,
    device: &Device,
    report: &bwade::build::BuildReport,
    rows: &[ProfileRow],
    ingress: &[(String, String, &'static str, f64)],
    (meas_total_ms, mean_abs, max_abs): (f64, f64, f64),
    steady: &SteadyState,
) -> Json {
    let actors: Vec<Json> = rows
        .iter()
        .map(|r| {
            json::obj(vec![
                ("name", Json::str(r.name.clone())),
                ("op", Json::str(r.op.clone())),
                ("kernel", Json::str(r.variant)),
                ("calls", Json::num(r.calls as f64)),
                ("measured_ms_per_frame", Json::num(r.meas_ms)),
                ("measured_share", Json::num(r.meas_share)),
                ("predicted_cycles", Json::num(r.cycles as f64)),
                ("predicted_ms_per_frame", Json::num(r.pred_ms)),
                ("predicted_share", Json::num(r.pred_share)),
                ("err_pp", Json::num(r.err_pp)),
            ])
        })
        .collect();
    let ing: Vec<Json> = ingress
        .iter()
        .map(|(name, op, variant, ms)| {
            json::obj(vec![
                ("name", Json::str(name.clone())),
                ("op", Json::str(op.clone())),
                ("kernel", Json::str(*variant)),
                ("measured_ms_per_frame", Json::num(*ms)),
            ])
        })
        .collect();
    json::obj(vec![
        ("schema", Json::str("bwade/profile/v1")),
        ("config", Json::str(cfg.describe())),
        ("datapath", Json::str(datapath.describe())),
        ("frames", Json::num(frames as f64)),
        ("device", Json::str(device.name)),
        ("actors", Json::Arr(actors)),
        ("ingress", Json::Arr(ing)),
        (
            "summary",
            json::obj(vec![
                ("matched", Json::num(rows.len() as f64)),
                ("mean_abs_err_pp", Json::num(mean_abs)),
                ("max_abs_err_pp", Json::num(max_abs)),
                ("measured_ms_per_frame", Json::num(meas_total_ms)),
                ("predicted_fps", Json::num(report.fps)),
                (
                    "predicted_steady_ms",
                    Json::num(device.cycles_to_ms(report.steady_cycles)),
                ),
            ]),
        ),
        (
            "steady_state",
            json::obj(vec![
                ("stages", Json::num(steady.stages as f64)),
                ("measured_steady_ms", Json::num(steady.measured_steady_ms)),
                ("sequential_ms", Json::num(steady.sequential_ms)),
                ("predicted_steady_ms", Json::num(steady.predicted_steady_ms)),
                (
                    "measured_bottleneck_share",
                    Json::num(steady.measured_bottleneck_share),
                ),
                (
                    "predicted_bottleneck_share",
                    Json::num(steady.predicted_bottleneck_share),
                ),
                ("err_pp", Json::num(steady.err_pp)),
                (
                    "flagged_actors",
                    Json::Arr(steady.flagged.iter().map(|n| Json::str(n.clone())).collect()),
                ),
            ]),
        ),
    ])
}

fn cmd_episodes(args: &Args) -> Result<()> {
    let n_eps = args.get_usize("episodes", 200)?;
    let way = args.get_usize("way", 5)?;
    let shot = args.get_usize("shot", 5)?;
    let engine = args.get_or("engine", default_engine()).to_string();
    let datapath = Datapath::parse(args.get_or("datapath", "f32"))?;
    let cfg = parse_config(args.get_or("config", "b6_c1.5_r2.2"))?;
    let paths = ArtifactPaths::default_dir();
    let bundle = paths.model_bundle()?;
    let bank = FewshotBank::load(&paths.fewshot_bank())?;
    let batch = *bundle.batch_sizes.iter().max().unwrap_or(&1);
    let factory = EngineFactory::new(&engine, datapath, &paths)?;
    let runner = factory.make(&paths, Some(&bundle), batch, cfg)?;
    println!(
        "extracting features for {} bank images (engine {engine}, datapath {}) ...",
        bank.num_images(),
        datapath.describe()
    );
    let feats = runner.extract_all(&bank.images, bank.num_images())?;
    let mut rng = Rng::new(args.get_usize("seed", 0xEE)? as u64);
    let eps: Vec<_> = (0..n_eps)
        .map(|_| sample_episode(&mut rng, bank.num_classes, bank.per_class, way, shot, 15))
        .collect::<Result<_>>()?;
    let report = evaluate(&feats, bundle.feature_dim, &eps)?;
    println!(
        "{}  {}-way {}-shot: {:.2}% ± {:.2}%  ({} episodes)",
        cfg.describe(),
        way,
        shot,
        report.mean * 100.0,
        report.ci95 * 100.0,
        report.episodes
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let _ = args;
    let paths = ArtifactPaths::default_dir();
    println!("artifact dir: {} (stamp: {})", paths.dir.display(), paths.exists());
    let bundle = paths.model_bundle()?;
    println!(
        "backbone: widths {:?}, feature dim {}, img {}, {} params",
        bundle.widths,
        bundle.feature_dim,
        bundle.img,
        bundle.param_count()
    );
    println!("batch sizes: {:?}", bundle.batch_sizes);
    println!("layers:");
    for l in &bundle.layers {
        println!(
            "  {:<8} {:>3} -> {:<3} pool={} res_begin={} res_add={}",
            l.name, l.cin, l.cout, l.pool, l.res_begin, l.res_add
        );
    }
    if let Ok(bank) = FewshotBank::load(&paths.fewshot_bank()) {
        println!(
            "fewshot bank: {} classes x {} images ({}x{}x{})",
            bank.num_classes, bank.per_class, bank.height, bank.width, bank.channels
        );
    }
    let device = Device::pynq_z1();
    println!("device: {}", device.name);
    println!("{}", utilization_line("device budget", &device.budget, &device));
    Ok(())
}
