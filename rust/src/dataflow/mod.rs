//! Cycle-level simulator of the FINN streaming dataflow architecture.
//!
//! Table I: "Streaming processing, layer-wise design ... connecting them
//! through FIFO buffers to enable low-latency streaming processing."
//! This simulator executes that architecture: every HW layer is an actor
//! that consumes/produces stream elements at its folded rate; actors are
//! connected by bounded FIFOs with back-pressure; forks (the residual
//! skip) duplicate the stream.  It produces the numbers behind the
//! paper's Table III latency row and Fig. 5's fps:
//!
//! * single-frame latency = cycle at which the sink finishes frame 0,
//! * steady-state throughput = cycles between consecutive frame
//!   completions (= max layer II when FIFOs are sized right),
//! * per-FIFO peak occupancy — the FIFO-sizing pass (run once with
//!   unbounded FIFOs, then set capacities to the observed peaks).
//!
//! Rates are modeled with Bresenham-style accumulators: an actor that
//! consumes E elements over C cycles consumes `ceil(E*p/C)` elements by
//! progress-cycle p — linear pacing, which is what the synthesized HLS
//! dataflow does in the steady state.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::hw::HwNodeModel;

/// One directed FIFO channel between a producer and ONE consumer.
#[derive(Debug, Clone)]
pub struct Channel {
    pub name: String,
    pub producer: Option<usize>,
    pub consumer: Option<usize>,
    pub capacity: u64,
    pub occupancy: u64,
    pub peak: u64,
    pub total: u64,
}

/// Actor runtime state.
#[derive(Debug, Clone)]
struct Actor {
    /// Progress through the current frame, in cycles.
    progress: u64,
    cycles: u64,
    in_chans: Vec<usize>,
    in_elems: Vec<u64>,
    consumed: Vec<u64>,
    out_chans: Vec<usize>,
    out_elems: u64,
    produced: u64,
    frames_done: u64,
    /// Bresenham pacing state (§Perf iteration 4: no division in the hot
    /// loop).  take(p) = base + (err rolls over C), with ceil pacing for
    /// inputs (err starts at C-1) and floor pacing for outputs (err
    /// starts at 0); after C steps the err state returns to its initial
    /// value, so frame wrap needs no reset.
    in_base: Vec<u64>,
    in_rem: Vec<u64>,
    in_err: Vec<u64>,
    out_base: u64,
    out_rem: u64,
    out_err: u64,
    /// Cached stall condition: while `stall_ch`'s occupancy stays below
    /// (`StallKind::Input`) / above (`StallKind::Output`) `stall_level`,
    /// re-checking the full firing rule is pointless — this turns a
    /// stalled actor into one load + compare per cycle (§Perf iteration 3).
    stall: Option<(StallKind, usize, u64)>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum StallKind {
    /// Waiting for `stall_level` tokens of input occupancy.
    Input,
    /// Waiting for occupancy to drop to `stall_level` or below.
    Output,
}

/// Simulation result for one run.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Cycle at which frame 0 exited the sink.
    pub first_frame_latency: u64,
    /// Steady-state cycles per frame (frame k -> k+1 completion gap).
    pub steady_interval: u64,
    /// Total cycles simulated.
    pub total_cycles: u64,
    /// Peak occupancy per channel name.
    pub fifo_peaks: HashMap<String, u64>,
    pub frames: u64,
}

/// The dataflow pipeline: actors + channels built from HW node models.
pub struct DataflowSim {
    actors: Vec<Actor>,
    channels: Vec<Channel>,
    names: Vec<String>,
    /// Channel indices feeding from the outside world (graph input).
    source_chans: Vec<usize>,
    /// Channel indices draining to the outside world (graph output).
    sink_chans: Vec<usize>,
}

impl DataflowSim {
    /// Build from node models.  `graph_inputs`/`graph_outputs` are the
    /// boundary tensor names; `default_capacity` sizes all FIFOs (use
    /// `u64::MAX/4` for the unbounded sizing run).
    pub fn new(
        models: &[HwNodeModel],
        graph_inputs: &[String],
        graph_outputs: &[String],
        default_capacity: u64,
    ) -> Result<Self> {
        let mut channels: Vec<Channel> = Vec::new();
        let mut actors: Vec<Actor> = Vec::new();
        let mut source_chans = Vec::new();
        let mut sink_chans = Vec::new();

        // Producer lookup: tensor -> (actor idx, elems per frame).
        let mut producer_of: HashMap<&str, usize> = HashMap::new();
        for (i, m) in models.iter().enumerate() {
            producer_of.insert(m.output.as_str(), i);
        }

        for m in models.iter() {
            let c = m.cycles.max(1);
            actors.push(Actor {
                progress: 0,
                cycles: m.cycles,
                in_chans: Vec::new(),
                in_elems: m.in_elems.clone(),
                consumed: vec![0; m.in_elems.len()],
                out_chans: Vec::new(),
                out_elems: m.out_elems,
                produced: 0,
                frames_done: 0,
                stall: None,
                in_base: m.in_elems.iter().map(|e| e / c).collect(),
                in_rem: m.in_elems.iter().map(|e| e % c).collect(),
                in_err: vec![c - 1; m.in_elems.len()], // ceil pacing
                out_base: m.out_elems / c,
                out_rem: m.out_elems % c,
                out_err: 0, // floor pacing
            });
        }

        // One channel per (producer-tensor, consumer) pair: forks become
        // parallel channels filled simultaneously by the producer.
        for (ci, m) in models.iter().enumerate() {
            for (slot, t) in m.stream_inputs.iter().enumerate() {
                let chan_idx = channels.len();
                channels.push(Channel {
                    name: format!("{t}->{}", m.name),
                    producer: producer_of.get(t.as_str()).copied(),
                    consumer: Some(ci),
                    capacity: default_capacity,
                    occupancy: 0,
                    peak: 0,
                    total: 0,
                });
                actors[ci].in_chans.push(chan_idx);
                match producer_of.get(t.as_str()) {
                    Some(&pi) => actors[pi].out_chans.push(chan_idx),
                    None => {
                        if !graph_inputs.contains(t) {
                            bail!("stream input {t} has no producer and is not a graph input");
                        }
                        source_chans.push(chan_idx);
                    }
                }
                let _ = slot;
            }
        }
        // Sink channels for graph outputs.
        for out in graph_outputs {
            let Some(&pi) = producer_of.get(out.as_str()) else {
                bail!("graph output {out} has no producing actor");
            };
            let chan_idx = channels.len();
            channels.push(Channel {
                name: format!("{out}->sink"),
                producer: Some(pi),
                consumer: None,
                capacity: u64::MAX / 4,
                occupancy: 0,
                peak: 0,
                total: 0,
            });
            actors[pi].out_chans.push(chan_idx);
            sink_chans.push(chan_idx);
        }

        Ok(Self {
            actors,
            channels,
            names: models.iter().map(|m| m.name.clone()).collect(),
            source_chans,
            sink_chans,
        })
    }

    /// Override one channel's capacity (by suffix match on the name).
    pub fn set_capacity(&mut self, name_contains: &str, capacity: u64) {
        for c in &mut self.channels {
            if c.name.contains(name_contains) {
                c.capacity = capacity;
            }
        }
    }

    /// Run for `frames` frames; the source injects each frame's input as
    /// fast as the first FIFO accepts it (DMA at full rate).
    pub fn run(&mut self, frames: u64, frame_in_elems: u64) -> Result<SimResult> {
        let mut cycle: u64 = 0;
        let mut injected_frames = 0u64;
        let mut injected_in_frame = 0u64;
        let mut completions: Vec<u64> = Vec::new();
        let sink_total: u64 = self
            .sink_chans
            .iter()
            .map(|&c| {
                self.channels[c]
                    .producer
                    .map(|p| self.actors[p].out_elems)
                    .unwrap_or(0)
            })
            .sum();
        let mut drained: u64 = 0;
        let max_cycles: u64 = 500_000_000;

        // A FIFO narrower than one production beat can never accept it.
        for a in &self.actors {
            let beat = a.out_elems.div_ceil(a.cycles.max(1));
            for &ch in &a.out_chans {
                if self.channels[ch].capacity < beat {
                    bail!(
                        "channel {} capacity {} smaller than one beat ({beat})",
                        self.channels[ch].name,
                        self.channels[ch].capacity
                    );
                }
            }
        }
        for &c in &self.source_chans {
            if self.channels[c].capacity < 1 {
                bail!("source channel {} has zero capacity", self.channels[c].name);
            }
        }

        while (completions.len() as u64) < frames {
            // 1. Source injection (per-cycle up to a DMA beat of 8 elems,
            //    clipped to the free space of every source FIFO).
            if injected_frames < frames {
                let mut beat = 8.min(frame_in_elems - injected_in_frame);
                for &c in &self.source_chans {
                    let free = self.channels[c].capacity - self.channels[c].occupancy;
                    beat = beat.min(free);
                }
                if beat > 0 {
                    for &c in &self.source_chans {
                        let ch = &mut self.channels[c];
                        ch.occupancy += beat;
                        ch.total += beat;
                        ch.peak = ch.peak.max(ch.occupancy);
                    }
                    injected_in_frame += beat;
                    if injected_in_frame == frame_in_elems {
                        injected_frames += 1;
                        injected_in_frame = 0;
                    }
                }
            }

            // 2. Actors advance (topological order = construction order).
            //    Hot loop: no heap allocation — per-actor fan-in/out is
            //    bounded by MAX_PORTS (residual join = 2 inputs; fork =
            //    2 outputs), and element*cycle products fit u64
            //    (elems < 2^20, cycles < 2^32 in any realistic build).
            const MAX_PORTS: usize = 4;
            for ai in 0..self.actors.len() {
                let a = &self.actors[ai];
                if a.cycles == 0 {
                    continue;
                }
                // Fast path: cached stall condition still holds.
                if let Some((kind, ch, level)) = a.stall {
                    let occ = self.channels[ch].occupancy;
                    match kind {
                        StallKind::Input if occ < level => continue,
                        StallKind::Output if occ > level => continue,
                        _ => {}
                    }
                }
                let p_next = a.progress + 1;
                // Required consumption this cycle (ceil pacing, div-free:
                // err accumulator rolls over at C).
                let mut need = [0u64; MAX_PORTS];
                let mut errs = [0u64; MAX_PORTS];
                let mut blocked: Option<(StallKind, usize, u64)> = None;
                for slot in 0..a.in_chans.len() {
                    let mut err = a.in_err[slot] + a.in_rem[slot];
                    let mut take = a.in_base[slot];
                    if err >= a.cycles {
                        err -= a.cycles;
                        take += 1;
                    }
                    if self.channels[a.in_chans[slot]].occupancy < take {
                        blocked = Some((StallKind::Input, a.in_chans[slot], take));
                        break;
                    }
                    need[slot] = take;
                    errs[slot] = err;
                }
                if let Some(b) = blocked {
                    self.actors[ai].stall = Some(b);
                    continue;
                }
                // Production this cycle: floor pacing (consume early,
                // produce late — the last output token leaves on the
                // frame's final cycle, a conservative streaming model).
                let mut out_err = a.out_err + a.out_rem;
                let mut put = a.out_base;
                if out_err >= a.cycles {
                    out_err -= a.cycles;
                    put += 1;
                }
                for &ch in &a.out_chans {
                    let c = &self.channels[ch];
                    if c.occupancy + put > c.capacity {
                        blocked = Some((StallKind::Output, ch, c.capacity - put));
                        break;
                    }
                }
                if let Some(b) = blocked {
                    self.actors[ai].stall = Some(b);
                    continue;
                }
                // Commit: copy the (short) port lists to the stack so the
                // actor and channel borrows don't conflict.
                let n_in = a.in_chans.len().min(MAX_PORTS);
                let n_out = a.out_chans.len().min(MAX_PORTS);
                let mut in_ports = [0usize; MAX_PORTS];
                let mut out_ports = [0usize; MAX_PORTS];
                in_ports[..n_in].copy_from_slice(&a.in_chans[..n_in]);
                out_ports[..n_out].copy_from_slice(&a.out_chans[..n_out]);

                let a = &mut self.actors[ai];
                a.stall = None;
                for slot in 0..n_in {
                    a.consumed[slot] += need[slot];
                    a.in_err[slot] = errs[slot];
                }
                a.out_err = out_err;
                a.produced += put;
                a.progress = p_next;
                if a.progress == a.cycles {
                    // Pacing err state returns to its initial value after
                    // exactly C steps; only the frame counters reset.
                    a.progress = 0;
                    a.consumed.iter_mut().for_each(|c| *c = 0);
                    a.produced = 0;
                    a.frames_done += 1;
                }
                for slot in 0..n_in {
                    self.channels[in_ports[slot]].occupancy -= need[slot];
                }
                if put > 0 {
                    for &ch in &out_ports[..n_out] {
                        let c = &mut self.channels[ch];
                        c.occupancy += put;
                        c.total += put;
                        c.peak = c.peak.max(c.occupancy);
                    }
                }
            }

            // 3. Sink drain.
            for &c in &self.sink_chans {
                drained += self.channels[c].occupancy;
                self.channels[c].occupancy = 0;
            }
            while drained >= sink_total && sink_total > 0 {
                drained -= sink_total;
                // +1: the frame is complete at the END of this cycle.
                completions.push(cycle + 1);
            }

            cycle += 1;
            if cycle > max_cycles {
                bail!("dataflow simulation exceeded {max_cycles} cycles (deadlock?)");
            }
        }

        let first = completions.first().copied().unwrap_or(0);
        let steady = if completions.len() >= 2 {
            completions[completions.len() - 1] - completions[completions.len() - 2]
        } else {
            first
        };
        let mut fifo_peaks = HashMap::new();
        for c in &self.channels {
            fifo_peaks.insert(c.name.clone(), c.peak);
        }
        Ok(SimResult {
            first_frame_latency: first,
            steady_interval: steady,
            total_cycles: cycle,
            fifo_peaks,
            frames: completions.len() as u64,
        })
    }

    pub fn actor_names(&self) -> &[String] {
        &self.names
    }

    pub fn channels(&self) -> &[Channel] {
        &self.channels
    }
}

/// FIFO sizing: run once with unbounded FIFOs and return per-channel
/// depths (peak occupancy, rounded up to a power of two as HLS FIFOs are).
pub fn size_fifos(
    models: &[HwNodeModel],
    graph_inputs: &[String],
    graph_outputs: &[String],
    frame_in_elems: u64,
) -> Result<HashMap<String, u64>> {
    let mut sim = DataflowSim::new(models, graph_inputs, graph_outputs, u64::MAX / 4)?;
    let res = sim.run(2, frame_in_elems)?;
    Ok(res
        .fifo_peaks
        .into_iter()
        .map(|(k, v)| (k, v.max(2).next_power_of_two()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::Resources;

    fn model(
        name: &str,
        input: &str,
        output: &str,
        in_elems: u64,
        out_elems: u64,
        cycles: u64,
    ) -> HwNodeModel {
        HwNodeModel {
            name: name.into(),
            op: "Test".into(),
            stream_inputs: vec![input.into()],
            in_elems: vec![in_elems],
            output: output.into(),
            out_elems,
            cycles,
            resources: Resources::ZERO,
            weight_bits: 0,
        }
    }

    #[test]
    fn single_actor_latency() {
        let models = vec![model("a", "in", "out", 64, 64, 100)];
        let mut sim =
            DataflowSim::new(&models, &["in".into()], &["out".into()], 1 << 20).unwrap();
        let r = sim.run(1, 64).unwrap();
        // 64 elems injected at 8/cycle = 8 cycles; actor needs 100 cycles.
        assert!(r.first_frame_latency >= 100);
        assert!(r.first_frame_latency < 120);
    }

    #[test]
    fn pipeline_throughput_bounded_by_slowest() {
        let models = vec![
            model("fast1", "in", "t1", 64, 64, 50),
            model("slow", "t1", "t2", 64, 64, 400),
            model("fast2", "t2", "out", 64, 64, 50),
        ];
        let mut sim =
            DataflowSim::new(&models, &["in".into()], &["out".into()], 1 << 20).unwrap();
        let r = sim.run(4, 64).unwrap();
        assert!(
            (r.steady_interval as i64 - 400).unsigned_abs() <= 20,
            "steady {}",
            r.steady_interval
        );
        // Latency ~ sum of fills, < sum of all cycles + injection.
        assert!(r.first_frame_latency >= 400);
        assert!(r.first_frame_latency <= 520);
    }

    #[test]
    fn backpressure_limits_occupancy() {
        let models = vec![
            model("fast", "in", "t1", 64, 64, 8),
            model("slow", "t1", "out", 64, 64, 6400),
        ];
        let mut sim =
            DataflowSim::new(&models, &["in".into()], &["out".into()], 16).unwrap();
        let r = sim.run(1, 64).unwrap();
        // The fast producer is throttled by the bounded FIFO: it can
        // never pile up more than the capacity.
        assert!(r.fifo_peaks["t1->slow"] <= 16);
    }

    #[test]
    fn too_small_fifo_is_reported_not_deadlocked() {
        let models = vec![
            model("fast", "in", "t1", 64, 64, 8),
            model("slow", "t1", "out", 64, 64, 6400),
        ];
        let mut sim =
            DataflowSim::new(&models, &["in".into()], &["out".into()], 4).unwrap();
        let err = sim.run(1, 64).unwrap_err().to_string();
        assert!(err.contains("beat"), "{err}");
    }

    #[test]
    fn fork_join_residual_pattern() {
        // src -> (branchA, skip) ; join consumes both.
        let models = vec![
            model("src", "in", "t", 64, 64, 64),
            model("branch", "t", "b", 64, 64, 640),
            HwNodeModel {
                name: "join".into(),
                op: "AddStreams".into(),
                stream_inputs: vec!["b".into(), "t".into()],
                in_elems: vec![64, 64],
                output: "out".into(),
                out_elems: 64,
                cycles: 64,
                resources: Resources::ZERO,
                weight_bits: 0,
            },
        ];
        let mut sim =
            DataflowSim::new(&models, &["in".into()], &["out".into()], 1 << 20).unwrap();
        let r = sim.run(2, 64).unwrap();
        assert!(r.frames >= 2);
        // Skip channel must have buffered while the branch lagged.
        assert!(r.fifo_peaks["t->join"] > 8, "{:?}", r.fifo_peaks);
    }

    #[test]
    fn fifo_sizing_covers_latency_mismatch() {
        let models = vec![
            model("src", "in", "t", 64, 64, 64),
            model("branch", "t", "b", 64, 64, 640),
            HwNodeModel {
                name: "join".into(),
                op: "AddStreams".into(),
                stream_inputs: vec!["b".into(), "t".into()],
                in_elems: vec![64, 64],
                output: "out".into(),
                out_elems: 64,
                cycles: 64,
                resources: Resources::ZERO,
                weight_bits: 0,
            },
        ];
        let sizes = size_fifos(&models, &["in".into()], &["out".into()], 64).unwrap();
        let skip = sizes["t->join"];
        assert!(skip >= 32, "skip fifo {skip}");
        assert!(skip.is_power_of_two());
        // Re-run bounded at the sized depths: must not deadlock.
        let mut sim = DataflowSim::new(&models, &["in".into()], &["out".into()], 2).unwrap();
        for (name, cap) in &sizes {
            sim.set_capacity(name, *cap);
        }
        let r = sim.run(3, 64).unwrap();
        assert_eq!(r.frames, 3);
    }

    #[test]
    fn unknown_input_errors() {
        let models = vec![model("a", "ghost", "out", 8, 8, 8)];
        assert!(DataflowSim::new(&models, &["in".into()], &["out".into()], 16).is_err());
    }
}
