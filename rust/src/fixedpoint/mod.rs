//! Arbitrary-bit-width fixed-point arithmetic — the rust twin of
//! `python/compile/fxp.py`.
//!
//! The paper's whole premise is that the fixed-point bit-width is a free
//! design parameter (FINN) instead of 16/32 only (Tensil).  Everything in
//! the design environment that touches numbers goes through [`FxpFormat`]:
//! weight quantization (PTQ before PJRT execution), MultiThreshold
//! executors, HW-layer datapath width calculations and BRAM sizing.
//!
//! Semantics are IDENTICAL to the python side — same round-half-up rule
//! `floor(x * 2^f + 0.5)`, same saturation — so cross-layer tests can
//! require exact equality (see python/tests/test_fxp.py for the mirrored
//! property list).

use anyhow::{bail, Result};

/// A fixed-point format: total bits, fractional bits, signedness.
///
/// Signed formats are two's-complement with the sign bit counted in the
/// integer part (Brevitas convention): `s6.5` = "6 bits: 1 integer + 5
/// fractional" = range [-1, 1 - 2^-5].  Unsigned formats model post-ReLU
/// activations: `u4.2` = range [0, 3.75].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FxpFormat {
    pub bits: u8,
    pub frac_bits: u8,
    pub signed: bool,
}

impl FxpFormat {
    pub fn signed(bits: u8, frac_bits: u8) -> Result<Self> {
        Self::new(bits, frac_bits, true)
    }

    pub fn unsigned(bits: u8, frac_bits: u8) -> Result<Self> {
        Self::new(bits, frac_bits, false)
    }

    /// Validate and build a format.
    ///
    /// Convention: `frac_bits` may exceed `bits` — a *pure-fractional*
    /// format whose whole range sits below 1.0 (`int_bits` goes negative;
    /// e.g. `s2.6` spans [-2^-5, 2^-6]) — but by **at most 8 bits**.
    /// Anything beyond that is outside what `num_thresholds()`-driven
    /// MultiThreshold generation and the BRAM/datapath width models are
    /// designed for, and historically the looser `bits + 16` bound only
    /// admitted formats nothing downstream could realize.  The python
    /// twin (`python/compile/fxp.py`) enforces the identical bound, and
    /// `python/tests/test_fxp.py` / the property tests below probe the
    /// boundary from both sides.
    pub fn new(bits: u8, frac_bits: u8, signed: bool) -> Result<Self> {
        if bits == 0 || bits > 32 {
            bail!("bits must be in [1, 32], got {bits}");
        }
        if frac_bits > bits + 8 {
            bail!(
                "frac_bits {frac_bits} exceeds bits + 8 = {} (at most 8 bits of pure-fractional headroom)",
                bits + 8
            );
        }
        Ok(Self {
            bits,
            frac_bits,
            signed,
        })
    }

    /// Integer bits (incl. sign when signed) — the paper's "int." column.
    pub fn int_bits(&self) -> i32 {
        self.bits as i32 - self.frac_bits as i32
    }

    /// Code scale: quantized code = value * scale.
    pub fn scale(&self) -> f64 {
        (2.0f64).powi(self.frac_bits as i32)
    }

    /// Signed 1-bit formats are *bipolar* (the FINN/BNN convention):
    /// codes {-1, +1}, no zero, realized by an XNOR/popcount datapath.
    /// Two's-complement 1-bit ({-1, 0}) has no useful multiplier, so
    /// there is nothing for this to conflict with.
    pub fn is_bipolar(&self) -> bool {
        self.signed && self.bits == 1
    }

    pub fn qmin(&self) -> i64 {
        if self.signed {
            -(1i64 << (self.bits - 1))
        } else {
            0
        }
    }

    pub fn qmax(&self) -> i64 {
        if self.is_bipolar() {
            1
        } else if self.signed {
            (1i64 << (self.bits - 1)) - 1
        } else {
            (1i64 << self.bits) - 1
        }
    }

    pub fn vmin(&self) -> f64 {
        self.qmin() as f64 / self.scale()
    }

    pub fn vmax(&self) -> f64 {
        self.qmax() as f64 / self.scale()
    }

    /// Steps a MultiThreshold unit needs to realize this quantizer.
    /// Bipolar needs one sign threshold (codes {-1, +1} skip zero, so
    /// `qmax - qmin` would overcount by one).
    pub fn num_thresholds(&self) -> i64 {
        if self.is_bipolar() {
            return 1;
        }
        self.qmax() - self.qmin()
    }

    /// Narrowest container in {1, 4, 8, 16, 32} bits holding every code
    /// of this format — the storage width the packed bit-true datapath
    /// streams (DESIGN.md §9).  Unsigned formats reach the sub-byte
    /// bit-packed rungs (u1 at 1 bit, u2..u4 at 4); byte-aligned
    /// containers are signed, matching the FPGA-side signed accumulator
    /// convention, so signed b-bit fits 8 up to b = 8 while unsigned
    /// only up to b = 7.  Bipolar is the 1-bit container even though
    /// its range straddles zero — the code *set* {-1, +1} is known
    /// here, unlike in the range-only rule.  Formats whose codes exceed
    /// i32 still report 32 — the datapath's checked conversions reject
    /// them.  Mirrored by `container_bits` in python/compile/fxp.py.
    pub fn container_bits(&self) -> u8 {
        if self.is_bipolar() {
            return 1;
        }
        container_bits_for_range(self.qmin(), self.qmax())
    }

    /// Quantize to integer code: `clip(floor(x * 2^f + 0.5), qmin, qmax)`.
    /// Bipolar uses the sign rule instead (`x >= 0 -> +1`, else `-1`) —
    /// there is no zero code to round to.
    ///
    /// f64 intermediate matches the f32-graph python semantics on every
    /// value the pipeline produces (f32 inputs are exactly representable).
    pub fn quantize_int(&self, x: f32) -> i64 {
        if self.is_bipolar() {
            return if x >= 0.0 { 1 } else { -1 };
        }
        let q = (x as f64 * self.scale() + 0.5).floor();
        let q = q.clamp(self.qmin() as f64, self.qmax() as f64);
        q as i64
    }

    /// Quantize onto the fixed-point grid, returned as f32.
    pub fn quantize(&self, x: f32) -> f32 {
        (self.quantize_int(x) as f64 / self.scale()) as f32
    }

    /// Dequantize an integer code.
    pub fn dequantize(&self, code: i64) -> f32 {
        (code as f64 / self.scale()) as f32
    }

    /// Quantize a slice in place.
    pub fn quantize_slice(&self, xs: &mut [f32]) {
        for x in xs {
            *x = self.quantize(*x);
        }
    }

    /// Short name, e.g. `s6.5` / `u4.2` (same as python `describe()`).
    pub fn describe(&self) -> String {
        format!(
            "{}{}.{}",
            if self.signed { "s" } else { "u" },
            self.bits,
            self.frac_bits
        )
    }
}

/// One row of Table II: weight format + activation format.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct QuantConfig {
    pub weight: FxpFormat,
    pub act: FxpFormat,
}

impl QuantConfig {
    pub fn new(weight: FxpFormat, act: FxpFormat) -> Result<Self> {
        if !weight.signed {
            bail!("weight format must be signed");
        }
        if act.signed {
            bail!("activation format must be unsigned");
        }
        Ok(Self { weight, act })
    }

    /// Paper notation: (w_int, w_frac, a_int, a_frac), sign in int part.
    pub fn from_split(w_int: u8, w_frac: u8, a_int: u8, a_frac: u8) -> Result<Self> {
        Self::new(
            FxpFormat::signed(w_int + w_frac, w_frac)?,
            FxpFormat::unsigned(a_int + a_frac, a_frac)?,
        )
    }

    pub fn max_bits(&self) -> u8 {
        self.weight.bits.max(self.act.bits)
    }

    /// Accumulator format for MVAU bias/threshold data (wide, exact).
    pub fn acc_format(&self) -> FxpFormat {
        FxpFormat {
            bits: 32,
            frac_bits: self.weight.frac_bits + self.act.frac_bits,
            signed: true,
        }
    }

    pub fn describe(&self) -> String {
        format!("W{}_A{}", self.weight.describe(), self.act.describe())
    }
}

/// THE container-selection rule, in one place: the narrowest container
/// in {1, 4, 8, 16, 32} bits covering the code range `[lo, hi]`.
/// Everything that picks a storage width routes through here —
/// [`FxpFormat::container_bits`] (spec level), the `bt_container`
/// annotation in `transforms::annotate_bit_true_formats` (graph level),
/// and the width-native initializer conversion in `plan` (compile
/// level) — so the rule can never desynchronize between layers.
///
/// The sub-byte rungs are unsigned bit-packed containers: `[0, 1]`
/// packs eight binary codes per byte, `[0, 15]` packs two nibbles per
/// byte (DESIGN.md §9).  A bipolar {-1, +1} container is NOT derivable
/// from a range alone — `[-1, 1]` includes 0, which bipolar cannot
/// store — so bipolar selection happens where the code *set* is known
/// (annotation / weight conversion), not here.  Ranges beyond i32
/// still report 32; the datapath's checked conversions reject them
/// downstream.
pub fn container_bits_for_range(lo: i64, hi: i64) -> u8 {
    if lo >= 0 && hi <= 1 {
        return 1;
    }
    if lo >= 0 && hi <= 15 {
        return 4;
    }
    for bits in [8u8, 16] {
        if lo >= -(1i64 << (bits - 1)) && hi <= (1i64 << (bits - 1)) - 1 {
            return bits;
        }
    }
    32
}

/// Exact rational decomposition of a finite nonzero float: `x = m * 2^e`
/// with `m` odd.  Every f64 (and every f32 widened to f64) is exactly
/// such a rational, so this is lossless — the bit-true datapath uses it
/// to turn float scale factors into an integer multiplier plus a
/// fractional-bit shift.  Returns `None` for 0, NaN and infinities.
pub fn pow2_decompose(x: f64) -> Option<(i64, i32)> {
    if x == 0.0 || !x.is_finite() {
        return None;
    }
    let bits = x.to_bits();
    let biased = ((bits >> 52) & 0x7ff) as i32;
    let frac = (bits & ((1u64 << 52) - 1)) as i64;
    let (mut m, mut e) = if biased == 0 {
        (frac, -1074) // subnormal: no implicit leading 1
    } else {
        (frac | (1i64 << 52), biased - 1075)
    };
    while m & 1 == 0 {
        m >>= 1;
        e += 1;
    }
    if x < 0.0 {
        m = -m;
    }
    Some((m, e))
}

/// The eight rows of the paper's Table II, in paper order.
pub fn table2_configs() -> Vec<(String, QuantConfig)> {
    [
        ("b5_c2.3_r2.2", (2u8, 3u8, 2u8, 2u8)),
        ("b6_c1.5_r2.2", (1, 5, 2, 2)), // the paper's chosen build (59.70%)
        ("b6_c3.3_r3.3", (3, 3, 3, 3)),
        ("b8_c4.4_r4.4", (4, 4, 4, 4)),
        ("b10_c5.5_r5.5", (5, 5, 5, 5)),
        ("b12_c6.6_r6.6", (6, 6, 6, 6)),
        ("b14_c7.7_r7.7", (7, 7, 7, 7)),
        ("b16_c8.8_r8.8", (8, 8, 8, 8)), // the conventional 16-bit baseline
    ]
    .into_iter()
    .map(|(name, (wi, wf, ai, af))| {
        (
            name.to_string(),
            QuantConfig::from_split(wi, wf, ai, af).expect("static config"),
        )
    })
    .collect()
}

/// The paper's headline deployment config: conv 1/5 (6b), ReLU 2/2 (4b).
pub fn headline_config() -> QuantConfig {
    QuantConfig::from_split(1, 5, 2, 2).expect("static config")
}

/// The conventional 16-bit baseline config (Tensil's fixed width).
pub fn baseline16_config() -> QuantConfig {
    QuantConfig::from_split(8, 8, 8, 8).expect("static config")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn paper_headline_weight_format() {
        let f = FxpFormat::signed(6, 5).unwrap();
        assert_eq!(f.int_bits(), 1);
        assert_eq!(f.vmin(), -1.0);
        assert_eq!(f.vmax(), 1.0 - 2.0f64.powi(-5));
        assert_eq!(f.num_thresholds(), 63);
    }

    #[test]
    fn paper_headline_act_format() {
        let f = FxpFormat::unsigned(4, 2).unwrap();
        assert_eq!(f.qmin(), 0);
        assert_eq!(f.qmax(), 15);
        assert_eq!(f.vmax(), 3.75);
    }

    #[test]
    fn round_half_up_rule_matches_python() {
        // Mirrors test_fxp.py::test_round_half_up_exact_rule.
        let f = FxpFormat::signed(8, 0).unwrap();
        let cases = [
            (0.5f32, 1.0f32),
            (1.5, 2.0),
            (-0.5, 0.0),
            (-1.5, -1.0),
            (2.49, 2.0),
            (-2.51, -3.0),
        ];
        for (x, want) in cases {
            assert_eq!(f.quantize(x), want, "x={x}");
        }
    }

    #[test]
    fn rejects_bad_formats() {
        assert!(FxpFormat::signed(0, 0).is_err());
        assert!(FxpFormat::signed(33, 0).is_err());
        assert!(QuantConfig::new(
            FxpFormat::unsigned(6, 5).unwrap(),
            FxpFormat::unsigned(4, 2).unwrap()
        )
        .is_err());
    }

    #[test]
    fn table2_matches_paper_rows() {
        let cfgs = table2_configs();
        assert_eq!(cfgs.len(), 8);
        let maxes: Vec<u8> = cfgs.iter().map(|(_, c)| c.max_bits()).collect();
        assert_eq!(maxes, [5, 6, 6, 8, 10, 12, 14, 16]);
        let head = &cfgs[1].1;
        assert_eq!(head.weight.describe(), "s6.5");
        assert_eq!(head.act.describe(), "u4.2");
    }

    // ------------------------------------------------------ property tests
    // Hand-rolled harness (no proptest offline): many random cases per
    // invariant, deterministic seed, failures print the counterexample.

    fn random_format(r: &mut Rng, signed: bool) -> FxpFormat {
        let bits = 2 + r.below(15) as u8;
        let frac = r.below((bits + 8) as usize) as u8;
        FxpFormat::new(bits, frac, signed).unwrap()
    }

    #[test]
    fn prop_idempotent() {
        let mut r = Rng::new(100);
        for _ in 0..2_000 {
            let signed = r.next_f32() < 0.5;
            let f = random_format(&mut r, signed);
            let x = r.range_f32(-64.0, 64.0);
            let q1 = f.quantize(x);
            let q2 = f.quantize(q1);
            assert_eq!(q1, q2, "fmt {} x {x}", f.describe());
        }
    }

    #[test]
    fn prop_monotone() {
        let mut r = Rng::new(101);
        for _ in 0..2_000 {
            let f = random_format(&mut r, true);
            let a = r.range_f32(-64.0, 64.0);
            let b = r.range_f32(-64.0, 64.0);
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            assert!(
                f.quantize(lo) <= f.quantize(hi),
                "fmt {} lo {lo} hi {hi}",
                f.describe()
            );
        }
    }

    #[test]
    fn prop_saturates_and_stays_on_grid() {
        let mut r = Rng::new(102);
        for _ in 0..2_000 {
            let signed = r.next_f32() < 0.5;
            let f = random_format(&mut r, signed);
            let x = r.range_f32(-1e6, 1e6);
            let q = f.quantize(x);
            assert!(q as f64 >= f.vmin() - 1e-9 && q as f64 <= f.vmax() + 1e-9);
            let code = q as f64 * f.scale();
            assert_eq!(code, code.round(), "fmt {} x {x}", f.describe());
        }
    }

    #[test]
    fn prop_error_within_half_lsb_inside_range() {
        let mut r = Rng::new(103);
        for _ in 0..2_000 {
            let f = random_format(&mut r, true);
            let x = r.range_f32(-30.0, 30.0);
            if (x as f64) < f.vmin() || (x as f64) > f.vmax() {
                continue;
            }
            let q = f.quantize(x);
            assert!(
                ((q - x).abs() as f64) <= 0.5 / f.scale() + 1e-6,
                "fmt {} x {x} q {q}",
                f.describe()
            );
        }
    }

    #[test]
    fn prop_int_round_trip() {
        let mut r = Rng::new(104);
        for _ in 0..2_000 {
            let signed = r.next_f32() < 0.5;
            let f = random_format(&mut r, signed);
            let span = (f.qmax() - f.qmin() + 1) as usize;
            let code = f.qmin() + (r.below(span) as i64);
            let v = f.dequantize(code);
            assert_eq!(f.quantize_int(v), code, "fmt {} code {code}", f.describe());
        }
    }

    #[test]
    fn frac_bound_is_bits_plus_8_exactly() {
        // Mirrors test_fxp.py::test_frac_bits_bound_is_bits_plus_8.
        for bits in [1u8, 2, 4, 8, 16, 24, 32] {
            assert!(
                FxpFormat::new(bits, bits + 8, true).is_ok(),
                "bits {bits}: frac = bits + 8 must be accepted"
            );
            assert!(
                FxpFormat::new(bits, bits + 9, true).is_err(),
                "bits {bits}: frac = bits + 9 must be rejected"
            );
            assert!(FxpFormat::new(bits, bits + 8, false).is_ok());
            assert!(FxpFormat::new(bits, bits + 9, false).is_err());
        }
    }

    #[test]
    fn prop_pure_fractional_formats_stay_consistent() {
        // Boundary-region property: for frac in (bits, bits + 8] the
        // format is pure-fractional (negative int_bits) but the quantizer
        // grid, threshold count and range formulas all keep holding.
        let mut r = Rng::new(105);
        for _ in 0..2_000 {
            let bits = 1 + r.below(16) as u8;
            let frac = bits + 1 + r.below(8) as u8; // (bits, bits + 8]
            let signed = r.next_f32() < 0.5;
            let f = FxpFormat::new(bits, frac, signed).unwrap();
            assert!(f.int_bits() < 0);
            assert!(f.vmax() < 1.0, "fmt {} vmax {}", f.describe(), f.vmax());
            // Independent derivation (not the definition): a b-bit
            // quantizer spans 2^b codes -> 2^b - 1 threshold steps,
            // signed or not — fractional headroom must not change it.
            assert_eq!(f.num_thresholds(), (1i64 << f.bits) - 1);
            // Round-trip through codes is still exact on the grid
            // (bipolar has no zero code — sample from {-1, +1}).
            let code = if f.is_bipolar() {
                2 * (r.below(2) as i64) - 1
            } else {
                f.qmin() + r.below((f.qmax() - f.qmin() + 1) as usize) as i64
            };
            assert_eq!(f.quantize_int(f.dequantize(code)), code);
        }
    }

    #[test]
    fn pow2_decompose_exact_rationals() {
        assert_eq!(pow2_decompose(1.0), Some((1, 0)));
        assert_eq!(pow2_decompose(0.25), Some((1, -2)));
        assert_eq!(pow2_decompose(1.0 / 256.0), Some((1, -8)));
        assert_eq!(pow2_decompose(3.0), Some((3, 0)));
        assert_eq!(pow2_decompose(-0.75), Some((-3, -2)));
        assert_eq!(pow2_decompose(6.0), Some((3, 1)));
        assert_eq!(pow2_decompose(0.0), None);
        assert_eq!(pow2_decompose(f64::NAN), None);
        assert_eq!(pow2_decompose(f64::INFINITY), None);
        // Non-dyadic floats decompose to their exact rational bit pattern.
        let mut r = Rng::new(106);
        for _ in 0..2_000 {
            let x = (r.range_f32(-100.0, 100.0)) as f64;
            if x == 0.0 {
                continue;
            }
            let (m, e) = pow2_decompose(x).unwrap();
            assert_eq!(m.rem_euclid(2), 1, "m {m} must be odd for x {x}");
            assert_eq!(m as f64 * (2.0f64).powi(e), x, "reconstruct {x}");
        }
    }

    #[test]
    fn container_bits_rule_matches_python_twin() {
        // Mirrors test_fxp.py::test_container_bits_rule.
        assert_eq!(FxpFormat::unsigned(1, 0).unwrap().container_bits(), 1);
        assert_eq!(FxpFormat::signed(1, 0).unwrap().container_bits(), 1); // bipolar
        assert_eq!(FxpFormat::unsigned(2, 1).unwrap().container_bits(), 4);
        assert_eq!(FxpFormat::unsigned(4, 2).unwrap().container_bits(), 4);
        assert_eq!(FxpFormat::signed(4, 2).unwrap().container_bits(), 8);
        assert_eq!(FxpFormat::unsigned(5, 2).unwrap().container_bits(), 8);
        assert_eq!(FxpFormat::signed(8, 4).unwrap().container_bits(), 8);
        assert_eq!(FxpFormat::unsigned(7, 0).unwrap().container_bits(), 8);
        assert_eq!(FxpFormat::unsigned(8, 4).unwrap().container_bits(), 16);
        assert_eq!(FxpFormat::signed(16, 8).unwrap().container_bits(), 16);
        assert_eq!(FxpFormat::unsigned(15, 0).unwrap().container_bits(), 16);
        assert_eq!(FxpFormat::unsigned(16, 8).unwrap().container_bits(), 32);
        assert_eq!(FxpFormat::signed(32, 16).unwrap().container_bits(), 32);
        assert_eq!(FxpFormat::unsigned(32, 16).unwrap().container_bits(), 32);
        // The whole Table-II family, against an independent derivation
        // (not the definition): signed b-bit fits 2^(c-1) containers at
        // b <= c, unsigned b-bit packs sub-byte at b <= 4 and otherwise
        // needs c >= b + 1.
        for (name, cfg) in table2_configs() {
            let expect_w = match cfg.weight.bits {
                1 => 1, // bipolar
                2..=8 => 8,
                9..=16 => 16,
                _ => 32,
            };
            let expect_a = match cfg.act.bits {
                1 => 1,
                2..=4 => 4,
                5..=7 => 8,
                8..=15 => 16,
                _ => 32,
            };
            assert_eq!(cfg.weight.container_bits(), expect_w, "{name} weights");
            assert_eq!(cfg.act.container_bits(), expect_a, "{name} acts");
        }
        // The range-level rule is the same function all layers share.
        assert_eq!(container_bits_for_range(0, 1), 1);
        assert_eq!(container_bits_for_range(0, 3), 4);
        assert_eq!(container_bits_for_range(0, 15), 4);
        assert_eq!(container_bits_for_range(0, 16), 8);
        // Range-only can't see bipolar: [-1, 1] includes 0, so it gets a
        // byte container — the code-set-aware layers pick B1 instead.
        assert_eq!(container_bits_for_range(-1, 1), 8);
        assert_eq!(container_bits_for_range(-128, 127), 8);
        assert_eq!(container_bits_for_range(0, 255), 16);
        assert_eq!(container_bits_for_range(0, 1 << 20), 32);
        let head = headline_config();
        assert_eq!(head.weight.container_bits(), 8); // s6.5
        assert_eq!(head.act.container_bits(), 4); // u4.2 packs two per byte
    }

    #[test]
    fn bipolar_one_bit_format_semantics() {
        // Signed 1-bit is the FINN bipolar convention: codes {-1, +1},
        // sign-rule quantizer, one threshold step, 1-bit container.
        let f = FxpFormat::signed(1, 0).unwrap();
        assert!(f.is_bipolar());
        assert_eq!((f.qmin(), f.qmax()), (-1, 1));
        assert_eq!(f.num_thresholds(), 1);
        assert_eq!(f.quantize_int(0.7), 1);
        assert_eq!(f.quantize_int(0.0), 1);
        assert_eq!(f.quantize_int(-0.2), -1);
        assert_eq!(f.quantize(3.0), 1.0);
        assert_eq!(f.quantize(-3.0), -1.0);
        // Fractional bipolar scales the grid but keeps the sign rule.
        let f = FxpFormat::signed(1, 2).unwrap();
        assert_eq!(f.quantize(0.7), 0.25);
        assert_eq!(f.quantize(-0.1), -0.25);
        assert!(!FxpFormat::unsigned(1, 0).unwrap().is_bipolar());
    }

    #[test]
    fn acc_format_is_wide_enough() {
        let cfg = headline_config();
        let acc = cfg.acc_format();
        assert_eq!(acc.frac_bits, 7); // 5 + 2
        assert_eq!(acc.bits, 32);
        assert!(acc.signed);
    }
}
