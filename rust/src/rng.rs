//! SplitMix64 PRNG — the deterministic randomness source for episodes,
//! synthetic workloads and the property-test harness.
//!
//! The offline crate cache has no `rand` (DESIGN.md §2).  SplitMix64 is
//! tiny, fast, has a 64-bit state with provably full period, and passes
//! BigCrush when used as a stream; more than enough for sampling episodes
//! and property-test inputs.

/// Deterministic 64-bit PRNG (SplitMix64).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [0, 1) with f64 resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let l = m as u64;
            if l >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (k <= n), in random order.
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose_k({n}, {k})");
        // Partial Fisher-Yates over an index vector.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Independent child stream (for per-worker determinism).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "counts {counts:?}");
        }
    }

    #[test]
    fn choose_k_distinct_and_in_range() {
        let mut r = Rng::new(9);
        for _ in 0..100 {
            let picked = r.choose_k(20, 5);
            let mut sorted = picked.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 5);
            assert!(picked.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn normal_has_plausible_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Rng::new(1);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
