//! Tiny benchmark harness (no `criterion` in the offline crate set —
//! DESIGN.md §2): warmup + N samples, mean/p50/p95 reporting.

use std::time::{Duration, Instant};

/// One benchmark's samples.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<Duration>,
}

impl BenchResult {
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len().max(1) as u32
    }

    pub fn percentile(&self, p: f64) -> Duration {
        let mut v = self.samples.clone();
        v.sort();
        let idx = ((v.len() as f64 - 1.0) * p / 100.0).round() as usize;
        v[idx]
    }

    pub fn line(&self) -> String {
        format!(
            "{:<44} mean {:>10.3?}  p50 {:>10.3?}  p95 {:>10.3?}  n={}",
            self.name,
            self.mean(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.samples.len()
        )
    }
}

/// Run `f` with warmup, collect `iters` timed samples, print the line.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    let r = BenchResult {
        name: name.to_string(),
        samples,
    };
    println!("{}", r.line());
    r
}

/// Throughput helper: items/second from a result.
pub fn throughput(result: &BenchResult, items_per_iter: f64) -> f64 {
    items_per_iter / result.mean().as_secs_f64()
}

/// Environment knob for bench sizes (`BWADE_BENCH_EPISODES` etc.).
pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let r = bench("noop", 1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.samples.len(), 5);
        assert!(r.mean() < Duration::from_millis(10));
    }

    #[test]
    fn percentiles_ordered() {
        let r = BenchResult {
            name: "x".into(),
            samples: (1..=100).map(Duration::from_micros).collect(),
        };
        assert!(r.percentile(50.0) <= r.percentile(95.0));
    }

    #[test]
    fn env_default() {
        assert_eq!(env_usize("BWADE_NOT_SET_XYZ", 42), 42);
    }
}
