//! Tiny benchmark harness (no `criterion` in the offline crate set —
//! DESIGN.md §2): warmup + N samples, mean/p50/p95 reporting, plus the
//! `BENCH_serving.json` emitter that records the serving-throughput
//! trajectory (schema in DESIGN.md §10).

use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::json::{self, Json};

/// Nearest-rank index into a sorted sample of `len` items for
/// percentile `p` (percent): the smallest index whose rank covers a
/// `p/100` fraction of the data.  The single percentile convention of
/// the crate ([`BenchResult::percentile`],
/// `coordinator::Metrics::percentile_ms`):
///
/// * `None` for empty samples — callers report 0 instead of indexing;
/// * `p` clamps to [0, 100] (and non-finite `p` means 100), so p=0 is
///   the minimum and p=100 exactly the maximum — no interpolation and
///   no off-by-one past either end.
pub fn nearest_rank_index(len: usize, p: f64) -> Option<usize> {
    if len == 0 {
        return None;
    }
    let p = if p.is_finite() {
        p.clamp(0.0, 100.0)
    } else {
        100.0
    };
    let rank = (p / 100.0 * len as f64).ceil() as usize;
    Some(rank.clamp(1, len) - 1)
}

/// One benchmark's samples.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<Duration>,
}

impl BenchResult {
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len().max(1) as u32
    }

    pub fn percentile(&self, p: f64) -> Duration {
        let Some(idx) = nearest_rank_index(self.samples.len(), p) else {
            return Duration::ZERO;
        };
        let mut v = self.samples.clone();
        v.sort();
        v[idx]
    }

    pub fn line(&self) -> String {
        format!(
            "{:<44} mean {:>10.3?}  p50 {:>10.3?}  p95 {:>10.3?}  n={}",
            self.name,
            self.mean(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.samples.len()
        )
    }
}

/// Run `f` with warmup, collect `iters` timed samples, print the line.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    let r = BenchResult {
        name: name.to_string(),
        samples,
    };
    println!("{}", r.line());
    r
}

/// Throughput helper: items/second from a result.
pub fn throughput(result: &BenchResult, items_per_iter: f64) -> f64 {
    items_per_iter / result.mean().as_secs_f64()
}

/// Environment knob for bench sizes (`BWADE_BENCH_EPISODES` etc.).
pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Schema id stamped into `BENCH_serving.json`.
pub const SERVING_SCHEMA: &str = "bwade/bench-serving/v1";

/// One measured serving configuration — a row of `BENCH_serving.json`
/// (schema documented in DESIGN.md §10).
#[derive(Debug, Clone)]
pub struct ServingRow {
    /// Quantization config name (e.g. `b6_c1.5_r2.2`).
    pub config: String,
    /// `f32` or `bit-true`.
    pub datapath: String,
    pub replicas: usize,
    pub streams: usize,
    /// Frames served end to end in this measurement.
    pub frames: usize,
    /// Aggregate pool throughput (frames / pool wall clock).
    pub fps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Bytes one frame streams through the backbone kernels (0 when the
    /// engine cannot account for them).
    pub bytes_per_frame: u64,
}

impl ServingRow {
    fn to_json(&self) -> Json {
        json::obj(vec![
            ("config", Json::str(self.config.clone())),
            ("datapath", Json::str(self.datapath.clone())),
            ("replicas", Json::num(self.replicas as f64)),
            ("streams", Json::num(self.streams as f64)),
            ("frames", Json::num(self.frames as f64)),
            ("fps", Json::num(self.fps)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p95_ms", Json::num(self.p95_ms)),
            ("p99_ms", Json::num(self.p99_ms)),
            ("bytes_per_frame", Json::num(self.bytes_per_frame as f64)),
        ])
    }
}

/// Serialize serving rows to the `BENCH_serving.json` document (without
/// touching the filesystem — the testable half of the emitter).
pub fn serving_json(host_parallelism: usize, rows: &[ServingRow]) -> String {
    let doc = json::obj(vec![
        ("schema", Json::str(SERVING_SCHEMA)),
        ("host_parallelism", Json::num(host_parallelism as f64)),
        ("rows", Json::Arr(rows.iter().map(|r| r.to_json()).collect())),
    ]);
    doc.to_string_pretty() + "\n"
}

/// Record the serving perf trajectory: write `rows` to `path` (normally
/// `BENCH_serving.json` at the repo root, produced by the fig5 bench).
pub fn write_serving_json(path: &Path, host_parallelism: usize, rows: &[ServingRow]) -> Result<()> {
    std::fs::write(path, serving_json(host_parallelism, rows))
        .with_context(|| format!("writing {}", path.display()))
}

/// Schema id stamped into `BENCH_pipeline.json`.
pub const PIPELINE_SCHEMA: &str = "bwade/bench-pipeline/v1";

/// One measured pipeline configuration — a row of `BENCH_pipeline.json`
/// (schema documented in DESIGN.md §12).  `stages == 1` rows are the
/// sequential single-runner baseline the pipelined rows are judged
/// against.
#[derive(Debug, Clone)]
pub struct PipelineRow {
    /// Quantization config name (e.g. `b6_c1.5_r2.2`).
    pub config: String,
    /// `f32` or `bit-true`.
    pub datapath: String,
    /// Stage-worker count (1 = sequential baseline).
    pub stages: usize,
    /// Frames streamed in this measurement.
    pub frames: usize,
    /// End-to-end throughput (frames / wall clock).
    pub fps: f64,
    /// Measured steady-state inter-frame interval at egress.
    pub steady_ms: f64,
    /// DataflowSim's predicted steady-state interval for the design.
    pub predicted_steady_ms: f64,
}

impl PipelineRow {
    fn to_json(&self) -> Json {
        json::obj(vec![
            ("config", Json::str(self.config.clone())),
            ("datapath", Json::str(self.datapath.clone())),
            ("stages", Json::num(self.stages as f64)),
            ("frames", Json::num(self.frames as f64)),
            ("fps", Json::num(self.fps)),
            ("steady_ms", Json::num(self.steady_ms)),
            ("predicted_steady_ms", Json::num(self.predicted_steady_ms)),
        ])
    }
}

/// Serialize pipeline rows to the `BENCH_pipeline.json` document (the
/// testable half of the emitter, like [`serving_json`]).
pub fn pipeline_json(host_parallelism: usize, rows: &[PipelineRow]) -> String {
    let doc = json::obj(vec![
        ("schema", Json::str(PIPELINE_SCHEMA)),
        ("host_parallelism", Json::num(host_parallelism as f64)),
        ("rows", Json::Arr(rows.iter().map(|r| r.to_json()).collect())),
    ]);
    doc.to_string_pretty() + "\n"
}

/// Record the pipeline stage sweep: write `rows` to `path` (normally
/// `BENCH_pipeline.json` at the repo root, produced by the fig5 bench).
pub fn write_pipeline_json(
    path: &Path,
    host_parallelism: usize,
    rows: &[PipelineRow],
) -> Result<()> {
    std::fs::write(path, pipeline_json(host_parallelism, rows))
        .with_context(|| format!("writing {}", path.display()))
}

/// Schema id stamped into `BENCH_topology.json`.
pub const TOPOLOGY_SCHEMA: &str = "bwade/bench-topology/v1";

/// One measured composed-topology point — a row of `BENCH_topology.json`
/// (schema documented in DESIGN.md §13).  The sweep's axes: P pipelines
/// behind the pool × S stages × per-stage replication R.  `pipelines ==
/// 1 && stages == 1` rows are the single-runner baseline; pool-only
/// (P>1, S=1) and pipeline-only (P=1, S>1) rows bracket the composed
/// points.
#[derive(Debug, Clone)]
pub struct TopologyRow {
    /// Quantization config name (e.g. `b6_c1.5_r2.2`).
    pub config: String,
    /// `f32` or `bit-true`.
    pub datapath: String,
    /// Whole-pipeline replicas behind the work-stealing pool (P).
    pub pipelines: usize,
    /// Stages per pipeline (S).
    pub stages: usize,
    /// Per-stage worker counts, comma-joined (e.g. `1,2,1`) so the row
    /// stays flat for spreadsheet/jq consumers.
    pub stage_replicas: String,
    /// Total stage workers across the topology: P × ΣR.
    pub workers: usize,
    /// Frames streamed in this measurement.
    pub frames: usize,
    /// End-to-end throughput (frames / wall clock).
    pub fps: f64,
}

impl TopologyRow {
    fn to_json(&self) -> Json {
        json::obj(vec![
            ("config", Json::str(self.config.clone())),
            ("datapath", Json::str(self.datapath.clone())),
            ("pipelines", Json::num(self.pipelines as f64)),
            ("stages", Json::num(self.stages as f64)),
            ("stage_replicas", Json::str(self.stage_replicas.clone())),
            ("workers", Json::num(self.workers as f64)),
            ("frames", Json::num(self.frames as f64)),
            ("fps", Json::num(self.fps)),
        ])
    }
}

/// Serialize topology rows to the `BENCH_topology.json` document (the
/// testable half of the emitter, like [`serving_json`]).
pub fn topology_json(host_parallelism: usize, rows: &[TopologyRow]) -> String {
    let doc = json::obj(vec![
        ("schema", Json::str(TOPOLOGY_SCHEMA)),
        ("host_parallelism", Json::num(host_parallelism as f64)),
        ("rows", Json::Arr(rows.iter().map(|r| r.to_json()).collect())),
    ]);
    doc.to_string_pretty() + "\n"
}

/// Record the composed-topology sweep: write `rows` to `path` (normally
/// `BENCH_topology.json` at the repo root, produced by the fig5 bench).
pub fn write_topology_json(
    path: &Path,
    host_parallelism: usize,
    rows: &[TopologyRow],
) -> Result<()> {
    std::fs::write(path, topology_json(host_parallelism, rows))
        .with_context(|| format!("writing {}", path.display()))
}

/// Schema id stamped into `BENCH_kernels.json`.
pub const KERNELS_SCHEMA: &str = "bwade/bench-kernels/v1";

/// One recorded kernel comparison — a row of `BENCH_kernels.json`
/// (schema documented in DESIGN.md §11).  The `hotpath_micro` bench
/// emits these instead of leaving speedups as print-only output.
#[derive(Debug, Clone)]
pub struct KernelRow {
    /// Kernel under test (e.g. `mvau`, `multithreshold`, `backbone`).
    pub kernel: String,
    /// Shape / config label (e.g. `256x144x64` or `b6_c1.5_r2.2`).
    pub config: String,
    /// Baseline variant label (e.g. `f32`, `i32-wide`).
    pub baseline: String,
    /// Contender variant label (e.g. `packed-i8`).
    pub contender: String,
    pub baseline_ms: f64,
    pub contender_ms: f64,
}

impl KernelRow {
    /// From two measured [`BenchResult`]s (mean over samples).
    pub fn from_results(
        kernel: &str,
        config: &str,
        baseline: (&str, &BenchResult),
        contender: (&str, &BenchResult),
    ) -> KernelRow {
        KernelRow {
            kernel: kernel.to_string(),
            config: config.to_string(),
            baseline: baseline.0.to_string(),
            contender: contender.0.to_string(),
            baseline_ms: baseline.1.mean().as_secs_f64() * 1e3,
            contender_ms: contender.1.mean().as_secs_f64() * 1e3,
        }
    }

    /// Contender speedup over baseline (>1 means the contender wins).
    pub fn speedup(&self) -> f64 {
        if self.contender_ms > 0.0 {
            self.baseline_ms / self.contender_ms
        } else {
            0.0
        }
    }

    fn to_json(&self) -> Json {
        json::obj(vec![
            ("kernel", Json::str(self.kernel.clone())),
            ("config", Json::str(self.config.clone())),
            ("baseline", Json::str(self.baseline.clone())),
            ("contender", Json::str(self.contender.clone())),
            ("baseline_ms", Json::num(self.baseline_ms)),
            ("contender_ms", Json::num(self.contender_ms)),
            ("speedup", Json::num(self.speedup())),
        ])
    }
}

/// Serialize kernel rows to the `BENCH_kernels.json` document (the
/// testable half of the emitter, like [`serving_json`]).
pub fn kernels_json(rows: &[KernelRow]) -> String {
    let doc = json::obj(vec![
        ("schema", Json::str(KERNELS_SCHEMA)),
        ("rows", Json::Arr(rows.iter().map(|r| r.to_json()).collect())),
    ]);
    doc.to_string_pretty() + "\n"
}

/// Record kernel speedups: write `rows` to `path` (normally
/// `BENCH_kernels.json` at the repo root, produced by `hotpath_micro`).
pub fn write_kernels_json(path: &Path, rows: &[KernelRow]) -> Result<()> {
    std::fs::write(path, kernels_json(rows)).with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let r = bench("noop", 1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.samples.len(), 5);
        assert!(r.mean() < Duration::from_millis(10));
    }

    #[test]
    fn percentiles_ordered() {
        let r = BenchResult {
            name: "x".into(),
            samples: (1..=100).map(Duration::from_micros).collect(),
        };
        assert!(r.percentile(50.0) <= r.percentile(95.0));
    }

    #[test]
    fn env_default() {
        assert_eq!(env_usize("BWADE_NOT_SET_XYZ", 42), 42);
    }

    #[test]
    fn nearest_rank_convention() {
        // Empty: no index, callers report zero — including percentile()
        // itself, which used to index blind and panic.
        assert_eq!(nearest_rank_index(0, 50.0), None);
        let empty = BenchResult {
            name: "e".into(),
            samples: Vec::new(),
        };
        assert_eq!(empty.percentile(50.0), Duration::ZERO);

        // Nearest rank over 4 items: p=100 is exactly the last index
        // (the p=1.0-as-fraction off-by-one class of bug), p=0 the
        // first, out-of-range p clamps.
        assert_eq!(nearest_rank_index(4, 0.0), Some(0));
        assert_eq!(nearest_rank_index(4, 1.0), Some(0));
        assert_eq!(nearest_rank_index(4, 25.0), Some(0));
        assert_eq!(nearest_rank_index(4, 50.0), Some(1));
        assert_eq!(nearest_rank_index(4, 75.0), Some(2));
        assert_eq!(nearest_rank_index(4, 100.0), Some(3));
        assert_eq!(nearest_rank_index(4, 1000.0), Some(3));
        assert_eq!(nearest_rank_index(4, -3.0), Some(0));
        assert_eq!(nearest_rank_index(4, f64::NAN), Some(3));
        assert_eq!(nearest_rank_index(1, 100.0), Some(0));
    }

    #[test]
    fn kernels_json_schema_round_trip() {
        let base = BenchResult {
            name: "f32".into(),
            samples: vec![Duration::from_millis(4)],
        };
        let cont = BenchResult {
            name: "packed".into(),
            samples: vec![Duration::from_millis(1)],
        };
        let row =
            KernelRow::from_results("mvau", "256x144x64", ("f32", &base), ("packed-i8", &cont));
        assert!((row.speedup() - 4.0).abs() < 1e-9);
        let doc = kernels_json(&[row]);
        let parsed = Json::parse(&doc).expect("emitted document parses");
        assert_eq!(parsed.get("schema").unwrap().as_str().unwrap(), KERNELS_SCHEMA);
        let rows = parsed.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("kernel").unwrap().as_str().unwrap(), "mvau");
        assert_eq!(rows[0].get("contender").unwrap().as_str().unwrap(), "packed-i8");
        assert!((rows[0].get("speedup").unwrap().as_f64().unwrap() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn pipeline_json_schema_round_trip() {
        let rows = vec![
            PipelineRow {
                config: "b6_c1.5_r2.2".into(),
                datapath: "f32".into(),
                stages: 1,
                frames: 96,
                fps: 100.0,
                steady_ms: 10.0,
                predicted_steady_ms: 16.3,
            },
            PipelineRow {
                config: "b6_c1.5_r2.2".into(),
                datapath: "f32".into(),
                stages: 4,
                frames: 96,
                fps: 320.0,
                steady_ms: 3.125,
                predicted_steady_ms: 16.3,
            },
        ];
        let doc = pipeline_json(8, &rows);
        let parsed = Json::parse(&doc).expect("emitted document parses");
        assert_eq!(parsed.get("schema").unwrap().as_str().unwrap(), PIPELINE_SCHEMA);
        assert_eq!(parsed.get("host_parallelism").unwrap().as_usize().unwrap(), 8);
        let all = parsed.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].get("stages").unwrap().as_usize().unwrap(), 1);
        assert_eq!(all[1].get("stages").unwrap().as_usize().unwrap(), 4);
        assert_eq!(all[1].get("fps").unwrap().as_f64().unwrap(), 320.0);
        assert_eq!(all[1].get("steady_ms").unwrap().as_f64().unwrap(), 3.125);
    }

    #[test]
    fn topology_json_schema_round_trip() {
        let rows = vec![
            TopologyRow {
                config: "b6_c1.5_r2.2".into(),
                datapath: "f32".into(),
                pipelines: 1,
                stages: 1,
                stage_replicas: "1".into(),
                workers: 1,
                frames: 96,
                fps: 100.0,
            },
            TopologyRow {
                config: "b6_c1.5_r2.2".into(),
                datapath: "f32".into(),
                pipelines: 2,
                stages: 2,
                stage_replicas: "1,2".into(),
                workers: 6,
                frames: 96,
                fps: 410.0,
            },
        ];
        let doc = topology_json(8, &rows);
        let parsed = Json::parse(&doc).expect("emitted document parses");
        assert_eq!(parsed.get("schema").unwrap().as_str().unwrap(), TOPOLOGY_SCHEMA);
        assert_eq!(parsed.get("host_parallelism").unwrap().as_usize().unwrap(), 8);
        let all = parsed.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].get("pipelines").unwrap().as_usize().unwrap(), 1);
        assert_eq!(all[1].get("pipelines").unwrap().as_usize().unwrap(), 2);
        assert_eq!(all[1].get("stage_replicas").unwrap().as_str().unwrap(), "1,2");
        assert_eq!(all[1].get("workers").unwrap().as_usize().unwrap(), 6);
        assert_eq!(all[1].get("fps").unwrap().as_f64().unwrap(), 410.0);
    }

    #[test]
    fn serving_json_schema_round_trip() {
        let rows = vec![ServingRow {
            config: "b6_c1.5_r2.2".into(),
            datapath: "bit-true".into(),
            replicas: 4,
            streams: 8,
            frames: 240,
            fps: 812.5,
            p50_ms: 3.25,
            p95_ms: 7.5,
            p99_ms: 11.0,
            bytes_per_frame: 123_456,
        }];
        let doc = serving_json(4, &rows);
        let parsed = Json::parse(&doc).expect("emitted document parses");
        assert_eq!(parsed.get("schema").unwrap().as_str().unwrap(), SERVING_SCHEMA);
        assert_eq!(parsed.get("host_parallelism").unwrap().as_usize().unwrap(), 4);
        let all = parsed.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(all.len(), 1);
        let row = &all[0];
        assert_eq!(row.get("datapath").unwrap().as_str().unwrap(), "bit-true");
        assert_eq!(row.get("replicas").unwrap().as_usize().unwrap(), 4);
        assert_eq!(row.get("fps").unwrap().as_f64().unwrap(), 812.5);
        assert_eq!(row.get("bytes_per_frame").unwrap().as_usize().unwrap(), 123_456);
    }
}
