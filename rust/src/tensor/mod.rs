//! Small dense tensor with shape/stride utilities and a typed payload.
//!
//! Deliberately minimal: the graph executor and hardware models need
//! row-major storage, reshape/transpose, NCHW<->NHWC conversion and
//! elementwise access — not a full ndarray library.
//!
//! The payload is a [`TensorData`] enum: `F32` for the float simulation
//! path and `I8` / `I16` / `I32` for the bit-true integer datapath
//! (quantized codes, the numbers the FPGA actually streams — stored in
//! the narrowest container their format permits, so the CPU emulation
//! moves the same bytes the narrow hardware datapath would).  The f32
//! accessors keep their old signatures — `data()` / `data_mut()` /
//! `into_data()` panic on a code tensor, which is exactly the "no f32
//! arithmetic in integer steps" guard the bit-true plan relies on: a
//! float kernel touching a code tensor is a compile bug, not a silent
//! dequantization.
//!
//! The [`IntCode`] trait is the monomorphization seam for packed integer
//! kernels: `i8`, `i16` and `i32` implement it, widening losslessly to
//! `i32` for arithmetic while keeping storage (and therefore bandwidth)
//! width-native.

use anyhow::{anyhow, bail, Result};

/// Element type of a [`Tensor`].
///
/// `U4` / `U1` / `B1` are true sub-byte containers: two codes per byte
/// (nibbles, low nibble first) and eight codes per byte (bits, LSB
/// first).  `U1` holds binary codes {0, 1}; `B1` holds bipolar codes
/// {-1, +1} with bit 1 ↔ +1 — the FINN XNOR-popcount encoding.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I8,
    I16,
    I32,
    U4,
    U1,
    B1,
}

impl DType {
    /// Storage bytes per element.  Panics on the sub-byte containers —
    /// they have no per-element byte size; use [`DType::bytes_for`] for
    /// the bytes-moved-per-frame accounting (DESIGN.md §9).
    pub fn size_bytes(self) -> usize {
        match self {
            DType::I8 => 1,
            DType::I16 => 2,
            DType::F32 | DType::I32 => 4,
            DType::U4 | DType::U1 | DType::B1 => {
                panic!("size_bytes() on sub-byte container {self:?}; use DType::bytes_for")
            }
        }
    }

    /// Storage bits per code — the container width.
    pub fn bits(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 32,
            DType::I16 => 16,
            DType::I8 => 8,
            DType::U4 => 4,
            DType::U1 | DType::B1 => 1,
        }
    }

    /// Bytes a contiguous buffer of `numel` elements occupies, rounding
    /// the sub-byte tail up to a whole byte — the unit of the
    /// bytes-moved-per-frame accounting (DESIGN.md §9).
    pub fn bytes_for(self, numel: usize) -> usize {
        (numel * self.bits() + 7) / 8
    }

    /// True for the integer-code payloads (everything but `F32`).
    pub fn is_int(self) -> bool {
        self != DType::F32
    }

    /// True for the bit-packed sub-byte containers.
    pub fn is_packed(self) -> bool {
        matches!(self, DType::U4 | DType::U1 | DType::B1)
    }
}

// ------------------------------------------------------ sub-byte codecs

/// Pack u4 codes (each in 0..=15) two per byte, low nibble first; a
/// trailing odd code leaves the high nibble of the last byte zero.
pub fn pack_u4(codes: &[i32]) -> Result<Vec<u8>> {
    let mut bytes = vec![0u8; (codes.len() + 1) / 2];
    for (i, &c) in codes.iter().enumerate() {
        if !(0..=15).contains(&c) {
            bail!("pack_u4: code {c} at index {i} outside the u4 range 0..=15");
        }
        bytes[i / 2] |= (c as u8) << ((i & 1) * 4);
    }
    Ok(bytes)
}

/// Inverse of [`pack_u4`]: the first `len` nibbles as codes.
pub fn unpack_u4(bytes: &[u8], len: usize) -> Vec<i32> {
    (0..len)
        .map(|i| ((bytes[i / 2] >> ((i & 1) * 4)) & 0xF) as i32)
        .collect()
}

/// Pack 1-bit codes eight per byte, LSB first.  `bipolar` selects the
/// encoding: binary codes {0, 1} store the code as the bit; bipolar
/// codes {-1, +1} store bit 1 for +1 (tail bits of the last byte are
/// zero-padded in both encodings).
pub fn pack_u1(codes: &[i32], bipolar: bool) -> Result<Vec<u8>> {
    let mut bytes = vec![0u8; (codes.len() + 7) / 8];
    for (i, &c) in codes.iter().enumerate() {
        let bit = match (bipolar, c) {
            (false, 0) | (true, -1) => 0u8,
            (false, 1) | (true, 1) => 1u8,
            _ => bail!(
                "pack_u1: code {c} at index {i} outside the {} set",
                if bipolar { "bipolar {-1, +1}" } else { "binary {0, 1}" }
            ),
        };
        bytes[i / 8] |= bit << (i & 7);
    }
    Ok(bytes)
}

/// Inverse of [`pack_u1`]: the first `len` bits as codes.
pub fn unpack_u1(bytes: &[u8], len: usize, bipolar: bool) -> Vec<i32> {
    (0..len)
        .map(|i| {
            let b = ((bytes[i / 8] >> (i & 7)) & 1) as i32;
            if bipolar {
                2 * b - 1
            } else {
                b
            }
        })
        .collect()
}

/// Bit-packed nibble payload: two u4 codes per byte, low nibble first.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedU4 {
    bytes: Vec<u8>,
    len: usize,
}

impl PackedU4 {
    pub fn from_codes(codes: &[i32]) -> Result<Self> {
        Ok(Self {
            bytes: pack_u4(codes)?,
            len: codes.len(),
        })
    }

    pub fn zeros(len: usize) -> Self {
        Self {
            bytes: vec![0u8; (len + 1) / 2],
            len,
        }
    }

    /// Wrap a recycled byte buffer (the arena path): resized to hold
    /// `len` nibbles and zero-filled, so stale bits from a previous
    /// frame never leak into tail padding.
    pub fn from_buf(mut bytes: Vec<u8>, len: usize) -> Self {
        bytes.clear();
        bytes.resize((len + 1) / 2, 0);
        Self { bytes, len }
    }

    /// Surrender the byte buffer (back to the arena pool).
    pub fn into_bytes(self) -> Vec<u8> {
        self.bytes
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    #[inline(always)]
    pub fn get(&self, i: usize) -> i32 {
        ((self.bytes[i / 2] >> ((i & 1) * 4)) & 0xF) as i32
    }

    /// Overwrite code `i` (must be in 0..=15).
    #[inline]
    pub fn set(&mut self, i: usize, c: i32) -> Result<()> {
        if !(0..=15).contains(&c) {
            bail!("PackedU4::set: code {c} outside the u4 range 0..=15");
        }
        let shift = (i & 1) * 4;
        let b = &mut self.bytes[i / 2];
        *b = (*b & !(0xF << shift)) | ((c as u8) << shift);
        Ok(())
    }

    pub fn to_codes(&self) -> Vec<i32> {
        unpack_u4(&self.bytes, self.len)
    }
}

/// Bit-packed 1-bit payload: eight codes per byte, LSB first.  Shared
/// by the `U1` (binary, code = bit) and `B1` (bipolar, code = 2·bit−1)
/// containers — the variant selects the decoding.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedU1 {
    bytes: Vec<u8>,
    len: usize,
}

impl PackedU1 {
    pub fn from_codes(codes: &[i32], bipolar: bool) -> Result<Self> {
        Ok(Self {
            bytes: pack_u1(codes, bipolar)?,
            len: codes.len(),
        })
    }

    pub fn zeros(len: usize) -> Self {
        Self {
            bytes: vec![0u8; (len + 7) / 8],
            len,
        }
    }

    /// Wrap a recycled byte buffer (see [`PackedU4::from_buf`]).
    pub fn from_buf(mut bytes: Vec<u8>, len: usize) -> Self {
        bytes.clear();
        bytes.resize((len + 7) / 8, 0);
        Self { bytes, len }
    }

    /// Surrender the byte buffer (back to the arena pool).
    pub fn into_bytes(self) -> Vec<u8> {
        self.bytes
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// The raw bit (0 or 1) at code index `i`.
    #[inline(always)]
    pub fn bit(&self, i: usize) -> i32 {
        ((self.bytes[i / 8] >> (i & 7)) & 1) as i32
    }

    /// Overwrite bit `i`.
    #[inline]
    pub fn set_bit(&mut self, i: usize, bit: u8) {
        let b = &mut self.bytes[i / 8];
        *b = (*b & !(1 << (i & 7))) | ((bit & 1) << (i & 7));
    }

    pub fn to_codes(&self, bipolar: bool) -> Vec<i32> {
        unpack_u1(&self.bytes, self.len, bipolar)
    }
}

/// The typed payload: float values or packed integer fixed-point codes.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I8(Vec<i8>),
    I16(Vec<i16>),
    I32(Vec<i32>),
    U4(PackedU4),
    U1(PackedU1),
    B1(PackedU1),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I8(v) => v.len(),
            TensorData::I16(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::U4(p) => p.len(),
            TensorData::U1(p) | TensorData::B1(p) => p.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            TensorData::F32(_) => DType::F32,
            TensorData::I8(_) => DType::I8,
            TensorData::I16(_) => DType::I16,
            TensorData::I32(_) => DType::I32,
            TensorData::U4(_) => DType::U4,
            TensorData::U1(_) => DType::U1,
            TensorData::B1(_) => DType::B1,
        }
    }
}

/// Read-only width-generic view over any integer-code payload — the
/// dispatch seam for kernels that must accept packed sub-byte operands
/// (the byte-aligned monomorphized kernels stay the fast path).
#[derive(Clone, Copy)]
pub enum CodeView<'a> {
    I8(&'a [i8]),
    I16(&'a [i16]),
    I32(&'a [i32]),
    U4(&'a PackedU4),
    U1(&'a PackedU1),
    B1(&'a PackedU1),
}

impl<'a> CodeView<'a> {
    pub fn len(&self) -> usize {
        match self {
            CodeView::I8(v) => v.len(),
            CodeView::I16(v) => v.len(),
            CodeView::I32(v) => v.len(),
            CodeView::U4(p) => p.len(),
            CodeView::U1(p) | CodeView::B1(p) => p.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The code value at flat index `i`, widened to i32.
    #[inline(always)]
    pub fn get(&self, i: usize) -> i32 {
        match self {
            CodeView::I8(v) => v[i] as i32,
            CodeView::I16(v) => v[i] as i32,
            CodeView::I32(v) => v[i],
            CodeView::U4(p) => p.get(i),
            CodeView::U1(p) => p.bit(i),
            CodeView::B1(p) => 2 * p.bit(i) - 1,
        }
    }
}

/// Mutable width-generic code writer; `set` checks the value against
/// the container's representable set (overflow is a datapath error,
/// never a silent wrap).
pub enum CodeViewMut<'a> {
    I8(&'a mut [i8]),
    I16(&'a mut [i16]),
    I32(&'a mut [i32]),
    U4(&'a mut PackedU4),
    U1(&'a mut PackedU1),
    B1(&'a mut PackedU1),
}

impl<'a> CodeViewMut<'a> {
    pub fn len(&self) -> usize {
        match self {
            CodeViewMut::I8(v) => v.len(),
            CodeViewMut::I16(v) => v.len(),
            CodeViewMut::I32(v) => v.len(),
            CodeViewMut::U4(p) => p.len(),
            CodeViewMut::U1(p) | CodeViewMut::B1(p) => p.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: i64) -> Result<()> {
        match self {
            CodeViewMut::I8(s) => {
                s[i] = i8::try_from(v)
                    .map_err(|_| anyhow!("value {v} overflows the I8 container"))?
            }
            CodeViewMut::I16(s) => {
                s[i] = i16::try_from(v)
                    .map_err(|_| anyhow!("value {v} overflows the I16 container"))?
            }
            CodeViewMut::I32(s) => {
                s[i] = i32::try_from(v)
                    .map_err(|_| anyhow!("value {v} overflows the I32 container"))?
            }
            CodeViewMut::U4(p) => p.set(i, i32::try_from(v).unwrap_or(-1))?,
            CodeViewMut::U1(p) => match v {
                0 => p.set_bit(i, 0),
                1 => p.set_bit(i, 1),
                _ => bail!("value {v} outside the binary U1 set {{0, 1}}"),
            },
            CodeViewMut::B1(p) => match v {
                -1 => p.set_bit(i, 0),
                1 => p.set_bit(i, 1),
                _ => bail!("value {v} outside the bipolar B1 set {{-1, +1}}"),
            },
        }
        Ok(())
    }
}

/// An integer code container: the monomorphization seam of the packed
/// kernels in [`crate::ops`].  Codes widen losslessly to `i32` for
/// arithmetic (`widen`), narrow checked from the `i64` accumulator domain
/// (`from_wide`), and view their storage inside a [`TensorData`] without
/// copying (`slice` / `slice_mut`).
pub trait IntCode: Copy + Default + PartialEq + PartialOrd + Send + Sync + 'static {
    const DTYPE: DType;
    const BITS: u32;

    /// Lossless widening to the arithmetic type.
    fn widen(self) -> i32;

    /// Checked narrowing from the accumulator domain; `None` = the value
    /// overflows this container (an error on the datapath, never a wrap).
    fn from_wide(v: i64) -> Option<Self>;

    fn slice(data: &TensorData) -> Option<&[Self]>;
    fn slice_mut(data: &mut TensorData) -> Option<&mut [Self]>;
    fn wrap(v: Vec<Self>) -> TensorData;
}

macro_rules! impl_int_code {
    ($t:ty, $dtype:expr, $bits:expr, $variant:ident) => {
        impl IntCode for $t {
            const DTYPE: DType = $dtype;
            const BITS: u32 = $bits;

            #[inline(always)]
            fn widen(self) -> i32 {
                self as i32
            }

            #[inline(always)]
            fn from_wide(v: i64) -> Option<Self> {
                Self::try_from(v).ok()
            }

            #[inline]
            fn slice(data: &TensorData) -> Option<&[Self]> {
                match data {
                    TensorData::$variant(v) => Some(v),
                    _ => None,
                }
            }

            #[inline]
            fn slice_mut(data: &mut TensorData) -> Option<&mut [Self]> {
                match data {
                    TensorData::$variant(v) => Some(v),
                    _ => None,
                }
            }

            fn wrap(v: Vec<Self>) -> TensorData {
                TensorData::$variant(v)
            }
        }
    };
}

impl_int_code!(i8, DType::I8, 8, I8);
impl_int_code!(i16, DType::I16, 16, I16);
impl_int_code!(i32, DType::I32, 32, I32);

/// Row-major dense tensor (f32 values or i32 fixed-point codes).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: TensorData,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            bail!("shape {shape:?} wants {numel} elems, got {}", data.len());
        }
        Ok(Self {
            shape,
            data: TensorData::F32(data),
        })
    }

    /// Packed integer-code tensor of any container width.
    pub fn from_codes<T: IntCode>(shape: Vec<usize>, data: Vec<T>) -> Result<Self> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            bail!("shape {shape:?} wants {numel} elems, got {}", data.len());
        }
        Ok(Self {
            shape,
            data: T::wrap(data),
        })
    }

    /// i32-container code tensor (the bit-true datapath's widest type).
    pub fn new_i32(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        Self::from_codes(shape, data)
    }

    pub fn new_i16(shape: Vec<usize>, data: Vec<i16>) -> Result<Self> {
        Self::from_codes(shape, data)
    }

    pub fn new_i8(shape: Vec<usize>, data: Vec<i8>) -> Result<Self> {
        Self::from_codes(shape, data)
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let numel = shape.iter().product();
        Self {
            shape,
            data: TensorData::F32(vec![0.0; numel]),
        }
    }

    pub fn zeros_i32(shape: Vec<usize>) -> Self {
        Self::zeros_typed(shape, DType::I32)
    }

    /// Zero tensor of any element type (codes are 0 on every grid;
    /// `B1`'s all-zero bits decode to −1 — a bipolar buffer is only
    /// valid once a kernel has fully overwritten it).
    pub fn zeros_typed(shape: Vec<usize>, dtype: DType) -> Self {
        let numel = shape.iter().product();
        let data = match dtype {
            DType::F32 => TensorData::F32(vec![0.0; numel]),
            DType::I8 => TensorData::I8(vec![0; numel]),
            DType::I16 => TensorData::I16(vec![0; numel]),
            DType::I32 => TensorData::I32(vec![0; numel]),
            DType::U4 => TensorData::U4(PackedU4::zeros(numel)),
            DType::U1 => TensorData::U1(PackedU1::zeros(numel)),
            DType::B1 => TensorData::B1(PackedU1::zeros(numel)),
        };
        Self { shape, data }
    }

    /// Bit-packed code tensor: pack `codes` into the sub-byte container
    /// `dtype` (`U4`, `U1` or `B1`), checking every code against the
    /// container's representable set.
    pub fn from_codes_packed(shape: Vec<usize>, codes: &[i32], dtype: DType) -> Result<Self> {
        let numel: usize = shape.iter().product();
        if numel != codes.len() {
            bail!("shape {shape:?} wants {numel} elems, got {}", codes.len());
        }
        let data = match dtype {
            DType::U4 => TensorData::U4(PackedU4::from_codes(codes)?),
            DType::U1 => TensorData::U1(PackedU1::from_codes(codes, false)?),
            DType::B1 => TensorData::B1(PackedU1::from_codes(codes, true)?),
            other => bail!("from_codes_packed: {other:?} is not a sub-byte container"),
        };
        Ok(Self { shape, data })
    }

    /// Packed sub-byte tensor over a recycled byte buffer (the arena
    /// path): the buffer is resized to `bytes_for(numel)` and
    /// zero-filled, so stale bits from a previous frame never leak.
    pub fn packed_from_buf(shape: Vec<usize>, bytes: Vec<u8>, dtype: DType) -> Result<Self> {
        let numel: usize = shape.iter().product();
        let data = match dtype {
            DType::U4 => TensorData::U4(PackedU4::from_buf(bytes, numel)),
            DType::U1 => TensorData::U1(PackedU1::from_buf(bytes, numel)),
            DType::B1 => TensorData::B1(PackedU1::from_buf(bytes, numel)),
            other => bail!("packed_from_buf: {other:?} is not a sub-byte container"),
        };
        Ok(Self { shape, data })
    }

    pub fn full(shape: Vec<usize>, value: f32) -> Self {
        let numel = shape.iter().product();
        Self {
            shape,
            data: TensorData::F32(vec![value; numel]),
        }
    }

    pub fn scalar(value: f32) -> Self {
        Self {
            shape: vec![],
            data: TensorData::F32(vec![value]),
        }
    }

    pub fn from_fn(shape: Vec<usize>, mut f: impl FnMut(usize) -> f32) -> Self {
        let numel: usize = shape.iter().product();
        Self {
            shape,
            data: TensorData::F32((0..numel).map(|i| f(i)).collect()),
        }
    }

    pub fn from_fn_i32(shape: Vec<usize>, mut f: impl FnMut(usize) -> i32) -> Self {
        let numel: usize = shape.iter().product();
        Self {
            shape,
            data: TensorData::I32((0..numel).map(|i| f(i)).collect()),
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn dtype(&self) -> DType {
        self.data.dtype()
    }

    pub fn is_i32(&self) -> bool {
        self.dtype() == DType::I32
    }

    /// True for any packed integer-code payload (i8 / i16 / i32).
    pub fn is_int(&self) -> bool {
        self.dtype().is_int()
    }

    /// f32 payload.  Panics on a code tensor — a float kernel reading
    /// integer codes is a plan-compilation bug, never a legal cast.
    pub fn data(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            _ => panic!("Tensor::data(): f32 access on an integer code tensor"),
        }
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            TensorData::F32(v) => v,
            _ => panic!("Tensor::data_mut(): f32 access on an integer code tensor"),
        }
    }

    pub fn into_data(self) -> Vec<f32> {
        match self.data {
            TensorData::F32(v) => v,
            _ => panic!("Tensor::into_data(): f32 access on an integer code tensor"),
        }
    }

    /// i32 code payload.  Panics unless the container is exactly i32 —
    /// width-generic readers go through [`Tensor::codes`] or
    /// [`Tensor::codes_i32`] instead.
    pub fn data_i32(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            _ => panic!("Tensor::data_i32(): i32 access on a {:?} tensor", self.dtype()),
        }
    }

    pub fn data_i32_mut(&mut self) -> &mut [i32] {
        match &mut self.data {
            TensorData::I32(v) => v,
            other => panic!("Tensor::data_i32_mut(): i32 access on a {:?} tensor", other.dtype()),
        }
    }

    pub fn into_data_i32(self) -> Vec<i32> {
        match self.data {
            TensorData::I32(v) => v,
            other => panic!("Tensor::into_data_i32(): i32 access on a {:?} tensor", other.dtype()),
        }
    }

    /// Typed view of a packed code payload; `None` on container mismatch.
    pub fn codes<T: IntCode>(&self) -> Option<&[T]> {
        T::slice(&self.data)
    }

    pub fn codes_mut<T: IntCode>(&mut self) -> Option<&mut [T]> {
        T::slice_mut(&mut self.data)
    }

    /// Widened copy of any integer-code payload (test/egress convenience —
    /// the hot paths read the packed storage directly).  Panics on f32.
    pub fn codes_i32(&self) -> Vec<i32> {
        match &self.data {
            TensorData::F32(_) => panic!("Tensor::codes_i32(): integer access on an f32 tensor"),
            TensorData::I8(v) => v.iter().map(|&c| c as i32).collect(),
            TensorData::I16(v) => v.iter().map(|&c| c as i32).collect(),
            TensorData::I32(v) => v.clone(),
            TensorData::U4(p) => p.to_codes(),
            TensorData::U1(p) => p.to_codes(false),
            TensorData::B1(p) => p.to_codes(true),
        }
    }

    /// Width-generic read view over any integer-code payload (packed
    /// containers included); `None` on f32.
    pub fn code_view(&self) -> Option<CodeView<'_>> {
        Some(match &self.data {
            TensorData::F32(_) => return None,
            TensorData::I8(v) => CodeView::I8(v),
            TensorData::I16(v) => CodeView::I16(v),
            TensorData::I32(v) => CodeView::I32(v),
            TensorData::U4(p) => CodeView::U4(p),
            TensorData::U1(p) => CodeView::U1(p),
            TensorData::B1(p) => CodeView::B1(p),
        })
    }

    /// Width-generic write view over any integer-code payload; `None`
    /// on f32.
    pub fn code_view_mut(&mut self) -> Option<CodeViewMut<'_>> {
        Some(match &mut self.data {
            TensorData::F32(_) => return None,
            TensorData::I8(v) => CodeViewMut::I8(v),
            TensorData::I16(v) => CodeViewMut::I16(v),
            TensorData::I32(v) => CodeViewMut::I32(v),
            TensorData::U4(p) => CodeViewMut::U4(p),
            TensorData::U1(p) => CodeViewMut::U1(p),
            TensorData::B1(p) => CodeViewMut::B1(p),
        })
    }

    /// Storage bytes of this tensor's payload (sub-byte tails rounded up).
    pub fn storage_bytes(&self) -> usize {
        self.dtype().bytes_for(self.numel())
    }

    /// Dtype-agnostic payload access (kernel dispatch and the arena).
    pub fn raw_data(&self) -> &TensorData {
        &self.data
    }

    pub fn raw_data_mut(&mut self) -> &mut TensorData {
        &mut self.data
    }

    pub fn into_raw_data(self) -> TensorData {
        self.data
    }

    /// Row-major strides for the current shape.
    pub fn strides(&self) -> Vec<usize> {
        strides_of(&self.shape)
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        self.reshape_in_place(shape)?;
        Ok(self)
    }

    /// Metadata-only reshape of an owned buffer (the plan engine's
    /// zero-copy Reshape path).
    pub fn reshape_in_place(&mut self, shape: Vec<usize>) -> Result<()> {
        let numel: usize = shape.iter().product();
        if numel != self.data.len() {
            bail!(
                "reshape {:?} -> {shape:?} changes element count",
                self.shape
            );
        }
        self.shape = shape;
        Ok(())
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        // Arity is checked unconditionally: a rank mismatch in release
        // would otherwise silently read the wrong element (the kernels
        // never come through this accessor, so the check is free where
        // it matters).
        assert_eq!(
            idx.len(),
            self.shape.len(),
            "at(): index arity {} != tensor rank {}",
            idx.len(),
            self.shape.len()
        );
        let mut off = 0;
        let strides = self.strides();
        for (i, &ix) in idx.iter().enumerate() {
            debug_assert!(
                ix < self.shape[i],
                "at(): index {ix} out of bounds for axis {i} (extent {})",
                self.shape[i]
            );
            off += ix * strides[i];
        }
        self.data()[off]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        // Always-on arity check; see `at`.
        assert_eq!(
            idx.len(),
            self.shape.len(),
            "set(): index arity {} != tensor rank {}",
            idx.len(),
            self.shape.len()
        );
        let mut off = 0;
        let strides = self.strides();
        for (i, &ix) in idx.iter().enumerate() {
            debug_assert!(
                ix < self.shape[i],
                "set(): index {ix} out of bounds for axis {i} (extent {})",
                self.shape[i]
            );
            off += ix * strides[i];
        }
        self.data_mut()[off] = v;
    }

    /// Generalized transpose: output axis i takes input axis `perm[i]`.
    /// Dtype-preserving (the bit-true plan transposes code tensors too).
    pub fn transpose(&self, perm: &[usize]) -> Result<Self> {
        let out_shape: Vec<usize> = self.transposed_shape(perm)?;
        let mut out = Tensor::zeros_typed(out_shape, self.dtype());
        self.transpose_into(perm, &mut out)?;
        Ok(out)
    }

    /// The shape a transpose by `perm` would produce (validates `perm`).
    pub fn transposed_shape(&self, perm: &[usize]) -> Result<Vec<usize>> {
        if perm.len() != self.shape.len() {
            bail!("perm {perm:?} rank mismatch with {:?}", self.shape);
        }
        let mut seen = vec![false; perm.len()];
        for &p in perm {
            if p >= perm.len() || seen[p] {
                bail!("bad permutation {perm:?}");
            }
            seen[p] = true;
        }
        Ok(perm.iter().map(|&p| self.shape[p]).collect())
    }

    /// Transpose into a caller-provided buffer (the plan engine's path;
    /// `out` must already have the permuted shape and the same dtype).
    pub fn transpose_into(&self, perm: &[usize], out: &mut Tensor) -> Result<()> {
        let out_shape = self.transposed_shape(perm)?;
        if out.shape != out_shape {
            bail!(
                "transpose_into: out shape {:?} != permuted shape {out_shape:?}",
                out.shape
            );
        }
        let in_strides = self.strides();
        let out_strides = strides_of(&out_shape);
        if self.dtype().is_packed() || out.dtype().is_packed() {
            if self.dtype() != out.dtype() {
                bail!(
                    "transpose_into: dtype mismatch ({:?} -> {:?})",
                    self.dtype(),
                    out.dtype()
                );
            }
            // Sub-byte transpose: bit-addressed get/set (cold path — the
            // lowered graphs only transpose at the f32 ingress).
            let view = self.code_view().expect("packed payload");
            let rank = perm.len();
            let n = out.numel();
            let mut dstv = out.code_view_mut().expect("packed payload");
            let mut idx = vec![0usize; rank];
            for o in 0..n {
                let mut rem = o;
                for d in 0..rank {
                    idx[d] = rem / out_strides[d];
                    rem %= out_strides[d];
                }
                let mut in_off = 0;
                for d in 0..rank {
                    in_off += idx[d] * in_strides[perm[d]];
                }
                dstv.set(o, view.get(in_off) as i64)?;
            }
            return Ok(());
        }
        match (&self.data, &mut out.data) {
            (TensorData::F32(src), TensorData::F32(dst)) => {
                transpose_copy(src, dst, &in_strides, &out_strides, perm)
            }
            (TensorData::I8(src), TensorData::I8(dst)) => {
                transpose_copy(src, dst, &in_strides, &out_strides, perm)
            }
            (TensorData::I16(src), TensorData::I16(dst)) => {
                transpose_copy(src, dst, &in_strides, &out_strides, perm)
            }
            (TensorData::I32(src), TensorData::I32(dst)) => {
                transpose_copy(src, dst, &in_strides, &out_strides, perm)
            }
            _ => bail!(
                "transpose_into: dtype mismatch ({:?} -> {:?})",
                self.dtype(),
                out.dtype()
            ),
        }
        Ok(())
    }

    /// NCHW -> NHWC.
    pub fn nchw_to_nhwc(&self) -> Result<Self> {
        self.transpose(&[0, 2, 3, 1])
    }

    /// NHWC -> NCHW.
    pub fn nhwc_to_nchw(&self) -> Result<Self> {
        self.transpose(&[0, 3, 1, 2])
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Self {
        Self {
            shape: self.shape.clone(),
            data: TensorData::F32(self.data().iter().map(|&x| f(x)).collect()),
        }
    }

    /// Elementwise binary op with numpy-style broadcasting (f32 only).
    pub fn broadcast_with(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Self> {
        let out_shape = broadcast_shape(&self.shape, &other.shape)?;
        let numel: usize = out_shape.iter().product();
        let mut out = Tensor::new(out_shape, vec![0.0f32; numel])?;
        self.broadcast_into(other, f, &mut out)?;
        Ok(out)
    }

    /// Broadcasting binary op into a caller-provided buffer (`out` must
    /// already have the broadcast shape; aliasing `out` with `self` or
    /// `other` is not supported).
    pub fn broadcast_into(
        &self,
        other: &Tensor,
        f: impl Fn(f32, f32) -> f32,
        out: &mut Tensor,
    ) -> Result<()> {
        let out_shape = broadcast_shape(&self.shape, &other.shape)?;
        if out.shape != out_shape {
            bail!(
                "broadcast_into: out shape {:?} != broadcast shape {out_shape:?}",
                out.shape
            );
        }
        let a_data = self.data();
        let b_data = other.data();
        let od = out.data_mut();
        // Fast paths: same-shape zip and scalar rhs cover almost every op
        // on the request path (bias adds, residual adds, scale muls).
        if b_data.len() == 1 {
            let b = b_data[0];
            for (slot, &a) in od.iter_mut().zip(a_data) {
                *slot = f(a, b);
            }
            return Ok(());
        }
        if self.shape == other.shape {
            for ((slot, &a), &b) in od.iter_mut().zip(a_data).zip(b_data) {
                *slot = f(a, b);
            }
            return Ok(());
        }
        let rank = out_shape.len();
        let a_shape = pad_shape(&self.shape, rank);
        let b_shape = pad_shape(&other.shape, rank);
        let a_str = broadcast_strides(&a_shape, &strides_of(&a_shape));
        let b_str = broadcast_strides(&b_shape, &strides_of(&b_shape));
        let out_strides = strides_of(&out_shape);
        let mut idx = vec![0usize; rank];
        for (o, slot) in od.iter_mut().enumerate() {
            let mut rem = o;
            for d in 0..rank {
                idx[d] = rem / out_strides[d];
                rem %= out_strides[d];
            }
            let mut ao = 0;
            let mut bo = 0;
            for d in 0..rank {
                ao += if a_shape[d] == 1 { 0 } else { idx[d] } * a_str[d];
                bo += if b_shape[d] == 1 { 0 } else { idx[d] } * b_str[d];
            }
            *slot = f(a_data[ao], b_data[bo]);
        }
        Ok(())
    }

    /// In-place broadcasting binary op: `self[i] = f(self[i], other[...])`.
    /// Requires the broadcast shape to equal `self`'s shape (i.e. `other`
    /// broadcasts into `self`) — the plan engine's in-place elementwise
    /// path, which avoids one buffer per node.
    pub fn broadcast_assign(
        &mut self,
        other: &Tensor,
        f: impl Fn(f32, f32) -> f32,
    ) -> Result<()> {
        let out_shape = broadcast_shape(&self.shape, &other.shape)?;
        if out_shape != self.shape {
            bail!(
                "broadcast_assign: result shape {out_shape:?} != lhs shape {:?}",
                self.shape
            );
        }
        let b_data = other.data();
        if b_data.len() == 1 {
            let b = b_data[0];
            for a in self.data_mut().iter_mut() {
                *a = f(*a, b);
            }
            return Ok(());
        }
        if self.shape == other.shape {
            for (a, &b) in self.data_mut().iter_mut().zip(b_data) {
                *a = f(*a, b);
            }
            return Ok(());
        }
        let rank = self.shape.len();
        let b_shape = pad_shape(&other.shape, rank);
        let b_str = broadcast_strides(&b_shape, &strides_of(&b_shape));
        let out_strides = strides_of(&self.shape);
        let mut idx = vec![0usize; rank];
        for (o, a) in self.data_mut().iter_mut().enumerate() {
            let mut rem = o;
            for d in 0..rank {
                idx[d] = rem / out_strides[d];
                rem %= out_strides[d];
            }
            let mut bo = 0;
            for d in 0..rank {
                bo += if b_shape[d] == 1 { 0 } else { idx[d] } * b_str[d];
            }
            *a = f(*a, b_data[bo]);
        }
        Ok(())
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data()
            .iter()
            .zip(other.data())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn allclose(&self, other: &Tensor, atol: f32) -> bool {
        self.shape == other.shape && self.max_abs_diff(other) <= atol
    }
}

fn transpose_copy<T: Copy>(
    src: &[T],
    dst: &mut [T],
    in_strides: &[usize],
    out_strides: &[usize],
    perm: &[usize],
) {
    let rank = perm.len();
    let mut idx = vec![0usize; rank];
    for (o, slot) in dst.iter_mut().enumerate() {
        // Decompose o into output index.
        let mut rem = o;
        for d in 0..rank {
            idx[d] = rem / out_strides[d];
            rem %= out_strides[d];
        }
        let mut in_off = 0;
        for d in 0..rank {
            in_off += idx[d] * in_strides[perm[d]];
        }
        *slot = src[in_off];
    }
}

pub fn strides_of(shape: &[usize]) -> Vec<usize> {
    let mut strides = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        strides[i] = strides[i + 1] * shape[i + 1];
    }
    strides
}

fn pad_shape(shape: &[usize], rank: usize) -> Vec<usize> {
    let mut s = vec![1usize; rank - shape.len()];
    s.extend_from_slice(shape);
    s
}

fn broadcast_strides(shape: &[usize], strides: &[usize]) -> Vec<usize> {
    shape
        .iter()
        .zip(strides)
        .map(|(&s, &st)| if s == 1 { 0 } else { st })
        .collect()
}

pub fn broadcast_shape(a: &[usize], b: &[usize]) -> Result<Vec<usize>> {
    let rank = a.len().max(b.len());
    let a = pad_shape(a, rank);
    let b = pad_shape(b, rank);
    let mut out = Vec::with_capacity(rank);
    for (&x, &y) in a.iter().zip(&b) {
        if x == y || x == 1 || y == 1 {
            out.push(x.max(y));
        } else {
            bail!("cannot broadcast {a:?} with {b:?}");
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_element_count() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::new_i32(vec![2, 3], vec![0; 6]).is_ok());
        assert!(Tensor::new_i32(vec![2, 3], vec![0; 5]).is_err());
    }

    #[test]
    fn strides_row_major() {
        let t = Tensor::zeros(vec![2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn at_and_set() {
        let mut t = Tensor::zeros(vec![2, 3]);
        t.set(&[1, 2], 5.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
        assert_eq!(t.at(&[0, 0]), 0.0);
    }

    #[test]
    fn transpose_2d() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let tt = t.transpose(&[1, 0]).unwrap();
        assert_eq!(tt.shape(), &[3, 2]);
        assert_eq!(tt.data(), &[1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn transpose_round_trip_nchw_nhwc() {
        let t = Tensor::from_fn(vec![1, 3, 4, 4], |i| i as f32);
        let back = t.nchw_to_nhwc().unwrap().nhwc_to_nchw().unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn transpose_rejects_bad_perm() {
        let t = Tensor::zeros(vec![2, 3]);
        assert!(t.transpose(&[0, 0]).is_err());
        assert!(t.transpose(&[0]).is_err());
    }

    #[test]
    fn broadcast_scalar() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::scalar(10.0);
        let c = a.broadcast_with(&b, |x, y| x * y).unwrap();
        assert_eq!(c.data(), &[10., 20., 30., 40.]);
    }

    #[test]
    fn broadcast_per_channel_bias_nchw() {
        // [1,2,2,2] + [2,1,1] channel bias (as exported biases broadcast).
        let a = Tensor::from_fn(vec![1, 2, 2, 2], |_| 0.0);
        let b = Tensor::new(vec![2, 1, 1], vec![1.0, 2.0]).unwrap();
        let c = a.broadcast_with(&b, |x, y| x + y).unwrap();
        assert_eq!(c.shape(), &[1, 2, 2, 2]);
        assert_eq!(c.data()[0..4], [1.0; 4]);
        assert_eq!(c.data()[4..8], [2.0; 4]);
    }

    #[test]
    fn broadcast_incompatible_fails() {
        let a = Tensor::zeros(vec![2, 3]);
        let b = Tensor::zeros(vec![2, 4]);
        assert!(a.broadcast_with(&b, |x, _| x).is_err());
    }

    #[test]
    fn broadcast_assign_matches_broadcast_with() {
        let a = Tensor::from_fn(vec![1, 2, 2, 2], |i| i as f32);
        let b = Tensor::new(vec![2, 1, 1], vec![1.0, 2.0]).unwrap();
        let want = a.broadcast_with(&b, |x, y| x + y).unwrap();
        let mut got = a.clone();
        got.broadcast_assign(&b, |x, y| x + y).unwrap();
        assert_eq!(got, want);
        // Scalar rhs fast path.
        let s = Tensor::scalar(3.0);
        let want = a.broadcast_with(&s, |x, y| x * y).unwrap();
        let mut got = a.clone();
        got.broadcast_assign(&s, |x, y| x * y).unwrap();
        assert_eq!(got, want);
        // Result shape growing beyond lhs must be rejected.
        let wide = Tensor::zeros(vec![3, 1]);
        assert!(Tensor::zeros(vec![1, 4]).broadcast_assign(&wide, |x, _| x).is_err());
    }

    #[test]
    fn transpose_into_validates_out_shape() {
        let t = Tensor::from_fn(vec![2, 3], |i| i as f32);
        let mut bad = Tensor::zeros(vec![2, 3]);
        assert!(t.transpose_into(&[1, 0], &mut bad).is_err());
        let mut good = Tensor::zeros(vec![3, 2]);
        t.transpose_into(&[1, 0], &mut good).unwrap();
        assert_eq!(good, t.transpose(&[1, 0]).unwrap());
    }

    #[test]
    fn reshape_in_place_is_metadata_only() {
        let mut t = Tensor::from_fn(vec![2, 3], |i| i as f32);
        let ptr = t.data().as_ptr();
        t.reshape_in_place(vec![3, 2]).unwrap();
        assert_eq!(t.shape(), &[3, 2]);
        assert_eq!(t.data().as_ptr(), ptr);
        assert!(t.reshape_in_place(vec![7]).is_err());
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let r = t.clone().reshape(vec![3, 2]).unwrap();
        assert_eq!(r.data(), t.data());
        assert!(t.reshape(vec![4, 2]).is_err());
    }

    // -------------------------------------------------- typed payloads

    #[test]
    fn i32_tensor_round_trip_and_dtype() {
        let t = Tensor::new_i32(vec![2, 2], vec![1, -2, 3, -4]).unwrap();
        assert_eq!(t.dtype(), DType::I32);
        assert!(t.is_i32());
        assert_eq!(t.data_i32(), &[1, -2, 3, -4]);
        assert_eq!(t.numel(), 4);
        let z = Tensor::zeros_i32(vec![3]);
        assert_eq!(z.data_i32(), &[0, 0, 0]);
        assert_eq!(t.into_data_i32(), vec![1, -2, 3, -4]);
    }

    #[test]
    fn i32_transpose_matches_f32_transpose() {
        let f = Tensor::from_fn(vec![2, 3, 4], |i| i as f32);
        let i = Tensor::from_fn_i32(vec![2, 3, 4], |i| i as i32);
        let ft = f.transpose(&[2, 0, 1]).unwrap();
        let it = i.transpose(&[2, 0, 1]).unwrap();
        assert_eq!(it.shape(), ft.shape());
        for (a, b) in it.data_i32().iter().zip(ft.data()) {
            assert_eq!(*a as f32, *b);
        }
    }

    #[test]
    fn transpose_into_rejects_dtype_mismatch() {
        let i = Tensor::from_fn_i32(vec![2, 3], |i| i as i32);
        let mut f_out = Tensor::zeros(vec![3, 2]);
        assert!(i.transpose_into(&[1, 0], &mut f_out).is_err());
        let mut i_out = Tensor::zeros_i32(vec![3, 2]);
        i.transpose_into(&[1, 0], &mut i_out).unwrap();
        assert_eq!(i_out.data_i32(), &[0, 3, 1, 4, 2, 5]);
    }

    #[test]
    fn i32_reshape_is_metadata_only() {
        let mut t = Tensor::from_fn_i32(vec![2, 3], |i| i as i32);
        let ptr = t.data_i32().as_ptr();
        t.reshape_in_place(vec![6]).unwrap();
        assert_eq!(t.data_i32().as_ptr(), ptr);
    }

    #[test]
    #[should_panic(expected = "f32 access on an integer code tensor")]
    fn f32_access_on_i32_tensor_panics() {
        let t = Tensor::zeros_i32(vec![2]);
        let _ = t.data();
    }

    #[test]
    #[should_panic(expected = "i32 access on a F32 tensor")]
    fn i32_access_on_f32_tensor_panics() {
        let t = Tensor::zeros(vec![2]);
        let _ = t.data_i32();
    }

    // ------------------------------------------------ packed containers

    #[test]
    fn packed_containers_round_trip() {
        let t8 = Tensor::new_i8(vec![2, 2], vec![-128, -1, 0, 127]).unwrap();
        assert_eq!(t8.dtype(), DType::I8);
        assert!(t8.is_int() && !t8.is_i32());
        assert_eq!(t8.codes::<i8>().unwrap(), &[-128, -1, 0, 127]);
        assert!(t8.codes::<i32>().is_none());
        assert_eq!(t8.codes_i32(), vec![-128, -1, 0, 127]);

        let t16 = Tensor::new_i16(vec![3], vec![-32768, 255, 32767]).unwrap();
        assert_eq!(t16.dtype(), DType::I16);
        assert_eq!(t16.codes_i32(), vec![-32768, 255, 32767]);
        assert!(Tensor::new_i8(vec![2], vec![1]).is_err());
    }

    #[test]
    fn zeros_typed_matches_dtype_and_size() {
        for (dt, bytes) in [
            (DType::F32, 4),
            (DType::I8, 1),
            (DType::I16, 2),
            (DType::I32, 4),
        ] {
            let t = Tensor::zeros_typed(vec![2, 3], dt);
            assert_eq!(t.dtype(), dt);
            assert_eq!(t.numel(), 6);
            assert_eq!(dt.size_bytes(), bytes);
        }
        assert!(DType::I8.is_int() && !DType::F32.is_int());
    }

    #[test]
    fn packed_transpose_preserves_container() {
        let t = Tensor::new_i8(vec![2, 3], vec![0, 1, 2, 3, 4, 5]).unwrap();
        let tt = t.transpose(&[1, 0]).unwrap();
        assert_eq!(tt.dtype(), DType::I8);
        assert_eq!(tt.codes::<i8>().unwrap(), &[0, 3, 1, 4, 2, 5]);
        // Mixed-container transpose_into is a dtype error, not a cast.
        let mut wide = Tensor::zeros_i32(vec![3, 2]);
        assert!(t.transpose_into(&[1, 0], &mut wide).is_err());
    }

    // ------------------------------------------------- sub-byte codecs

    #[test]
    fn u4_codec_round_trips_all_codes_and_tails() {
        // All code values × odd/even lengths × tail bytes.
        for len in 0..=33 {
            let codes: Vec<i32> = (0..len).map(|i| (i * 7 + 3) as i32 % 16).collect();
            let bytes = pack_u4(&codes).unwrap();
            assert_eq!(bytes.len(), (len + 1) / 2);
            assert_eq!(unpack_u4(&bytes, len), codes);
            if len % 2 == 1 {
                // Odd tail: the high nibble of the last byte is padding.
                assert_eq!(bytes[len / 2] >> 4, 0, "tail nibble not zero at len {len}");
            }
        }
        // Every representable code survives.
        let all: Vec<i32> = (0..16).collect();
        assert_eq!(unpack_u4(&pack_u4(&all).unwrap(), 16), all);
        // Out-of-range codes are an error, not a wrap.
        assert!(pack_u4(&[16]).is_err());
        assert!(pack_u4(&[-1]).is_err());
    }

    #[test]
    fn u1_codec_round_trips_binary_and_bipolar() {
        for len in 0..=25 {
            let bin: Vec<i32> = (0..len).map(|i| ((i * 5 + 1) % 3 == 0) as i32).collect();
            let bytes = pack_u1(&bin, false).unwrap();
            assert_eq!(bytes.len(), (len + 7) / 8);
            assert_eq!(unpack_u1(&bytes, len, false), bin);
            let bip: Vec<i32> = bin.iter().map(|&b| 2 * b - 1).collect();
            let bytes = pack_u1(&bip, true).unwrap();
            assert_eq!(unpack_u1(&bytes, len, true), bip);
            if len % 8 != 0 && !bytes.is_empty() {
                // Tail bits beyond `len` are zero-padded.
                assert_eq!(bytes[bytes.len() - 1] >> (len % 8), 0);
            }
        }
        // Encoding mismatches are errors: binary rejects -1, bipolar
        // rejects 0 (zero is unrepresentable in a bipolar container).
        assert!(pack_u1(&[-1], false).is_err());
        assert!(pack_u1(&[0], true).is_err());
        assert!(pack_u1(&[2], false).is_err());
    }

    #[test]
    fn packed_tensor_round_trip_and_views() {
        let codes: Vec<i32> = (0..11).map(|i| i % 16).collect();
        let t = Tensor::from_codes_packed(vec![11], &codes, DType::U4).unwrap();
        assert_eq!(t.dtype(), DType::U4);
        assert_eq!(t.numel(), 11);
        assert_eq!(t.storage_bytes(), 6);
        assert_eq!(t.codes_i32(), codes);
        let v = t.code_view().unwrap();
        assert_eq!(v.get(10), 10);

        let bip: Vec<i32> = (0..10).map(|i| if i % 3 == 0 { 1 } else { -1 }).collect();
        let t = Tensor::from_codes_packed(vec![2, 5], &bip, DType::B1).unwrap();
        assert_eq!(t.dtype(), DType::B1);
        assert_eq!(t.storage_bytes(), 2);
        assert_eq!(t.codes_i32(), bip);

        // Mutation through the write view is checked.
        let mut t = Tensor::zeros_typed(vec![4], DType::U1);
        {
            let mut w = t.code_view_mut().unwrap();
            w.set(2, 1).unwrap();
            assert!(w.set(0, 2).is_err());
        }
        assert_eq!(t.codes_i32(), vec![0, 0, 1, 0]);
    }

    #[test]
    fn packed_transpose_round_trips() {
        let codes: Vec<i32> = (0..12).map(|i| i % 16).collect();
        let t = Tensor::from_codes_packed(vec![3, 4], &codes, DType::U4).unwrap();
        let tt = t.transpose(&[1, 0]).unwrap();
        assert_eq!(tt.dtype(), DType::U4);
        assert_eq!(tt.shape(), &[4, 3]);
        let back = tt.transpose(&[1, 0]).unwrap();
        assert_eq!(back.codes_i32(), codes);
    }

    #[test]
    fn dtype_bits_and_bytes_for() {
        assert_eq!(DType::U4.bits(), 4);
        assert_eq!(DType::U1.bits(), 1);
        assert_eq!(DType::B1.bits(), 1);
        assert_eq!(DType::U4.bytes_for(11), 6);
        assert_eq!(DType::U1.bytes_for(8), 1);
        assert_eq!(DType::U1.bytes_for(9), 2);
        assert_eq!(DType::I8.bytes_for(9), 9);
        assert_eq!(DType::F32.bytes_for(3), 12);
        assert!(DType::U4.is_packed() && !DType::I8.is_packed());
    }

    #[test]
    #[should_panic(expected = "index arity")]
    fn at_arity_mismatch_panics_in_release_too() {
        let t = Tensor::zeros(vec![2, 3]);
        // Rank-1 index into a rank-2 tensor must panic even with
        // debug_assertions off — this is the always-on accessor check.
        let _ = t.at(&[1]);
    }

    #[test]
    #[should_panic(expected = "index arity")]
    fn set_arity_mismatch_panics_in_release_too() {
        let mut t = Tensor::zeros(vec![2, 3]);
        t.set(&[1], 0.0);
    }

    #[test]
    fn int_code_widen_and_narrow() {
        assert_eq!(<i8 as IntCode>::from_wide(127), Some(127i8));
        assert_eq!(<i8 as IntCode>::from_wide(128), None);
        assert_eq!(<i16 as IntCode>::from_wide(-32768), Some(-32768i16));
        assert_eq!(<i16 as IntCode>::from_wide(32768), None);
        assert_eq!(<i32 as IntCode>::from_wide(1 << 33), None);
        assert_eq!((-5i8).widen(), -5i32);
        assert_eq!(<i8 as IntCode>::BITS, 8);
        assert_eq!(<i16 as IntCode>::DTYPE, DType::I16);
    }
}
