//! Small dense tensor with shape/stride utilities and a typed payload.
//!
//! Deliberately minimal: the graph executor and hardware models need
//! row-major storage, reshape/transpose, NCHW<->NHWC conversion and
//! elementwise access — not a full ndarray library.
//!
//! The payload is a [`TensorData`] enum: `F32` for the float simulation
//! path and `I8` / `I16` / `I32` for the bit-true integer datapath
//! (quantized codes, the numbers the FPGA actually streams — stored in
//! the narrowest container their format permits, so the CPU emulation
//! moves the same bytes the narrow hardware datapath would).  The f32
//! accessors keep their old signatures — `data()` / `data_mut()` /
//! `into_data()` panic on a code tensor, which is exactly the "no f32
//! arithmetic in integer steps" guard the bit-true plan relies on: a
//! float kernel touching a code tensor is a compile bug, not a silent
//! dequantization.
//!
//! The [`IntCode`] trait is the monomorphization seam for packed integer
//! kernels: `i8`, `i16` and `i32` implement it, widening losslessly to
//! `i32` for arithmetic while keeping storage (and therefore bandwidth)
//! width-native.

use anyhow::{bail, Result};

/// Element type of a [`Tensor`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I8,
    I16,
    I32,
}

impl DType {
    /// Storage bytes per element — the unit of the bytes-moved-per-frame
    /// accounting (DESIGN.md §9).
    pub fn size_bytes(self) -> usize {
        match self {
            DType::I8 => 1,
            DType::I16 => 2,
            DType::F32 | DType::I32 => 4,
        }
    }

    /// True for the integer-code payloads (everything but `F32`).
    pub fn is_int(self) -> bool {
        self != DType::F32
    }
}

/// The typed payload: float values or packed integer fixed-point codes.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I8(Vec<i8>),
    I16(Vec<i16>),
    I32(Vec<i32>),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I8(v) => v.len(),
            TensorData::I16(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            TensorData::F32(_) => DType::F32,
            TensorData::I8(_) => DType::I8,
            TensorData::I16(_) => DType::I16,
            TensorData::I32(_) => DType::I32,
        }
    }
}

/// An integer code container: the monomorphization seam of the packed
/// kernels in [`crate::ops`].  Codes widen losslessly to `i32` for
/// arithmetic (`widen`), narrow checked from the `i64` accumulator domain
/// (`from_wide`), and view their storage inside a [`TensorData`] without
/// copying (`slice` / `slice_mut`).
pub trait IntCode: Copy + Default + PartialEq + PartialOrd + Send + Sync + 'static {
    const DTYPE: DType;
    const BITS: u32;

    /// Lossless widening to the arithmetic type.
    fn widen(self) -> i32;

    /// Checked narrowing from the accumulator domain; `None` = the value
    /// overflows this container (an error on the datapath, never a wrap).
    fn from_wide(v: i64) -> Option<Self>;

    fn slice(data: &TensorData) -> Option<&[Self]>;
    fn slice_mut(data: &mut TensorData) -> Option<&mut [Self]>;
    fn wrap(v: Vec<Self>) -> TensorData;
}

macro_rules! impl_int_code {
    ($t:ty, $dtype:expr, $bits:expr, $variant:ident) => {
        impl IntCode for $t {
            const DTYPE: DType = $dtype;
            const BITS: u32 = $bits;

            #[inline(always)]
            fn widen(self) -> i32 {
                self as i32
            }

            #[inline(always)]
            fn from_wide(v: i64) -> Option<Self> {
                Self::try_from(v).ok()
            }

            #[inline]
            fn slice(data: &TensorData) -> Option<&[Self]> {
                match data {
                    TensorData::$variant(v) => Some(v),
                    _ => None,
                }
            }

            #[inline]
            fn slice_mut(data: &mut TensorData) -> Option<&mut [Self]> {
                match data {
                    TensorData::$variant(v) => Some(v),
                    _ => None,
                }
            }

            fn wrap(v: Vec<Self>) -> TensorData {
                TensorData::$variant(v)
            }
        }
    };
}

impl_int_code!(i8, DType::I8, 8, I8);
impl_int_code!(i16, DType::I16, 16, I16);
impl_int_code!(i32, DType::I32, 32, I32);

/// Row-major dense tensor (f32 values or i32 fixed-point codes).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: TensorData,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            bail!("shape {shape:?} wants {numel} elems, got {}", data.len());
        }
        Ok(Self {
            shape,
            data: TensorData::F32(data),
        })
    }

    /// Packed integer-code tensor of any container width.
    pub fn from_codes<T: IntCode>(shape: Vec<usize>, data: Vec<T>) -> Result<Self> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            bail!("shape {shape:?} wants {numel} elems, got {}", data.len());
        }
        Ok(Self {
            shape,
            data: T::wrap(data),
        })
    }

    /// i32-container code tensor (the bit-true datapath's widest type).
    pub fn new_i32(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        Self::from_codes(shape, data)
    }

    pub fn new_i16(shape: Vec<usize>, data: Vec<i16>) -> Result<Self> {
        Self::from_codes(shape, data)
    }

    pub fn new_i8(shape: Vec<usize>, data: Vec<i8>) -> Result<Self> {
        Self::from_codes(shape, data)
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let numel = shape.iter().product();
        Self {
            shape,
            data: TensorData::F32(vec![0.0; numel]),
        }
    }

    pub fn zeros_i32(shape: Vec<usize>) -> Self {
        Self::zeros_typed(shape, DType::I32)
    }

    /// Zero tensor of any element type (codes are 0 on every grid).
    pub fn zeros_typed(shape: Vec<usize>, dtype: DType) -> Self {
        let numel = shape.iter().product();
        let data = match dtype {
            DType::F32 => TensorData::F32(vec![0.0; numel]),
            DType::I8 => TensorData::I8(vec![0; numel]),
            DType::I16 => TensorData::I16(vec![0; numel]),
            DType::I32 => TensorData::I32(vec![0; numel]),
        };
        Self { shape, data }
    }

    pub fn full(shape: Vec<usize>, value: f32) -> Self {
        let numel = shape.iter().product();
        Self {
            shape,
            data: TensorData::F32(vec![value; numel]),
        }
    }

    pub fn scalar(value: f32) -> Self {
        Self {
            shape: vec![],
            data: TensorData::F32(vec![value]),
        }
    }

    pub fn from_fn(shape: Vec<usize>, mut f: impl FnMut(usize) -> f32) -> Self {
        let numel: usize = shape.iter().product();
        Self {
            shape,
            data: TensorData::F32((0..numel).map(|i| f(i)).collect()),
        }
    }

    pub fn from_fn_i32(shape: Vec<usize>, mut f: impl FnMut(usize) -> i32) -> Self {
        let numel: usize = shape.iter().product();
        Self {
            shape,
            data: TensorData::I32((0..numel).map(|i| f(i)).collect()),
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn dtype(&self) -> DType {
        self.data.dtype()
    }

    pub fn is_i32(&self) -> bool {
        self.dtype() == DType::I32
    }

    /// True for any packed integer-code payload (i8 / i16 / i32).
    pub fn is_int(&self) -> bool {
        self.dtype().is_int()
    }

    /// f32 payload.  Panics on a code tensor — a float kernel reading
    /// integer codes is a plan-compilation bug, never a legal cast.
    pub fn data(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            _ => panic!("Tensor::data(): f32 access on an integer code tensor"),
        }
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            TensorData::F32(v) => v,
            _ => panic!("Tensor::data_mut(): f32 access on an integer code tensor"),
        }
    }

    pub fn into_data(self) -> Vec<f32> {
        match self.data {
            TensorData::F32(v) => v,
            _ => panic!("Tensor::into_data(): f32 access on an integer code tensor"),
        }
    }

    /// i32 code payload.  Panics unless the container is exactly i32 —
    /// width-generic readers go through [`Tensor::codes`] or
    /// [`Tensor::codes_i32`] instead.
    pub fn data_i32(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            _ => panic!("Tensor::data_i32(): i32 access on a {:?} tensor", self.dtype()),
        }
    }

    pub fn data_i32_mut(&mut self) -> &mut [i32] {
        match &mut self.data {
            TensorData::I32(v) => v,
            other => panic!("Tensor::data_i32_mut(): i32 access on a {:?} tensor", other.dtype()),
        }
    }

    pub fn into_data_i32(self) -> Vec<i32> {
        match self.data {
            TensorData::I32(v) => v,
            other => panic!("Tensor::into_data_i32(): i32 access on a {:?} tensor", other.dtype()),
        }
    }

    /// Typed view of a packed code payload; `None` on container mismatch.
    pub fn codes<T: IntCode>(&self) -> Option<&[T]> {
        T::slice(&self.data)
    }

    pub fn codes_mut<T: IntCode>(&mut self) -> Option<&mut [T]> {
        T::slice_mut(&mut self.data)
    }

    /// Widened copy of any integer-code payload (test/egress convenience —
    /// the hot paths read the packed storage directly).  Panics on f32.
    pub fn codes_i32(&self) -> Vec<i32> {
        match &self.data {
            TensorData::F32(_) => panic!("Tensor::codes_i32(): integer access on an f32 tensor"),
            TensorData::I8(v) => v.iter().map(|&c| c as i32).collect(),
            TensorData::I16(v) => v.iter().map(|&c| c as i32).collect(),
            TensorData::I32(v) => v.clone(),
        }
    }

    /// Dtype-agnostic payload access (kernel dispatch and the arena).
    pub fn raw_data(&self) -> &TensorData {
        &self.data
    }

    pub fn raw_data_mut(&mut self) -> &mut TensorData {
        &mut self.data
    }

    pub fn into_raw_data(self) -> TensorData {
        self.data
    }

    /// Row-major strides for the current shape.
    pub fn strides(&self) -> Vec<usize> {
        strides_of(&self.shape)
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        self.reshape_in_place(shape)?;
        Ok(self)
    }

    /// Metadata-only reshape of an owned buffer (the plan engine's
    /// zero-copy Reshape path).
    pub fn reshape_in_place(&mut self, shape: Vec<usize>) -> Result<()> {
        let numel: usize = shape.iter().product();
        if numel != self.data.len() {
            bail!(
                "reshape {:?} -> {shape:?} changes element count",
                self.shape
            );
        }
        self.shape = shape;
        Ok(())
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(
            idx.len(),
            self.shape.len(),
            "at(): index arity {} != tensor rank {}",
            idx.len(),
            self.shape.len()
        );
        let mut off = 0;
        let strides = self.strides();
        for (i, &ix) in idx.iter().enumerate() {
            debug_assert!(
                ix < self.shape[i],
                "at(): index {ix} out of bounds for axis {i} (extent {})",
                self.shape[i]
            );
            off += ix * strides[i];
        }
        self.data()[off]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        debug_assert_eq!(
            idx.len(),
            self.shape.len(),
            "set(): index arity {} != tensor rank {}",
            idx.len(),
            self.shape.len()
        );
        let mut off = 0;
        let strides = self.strides();
        for (i, &ix) in idx.iter().enumerate() {
            debug_assert!(
                ix < self.shape[i],
                "set(): index {ix} out of bounds for axis {i} (extent {})",
                self.shape[i]
            );
            off += ix * strides[i];
        }
        self.data_mut()[off] = v;
    }

    /// Generalized transpose: output axis i takes input axis `perm[i]`.
    /// Dtype-preserving (the bit-true plan transposes code tensors too).
    pub fn transpose(&self, perm: &[usize]) -> Result<Self> {
        let out_shape: Vec<usize> = self.transposed_shape(perm)?;
        let mut out = Tensor::zeros_typed(out_shape, self.dtype());
        self.transpose_into(perm, &mut out)?;
        Ok(out)
    }

    /// The shape a transpose by `perm` would produce (validates `perm`).
    pub fn transposed_shape(&self, perm: &[usize]) -> Result<Vec<usize>> {
        if perm.len() != self.shape.len() {
            bail!("perm {perm:?} rank mismatch with {:?}", self.shape);
        }
        let mut seen = vec![false; perm.len()];
        for &p in perm {
            if p >= perm.len() || seen[p] {
                bail!("bad permutation {perm:?}");
            }
            seen[p] = true;
        }
        Ok(perm.iter().map(|&p| self.shape[p]).collect())
    }

    /// Transpose into a caller-provided buffer (the plan engine's path;
    /// `out` must already have the permuted shape and the same dtype).
    pub fn transpose_into(&self, perm: &[usize], out: &mut Tensor) -> Result<()> {
        let out_shape = self.transposed_shape(perm)?;
        if out.shape != out_shape {
            bail!(
                "transpose_into: out shape {:?} != permuted shape {out_shape:?}",
                out.shape
            );
        }
        let in_strides = self.strides();
        let out_strides = strides_of(&out_shape);
        match (&self.data, &mut out.data) {
            (TensorData::F32(src), TensorData::F32(dst)) => {
                transpose_copy(src, dst, &in_strides, &out_strides, perm)
            }
            (TensorData::I8(src), TensorData::I8(dst)) => {
                transpose_copy(src, dst, &in_strides, &out_strides, perm)
            }
            (TensorData::I16(src), TensorData::I16(dst)) => {
                transpose_copy(src, dst, &in_strides, &out_strides, perm)
            }
            (TensorData::I32(src), TensorData::I32(dst)) => {
                transpose_copy(src, dst, &in_strides, &out_strides, perm)
            }
            _ => bail!(
                "transpose_into: dtype mismatch ({:?} -> {:?})",
                self.dtype(),
                out.dtype()
            ),
        }
        Ok(())
    }

    /// NCHW -> NHWC.
    pub fn nchw_to_nhwc(&self) -> Result<Self> {
        self.transpose(&[0, 2, 3, 1])
    }

    /// NHWC -> NCHW.
    pub fn nhwc_to_nchw(&self) -> Result<Self> {
        self.transpose(&[0, 3, 1, 2])
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Self {
        Self {
            shape: self.shape.clone(),
            data: TensorData::F32(self.data().iter().map(|&x| f(x)).collect()),
        }
    }

    /// Elementwise binary op with numpy-style broadcasting (f32 only).
    pub fn broadcast_with(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Self> {
        let out_shape = broadcast_shape(&self.shape, &other.shape)?;
        let numel: usize = out_shape.iter().product();
        let mut out = Tensor::new(out_shape, vec![0.0f32; numel])?;
        self.broadcast_into(other, f, &mut out)?;
        Ok(out)
    }

    /// Broadcasting binary op into a caller-provided buffer (`out` must
    /// already have the broadcast shape; aliasing `out` with `self` or
    /// `other` is not supported).
    pub fn broadcast_into(
        &self,
        other: &Tensor,
        f: impl Fn(f32, f32) -> f32,
        out: &mut Tensor,
    ) -> Result<()> {
        let out_shape = broadcast_shape(&self.shape, &other.shape)?;
        if out.shape != out_shape {
            bail!(
                "broadcast_into: out shape {:?} != broadcast shape {out_shape:?}",
                out.shape
            );
        }
        let a_data = self.data();
        let b_data = other.data();
        let od = out.data_mut();
        // Fast paths: same-shape zip and scalar rhs cover almost every op
        // on the request path (bias adds, residual adds, scale muls).
        if b_data.len() == 1 {
            let b = b_data[0];
            for (slot, &a) in od.iter_mut().zip(a_data) {
                *slot = f(a, b);
            }
            return Ok(());
        }
        if self.shape == other.shape {
            for ((slot, &a), &b) in od.iter_mut().zip(a_data).zip(b_data) {
                *slot = f(a, b);
            }
            return Ok(());
        }
        let rank = out_shape.len();
        let a_shape = pad_shape(&self.shape, rank);
        let b_shape = pad_shape(&other.shape, rank);
        let a_str = broadcast_strides(&a_shape, &strides_of(&a_shape));
        let b_str = broadcast_strides(&b_shape, &strides_of(&b_shape));
        let out_strides = strides_of(&out_shape);
        let mut idx = vec![0usize; rank];
        for (o, slot) in od.iter_mut().enumerate() {
            let mut rem = o;
            for d in 0..rank {
                idx[d] = rem / out_strides[d];
                rem %= out_strides[d];
            }
            let mut ao = 0;
            let mut bo = 0;
            for d in 0..rank {
                ao += if a_shape[d] == 1 { 0 } else { idx[d] } * a_str[d];
                bo += if b_shape[d] == 1 { 0 } else { idx[d] } * b_str[d];
            }
            *slot = f(a_data[ao], b_data[bo]);
        }
        Ok(())
    }

    /// In-place broadcasting binary op: `self[i] = f(self[i], other[...])`.
    /// Requires the broadcast shape to equal `self`'s shape (i.e. `other`
    /// broadcasts into `self`) — the plan engine's in-place elementwise
    /// path, which avoids one buffer per node.
    pub fn broadcast_assign(
        &mut self,
        other: &Tensor,
        f: impl Fn(f32, f32) -> f32,
    ) -> Result<()> {
        let out_shape = broadcast_shape(&self.shape, &other.shape)?;
        if out_shape != self.shape {
            bail!(
                "broadcast_assign: result shape {out_shape:?} != lhs shape {:?}",
                self.shape
            );
        }
        let b_data = other.data();
        if b_data.len() == 1 {
            let b = b_data[0];
            for a in self.data_mut().iter_mut() {
                *a = f(*a, b);
            }
            return Ok(());
        }
        if self.shape == other.shape {
            for (a, &b) in self.data_mut().iter_mut().zip(b_data) {
                *a = f(*a, b);
            }
            return Ok(());
        }
        let rank = self.shape.len();
        let b_shape = pad_shape(&other.shape, rank);
        let b_str = broadcast_strides(&b_shape, &strides_of(&b_shape));
        let out_strides = strides_of(&self.shape);
        let mut idx = vec![0usize; rank];
        for (o, a) in self.data_mut().iter_mut().enumerate() {
            let mut rem = o;
            for d in 0..rank {
                idx[d] = rem / out_strides[d];
                rem %= out_strides[d];
            }
            let mut bo = 0;
            for d in 0..rank {
                bo += if b_shape[d] == 1 { 0 } else { idx[d] } * b_str[d];
            }
            *a = f(*a, b_data[bo]);
        }
        Ok(())
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data()
            .iter()
            .zip(other.data())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn allclose(&self, other: &Tensor, atol: f32) -> bool {
        self.shape == other.shape && self.max_abs_diff(other) <= atol
    }
}

fn transpose_copy<T: Copy>(
    src: &[T],
    dst: &mut [T],
    in_strides: &[usize],
    out_strides: &[usize],
    perm: &[usize],
) {
    let rank = perm.len();
    let mut idx = vec![0usize; rank];
    for (o, slot) in dst.iter_mut().enumerate() {
        // Decompose o into output index.
        let mut rem = o;
        for d in 0..rank {
            idx[d] = rem / out_strides[d];
            rem %= out_strides[d];
        }
        let mut in_off = 0;
        for d in 0..rank {
            in_off += idx[d] * in_strides[perm[d]];
        }
        *slot = src[in_off];
    }
}

pub fn strides_of(shape: &[usize]) -> Vec<usize> {
    let mut strides = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        strides[i] = strides[i + 1] * shape[i + 1];
    }
    strides
}

fn pad_shape(shape: &[usize], rank: usize) -> Vec<usize> {
    let mut s = vec![1usize; rank - shape.len()];
    s.extend_from_slice(shape);
    s
}

fn broadcast_strides(shape: &[usize], strides: &[usize]) -> Vec<usize> {
    shape
        .iter()
        .zip(strides)
        .map(|(&s, &st)| if s == 1 { 0 } else { st })
        .collect()
}

pub fn broadcast_shape(a: &[usize], b: &[usize]) -> Result<Vec<usize>> {
    let rank = a.len().max(b.len());
    let a = pad_shape(a, rank);
    let b = pad_shape(b, rank);
    let mut out = Vec::with_capacity(rank);
    for (&x, &y) in a.iter().zip(&b) {
        if x == y || x == 1 || y == 1 {
            out.push(x.max(y));
        } else {
            bail!("cannot broadcast {a:?} with {b:?}");
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_element_count() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::new_i32(vec![2, 3], vec![0; 6]).is_ok());
        assert!(Tensor::new_i32(vec![2, 3], vec![0; 5]).is_err());
    }

    #[test]
    fn strides_row_major() {
        let t = Tensor::zeros(vec![2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn at_and_set() {
        let mut t = Tensor::zeros(vec![2, 3]);
        t.set(&[1, 2], 5.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
        assert_eq!(t.at(&[0, 0]), 0.0);
    }

    #[test]
    fn transpose_2d() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let tt = t.transpose(&[1, 0]).unwrap();
        assert_eq!(tt.shape(), &[3, 2]);
        assert_eq!(tt.data(), &[1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn transpose_round_trip_nchw_nhwc() {
        let t = Tensor::from_fn(vec![1, 3, 4, 4], |i| i as f32);
        let back = t.nchw_to_nhwc().unwrap().nhwc_to_nchw().unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn transpose_rejects_bad_perm() {
        let t = Tensor::zeros(vec![2, 3]);
        assert!(t.transpose(&[0, 0]).is_err());
        assert!(t.transpose(&[0]).is_err());
    }

    #[test]
    fn broadcast_scalar() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::scalar(10.0);
        let c = a.broadcast_with(&b, |x, y| x * y).unwrap();
        assert_eq!(c.data(), &[10., 20., 30., 40.]);
    }

    #[test]
    fn broadcast_per_channel_bias_nchw() {
        // [1,2,2,2] + [2,1,1] channel bias (as exported biases broadcast).
        let a = Tensor::from_fn(vec![1, 2, 2, 2], |_| 0.0);
        let b = Tensor::new(vec![2, 1, 1], vec![1.0, 2.0]).unwrap();
        let c = a.broadcast_with(&b, |x, y| x + y).unwrap();
        assert_eq!(c.shape(), &[1, 2, 2, 2]);
        assert_eq!(c.data()[0..4], [1.0; 4]);
        assert_eq!(c.data()[4..8], [2.0; 4]);
    }

    #[test]
    fn broadcast_incompatible_fails() {
        let a = Tensor::zeros(vec![2, 3]);
        let b = Tensor::zeros(vec![2, 4]);
        assert!(a.broadcast_with(&b, |x, _| x).is_err());
    }

    #[test]
    fn broadcast_assign_matches_broadcast_with() {
        let a = Tensor::from_fn(vec![1, 2, 2, 2], |i| i as f32);
        let b = Tensor::new(vec![2, 1, 1], vec![1.0, 2.0]).unwrap();
        let want = a.broadcast_with(&b, |x, y| x + y).unwrap();
        let mut got = a.clone();
        got.broadcast_assign(&b, |x, y| x + y).unwrap();
        assert_eq!(got, want);
        // Scalar rhs fast path.
        let s = Tensor::scalar(3.0);
        let want = a.broadcast_with(&s, |x, y| x * y).unwrap();
        let mut got = a.clone();
        got.broadcast_assign(&s, |x, y| x * y).unwrap();
        assert_eq!(got, want);
        // Result shape growing beyond lhs must be rejected.
        let wide = Tensor::zeros(vec![3, 1]);
        assert!(Tensor::zeros(vec![1, 4]).broadcast_assign(&wide, |x, _| x).is_err());
    }

    #[test]
    fn transpose_into_validates_out_shape() {
        let t = Tensor::from_fn(vec![2, 3], |i| i as f32);
        let mut bad = Tensor::zeros(vec![2, 3]);
        assert!(t.transpose_into(&[1, 0], &mut bad).is_err());
        let mut good = Tensor::zeros(vec![3, 2]);
        t.transpose_into(&[1, 0], &mut good).unwrap();
        assert_eq!(good, t.transpose(&[1, 0]).unwrap());
    }

    #[test]
    fn reshape_in_place_is_metadata_only() {
        let mut t = Tensor::from_fn(vec![2, 3], |i| i as f32);
        let ptr = t.data().as_ptr();
        t.reshape_in_place(vec![3, 2]).unwrap();
        assert_eq!(t.shape(), &[3, 2]);
        assert_eq!(t.data().as_ptr(), ptr);
        assert!(t.reshape_in_place(vec![7]).is_err());
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let r = t.clone().reshape(vec![3, 2]).unwrap();
        assert_eq!(r.data(), t.data());
        assert!(t.reshape(vec![4, 2]).is_err());
    }

    // -------------------------------------------------- typed payloads

    #[test]
    fn i32_tensor_round_trip_and_dtype() {
        let t = Tensor::new_i32(vec![2, 2], vec![1, -2, 3, -4]).unwrap();
        assert_eq!(t.dtype(), DType::I32);
        assert!(t.is_i32());
        assert_eq!(t.data_i32(), &[1, -2, 3, -4]);
        assert_eq!(t.numel(), 4);
        let z = Tensor::zeros_i32(vec![3]);
        assert_eq!(z.data_i32(), &[0, 0, 0]);
        assert_eq!(t.into_data_i32(), vec![1, -2, 3, -4]);
    }

    #[test]
    fn i32_transpose_matches_f32_transpose() {
        let f = Tensor::from_fn(vec![2, 3, 4], |i| i as f32);
        let i = Tensor::from_fn_i32(vec![2, 3, 4], |i| i as i32);
        let ft = f.transpose(&[2, 0, 1]).unwrap();
        let it = i.transpose(&[2, 0, 1]).unwrap();
        assert_eq!(it.shape(), ft.shape());
        for (a, b) in it.data_i32().iter().zip(ft.data()) {
            assert_eq!(*a as f32, *b);
        }
    }

    #[test]
    fn transpose_into_rejects_dtype_mismatch() {
        let i = Tensor::from_fn_i32(vec![2, 3], |i| i as i32);
        let mut f_out = Tensor::zeros(vec![3, 2]);
        assert!(i.transpose_into(&[1, 0], &mut f_out).is_err());
        let mut i_out = Tensor::zeros_i32(vec![3, 2]);
        i.transpose_into(&[1, 0], &mut i_out).unwrap();
        assert_eq!(i_out.data_i32(), &[0, 3, 1, 4, 2, 5]);
    }

    #[test]
    fn i32_reshape_is_metadata_only() {
        let mut t = Tensor::from_fn_i32(vec![2, 3], |i| i as i32);
        let ptr = t.data_i32().as_ptr();
        t.reshape_in_place(vec![6]).unwrap();
        assert_eq!(t.data_i32().as_ptr(), ptr);
    }

    #[test]
    #[should_panic(expected = "f32 access on an integer code tensor")]
    fn f32_access_on_i32_tensor_panics() {
        let t = Tensor::zeros_i32(vec![2]);
        let _ = t.data();
    }

    #[test]
    #[should_panic(expected = "i32 access on a F32 tensor")]
    fn i32_access_on_f32_tensor_panics() {
        let t = Tensor::zeros(vec![2]);
        let _ = t.data_i32();
    }

    // ------------------------------------------------ packed containers

    #[test]
    fn packed_containers_round_trip() {
        let t8 = Tensor::new_i8(vec![2, 2], vec![-128, -1, 0, 127]).unwrap();
        assert_eq!(t8.dtype(), DType::I8);
        assert!(t8.is_int() && !t8.is_i32());
        assert_eq!(t8.codes::<i8>().unwrap(), &[-128, -1, 0, 127]);
        assert!(t8.codes::<i32>().is_none());
        assert_eq!(t8.codes_i32(), vec![-128, -1, 0, 127]);

        let t16 = Tensor::new_i16(vec![3], vec![-32768, 255, 32767]).unwrap();
        assert_eq!(t16.dtype(), DType::I16);
        assert_eq!(t16.codes_i32(), vec![-32768, 255, 32767]);
        assert!(Tensor::new_i8(vec![2], vec![1]).is_err());
    }

    #[test]
    fn zeros_typed_matches_dtype_and_size() {
        for (dt, bytes) in [
            (DType::F32, 4),
            (DType::I8, 1),
            (DType::I16, 2),
            (DType::I32, 4),
        ] {
            let t = Tensor::zeros_typed(vec![2, 3], dt);
            assert_eq!(t.dtype(), dt);
            assert_eq!(t.numel(), 6);
            assert_eq!(dt.size_bytes(), bytes);
        }
        assert!(DType::I8.is_int() && !DType::F32.is_int());
    }

    #[test]
    fn packed_transpose_preserves_container() {
        let t = Tensor::new_i8(vec![2, 3], vec![0, 1, 2, 3, 4, 5]).unwrap();
        let tt = t.transpose(&[1, 0]).unwrap();
        assert_eq!(tt.dtype(), DType::I8);
        assert_eq!(tt.codes::<i8>().unwrap(), &[0, 3, 1, 4, 2, 5]);
        // Mixed-container transpose_into is a dtype error, not a cast.
        let mut wide = Tensor::zeros_i32(vec![3, 2]);
        assert!(t.transpose_into(&[1, 0], &mut wide).is_err());
    }

    #[test]
    fn int_code_widen_and_narrow() {
        assert_eq!(<i8 as IntCode>::from_wide(127), Some(127i8));
        assert_eq!(<i8 as IntCode>::from_wide(128), None);
        assert_eq!(<i16 as IntCode>::from_wide(-32768), Some(-32768i16));
        assert_eq!(<i16 as IntCode>::from_wide(32768), None);
        assert_eq!(<i32 as IntCode>::from_wide(1 << 33), None);
        assert_eq!((-5i8).widen(), -5i32);
        assert_eq!(<i8 as IntCode>::BITS, 8);
        assert_eq!(<i16 as IntCode>::DTYPE, DType::I16);
    }
}
