//! Small dense f32 tensor with shape/stride utilities.
//!
//! Deliberately minimal: the graph executor and hardware models need
//! row-major storage, reshape/transpose, NCHW<->NHWC conversion and
//! elementwise access — not a full ndarray library.

use anyhow::{bail, Result};

/// Row-major dense f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            bail!("shape {shape:?} wants {numel} elems, got {}", data.len());
        }
        Ok(Self { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let numel = shape.iter().product();
        Self {
            shape,
            data: vec![0.0; numel],
        }
    }

    pub fn full(shape: Vec<usize>, value: f32) -> Self {
        let numel = shape.iter().product();
        Self {
            shape,
            data: vec![value; numel],
        }
    }

    pub fn scalar(value: f32) -> Self {
        Self {
            shape: vec![],
            data: vec![value],
        }
    }

    pub fn from_fn(shape: Vec<usize>, mut f: impl FnMut(usize) -> f32) -> Self {
        let numel: usize = shape.iter().product();
        Self {
            shape,
            data: (0..numel).map(|i| f(i)).collect(),
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Row-major strides for the current shape.
    pub fn strides(&self) -> Vec<usize> {
        strides_of(&self.shape)
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        let numel: usize = shape.iter().product();
        if numel != self.data.len() {
            bail!(
                "reshape {:?} -> {shape:?} changes element count",
                self.shape
            );
        }
        self.shape = shape;
        Ok(self)
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        let strides = self.strides();
        for (i, &ix) in idx.iter().enumerate() {
            debug_assert!(ix < self.shape[i]);
            off += ix * strides[i];
        }
        self.data[off]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let mut off = 0;
        let strides = self.strides();
        for (i, &ix) in idx.iter().enumerate() {
            off += ix * strides[i];
        }
        self.data[off] = v;
    }

    /// Generalized transpose: output axis i takes input axis `perm[i]`.
    pub fn transpose(&self, perm: &[usize]) -> Result<Self> {
        if perm.len() != self.shape.len() {
            bail!("perm {perm:?} rank mismatch with {:?}", self.shape);
        }
        let mut seen = vec![false; perm.len()];
        for &p in perm {
            if p >= perm.len() || seen[p] {
                bail!("bad permutation {perm:?}");
            }
            seen[p] = true;
        }
        let out_shape: Vec<usize> = perm.iter().map(|&p| self.shape[p]).collect();
        let in_strides = self.strides();
        let out_strides = strides_of(&out_shape);
        let mut out = vec![0.0f32; self.data.len()];
        // Iterate output linearly; map to input offset.
        let rank = perm.len();
        let mut idx = vec![0usize; rank];
        for (o, slot) in out.iter_mut().enumerate() {
            // Decompose o into output index.
            let mut rem = o;
            for d in 0..rank {
                idx[d] = rem / out_strides[d];
                rem %= out_strides[d];
            }
            let mut in_off = 0;
            for d in 0..rank {
                in_off += idx[d] * in_strides[perm[d]];
            }
            *slot = self.data[in_off];
        }
        Tensor::new(out_shape, out)
    }

    /// NCHW -> NHWC.
    pub fn nchw_to_nhwc(&self) -> Result<Self> {
        self.transpose(&[0, 2, 3, 1])
    }

    /// NHWC -> NCHW.
    pub fn nhwc_to_nchw(&self) -> Result<Self> {
        self.transpose(&[0, 3, 1, 2])
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Self {
        Self {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise binary op with numpy-style broadcasting.
    pub fn broadcast_with(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Self> {
        let out_shape = broadcast_shape(&self.shape, &other.shape)?;
        let rank = out_shape.len();
        let a_shape = pad_shape(&self.shape, rank);
        let b_shape = pad_shape(&other.shape, rank);
        let a_str = broadcast_strides(&a_shape, &strides_of(&a_shape));
        let b_str = broadcast_strides(&b_shape, &strides_of(&b_shape));
        let out_strides = strides_of(&out_shape);
        let numel: usize = out_shape.iter().product();
        let mut out = vec![0.0f32; numel];
        let mut idx = vec![0usize; rank];
        for (o, slot) in out.iter_mut().enumerate() {
            let mut rem = o;
            for d in 0..rank {
                idx[d] = rem / out_strides[d];
                rem %= out_strides[d];
            }
            let mut ao = 0;
            let mut bo = 0;
            for d in 0..rank {
                ao += if a_shape[d] == 1 { 0 } else { idx[d] } * a_str[d];
                bo += if b_shape[d] == 1 { 0 } else { idx[d] } * b_str[d];
            }
            *slot = f(self.data[ao], other.data[bo]);
        }
        Tensor::new(out_shape, out)
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn allclose(&self, other: &Tensor, atol: f32) -> bool {
        self.shape == other.shape && self.max_abs_diff(other) <= atol
    }
}

pub fn strides_of(shape: &[usize]) -> Vec<usize> {
    let mut strides = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        strides[i] = strides[i + 1] * shape[i + 1];
    }
    strides
}

fn pad_shape(shape: &[usize], rank: usize) -> Vec<usize> {
    let mut s = vec![1usize; rank - shape.len()];
    s.extend_from_slice(shape);
    s
}

fn broadcast_strides(shape: &[usize], strides: &[usize]) -> Vec<usize> {
    shape
        .iter()
        .zip(strides)
        .map(|(&s, &st)| if s == 1 { 0 } else { st })
        .collect()
}

pub fn broadcast_shape(a: &[usize], b: &[usize]) -> Result<Vec<usize>> {
    let rank = a.len().max(b.len());
    let a = pad_shape(a, rank);
    let b = pad_shape(b, rank);
    let mut out = Vec::with_capacity(rank);
    for (&x, &y) in a.iter().zip(&b) {
        if x == y || x == 1 || y == 1 {
            out.push(x.max(y));
        } else {
            bail!("cannot broadcast {a:?} with {b:?}");
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_element_count() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn strides_row_major() {
        let t = Tensor::zeros(vec![2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn at_and_set() {
        let mut t = Tensor::zeros(vec![2, 3]);
        t.set(&[1, 2], 5.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
        assert_eq!(t.at(&[0, 0]), 0.0);
    }

    #[test]
    fn transpose_2d() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let tt = t.transpose(&[1, 0]).unwrap();
        assert_eq!(tt.shape(), &[3, 2]);
        assert_eq!(tt.data(), &[1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn transpose_round_trip_nchw_nhwc() {
        let t = Tensor::from_fn(vec![1, 3, 4, 4], |i| i as f32);
        let back = t.nchw_to_nhwc().unwrap().nhwc_to_nchw().unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn transpose_rejects_bad_perm() {
        let t = Tensor::zeros(vec![2, 3]);
        assert!(t.transpose(&[0, 0]).is_err());
        assert!(t.transpose(&[0]).is_err());
    }

    #[test]
    fn broadcast_scalar() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::scalar(10.0);
        let c = a.broadcast_with(&b, |x, y| x * y).unwrap();
        assert_eq!(c.data(), &[10., 20., 30., 40.]);
    }

    #[test]
    fn broadcast_per_channel_bias_nchw() {
        // [1,2,2,2] + [2,1,1] channel bias (as exported biases broadcast).
        let a = Tensor::from_fn(vec![1, 2, 2, 2], |_| 0.0);
        let b = Tensor::new(vec![2, 1, 1], vec![1.0, 2.0]).unwrap();
        let c = a.broadcast_with(&b, |x, y| x + y).unwrap();
        assert_eq!(c.shape(), &[1, 2, 2, 2]);
        assert_eq!(c.data()[0..4], [1.0; 4]);
        assert_eq!(c.data()[4..8], [2.0; 4]);
    }

    #[test]
    fn broadcast_incompatible_fails() {
        let a = Tensor::zeros(vec![2, 3]);
        let b = Tensor::zeros(vec![2, 4]);
        assert!(a.broadcast_with(&b, |x, _| x).is_err());
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let r = t.clone().reshape(vec![3, 2]).unwrap();
        assert_eq!(r.data(), t.data());
        assert!(t.reshape(vec![4, 2]).is_err());
    }
}
