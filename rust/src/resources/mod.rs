//! FPGA resource accounting: LUT / FF / BRAM36 / DSP plus the Zynq-7020
//! device budget (PYNQ-Z1, the paper's board).
//!
//! The per-layer estimation formulas live with the layer models in
//! [`crate::hw`]; this module provides the common currency and the
//! device-utilization report used by Table III.

use std::fmt;
use std::ops::{Add, AddAssign};

/// A resource vector.  Fractional BRAM (18Kb halves) is kept as f64, like
/// Vivado reports (the paper's Table III lists 131.5 BRAM36).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Resources {
    pub lut: f64,
    pub ff: f64,
    pub bram36: f64,
    pub dsp: f64,
}

impl Resources {
    pub const ZERO: Resources = Resources {
        lut: 0.0,
        ff: 0.0,
        bram36: 0.0,
        dsp: 0.0,
    };

    pub fn new(lut: f64, ff: f64, bram36: f64, dsp: f64) -> Self {
        Self {
            lut,
            ff,
            bram36,
            dsp,
        }
    }

    pub fn scaled(&self, k: f64) -> Self {
        Self::new(self.lut * k, self.ff * k, self.bram36 * k, self.dsp * k)
    }

    /// True if every component fits within `budget`.
    pub fn fits(&self, budget: &Resources) -> bool {
        self.lut <= budget.lut
            && self.ff <= budget.ff
            && self.bram36 <= budget.bram36
            && self.dsp <= budget.dsp
    }

    /// Worst-component utilization fraction against a device.
    pub fn max_utilization(&self, device: &Device) -> f64 {
        let b = &device.budget;
        [
            self.lut / b.lut,
            self.ff / b.ff,
            self.bram36 / b.bram36,
            self.dsp / b.dsp,
        ]
        .into_iter()
        .fold(0.0, f64::max)
    }
}

impl Add for Resources {
    type Output = Resources;
    fn add(self, rhs: Resources) -> Resources {
        Resources::new(
            self.lut + rhs.lut,
            self.ff + rhs.ff,
            self.bram36 + rhs.bram36,
            self.dsp + rhs.dsp,
        )
    }
}

impl AddAssign for Resources {
    fn add_assign(&mut self, rhs: Resources) {
        *self = *self + rhs;
    }
}

impl fmt::Display for Resources {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "LUT {:>7.0}  FF {:>7.0}  BRAM36 {:>6.1}  DSP {:>4.0}",
            self.lut, self.ff, self.bram36, self.dsp
        )
    }
}

/// An FPGA device model.
#[derive(Debug, Clone)]
pub struct Device {
    pub name: &'static str,
    pub budget: Resources,
    /// Fabric clock in MHz (the paper runs the FINN build at 125 MHz).
    pub clock_mhz: f64,
    /// Sustainable DMA bandwidth between host memory and the fabric in
    /// bytes/s — one 64-bit AXI HP port at the fabric clock for the
    /// Zynq-7000 parts.  Frames stream in and out over this link, so
    /// `bandwidth / bytes_per_frame` is a throughput ceiling independent
    /// of the compute initiation interval.
    pub dma_bandwidth_bytes_per_s: f64,
}

impl Device {
    /// PYNQ-Z1: Zynq XC7Z020-1CLG400C.
    pub fn pynq_z1() -> Device {
        Device {
            name: "PYNQ-Z1 (Zynq-7020)",
            budget: Resources::new(53_200.0, 106_400.0, 140.0, 220.0),
            clock_mhz: 125.0,
            // 64-bit HP port at 125 MHz: 8 B x 125e6 = 1.0 GB/s.
            dma_bandwidth_bytes_per_s: 1.0e9,
        }
    }

    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.clock_mhz * 1e3)
    }

    pub fn fps(&self, cycles_per_frame: u64) -> f64 {
        self.clock_mhz * 1e6 / cycles_per_frame as f64
    }

    /// Achievable-fps ceiling from streaming `bytes_per_frame` over the
    /// DMA link — the bandwidth axis that sits alongside the dataflow
    /// sim's initiation-interval bound.  Narrow packed containers lower
    /// bytes-per-frame and raise this ceiling; a config whose II-fps
    /// exceeds it is DMA-bound, not compute-bound.
    pub fn bandwidth_fps_ceiling(&self, bytes_per_frame: u64) -> f64 {
        if bytes_per_frame == 0 {
            f64::INFINITY
        } else {
            self.dma_bandwidth_bytes_per_s / bytes_per_frame as f64
        }
    }

    /// Total on-chip BRAM capacity in bits: every BRAM36 block holds
    /// 36 Kib (140 blocks on the Zynq-7020 ≈ 4.9 Mib of datasheet block
    /// RAM).  Weight memories beyond this cannot be fully on-chip.
    pub fn bram_capacity_bits(&self) -> u64 {
        (self.budget.bram36 * 36.0 * 1024.0) as u64
    }

    /// The memory-aware throughput ceiling: a design whose weights fit
    /// on-chip streams only activations over the DMA link (the plain
    /// [`Device::bandwidth_fps_ceiling`]); one that overflows BRAM must
    /// re-stream the spilled weight bytes every frame, which lowers the
    /// ceiling and marks the config BRAM-bound rather than DMA-bound.
    pub fn memory_fps_ceiling(&self, bytes_per_frame: u64, weight_bits: u64) -> MemoryCeiling {
        let spilled_bits = weight_bits.saturating_sub(self.bram_capacity_bits());
        let spilled_weight_bytes = spilled_bits.div_ceil(8);
        MemoryCeiling {
            fps: self.bandwidth_fps_ceiling(bytes_per_frame + spilled_weight_bytes),
            spilled_weight_bytes,
            bram_bound: spilled_bits > 0,
        }
    }
}

/// Verdict of [`Device::memory_fps_ceiling`]: the achievable-fps ceiling
/// once on-chip weight capacity is accounted for, and which resource set
/// it — DMA bandwidth alone, or BRAM overflow forcing weight re-streaming.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryCeiling {
    /// fps ceiling over the DMA link (activations + any spilled weights).
    pub fps: f64,
    /// Weight bytes that do not fit on-chip and re-stream every frame.
    pub spilled_weight_bytes: u64,
    /// True when the weight memory overflows the device's BRAM capacity.
    pub bram_bound: bool,
}

/// BRAM36 blocks needed for a memory of `depth` words x `width` bits,
/// taking the min over the block's hard aspect-ratio configs
/// (512x72, 1Kx36, 2Kx18, 4Kx9) — the standard Xilinx packing model.
pub fn bram36_for(depth: u64, width: u64) -> f64 {
    if depth == 0 || width == 0 {
        return 0.0;
    }
    let configs: [(u64, u64); 4] = [(512, 72), (1024, 36), (2048, 18), (4096, 9)];
    let mut best = f64::MAX;
    for (d, w) in configs {
        let blocks = (depth.div_ceil(d)) * (width.div_ceil(w));
        best = best.min(blocks as f64);
    }
    // An 18Kb half-block suffices for small memories (Vivado packs pairs).
    if depth * width <= 18 * 1024 && width <= 36 && depth <= 1024 {
        best = best.min(0.5);
    }
    best
}

/// Utilization table row (Table III formatting).
pub fn utilization_line(name: &str, r: &Resources, device: &Device) -> String {
    let b = &device.budget;
    format!(
        "{name:<28} LUT {:>6.0} ({:>4.1}%)  FF {:>6.0} ({:>4.1}%)  BRAM36 {:>6.1} ({:>4.1}%)  DSP {:>4.0} ({:>4.1}%)",
        r.lut,
        100.0 * r.lut / b.lut,
        r.ff,
        100.0 * r.ff / b.ff,
        r.bram36,
        100.0 * r.bram36 / b.bram36,
        r.dsp,
        100.0 * r.dsp / b.dsp,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_fits() {
        let a = Resources::new(100.0, 200.0, 1.0, 2.0);
        let b = Resources::new(50.0, 100.0, 0.5, 1.0);
        let s = a + b;
        assert_eq!(s.lut, 150.0);
        assert!(s.fits(&Device::pynq_z1().budget));
        assert!(!Resources::new(1e6, 0.0, 0.0, 0.0).fits(&Device::pynq_z1().budget));
    }

    #[test]
    fn pynq_budget_matches_datasheet() {
        let d = Device::pynq_z1();
        assert_eq!(d.budget.lut, 53_200.0);
        assert_eq!(d.budget.bram36, 140.0);
        assert_eq!(d.budget.dsp, 220.0);
        assert_eq!(d.clock_mhz, 125.0);
    }

    #[test]
    fn cycle_time_conversions() {
        let d = Device::pynq_z1();
        // 16.3 ms at 125 MHz = 2.0375 M cycles (the paper's latency).
        let cycles = (16.3e-3 * 125e6) as u64;
        assert!((d.cycles_to_ms(cycles) - 16.3).abs() < 1e-3);
        assert!((d.fps(cycles) - 61.35).abs() < 0.1);
    }

    #[test]
    fn bram_packing() {
        assert_eq!(bram36_for(0, 8), 0.0);
        assert_eq!(bram36_for(512, 36), 0.5); // half block
        assert_eq!(bram36_for(1024, 36), 1.0);
        assert_eq!(bram36_for(512, 72), 1.0);
        assert_eq!(bram36_for(4096, 9), 1.0);
        assert_eq!(bram36_for(2048, 36), 2.0);
        // Wide shallow memory wastes depth: 16 x 288 bits -> 4 blocks.
        assert_eq!(bram36_for(16, 288), 4.0);
    }

    #[test]
    fn bandwidth_ceiling_scales_with_bytes() {
        let d = Device::pynq_z1();
        assert_eq!(d.dma_bandwidth_bytes_per_s, 1.0e9);
        // 1 MB/frame over 1 GB/s -> 1000 fps; half the bytes doubles it.
        assert!((d.bandwidth_fps_ceiling(1_000_000) - 1000.0).abs() < 1e-9);
        assert!((d.bandwidth_fps_ceiling(500_000) - 2000.0).abs() < 1e-9);
        assert!(d.bandwidth_fps_ceiling(0).is_infinite());
    }

    #[test]
    fn bram_capacity_matches_block_count() {
        let d = Device::pynq_z1();
        // 140 BRAM36 x 36 Kib = 5_160_960 bits (~4.9 Mib).
        assert_eq!(d.bram_capacity_bits(), 140 * 36 * 1024);
    }

    #[test]
    fn memory_ceiling_distinguishes_dma_from_bram_bound() {
        let d = Device::pynq_z1();
        // Weights fit on-chip: the ceiling is the plain DMA bound.
        let fit = d.memory_fps_ceiling(1_000_000, 1024);
        assert!(!fit.bram_bound);
        assert_eq!(fit.spilled_weight_bytes, 0);
        assert!((fit.fps - d.bandwidth_fps_ceiling(1_000_000)).abs() < 1e-9);
        // Weights overflow BRAM by exactly 8 MiB of spill: those bytes
        // re-stream every frame alongside the activations, so the
        // ceiling drops well below the DMA-only bound.
        let spill_bits = d.bram_capacity_bits() + 8 * 1024 * 1024 * 8;
        let spilled = d.memory_fps_ceiling(1_000_000, spill_bits);
        assert!(spilled.bram_bound);
        assert_eq!(spilled.spilled_weight_bytes, 8 * 1024 * 1024);
        assert!(spilled.fps < fit.fps);
    }

    #[test]
    fn max_utilization_picks_bottleneck() {
        let d = Device::pynq_z1();
        let r = Resources::new(5_320.0, 0.0, 70.0, 0.0); // 10% LUT, 50% BRAM
        assert!((r.max_utilization(&d) - 0.5).abs() < 1e-9);
    }
}
