//! The design environment driver — the paper's Fig. 3 "build" flow as one
//! call: requantize -> streamline/lower/§III-C/§III-D -> HW mapping ->
//! folding search against the device budget -> FIFO sizing -> bounded
//! dataflow simulation -> Table-III-style report.
//!
//! Also home of [`synth_backbone_graph`] (the ResNet-9 import synthesized
//! at arbitrary widths — mirrors python/compile/export_graph.py so the
//! whole pipeline runs without `make artifacts`) and [`requantize_graph`]
//! (rust-side PTQ: the bit-width is a *design parameter* here, the
//! paper's core claim vs Tensil's fixed 16/32-bit).

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::dataflow::{size_fifos, DataflowSim};
use crate::fixedpoint::{headline_config, FxpFormat, QuantConfig};
use crate::graph::{AttrVal, Attrs, Graph, Node};
use crate::hw::{initiation_interval, model_graph, total_resources, total_weight_bits, HwNodeModel};
use crate::resources::{Device, Resources};
use crate::rng::Rng;
use crate::tensor::Tensor;
use crate::transforms::{convert_to_hw, run_default_pipeline, StageReport};

/// One design point: bit-width config + throughput/utilization targets.
#[derive(Debug, Clone)]
pub struct DesignConfig {
    pub quant: QuantConfig,
    /// Fold until this frame rate is met (None = fold until the
    /// utilization cap stops paying).
    pub target_fps: Option<f64>,
    /// Per-resource utilization ceiling for the folding search (LUT / FF
    /// / DSP / BRAM; the BRAM cap is floored at the entry footprint —
    /// weight memory at minimal folding is set by the model, not a
    /// foldable quantity — so folding may never grow it past the cap).
    pub max_utilization: f64,
    /// Numerically verify every transform stage against a probe input.
    pub verify: bool,
}

impl Default for DesignConfig {
    fn default() -> Self {
        Self {
            quant: headline_config(),
            target_fps: Some(60.0),
            max_utilization: 0.85,
            verify: false,
        }
    }
}

/// Everything `build` learned about one design point.
#[derive(Debug, Clone)]
pub struct BuildReport {
    pub stages: Vec<StageReport>,
    pub census_before: HashMap<String, usize>,
    pub census_after: HashMap<String, usize>,
    pub models: Vec<HwNodeModel>,
    pub config: QuantConfig,
    pub total_resources: Resources,
    /// BRAM-resident weight bits (Table I's "weights stored in BRAM").
    pub weight_bits: u64,
    pub fifo_depths: HashMap<String, u64>,
    /// Cycles until frame 0 exits (single-frame latency).
    pub latency_cycles: u64,
    /// Steady-state cycles per frame (the initiation interval actually
    /// achieved with sized FIFOs).
    pub steady_cycles: u64,
    pub latency_ms: f64,
    pub fps: f64,
    /// True when the weight memory overflows the device's on-chip BRAM
    /// capacity ([`Device::bram_capacity_bits`]) — the config is memory-
    /// bound before it is DMA-bound.
    pub bram_bound: bool,
}

impl BuildReport {
    pub fn summary(&self) -> String {
        let residency = if self.bram_bound {
            "spills off-chip (BRAM-bound)"
        } else {
            "on-chip"
        };
        format!(
            "config {}  |  {} HW layers  |  {}  |  weights {:.1} KiB {residency}  |  latency {:.2} ms  {:.1} fps (II {} cycles)",
            self.config.describe(),
            self.models.len(),
            self.total_resources,
            self.weight_bits as f64 / 8192.0,
            self.latency_ms,
            self.fps,
            self.steady_cycles
        )
    }
}

/// Run the whole design environment on an imported (or synthesized) NCHW
/// graph.  The graph is rewritten in place to its fully-lowered HW form.
pub fn build(graph: &mut Graph, cfg: &DesignConfig, device: &Device) -> Result<BuildReport> {
    let census_before = graph.op_census();
    requantize_graph(graph, &cfg.quant)?;

    // Probe input for per-stage numerical verification.  Weights and
    // activations sit on the fixed-point grid after requantization, so
    // every rewrite is exact up to threshold-boundary float noise; 2e-3
    // is the documented stage tolerance.
    let probe = if cfg.verify {
        let mut rng = Rng::new(0xBEEF);
        let mut feeds = HashMap::new();
        for input in &graph.inputs {
            let shape = graph.shape_of(input)?.to_vec();
            feeds.insert(input.clone(), Tensor::from_fn(shape, |_| rng.next_f32()));
        }
        Some(feeds)
    } else {
        None
    };
    let stages = run_default_pipeline(graph, probe.as_ref(), 2e-3)?;
    if !convert_to_hw::is_fully_hw(graph) {
        bail!(
            "build left non-HW ops in the graph: {:?}",
            graph.op_census()
        );
    }
    let mut report = implement_lowered(graph, cfg, device)?;
    report.stages = stages;
    report.census_before = census_before;
    Ok(report)
}

/// The bit-true front half of [`build`]: PTQ the imported NCHW graph,
/// lower it through the full Fig.-3 pipeline, and annotate every HW
/// node's fixed-point formats *and* storage containers (`bt_container`)
/// so [`crate::plan::ExecutionPlan::compile_with`] can select packed,
/// container-monomorphized integer kernels
/// ([`crate::plan::Datapath::BitTrue`]).  After this the graph executes
/// bit-exactly what the FPGA datapath computes, moving the bytes its
/// narrow containers imply — `dse` and the CLI's `--datapath bit-true`
/// route through here.
pub fn lower_bit_true(graph: &mut Graph, quant: &QuantConfig) -> Result<()> {
    requantize_graph(graph, quant)?;
    run_default_pipeline(graph, None, 0.0)?;
    if !convert_to_hw::is_fully_hw(graph) {
        bail!(
            "bit-true lowering left non-HW ops in the graph: {:?}",
            graph.op_census()
        );
    }
    crate::transforms::annotate_bit_true_formats(graph)
}

/// The cap-dependent tail of [`build`]: folding search + FIFO sizing +
/// bounded dataflow sim on an **already-lowered** HW graph.  Callable
/// once per utilization cap on a clone of one lowered graph (the dse
/// sweep lowers each config once and implements it per cap); `stages` and
/// `census_before` in the returned report are empty here — [`build`]
/// fills them.
pub fn implement_lowered(
    graph: &mut Graph,
    cfg: &DesignConfig,
    device: &Device,
) -> Result<BuildReport> {
    let census_after = graph.op_census();

    let models = folding_search(graph, cfg, device)?;
    let frame_in: u64 = graph
        .shape_of(&graph.inputs[0])?
        .iter()
        .product::<usize>() as u64;

    // FIFO sizing: unbounded run, capacities = observed peaks; then a
    // bounded 3-frame run proves the sized design streams without
    // deadlock and measures the achieved latency/II.
    let fifo_depths = size_fifos(&models, &graph.inputs, &graph.outputs, frame_in)?;
    let mut sim = DataflowSim::new(&models, &graph.inputs, &graph.outputs, 2)?;
    for (name, depth) in &fifo_depths {
        sim.set_capacity(name, *depth);
    }
    let sim_res = sim.run(3, frame_in)?;

    let total = total_resources(&models);
    let weight_bits = total_weight_bits(&models);
    let steady = sim_res.steady_interval.max(1);
    Ok(BuildReport {
        stages: Vec::new(),
        census_before: HashMap::new(),
        census_after,
        config: cfg.quant,
        total_resources: total,
        weight_bits,
        fifo_depths,
        latency_cycles: sim_res.first_frame_latency,
        steady_cycles: steady,
        latency_ms: device.cycles_to_ms(sim_res.first_frame_latency),
        fps: device.fps(steady),
        bram_bound: weight_bits > device.bram_capacity_bits(),
        models,
    })
}

// ---------------------------------------------------------------------------
// Rust-side PTQ
// ---------------------------------------------------------------------------

/// Quantize the graph's weight/bias initializers onto `quant`'s grids:
/// conv/matmul weights onto the weight format, biases onto the (wide)
/// accumulator format — mirroring python `model.ptq`.  Thresholds and
/// scale constants are already exact grid values and are left alone.
/// Idempotent (quantization is a projection).
pub fn requantize_graph(graph: &mut Graph, quant: &QuantConfig) -> Result<()> {
    let acc = quant.acc_format();
    let mut jobs: Vec<(String, FxpFormat)> = Vec::new();
    for node in &graph.nodes {
        match node.op.as_str() {
            "Conv" => {
                jobs.push((node.inputs[1].clone(), quant.weight));
                if let Some(b) = node.inputs.get(2) {
                    jobs.push((b.clone(), acc));
                }
            }
            "MatMul" => {
                jobs.push((node.inputs[1].clone(), quant.weight));
            }
            "MVAU" => {
                jobs.push((node.inputs[1].clone(), quant.weight));
                if let Some(b) = node.inputs.get(2) {
                    jobs.push((b.clone(), acc));
                }
            }
            // Bias Adds from conv lowering carry one initializer input.
            "Add" => {
                for t in &node.inputs {
                    if graph.is_initializer(t) {
                        jobs.push((t.clone(), acc));
                    }
                }
            }
            _ => {}
        }
    }
    for (name, fmt) in jobs {
        if let Some(t) = graph.initializers.get_mut(&name) {
            fmt.quantize_slice(t.data_mut());
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Synthetic backbone import
// ---------------------------------------------------------------------------

/// FINN-style [C, K] threshold matrix for an unsigned quantizer:
/// `t_k = (k + 0.5) * 2^-f`, replicated per channel — the same matrix
/// export_graph.py emits.
fn thresholds(channels: usize, bits: u8, frac_bits: u8) -> Tensor {
    let k = ((1u32 << bits) - 1) as usize;
    let scale = (1u64 << frac_bits) as f32;
    let row: Vec<f32> = (0..k).map(|i| (i as f32 + 0.5) / scale).collect();
    let mut data = Vec::with_capacity(channels * k);
    for _ in 0..channels {
        data.extend_from_slice(&row);
    }
    Tensor::new(vec![channels, k], data).expect("threshold matrix")
}

/// Synthesize the pre-streamlining ResNet-9 NCHW import at arbitrary
/// widths — structurally identical to what export_graph.py writes for the
/// trained model (8 Convs, 9 MultiThresholds + scale Muls, 2 residual
/// Adds, 3 MaxPools, final spatial ReduceMean), with deterministic
/// He-initialized weights.  `act_bits`/`act_frac` set the layer
/// activation quantizers; the input quantizer is fixed at u8.8 (the
/// camera interface, python model.INPUT_FMT).
pub fn synth_backbone_graph(
    widths: [usize; 4],
    img: usize,
    act_bits: u8,
    act_frac: u8,
) -> Graph {
    let [c0, c1, c2, c3] = widths;
    // (name, cin, cout, pool, res_begin, res_add) — python model.arch().
    let specs: [(&str, usize, usize, bool, bool, bool); 8] = [
        ("stem", 3, c0, false, false, false),
        ("conv1", c0, c1, true, false, false),
        ("res1a", c1, c1, false, true, false),
        ("res1b", c1, c1, false, false, true),
        ("conv2", c1, c2, true, false, false),
        ("conv3", c2, c3, true, false, false),
        ("res2a", c3, c3, false, true, false),
        ("res2b", c3, c3, false, false, true),
    ];
    let mut g = Graph::new(&format!("synth_resnet9_{c0}_{c1}_{c2}_{c3}_img{img}"));
    let mut rng = Rng::new(0xB3ADE);

    g.inputs = vec!["global_in".to_string()];
    g.shapes.insert("global_in".into(), vec![1, 3, img, img]);

    // Input quantizer (u8.8): MultiThreshold (codes) + Mul (scale back).
    g.shapes.insert("in_thresh".into(), vec![3, 255]);
    g.initializers.insert("in_thresh".into(), thresholds(3, 8, 8));
    g.shapes.insert("in_codes".into(), vec![1, 3, img, img]);
    g.nodes.push(
        Node::new(
            "MultiThreshold",
            "quant_in",
            vec!["global_in".into(), "in_thresh".into()],
            vec!["in_codes".into()],
        )
        .with_attrs(
            Attrs::new()
                .with("out_scale", AttrVal::Float(1.0))
                .with("out_bias", AttrVal::Float(0.0))
                .with("data_layout", AttrVal::Str("NCHW".into())),
        ),
    );
    g.shapes.insert("in_scale".into(), vec![]);
    g.initializers
        .insert("in_scale".into(), Tensor::scalar(1.0 / 256.0));
    g.shapes.insert("in_q".into(), vec![1, 3, img, img]);
    g.nodes.push(Node::new(
        "Mul",
        "quant_in_scale",
        vec!["in_codes".into(), "in_scale".into()],
        vec!["in_q".into()],
    ));

    let act_scale = (1u64 << act_frac) as f32;
    let n_thresh = ((1u32 << act_bits) - 1) as usize;
    let mut cur = "in_q".to_string();
    let mut h = img;
    let mut skip: Option<String> = None;
    for (name, cin, cout, pool, res_begin, res_add) in specs {
        if res_begin {
            skip = Some(cur.clone());
        }
        // Conv weights: OIHW, He-init; bias small.
        let fan_in = 9 * cin;
        let std = (2.0 / fan_in as f32).sqrt();
        let w = Tensor::from_fn(vec![cout, cin, 3, 3], |_| rng.normal() * std);
        let b = Tensor::from_fn(vec![cout], |_| rng.normal() * 0.05);
        g.shapes.insert(format!("{name}_w"), vec![cout, cin, 3, 3]);
        g.initializers.insert(format!("{name}_w"), w);
        g.shapes.insert(format!("{name}_b"), vec![cout]);
        g.initializers.insert(format!("{name}_b"), b);
        let conv_out = format!("{name}_conv");
        g.shapes.insert(conv_out.clone(), vec![1, cout, h, h]);
        g.nodes.push(
            Node::new(
                "Conv",
                name,
                vec![cur.clone(), format!("{name}_w"), format!("{name}_b")],
                vec![conv_out.clone()],
            )
            .with_attrs(
                Attrs::new()
                    .with("kernel", AttrVal::Ints(vec![3, 3]))
                    .with("stride", AttrVal::Ints(vec![1, 1]))
                    .with("pad", AttrVal::Ints(vec![1, 1]))
                    .with("group", AttrVal::Int(1)),
            ),
        );
        cur = conv_out;
        if res_add {
            let s = skip.clone().expect("res_add without res_begin");
            let add_out = format!("{name}_add");
            g.shapes.insert(add_out.clone(), vec![1, cout, h, h]);
            g.nodes.push(Node::new(
                "Add",
                &format!("{name}_res"),
                vec![cur.clone(), s],
                vec![add_out.clone()],
            ));
            cur = add_out;
        }
        // Activation quantizer (absorbs ReLU): MultiThreshold + Mul.
        g.shapes
            .insert(format!("{name}_thresh"), vec![cout, n_thresh]);
        g.initializers
            .insert(format!("{name}_thresh"), thresholds(cout, act_bits, act_frac));
        let codes = format!("{name}_codes");
        g.shapes.insert(codes.clone(), vec![1, cout, h, h]);
        g.nodes.push(
            Node::new(
                "MultiThreshold",
                &format!("{name}_quant"),
                vec![cur.clone(), format!("{name}_thresh")],
                vec![codes.clone()],
            )
            .with_attrs(
                Attrs::new()
                    .with("out_scale", AttrVal::Float(1.0))
                    .with("out_bias", AttrVal::Float(0.0))
                    .with("data_layout", AttrVal::Str("NCHW".into())),
            ),
        );
        g.shapes.insert(format!("{name}_actscale"), vec![]);
        g.initializers
            .insert(format!("{name}_actscale"), Tensor::scalar(1.0 / act_scale));
        let scaled = format!("{name}_q");
        g.shapes.insert(scaled.clone(), vec![1, cout, h, h]);
        g.nodes.push(Node::new(
            "Mul",
            &format!("{name}_quant_scale"),
            vec![codes, format!("{name}_actscale")],
            vec![scaled.clone()],
        ));
        cur = scaled;
        if pool {
            h /= 2;
            let pool_out = format!("{name}_pool");
            g.shapes.insert(pool_out.clone(), vec![1, cout, h, h]);
            g.nodes.push(
                Node::new(
                    "MaxPool",
                    &format!("{name}_maxpool"),
                    vec![cur.clone()],
                    vec![pool_out.clone()],
                )
                .with_attrs(
                    Attrs::new()
                        .with("kernel", AttrVal::Ints(vec![2, 2]))
                        .with("stride", AttrVal::Ints(vec![2, 2])),
                ),
            );
            cur = pool_out;
        }
    }

    // The backbone's final node — the paper's §III-D target.
    g.outputs = vec!["global_out".to_string()];
    g.shapes.insert("global_out".into(), vec![1, c3]);
    g.nodes.push(
        Node::new("ReduceMean", "gap", vec![cur], vec!["global_out".into()]).with_attrs(
            Attrs::new()
                .with("axes", AttrVal::Ints(vec![2, 3]))
                .with("keepdims", AttrVal::Int(0)),
        ),
    );
    g
}

// ---------------------------------------------------------------------------
// Folding search
// ---------------------------------------------------------------------------

/// Greedy folding (PE/SIMD) search: repeatedly double the parallelism of
/// the initiation-interval bottleneck until the fps target is met or the
/// LUT/FF/DSP/BRAM utilization cap would be exceeded.  The BRAM cap is
/// relaxed to the entry floor when minimal folding already exceeds it —
/// the weight memory is a fixed floor, and the search must not reject
/// the starting point — but folding may not grow BRAM *beyond*
/// `max(cap, entry)`.  Writes the chosen pe/simd attributes into the
/// graph and returns the node models at the final folding.
pub fn folding_search(
    graph: &mut Graph,
    cfg: &DesignConfig,
    device: &Device,
) -> Result<Vec<HwNodeModel>> {
    Ok(folding_search_traced(graph, cfg, device)?.0)
}

/// [`folding_search`] plus the initiation interval observed at the top of
/// every greedy iteration and after the final model (test/report
/// instrumentation).  The trace is non-increasing by construction: only
/// the bottleneck's parallelism is ever bumped, and folding never slows a
/// node down; a bump that breaks the utilization cap is rolled back.
pub fn folding_search_traced(
    graph: &mut Graph,
    cfg: &DesignConfig,
    device: &Device,
) -> Result<(Vec<HwNodeModel>, Vec<u64>)> {
    let cap_lut = device.budget.lut * cfg.max_utilization;
    let cap_ff = device.budget.ff * cfg.max_utilization;
    let cap_dsp = device.budget.dsp * cfg.max_utilization;
    // Entry BRAM floor: the weight memory at minimal folding is a fact of
    // the config, not a folding choice, so the cap never rejects it.
    let entry_bram = total_resources(&model_graph(graph, &cfg.quant)?).bram36;
    let cap_bram = (device.budget.bram36 * cfg.max_utilization).max(entry_bram);
    let fits = |r: &Resources| {
        r.lut <= cap_lut && r.ff <= cap_ff && r.dsp <= cap_dsp && r.bram36 <= cap_bram
    };
    let target_ii: Option<u64> = cfg
        .target_fps
        .map(|fps| (device.clock_mhz * 1e6 / fps).max(1.0) as u64);

    let mut trace: Vec<u64> = Vec::new();
    for _ in 0..10_000 {
        let models = model_graph(graph, &cfg.quant)?;
        let ii = initiation_interval(&models);
        trace.push(ii);
        if let Some(t) = target_ii {
            if ii <= t {
                break;
            }
        }
        // The bottleneck bounds the II; folding anything else is wasted
        // area.  If the bottleneck can't improve — maxed out, or its next
        // bump would break the cap — the search is done.
        let Some(bottleneck) = models.iter().max_by_key(|m| m.cycles) else {
            break;
        };
        if bottleneck.cycles <= 1 {
            break;
        }
        let name = bottleneck.name.clone();
        let saved = save_folding(graph, &name);
        if !bump_folding(graph, &name)? {
            break;
        }
        let after = model_graph(graph, &cfg.quant)?;
        if !fits(&total_resources(&after)) {
            restore_folding(graph, &name, saved);
            break;
        }
    }
    let models = model_graph(graph, &cfg.quant)?;
    trace.push(initiation_interval(&models));
    Ok((models, trace))
}

fn node_index(graph: &Graph, name: &str) -> Option<usize> {
    graph.nodes.iter().position(|n| n.name == name)
}

fn save_folding(graph: &Graph, name: &str) -> (i64, i64) {
    let node = &graph.nodes[node_index(graph, name).expect("folding node")];
    (node.attrs.int_or("pe", 1), node.attrs.int_or("simd", 1))
}

fn restore_folding(graph: &mut Graph, name: &str, saved: (i64, i64)) {
    let idx = node_index(graph, name).expect("folding node");
    graph.nodes[idx].attrs.set("pe", AttrVal::Int(saved.0));
    graph.nodes[idx].attrs.set("simd", AttrVal::Int(saved.1));
}

/// Double one folding knob of the named node; false when maxed out.
fn bump_folding(graph: &mut Graph, name: &str) -> Result<bool> {
    let Some(idx) = node_index(graph, name) else {
        bail!("folding target {name} not in graph");
    };
    // Read bounds with an immutable borrow first.
    let (op, pe, simd, k, n) = {
        let node = &graph.nodes[idx];
        let pe = node.attrs.int_or("pe", 1).max(1);
        let simd = node.attrs.int_or("simd", 1).max(1);
        let (k, n): (i64, i64) = match node.op.as_str() {
            "MVAU" => {
                let w = graph.shape_of(&node.inputs[1])?;
                (w[0] as i64, w[1] as i64)
            }
            "ConvolutionInputGenerator" | "GlobalAccPool_hw" => {
                let x = graph.shape_of(&node.inputs[0])?;
                (*x.last().unwrap_or(&1) as i64, 1)
            }
            "Thresholding" | "StreamingMaxPool" | "AddStreams" | "ChannelwiseMul" => {
                let y = graph.shape_of(&node.outputs[0])?;
                (1, *y.last().unwrap_or(&1) as i64)
            }
            // Transpose (host-side DMA) and anything else: not foldable.
            _ => (1, 1),
        };
        (node.op.clone(), pe, simd, k, n)
    };
    let node = &mut graph.nodes[idx];
    match op.as_str() {
        "MVAU" => {
            if simd < k {
                node.attrs.set("simd", AttrVal::Int((simd * 2).min(k)));
            } else if pe < n {
                node.attrs.set("pe", AttrVal::Int((pe * 2).min(n)));
            } else {
                return Ok(false);
            }
            Ok(true)
        }
        "ConvolutionInputGenerator" | "GlobalAccPool_hw" => {
            if simd < k {
                node.attrs.set("simd", AttrVal::Int((simd * 2).min(k)));
                Ok(true)
            } else {
                Ok(false)
            }
        }
        "Thresholding" | "StreamingMaxPool" | "AddStreams" | "ChannelwiseMul" => {
            if pe < n {
                node.attrs.set("pe", AttrVal::Int((pe * 2).min(n)));
                Ok(true)
            } else {
                Ok(false)
            }
        }
        _ => Ok(false),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_graph_matches_export_structure() {
        let g = synth_backbone_graph([4, 8, 8, 16], 16, 4, 2);
        g.validate().expect("valid synth graph");
        assert_eq!(g.count_op("Conv"), 8);
        assert_eq!(g.count_op("MultiThreshold"), 9); // 8 act + 1 input
        assert_eq!(g.count_op("Mul"), 9); // matching scale muls
        assert_eq!(g.count_op("ReduceMean"), 1);
        assert_eq!(g.count_op("Add"), 2);
        assert_eq!(g.count_op("MaxPool"), 3);
        assert_eq!(g.shape_of("global_in").unwrap(), &[1, 3, 16, 16]);
        assert_eq!(g.shape_of("global_out").unwrap(), &[1, 16]);
    }

    #[test]
    fn synth_graph_is_deterministic() {
        let a = synth_backbone_graph([4, 8, 8, 16], 16, 4, 2);
        let b = synth_backbone_graph([4, 8, 8, 16], 16, 4, 2);
        for (name, t) in &a.initializers {
            assert_eq!(t, &b.initializers[name], "initializer {name}");
        }
    }

    #[test]
    fn synth_graph_executes() {
        let g = synth_backbone_graph([4, 8, 8, 16], 16, 4, 2);
        let mut rng = Rng::new(1);
        let mut feeds = HashMap::new();
        feeds.insert(
            "global_in".to_string(),
            Tensor::from_fn(vec![1, 3, 16, 16], |_| rng.next_f32()),
        );
        let out = crate::ops::execute(&g, &feeds).unwrap();
        assert_eq!(out["global_out"].shape(), &[1, 16]);
        assert!(out["global_out"].data().iter().any(|&v| v != 0.0));
    }

    #[test]
    fn requantize_puts_weights_on_grid() {
        let mut g = synth_backbone_graph([4, 8, 8, 16], 16, 4, 2);
        let quant = headline_config(); // s6.5 weights
        requantize_graph(&mut g, &quant).unwrap();
        let w = &g.initializers["stem_w"];
        for &v in w.data() {
            let code = v as f64 * quant.weight.scale();
            assert_eq!(code, code.round(), "weight {v} off the s6.5 grid");
        }
        // Thresholds untouched (already exact).
        assert_eq!(
            g.initializers["stem_thresh"],
            synth_backbone_graph([4, 8, 8, 16], 16, 4, 2).initializers["stem_thresh"]
        );
    }

    #[test]
    fn folding_search_reduces_ii_under_target() {
        let device = Device::pynq_z1();
        let mut g = synth_backbone_graph([4, 8, 8, 16], 16, 4, 2);
        let cfg = DesignConfig {
            target_fps: Some(5_000.0), // aggressive: forces real folding
            max_utilization: 0.85,
            ..DesignConfig::default()
        };
        requantize_graph(&mut g, &cfg.quant).unwrap();
        run_default_pipeline(&mut g, None, 0.0).unwrap();
        let baseline = model_graph(&g, &cfg.quant).unwrap();
        let ii0 = initiation_interval(&baseline);
        let models = folding_search(&mut g, &cfg, &device).unwrap();
        let ii1 = initiation_interval(&models);
        assert!(ii1 < ii0, "folding did not improve II: {ii0} -> {ii1}");
    }

    #[test]
    fn build_end_to_end_on_synth_graph() {
        let device = Device::pynq_z1();
        let mut g = synth_backbone_graph([4, 8, 8, 16], 16, 4, 2);
        let report = build(&mut g, &DesignConfig::default(), &device).expect("build");
        assert!(convert_to_hw::is_fully_hw(&g));
        assert!(report.fps > 0.0);
        assert!(report.latency_ms > 0.0);
        assert!(report.weight_bits > 0);
        assert!(report.latency_cycles >= report.steady_cycles);
        assert_eq!(report.census_before["Conv"], 8);
        assert!(!report.fifo_depths.is_empty());
        // The report prints.
        assert!(report.summary().contains("fps"));
    }

    #[test]
    fn build_with_verification_is_numerically_silent() {
        let device = Device::pynq_z1();
        let mut g = synth_backbone_graph([4, 8, 8, 16], 16, 4, 2);
        let report = build(
            &mut g,
            &DesignConfig {
                verify: true,
                ..DesignConfig::default()
            },
            &device,
        )
        .expect("build");
        for s in &report.stages {
            assert!(
                s.max_divergence.unwrap_or(0.0) <= 2e-3,
                "stage {} diverged",
                s.transform
            );
        }
    }
}
