//! Reference executors for every graph op — the rust analogue of FINN's
//! `execute_onnx`, refactored around the compiled-plan engine.
//!
//! Four layers of API, fastest first:
//!
//! * [`execute_spec_into`] / [`execute_spec_inplace`] — kernels driven by a
//!   pre-resolved [`OpSpec`] (the [`crate::plan`] engine's path: attributes
//!   are parsed ONCE at plan compile, the run loop never scans an attr
//!   string or clones an attr `Vec` again); the bit-true integer datapath
//!   has its own spec layer next to it ([`IntOpSpec`] /
//!   [`execute_int_spec_into`]) executing packed fixed-point codes — each
//!   tensor stored in the narrowest container its format permits (i8 /
//!   i16 / i32, [`crate::tensor::IntCode`]) with kernels monomorphized
//!   per container and a cache-blocked i8×i8→i32-accumulate MVAU inner
//!   loop — what the FPGA actually computes *and* the bytes it actually
//!   streams, not a float simulation of either;
//! * [`execute_node_into`] / [`execute_node_inplace`] — same kernels, with
//!   the spec resolved from the node's `Attrs` on the spot;
//! * [`execute_node`] — compatibility form: infers the output shape
//!   ([`infer_output_shape`]), allocates, and delegates to the into-form;
//! * [`execute`] — whole-graph execution; now a thin wrapper that compiles
//!   an [`crate::plan::ExecutionPlan`] and runs it.  The original
//!   string-keyed interpreter survives as [`execute_interpreted`] for
//!   differential tests and the hotpath_micro engine comparison — it
//!   re-clones and re-toposorts the graph and resolves every tensor
//!   through `HashMap<String, Tensor>` per call, which is exactly the
//!   overhead the plan engine removes.
//!
//! Transform correctness is proven by executing the graph before and after
//! each rewrite on the same input and requiring (near-)exact equality; the
//! HW-layer ops (MVAU, Thresholding, ...) have behavioural executors here
//! too, so the *fully lowered* graph still executes and can be compared
//! against the original NCHW import and against features from the PJRT
//! artifact.
//!
//! Layout conventions: imported compute ops are NCHW (PyTorch-style); the
//! lowered/HW ops are NHWC streams, matching FINN's HLS library (§III-C of
//! the paper is precisely about this seam).

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::graph::{Graph, Node};
use crate::tensor::{broadcast_shape, CodeView, CodeViewMut, DType, IntCode, Tensor, TensorData};

/// Execute the graph on named input tensors; returns all graph outputs.
///
/// Compatibility wrapper over the plan engine: compiles an
/// [`crate::plan::ExecutionPlan`] for this call and runs it once.  Callers
/// that execute the same graph repeatedly should compile the plan
/// themselves and call [`crate::plan::ExecutionPlan::run_with`].
///
/// Contract note: plan compilation sizes buffers from the graph's shape
/// table, so every node output needs a `shapes` entry — the same
/// invariant [`Graph::validate`] enforces.  A hand-built graph without
/// annotations (which the old interpreter would run) fails at compile
/// with an "unknown tensor" error; annotate the shapes or use
/// [`execute_interpreted`].
pub fn execute(graph: &Graph, feeds: &HashMap<String, Tensor>) -> Result<HashMap<String, Tensor>> {
    crate::plan::ExecutionPlan::compile(graph)?.run(feeds)
}

/// The legacy string-keyed interpreter, preserved verbatim for
/// differential testing against the plan engine and for the
/// interpreter-vs-plan benchmark: clones + toposorts the graph and keys
/// every tensor through a `HashMap<String, Tensor>` on every call.
pub fn execute_interpreted(
    graph: &Graph,
    feeds: &HashMap<String, Tensor>,
) -> Result<HashMap<String, Tensor>> {
    let mut env: HashMap<String, Tensor> = HashMap::new();
    for (k, v) in feeds {
        env.insert(k.clone(), v.clone());
    }
    for input in &graph.inputs {
        if !env.contains_key(input) {
            bail!("missing feed for graph input {input}");
        }
    }
    let mut sorted = graph.clone();
    sorted.toposort()?;
    for node in &sorted.nodes {
        let inputs: Vec<&Tensor> = node
            .inputs
            .iter()
            .map(|name| {
                env.get(name)
                    .or_else(|| graph.initializers.get(name))
                    .ok_or_else(|| anyhow!("node {}: tensor {name} unavailable", node.name))
            })
            .collect::<Result<_>>()?;
        let outputs = execute_node(node, &inputs)
            .map_err(|e| anyhow!("executing {} ({}): {e}", node.name, node.op))?;
        if outputs.len() != node.outputs.len() {
            bail!(
                "node {} produced {} outputs, expected {}",
                node.name,
                outputs.len(),
                node.outputs.len()
            );
        }
        for (name, tensor) in node.outputs.iter().zip(outputs) {
            env.insert(name.clone(), tensor);
        }
    }
    let mut result = HashMap::new();
    for out in &graph.outputs {
        let t = env
            .remove(out)
            .ok_or_else(|| anyhow!("graph output {out} not produced"))?;
        result.insert(out.clone(), t);
    }
    Ok(result)
}

/// Execute a single node on resolved input tensors (compatibility form:
/// infers the output shape, allocates, delegates to the into-form).
pub fn execute_node(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let shapes: Vec<&[usize]> = inputs.iter().map(|t| t.shape()).collect();
    let out_shape = infer_output_shape(node, &shapes)?;
    let mut out = Tensor::zeros(out_shape);
    execute_node_into(node, inputs, &mut out)?;
    Ok(vec![out])
}

/// Output shape of a node given its input shapes — shared by the compat
/// executor and the plan compiler's shape cross-check.
pub fn infer_output_shape(node: &Node, inputs: &[&[usize]]) -> Result<Vec<usize>> {
    let in_shape = |i: usize| -> Result<&[usize]> {
        inputs
            .get(i)
            .copied()
            .ok_or_else(|| anyhow!("node {}: missing input {i}", node.name))
    };
    match node.op.as_str() {
        "Conv" => {
            let kernel = node.attrs.ints("kernel")?;
            let stride = node.attrs.ints("stride")?;
            let pad = node.attrs.ints("pad")?;
            let x = in_shape(0)?;
            let w = in_shape(1)?;
            if x.len() != 4 || w.len() != 4 {
                bail!("conv input/weight must be 4-D, got {x:?} / {w:?}");
            }
            let ho = (x[2] + 2 * pad[0] as usize - kernel[0] as usize) / stride[0] as usize + 1;
            let wo = (x[3] + 2 * pad[1] as usize - kernel[1] as usize) / stride[1] as usize + 1;
            Ok(vec![x[0], w[0], ho, wo])
        }
        "MultiThreshold" | "Thresholding" => Ok(in_shape(0)?.to_vec()),
        "Mul" | "Add" | "AddStreams" | "ChannelwiseMul" => {
            broadcast_shape(in_shape(0)?, in_shape(1)?)
        }
        "MaxPool" => {
            let kernel = node.attrs.ints("kernel")?;
            let x = in_shape(0)?;
            if x.len() != 4 {
                bail!("maxpool input must be 4-D");
            }
            Ok(vec![x[0], x[1], x[2] / kernel[0] as usize, x[3] / kernel[1] as usize])
        }
        "MaxPoolNHWC" | "StreamingMaxPool" => {
            let x = in_shape(0)?;
            if x.len() != 4 {
                bail!("pool input must be 4-D");
            }
            Ok(vec![x[0], x[1] / 2, x[2] / 2, x[3]])
        }
        "ReduceMean" => {
            let axes: Vec<usize> = node.attrs.ints("axes")?.iter().map(|&a| a as usize).collect();
            let keepdims = node.attrs.int_or("keepdims", 0) != 0;
            let x = in_shape(0)?;
            let mut out = Vec::new();
            for (i, &d) in x.iter().enumerate() {
                if axes.contains(&i) {
                    if keepdims {
                        out.push(1);
                    }
                } else {
                    out.push(d);
                }
            }
            Ok(out)
        }
        "Transpose" => {
            let perm: Vec<usize> = node.attrs.ints("perm")?.iter().map(|&p| p as usize).collect();
            let x = in_shape(0)?;
            if perm.len() != x.len() {
                bail!("perm {perm:?} rank mismatch with {x:?}");
            }
            Ok(perm.iter().map(|&p| x[p]).collect())
        }
        "Reshape" => {
            Ok(node.attrs.ints("shape")?.iter().map(|&d| d as usize).collect())
        }
        "Im2Col" | "ConvolutionInputGenerator" => {
            let kernel = node.attrs.ints("kernel")?;
            let stride = node.attrs.ints("stride")?;
            let pad = node.attrs.ints("pad")?;
            let x = in_shape(0)?;
            if x.len() != 4 {
                bail!("im2col input must be 4-D");
            }
            let (kh, kw) = (kernel[0] as usize, kernel[1] as usize);
            let ho = (x[1] + 2 * pad[0] as usize - kh) / stride[0] as usize + 1;
            let wo = (x[2] + 2 * pad[1] as usize - kw) / stride[1] as usize + 1;
            Ok(vec![x[0], ho, wo, kh * kw * x[3]])
        }
        "MatMul" | "MVAU" => {
            let x = in_shape(0)?;
            let w = in_shape(1)?;
            if x.is_empty() || w.len() != 2 {
                bail!("matmul shapes {x:?} x {w:?} unsupported");
            }
            let mut out = x[..x.len() - 1].to_vec();
            out.push(w[1]);
            Ok(out)
        }
        "GlobalAccPool" | "GlobalAccPool_hw" => {
            let x = in_shape(0)?;
            if x.len() != 4 {
                bail!("gap input must be 4-D");
            }
            Ok(vec![x[0], x[3]])
        }
        other => bail!("no executor for op {other}"),
    }
}

// ---------------------------------------------------------------- OpSpec

/// Channel-axis convention of a threshold step, resolved from the
/// `data_layout` string attribute once instead of per call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChanLayout {
    Nchw,
    Nhwc,
    Nc,
}

impl ChanLayout {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "NCHW" => Ok(ChanLayout::Nchw),
            "NHWC" => Ok(ChanLayout::Nhwc),
            "NC" => Ok(ChanLayout::Nc),
            other => bail!("unknown data_layout {other}"),
        }
    }

    fn chan_axis(self, ndim: usize) -> usize {
        match self {
            ChanLayout::Nchw | ChanLayout::Nc => 1,
            ChanLayout::Nhwc => ndim - 1,
        }
    }
}

/// Kernel parameters of one node, resolved from its `Attrs` up front —
/// the typed alternative to re-running the attr string scan (plus a `Vec`
/// clone per `Attrs::ints`) on every execution.  The plan compiler
/// resolves one `OpSpec` per step; the run loop dispatches on the enum
/// with zero attribute work per frame.
#[derive(Debug, Clone, PartialEq)]
pub enum OpSpec {
    Conv { kernel: [usize; 2], stride: [usize; 2], pad: [usize; 2] },
    Threshold { layout: ChanLayout, out_scale: f32, out_bias: f32 },
    Mul,
    Add,
    MaxPool { kernel: [usize; 2] },
    MaxPoolNhwc,
    ReduceMean { axes: Vec<usize> },
    Transpose { perm: Vec<usize> },
    Reshape { shape: Vec<usize> },
    Im2Col { kernel: [usize; 2], stride: [usize; 2], pad: [usize; 2] },
    MatMul,
    GlobalAccPool,
    Mvau { apply_act: bool, out_scale: f32, out_bias: f32 },
}

pub(crate) fn attr_pair(v: Vec<i64>, what: &str) -> Result<[usize; 2]> {
    if v.len() != 2 {
        bail!("attr {what} must have 2 entries, got {v:?}");
    }
    Ok([v[0] as usize, v[1] as usize])
}

impl OpSpec {
    /// Resolve a node's attributes into a typed spec.  Missing or
    /// malformed attributes fail here — at plan compile time — instead of
    /// surfacing mid-run.
    pub fn resolve(node: &Node) -> Result<OpSpec> {
        let a = &node.attrs;
        Ok(match node.op.as_str() {
            "Conv" => OpSpec::Conv {
                kernel: attr_pair(a.ints("kernel")?, "kernel")?,
                stride: attr_pair(a.ints("stride")?, "stride")?,
                pad: attr_pair(a.ints("pad")?, "pad")?,
            },
            "MultiThreshold" | "Thresholding" => OpSpec::Threshold {
                layout: ChanLayout::parse(a.str_or("data_layout", "NCHW"))?,
                out_scale: a.float_or("out_scale", 1.0) as f32,
                out_bias: a.float_or("out_bias", 0.0) as f32,
            },
            "Mul" | "ChannelwiseMul" => OpSpec::Mul,
            "Add" | "AddStreams" => OpSpec::Add,
            "MaxPool" => OpSpec::MaxPool {
                kernel: attr_pair(a.ints("kernel")?, "kernel")?,
            },
            "MaxPoolNHWC" | "StreamingMaxPool" => OpSpec::MaxPoolNhwc,
            "ReduceMean" => OpSpec::ReduceMean {
                axes: a.ints("axes")?.iter().map(|&x| x as usize).collect(),
            },
            "Transpose" => OpSpec::Transpose {
                perm: a.ints("perm")?.iter().map(|&p| p as usize).collect(),
            },
            "Reshape" => OpSpec::Reshape {
                shape: a.ints("shape")?.iter().map(|&d| d as usize).collect(),
            },
            "Im2Col" | "ConvolutionInputGenerator" => OpSpec::Im2Col {
                kernel: attr_pair(a.ints("kernel")?, "kernel")?,
                stride: attr_pair(a.ints("stride")?, "stride")?,
                pad: attr_pair(a.ints("pad")?, "pad")?,
            },
            "MatMul" => OpSpec::MatMul,
            "GlobalAccPool" | "GlobalAccPool_hw" => OpSpec::GlobalAccPool,
            "MVAU" => OpSpec::Mvau {
                apply_act: a.int_or("apply_act", 1) != 0,
                out_scale: a.float_or("out_scale", 1.0) as f32,
                out_bias: a.float_or("out_bias", 0.0) as f32,
            },
            other => bail!("no executor for op {other}"),
        })
    }
}

/// Execute a pre-resolved spec into a caller-provided buffer — the plan
/// engine's per-step entry point; touches no `Attrs`.
pub fn execute_spec_into(spec: &OpSpec, inputs: &[&Tensor], out: &mut Tensor) -> Result<()> {
    match spec {
        OpSpec::Conv { kernel, stride, pad } => conv_into(*kernel, *stride, *pad, inputs, out),
        OpSpec::Threshold { layout, out_scale, out_bias } => {
            copy_into(inputs[0], out)?;
            threshold_in_place(out, inputs[1], *layout, *out_scale, *out_bias)
        }
        OpSpec::Mul => inputs[0].broadcast_into(inputs[1], |a, b| a * b, out),
        OpSpec::Add => inputs[0].broadcast_into(inputs[1], |a, b| a + b, out),
        OpSpec::MaxPool { kernel } => maxpool_into(*kernel, inputs, out),
        OpSpec::MaxPoolNhwc => maxpool_nhwc_into(inputs, out),
        OpSpec::ReduceMean { axes } => reduce_mean_into(axes, inputs, out),
        OpSpec::Transpose { perm } => inputs[0].transpose_into(perm, out),
        OpSpec::Reshape { .. } => copy_into(inputs[0], out),
        OpSpec::Im2Col { kernel, stride, pad } => im2col_into(*kernel, *stride, *pad, inputs, out),
        OpSpec::MatMul => matmul_into(inputs[0], inputs[1], out),
        OpSpec::GlobalAccPool => global_acc_pool_into(inputs, out),
        OpSpec::Mvau { apply_act, out_scale, out_bias } => {
            mvau_into(*apply_act, *out_scale, *out_bias, inputs, out)
        }
    }
}

/// Execute a single-output node into a caller-provided buffer.
///
/// `out` must already have the node's output shape ([`infer_output_shape`]);
/// its *contents* may be arbitrary — every kernel either fully overwrites
/// or zero-fills before accumulating.  Compatibility form: resolves the
/// node's [`OpSpec`] on the spot; repeated executors should resolve once
/// and call [`execute_spec_into`].
pub fn execute_node_into(node: &Node, inputs: &[&Tensor], out: &mut Tensor) -> Result<()> {
    execute_spec_into(&OpSpec::resolve(node)?, inputs, out)
}

/// Ops the plan engine may execute in place, mutating the first input's
/// buffer instead of allocating an output (requires equal element count;
/// for non-Reshape ops, equal shape — the plan compiler checks).
pub fn supports_inplace(op: &str) -> bool {
    matches!(
        op,
        "Mul" | "Add" | "AddStreams" | "ChannelwiseMul" | "MultiThreshold" | "Thresholding"
            | "Reshape"
    )
}

/// In-place form over a pre-resolved spec: `buf` arrives as the first
/// input and leaves as the output; `rest` are the remaining inputs
/// (thresholds, the other elementwise operand, ...).
pub fn execute_spec_inplace(spec: &OpSpec, buf: &mut Tensor, rest: &[&Tensor]) -> Result<()> {
    match spec {
        OpSpec::Mul => buf.broadcast_assign(rest[0], |a, b| a * b),
        OpSpec::Add => buf.broadcast_assign(rest[0], |a, b| a + b),
        OpSpec::Threshold { layout, out_scale, out_bias } => {
            threshold_in_place(buf, rest[0], *layout, *out_scale, *out_bias)
        }
        OpSpec::Reshape { shape } => buf.reshape_in_place(shape.clone()),
        other => bail!("op spec {other:?} has no in-place executor"),
    }
}

/// In-place form resolved from the node (compatibility; see
/// [`execute_spec_inplace`]).
pub fn execute_node_inplace(node: &Node, buf: &mut Tensor, rest: &[&Tensor]) -> Result<()> {
    execute_spec_inplace(&OpSpec::resolve(node)?, buf, rest)
}

// ------------------------------------------------------------- IntOpSpec

/// Kernel parameters of one bit-true (integer-datapath) plan step — the
/// `_i32` twin of [`OpSpec`], resolved by
/// [`crate::plan::ExecutionPlan::compile_with`] from the `bt_*` format
/// annotations `transforms::annotate_bit_true_formats` writes.
///
/// Steady-state execution of every variant except the two `ingress`
/// boundaries performs **zero f32 arithmetic**: activations are packed
/// fixed-point codes in their narrowest container (i8 / i16 / i32),
/// weights and standalone threshold matrices are pre-converted
/// width-native code copies (MVAU bias/thresholds live on the wide
/// accumulator grid and stay i32), and the MVAU inner loop is
/// monomorphized per container pair — i8 × i8 accumulates in i32 (the
/// paper's headline widths), wider pairs in i64.  Float scale factors
/// were decomposed at annotation time into an odd integer multiplier
/// (`out_mul` / `m`) plus a power-of-two carried in the slot's
/// fractional-bit bookkeeping, so scaling is exact integer arithmetic.
/// Every kernel reads its containers from the tensors it is handed, so
/// the same [`IntOpSpec`] drives a packed plan and the all-i32
/// differential oracle ([`crate::plan::ExecutionPlan::compile_bit_true_wide`]).
#[derive(Debug, Clone, PartialEq)]
pub enum IntOpSpec {
    /// Ingress quantizer: the ONE step that reads f32 — it compares the
    /// raw feed against the float threshold matrix (comparisons only, no
    /// arithmetic) and emits integer codes `q * out_mul + out_add`.
    QuantizeThreshold { layout: ChanLayout, out_mul: i64, out_add: i64 },
    /// Integer MultiThreshold: i32 codes against precomputed integer
    /// thresholds (`ceil(t * 2^in_frac)` of the float matrix).
    Threshold { layout: ChanLayout, out_mul: i64, out_add: i64 },
    /// Matrix-Vector-Activation Unit on codes: i64-accumulate matmul +
    /// integer bias + optional fused integer threshold activation.
    Mvau { apply_act: bool, out_mul: i64, out_add: i64 },
    Im2Col { kernel: [usize; 2], stride: [usize; 2], pad: [usize; 2] },
    MaxPoolNhwc,
    /// Residual add; per-operand left shifts align the two operands'
    /// fractional bits (exact — shifts never round).
    AddStreams { shift: [u32; 2] },
    /// Multiply codes by the odd-mantissa part of a float scalar scale
    /// (the power-of-two part moved into the output format).
    MulScalar { m: i64, data_input: usize },
    GlobalAccPool,
    /// Layout conversion; dtype-generic.  `float_ingress` marks the
    /// boundary transpose that still moves f32 camera data.
    Transpose { perm: Vec<usize>, float_ingress: bool },
}

impl IntOpSpec {
    /// Audit label for the kernel-variant audit: "int" for steady-state
    /// integer kernels, "ingress-*" for the two boundary steps allowed
    /// to touch f32 data.
    pub fn variant(&self) -> &'static str {
        match self {
            IntOpSpec::QuantizeThreshold { .. } => "ingress-quant",
            IntOpSpec::Transpose {
                float_ingress: true,
                ..
            } => "ingress-f32",
            _ => "int",
        }
    }
}

#[inline]
fn store_i32(v: i64, what: &str) -> Result<i32> {
    i32::try_from(v).map_err(|_| anyhow!("{what}: value {v} overflows the i32 datapath"))
}

/// Checked narrowing into a packed container — overflow is a datapath
/// error, never a silent wrap.
#[inline]
fn narrow<T: IntCode>(v: i64, what: &str) -> Result<T> {
    T::from_wide(v)
        .ok_or_else(|| anyhow!("{what}: value {v} overflows the {:?} container", T::DTYPE))
}

fn codes_of<'a, T: IntCode>(t: &'a Tensor, what: &str) -> Result<&'a [T]> {
    T::slice(t.raw_data()).ok_or_else(|| {
        anyhow!(
            "{what}: expected {:?} codes, got a {:?} tensor",
            T::DTYPE,
            t.dtype()
        )
    })
}

fn codes_mut_of<'a, T: IntCode>(t: &'a mut Tensor, what: &str) -> Result<&'a mut [T]> {
    let dtype = t.dtype();
    T::slice_mut(t.raw_data_mut()).ok_or_else(|| {
        anyhow!(
            "{what}: expected {:?} codes, got a {dtype:?} tensor",
            T::DTYPE
        )
    })
}

/// Monomorphize `$e` over the container behind `$dt`: `$T` binds i8 /
/// i16 / i32 in the respective arm.  Nest invocations to dispatch over
/// several containers at once (input × weight × output).  Sub-byte
/// containers never reach these monomorphized kernels — every dispatcher
/// routes any-packed operand sets to the bit-addressed fallback (or the
/// specialized packed MVAU kernels) first, so hitting one here is a
/// dispatch bug, not a data error.
macro_rules! with_code {
    ($dt:expr, $T:ident, $what:expr, $e:expr) => {
        match $dt {
            DType::I8 => {
                type $T = i8;
                $e
            }
            DType::I16 => {
                type $T = i16;
                $e
            }
            DType::I32 => {
                type $T = i32;
                $e
            }
            DType::F32 => bail!("{}: packed integer kernel on an f32 tensor", $what),
            DType::U4 | DType::U1 | DType::B1 => bail!(
                "{}: byte-aligned kernel reached a packed {:?} tensor (packed dispatch bug)",
                $what,
                $dt
            ),
        }
    };
}

/// True when any tensor in the step carries a sub-byte packed container —
/// the dispatchers then take the bit-addressed [`CodeView`] path instead
/// of the byte-aligned monomorphized kernels.
fn any_packed(ts: &[&Tensor]) -> bool {
    ts.iter().any(|t| t.dtype().is_packed())
}

fn view_of<'a>(t: &'a Tensor, what: &str) -> Result<CodeView<'a>> {
    t.code_view()
        .ok_or_else(|| anyhow!("{what}: integer kernel on an f32 tensor"))
}

fn view_mut_of<'a>(t: &'a mut Tensor, what: &str) -> Result<CodeViewMut<'a>> {
    let dtype = t.dtype();
    t.code_view_mut()
        .ok_or_else(|| anyhow!("{what}: integer kernel on an f32 ({dtype:?}) tensor"))
}

/// Execute a bit-true spec into a caller-provided buffer — the integer
/// plan's per-step entry point.  Containers are read from the tensors
/// themselves, so the same spec drives packed (i8/i16) and wide (i32)
/// plans.
pub fn execute_int_spec_into(spec: &IntOpSpec, inputs: &[&Tensor], out: &mut Tensor) -> Result<()> {
    match spec {
        IntOpSpec::QuantizeThreshold {
            layout,
            out_mul,
            out_add,
        } => quantize_threshold_into(inputs[0], inputs[1], *layout, *out_mul, *out_add, out),
        IntOpSpec::Threshold {
            layout,
            out_mul,
            out_add,
        } => threshold_packed_into(inputs[0], inputs[1], *layout, *out_mul, *out_add, out),
        IntOpSpec::Mvau {
            apply_act,
            out_mul,
            out_add,
        } => mvau_packed_into(*apply_act, *out_mul, *out_add, inputs, out),
        IntOpSpec::Im2Col {
            kernel,
            stride,
            pad,
        } => im2col_packed_into(*kernel, *stride, *pad, inputs, out),
        IntOpSpec::MaxPoolNhwc => maxpool_nhwc_packed_into(inputs, out),
        IntOpSpec::AddStreams { shift } => add_streams_packed_into(*shift, inputs, out),
        IntOpSpec::MulScalar { m, data_input } => {
            mul_scalar_packed_into(*m, inputs[*data_input], out)
        }
        IntOpSpec::GlobalAccPool => gap_packed_into(inputs, out),
        IntOpSpec::Transpose { perm, .. } => inputs[0].transpose_into(perm, out),
    }
}

/// Threshold-matrix geometry against a data tensor: `(rows, K, channel
/// stride, channels)` with the rows-vs-channels consistency check.
fn threshold_geometry(
    t: &Tensor,
    x_shape: &[usize],
    x_strides: &[usize],
    layout: ChanLayout,
    what: &str,
) -> Result<(usize, usize, usize, usize)> {
    let (c_t, k) = (t.shape()[0], t.shape()[1]);
    let chan_axis = layout.chan_axis(x_shape.len());
    let c = x_shape[chan_axis];
    if c_t != c && c_t != 1 {
        bail!("{what}: threshold rows {c_t} != channels {c}");
    }
    Ok((c_t, k, x_strides[chan_axis], c))
}

/// Ingress quantizer: count float thresholds <= x (comparisons only) and
/// emit integer codes into whatever container the plan selected.  The
/// float compare against the sorted threshold row is exactly FINN's
/// `q = #{k : x >= t_k}` — identical to the f32 MultiThreshold executor's
/// partition point, so the emitted codes agree with the float path by
/// construction.
fn quantize_threshold_into(
    x: &Tensor,
    t: &Tensor,
    layout: ChanLayout,
    out_mul: i64,
    out_add: i64,
    out: &mut Tensor,
) -> Result<()> {
    if out.shape() != x.shape() {
        bail!(
            "quantize_threshold: out shape {:?} != input {:?}",
            out.shape(),
            x.shape()
        );
    }
    let (c_t, k, chan_stride, c) =
        threshold_geometry(t, x.shape(), &x.strides(), layout, "quantize_threshold")?;
    let ts = t.data();
    let xs = x.data();
    if out.dtype().is_packed() {
        // Sub-byte output container: bit-addressed store (checked — a
        // code outside the container's set is a datapath error).
        let n = out.numel();
        let mut ov = view_mut_of(out, "quantize_threshold output")?;
        for i in 0..n {
            let v = xs[i];
            let row = if c_t == 1 { 0 } else { (i / chan_stride) % c };
            let q = ts[row * k..(row + 1) * k].partition_point(|&t| t <= v) as i64;
            ov.set(i, q * out_mul + out_add)
                .map_err(|e| anyhow!("quantize_threshold: {e}"))?;
        }
        return Ok(());
    }
    with_code!(out.dtype(), O, "quantize_threshold output", {
        let od = codes_mut_of::<O>(out, "quantize_threshold output")?;
        for (i, o) in od.iter_mut().enumerate() {
            let v = xs[i];
            let row = if c_t == 1 { 0 } else { (i / chan_stride) % c };
            let q = ts[row * k..(row + 1) * k].partition_point(|&t| t <= v) as i64;
            *o = narrow::<O>(q * out_mul + out_add, "quantize_threshold")?;
        }
        Ok(())
    })
}

/// Integer MultiThreshold on packed codes: input, threshold matrix and
/// output each carry their own container; comparisons widen to i32
/// (free — a sign-extending load), storage stays narrow.  With
/// `tc = ceil(t * 2^f)` and `x = c * 2^-f` on the grid, `c >= tc  <=>
/// x >= t` — bit-exact agreement with the float compare.
fn threshold_packed_into(
    x: &Tensor,
    t: &Tensor,
    layout: ChanLayout,
    out_mul: i64,
    out_add: i64,
    out: &mut Tensor,
) -> Result<()> {
    if out.shape() != x.shape() {
        bail!(
            "threshold: out shape {:?} != input {:?}",
            out.shape(),
            x.shape()
        );
    }
    let (c_t, k, chan_stride, c) =
        threshold_geometry(t, x.shape(), &x.strides(), layout, "threshold")?;
    if any_packed(&[x, t, out]) {
        // Any sub-byte operand: bit-addressed generic path.  Threshold
        // steps are O(numel · log K) compares — never the MVAU-dominated
        // hot loop — so the per-code view indirection is acceptable.
        let xv = view_of(x, "threshold input")?;
        let tv = view_of(t, "threshold matrix")?;
        let n = out.numel();
        let mut ov = view_mut_of(out, "threshold output")?;
        for i in 0..n {
            let v = xv.get(i);
            let row = if c_t == 1 { 0 } else { (i / chan_stride) % c };
            let base = row * k;
            // partition_point over the bit-addressed threshold row.
            let mut q = 0usize;
            while q < k && tv.get(base + q) <= v {
                q += 1;
            }
            ov.set(i, q as i64 * out_mul + out_add)
                .map_err(|e| anyhow!("threshold: {e}"))?;
        }
        return Ok(());
    }
    with_code!(
        x.dtype(),
        X,
        "threshold input",
        with_code!(
            t.dtype(),
            T,
            "threshold matrix",
            with_code!(
                out.dtype(),
                O,
                "threshold output",
                threshold_typed::<X, T, O>(x, t, c_t, k, chan_stride, c, out_mul, out_add, out)
            )
        )
    )
}

fn threshold_typed<X: IntCode, T: IntCode, O: IntCode>(
    x: &Tensor,
    t: &Tensor,
    c_t: usize,
    k: usize,
    chan_stride: usize,
    c: usize,
    out_mul: i64,
    out_add: i64,
    out: &mut Tensor,
) -> Result<()> {
    let ts = codes_of::<T>(t, "threshold matrix")?;
    let xs = codes_of::<X>(x, "threshold input")?;
    let od = codes_mut_of::<O>(out, "threshold output")?;
    for (i, o) in od.iter_mut().enumerate() {
        let v = xs[i].widen();
        let row = if c_t == 1 { 0 } else { (i / chan_stride) % c };
        let q = ts[row * k..(row + 1) * k].partition_point(|&t| t.widen() <= v) as i64;
        *o = narrow::<O>(q * out_mul + out_add, "threshold")?;
    }
    Ok(())
}

/// `[..., K] x [K, N]` integer matmul with i64 accumulation over i32
/// containers — kept as the plain differential oracle next to the
/// blocked packed MVAU (same zero-skip, so the post-ReLU sparsity
/// optimization carries over).
pub fn matmul_i32_into(x: &Tensor, w: &Tensor, out: &mut Tensor) -> Result<()> {
    let k = *x.shape().last().ok_or_else(|| anyhow!("matmul on scalar"))?;
    let [wk, n]: [usize; 2] = w
        .shape()
        .try_into()
        .map_err(|_| anyhow!("matmul weight must be 2-D"))?;
    if wk != k {
        bail!("matmul inner dim {k} != weight rows {wk}");
    }
    let rows: usize = x.shape()[..x.ndim() - 1].iter().product();
    if out.numel() != rows * n {
        bail!("matmul output buffer {:?} != {rows}x{n}", out.shape());
    }
    let xs = x.data_i32();
    let ws = w.data_i32();
    let od = out.data_i32_mut();
    let mut acc: Vec<i64> = vec![0; n];
    for r in 0..rows {
        let xrow = &xs[r * k..(r + 1) * k];
        acc.fill(0);
        for (kk, &xv) in xrow.iter().enumerate() {
            if xv == 0 {
                continue;
            }
            let wrow = &ws[kk * n..(kk + 1) * n];
            for (a, &wv) in acc.iter_mut().zip(wrow) {
                *a += xv as i64 * wv as i64;
            }
        }
        for (o, &a) in od[r * n..(r + 1) * n].iter_mut().zip(&acc) {
            *o = store_i32(a, "matmul_i32 accumulate")?;
        }
    }
    Ok(())
}

/// Column-block width of the packed MVAU: bounds the live accumulator
/// strip (256 × 8 B = 2 KiB — resident in L1 across the whole K loop)
/// while keeping the inner loop a straight-line multiply-add over
/// contiguous weights that the compiler can autovectorize.
const MVAU_BLOCK_N: usize = 256;

/// MVAU on packed codes: cache-blocked matmul monomorphized over the
/// input/weight containers, integer bias add, optional fused integer
/// threshold activation — no float anywhere.  i8 × i8 accumulates in i32
/// (products are < 2^14, so K ≤ 2^16 rows cannot overflow); wider
/// container pairs accumulate in i64.  Bias and threshold codes live on
/// the wide accumulator grid and are always i32.
fn mvau_packed_into(
    apply_act: bool,
    out_mul: i64,
    out_add: i64,
    inputs: &[&Tensor],
    out: &mut Tensor,
) -> Result<()> {
    let (x, w) = (inputs[0], inputs[1]);
    let bias = codes_of::<i32>(inputs[2], "mvau bias (accumulator grid)")?;
    let thr = if apply_act {
        Some(
            *inputs
                .get(3)
                .ok_or_else(|| anyhow!("MVAU with apply_act needs thresholds input"))?,
        )
    } else {
        None
    };
    // Kernel selection: both operands bipolar 1-bit -> XNOR+popcount;
    // any other sub-byte combination -> block-unpacking kernel (weights
    // stay packed in memory); all byte-aligned -> the monomorphized
    // cache-blocked fast path.
    if x.dtype() == DType::B1 && w.dtype() == DType::B1 {
        return mvau_xnor_b1(out_mul, out_add, x, w, bias, thr, out);
    }
    if any_packed(&[x, w, out]) {
        return mvau_unpack_blocked(out_mul, out_add, x, w, bias, thr, out);
    }
    with_code!(
        x.dtype(),
        X,
        "mvau input",
        with_code!(
            w.dtype(),
            W,
            "mvau weights",
            with_code!(
                out.dtype(),
                O,
                "mvau output",
                mvau_typed::<X, W, O>(out_mul, out_add, x, w, bias, thr, out)
            )
        )
    )
}

/// Shared geometry / threshold resolution of the packed MVAU kernels:
/// `(rows, K, N, thresholds)` with the same consistency checks the
/// monomorphized kernel performs.
fn mvau_geometry<'a>(
    x: &Tensor,
    w: &Tensor,
    out: &Tensor,
    bias: &[i32],
    thr: Option<&'a Tensor>,
) -> Result<(usize, usize, usize, Option<(&'a [i32], usize, usize)>)> {
    let k = *x.shape().last().ok_or_else(|| anyhow!("mvau on scalar"))?;
    let [wk, n]: [usize; 2] = w
        .shape()
        .try_into()
        .map_err(|_| anyhow!("mvau weight must be 2-D"))?;
    if wk != k {
        bail!("mvau inner dim {k} != weight rows {wk}");
    }
    let rows: usize = x.shape()[..x.ndim() - 1].iter().product();
    if out.numel() != rows * n {
        bail!("mvau output buffer {:?} != {rows}x{n}", out.shape());
    }
    if bias.len() != n {
        bail!("mvau bias length {} != output channels {n}", bias.len());
    }
    let tinfo = match thr {
        Some(t) => {
            let (c_t, kt) = (t.shape()[0], t.shape()[1]);
            if c_t != n && c_t != 1 {
                bail!("mvau threshold rows {c_t} != output channels {n}");
            }
            Some((
                codes_of::<i32>(t, "mvau thresholds (accumulator grid)")?,
                c_t,
                kt,
            ))
        }
        None => None,
    };
    Ok((rows, k, n, tinfo))
}

/// Fused MVAU activation epilogue on the wide accumulator value: count
/// thresholds <= v, scale onto the output grid (identical to the
/// monomorphized kernel's epilogue — the differential tests hold all
/// kernels to the same codes).
#[inline]
fn mvau_act(
    v: i64,
    col: usize,
    tinfo: Option<(&[i32], usize, usize)>,
    out_mul: i64,
    out_add: i64,
) -> i64 {
    match tinfo {
        Some((ts, c_t, kt)) => {
            let trow_at = if c_t == 1 { 0 } else { col };
            let trow = &ts[trow_at * kt..(trow_at + 1) * kt];
            let q = trow.partition_point(|&t| (t as i64) <= v) as i64;
            q * out_mul + out_add
        }
        None => v,
    }
}

/// MVAU over any operand set containing a sub-byte container — the
/// nibble-blocked u4 path: the activation row is unpacked once per row
/// and the weight matrix, which STAYS packed in memory, is unpacked one
/// `MVAU_BLOCK_N`-column strip at a time into a small i32 scratch tile,
/// so the inner multiply-add runs over flat integers while memory
/// traffic stays at 4 (or 1) bits per code.  Unpack work is O(rows·K·N)
/// shifts on top of the O(rows·K·N) MACs — constant factor, no extra
/// memory movement.
fn mvau_unpack_blocked(
    out_mul: i64,
    out_add: i64,
    x: &Tensor,
    w: &Tensor,
    bias: &[i32],
    thr: Option<&Tensor>,
    out: &mut Tensor,
) -> Result<()> {
    let (rows, k, n, tinfo) = mvau_geometry(x, w, out, bias, thr)?;
    let xv = view_of(x, "mvau input")?;
    let wv = view_of(w, "mvau weights")?;
    let mut xbuf = vec![0i32; k];
    let mut wbuf = vec![0i32; MVAU_BLOCK_N];
    let mut acc = vec![0i64; MVAU_BLOCK_N];
    let mut ov = view_mut_of(out, "mvau output")?;
    for r in 0..rows {
        for (i, slot) in xbuf.iter_mut().enumerate() {
            *slot = xv.get(r * k + i);
        }
        let mut jb = 0;
        while jb < n {
            let nb = MVAU_BLOCK_N.min(n - jb);
            let acc = &mut acc[..nb];
            acc.fill(0);
            for (kk, &xvv) in xbuf.iter().enumerate() {
                if xvv == 0 {
                    continue;
                }
                let base = kk * n + jb;
                let wtile = &mut wbuf[..nb];
                for (jj, slot) in wtile.iter_mut().enumerate() {
                    *slot = wv.get(base + jj);
                }
                let xvv = xvv as i64;
                for (a, &wvv) in acc.iter_mut().zip(wtile.iter()) {
                    *a += xvv * wvv as i64;
                }
            }
            for (jj, &a) in acc.iter().enumerate() {
                let col = jb + jj;
                let code = mvau_act(a + bias[col] as i64, col, tinfo, out_mul, out_add);
                ov.set(r * n + col, code).map_err(|e| anyhow!("mvau: {e}"))?;
            }
            jb += nb;
        }
    }
    Ok(())
}

/// XNOR+popcount MVAU for bipolar 1-bit configs — the FINN PE
/// realization: with codes in {-1, +1} stored as bits (1 ↔ +1), the dot
/// product is `2·popcount(xnor(w, a)) − K`, evaluated word-at-a-time on
/// u64 lanes.  The packed [K, N] weight matrix is transposed once per
/// call into per-column bit words (K·N bit reads, amortized over every
/// output row); bits past K in the last word are masked out of the
/// xnor — `!(a ^ w)` would otherwise count the zero padding as
/// agreement.
fn mvau_xnor_b1(
    out_mul: i64,
    out_add: i64,
    x: &Tensor,
    w: &Tensor,
    bias: &[i32],
    thr: Option<&Tensor>,
    out: &mut Tensor,
) -> Result<()> {
    let (rows, k, n, tinfo) = mvau_geometry(x, w, out, bias, thr)?;
    let (TensorData::B1(xp), TensorData::B1(wp)) = (x.raw_data(), w.raw_data()) else {
        bail!("mvau_xnor: operands must both be bipolar B1 tensors");
    };
    let words = k.div_ceil(64);
    let tail = k & 63;
    let tail_mask: u64 = if tail == 0 { u64::MAX } else { (1u64 << tail) - 1 };
    // Column-major bit image of the weights: wcols[col * words + wi].
    let mut wcols = vec![0u64; n * words];
    for kk in 0..k {
        let base = kk * n;
        for col in 0..n {
            if wp.bit(base + col) != 0 {
                wcols[col * words + kk / 64] |= 1u64 << (kk & 63);
            }
        }
    }
    let mut xw = vec![0u64; words];
    let mut ov = view_mut_of(out, "mvau output")?;
    for r in 0..rows {
        xw.fill(0);
        let base = r * k;
        for i in 0..k {
            if xp.bit(base + i) != 0 {
                xw[i / 64] |= 1u64 << (i & 63);
            }
        }
        for col in 0..n {
            let wc = &wcols[col * words..(col + 1) * words];
            let mut ones = 0u32;
            for (wi, (&xm, &wm)) in xw.iter().zip(wc).enumerate() {
                let mask = if wi + 1 == words { tail_mask } else { u64::MAX };
                ones += (!(xm ^ wm) & mask).count_ones();
            }
            let dot = 2 * ones as i64 - k as i64;
            let code = mvau_act(dot + bias[col] as i64, col, tinfo, out_mul, out_add);
            ov.set(r * n + col, code).map_err(|e| anyhow!("mvau: {e}"))?;
        }
    }
    Ok(())
}

fn mvau_typed<X: IntCode, W: IntCode, O: IntCode>(
    out_mul: i64,
    out_add: i64,
    x: &Tensor,
    w: &Tensor,
    bias: &[i32],
    thr: Option<&Tensor>,
    out: &mut Tensor,
) -> Result<()> {
    let k = *x.shape().last().ok_or_else(|| anyhow!("mvau on scalar"))?;
    let [wk, n]: [usize; 2] = w
        .shape()
        .try_into()
        .map_err(|_| anyhow!("mvau weight must be 2-D"))?;
    if wk != k {
        bail!("mvau inner dim {k} != weight rows {wk}");
    }
    let rows: usize = x.shape()[..x.ndim() - 1].iter().product();
    if out.numel() != rows * n {
        bail!("mvau output buffer {:?} != {rows}x{n}", out.shape());
    }
    if bias.len() != n {
        bail!("mvau bias length {} != output channels {n}", bias.len());
    }
    // The fused activation always sees the NHWC stream layout: output
    // column = channel.
    let tinfo: Option<(&[i32], usize, usize)> = match thr {
        Some(t) => {
            let (c_t, kt) = (t.shape()[0], t.shape()[1]);
            if c_t != n && c_t != 1 {
                bail!("mvau threshold rows {c_t} != output channels {n}");
            }
            Some((
                codes_of::<i32>(t, "mvau thresholds (accumulator grid)")?,
                c_t,
                kt,
            ))
        }
        None => None,
    };
    let xs = codes_of::<X>(x, "mvau input")?;
    let ws = codes_of::<W>(w, "mvau weights")?;
    let od = codes_mut_of::<O>(out, "mvau output")?;

    // i32 accumulation is safe iff every |x*w| < 2^(X+W-2) partial sum of
    // K terms stays below 2^31; the branch is constant per instantiation,
    // so each monomorphized kernel contains exactly one loop nest.
    let narrow_acc = X::BITS + W::BITS <= 16 && k <= (1 << 16);
    let mut acc64 = vec![0i64; MVAU_BLOCK_N];
    let mut acc32 = vec![0i32; MVAU_BLOCK_N];
    for r in 0..rows {
        let xrow = &xs[r * k..(r + 1) * k];
        let mut jb = 0;
        while jb < n {
            let nb = MVAU_BLOCK_N.min(n - jb);
            if narrow_acc {
                // SWAR-style inner loop: four k-rows per step, each
                // accumulator summing four independent products per
                // iteration.  No data-dependent branch (the old
                // zero-skip `continue` defeated autovectorization) and
                // a fixed-trip-count body over a contiguous strip, so
                // the compiler lifts it onto the vector unit; the four
                // products per lane also break the add latency chain.
                // Bitwise-identical to the scalar loop: i32 wrapping
                // addition is associative, and the bound that justifies
                // narrow_acc (K terms each < 2^(X+W-2), total < 2^31)
                // covers every partial order of the same terms.
                let acc = &mut acc32[..nb];
                acc.fill(0);
                let mut kk = 0;
                while kk + 4 <= k {
                    let x0 = xrow[kk].widen();
                    let x1 = xrow[kk + 1].widen();
                    let x2 = xrow[kk + 2].widen();
                    let x3 = xrow[kk + 3].widen();
                    let w0 = &ws[kk * n + jb..kk * n + jb + nb];
                    let w1 = &ws[(kk + 1) * n + jb..(kk + 1) * n + jb + nb];
                    let w2 = &ws[(kk + 2) * n + jb..(kk + 2) * n + jb + nb];
                    let w3 = &ws[(kk + 3) * n + jb..(kk + 3) * n + jb + nb];
                    for ((((a, &v0), &v1), &v2), &v3) in
                        acc.iter_mut().zip(w0).zip(w1).zip(w2).zip(w3)
                    {
                        *a += x0 * v0.widen()
                            + x1 * v1.widen()
                            + x2 * v2.widen()
                            + x3 * v3.widen();
                    }
                    kk += 4;
                }
                for kk in kk..k {
                    let xv = xrow[kk].widen();
                    let wrow = &ws[kk * n + jb..kk * n + jb + nb];
                    for (a, &wv) in acc.iter_mut().zip(wrow) {
                        *a += xv * wv.widen();
                    }
                }
                for (a64, &a32) in acc64[..nb].iter_mut().zip(acc.iter()) {
                    *a64 = a32 as i64;
                }
            } else {
                let acc = &mut acc64[..nb];
                acc.fill(0);
                for (kk, &xv) in xrow.iter().enumerate() {
                    let xv = xv.widen() as i64;
                    if xv == 0 {
                        continue;
                    }
                    let wrow = &ws[kk * n + jb..kk * n + jb + nb];
                    for (a, &wv) in acc.iter_mut().zip(wrow) {
                        *a += xv * wv.widen() as i64;
                    }
                }
            }
            for (jj, &a) in acc64[..nb].iter().enumerate() {
                let col = jb + jj;
                let v = a + bias[col] as i64;
                let code = match tinfo {
                    Some((ts, c_t, kt)) => {
                        let trow_at = if c_t == 1 { 0 } else { col };
                        let trow = &ts[trow_at * kt..(trow_at + 1) * kt];
                        let q = trow.partition_point(|&t| (t as i64) <= v) as i64;
                        q * out_mul + out_add
                    }
                    None => v,
                };
                od[r * n + col] = narrow::<O>(code, "mvau")?;
            }
            jb += nb;
        }
    }
    Ok(())
}

/// NHWC im2col on packed codes — zero padding is code 0 (value 0 on
/// every grid).  Container-preserving: the window generator only moves
/// bytes, it never widens them.
fn im2col_packed_into(
    kernel: [usize; 2],
    stride: [usize; 2],
    pad: [usize; 2],
    inputs: &[&Tensor],
    out: &mut Tensor,
) -> Result<()> {
    let x = inputs[0];
    if x.dtype() != out.dtype() {
        bail!(
            "im2col: container mismatch ({:?} -> {:?})",
            x.dtype(),
            out.dtype()
        );
    }
    if x.dtype().is_packed() {
        return im2col_view(kernel, stride, pad, x, out);
    }
    with_code!(
        x.dtype(),
        T,
        "im2col",
        im2col_typed::<T>(kernel, stride, pad, x, out)
    )
}

/// im2col over a sub-byte container: same traversal as the typed
/// kernel, through the bit-addressed views.  Zero padding is written as
/// code 0 — unrepresentable on a bipolar container, which errors loudly
/// rather than silently corrupting the patch (padded bipolar layers
/// must be annotated into a wider container).
fn im2col_view(
    kernel: [usize; 2],
    stride: [usize; 2],
    pad: [usize; 2],
    x: &Tensor,
    out: &mut Tensor,
) -> Result<()> {
    let [kh, kw] = kernel;
    let [sh, sw] = stride;
    let [ph, pw] = pad;
    let [n, h, w, c]: [usize; 4] = x
        .shape()
        .try_into()
        .map_err(|_| anyhow!("im2col input must be 4-D"))?;
    let ho = (h + 2 * ph - kh) / sh + 1;
    let wo = (w + 2 * pw - kw) / sw + 1;
    let k = kh * kw * c;
    if out.numel() != n * ho * wo * k {
        bail!("im2col output buffer {:?} wrong size", out.shape());
    }
    let xv = view_of(x, "im2col input")?;
    let mut ov = view_mut_of(out, "im2col output")?;
    for b in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let base = ((b * ho + oy) * wo + ox) * k;
                let mut slot = 0;
                for dy in 0..kh {
                    for dx in 0..kw {
                        let iy = oy * sh + dy;
                        let ix = ox * sw + dx;
                        for ch in 0..c {
                            let v = if iy < ph || iy >= h + ph || ix < pw || ix >= w + pw {
                                0
                            } else {
                                xv.get(((b * h + (iy - ph)) * w + (ix - pw)) * c + ch) as i64
                            };
                            ov.set(base + slot, v).map_err(|e| anyhow!("im2col: {e}"))?;
                            slot += 1;
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

fn im2col_typed<T: IntCode>(
    kernel: [usize; 2],
    stride: [usize; 2],
    pad: [usize; 2],
    x: &Tensor,
    out: &mut Tensor,
) -> Result<()> {
    let [kh, kw] = kernel;
    let [sh, sw] = stride;
    let [ph, pw] = pad;
    let [n, h, w, c]: [usize; 4] = x
        .shape()
        .try_into()
        .map_err(|_| anyhow!("im2col input must be 4-D"))?;
    let ho = (h + 2 * ph - kh) / sh + 1;
    let wo = (w + 2 * pw - kw) / sw + 1;
    let k = kh * kw * c;
    if out.numel() != n * ho * wo * k {
        bail!("im2col output buffer {:?} wrong size", out.shape());
    }
    let xs = codes_of::<T>(x, "im2col input")?;
    let od = codes_mut_of::<T>(out, "im2col output")?;
    for b in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let base = ((b * ho + oy) * wo + ox) * k;
                let mut slot = 0;
                for dy in 0..kh {
                    for dx in 0..kw {
                        let iy = oy * sh + dy;
                        let ix = ox * sw + dx;
                        for ch in 0..c {
                            let v = if iy < ph || iy >= h + ph || ix < pw || ix >= w + pw {
                                T::default()
                            } else {
                                xs[((b * h + (iy - ph)) * w + (ix - pw)) * c + ch]
                            };
                            od[base + slot] = v;
                            slot += 1;
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

/// NHWC 2x2/2 max-pool on packed codes (monotone dequantization makes
/// the code max equal the value max; same-sign widening keeps order, so
/// the compare runs on the narrow type directly).
fn maxpool_nhwc_packed_into(inputs: &[&Tensor], out: &mut Tensor) -> Result<()> {
    let x = inputs[0];
    if x.dtype() != out.dtype() {
        bail!(
            "maxpool: container mismatch ({:?} -> {:?})",
            x.dtype(),
            out.dtype()
        );
    }
    if x.dtype().is_packed() {
        return maxpool_nhwc_view(x, out);
    }
    with_code!(x.dtype(), T, "maxpool", maxpool_nhwc_typed::<T>(x, out))
}

/// 2x2/2 max-pool over a sub-byte container: the code max equals the
/// value max (monotone dequantization), and `CodeView::get` widens to
/// the signed code value, so the compare runs on i32.
fn maxpool_nhwc_view(x: &Tensor, out: &mut Tensor) -> Result<()> {
    let [n, h, w, c]: [usize; 4] = x
        .shape()
        .try_into()
        .map_err(|_| anyhow!("pool input must be 4-D"))?;
    let (ho, wo) = (h / 2, w / 2);
    if out.numel() != n * ho * wo * c {
        bail!("maxpool output buffer {:?} wrong size", out.shape());
    }
    let xv = view_of(x, "maxpool input")?;
    let mut ov = view_mut_of(out, "maxpool output")?;
    for b in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                for ch in 0..c {
                    let mut m = xv.get(((b * h + oy * 2) * w + ox * 2) * c + ch);
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let v = xv.get(((b * h + oy * 2 + dy) * w + ox * 2 + dx) * c + ch);
                            if v > m {
                                m = v;
                            }
                        }
                    }
                    ov.set(((b * ho + oy) * wo + ox) * c + ch, m as i64)
                        .map_err(|e| anyhow!("maxpool: {e}"))?;
                }
            }
        }
    }
    Ok(())
}

fn maxpool_nhwc_typed<T: IntCode>(x: &Tensor, out: &mut Tensor) -> Result<()> {
    let [n, h, w, c]: [usize; 4] = x
        .shape()
        .try_into()
        .map_err(|_| anyhow!("pool input must be 4-D"))?;
    let (ho, wo) = (h / 2, w / 2);
    if out.numel() != n * ho * wo * c {
        bail!("maxpool output buffer {:?} wrong size", out.shape());
    }
    let xs = codes_of::<T>(x, "maxpool input")?;
    let od = codes_mut_of::<T>(out, "maxpool output")?;
    for b in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                for ch in 0..c {
                    let mut m = xs[((b * h + oy * 2) * w + ox * 2) * c + ch];
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let v = xs[((b * h + oy * 2 + dy) * w + ox * 2 + dx) * c + ch];
                            if v > m {
                                m = v;
                            }
                        }
                    }
                    od[((b * ho + oy) * wo + ox) * c + ch] = m;
                }
            }
        }
    }
    Ok(())
}

/// Residual add with frac alignment: `(a << s0) + (b << s1)`.  The two
/// branches of a residual may arrive in different containers (each side
/// is stored at its own width); the sum lands in the annotated output
/// container.
fn add_streams_packed_into(shift: [u32; 2], inputs: &[&Tensor], out: &mut Tensor) -> Result<()> {
    let (a, b) = (inputs[0], inputs[1]);
    if a.shape() != b.shape() || out.shape() != a.shape() {
        bail!(
            "add_streams: shape mismatch {:?} + {:?} -> {:?}",
            a.shape(),
            b.shape(),
            out.shape()
        );
    }
    if any_packed(&[a, b, out]) {
        let [s0, s1] = shift;
        let av = view_of(a, "add_streams lhs")?;
        let bv = view_of(b, "add_streams rhs")?;
        let n = out.numel();
        let mut ov = view_mut_of(out, "add_streams output")?;
        for i in 0..n {
            let v = ((av.get(i) as i64) << s0) + ((bv.get(i) as i64) << s1);
            ov.set(i, v).map_err(|e| anyhow!("add_streams: {e}"))?;
        }
        return Ok(());
    }
    with_code!(
        a.dtype(),
        A,
        "add_streams lhs",
        with_code!(
            b.dtype(),
            B,
            "add_streams rhs",
            with_code!(
                out.dtype(),
                O,
                "add_streams output",
                add_streams_typed::<A, B, O>(shift, a, b, out)
            )
        )
    )
}

fn add_streams_typed<A: IntCode, B: IntCode, O: IntCode>(
    shift: [u32; 2],
    a: &Tensor,
    b: &Tensor,
    out: &mut Tensor,
) -> Result<()> {
    let [s0, s1] = shift;
    let asl = codes_of::<A>(a, "add_streams lhs")?;
    let bsl = codes_of::<B>(b, "add_streams rhs")?;
    let od = codes_mut_of::<O>(out, "add_streams output")?;
    for ((o, &x), &y) in od.iter_mut().zip(asl).zip(bsl) {
        let v = ((x.widen() as i64) << s0) + ((y.widen() as i64) << s1);
        *o = narrow::<O>(v, "add_streams")?;
    }
    Ok(())
}

/// Channelwise/scalar multiply on packed codes by the odd integer
/// multiplier (the output container may be wider — `m > 1` grows the
/// code range).
fn mul_scalar_packed_into(m: i64, data: &Tensor, out: &mut Tensor) -> Result<()> {
    if out.shape() != data.shape() {
        bail!(
            "mul_scalar: out shape {:?} != input {:?}",
            out.shape(),
            data.shape()
        );
    }
    if any_packed(&[data, out]) {
        let xv = view_of(data, "mul_scalar input")?;
        let n = out.numel();
        let mut ov = view_mut_of(out, "mul_scalar output")?;
        for i in 0..n {
            ov.set(i, xv.get(i) as i64 * m)
                .map_err(|e| anyhow!("mul_scalar: {e}"))?;
        }
        return Ok(());
    }
    with_code!(
        data.dtype(),
        T,
        "mul_scalar input",
        with_code!(
            out.dtype(),
            O,
            "mul_scalar output",
            mul_scalar_typed::<T, O>(m, data, out)
        )
    )
}

fn mul_scalar_typed<T: IntCode, O: IntCode>(m: i64, data: &Tensor, out: &mut Tensor) -> Result<()> {
    let xs = codes_of::<T>(data, "mul_scalar input")?;
    let od = codes_mut_of::<O>(out, "mul_scalar output")?;
    for (o, &x) in od.iter_mut().zip(xs) {
        *o = narrow::<O>(x.widen() as i64 * m, "mul_scalar")?;
    }
    Ok(())
}

/// GlobalAccPool on packed codes: NHWC -> [N, C] cumulative sum, i64
/// accumulate, stored in the annotated (spatially widened) container.
fn gap_packed_into(inputs: &[&Tensor], out: &mut Tensor) -> Result<()> {
    let x = inputs[0];
    if any_packed(&[x, out]) {
        let [n, h, w, c]: [usize; 4] = x
            .shape()
            .try_into()
            .map_err(|_| anyhow!("gap input must be 4-D"))?;
        if out.numel() != n * c {
            bail!("gap output buffer {:?} != [{n}, {c}]", out.shape());
        }
        let xv = view_of(x, "gap input")?;
        let mut acc: Vec<i64> = vec![0; n * c];
        for b in 0..n {
            for y in 0..h {
                for xcol in 0..w {
                    for ch in 0..c {
                        acc[b * c + ch] += xv.get(((b * h + y) * w + xcol) * c + ch) as i64;
                    }
                }
            }
        }
        let mut ov = view_mut_of(out, "gap output")?;
        for (i, &a) in acc.iter().enumerate() {
            ov.set(i, a).map_err(|e| anyhow!("global_acc_pool: {e}"))?;
        }
        return Ok(());
    }
    with_code!(
        x.dtype(),
        T,
        "gap input",
        with_code!(
            out.dtype(),
            O,
            "gap output",
            gap_typed::<T, O>(x, out)
        )
    )
}

fn gap_typed<T: IntCode, O: IntCode>(x: &Tensor, out: &mut Tensor) -> Result<()> {
    let [n, h, w, c]: [usize; 4] = x
        .shape()
        .try_into()
        .map_err(|_| anyhow!("gap input must be 4-D"))?;
    if out.numel() != n * c {
        bail!("gap output buffer {:?} != [{n}, {c}]", out.shape());
    }
    let xs = codes_of::<T>(x, "gap input")?;
    let mut acc: Vec<i64> = vec![0; n * c];
    for b in 0..n {
        for y in 0..h {
            for xcol in 0..w {
                for ch in 0..c {
                    acc[b * c + ch] += xs[((b * h + y) * w + xcol) * c + ch].widen() as i64;
                }
            }
        }
    }
    let od = codes_mut_of::<O>(out, "gap output")?;
    for (o, &a) in od.iter_mut().zip(&acc) {
        *o = narrow::<O>(a, "global_acc_pool")?;
    }
    Ok(())
}

fn copy_into(src: &Tensor, out: &mut Tensor) -> Result<()> {
    if src.numel() != out.numel() {
        bail!(
            "copy_into: element count mismatch {:?} -> {:?}",
            src.shape(),
            out.shape()
        );
    }
    match (src.raw_data(), out.raw_data_mut()) {
        (TensorData::F32(s), TensorData::F32(d)) => d.copy_from_slice(s),
        (TensorData::I8(s), TensorData::I8(d)) => d.copy_from_slice(s),
        (TensorData::I16(s), TensorData::I16(d)) => d.copy_from_slice(s),
        (TensorData::I32(s), TensorData::I32(d)) => d.copy_from_slice(s),
        (TensorData::U4(s), TensorData::U4(d)) => d.clone_from(s),
        (TensorData::U1(s), TensorData::U1(d)) => d.clone_from(s),
        (TensorData::B1(s), TensorData::B1(d)) => d.clone_from(s),
        _ => bail!(
            "copy_into: dtype mismatch ({:?} -> {:?})",
            src.dtype(),
            out.dtype()
        ),
    }
    Ok(())
}

// ---------------------------------------------------------------- Conv

/// NCHW x OIHW convolution with symmetric padding, stride and bias.
fn conv_into(
    kernel: [usize; 2],
    stride: [usize; 2],
    pad: [usize; 2],
    inputs: &[&Tensor],
    out: &mut Tensor,
) -> Result<()> {
    let (x, w) = (inputs[0], inputs[1]);
    let bias = inputs.get(2).copied();
    let [kh, kw] = kernel;
    let [sh, sw] = stride;
    let [ph, pw] = pad;
    let [n, cin, h, wdim]: [usize; 4] = x
        .shape()
        .try_into()
        .map_err(|_| anyhow!("conv input must be 4-D"))?;
    let [cout, wcin, wkh, wkw]: [usize; 4] = w
        .shape()
        .try_into()
        .map_err(|_| anyhow!("conv weight must be 4-D"))?;
    if wcin != cin || wkh != kh || wkw != kw {
        bail!("conv weight {:?} mismatch with input {:?}", w.shape(), x.shape());
    }
    let ho = (h + 2 * ph - kh) / sh + 1;
    let wo = (wdim + 2 * pw - kw) / sw + 1;
    if out.shape() != [n, cout, ho, wo] {
        bail!("conv output buffer {:?} != [{n}, {cout}, {ho}, {wo}]", out.shape());
    }
    let xs = x.data();
    let ws = w.data();
    let od = out.data_mut();
    for b in 0..n {
        for oc in 0..cout {
            let bias_v = bias.map(|t| t.data()[oc]).unwrap_or(0.0);
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut acc = 0.0f32;
                    for ic in 0..cin {
                        for dy in 0..kh {
                            let iy = oy * sh + dy;
                            if iy < ph || iy >= h + ph {
                                continue;
                            }
                            let iy = iy - ph;
                            for dx in 0..kw {
                                let ix = ox * sw + dx;
                                if ix < pw || ix >= wdim + pw {
                                    continue;
                                }
                                let ix = ix - pw;
                                let xv = xs[((b * cin + ic) * h + iy) * wdim + ix];
                                let wv = ws[((oc * cin + ic) * kh + dy) * kw + dx];
                                acc += xv * wv;
                            }
                        }
                    }
                    od[((b * cout + oc) * ho + oy) * wo + ox] = acc + bias_v;
                }
            }
        }
    }
    Ok(())
}

// ------------------------------------------------------- MultiThreshold

/// FINN MultiThreshold, applied in place: `q[c] = #{k : x >= T[c, k]}`,
/// then `y = out_scale * q + out_bias`.
///
/// `layout` selects the channel axis ("NCHW" -> axis 1, "NHWC" -> last).
/// The threshold matrix is [C, K]; rows may be identical (uniform
/// quantizer) but per-channel rows are supported — the paper's
/// AbsorbTransposeIntoMultiThreshold requires re-interpreting the channel
/// axis, which is exactly this parameter (Fig. 4).
fn threshold_in_place(
    buf: &mut Tensor,
    t: &Tensor,
    layout: ChanLayout,
    out_scale: f32,
    out_bias: f32,
) -> Result<()> {
    let [c_t, k] = [t.shape()[0], t.shape()[1]];
    let chan_axis = layout.chan_axis(buf.ndim());
    let c = buf.shape()[chan_axis];
    if c_t != c && c_t != 1 {
        bail!("threshold rows {c_t} != channels {c}");
    }
    let strides = buf.strides();
    let chan_stride = strides[chan_axis];
    let chan_extent = buf.shape()[chan_axis];
    let ts = t.data();
    let xs = buf.data_mut();
    for (i, v) in xs.iter_mut().enumerate() {
        let ch = (i / chan_stride) % chan_extent;
        let row = if c_t == 1 { 0 } else { ch };
        let thresholds = &ts[row * k..(row + 1) * k];
        // Thresholds are sorted ascending: q = #{k : x >= t_k} is the
        // partition point of (t <= x).
        let q = thresholds.partition_point(|&t| t <= *v);
        *v = out_scale * q as f32 + out_bias;
    }
    Ok(())
}

// -------------------------------------------------------------- MaxPool

/// NCHW max-pool (kernel = stride, the only form the backbone uses).
fn maxpool_into(kernel: [usize; 2], inputs: &[&Tensor], out: &mut Tensor) -> Result<()> {
    let x = inputs[0];
    let [kh, kw] = kernel;
    let [n, c, h, w]: [usize; 4] = x
        .shape()
        .try_into()
        .map_err(|_| anyhow!("maxpool input must be 4-D"))?;
    let (ho, wo) = (h / kh, w / kw);
    let xs = x.data();
    let od = out.data_mut();
    for b in 0..n {
        for ch in 0..c {
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..kh {
                        for dx in 0..kw {
                            let v = xs[((b * c + ch) * h + oy * kh + dy) * w + ox * kw + dx];
                            m = m.max(v);
                        }
                    }
                    od[((b * c + ch) * ho + oy) * wo + ox] = m;
                }
            }
        }
    }
    Ok(())
}

/// NHWC 2x2/2 max-pool (the streaming HW form).
fn maxpool_nhwc_into(inputs: &[&Tensor], out: &mut Tensor) -> Result<()> {
    let x = inputs[0];
    let [n, h, w, c]: [usize; 4] = x
        .shape()
        .try_into()
        .map_err(|_| anyhow!("pool input must be 4-D"))?;
    let (ho, wo) = (h / 2, w / 2);
    let xs = x.data();
    let od = out.data_mut();
    for b in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                for ch in 0..c {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            m = m.max(
                                xs[((b * h + oy * 2 + dy) * w + ox * 2 + dx) * c + ch],
                            );
                        }
                    }
                    od[((b * ho + oy) * wo + ox) * c + ch] = m;
                }
            }
        }
    }
    Ok(())
}

// ----------------------------------------------------------- ReduceMean

fn reduce_mean_into(axes: &[usize], inputs: &[&Tensor], out: &mut Tensor) -> Result<()> {
    let x = inputs[0];
    let shape = x.shape().to_vec();
    let reduce_count: usize = axes.iter().map(|&a| shape[a]).product();
    let strides = x.strides();
    let xs = x.data();
    // Iterate all elements, accumulate into the output slot.
    let kept: Vec<usize> = (0..shape.len()).filter(|i| !axes.contains(i)).collect();
    let out_strides = crate::tensor::strides_of(
        &kept.iter().map(|&i| shape[i]).collect::<Vec<_>>(),
    );
    let od = out.data_mut();
    od.fill(0.0);
    for (lin, &v) in xs.iter().enumerate() {
        let mut off = 0;
        for (j, &axis) in kept.iter().enumerate() {
            let idx = (lin / strides[axis]) % shape[axis];
            off += idx * out_strides[j];
        }
        od[off] += v;
    }
    for v in od.iter_mut() {
        *v /= reduce_count as f32;
    }
    Ok(())
}

// --------------------------------------------------------------- Im2Col

/// NHWC im2col (the SWG's functional semantics): [N,H,W,C] ->
/// [N, Ho, Wo, kh*kw*C], patch-major (dy, dx, c) — matching
/// python/compile/kernels/ref.py::im2col_ref.
fn im2col_into(
    kernel: [usize; 2],
    stride: [usize; 2],
    pad: [usize; 2],
    inputs: &[&Tensor],
    out: &mut Tensor,
) -> Result<()> {
    let x = inputs[0];
    let [kh, kw] = kernel;
    let [sh, sw] = stride;
    let [ph, pw] = pad;
    let [n, h, w, c]: [usize; 4] = x
        .shape()
        .try_into()
        .map_err(|_| anyhow!("im2col input must be 4-D"))?;
    let ho = (h + 2 * ph - kh) / sh + 1;
    let wo = (w + 2 * pw - kw) / sw + 1;
    let k = kh * kw * c;
    let xs = x.data();
    let od = out.data_mut();
    for b in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let base = ((b * ho + oy) * wo + ox) * k;
                let mut slot = 0;
                for dy in 0..kh {
                    for dx in 0..kw {
                        let iy = oy * sh + dy;
                        let ix = ox * sw + dx;
                        for ch in 0..c {
                            let v = if iy < ph || iy >= h + ph || ix < pw || ix >= w + pw {
                                0.0
                            } else {
                                xs[((b * h + (iy - ph)) * w + (ix - pw)) * c + ch]
                            };
                            od[base + slot] = v;
                            slot += 1;
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

// --------------------------------------------------------------- MatMul

/// Batched-free matmul over the last axis: [..., K] x [K, N] -> [..., N].
fn matmul_into(x: &Tensor, w: &Tensor, out: &mut Tensor) -> Result<()> {
    let k = *x.shape().last().ok_or_else(|| anyhow!("matmul on scalar"))?;
    let [wk, n]: [usize; 2] = w
        .shape()
        .try_into()
        .map_err(|_| anyhow!("matmul weight must be 2-D"))?;
    if wk != k {
        bail!("matmul inner dim {k} != weight rows {wk}");
    }
    let rows: usize = x.shape()[..x.ndim() - 1].iter().product();
    if out.numel() != rows * n {
        bail!("matmul output buffer {:?} != {rows}x{n}", out.shape());
    }
    let xs = x.data();
    let ws = w.data();
    let od = out.data_mut();
    od.fill(0.0);
    for r in 0..rows {
        let xrow = &xs[r * k..(r + 1) * k];
        let orow = &mut od[r * n..(r + 1) * n];
        for (kk, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &ws[kk * n..(kk + 1) * n];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
    Ok(())
}

// -------------------------------------------------------- GlobalAccPool

/// FINN GlobalAccPool: NHWC -> [N, C] cumulative SUM over spatial dims
/// (no division — the following Mul applies 1/HW, §III-D).
fn global_acc_pool_into(inputs: &[&Tensor], out: &mut Tensor) -> Result<()> {
    let x = inputs[0];
    let [n, h, w, c]: [usize; 4] = x
        .shape()
        .try_into()
        .map_err(|_| anyhow!("gap input must be 4-D"))?;
    let xs = x.data();
    let od = out.data_mut();
    od.fill(0.0);
    for b in 0..n {
        for y in 0..h {
            for xcol in 0..w {
                for ch in 0..c {
                    od[b * c + ch] += xs[((b * h + y) * w + xcol) * c + ch];
                }
            }
        }
    }
    Ok(())
}

// ----------------------------------------------------------------- MVAU

/// Matrix-Vector-Activation Unit: MatMul + bias + optional MultiThreshold,
/// fused into the output buffer (matmul writes `out`, bias and the
/// threshold stage then mutate it in place — no intermediates).
///
/// inputs: [x(..., K), w(K, N), bias(N), thresholds(C_or_1, T)?]
/// spec:   out_scale / out_bias for the threshold stage; `apply_act`.
fn mvau_into(
    apply_act: bool,
    out_scale: f32,
    out_bias: f32,
    inputs: &[&Tensor],
    out: &mut Tensor,
) -> Result<()> {
    matmul_into(inputs[0], inputs[1], out)?;
    let bias = inputs[2];
    out.broadcast_assign(bias, |a, b| a + b)?;
    if !apply_act {
        return Ok(());
    }
    let thresholds = inputs
        .get(3)
        .ok_or_else(|| anyhow!("MVAU with apply_act needs thresholds input"))?;
    // The fused activation always sees the NHWC stream layout.
    threshold_in_place(out, thresholds, ChanLayout::Nhwc, out_scale, out_bias)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{AttrVal, Attrs};

    fn node(op: &str, attrs: Attrs) -> Node {
        Node::new(op, "t", vec![], vec![]).with_attrs(attrs)
    }

    /// Run one node through the compat path (infer + into) and pop the
    /// single output.
    fn run1(n: &Node, inputs: &[&Tensor]) -> Tensor {
        execute_node(n, inputs).unwrap().pop().unwrap()
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 kernel with identity weights passes channels through.
        let x = Tensor::from_fn(vec![1, 2, 3, 3], |i| i as f32);
        let mut w = Tensor::zeros(vec![2, 2, 1, 1]);
        w.set(&[0, 0, 0, 0], 1.0);
        w.set(&[1, 1, 0, 0], 1.0);
        let attrs = Attrs::new()
            .with("kernel", AttrVal::Ints(vec![1, 1]))
            .with("stride", AttrVal::Ints(vec![1, 1]))
            .with("pad", AttrVal::Ints(vec![0, 0]));
        let y = run1(&node("Conv", attrs), &[&x, &w]);
        assert_eq!(y, x);
    }

    #[test]
    fn conv_3x3_known_values() {
        // All-ones 3x3 kernel over constant image = 9 in interior, less on
        // border (zero pad).
        let x = Tensor::full(vec![1, 1, 4, 4], 1.0);
        let w = Tensor::full(vec![1, 1, 3, 3], 1.0);
        let attrs = Attrs::new()
            .with("kernel", AttrVal::Ints(vec![3, 3]))
            .with("stride", AttrVal::Ints(vec![1, 1]))
            .with("pad", AttrVal::Ints(vec![1, 1]));
        let y = run1(&node("Conv", attrs), &[&x, &w]);
        assert_eq!(y.at(&[0, 0, 1, 1]), 9.0);
        assert_eq!(y.at(&[0, 0, 0, 0]), 4.0);
        assert_eq!(y.at(&[0, 0, 0, 1]), 6.0);
    }

    #[test]
    fn conv_bias_added() {
        let x = Tensor::zeros(vec![1, 1, 2, 2]);
        let w = Tensor::zeros(vec![3, 1, 1, 1]);
        let b = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let attrs = Attrs::new()
            .with("kernel", AttrVal::Ints(vec![1, 1]))
            .with("stride", AttrVal::Ints(vec![1, 1]))
            .with("pad", AttrVal::Ints(vec![0, 0]));
        let y = run1(&node("Conv", attrs), &[&x, &w, &b]);
        assert_eq!(y.at(&[0, 0, 0, 0]), 1.0);
        assert_eq!(y.at(&[0, 2, 1, 1]), 3.0);
    }

    #[test]
    fn multithreshold_counts_thresholds() {
        // thresholds [0.5, 1.5, 2.5]: x=2.0 -> 2 crossings.
        let x = Tensor::new(vec![1, 1, 1, 3], vec![-1.0, 2.0, 9.0]).unwrap();
        let t = Tensor::new(vec![1, 3], vec![0.5, 1.5, 2.5]).unwrap();
        let attrs = Attrs::new().with("data_layout", AttrVal::Str("NCHW".into()));
        let y = run1(&node("MultiThreshold", attrs), &[&x, &t]);
        assert_eq!(y.data(), &[0.0, 2.0, 3.0]);
    }

    #[test]
    fn multithreshold_x_equal_threshold_counts() {
        // FINN: q = #{k : x >= t_k}, so equality crosses.
        let x = Tensor::new(vec![1, 1], vec![1.5]).unwrap();
        let t = Tensor::new(vec![1, 3], vec![0.5, 1.5, 2.5]).unwrap();
        let attrs = Attrs::new().with("data_layout", AttrVal::Str("NC".into()));
        let y = run1(&node("MultiThreshold", attrs), &[&x, &t]);
        assert_eq!(y.data(), &[2.0]);
    }

    #[test]
    fn multithreshold_per_channel_rows_nchw_vs_nhwc() {
        // Channel 0 thresholds at 0.5; channel 1 at 5.0.
        let t = Tensor::new(vec![2, 1], vec![0.5, 5.0]).unwrap();
        let x_nchw = Tensor::new(vec![1, 2, 1, 2], vec![1.0, 1.0, 1.0, 6.0]).unwrap();
        let attrs = Attrs::new().with("data_layout", AttrVal::Str("NCHW".into()));
        let y = run1(&node("MultiThreshold", attrs), &[&x_nchw, &t]);
        assert_eq!(y.data(), &[1.0, 1.0, 0.0, 1.0]);
        // Same data in NHWC must give the transposed result.
        let x_nhwc = x_nchw.nchw_to_nhwc().unwrap();
        let attrs = Attrs::new().with("data_layout", AttrVal::Str("NHWC".into()));
        let y2 = run1(&node("MultiThreshold", attrs), &[&x_nhwc, &t]);
        assert_eq!(y2, y.nchw_to_nhwc().unwrap());
    }

    #[test]
    fn multithreshold_out_scale_bias() {
        let x = Tensor::new(vec![1, 1], vec![2.0]).unwrap();
        let t = Tensor::new(vec![1, 3], vec![0.5, 1.5, 2.5]).unwrap();
        let attrs = Attrs::new()
            .with("data_layout", AttrVal::Str("NC".into()))
            .with("out_scale", AttrVal::Float(0.25))
            .with("out_bias", AttrVal::Float(-1.0));
        let y = run1(&node("MultiThreshold", attrs), &[&x, &t]);
        assert_eq!(y.data(), &[0.25 * 2.0 - 1.0]);
    }

    #[test]
    fn maxpool_2x2() {
        let x = Tensor::new(
            vec![1, 1, 2, 4],
            vec![1., 2., 3., 4., 5., 6., 7., 8.],
        )
        .unwrap();
        let attrs = Attrs::new()
            .with("kernel", AttrVal::Ints(vec![2, 2]))
            .with("stride", AttrVal::Ints(vec![2, 2]));
        let y = run1(&node("MaxPool", attrs), &[&x]);
        assert_eq!(y.shape(), &[1, 1, 1, 2]);
        assert_eq!(y.data(), &[6.0, 8.0]);
    }

    #[test]
    fn maxpool_nhwc_matches_nchw() {
        let x = Tensor::from_fn(vec![1, 2, 4, 4], |i| ((i * 7919) % 13) as f32);
        let attrs = Attrs::new()
            .with("kernel", AttrVal::Ints(vec![2, 2]))
            .with("stride", AttrVal::Ints(vec![2, 2]));
        let want = run1(&node("MaxPool", attrs), &[&x]);
        let got = run1(
            &node("MaxPoolNHWC", Attrs::new()),
            &[&x.nchw_to_nhwc().unwrap()],
        );
        assert_eq!(got.nhwc_to_nchw().unwrap(), want);
    }

    #[test]
    fn reduce_mean_spatial() {
        let x = Tensor::from_fn(vec![1, 2, 2, 2], |i| i as f32);
        let attrs = Attrs::new()
            .with("axes", AttrVal::Ints(vec![2, 3]))
            .with("keepdims", AttrVal::Int(0));
        let y = run1(&node("ReduceMean", attrs), &[&x]);
        assert_eq!(y.shape(), &[1, 2]);
        assert_eq!(y.data(), &[1.5, 5.5]);
    }

    #[test]
    fn im2col_center_patch() {
        let x = Tensor::from_fn(vec![1, 4, 4, 1], |i| i as f32);
        let attrs = Attrs::new()
            .with("kernel", AttrVal::Ints(vec![3, 3]))
            .with("stride", AttrVal::Ints(vec![1, 1]))
            .with("pad", AttrVal::Ints(vec![1, 1]));
        let y = run1(&node("Im2Col", attrs), &[&x]);
        assert_eq!(y.shape(), &[1, 4, 4, 9]);
        // Patch at (1,1) = rows 0..3 x cols 0..3 of the image.
        let patch: Vec<f32> = (0..9).map(|i| y.at(&[0, 1, 1, i])).collect();
        assert_eq!(patch, vec![0., 1., 2., 4., 5., 6., 8., 9., 10.]);
    }

    #[test]
    fn im2col_matmul_equals_conv() {
        // The lowering identity: conv(NCHW) == transpose . im2col . matmul.
        let mut rng = crate::rng::Rng::new(77);
        let x_nchw = Tensor::from_fn(vec![1, 3, 6, 6], |_| rng.normal());
        let w_oihw = Tensor::from_fn(vec![4, 3, 3, 3], |_| rng.normal());
        let conv_attrs = Attrs::new()
            .with("kernel", AttrVal::Ints(vec![3, 3]))
            .with("stride", AttrVal::Ints(vec![1, 1]))
            .with("pad", AttrVal::Ints(vec![1, 1]));
        let want = run1(&node("Conv", conv_attrs.clone()), &[&x_nchw, &w_oihw]);

        let x_nhwc = x_nchw.nchw_to_nhwc().unwrap();
        let cols = run1(&node("Im2Col", conv_attrs), &[&x_nhwc]);
        // OIHW -> (dy, dx, cin)-major K x O matrix = transpose to HWIO then
        // reshape.
        let w_k_o = w_oihw.transpose(&[2, 3, 1, 0]).unwrap().reshape(vec![27, 4]).unwrap();
        let got_nhwc = run1(&node("MatMul", Attrs::new()), &[&cols, &w_k_o]);
        let got = got_nhwc.nhwc_to_nchw().unwrap();
        assert!(got.allclose(&want, 1e-4), "max diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn global_acc_pool_sums() {
        let x = Tensor::full(vec![1, 2, 2, 3], 1.5);
        let y = run1(&node("GlobalAccPool", Attrs::new()), &[&x]);
        assert_eq!(y.shape(), &[1, 3]);
        assert_eq!(y.data(), &[6.0, 6.0, 6.0]);
    }

    #[test]
    fn mvau_with_thresholds() {
        let x = Tensor::new(vec![1, 2], vec![1.0, 1.0]).unwrap();
        let w = Tensor::new(vec![2, 1], vec![1.0, 1.0]).unwrap();
        let b = Tensor::new(vec![1], vec![0.5]).unwrap();
        let t = Tensor::new(vec![1, 4], vec![0.5, 1.0, 2.0, 3.0]).unwrap();
        let attrs = Attrs::new()
            .with("apply_act", AttrVal::Int(1))
            .with("out_scale", AttrVal::Float(0.5));
        let y = run1(&node("MVAU", attrs), &[&x, &w, &b, &t]);
        // acc = 2.5 -> crosses 0.5, 1.0, 2.0 -> q=3 -> 1.5 after scale.
        assert_eq!(y.data(), &[1.5]);
    }

    #[test]
    fn mvau_no_act_is_affine() {
        let x = Tensor::new(vec![1, 2], vec![2.0, 3.0]).unwrap();
        let w = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let b = Tensor::new(vec![2], vec![10.0, 20.0]).unwrap();
        let attrs = Attrs::new().with("apply_act", AttrVal::Int(0));
        let y = run1(&node("MVAU", attrs), &[&x, &w, &b]);
        assert_eq!(y.data(), &[12.0, 23.0]);
    }

    #[test]
    fn inplace_matches_into_for_elementwise() {
        let mut rng = crate::rng::Rng::new(10);
        let a = Tensor::from_fn(vec![1, 3, 4, 4], |_| rng.normal());
        let s = Tensor::scalar(0.5);
        for op in ["Mul", "Add", "ChannelwiseMul", "AddStreams"] {
            assert!(supports_inplace(op));
            let n = node(op, Attrs::new());
            let want = run1(&n, &[&a, &s]);
            let mut buf = a.clone();
            execute_node_inplace(&n, &mut buf, &[&s]).unwrap();
            assert_eq!(buf, want, "op {op}");
        }
        // Threshold in place.
        let t = Tensor::new(vec![1, 2], vec![0.0, 0.5]).unwrap();
        let n = node(
            "MultiThreshold",
            Attrs::new().with("data_layout", AttrVal::Str("NCHW".into())),
        );
        let want = run1(&n, &[&a, &t]);
        let mut buf = a.clone();
        execute_node_inplace(&n, &mut buf, &[&t]).unwrap();
        assert_eq!(buf, want);
        // Reshape in place is metadata-only.
        let n = node("Reshape", Attrs::new().with("shape", AttrVal::Ints(vec![3, 16])));
        let want = run1(&n, &[&a]);
        let mut buf = a.clone();
        execute_node_inplace(&n, &mut buf, &[]).unwrap();
        assert_eq!(buf, want);
    }

    #[test]
    fn spec_resolution_catches_bad_attrs_up_front() {
        // Conv without kernel/stride/pad attrs: the error now surfaces at
        // spec resolution (plan compile time), not mid-execution.
        let n = node("Conv", Attrs::new());
        let err = OpSpec::resolve(&n).unwrap_err().to_string();
        assert!(err.contains("kernel"), "{err}");
        // Bad data_layout likewise fails at resolve.
        let n = node(
            "MultiThreshold",
            Attrs::new().with("data_layout", AttrVal::Str("XYZW".into())),
        );
        let err = OpSpec::resolve(&n).unwrap_err().to_string();
        assert!(err.contains("data_layout"), "{err}");
        assert!(OpSpec::resolve(&node("NoSuchOp", Attrs::new())).is_err());
    }

    #[test]
    fn spec_executors_match_node_executors() {
        let mut rng = crate::rng::Rng::new(21);
        let x = Tensor::from_fn(vec![1, 3, 6, 6], |_| rng.normal());
        let w = Tensor::from_fn(vec![4, 3, 3, 3], |_| rng.normal());
        let attrs = Attrs::new()
            .with("kernel", AttrVal::Ints(vec![3, 3]))
            .with("stride", AttrVal::Ints(vec![1, 1]))
            .with("pad", AttrVal::Ints(vec![1, 1]));
        let n = node("Conv", attrs);
        let spec = OpSpec::resolve(&n).unwrap();
        assert_eq!(
            spec,
            OpSpec::Conv { kernel: [3, 3], stride: [1, 1], pad: [1, 1] }
        );
        let want = run1(&n, &[&x, &w]);
        let mut got = Tensor::zeros(want.shape().to_vec());
        execute_spec_into(&spec, &[&x, &w], &mut got).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn infer_shapes_match_execution() {
        let mut rng = crate::rng::Rng::new(11);
        let x = Tensor::from_fn(vec![1, 3, 6, 6], |_| rng.normal());
        let w = Tensor::from_fn(vec![4, 3, 3, 3], |_| rng.normal());
        let attrs = Attrs::new()
            .with("kernel", AttrVal::Ints(vec![3, 3]))
            .with("stride", AttrVal::Ints(vec![1, 1]))
            .with("pad", AttrVal::Ints(vec![1, 1]));
        let n = node("Conv", attrs);
        let inferred = infer_output_shape(&n, &[x.shape(), w.shape()]).unwrap();
        let y = run1(&n, &[&x, &w]);
        assert_eq!(y.shape(), inferred.as_slice());
    }

    #[test]
    fn execute_full_graph_plumbing() {
        use crate::graph::Graph;
        let mut g = Graph::new("tiny");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["y".into()];
        g.shapes.insert("x".into(), vec![1, 2]);
        g.shapes.insert("s".into(), vec![]);
        g.shapes.insert("y".into(), vec![1, 2]);
        g.initializers.insert("s".into(), Tensor::scalar(3.0));
        g.nodes.push(Node::new("Mul", "m", vec!["x".into(), "s".into()], vec!["y".into()]));
        let mut feeds = HashMap::new();
        feeds.insert("x".to_string(), Tensor::new(vec![1, 2], vec![1.0, 2.0]).unwrap());
        let out = execute(&g, &feeds).unwrap();
        assert_eq!(out["y"].data(), &[3.0, 6.0]);
        // The legacy interpreter agrees bit for bit.
        let legacy = execute_interpreted(&g, &feeds).unwrap();
        assert_eq!(legacy["y"], out["y"]);
    }

    #[test]
    fn execute_missing_feed_errors() {
        use crate::graph::Graph;
        let mut g = Graph::new("tiny");
        g.inputs = vec!["x".into()];
        let feeds = HashMap::new();
        assert!(execute(&g, &feeds).is_err());
        assert!(execute_interpreted(&g, &feeds).is_err());
    }

    // ------------------------------------------------- integer kernels

    /// Grid tensor + its code twin at the given frac.
    fn grid_pair(shape: Vec<usize>, frac: i32, seed: u64, signed: bool) -> (Tensor, Tensor) {
        let mut rng = crate::rng::Rng::new(seed);
        let span = 1i64 << 6;
        let codes: Vec<i32> = (0..shape.iter().product::<usize>())
            .map(|_| {
                let c = rng.below(span as usize) as i64 - if signed { span / 2 } else { 0 };
                c as i32
            })
            .collect();
        let scale = (2.0f64).powi(frac);
        let floats: Vec<f32> = codes.iter().map(|&c| (c as f64 / scale) as f32).collect();
        (
            Tensor::new(shape.clone(), floats).unwrap(),
            Tensor::new_i32(shape, codes).unwrap(),
        )
    }

    #[test]
    fn matmul_i32_matches_f32_on_grid() {
        let (xf, xi) = grid_pair(vec![4, 6], 2, 31, false);
        let (wf, wi) = grid_pair(vec![6, 3], 3, 32, true);
        let mut want = Tensor::zeros(vec![4, 3]);
        matmul_into(&xf, &wf, &mut want).unwrap();
        let mut got = Tensor::zeros_i32(vec![4, 3]);
        matmul_i32_into(&xi, &wi, &mut got).unwrap();
        let scale = (2.0f64).powi(5); // 2 + 3 frac bits
        for (c, v) in got.data_i32().iter().zip(want.data()) {
            assert_eq!((*c as f64 / scale) as f32, *v);
        }
    }

    #[test]
    fn threshold_i32_matches_float_threshold_on_grid() {
        let frac = 3;
        let (xf, xi) = grid_pair(vec![1, 2, 2, 4], frac, 33, true);
        // Arbitrary ascending float thresholds, one row per channel.
        let tf = Tensor::new(
            vec![4, 3],
            vec![
                -0.3, 0.1, 0.7, -1.0, 0.0, 0.9, -0.55, 0.2, 1.3, -0.05, 0.4, 2.0,
            ],
        )
        .unwrap();
        let spec = OpSpec::Threshold {
            layout: ChanLayout::Nhwc,
            out_scale: 1.0,
            out_bias: 0.0,
        };
        let mut want = Tensor::zeros(vec![1, 2, 2, 4]);
        execute_spec_into(&spec, &[&xf, &tf], &mut want).unwrap();
        // Integer thresholds via the ceil rule.
        let scale = (2.0f64).powi(frac);
        let tc: Vec<i32> = tf
            .data()
            .iter()
            .map(|&t| (t as f64 * scale).ceil() as i32)
            .collect();
        let ti = Tensor::new_i32(vec![4, 3], tc).unwrap();
        let ispec = IntOpSpec::Threshold {
            layout: ChanLayout::Nhwc,
            out_mul: 1,
            out_add: 0,
        };
        let mut got = Tensor::zeros_i32(vec![1, 2, 2, 4]);
        execute_int_spec_into(&ispec, &[&xi, &ti], &mut got).unwrap();
        for (c, v) in got.data_i32().iter().zip(want.data()) {
            assert_eq!(*c as f32, *v);
        }
    }

    #[test]
    fn quantize_threshold_matches_float_multithreshold() {
        let mut rng = crate::rng::Rng::new(34);
        let x = Tensor::from_fn(vec![1, 3, 3, 2], |_| rng.next_f32() * 4.0 - 1.0);
        let t = Tensor::new(vec![1, 3], vec![0.25, 0.75, 1.25]).unwrap();
        let spec = OpSpec::Threshold {
            layout: ChanLayout::Nhwc,
            out_scale: 1.0,
            out_bias: 0.0,
        };
        let mut want = Tensor::zeros(vec![1, 3, 3, 2]);
        execute_spec_into(&spec, &[&x, &t], &mut want).unwrap();
        let ispec = IntOpSpec::QuantizeThreshold {
            layout: ChanLayout::Nhwc,
            out_mul: 1,
            out_add: 0,
        };
        let mut got = Tensor::zeros_i32(vec![1, 3, 3, 2]);
        execute_int_spec_into(&ispec, &[&x, &t], &mut got).unwrap();
        for (c, v) in got.data_i32().iter().zip(want.data()) {
            assert_eq!(*c as f32, *v);
        }
    }

    #[test]
    fn mvau_i32_matches_f32_mvau_on_grid() {
        let (xf, xi) = grid_pair(vec![5, 4], 2, 35, false);
        let (wf, wi) = grid_pair(vec![4, 3], 3, 36, true);
        // Bias on the accumulator grid (frac 5), thresholds arbitrary.
        let (bf, bi) = grid_pair(vec![3], 5, 37, true);
        let tf = Tensor::new(vec![1, 3], vec![-0.5, 0.5, 1.5]).unwrap();
        let tc: Vec<i32> = tf
            .data()
            .iter()
            .map(|&t| (t as f64 * 32.0).ceil() as i32)
            .collect();
        let ti = Tensor::new_i32(vec![1, 3], tc).unwrap();

        let fspec = OpSpec::Mvau {
            apply_act: true,
            out_scale: 0.25,
            out_bias: 0.0,
        };
        let mut want = Tensor::zeros(vec![5, 3]);
        execute_spec_into(&fspec, &[&xf, &wf, &bf, &tf], &mut want).unwrap();

        // out_scale 0.25 = 1 * 2^-2: codes at frac 2 are exactly q.
        let ispec = IntOpSpec::Mvau {
            apply_act: true,
            out_mul: 1,
            out_add: 0,
        };
        let mut got = Tensor::zeros_i32(vec![5, 3]);
        execute_int_spec_into(&ispec, &[&xi, &wi, &bi, &ti], &mut got).unwrap();
        for (c, v) in got.data_i32().iter().zip(want.data()) {
            assert_eq!((*c as f64 / 4.0) as f32, *v);
        }
    }

    #[test]
    fn add_streams_aligns_fracs_by_shifting() {
        // a at frac 2 (codes x4), b at frac 5 (codes x32): align a by 3.
        let a = Tensor::new_i32(vec![4], vec![1, -2, 3, 0]).unwrap();
        let b = Tensor::new_i32(vec![4], vec![8, 8, -16, 40]).unwrap();
        let spec = IntOpSpec::AddStreams { shift: [3, 0] };
        let mut out = Tensor::zeros_i32(vec![4]);
        execute_int_spec_into(&spec, &[&a, &b], &mut out).unwrap();
        assert_eq!(out.data_i32(), &[16, -8, 8, 40]);
    }

    #[test]
    fn mul_scalar_and_gap_i32() {
        let x = Tensor::new_i32(vec![1, 2, 2, 2], vec![1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        let mut gap = Tensor::zeros_i32(vec![1, 2]);
        execute_int_spec_into(&IntOpSpec::GlobalAccPool, &[&x], &mut gap).unwrap();
        assert_eq!(gap.data_i32(), &[16, 20]); // odd/even channel sums
        let mut scaled = Tensor::zeros_i32(vec![1, 2]);
        execute_int_spec_into(
            &IntOpSpec::MulScalar { m: 3, data_input: 0 },
            &[&gap],
            &mut scaled,
        )
        .unwrap();
        assert_eq!(scaled.data_i32(), &[48, 60]);
    }

    #[test]
    fn int_kernels_reject_overflow() {
        let x = Tensor::new_i32(vec![1, 2], vec![1 << 20, 1 << 20]).unwrap();
        let w = Tensor::new_i32(vec![2, 1], vec![1 << 20, 1 << 20]).unwrap();
        let mut out = Tensor::zeros_i32(vec![1, 1]);
        let err = matmul_i32_into(&x, &w, &mut out).unwrap_err().to_string();
        assert!(err.contains("overflows the i32 datapath"), "{err}");
        let big = Tensor::new_i32(vec![1], vec![i32::MAX]).unwrap();
        let mut o = Tensor::zeros_i32(vec![1]);
        assert!(
            execute_int_spec_into(&IntOpSpec::MulScalar { m: 3, data_input: 0 }, &[&big], &mut o)
                .is_err()
        );
    }

    #[test]
    fn im2col_and_maxpool_i32_match_f32_on_codes() {
        let (xf, xi) = grid_pair(vec![1, 4, 4, 2], 0, 38, false);
        let attrs = Attrs::new()
            .with("kernel", AttrVal::Ints(vec![3, 3]))
            .with("stride", AttrVal::Ints(vec![1, 1]))
            .with("pad", AttrVal::Ints(vec![1, 1]));
        let want = run1(&node("Im2Col", attrs), &[&xf]);
        let spec = IntOpSpec::Im2Col {
            kernel: [3, 3],
            stride: [1, 1],
            pad: [1, 1],
        };
        let mut got = Tensor::zeros_i32(vec![1, 4, 4, 18]);
        execute_int_spec_into(&spec, &[&xi], &mut got).unwrap();
        for (c, v) in got.data_i32().iter().zip(want.data()) {
            assert_eq!(*c as f32, *v);
        }
        let want = run1(&node("MaxPoolNHWC", Attrs::new()), &[&xf]);
        let mut got = Tensor::zeros_i32(vec![1, 2, 2, 2]);
        execute_int_spec_into(&IntOpSpec::MaxPoolNhwc, &[&xi], &mut got).unwrap();
        for (c, v) in got.data_i32().iter().zip(want.data()) {
            assert_eq!(*c as f32, *v);
        }
    }

    // ------------------------------------------------- packed containers

    /// The same codes in an i8 tensor and an i32 tensor — the packed
    /// kernels must be bitwise-equivalent to the wide oracle.
    fn i8_i32_pair(shape: Vec<usize>, seed: u64, signed: bool) -> (Tensor, Tensor) {
        let mut rng = crate::rng::Rng::new(seed);
        let codes8: Vec<i8> = (0..shape.iter().product::<usize>())
            .map(|_| {
                let c = rng.below(64) as i64 - if signed { 32 } else { 0 };
                c as i8
            })
            .collect();
        let codes32: Vec<i32> = codes8.iter().map(|&c| c as i32).collect();
        (
            Tensor::new_i8(shape.clone(), codes8).unwrap(),
            Tensor::new_i32(shape, codes32).unwrap(),
        )
    }

    #[test]
    fn packed_threshold_matches_i32_oracle_across_containers() {
        let (x8, x32) = i8_i32_pair(vec![1, 2, 3, 4], 50, true);
        let x16 = Tensor::new_i16(
            vec![1, 2, 3, 4],
            x32.data_i32().iter().map(|&c| c as i16).collect(),
        )
        .unwrap();
        let t32 = Tensor::new_i32(vec![1, 3], vec![-5, 0, 9]).unwrap();
        let t8 = Tensor::new_i8(vec![1, 3], vec![-5, 0, 9]).unwrap();
        let spec = IntOpSpec::Threshold {
            layout: ChanLayout::Nhwc,
            out_mul: 3,
            out_add: -1,
        };
        let mut want = Tensor::zeros_i32(vec![1, 2, 3, 4]);
        execute_int_spec_into(&spec, &[&x32, &t32], &mut want).unwrap();
        // Every (input, matrix, output) container combination agrees.
        for xin in [&x8, &x16, &x32] {
            for tin in [&t8, &t32] {
                let mut got8 = Tensor::zeros_typed(vec![1, 2, 3, 4], DType::I8);
                execute_int_spec_into(&spec, &[xin, tin], &mut got8).unwrap();
                assert_eq!(got8.codes_i32(), want.codes_i32());
            }
        }
    }

    #[test]
    fn packed_mvau_matches_i32_oracle_and_crosses_column_blocks() {
        // n = 300 > MVAU_BLOCK_N exercises the block seam; u4-ish acts and
        // s6-ish weights take the i8 x i8 -> i32-accumulate fast path.
        let (rows, k, n) = (4usize, 7usize, 300usize);
        let mut rng = crate::rng::Rng::new(51);
        let x8: Vec<i8> = (0..rows * k).map(|_| rng.below(16) as i8).collect();
        let w8: Vec<i8> = (0..k * n).map(|_| rng.below(64) as i8 - 32).collect();
        let bias: Vec<i32> = (0..n).map(|_| rng.below(100) as i32 - 50).collect();
        let xi8 = Tensor::new_i8(vec![rows, k], x8.clone()).unwrap();
        let wi8 = Tensor::new_i8(vec![k, n], w8.clone()).unwrap();
        let xi32 =
            Tensor::new_i32(vec![rows, k], x8.iter().map(|&c| c as i32).collect()).unwrap();
        let wi32 = Tensor::new_i32(vec![k, n], w8.iter().map(|&c| c as i32).collect()).unwrap();
        let bt = Tensor::new_i32(vec![n], bias.clone()).unwrap();
        let tt = Tensor::new_i32(vec![1, 7], vec![-90, -40, -10, 0, 15, 60, 200]).unwrap();

        let spec = IntOpSpec::Mvau {
            apply_act: true,
            out_mul: 1,
            out_add: 0,
        };
        let mut want = Tensor::zeros_i32(vec![rows, n]);
        execute_int_spec_into(&spec, &[&xi32, &wi32, &bt, &tt], &mut want).unwrap();
        let mut got = Tensor::zeros_typed(vec![rows, n], DType::I8);
        execute_int_spec_into(&spec, &[&xi8, &wi8, &bt, &tt], &mut got).unwrap();
        assert_eq!(got.codes_i32(), want.codes_i32());

        // Raw (no-act) MVAU: wide accumulator output.
        let spec = IntOpSpec::Mvau {
            apply_act: false,
            out_mul: 1,
            out_add: 0,
        };
        let mut want = Tensor::zeros_i32(vec![rows, n]);
        execute_int_spec_into(&spec, &[&xi32, &wi32, &bt], &mut want).unwrap();
        let mut got = Tensor::zeros_i32(vec![rows, n]);
        execute_int_spec_into(&spec, &[&xi8, &wi8, &bt], &mut got).unwrap();
        assert_eq!(got.data_i32(), want.data_i32());
        // And it matches the plain matmul oracle + bias by hand.
        let mut mm = Tensor::zeros_i32(vec![rows, n]);
        matmul_i32_into(&xi32, &wi32, &mut mm).unwrap();
        for (i, (&v, &m)) in want.data_i32().iter().zip(mm.data_i32()).enumerate() {
            assert_eq!(v, m + bias[i % n]);
        }
    }

    #[test]
    fn packed_addstreams_and_mulscalar_mix_containers() {
        let (a8, a32) = i8_i32_pair(vec![6], 52, true);
        let b16 = Tensor::new_i16(vec![6], vec![100, -200, 300, -400, 500, -600]).unwrap();
        let b32 = Tensor::new_i32(vec![6], b16.codes_i32()).unwrap();
        let spec = IntOpSpec::AddStreams { shift: [4, 0] };
        let mut want = Tensor::zeros_i32(vec![6]);
        execute_int_spec_into(&spec, &[&a32, &b32], &mut want).unwrap();
        let mut got = Tensor::zeros_typed(vec![6], DType::I16);
        execute_int_spec_into(&spec, &[&a8, &b16], &mut got).unwrap();
        assert_eq!(got.codes_i32(), want.codes_i32());

        // MulScalar widening: i8 codes x 100 land in an i16 container.
        let spec = IntOpSpec::MulScalar {
            m: 100,
            data_input: 0,
        };
        let mut wide = Tensor::zeros_typed(vec![6], DType::I16);
        execute_int_spec_into(&spec, &[&a8], &mut wide).unwrap();
        let mut oracle = Tensor::zeros_i32(vec![6]);
        execute_int_spec_into(&spec, &[&a32], &mut oracle).unwrap();
        assert_eq!(wide.codes_i32(), oracle.codes_i32());
    }

    #[test]
    fn packed_im2col_maxpool_gap_preserve_codes() {
        let (x8, x32) = i8_i32_pair(vec![1, 4, 4, 2], 53, false);
        let spec = IntOpSpec::Im2Col {
            kernel: [3, 3],
            stride: [1, 1],
            pad: [1, 1],
        };
        let mut want = Tensor::zeros_i32(vec![1, 4, 4, 18]);
        execute_int_spec_into(&spec, &[&x32], &mut want).unwrap();
        let mut got = Tensor::zeros_typed(vec![1, 4, 4, 18], DType::I8);
        execute_int_spec_into(&spec, &[&x8], &mut got).unwrap();
        assert_eq!(got.codes_i32(), want.codes_i32());
        // Container mismatch between input and output is an error, not a
        // silent cast.
        let mut bad = Tensor::zeros_i32(vec![1, 4, 4, 18]);
        assert!(execute_int_spec_into(&spec, &[&x8], &mut bad).is_err());

        let mut want = Tensor::zeros_i32(vec![1, 2, 2, 2]);
        execute_int_spec_into(&IntOpSpec::MaxPoolNhwc, &[&x32], &mut want).unwrap();
        let mut got = Tensor::zeros_typed(vec![1, 2, 2, 2], DType::I8);
        execute_int_spec_into(&IntOpSpec::MaxPoolNhwc, &[&x8], &mut got).unwrap();
        assert_eq!(got.codes_i32(), want.codes_i32());

        let mut want = Tensor::zeros_i32(vec![1, 2]);
        execute_int_spec_into(&IntOpSpec::GlobalAccPool, &[&x32], &mut want).unwrap();
        let mut got = Tensor::zeros_typed(vec![1, 2], DType::I16);
        execute_int_spec_into(&IntOpSpec::GlobalAccPool, &[&x8], &mut got).unwrap();
        assert_eq!(got.codes_i32(), want.codes_i32());
    }

    #[test]
    fn packed_container_overflow_is_an_error() {
        // Accumulator value 1000 cannot be stored as a raw i8 MVAU output.
        let x = Tensor::new_i8(vec![1, 2], vec![10, 10]).unwrap();
        let w = Tensor::new_i8(vec![2, 1], vec![50, 50]).unwrap();
        let b = Tensor::new_i32(vec![1], vec![0]).unwrap();
        let spec = IntOpSpec::Mvau {
            apply_act: false,
            out_mul: 1,
            out_add: 0,
        };
        let mut narrow_out = Tensor::zeros_typed(vec![1, 1], DType::I8);
        let err = execute_int_spec_into(&spec, &[&x, &w, &b], &mut narrow_out)
            .unwrap_err()
            .to_string();
        assert!(err.contains("overflows the I8 container"), "{err}");
        let mut wide_out = Tensor::zeros_typed(vec![1, 1], DType::I16);
        execute_int_spec_into(&spec, &[&x, &w, &b], &mut wide_out).unwrap();
        assert_eq!(wide_out.codes_i32(), vec![1000]);
    }

    // ---------------------------------------------- sub-byte containers

    /// The same codes in a packed container and an i32 tensor.
    fn packed_i32_pair(shape: Vec<usize>, dtype: DType, seed: u64) -> (Tensor, Tensor) {
        let mut rng = crate::rng::Rng::new(seed);
        let codes: Vec<i32> = (0..shape.iter().product::<usize>())
            .map(|_| match dtype {
                DType::U4 => rng.below(16) as i32,
                DType::U1 => rng.below(2) as i32,
                DType::B1 => 2 * rng.below(2) as i32 - 1,
                _ => unreachable!(),
            })
            .collect();
        (
            Tensor::from_codes_packed(shape.clone(), &codes, dtype).unwrap(),
            Tensor::new_i32(shape, codes).unwrap(),
        )
    }

    #[test]
    fn u4_mvau_matches_i32_oracle_and_crosses_column_blocks() {
        // The headline Table-II combo: u4 activations x signed i8 weights,
        // n = 300 > MVAU_BLOCK_N so the unpack tile crosses a block seam.
        let (rows, k, n) = (3usize, 11usize, 300usize);
        let (x4, x32) = packed_i32_pair(vec![rows, k], DType::U4, 60);
        let mut rng = crate::rng::Rng::new(61);
        let w8: Vec<i8> = (0..k * n).map(|_| rng.below(64) as i8 - 32).collect();
        let wi8 = Tensor::new_i8(vec![k, n], w8.clone()).unwrap();
        let wi32 = Tensor::new_i32(vec![k, n], w8.iter().map(|&c| c as i32).collect()).unwrap();
        let bias: Vec<i32> = (0..n).map(|_| rng.below(100) as i32 - 50).collect();
        let bt = Tensor::new_i32(vec![n], bias).unwrap();
        let tt = Tensor::new_i32(vec![1, 15], (0..15).map(|q| q * 30 - 220).collect()).unwrap();

        let spec = IntOpSpec::Mvau {
            apply_act: true,
            out_mul: 1,
            out_add: 0,
        };
        let mut want = Tensor::zeros_i32(vec![rows, n]);
        execute_int_spec_into(&spec, &[&x32, &wi32, &bt, &tt], &mut want).unwrap();
        // Packed acts x byte weights, output back into a u4 container.
        let mut got = Tensor::zeros_typed(vec![rows, n], DType::U4);
        execute_int_spec_into(&spec, &[&x4, &wi8, &bt, &tt], &mut got).unwrap();
        assert_eq!(got.codes_i32(), want.codes_i32());

        // Fully packed u4 x u4, wide i32 output, no activation.
        let (w4, w32) = packed_i32_pair(vec![k, n], DType::U4, 62);
        let spec = IntOpSpec::Mvau {
            apply_act: false,
            out_mul: 1,
            out_add: 0,
        };
        let mut want = Tensor::zeros_i32(vec![rows, n]);
        execute_int_spec_into(&spec, &[&x32, &w32, &bt], &mut want).unwrap();
        let mut got = Tensor::zeros_i32(vec![rows, n]);
        execute_int_spec_into(&spec, &[&x4, &w4, &bt], &mut got).unwrap();
        assert_eq!(got.data_i32(), want.data_i32());
    }

    #[test]
    fn xnor_b1_mvau_matches_i32_oracle_with_masked_tail() {
        // k = 70 forces a partial second u64 word — the tail mask keeps
        // xnor from counting the zero padding as agreement.
        let (rows, k, n) = (5usize, 70usize, 9usize);
        let (xb, x32) = packed_i32_pair(vec![rows, k], DType::B1, 63);
        let (wb, w32) = packed_i32_pair(vec![k, n], DType::B1, 64);
        let mut rng = crate::rng::Rng::new(65);
        let bias: Vec<i32> = (0..n).map(|_| rng.below(20) as i32 - 10).collect();
        let bt = Tensor::new_i32(vec![n], bias).unwrap();

        // Raw accumulator output first.
        let spec = IntOpSpec::Mvau {
            apply_act: false,
            out_mul: 1,
            out_add: 0,
        };
        let mut want = Tensor::zeros_i32(vec![rows, n]);
        execute_int_spec_into(&spec, &[&x32, &w32, &bt], &mut want).unwrap();
        let mut got = Tensor::zeros_i32(vec![rows, n]);
        execute_int_spec_into(&spec, &[&xb, &wb, &bt], &mut got).unwrap();
        assert_eq!(got.data_i32(), want.data_i32());

        // Fused sign activation back onto the bipolar grid: one threshold
        // at 1 with q*2 - 1 maps acc >= 1 -> +1, else -1.
        let tt = Tensor::new_i32(vec![1, 1], vec![1]).unwrap();
        let spec = IntOpSpec::Mvau {
            apply_act: true,
            out_mul: 2,
            out_add: -1,
        };
        let mut want = Tensor::zeros_i32(vec![rows, n]);
        execute_int_spec_into(&spec, &[&x32, &w32, &bt, &tt], &mut want).unwrap();
        let mut got = Tensor::zeros_typed(vec![rows, n], DType::B1);
        execute_int_spec_into(&spec, &[&xb, &wb, &bt, &tt], &mut got).unwrap();
        assert_eq!(got.codes_i32(), want.codes_i32());
    }

    #[test]
    fn xnor_b1_mvau_exact_word_boundary() {
        // k = 128 is exactly two u64 words: tail == 0 must mask nothing.
        let (rows, k, n) = (2usize, 128usize, 3usize);
        let (xb, x32) = packed_i32_pair(vec![rows, k], DType::B1, 66);
        let (wb, w32) = packed_i32_pair(vec![k, n], DType::B1, 67);
        let bt = Tensor::new_i32(vec![n], vec![0; n]).unwrap();
        let spec = IntOpSpec::Mvau {
            apply_act: false,
            out_mul: 1,
            out_add: 0,
        };
        let mut want = Tensor::zeros_i32(vec![rows, n]);
        execute_int_spec_into(&spec, &[&x32, &w32, &bt], &mut want).unwrap();
        let mut got = Tensor::zeros_i32(vec![rows, n]);
        execute_int_spec_into(&spec, &[&xb, &wb, &bt], &mut got).unwrap();
        assert_eq!(got.data_i32(), want.data_i32());
    }

    #[test]
    fn subbyte_elementwise_ops_match_i32_oracle() {
        let shape = vec![1, 4, 4, 2];
        let (x4, x32) = packed_i32_pair(shape.clone(), DType::U4, 70);

        // Threshold into a u4 container.
        let t32 = Tensor::new_i32(vec![1, 3], vec![3, 7, 12]).unwrap();
        let spec = IntOpSpec::Threshold {
            layout: ChanLayout::Nhwc,
            out_mul: 1,
            out_add: 0,
        };
        let mut want = Tensor::zeros_i32(shape.clone());
        execute_int_spec_into(&spec, &[&x32, &t32], &mut want).unwrap();
        let mut got = Tensor::zeros_typed(shape.clone(), DType::U4);
        execute_int_spec_into(&spec, &[&x4, &t32], &mut got).unwrap();
        assert_eq!(got.codes_i32(), want.codes_i32());

        // im2col preserves the packed container (zero pad is a valid u4).
        let spec = IntOpSpec::Im2Col {
            kernel: [3, 3],
            stride: [1, 1],
            pad: [1, 1],
        };
        let mut want = Tensor::zeros_i32(vec![1, 4, 4, 18]);
        execute_int_spec_into(&spec, &[&x32], &mut want).unwrap();
        let mut got = Tensor::zeros_typed(vec![1, 4, 4, 18], DType::U4);
        execute_int_spec_into(&spec, &[&x4], &mut got).unwrap();
        assert_eq!(got.codes_i32(), want.codes_i32());

        // maxpool on packed codes.
        let mut want = Tensor::zeros_i32(vec![1, 2, 2, 2]);
        execute_int_spec_into(&IntOpSpec::MaxPoolNhwc, &[&x32], &mut want).unwrap();
        let mut got = Tensor::zeros_typed(vec![1, 2, 2, 2], DType::U4);
        execute_int_spec_into(&IntOpSpec::MaxPoolNhwc, &[&x4], &mut got).unwrap();
        assert_eq!(got.codes_i32(), want.codes_i32());

        // gap widens out of the packed container.
        let mut want = Tensor::zeros_i32(vec![1, 2]);
        execute_int_spec_into(&IntOpSpec::GlobalAccPool, &[&x32], &mut want).unwrap();
        let mut got = Tensor::zeros_typed(vec![1, 2], DType::I16);
        execute_int_spec_into(&IntOpSpec::GlobalAccPool, &[&x4], &mut got).unwrap();
        assert_eq!(got.codes_i32(), want.codes_i32());

        // add_streams mixing a packed and a byte container.
        let flat = vec![32usize];
        let (a4, a32) = packed_i32_pair(flat.clone(), DType::U4, 71);
        let b8 = Tensor::new_i8(flat.clone(), (0..32).map(|i| i as i8 - 16).collect()).unwrap();
        let b32 = Tensor::new_i32(flat.clone(), b8.codes_i32()).unwrap();
        let spec = IntOpSpec::AddStreams { shift: [2, 0] };
        let mut want = Tensor::zeros_i32(flat.clone());
        execute_int_spec_into(&spec, &[&a32, &b32], &mut want).unwrap();
        let mut got = Tensor::zeros_typed(flat.clone(), DType::I8);
        execute_int_spec_into(&spec, &[&a4, &b8], &mut got).unwrap();
        assert_eq!(got.codes_i32(), want.codes_i32());

        // mul_scalar widening out of u4.
        let spec = IntOpSpec::MulScalar { m: 9, data_input: 0 };
        let mut want = Tensor::zeros_i32(flat.clone());
        execute_int_spec_into(&spec, &[&a32], &mut want).unwrap();
        let mut got = Tensor::zeros_typed(flat, DType::I8);
        execute_int_spec_into(&spec, &[&a4], &mut got).unwrap();
        assert_eq!(got.codes_i32(), want.codes_i32());
    }

    #[test]
    fn bipolar_zero_pad_im2col_errors_loudly() {
        // Zero padding has no bipolar code; the kernel must refuse rather
        // than silently corrupt the patch.
        let (xb, _) = packed_i32_pair(vec![1, 4, 4, 1], DType::B1, 72);
        let spec = IntOpSpec::Im2Col {
            kernel: [3, 3],
            stride: [1, 1],
            pad: [1, 1],
        };
        let mut out = Tensor::zeros_typed(vec![1, 4, 4, 9], DType::B1);
        assert!(execute_int_spec_into(&spec, &[&xb], &mut out).is_err());
        // Unpadded bipolar im2col is fine.
        let spec = IntOpSpec::Im2Col {
            kernel: [3, 3],
            stride: [1, 1],
            pad: [0, 0],
        };
        let mut out = Tensor::zeros_typed(vec![1, 2, 2, 9], DType::B1);
        execute_int_spec_into(&spec, &[&xb], &mut out).unwrap();
    }

    #[test]
    fn subbyte_container_overflow_is_an_error() {
        // Code 18 does not fit a u4 container; the view store must refuse.
        let x4 = Tensor::from_codes_packed(vec![4], &[1, 2, 6, 15], DType::U4).unwrap();
        let spec = IntOpSpec::MulScalar { m: 3, data_input: 0 };
        let mut out4 = Tensor::zeros_typed(vec![4], DType::U4);
        assert!(execute_int_spec_into(&spec, &[&x4], &mut out4).is_err());
        // The same product fits an i8 container.
        let mut out8 = Tensor::zeros_typed(vec![4], DType::I8);
        execute_int_spec_into(&spec, &[&x4], &mut out8).unwrap();
    }
}
