//! Reference executors for every graph op — the rust analogue of FINN's
//! `execute_onnx`.
//!
//! Transform correctness is proven by executing the graph before and after
//! each rewrite on the same input and requiring (near-)exact equality; the
//! HW-layer ops (MVAU, Thresholding, ...) have behavioural executors here
//! too, so the *fully lowered* graph still executes and can be compared
//! against the original NCHW import and against features from the PJRT
//! artifact.
//!
//! Layout conventions: imported compute ops are NCHW (PyTorch-style); the
//! lowered/HW ops are NHWC streams, matching FINN's HLS library (§III-C of
//! the paper is precisely about this seam).

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::graph::{Graph, Node};
use crate::tensor::Tensor;

/// Execute the graph on named input tensors; returns all graph outputs.
pub fn execute(graph: &Graph, feeds: &HashMap<String, Tensor>) -> Result<HashMap<String, Tensor>> {
    let mut env: HashMap<String, Tensor> = HashMap::new();
    for (k, v) in feeds {
        env.insert(k.clone(), v.clone());
    }
    for input in &graph.inputs {
        if !env.contains_key(input) {
            bail!("missing feed for graph input {input}");
        }
    }
    let mut sorted = graph.clone();
    sorted.toposort()?;
    for node in &sorted.nodes {
        let inputs: Vec<&Tensor> = node
            .inputs
            .iter()
            .map(|name| {
                env.get(name)
                    .or_else(|| graph.initializers.get(name))
                    .ok_or_else(|| anyhow!("node {}: tensor {name} unavailable", node.name))
            })
            .collect::<Result<_>>()?;
        let outputs = execute_node(node, &inputs)
            .map_err(|e| anyhow!("executing {} ({}): {e}", node.name, node.op))?;
        if outputs.len() != node.outputs.len() {
            bail!("node {} produced {} outputs, expected {}", node.name, outputs.len(), node.outputs.len());
        }
        for (name, tensor) in node.outputs.iter().zip(outputs) {
            env.insert(name.clone(), tensor);
        }
    }
    let mut result = HashMap::new();
    for out in &graph.outputs {
        let t = env
            .remove(out)
            .ok_or_else(|| anyhow!("graph output {out} not produced"))?;
        result.insert(out.clone(), t);
    }
    Ok(result)
}

/// Execute a single node on resolved input tensors.
pub fn execute_node(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    match node.op.as_str() {
        "Conv" => conv(node, inputs),
        "MultiThreshold" => multithreshold(node, inputs),
        "Mul" => Ok(vec![inputs[0].broadcast_with(inputs[1], |a, b| a * b)?]),
        "Add" => Ok(vec![inputs[0].broadcast_with(inputs[1], |a, b| a + b)?]),
        "MaxPool" => maxpool(node, inputs),
        "MaxPoolNHWC" => maxpool_nhwc(inputs),
        "ReduceMean" => reduce_mean(node, inputs),
        "Transpose" => {
            let perm: Vec<usize> = node.attrs.ints("perm")?.iter().map(|&i| i as usize).collect();
            Ok(vec![inputs[0].transpose(&perm)?])
        }
        "Reshape" => {
            let shape: Vec<usize> =
                node.attrs.ints("shape")?.iter().map(|&i| i as usize).collect();
            Ok(vec![inputs[0].clone().reshape(shape)?])
        }
        "Im2Col" => im2col(node, inputs),
        "MatMul" => matmul(inputs),
        "GlobalAccPool" => global_acc_pool(inputs),
        // HW layers (behavioural semantics; cycle/resource models in hw/).
        "MVAU" => mvau(node, inputs),
        "Thresholding" => multithreshold(node, inputs),
        "ConvolutionInputGenerator" => im2col(node, inputs),
        "StreamingMaxPool" => maxpool_nhwc(inputs),
        "GlobalAccPool_hw" => global_acc_pool(inputs),
        "AddStreams" => Ok(vec![inputs[0].broadcast_with(inputs[1], |a, b| a + b)?]),
        "ChannelwiseMul" => Ok(vec![inputs[0].broadcast_with(inputs[1], |a, b| a * b)?]),
        other => bail!("no executor for op {other}"),
    }
}

// ---------------------------------------------------------------- Conv

/// NCHW x OIHW convolution with symmetric padding, stride and bias.
fn conv(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let (x, w) = (inputs[0], inputs[1]);
    let bias = inputs.get(2).copied();
    let kernel = node.attrs.ints("kernel")?;
    let stride = node.attrs.ints("stride")?;
    let pad = node.attrs.ints("pad")?;
    let (kh, kw) = (kernel[0] as usize, kernel[1] as usize);
    let (sh, sw) = (stride[0] as usize, stride[1] as usize);
    let (ph, pw) = (pad[0] as usize, pad[1] as usize);
    let [n, cin, h, wdim]: [usize; 4] = x.shape().try_into().map_err(|_| anyhow!("conv input must be 4-D"))?;
    let [cout, wcin, wkh, wkw]: [usize; 4] = w.shape().try_into().map_err(|_| anyhow!("conv weight must be 4-D"))?;
    if wcin != cin || wkh != kh || wkw != kw {
        bail!("conv weight {:?} mismatch with input {:?}", w.shape(), x.shape());
    }
    let ho = (h + 2 * ph - kh) / sh + 1;
    let wo = (wdim + 2 * pw - kw) / sw + 1;
    let mut out = Tensor::zeros(vec![n, cout, ho, wo]);
    let xs = x.data();
    let ws = w.data();
    let od = out.data_mut();
    for b in 0..n {
        for oc in 0..cout {
            let bias_v = bias.map(|t| t.data()[oc]).unwrap_or(0.0);
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut acc = 0.0f32;
                    for ic in 0..cin {
                        for dy in 0..kh {
                            let iy = oy * sh + dy;
                            if iy < ph || iy >= h + ph {
                                continue;
                            }
                            let iy = iy - ph;
                            for dx in 0..kw {
                                let ix = ox * sw + dx;
                                if ix < pw || ix >= wdim + pw {
                                    continue;
                                }
                                let ix = ix - pw;
                                let xv = xs[((b * cin + ic) * h + iy) * wdim + ix];
                                let wv = ws[((oc * cin + ic) * kh + dy) * kw + dx];
                                acc += xv * wv;
                            }
                        }
                    }
                    od[((b * cout + oc) * ho + oy) * wo + ox] = acc + bias_v;
                }
            }
        }
    }
    Ok(vec![out])
}

// ------------------------------------------------------- MultiThreshold

/// FINN MultiThreshold: `q[c] = #{k : x >= T[c, k]}`, then
/// `y = out_scale * q + out_bias`.
///
/// `data_layout` attr selects which axis is the channel axis ("NCHW" ->
/// axis 1, "NHWC" -> last).  The threshold matrix is [C, K]; rows may be
/// identical (uniform quantizer) but per-channel rows are supported — the
/// paper's AbsorbTransposeIntoMultiThreshold requires re-interpreting the
/// channel axis, which is exactly this attribute (Fig. 4).
fn multithreshold(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let (x, t) = (inputs[0], inputs[1]);
    let layout = node.attrs.str_or("data_layout", "NCHW");
    let out_scale = node.attrs.float_or("out_scale", 1.0) as f32;
    let out_bias = node.attrs.float_or("out_bias", 0.0) as f32;
    let [c_t, k] = [t.shape()[0], t.shape()[1]];
    let chan_axis = match layout {
        "NCHW" => 1,
        "NHWC" => x.ndim() - 1,
        "NC" => 1,
        other => bail!("unknown data_layout {other}"),
    };
    let c = x.shape()[chan_axis];
    if c_t != c && c_t != 1 {
        bail!("threshold rows {c_t} != channels {c}");
    }
    let strides = x.strides();
    let chan_stride = strides[chan_axis];
    let chan_extent = x.shape()[chan_axis];
    let mut out = x.clone();
    let ts = t.data();
    let xs = out.data_mut();
    for (i, v) in xs.iter_mut().enumerate() {
        let ch = (i / chan_stride) % chan_extent;
        let row = if c_t == 1 { 0 } else { ch };
        let thresholds = &ts[row * k..(row + 1) * k];
        // Thresholds are sorted ascending: q = #{k : x >= t_k} is the
        // partition point of (t <= x).
        let q = thresholds.partition_point(|&t| t <= *v);
        *v = out_scale * q as f32 + out_bias;
    }
    Ok(vec![out])
}

// -------------------------------------------------------------- MaxPool

/// NCHW max-pool (kernel = stride, the only form the backbone uses).
fn maxpool(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let x = inputs[0];
    let kernel = node.attrs.ints("kernel")?;
    let (kh, kw) = (kernel[0] as usize, kernel[1] as usize);
    let [n, c, h, w]: [usize; 4] = x.shape().try_into().map_err(|_| anyhow!("maxpool input must be 4-D"))?;
    let (ho, wo) = (h / kh, w / kw);
    let mut out = Tensor::zeros(vec![n, c, ho, wo]);
    let xs = x.data();
    let od = out.data_mut();
    for b in 0..n {
        for ch in 0..c {
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..kh {
                        for dx in 0..kw {
                            let v = xs[((b * c + ch) * h + oy * kh + dy) * w + ox * kw + dx];
                            m = m.max(v);
                        }
                    }
                    od[((b * c + ch) * ho + oy) * wo + ox] = m;
                }
            }
        }
    }
    Ok(vec![out])
}

/// NHWC 2x2/2 max-pool (the streaming HW form).
fn maxpool_nhwc(inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let x = inputs[0];
    let [n, h, w, c]: [usize; 4] = x.shape().try_into().map_err(|_| anyhow!("pool input must be 4-D"))?;
    let (ho, wo) = (h / 2, w / 2);
    let mut out = Tensor::zeros(vec![n, ho, wo, c]);
    let xs = x.data();
    let od = out.data_mut();
    for b in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                for ch in 0..c {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            m = m.max(
                                xs[((b * h + oy * 2 + dy) * w + ox * 2 + dx) * c + ch],
                            );
                        }
                    }
                    od[((b * ho + oy) * wo + ox) * c + ch] = m;
                }
            }
        }
    }
    Ok(vec![out])
}

// ----------------------------------------------------------- ReduceMean

fn reduce_mean(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let x = inputs[0];
    let axes: Vec<usize> = node.attrs.ints("axes")?.iter().map(|&a| a as usize).collect();
    let keepdims = node.attrs.int_or("keepdims", 0) != 0;
    let shape = x.shape();
    let mut out_shape = Vec::new();
    for (i, &d) in shape.iter().enumerate() {
        if axes.contains(&i) {
            if keepdims {
                out_shape.push(1);
            }
        } else {
            out_shape.push(d);
        }
    }
    let reduce_count: usize = axes.iter().map(|&a| shape[a]).product();
    let strides = x.strides();
    let mut out = Tensor::zeros(out_shape.clone());
    let xs = x.data();
    // Iterate all elements, accumulate into the output slot.
    let kept: Vec<usize> = (0..shape.len()).filter(|i| !axes.contains(i)).collect();
    let out_strides = crate::tensor::strides_of(
        &kept.iter().map(|&i| shape[i]).collect::<Vec<_>>(),
    );
    let od = out.data_mut();
    for (lin, &v) in xs.iter().enumerate() {
        let mut off = 0;
        for (j, &axis) in kept.iter().enumerate() {
            let idx = (lin / strides[axis]) % shape[axis];
            off += idx * out_strides[j];
        }
        od[off] += v;
    }
    for v in od.iter_mut() {
        *v /= reduce_count as f32;
    }
    Ok(vec![out])
}

// --------------------------------------------------------------- Im2Col

/// NHWC im2col (the SWG's functional semantics): [N,H,W,C] ->
/// [N, Ho, Wo, kh*kw*C], patch-major (dy, dx, c) — matching
/// python/compile/kernels/ref.py::im2col_ref.
fn im2col(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let x = inputs[0];
    let kernel = node.attrs.ints("kernel")?;
    let stride = node.attrs.ints("stride")?;
    let pad = node.attrs.ints("pad")?;
    let (kh, kw) = (kernel[0] as usize, kernel[1] as usize);
    let (sh, sw) = (stride[0] as usize, stride[1] as usize);
    let (ph, pw) = (pad[0] as usize, pad[1] as usize);
    let [n, h, w, c]: [usize; 4] = x.shape().try_into().map_err(|_| anyhow!("im2col input must be 4-D"))?;
    let ho = (h + 2 * ph - kh) / sh + 1;
    let wo = (w + 2 * pw - kw) / sw + 1;
    let k = kh * kw * c;
    let mut out = Tensor::zeros(vec![n, ho, wo, k]);
    let xs = x.data();
    let od = out.data_mut();
    for b in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let base = ((b * ho + oy) * wo + ox) * k;
                let mut slot = 0;
                for dy in 0..kh {
                    for dx in 0..kw {
                        let iy = oy * sh + dy;
                        let ix = ox * sw + dx;
                        for ch in 0..c {
                            let v = if iy < ph || iy >= h + ph || ix < pw || ix >= w + pw {
                                0.0
                            } else {
                                xs[((b * h + (iy - ph)) * w + (ix - pw)) * c + ch]
                            };
                            od[base + slot] = v;
                            slot += 1;
                        }
                    }
                }
            }
        }
    }
    Ok(vec![out])
}

// --------------------------------------------------------------- MatMul

/// Batched-free matmul over the last axis: [..., K] x [K, N] -> [..., N].
fn matmul(inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let (x, w) = (inputs[0], inputs[1]);
    let k = *x.shape().last().ok_or_else(|| anyhow!("matmul on scalar"))?;
    let [wk, n]: [usize; 2] = w.shape().try_into().map_err(|_| anyhow!("matmul weight must be 2-D"))?;
    if wk != k {
        bail!("matmul inner dim {k} != weight rows {wk}");
    }
    let rows: usize = x.shape()[..x.ndim() - 1].iter().product();
    let mut out_shape = x.shape()[..x.ndim() - 1].to_vec();
    out_shape.push(n);
    let mut out = Tensor::zeros(out_shape);
    let xs = x.data();
    let ws = w.data();
    let od = out.data_mut();
    for r in 0..rows {
        let xrow = &xs[r * k..(r + 1) * k];
        let orow = &mut od[r * n..(r + 1) * n];
        for (kk, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &ws[kk * n..(kk + 1) * n];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
    Ok(vec![out])
}

// -------------------------------------------------------- GlobalAccPool

/// FINN GlobalAccPool: NHWC -> [N, C] cumulative SUM over spatial dims
/// (no division — the following Mul applies 1/HW, §III-D).
fn global_acc_pool(inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let x = inputs[0];
    let [n, h, w, c]: [usize; 4] = x.shape().try_into().map_err(|_| anyhow!("gap input must be 4-D"))?;
    let mut out = Tensor::zeros(vec![n, c]);
    let xs = x.data();
    let od = out.data_mut();
    for b in 0..n {
        for y in 0..h {
            for xcol in 0..w {
                for ch in 0..c {
                    od[b * c + ch] += xs[((b * h + y) * w + xcol) * c + ch];
                }
            }
        }
    }
    Ok(vec![out])
}

// ----------------------------------------------------------------- MVAU

/// Matrix-Vector-Activation Unit: MatMul + bias + optional MultiThreshold.
///
/// inputs: [x(..., K), w(K, N), bias(N), thresholds(C_or_1, T)?]
/// attrs:  out_scale / out_bias for the threshold stage; `apply_act`.
fn mvau(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let mm = matmul(&[inputs[0], inputs[1]])?.pop().unwrap();
    let bias = inputs[2];
    let with_bias = mm.broadcast_with(bias, |a, b| a + b)?;
    let apply_act = node.attrs.int_or("apply_act", 1) != 0;
    if !apply_act {
        return Ok(vec![with_bias]);
    }
    let thresholds = inputs
        .get(3)
        .ok_or_else(|| anyhow!("MVAU with apply_act needs thresholds input"))?;
    let mut thresh_node = Node::new("Thresholding", &node.name, vec![], vec![]);
    thresh_node.attrs = node.attrs.clone();
    thresh_node
        .attrs
        .set("data_layout", crate::graph::AttrVal::Str("NHWC".into()));
    multithreshold(&thresh_node, &[&with_bias, thresholds])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{AttrVal, Attrs};

    fn node(op: &str, attrs: Attrs) -> Node {
        Node::new(op, "t", vec![], vec![]).with_attrs(attrs)
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 kernel with identity weights passes channels through.
        let x = Tensor::from_fn(vec![1, 2, 3, 3], |i| i as f32);
        let mut w = Tensor::zeros(vec![2, 2, 1, 1]);
        w.set(&[0, 0, 0, 0], 1.0);
        w.set(&[1, 1, 0, 0], 1.0);
        let attrs = Attrs::new()
            .with("kernel", AttrVal::Ints(vec![1, 1]))
            .with("stride", AttrVal::Ints(vec![1, 1]))
            .with("pad", AttrVal::Ints(vec![0, 0]));
        let y = conv(&node("Conv", attrs), &[&x, &w]).unwrap().pop().unwrap();
        assert_eq!(y, x);
    }

    #[test]
    fn conv_3x3_known_values() {
        // All-ones 3x3 kernel over constant image = 9 in interior, less on
        // border (zero pad).
        let x = Tensor::full(vec![1, 1, 4, 4], 1.0);
        let w = Tensor::full(vec![1, 1, 3, 3], 1.0);
        let attrs = Attrs::new()
            .with("kernel", AttrVal::Ints(vec![3, 3]))
            .with("stride", AttrVal::Ints(vec![1, 1]))
            .with("pad", AttrVal::Ints(vec![1, 1]));
        let y = conv(&node("Conv", attrs), &[&x, &w]).unwrap().pop().unwrap();
        assert_eq!(y.at(&[0, 0, 1, 1]), 9.0);
        assert_eq!(y.at(&[0, 0, 0, 0]), 4.0);
        assert_eq!(y.at(&[0, 0, 0, 1]), 6.0);
    }

    #[test]
    fn conv_bias_added() {
        let x = Tensor::zeros(vec![1, 1, 2, 2]);
        let w = Tensor::zeros(vec![3, 1, 1, 1]);
        let b = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let attrs = Attrs::new()
            .with("kernel", AttrVal::Ints(vec![1, 1]))
            .with("stride", AttrVal::Ints(vec![1, 1]))
            .with("pad", AttrVal::Ints(vec![0, 0]));
        let y = conv(&node("Conv", attrs), &[&x, &w, &b]).unwrap().pop().unwrap();
        assert_eq!(y.at(&[0, 0, 0, 0]), 1.0);
        assert_eq!(y.at(&[0, 2, 1, 1]), 3.0);
    }

    #[test]
    fn multithreshold_counts_thresholds() {
        // thresholds [0.5, 1.5, 2.5]: x=2.0 -> 2 crossings.
        let x = Tensor::new(vec![1, 1, 1, 3], vec![-1.0, 2.0, 9.0]).unwrap();
        let t = Tensor::new(vec![1, 3], vec![0.5, 1.5, 2.5]).unwrap();
        let attrs = Attrs::new().with("data_layout", AttrVal::Str("NCHW".into()));
        let y = multithreshold(&node("MultiThreshold", attrs), &[&x, &t])
            .unwrap()
            .pop()
            .unwrap();
        assert_eq!(y.data(), &[0.0, 2.0, 3.0]);
    }

    #[test]
    fn multithreshold_x_equal_threshold_counts() {
        // FINN: q = #{k : x >= t_k}, so equality crosses.
        let x = Tensor::new(vec![1, 1], vec![1.5]).unwrap();
        let t = Tensor::new(vec![1, 3], vec![0.5, 1.5, 2.5]).unwrap();
        let attrs = Attrs::new().with("data_layout", AttrVal::Str("NC".into()));
        let y = multithreshold(&node("MultiThreshold", attrs), &[&x, &t])
            .unwrap()
            .pop()
            .unwrap();
        assert_eq!(y.data(), &[2.0]);
    }

    #[test]
    fn multithreshold_per_channel_rows_nchw_vs_nhwc() {
        // Channel 0 thresholds at 0.5; channel 1 at 5.0.
        let t = Tensor::new(vec![2, 1], vec![0.5, 5.0]).unwrap();
        let x_nchw = Tensor::new(vec![1, 2, 1, 2], vec![1.0, 1.0, 1.0, 6.0]).unwrap();
        let attrs = Attrs::new().with("data_layout", AttrVal::Str("NCHW".into()));
        let y = multithreshold(&node("MT", attrs), &[&x_nchw, &t]).unwrap().pop().unwrap();
        assert_eq!(y.data(), &[1.0, 1.0, 0.0, 1.0]);
        // Same data in NHWC must give the transposed result.
        let x_nhwc = x_nchw.nchw_to_nhwc().unwrap();
        let attrs = Attrs::new().with("data_layout", AttrVal::Str("NHWC".into()));
        let y2 = multithreshold(&node("MT", attrs), &[&x_nhwc, &t]).unwrap().pop().unwrap();
        assert_eq!(y2, y.nchw_to_nhwc().unwrap());
    }

    #[test]
    fn multithreshold_out_scale_bias() {
        let x = Tensor::new(vec![1, 1], vec![2.0]).unwrap();
        let t = Tensor::new(vec![1, 3], vec![0.5, 1.5, 2.5]).unwrap();
        let attrs = Attrs::new()
            .with("data_layout", AttrVal::Str("NC".into()))
            .with("out_scale", AttrVal::Float(0.25))
            .with("out_bias", AttrVal::Float(-1.0));
        let y = multithreshold(&node("MT", attrs), &[&x, &t]).unwrap().pop().unwrap();
        assert_eq!(y.data(), &[0.25 * 2.0 - 1.0]);
    }

    #[test]
    fn maxpool_2x2() {
        let x = Tensor::new(
            vec![1, 1, 2, 4],
            vec![1., 2., 3., 4., 5., 6., 7., 8.],
        )
        .unwrap();
        let attrs = Attrs::new()
            .with("kernel", AttrVal::Ints(vec![2, 2]))
            .with("stride", AttrVal::Ints(vec![2, 2]));
        let y = maxpool(&node("MaxPool", attrs), &[&x]).unwrap().pop().unwrap();
        assert_eq!(y.shape(), &[1, 1, 1, 2]);
        assert_eq!(y.data(), &[6.0, 8.0]);
    }

    #[test]
    fn maxpool_nhwc_matches_nchw() {
        let x = Tensor::from_fn(vec![1, 2, 4, 4], |i| ((i * 7919) % 13) as f32);
        let attrs = Attrs::new()
            .with("kernel", AttrVal::Ints(vec![2, 2]))
            .with("stride", AttrVal::Ints(vec![2, 2]));
        let want = maxpool(&node("MaxPool", attrs), &[&x]).unwrap().pop().unwrap();
        let got = maxpool_nhwc(&[&x.nchw_to_nhwc().unwrap()]).unwrap().pop().unwrap();
        assert_eq!(got.nhwc_to_nchw().unwrap(), want);
    }

    #[test]
    fn reduce_mean_spatial() {
        let x = Tensor::from_fn(vec![1, 2, 2, 2], |i| i as f32);
        let attrs = Attrs::new()
            .with("axes", AttrVal::Ints(vec![2, 3]))
            .with("keepdims", AttrVal::Int(0));
        let y = reduce_mean(&node("ReduceMean", attrs), &[&x]).unwrap().pop().unwrap();
        assert_eq!(y.shape(), &[1, 2]);
        assert_eq!(y.data(), &[1.5, 5.5]);
    }

    #[test]
    fn im2col_center_patch() {
        let x = Tensor::from_fn(vec![1, 4, 4, 1], |i| i as f32);
        let attrs = Attrs::new()
            .with("kernel", AttrVal::Ints(vec![3, 3]))
            .with("stride", AttrVal::Ints(vec![1, 1]))
            .with("pad", AttrVal::Ints(vec![1, 1]));
        let y = im2col(&node("Im2Col", attrs), &[&x]).unwrap().pop().unwrap();
        assert_eq!(y.shape(), &[1, 4, 4, 9]);
        // Patch at (1,1) = rows 0..3 x cols 0..3 of the image.
        let patch: Vec<f32> = (0..9).map(|i| y.at(&[0, 1, 1, i])).collect();
        assert_eq!(patch, vec![0., 1., 2., 4., 5., 6., 8., 9., 10.]);
    }

    #[test]
    fn im2col_matmul_equals_conv() {
        // The lowering identity: conv(NCHW) == transpose . im2col . matmul.
        let mut rng = crate::rng::Rng::new(77);
        let x_nchw = Tensor::from_fn(vec![1, 3, 6, 6], |_| rng.normal());
        let w_oihw = Tensor::from_fn(vec![4, 3, 3, 3], |_| rng.normal());
        let conv_attrs = Attrs::new()
            .with("kernel", AttrVal::Ints(vec![3, 3]))
            .with("stride", AttrVal::Ints(vec![1, 1]))
            .with("pad", AttrVal::Ints(vec![1, 1]));
        let want = conv(&node("Conv", conv_attrs.clone()), &[&x_nchw, &w_oihw])
            .unwrap()
            .pop()
            .unwrap();

        let x_nhwc = x_nchw.nchw_to_nhwc().unwrap();
        let cols = im2col(&node("Im2Col", conv_attrs), &[&x_nhwc]).unwrap().pop().unwrap();
        // OIHW -> (dy, dx, cin)-major K x O matrix = transpose to HWIO then
        // reshape.
        let w_k_o = w_oihw.transpose(&[2, 3, 1, 0]).unwrap().reshape(vec![27, 4]).unwrap();
        let got_nhwc = matmul(&[&cols, &w_k_o]).unwrap().pop().unwrap();
        let got = got_nhwc.nhwc_to_nchw().unwrap();
        assert!(got.allclose(&want, 1e-4), "max diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn global_acc_pool_sums() {
        let x = Tensor::full(vec![1, 2, 2, 3], 1.5);
        let y = global_acc_pool(&[&x]).unwrap().pop().unwrap();
        assert_eq!(y.shape(), &[1, 3]);
        assert_eq!(y.data(), &[6.0, 6.0, 6.0]);
    }

    #[test]
    fn mvau_with_thresholds() {
        let x = Tensor::new(vec![1, 2], vec![1.0, 1.0]).unwrap();
        let w = Tensor::new(vec![2, 1], vec![1.0, 1.0]).unwrap();
        let b = Tensor::new(vec![1], vec![0.5]).unwrap();
        let t = Tensor::new(vec![1, 4], vec![0.5, 1.0, 2.0, 3.0]).unwrap();
        let attrs = Attrs::new()
            .with("apply_act", AttrVal::Int(1))
            .with("out_scale", AttrVal::Float(0.5));
        let y = mvau(&node("MVAU", attrs), &[&x, &w, &b, &t]).unwrap().pop().unwrap();
        // acc = 2.5 -> crosses 0.5, 1.0, 2.0 -> q=3 -> 1.5 after scale.
        assert_eq!(y.data(), &[1.5]);
    }

    #[test]
    fn mvau_no_act_is_affine() {
        let x = Tensor::new(vec![1, 2], vec![2.0, 3.0]).unwrap();
        let w = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let b = Tensor::new(vec![2], vec![10.0, 20.0]).unwrap();
        let attrs = Attrs::new().with("apply_act", AttrVal::Int(0));
        let y = mvau(&node("MVAU", attrs), &[&x, &w, &b]).unwrap().pop().unwrap();
        assert_eq!(y.data(), &[12.0, 23.0]);
    }

    #[test]
    fn execute_full_graph_plumbing() {
        use crate::graph::Graph;
        let mut g = Graph::new("tiny");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["y".into()];
        g.shapes.insert("x".into(), vec![1, 2]);
        g.shapes.insert("s".into(), vec![]);
        g.shapes.insert("y".into(), vec![1, 2]);
        g.initializers.insert("s".into(), Tensor::scalar(3.0));
        g.nodes.push(Node::new("Mul", "m", vec!["x".into(), "s".into()], vec!["y".into()]));
        let mut feeds = HashMap::new();
        feeds.insert("x".to_string(), Tensor::new(vec![1, 2], vec![1.0, 2.0]).unwrap());
        let out = execute(&g, &feeds).unwrap();
        assert_eq!(out["y"].data(), &[3.0, 6.0]);
    }

    #[test]
    fn execute_missing_feed_errors() {
        use crate::graph::Graph;
        let mut g = Graph::new("tiny");
        g.inputs = vec!["x".into()];
        let feeds = HashMap::new();
        assert!(execute(&g, &feeds).is_err());
    }
}
