//! Hand-rolled CLI argument parsing (no `clap` in the offline crate set,
//! DESIGN.md §2).  Subcommand + `--key value` / `--flag` options.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line: subcommand + options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        match it.next() {
            Some(cmd) if !cmd.starts_with("--") => args.command = cmd.clone(),
            Some(cmd) => bail!("expected subcommand before {cmd}"),
            None => args.command = "help".to_string(),
        }
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected positional argument {a:?}");
            };
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    args.options.insert(key.to_string(), it.next().unwrap().clone());
                }
                _ => args.flags.push(key.to_string()),
            }
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
            None => Ok(default),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects a number, got {v:?}")),
            None => Ok(default),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

/// Resolve a Table-II config by name (or w<int>.<frac>a<int>.<frac> spec).
pub fn parse_config(spec: &str) -> Result<crate::fixedpoint::QuantConfig> {
    for (name, cfg) in crate::fixedpoint::table2_configs() {
        if name == spec {
            return Ok(cfg);
        }
    }
    // wI.F_aI.F, e.g. "w1.5_a2.2"
    if let Some(rest) = spec.strip_prefix('w') {
        let parts: Vec<&str> = rest.split("_a").collect();
        if parts.len() == 2 {
            let w: Vec<&str> = parts[0].split('.').collect();
            let a: Vec<&str> = parts[1].split('.').collect();
            if w.len() == 2 && a.len() == 2 {
                return crate::fixedpoint::QuantConfig::from_split(
                    w[0].parse()?,
                    w[1].parse()?,
                    a[0].parse()?,
                    a[1].parse()?,
                );
            }
        }
    }
    bail!(
        "unknown config {spec:?}; use a Table-II name (e.g. b6_c1.5_r2.2) or wI.F_aI.F (e.g. w1.5_a2.2)"
    )
}

/// Comma-separated f64 list, e.g. "0.5,0.85".
pub fn parse_f64_list(s: &str) -> Result<Vec<f64>> {
    s.split(',')
        .map(|p| {
            p.trim()
                .parse::<f64>()
                .map_err(|_| anyhow!("bad number {p:?} in list {s:?}"))
        })
        .collect()
}

/// Comma-separated config list (Table-II names or wI.F_aI.F specs).
pub fn parse_config_list(s: &str) -> Result<Vec<(String, crate::fixedpoint::QuantConfig)>> {
    s.split(',')
        .map(|p| {
            let p = p.trim();
            Ok((p.to_string(), parse_config(p)?))
        })
        .collect()
}

/// Run-length `SxR` pipeline topology, e.g. `1x1,1x2,2x1` = one stage
/// with 1 worker, one stage with 2 workers, two stages with 1 worker —
/// the per-stage replication vector `[1, 2, 1, 1]`.  The same encoding
/// `PlanPipeline::topology` prints, so a logged topology pastes straight
/// back into `--topology` for a reproducible rerun.
pub fn parse_topology(s: &str) -> Result<Vec<usize>> {
    let mut reps = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        let (stages, workers) = part.split_once('x').ok_or_else(|| {
            anyhow!("bad topology group {part:?} in {s:?}: expected SxR (e.g. 2x3)")
        })?;
        let stages: usize = stages
            .trim()
            .parse()
            .map_err(|_| anyhow!("bad stage count in topology group {part:?}"))?;
        let workers: usize = workers
            .trim()
            .parse()
            .map_err(|_| anyhow!("bad worker count in topology group {part:?}"))?;
        if stages == 0 || workers == 0 {
            bail!("topology group {part:?} must have S >= 1 and R >= 1");
        }
        reps.extend(std::iter::repeat_n(workers, stages));
    }
    if reps.is_empty() {
        bail!("empty topology {s:?}");
    }
    Ok(reps)
}

pub const USAGE: &str = "\
bwade — Bit-Width-Aware Design Environment (ISCAS reproduction)

USAGE: bwade <command> [options]

COMMANDS
  build      run the design environment on artifacts/graph.json
             --config <name|wI.F_aI.F>   bit-width config (default b6_c1.5_r2.2)
             --target-fps <f>            folding target (default 60)
             --max-util <f>              device utilization cap (default 0.85)
             --verify                    numerically verify each transform stage
  compare    FINN dataflow vs Tensil systolic (Table III / Table I)
  table2     accuracy sweep over the eight Table-II configs
             --episodes <n>              episodes per config (default 200)
             --engine <pjrt|plan>        backbone engine (default: pjrt if
                                         built with the feature, else plan)
             --datapath <f32|bit-true>   f32 simulation or bit-exact integer
                                         execution of the lowered HW graph
                                         (bit-true needs --engine plan)
  dse        parallel design-space exploration: quant configs x
             utilization caps -> Pareto frontier + EXPERIMENTS.md
             (offline: synthesized backbone + compiled plan engine)
             --workers <n>               worker threads (default 4)
             --episodes <n>              episodes per point (default 50)
             --configs <a,b,...>         config subset (default: all 8 Table-II rows)
             --caps <f,f,...>            utilization caps (default 0.5,0.85)
             --target-fps <f>            folding target (default: fold to cap)
             --cache [dir]               reuse/populate result cache
                                         (default dir .dse-cache)
             --out <path>                report path (default EXPERIMENTS.md)
             --seed <n>  --img <n>       bank seed / input size
             --datapath <f32|bit-true>   accuracy arithmetic (recorded per
                                         row; part of the cache key)
  serve      run the Fig.-5 serving pipeline on synthetic frames
             --frames <n>  --batch <n>  --rate <fps>  --config <...>
             --engine <pjrt|plan>  --datapath <f32|bit-true>
             --replicas <n>              plan-runner pool size (default 1;
                                         >1 needs --engine plan: N replicas
                                         share ONE compiled plan via Arc
                                         behind a work-stealing queue)
             --streams <m>               concurrent camera streams feeding
                                         the tier (default 1; --rate is
                                         per-stream)
             --pipeline                  streaming pipelined executor:
                                         the compiled plan is cut into
                                         per-stage workers on bounded
                                         FIFOs (frames in flight across
                                         layers; needs --engine plan).
                                         With --replicas P > 1 the pool
                                         hosts P whole pipelines — the
                                         composed P x S x R topology
             --stages <n>                pipeline stage count (default:
                                         auto, 4 clamped to plan steps)
             --topology <SxR,...>        explicit per-stage worker
                                         replication as run-length SxR
                                         groups (e.g. 1x1,1x2,2x1 = 4
                                         stages, workers [1,2,1,1]);
                                         overrides --stages, for
                                         reproducible composed runs
             --elastic                   telemetry-driven rebalance: serve
                                         a warmup window on the seeded
                                         topology, then promote the
                                         measured bottleneck stage from
                                         its recv/send stall counters
                                         and serve the rest on the
                                         adopted topology
             --max-wait-ms <t>           batch deadline: close a batch when
                                         the oldest frame waited this long
                                         (default 5)
             --synth                     serve the dse's synthetic backbone
                                         + bank — no artifacts needed
                                         (implies --engine plan)
             --json <path>               record the run as a one-row
                                         BENCH_serving.json document
             --metrics-json <path>       write the process telemetry
                                         registry (pool queue depths,
                                         steals, batch close reasons,
                                         per-replica busy/idle) as a
                                         bwade/telemetry/v1 snapshot;
                                         also emits a periodic summary
                                         line on stderr while serving
  profile    per-step plan profile joined against the DataflowSim
             per-actor cycle prediction -> PROFILE.md (measured vs
             predicted shares, per-layer error in percentage points)
             --synth                     profile the dse's synthetic
                                         backbone — no artifacts needed
             --config <...>              bit-width config (default b6_c1.5_r2.2)
             --datapath <f32|bit-true>   measured datapath (default bit-true)
             --frames <n>                measured frames after warmup
                                         (default 16)
             --stages <n>                stage count for the pipelined
                                         steady-state measurement
                                         (default 4); the report joins
                                         the measured egress interval
                                         against DataflowSim's II
             --max-util <f>              folding cap for the predicted
                                         side (default 0.85)
             --out <path>                report path (default PROFILE.md)
             --json <path>               machine-readable bwade/profile/v1
  episodes   few-shot evaluation for one config
             --config <...>  --episodes <n>  --shot <k>  --way <n>
             --engine <pjrt|plan>  --datapath <f32|bit-true>
  info       print artifact + model metadata
  help       this text

The `plan` engine executes the exported compiler graph through the
compiled ExecutionPlan (rust/src/plan/) — python-free and XLA-free.
With `--datapath bit-true` the graph is lowered to the HW form and run
on the integer datapath: every code tensor is packed into the narrowest
container its bit-width permits (i8/i16/i32) and the kernels are
monomorphized per container (i8xi8 accumulates in i32), so features are
bit-exactly what the FPGA computes — and the bytes moved per frame are
what its narrow datapath would stream — dequantized only at egress.

Artifacts are read from ./artifacts (override with BWADE_ARTIFACTS).";

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(&sv(&["build", "--config", "b6_c1.5_r2.2", "--verify"])).unwrap();
        assert_eq!(a.command, "build");
        assert_eq!(a.get("config"), Some("b6_c1.5_r2.2"));
        assert!(a.has_flag("verify"));
    }

    #[test]
    fn typed_getters() {
        let a = Args::parse(&sv(&["serve", "--frames", "100", "--rate", "30.5"])).unwrap();
        assert_eq!(a.get_usize("frames", 0).unwrap(), 100);
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 30.5);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(a.get_usize("rate", 0).is_err());
    }

    #[test]
    fn rejects_positional_garbage() {
        assert!(Args::parse(&sv(&["build", "junk"])).is_err());
    }

    #[test]
    fn empty_means_help() {
        assert_eq!(Args::parse(&[]).unwrap().command, "help");
    }

    #[test]
    fn list_parsers() {
        assert_eq!(parse_f64_list("0.5, 0.85").unwrap(), vec![0.5, 0.85]);
        assert!(parse_f64_list("0.5,nope").is_err());
        let cfgs = parse_config_list("b6_c1.5_r2.2, w4.4_a4.4").unwrap();
        assert_eq!(cfgs.len(), 2);
        assert_eq!(cfgs[0].0, "b6_c1.5_r2.2");
        assert_eq!(cfgs[1].1.weight.describe(), "s8.4");
        assert!(parse_config_list("b6_c1.5_r2.2,junk").is_err());
    }

    #[test]
    fn config_by_name_and_spec() {
        let byname = parse_config("b6_c1.5_r2.2").unwrap();
        assert_eq!(byname.weight.describe(), "s6.5");
        let byspec = parse_config("w1.5_a2.2").unwrap();
        assert_eq!(byspec, byname);
        assert!(parse_config("nonsense").is_err());
    }

    #[test]
    fn topology_run_length_groups() {
        assert_eq!(parse_topology("1x1,1x2,2x1").unwrap(), vec![1, 2, 1, 1]);
        assert_eq!(parse_topology("3x2").unwrap(), vec![2, 2, 2]);
        assert_eq!(parse_topology(" 2x1 , 1x4 ").unwrap(), vec![1, 1, 4]);
        assert!(parse_topology("").is_err());
        assert!(parse_topology("2").is_err(), "missing R");
        assert!(parse_topology("0x2").is_err(), "zero stages");
        assert!(parse_topology("2x0").is_err(), "zero workers");
        assert!(parse_topology("axb").is_err());
    }
}
