//! Conv lowering — FINN's "Convert to HW Layer" prerequisite (Fig. 3).
//!
//! Each NCHW `Conv` becomes the NHWC stream form the FINN HLS library
//! executes:
//!
//! ```text
//! Transpose(NCHW->NHWC) -> Im2Col -> MatMul(W_km) -> Add(bias)
//!     -> Transpose(NHWC->NCHW)
//! ```
//!
//! The weight initializer is re-laid-out from OIHW to a K x O matrix with
//! (dy, dx, cin)-major K — the same ordering as the Pallas kernel's
//! im2col (python/compile/kernels/ref.py), so all three layers agree on
//! the weight stream.
//!
//! The trailing Transpose is precisely the node §III-C is about: it lands
//! in front of the next MultiThreshold and must be absorbed
//! ([`super::transpose_opt::AbsorbTransposeIntoMultiThreshold`]) for the
//! MVAU weight mapping to be correct (paper Fig. 4).

use anyhow::{bail, Result};

use super::Transform;
use crate::graph::{AttrVal, Attrs, Graph, Node};

pub const TO_NHWC: [i64; 4] = [0, 2, 3, 1];
pub const TO_NCHW: [i64; 4] = [0, 3, 1, 2];

pub struct LowerConvToMatMul;

impl Transform for LowerConvToMatMul {
    fn name(&self) -> &'static str {
        "LowerConvToMatMul"
    }

    fn apply(&self, graph: &mut Graph) -> Result<bool> {
        for idx in 0..graph.nodes.len() {
            if graph.nodes[idx].op != "Conv" {
                continue;
            }
            let node = graph.nodes[idx].clone();
            if node.attrs.int_or("group", 1) != 1 {
                bail!("grouped conv not supported by lowering");
            }
            let kernel = node.attrs.ints("kernel")?;
            let stride = node.attrs.ints("stride")?;
            let pad = node.attrs.ints("pad")?;
            let x = node.inputs[0].clone();
            let w_name = node.inputs[1].clone();
            let bias = node.inputs.get(2).cloned();
            let y = node.outputs[0].clone();

            let x_shape = graph.shape_of(&x)?.to_vec();
            let y_shape = graph.shape_of(&y)?.to_vec();
            let [n, cin, h, wdim] = [x_shape[0], x_shape[1], x_shape[2], x_shape[3]];
            let [cout, ho, wo] = [y_shape[1], y_shape[2], y_shape[3]];
            let (kh, kw) = (kernel[0] as usize, kernel[1] as usize);
            let k = kh * kw * cin;

            // Re-layout the weight: OIHW -> (dy, dx, cin)-major [K, O].
            let w_oihw = graph
                .initializers
                .get(&w_name)
                .ok_or_else(|| anyhow::anyhow!("conv weight {w_name} must be an initializer"))?
                .clone();
            let w_km = w_oihw
                .transpose(&[2, 3, 1, 0])? // OIHW -> (kh, kw, cin, cout)
                .reshape(vec![k, cout])?;
            let w_mat_name = graph.fresh_tensor(&format!("{}_wmat", node.name), vec![k, cout]);
            graph.initializers.insert(w_mat_name.clone(), w_km);

            // Intermediate tensors.
            let x_nhwc = graph.fresh_tensor(&format!("{}_nhwc", node.name), vec![n, h, wdim, cin]);
            let cols = graph.fresh_tensor(&format!("{}_cols", node.name), vec![n, ho, wo, k]);
            let mm = graph.fresh_tensor(&format!("{}_mm", node.name), vec![n, ho, wo, cout]);
            let pre_t = graph.fresh_tensor(&format!("{}_biased", node.name), vec![n, ho, wo, cout]);

            let mut new_nodes = vec![
                Node::new(
                    "Transpose",
                    &format!("{}_to_nhwc", node.name),
                    vec![x],
                    vec![x_nhwc.clone()],
                )
                .with_attrs(Attrs::new().with("perm", AttrVal::Ints(TO_NHWC.to_vec()))),
                Node::new(
                    "Im2Col",
                    &format!("{}_im2col", node.name),
                    vec![x_nhwc],
                    vec![cols.clone()],
                )
                .with_attrs(
                    Attrs::new()
                        .with("kernel", AttrVal::Ints(kernel.clone()))
                        .with("stride", AttrVal::Ints(stride.clone()))
                        .with("pad", AttrVal::Ints(pad.clone())),
                ),
                Node::new(
                    "MatMul",
                    &format!("{}_matmul", node.name),
                    vec![cols, w_mat_name],
                    vec![mm.clone()],
                ),
            ];
            let last_nhwc = if let Some(bias) = bias {
                new_nodes.push(Node::new(
                    "Add",
                    &format!("{}_bias", node.name),
                    vec![mm, bias],
                    vec![pre_t.clone()],
                ));
                pre_t
            } else {
                graph.shapes.remove(&pre_t);
                mm
            };
            new_nodes.push(
                Node::new(
                    "Transpose",
                    &format!("{}_to_nchw", node.name),
                    vec![last_nhwc],
                    vec![y],
                )
                .with_attrs(Attrs::new().with("perm", AttrVal::Ints(TO_NCHW.to_vec()))),
            );

            // Drop the old weight initializer if nothing else reads it.
            graph.remove_nodes(vec![idx]);
            if graph.consumers(&w_name).is_empty() {
                graph.initializers.remove(&w_name);
                graph.shapes.remove(&w_name);
            }
            graph.nodes.extend(new_nodes);
            graph.toposort()?;
            return Ok(true);
        }
        Ok(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::transforms::run_to_fixpoint;
    use std::collections::HashMap;

    fn conv_graph() -> Graph {
        let mut g = Graph::new("c");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["y".into()];
        g.shapes.insert("x".into(), vec![1, 3, 6, 6]);
        g.shapes.insert("w".into(), vec![4, 3, 3, 3]);
        g.shapes.insert("b".into(), vec![4]);
        g.shapes.insert("y".into(), vec![1, 4, 6, 6]);
        let mut rng = crate::rng::Rng::new(5);
        g.initializers.insert(
            "w".into(),
            Tensor::from_fn(vec![4, 3, 3, 3], |_| rng.normal()),
        );
        g.initializers
            .insert("b".into(), Tensor::from_fn(vec![4], |_| rng.normal()));
        g.nodes.push(
            Node::new("Conv", "conv0", vec!["x".into(), "w".into(), "b".into()], vec!["y".into()])
                .with_attrs(
                    Attrs::new()
                        .with("kernel", AttrVal::Ints(vec![3, 3]))
                        .with("stride", AttrVal::Ints(vec![1, 1]))
                        .with("pad", AttrVal::Ints(vec![1, 1]))
                        .with("group", AttrVal::Int(1)),
                ),
        );
        g
    }

    #[test]
    fn lowering_preserves_conv_semantics() {
        let mut g = conv_graph();
        let mut rng = crate::rng::Rng::new(9);
        let mut feeds = HashMap::new();
        feeds.insert(
            "x".to_string(),
            Tensor::from_fn(vec![1, 3, 6, 6], |_| rng.normal()),
        );
        let want = crate::ops::execute(&g, &feeds).unwrap()["y"].clone();
        let n = run_to_fixpoint(&mut g, &LowerConvToMatMul).unwrap();
        assert_eq!(n, 1);
        assert_eq!(g.count_op("Conv"), 0);
        assert_eq!(g.count_op("Transpose"), 2);
        assert_eq!(g.count_op("Im2Col"), 1);
        assert_eq!(g.count_op("MatMul"), 1);
        assert_eq!(g.count_op("Add"), 1);
        let got = crate::ops::execute(&g, &feeds).unwrap()["y"].clone();
        assert!(
            got.allclose(&want, 1e-4),
            "max diff {}",
            got.max_abs_diff(&want)
        );
        g.validate().unwrap();
    }

    #[test]
    fn weight_matrix_shape_and_old_weight_removed() {
        let mut g = conv_graph();
        run_to_fixpoint(&mut g, &LowerConvToMatMul).unwrap();
        assert!(!g.initializers.contains_key("w"));
        let wmat = g
            .initializers
            .iter()
            .find(|(k, _)| k.contains("wmat"))
            .unwrap()
            .1;
        assert_eq!(wmat.shape(), &[27, 4]);
    }

    #[test]
    fn bias_free_conv_lowered_without_add() {
        let mut g = conv_graph();
        g.nodes[0].inputs.truncate(2);
        run_to_fixpoint(&mut g, &LowerConvToMatMul).unwrap();
        assert_eq!(g.count_op("Add"), 0);
        g.validate().unwrap();
    }
}
