//! Graph transformation passes — the rust re-implementation of FINN's
//! "Network Preparation" phase (paper Fig. 3), including the paper's two
//! custom contributions:
//!
//! * [`transpose_opt::AbsorbTransposeIntoMultiThreshold`] — §III-C /
//!   Fig. 4: merge the NHWC->NCHW Transpose that conv lowering inserts
//!   into the following MultiThreshold (re-typed to NHWC) and re-insert
//!   the Transpose after it, so the weight stream maps correctly onto the
//!   MVAU.
//! * [`gap::ConvertReduceMeanToGap`] — §III-D: replace the backbone's
//!   final spatial `reduce_mean` with `GlobalAccPool` (cumulative sum)
//!   followed by a scalar `Mul` with 1/(H*W), avoiding a division unit.
//!
//! Every pass implements [`Transform`]: a semantics-preserving rewrite
//! returning whether it changed the graph.  [`apply_pipeline`] runs a
//! stage list to fixpoint, optionally checking numerical equivalence
//! after every stage (one compiled [`ExecutionPlan`] per side of the
//! rewrite, run on a probe input) — the FINN methodology, mechanized.

pub mod convert_to_hw;
pub mod gap;
pub mod lower_conv;
pub mod streamline;
pub mod transpose_opt;

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::graph::Graph;
use crate::plan::ExecutionPlan;
use crate::tensor::Tensor;

pub use convert_to_hw::{annotate_bit_true_formats, non_dyadic_scale_count};

/// A semantics-preserving graph rewrite.
pub trait Transform {
    fn name(&self) -> &'static str;

    /// Apply once; return true if the graph changed.
    fn apply(&self, graph: &mut Graph) -> Result<bool>;
}

/// Run one transform to fixpoint; returns number of applications.
pub fn run_to_fixpoint(graph: &mut Graph, t: &dyn Transform) -> Result<usize> {
    let mut n = 0;
    loop {
        if !t.apply(graph)? {
            break;
        }
        n += 1;
        if n > 10_000 {
            bail!("transform {} does not converge", t.name());
        }
    }
    graph.toposort()?;
    Ok(n)
}

/// One log entry per stage of a pipeline run.
#[derive(Debug, Clone)]
pub struct StageReport {
    pub transform: String,
    pub applications: usize,
    pub nodes_after: usize,
    pub max_divergence: Option<f32>,
}

/// Apply a list of transforms in order (each to fixpoint).
///
/// When `probe` is given, the graph is executed after every stage and the
/// outputs compared against the pre-pipeline reference; any divergence
/// greater than `tol` aborts — a transform broke semantics.  Each side of
/// the comparison compiles one [`ExecutionPlan`] (reference once, rewritten
/// graph once per stage — the graph changed, so its plan must too).
pub fn apply_pipeline(
    graph: &mut Graph,
    transforms: &[&dyn Transform],
    probe: Option<&HashMap<String, Tensor>>,
    tol: f32,
) -> Result<Vec<StageReport>> {
    let reference = match probe {
        Some(feeds) => Some(ExecutionPlan::compile(graph)?.run(feeds)?),
        None => None,
    };
    let mut reports = Vec::new();
    for t in transforms {
        let n = run_to_fixpoint(graph, *t)?;
        let mut max_div = None;
        if let (Some(feeds), Some(want)) = (probe, reference.as_ref()) {
            let got = ExecutionPlan::compile(graph)
                .and_then(|plan| plan.run(feeds))
                .map_err(|e| anyhow::anyhow!("after {}: {e}", t.name()))?;
            let mut stage_max = 0.0f32;
            for (name, w) in want {
                let g = &got[name];
                if g.shape() != w.shape() {
                    bail!(
                        "transform {} changed output {name} shape {:?} -> {:?}",
                        t.name(),
                        w.shape(),
                        g.shape()
                    );
                }
                stage_max = stage_max.max(g.max_abs_diff(w));
            }
            if stage_max > tol {
                bail!(
                    "transform {} diverged: max |diff| = {stage_max} > {tol}",
                    t.name()
                );
            }
            max_div = Some(stage_max);
        }
        graph.validate()?;
        reports.push(StageReport {
            transform: t.name().to_string(),
            applications: n,
            nodes_after: graph.nodes.len(),
            max_divergence: max_div,
        });
    }
    Ok(reports)
}

/// The full build pipeline in FINN order: streamline -> lower convs ->
/// transpose optimization (§III-C) -> GAP conversion (§III-D) -> HW
/// mapping.  This is what `build::DesignEnvironment` runs.
pub fn default_pipeline() -> Vec<Box<dyn Transform>> {
    vec![
        Box::new(streamline::CollapseMulIntoMultiThreshold),
        Box::new(streamline::CollapseRepeatedMul),
        Box::new(streamline::RemoveIdentityMul),
        Box::new(lower_conv::LowerConvToMatMul),
        Box::new(transpose_opt::AbsorbTransposeIntoMultiThreshold),
        Box::new(transpose_opt::MoveTransposePastMultiThreshold),
        Box::new(transpose_opt::MoveTransposePastMaxPool),
        Box::new(transpose_opt::MoveTransposePastEltwiseAdd),
        Box::new(transpose_opt::ComposeAdjacentTransposes),
        Box::new(transpose_opt::RemoveIdentityTranspose),
        Box::new(streamline::DeadNodeElimination),
        // A second round: moving transposes exposes new cancellations.
        Box::new(transpose_opt::AbsorbTransposeIntoMultiThreshold),
        Box::new(transpose_opt::MoveTransposePastMaxPool),
        Box::new(transpose_opt::MoveTransposePastEltwiseAdd),
        Box::new(transpose_opt::ComposeAdjacentTransposes),
        Box::new(transpose_opt::RemoveIdentityTranspose),
        Box::new(gap::ConvertReduceMeanToGap),
        Box::new(transpose_opt::ComposeAdjacentTransposes),
        Box::new(transpose_opt::RemoveIdentityTranspose),
        Box::new(streamline::DeadNodeElimination),
        Box::new(convert_to_hw::ConvertToHwLayers),
        Box::new(streamline::DeadNodeElimination),
    ]
}

/// Run the default pipeline with optional equivalence probing.
pub fn run_default_pipeline(
    graph: &mut Graph,
    probe: Option<&HashMap<String, Tensor>>,
    tol: f32,
) -> Result<Vec<StageReport>> {
    let pipeline = default_pipeline();
    let refs: Vec<&dyn Transform> = pipeline.iter().map(|b| b.as_ref()).collect();
    apply_pipeline(graph, &refs, probe, tol)
}
