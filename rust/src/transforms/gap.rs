//! ReduceMean -> GlobalAccPool conversion — the paper's §III-D
//! contribution.
//!
//! The backbone's final layer is a spatial `reduce_mean`.  Neither Tensil
//! nor FINN executes it directly; FINN's `GlobalAccPool` computes the
//! cumulative *sum* over the spatial dims and — to avoid a hardware
//! divider — the averaging is applied as a scalar `Mul` with 1/(H*W)
//! afterwards.  This pass implements exactly that conversion, in both the
//! post-lowering form (Transpose(NHWC->NCHW) -> ReduceMean) and the
//! direct NCHW form (a leading Transpose is inserted).

use anyhow::Result;

use super::lower_conv::{TO_NCHW, TO_NHWC};
use super::Transform;
use crate::graph::{AttrVal, Attrs, Graph, Node};
use crate::tensor::Tensor;

pub struct ConvertReduceMeanToGap;

impl Transform for ConvertReduceMeanToGap {
    fn name(&self) -> &'static str {
        "ConvertReduceMeanToGap"
    }

    fn apply(&self, graph: &mut Graph) -> Result<bool> {
        for rm_idx in 0..graph.nodes.len() {
            if graph.nodes[rm_idx].op != "ReduceMean" {
                continue;
            }
            let axes = graph.nodes[rm_idx].attrs.ints("axes")?;
            if axes != vec![2, 3] || graph.nodes[rm_idx].attrs.int_or("keepdims", 0) != 0 {
                continue; // only the spatial NCHW form the backbone emits
            }
            let x = graph.nodes[rm_idx].inputs[0].clone();
            let out = graph.nodes[rm_idx].outputs[0].clone();
            let rm_name = graph.nodes[rm_idx].name.clone();

            // If the input is produced by a NHWC->NCHW Transpose feeding
            // only us, absorb it; otherwise insert our own conversion.
            let producer = graph.producer(&x);
            let (nhwc_src, remove_also) = match producer {
                Some(p_idx)
                    if graph.nodes[p_idx].op == "Transpose"
                        && graph.nodes[p_idx].attrs.ints("perm").ok().as_deref()
                            == Some(&TO_NCHW)
                        && graph.consumers(&x).len() == 1 =>
                {
                    (graph.nodes[p_idx].inputs[0].clone(), Some(p_idx))
                }
                _ => {
                    let nchw = graph.shape_of(&x)?.to_vec();
                    let nhwc: Vec<usize> =
                        TO_NHWC.iter().map(|&p| nchw[p as usize]).collect();
                    let t_out = graph.fresh_tensor(&format!("{rm_name}_nhwc_in"), nhwc);
                    graph.nodes.push(
                        Node::new(
                            "Transpose",
                            &format!("{rm_name}_to_nhwc"),
                            vec![x.clone()],
                            vec![t_out.clone()],
                        )
                        .with_attrs(
                            Attrs::new().with("perm", AttrVal::Ints(TO_NHWC.to_vec())),
                        ),
                    );
                    (t_out, None)
                }
            };

            let nhwc_shape = graph.shape_of(&nhwc_src)?.to_vec();
            let (n, h, w, c) = (nhwc_shape[0], nhwc_shape[1], nhwc_shape[2], nhwc_shape[3]);
            let acc = graph.fresh_tensor(&format!("{rm_name}_acc"), vec![n, c]);
            let scale_name = graph.fresh_tensor(&format!("{rm_name}_inv_hw"), vec![]);
            graph
                .initializers
                .insert(scale_name.clone(), Tensor::scalar(1.0 / (h * w) as f32));

            let gap = Node::new(
                "GlobalAccPool",
                &format!("{rm_name}_accpool"),
                vec![nhwc_src],
                vec![acc.clone()],
            );
            // "The averaging is then achieved by applying scalar
            // multiplication through a Mul node" (§III-D).
            let mul = Node::new(
                "Mul",
                &format!("{rm_name}_avg"),
                vec![acc, scale_name],
                vec![out],
            );

            let mut to_remove = vec![rm_idx];
            if let Some(p) = remove_also {
                to_remove.push(p);
                graph.shapes.remove(&x);
            }
            graph.remove_nodes(to_remove);
            graph.nodes.push(gap);
            graph.nodes.push(mul);
            graph.toposort()?;
            return Ok(true);
        }
        Ok(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transforms::run_to_fixpoint;
    use std::collections::HashMap;

    fn feeds() -> HashMap<String, Tensor> {
        let mut rng = crate::rng::Rng::new(21);
        let mut f = HashMap::new();
        f.insert(
            "x".to_string(),
            Tensor::from_fn(vec![1, 3, 4, 4], |_| rng.normal()),
        );
        f
    }

    #[test]
    fn direct_nchw_reduce_mean_converted() {
        let mut g = Graph::new("g");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["y".into()];
        g.shapes.insert("x".into(), vec![1, 3, 4, 4]);
        g.shapes.insert("y".into(), vec![1, 3]);
        g.nodes.push(
            Node::new("ReduceMean", "gap", vec!["x".into()], vec!["y".into()]).with_attrs(
                Attrs::new()
                    .with("axes", AttrVal::Ints(vec![2, 3]))
                    .with("keepdims", AttrVal::Int(0)),
            ),
        );
        let f = feeds();
        let want = crate::ops::execute(&g, &f).unwrap()["y"].clone();
        let n = run_to_fixpoint(&mut g, &ConvertReduceMeanToGap).unwrap();
        assert_eq!(n, 1);
        assert_eq!(g.count_op("ReduceMean"), 0);
        assert_eq!(g.count_op("GlobalAccPool"), 1);
        assert_eq!(g.count_op("Mul"), 1);
        let got = crate::ops::execute(&g, &f).unwrap()["y"].clone();
        assert!(got.allclose(&want, 1e-5));
        g.validate().unwrap();
    }

    #[test]
    fn absorbs_preceding_transpose() {
        // NHWC stream -> Transpose(NCHW) -> ReduceMean: the transpose is
        // consumed by the conversion (no extra layout node remains).
        let mut g = Graph::new("g");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["y".into()];
        g.shapes.insert("x".into(), vec![1, 4, 4, 3]);
        g.shapes.insert("xt".into(), vec![1, 3, 4, 4]);
        g.shapes.insert("y".into(), vec![1, 3]);
        g.nodes.push(
            Node::new("Transpose", "t", vec!["x".into()], vec!["xt".into()])
                .with_attrs(Attrs::new().with("perm", AttrVal::Ints(TO_NCHW.to_vec()))),
        );
        g.nodes.push(
            Node::new("ReduceMean", "gap", vec!["xt".into()], vec!["y".into()]).with_attrs(
                Attrs::new()
                    .with("axes", AttrVal::Ints(vec![2, 3]))
                    .with("keepdims", AttrVal::Int(0)),
            ),
        );
        let mut rng = crate::rng::Rng::new(2);
        let mut f = HashMap::new();
        f.insert(
            "x".to_string(),
            Tensor::from_fn(vec![1, 4, 4, 3], |_| rng.normal()),
        );
        let want = crate::ops::execute(&g, &f).unwrap()["y"].clone();
        run_to_fixpoint(&mut g, &ConvertReduceMeanToGap).unwrap();
        assert_eq!(g.count_op("Transpose"), 0);
        assert_eq!(g.count_op("GlobalAccPool"), 1);
        let got = crate::ops::execute(&g, &f).unwrap()["y"].clone();
        assert!(got.allclose(&want, 1e-5));
        g.validate().unwrap();
    }

    #[test]
    fn gap_mul_scale_is_inv_hw() {
        let mut g = Graph::new("g");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["y".into()];
        g.shapes.insert("x".into(), vec![1, 3, 4, 4]);
        g.shapes.insert("y".into(), vec![1, 3]);
        g.nodes.push(
            Node::new("ReduceMean", "gap", vec!["x".into()], vec!["y".into()]).with_attrs(
                Attrs::new()
                    .with("axes", AttrVal::Ints(vec![2, 3]))
                    .with("keepdims", AttrVal::Int(0)),
            ),
        );
        run_to_fixpoint(&mut g, &ConvertReduceMeanToGap).unwrap();
        let scale = g
            .initializers
            .iter()
            .find(|(k, _)| k.contains("inv_hw"))
            .unwrap()
            .1;
        assert_eq!(scale.data()[0], 1.0 / 16.0);
    }

    #[test]
    fn non_spatial_reduce_mean_untouched() {
        let mut g = Graph::new("g");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["y".into()];
        g.shapes.insert("x".into(), vec![1, 3, 4, 4]);
        g.shapes.insert("y".into(), vec![1, 4, 4]);
        g.nodes.push(
            Node::new("ReduceMean", "rm", vec!["x".into()], vec!["y".into()]).with_attrs(
                Attrs::new()
                    .with("axes", AttrVal::Ints(vec![1]))
                    .with("keepdims", AttrVal::Int(0)),
            ),
        );
        let n = run_to_fixpoint(&mut g, &ConvertReduceMeanToGap).unwrap();
        assert_eq!(n, 0);
    }
}
