//! "Convert to HW Layer" — map the streamlined NHWC graph onto FINN-style
//! hardware layers (paper Fig. 3, Network Preparation's last step).
//!
//! Patterns handled (all NHWC after the §III-C passes):
//!
//! * `Im2Col`                              -> `ConvolutionInputGenerator` (SWG)
//! * `MatMul -> Add(bias) -> MultiThreshold` -> `MVAU` (apply_act=1)
//! * `MatMul -> Add(bias)`                 -> `MVAU` (apply_act=0, residual 2nd conv)
//! * `MultiThreshold` (standalone)         -> `Thresholding`
//! * `MaxPoolNHWC`                         -> `StreamingMaxPool`
//! * `Add` (two streams)                   -> `AddStreams`
//! * `GlobalAccPool`                       -> `GlobalAccPool_hw`
//! * `Mul` (scalar, after GAP)             -> `ChannelwiseMul`
//!
//! Folding attributes (PE/SIMD) are initialized to 1 and later set by the
//! folding search in [`crate::build`].

use anyhow::Result;

use super::Transform;
use crate::graph::{AttrVal, Graph, Node};

pub struct ConvertToHwLayers;

impl ConvertToHwLayers {
    /// MatMul (+bias Add) (+MultiThreshold) -> MVAU.
    fn try_mvau(&self, graph: &mut Graph) -> Result<bool> {
        for mm_idx in 0..graph.nodes.len() {
            if graph.nodes[mm_idx].op != "MatMul" {
                continue;
            }
            let mm_out = graph.nodes[mm_idx].outputs[0].clone();
            let consumers = graph.consumers(&mm_out);
            if consumers.len() != 1 || graph.nodes[consumers[0]].op != "Add" {
                continue;
            }
            let add_idx = consumers[0];
            // bias = the Add input that is an initializer.
            let add = &graph.nodes[add_idx];
            let bias = add
                .inputs
                .iter()
                .find(|t| graph.is_initializer(t))
                .cloned();
            let Some(bias) = bias else { continue };
            let add_out = graph.nodes[add_idx].outputs[0].clone();

            let x = graph.nodes[mm_idx].inputs[0].clone();
            let w = graph.nodes[mm_idx].inputs[1].clone();
            let mm_name = graph.nodes[mm_idx].name.clone();
            let base = mm_name.trim_end_matches("_matmul").to_string();

            // Optional fused activation.
            let add_consumers = graph.consumers(&add_out);
            let fuse_mt = add_consumers.len() == 1
                && graph.nodes[add_consumers[0]].op == "MultiThreshold"
                && graph.nodes[add_consumers[0]]
                    .attrs
                    .str_or("data_layout", "NCHW")
                    == "NHWC";

            let (inputs, outputs, attrs, remove) = if fuse_mt {
                let mt_idx = add_consumers[0];
                let thresh = graph.nodes[mt_idx].inputs[1].clone();
                let mt_out = graph.nodes[mt_idx].outputs[0].clone();
                let mut attrs = graph.nodes[mt_idx].attrs.clone();
                attrs.set("apply_act", AttrVal::Int(1));
                attrs.set("data_layout", AttrVal::Str("NHWC".into()));
                (
                    vec![x, w, bias, thresh],
                    vec![mt_out],
                    attrs,
                    vec![mm_idx, add_idx, mt_idx],
                )
            } else {
                let mut attrs = crate::graph::Attrs::new();
                attrs.set("apply_act", AttrVal::Int(0));
                (
                    vec![x, w, bias],
                    vec![add_out.clone()],
                    attrs,
                    vec![mm_idx, add_idx],
                )
            };

            let mut attrs = attrs;
            attrs.set("pe", AttrVal::Int(1));
            attrs.set("simd", AttrVal::Int(1));
            let mvau = Node::new("MVAU", &format!("{base}_mvau"), inputs, outputs)
                .with_attrs(attrs);
            if fuse_mt {
                graph.shapes.remove(&add_out);
            }
            graph.shapes.remove(&mm_out);
            graph.remove_nodes(remove);
            graph.nodes.push(mvau);
            graph.toposort()?;
            return Ok(true);
        }
        Ok(false)
    }

    /// Single-node renames: Im2Col->SWG, MaxPoolNHWC->StreamingMaxPool, ...
    fn try_rename(&self, graph: &mut Graph) -> Result<bool> {
        for idx in 0..graph.nodes.len() {
            let new_op = match graph.nodes[idx].op.as_str() {
                "Im2Col" => "ConvolutionInputGenerator",
                "MaxPoolNHWC" => "StreamingMaxPool",
                "GlobalAccPool" => "GlobalAccPool_hw",
                _ => continue,
            };
            graph.nodes[idx].op = new_op.to_string();
            if new_op == "ConvolutionInputGenerator" {
                graph.nodes[idx].attrs.set("simd", AttrVal::Int(1));
            }
            return Ok(true);
        }
        Ok(false)
    }

    /// Standalone NHWC MultiThreshold -> Thresholding (input quantizer and
    /// the post-residual quantizer).
    fn try_thresholding(&self, graph: &mut Graph) -> Result<bool> {
        for idx in 0..graph.nodes.len() {
            if graph.nodes[idx].op != "MultiThreshold" {
                continue;
            }
            if graph.nodes[idx].attrs.str_or("data_layout", "NCHW") != "NHWC" {
                continue;
            }
            graph.nodes[idx].op = "Thresholding".to_string();
            graph.nodes[idx].attrs.set("pe", AttrVal::Int(1));
            return Ok(true);
        }
        Ok(false)
    }

    /// Stream-stream Add -> AddStreams; scalar Mul -> ChannelwiseMul.
    fn try_eltwise(&self, graph: &mut Graph) -> Result<bool> {
        for idx in 0..graph.nodes.len() {
            match graph.nodes[idx].op.as_str() {
                "Add" => {
                    let any_init = graph.nodes[idx]
                        .inputs
                        .iter()
                        .any(|t| graph.is_initializer(t));
                    if !any_init {
                        graph.nodes[idx].op = "AddStreams".to_string();
                        return Ok(true);
                    }
                }
                "Mul" => {
                    let has_scalar_init = graph.nodes[idx].inputs.iter().any(|t| {
                        graph
                            .initializers
                            .get(t)
                            .map(|i| i.numel() == 1)
                            .unwrap_or(false)
                    });
                    if has_scalar_init {
                        graph.nodes[idx].op = "ChannelwiseMul".to_string();
                        return Ok(true);
                    }
                }
                _ => {}
            }
        }
        Ok(false)
    }
}

impl Transform for ConvertToHwLayers {
    fn name(&self) -> &'static str {
        "ConvertToHwLayers"
    }

    fn apply(&self, graph: &mut Graph) -> Result<bool> {
        if self.try_mvau(graph)? {
            return Ok(true);
        }
        if self.try_thresholding(graph)? {
            return Ok(true);
        }
        if self.try_rename(graph)? {
            return Ok(true);
        }
        self.try_eltwise(graph)
    }
}

/// Ops that constitute a fully HW-mapped dataflow graph (plus Transpose,
/// which survives only as the single input layout conversion).
pub const HW_OPS: &[&str] = &[
    "ConvolutionInputGenerator",
    "MVAU",
    "Thresholding",
    "StreamingMaxPool",
    "GlobalAccPool_hw",
    "AddStreams",
    "ChannelwiseMul",
];

/// True when every compute node is a HW layer (the build pipeline's
/// post-condition; the remaining Transpose is the host-side NCHW->NHWC
/// conversion done during DMA, as in FINN's driver).
pub fn is_fully_hw(graph: &Graph) -> bool {
    graph
        .nodes
        .iter()
        .all(|n| HW_OPS.contains(&n.op.as_str()) || n.op == "Transpose")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Attrs;
    use crate::tensor::Tensor;
    use crate::transforms::run_to_fixpoint;
    use std::collections::HashMap;

    /// NHWC: x -> MatMul(w) -> Add(b) -> MultiThreshold -> y
    fn mvau_pattern() -> Graph {
        let mut g = Graph::new("m");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["y".into()];
        g.shapes.insert("x".into(), vec![1, 2, 2, 3]);
        g.shapes.insert("w".into(), vec![3, 4]);
        g.shapes.insert("b".into(), vec![4]);
        g.shapes.insert("mm".into(), vec![1, 2, 2, 4]);
        g.shapes.insert("biased".into(), vec![1, 2, 2, 4]);
        g.shapes.insert("thr".into(), vec![1, 3]);
        g.shapes.insert("y".into(), vec![1, 2, 2, 4]);
        let mut rng = crate::rng::Rng::new(14);
        g.initializers
            .insert("w".into(), Tensor::from_fn(vec![3, 4], |_| rng.normal()));
        g.initializers
            .insert("b".into(), Tensor::from_fn(vec![4], |_| rng.normal()));
        g.initializers.insert(
            "thr".into(),
            Tensor::new(vec![1, 3], vec![0.25, 0.75, 1.25]).unwrap(),
        );
        g.nodes.push(Node::new(
            "MatMul",
            "l0_matmul",
            vec!["x".into(), "w".into()],
            vec!["mm".into()],
        ));
        g.nodes.push(Node::new(
            "Add",
            "l0_bias",
            vec!["mm".into(), "b".into()],
            vec!["biased".into()],
        ));
        g.nodes.push(
            Node::new(
                "MultiThreshold",
                "l0_quant",
                vec!["biased".into(), "thr".into()],
                vec!["y".into()],
            )
            .with_attrs(
                Attrs::new()
                    .with("data_layout", AttrVal::Str("NHWC".into()))
                    .with("out_scale", AttrVal::Float(0.25)),
            ),
        );
        g
    }

    #[test]
    fn fuses_matmul_bias_mt_into_mvau() {
        let mut g = mvau_pattern();
        let mut rng = crate::rng::Rng::new(31);
        let mut feeds = HashMap::new();
        feeds.insert(
            "x".to_string(),
            Tensor::from_fn(vec![1, 2, 2, 3], |_| rng.normal()),
        );
        let want = crate::ops::execute(&g, &feeds).unwrap()["y"].clone();
        run_to_fixpoint(&mut g, &ConvertToHwLayers).unwrap();
        assert_eq!(g.count_op("MVAU"), 1);
        assert_eq!(g.count_op("MatMul"), 0);
        assert_eq!(g.count_op("Add"), 0);
        assert_eq!(g.count_op("MultiThreshold"), 0);
        let mvau = g.nodes.iter().find(|n| n.op == "MVAU").unwrap();
        assert_eq!(mvau.attrs.int("apply_act").unwrap(), 1);
        assert_eq!(mvau.inputs.len(), 4);
        let got = crate::ops::execute(&g, &feeds).unwrap()["y"].clone();
        assert_eq!(got, want);
        g.validate().unwrap();
    }

    #[test]
    fn matmul_bias_without_mt_becomes_raw_mvau() {
        let mut g = mvau_pattern();
        // Cut the MT off: route graph output from `biased`.
        g.nodes.pop();
        g.outputs = vec!["biased".into()];
        g.shapes.remove(&"y".to_string());
        let mut rng = crate::rng::Rng::new(32);
        let mut feeds = HashMap::new();
        feeds.insert(
            "x".to_string(),
            Tensor::from_fn(vec![1, 2, 2, 3], |_| rng.normal()),
        );
        let want = crate::ops::execute(&g, &feeds).unwrap()["biased"].clone();
        run_to_fixpoint(&mut g, &ConvertToHwLayers).unwrap();
        let mvau = g.nodes.iter().find(|n| n.op == "MVAU").unwrap();
        assert_eq!(mvau.attrs.int("apply_act").unwrap(), 0);
        assert_eq!(mvau.inputs.len(), 3);
        let got = crate::ops::execute(&g, &feeds).unwrap()["biased"].clone();
        assert_eq!(got, want);
    }

    #[test]
    fn renames_and_hw_predicate() {
        let mut g = Graph::new("r");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["y".into()];
        g.shapes.insert("x".into(), vec![1, 4, 4, 2]);
        g.shapes.insert("p".into(), vec![1, 2, 2, 2]);
        g.shapes.insert("y".into(), vec![1, 2]);
        g.nodes.push(
            Node::new("MaxPoolNHWC", "mp", vec!["x".into()], vec!["p".into()]).with_attrs(
                Attrs::new()
                    .with("kernel", AttrVal::Ints(vec![2, 2]))
                    .with("stride", AttrVal::Ints(vec![2, 2])),
            ),
        );
        g.nodes.push(Node::new(
            "GlobalAccPool",
            "gap",
            vec!["p".into()],
            vec!["y".into()],
        ));
        assert!(!is_fully_hw(&g));
        run_to_fixpoint(&mut g, &ConvertToHwLayers).unwrap();
        assert_eq!(g.count_op("StreamingMaxPool"), 1);
        assert_eq!(g.count_op("GlobalAccPool_hw"), 1);
        assert!(is_fully_hw(&g));
    }

    #[test]
    fn stream_add_becomes_addstreams_but_bias_add_does_not() {
        let mut g = Graph::new("a");
        g.inputs = vec!["a".into(), "b".into()];
        g.outputs = vec!["y".into()];
        for t in ["a", "b", "y"] {
            g.shapes.insert(t.into(), vec![1, 4]);
        }
        g.nodes.push(Node::new(
            "Add",
            "resadd",
            vec!["a".into(), "b".into()],
            vec!["y".into()],
        ));
        run_to_fixpoint(&mut g, &ConvertToHwLayers).unwrap();
        assert_eq!(g.count_op("AddStreams"), 1);
    }
}
