//! "Convert to HW Layer" — map the streamlined NHWC graph onto FINN-style
//! hardware layers (paper Fig. 3, Network Preparation's last step).
//!
//! Patterns handled (all NHWC after the §III-C passes):
//!
//! * `Im2Col`                              -> `ConvolutionInputGenerator` (SWG)
//! * `MatMul -> Add(bias) -> MultiThreshold` -> `MVAU` (apply_act=1)
//! * `MatMul -> Add(bias)`                 -> `MVAU` (apply_act=0, residual 2nd conv)
//! * `MultiThreshold` (standalone)         -> `Thresholding`
//! * `MaxPoolNHWC`                         -> `StreamingMaxPool`
//! * `Add` (two streams)                   -> `AddStreams`
//! * `GlobalAccPool`                       -> `GlobalAccPool_hw`
//! * `Mul` (scalar, after GAP)             -> `ChannelwiseMul`
//!
//! Folding attributes (PE/SIMD) are initialized to 1 and later set by the
//! folding search in [`crate::build`].

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use super::Transform;
use crate::fixedpoint::pow2_decompose;
use crate::graph::{AttrVal, Graph, Node};
use crate::tensor::Tensor;

pub struct ConvertToHwLayers;

impl ConvertToHwLayers {
    /// MatMul (+bias Add) (+MultiThreshold) -> MVAU.
    fn try_mvau(&self, graph: &mut Graph) -> Result<bool> {
        for mm_idx in 0..graph.nodes.len() {
            if graph.nodes[mm_idx].op != "MatMul" {
                continue;
            }
            let mm_out = graph.nodes[mm_idx].outputs[0].clone();
            let consumers = graph.consumers(&mm_out);
            if consumers.len() != 1 || graph.nodes[consumers[0]].op != "Add" {
                continue;
            }
            let add_idx = consumers[0];
            // bias = the Add input that is an initializer.
            let add = &graph.nodes[add_idx];
            let bias = add
                .inputs
                .iter()
                .find(|t| graph.is_initializer(t))
                .cloned();
            let Some(bias) = bias else { continue };
            let add_out = graph.nodes[add_idx].outputs[0].clone();

            let x = graph.nodes[mm_idx].inputs[0].clone();
            let w = graph.nodes[mm_idx].inputs[1].clone();
            let mm_name = graph.nodes[mm_idx].name.clone();
            let base = mm_name.trim_end_matches("_matmul").to_string();

            // Optional fused activation.
            let add_consumers = graph.consumers(&add_out);
            let fuse_mt = add_consumers.len() == 1
                && graph.nodes[add_consumers[0]].op == "MultiThreshold"
                && graph.nodes[add_consumers[0]]
                    .attrs
                    .str_or("data_layout", "NCHW")
                    == "NHWC";

            let (inputs, outputs, attrs, remove) = if fuse_mt {
                let mt_idx = add_consumers[0];
                let thresh = graph.nodes[mt_idx].inputs[1].clone();
                let mt_out = graph.nodes[mt_idx].outputs[0].clone();
                let mut attrs = graph.nodes[mt_idx].attrs.clone();
                attrs.set("apply_act", AttrVal::Int(1));
                attrs.set("data_layout", AttrVal::Str("NHWC".into()));
                (
                    vec![x, w, bias, thresh],
                    vec![mt_out],
                    attrs,
                    vec![mm_idx, add_idx, mt_idx],
                )
            } else {
                let mut attrs = crate::graph::Attrs::new();
                attrs.set("apply_act", AttrVal::Int(0));
                (
                    vec![x, w, bias],
                    vec![add_out.clone()],
                    attrs,
                    vec![mm_idx, add_idx],
                )
            };

            let mut attrs = attrs;
            attrs.set("pe", AttrVal::Int(1));
            attrs.set("simd", AttrVal::Int(1));
            let mvau = Node::new("MVAU", &format!("{base}_mvau"), inputs, outputs)
                .with_attrs(attrs);
            if fuse_mt {
                graph.shapes.remove(&add_out);
            }
            graph.shapes.remove(&mm_out);
            graph.remove_nodes(remove);
            graph.nodes.push(mvau);
            graph.toposort()?;
            return Ok(true);
        }
        Ok(false)
    }

    /// Single-node renames: Im2Col->SWG, MaxPoolNHWC->StreamingMaxPool, ...
    fn try_rename(&self, graph: &mut Graph) -> Result<bool> {
        for idx in 0..graph.nodes.len() {
            let new_op = match graph.nodes[idx].op.as_str() {
                "Im2Col" => "ConvolutionInputGenerator",
                "MaxPoolNHWC" => "StreamingMaxPool",
                "GlobalAccPool" => "GlobalAccPool_hw",
                _ => continue,
            };
            graph.nodes[idx].op = new_op.to_string();
            if new_op == "ConvolutionInputGenerator" {
                graph.nodes[idx].attrs.set("simd", AttrVal::Int(1));
            }
            return Ok(true);
        }
        Ok(false)
    }

    /// Standalone NHWC MultiThreshold -> Thresholding (input quantizer and
    /// the post-residual quantizer).
    fn try_thresholding(&self, graph: &mut Graph) -> Result<bool> {
        for idx in 0..graph.nodes.len() {
            if graph.nodes[idx].op != "MultiThreshold" {
                continue;
            }
            if graph.nodes[idx].attrs.str_or("data_layout", "NCHW") != "NHWC" {
                continue;
            }
            graph.nodes[idx].op = "Thresholding".to_string();
            graph.nodes[idx].attrs.set("pe", AttrVal::Int(1));
            return Ok(true);
        }
        Ok(false)
    }

    /// Stream-stream Add -> AddStreams; scalar Mul -> ChannelwiseMul.
    fn try_eltwise(&self, graph: &mut Graph) -> Result<bool> {
        for idx in 0..graph.nodes.len() {
            match graph.nodes[idx].op.as_str() {
                "Add" => {
                    let any_init = graph.nodes[idx]
                        .inputs
                        .iter()
                        .any(|t| graph.is_initializer(t));
                    if !any_init {
                        graph.nodes[idx].op = "AddStreams".to_string();
                        return Ok(true);
                    }
                }
                "Mul" => {
                    let has_scalar_init = graph.nodes[idx].inputs.iter().any(|t| {
                        graph
                            .initializers
                            .get(t)
                            .map(|i| i.numel() == 1)
                            .unwrap_or(false)
                    });
                    if has_scalar_init {
                        graph.nodes[idx].op = "ChannelwiseMul".to_string();
                        return Ok(true);
                    }
                }
                _ => {}
            }
        }
        Ok(false)
    }
}

impl Transform for ConvertToHwLayers {
    fn name(&self) -> &'static str {
        "ConvertToHwLayers"
    }

    fn apply(&self, graph: &mut Graph) -> Result<bool> {
        if self.try_mvau(graph)? {
            return Ok(true);
        }
        if self.try_thresholding(graph)? {
            return Ok(true);
        }
        if self.try_rename(graph)? {
            return Ok(true);
        }
        self.try_eltwise(graph)
    }
}

/// Ops that constitute a fully HW-mapped dataflow graph (plus Transpose,
/// which survives only as the single input layout conversion).
pub const HW_OPS: &[&str] = &[
    "ConvolutionInputGenerator",
    "MVAU",
    "Thresholding",
    "StreamingMaxPool",
    "GlobalAccPool_hw",
    "AddStreams",
    "ChannelwiseMul",
];

/// True when every compute node is a HW layer (the build pipeline's
/// post-condition; the remaining Transpose is the host-side NCHW->NHWC
/// conversion done during DMA, as in FINN's driver).
pub fn is_fully_hw(graph: &Graph) -> bool {
    graph
        .nodes
        .iter()
        .all(|n| HW_OPS.contains(&n.op.as_str()) || n.op == "Transpose")
}

/// Count the scale factors in a (lowered) graph whose exact dyadic
/// decomposition `s = m * 2^-k` needs an odd multiplier `|m| > 1`.
///
/// Such scales execute *exactly* on the integer datapath (the
/// decomposition is lossless) but diverge from the f32 simulation by
/// design — f32 rounds where the integer path does not.  The dse report
/// flags configs with a nonzero count so "exact-but-f32-divergent"
/// rows are visible (ROADMAP item).  Reads the float attributes, so it
/// works on any lowered graph, annotated or not.
pub fn non_dyadic_scale_count(graph: &Graph) -> usize {
    // A scale that cannot be decomposed at all (zero, non-finite, or an
    // odd mantissa beyond the i32 datapath) is the *most* f32-divergent
    // case — flag it, don't silently report "dyadic".
    let non_dyadic = |s: f64| {
        scale_to_mul_frac(s, "scale-scan")
            .map(|(m, _)| m.abs() != 1)
            .unwrap_or(true)
    };
    let mut count = 0;
    for node in &graph.nodes {
        match node.op.as_str() {
            "MultiThreshold" | "Thresholding" => {
                if non_dyadic(node.attrs.float_or("out_scale", 1.0)) {
                    count += 1;
                }
            }
            "MVAU" => {
                if node.attrs.int_or("apply_act", 1) != 0
                    && non_dyadic(node.attrs.float_or("out_scale", 1.0))
                {
                    count += 1;
                }
            }
            "Mul" | "ChannelwiseMul" => {
                let scalar = node
                    .inputs
                    .iter()
                    .find_map(|t| graph.initializers.get(t).filter(|i| i.numel() == 1));
                if let Some(s) = scalar {
                    if non_dyadic(s.data()[0] as f64) {
                        count += 1;
                    }
                }
            }
            _ => {}
        }
    }
    count
}

// ---------------------------------------------------------------------------
// Bit-true format annotation
// ---------------------------------------------------------------------------

/// Propagated per-tensor format during annotation.
#[derive(Debug, Clone, Copy, PartialEq)]
enum BtFmt {
    /// Raw f32 — only legal between the graph input and the ingress
    /// quantizer (the camera feed crossing the layout Transpose).
    Float,
    /// Integer fixed-point codes: value = code * 2^-frac.  `[lo, hi]` is
    /// the conservative code range the producing node can emit — the
    /// input to container selection (codes are *stored* in the narrowest
    /// of {1, 4, 8, 16, 32}-bit container covering the range, DESIGN.md
    /// §9).  `bipolar` marks a {-1, +1} code *set* — narrower than its
    /// range `[-1, 1]` suggests (no zero code), which is what licenses
    /// the 1-bit container and the XNOR kernels; it survives only
    /// through ops that preserve the code set.
    Int {
        frac: i32,
        lo: i64,
        hi: i64,
        bipolar: bool,
    },
}

/// Narrowest container ({1, 4, 8, 16, 32} bits) covering a code range —
/// the storage the packed kernels stream, as an attr value.  One shared
/// rule ([`crate::fixedpoint::container_bits_for_range`]): ranges beyond
/// i32 still map to 32, and the plan's checked conversions reject such
/// graphs at compile, exactly as the all-i32 datapath did.  `bipolar`
/// overrides to the 1-bit container — the range alone cannot see that 0
/// is unrepresented.
fn container_for(lo: i64, hi: i64, bipolar: bool) -> i64 {
    if bipolar {
        return 1;
    }
    crate::fixedpoint::container_bits_for_range(lo, hi) as i64
}

/// A single threshold emitting `q * 2 - 1` produces exactly {-1, +1} —
/// the bipolar/BNN quantizer (sign activation).  Detected at the
/// code-set level because the range-only container rule cannot classify
/// it (its span contains 0).
fn bipolar_threshold(k: i64, m: i64, add: i64) -> bool {
    k == 1 && m == 2 && add == -1
}

fn stream_fmt(fmt: &HashMap<String, BtFmt>, tensor: &str, node: &str) -> Result<BtFmt> {
    fmt.get(tensor).copied().ok_or_else(|| {
        anyhow!("bit-true annotate: node {node} reads {tensor}, which has no propagated format")
    })
}

fn int_frac(f: BtFmt, node: &str, what: &str) -> Result<i32> {
    Ok(int_range(f, node, what)?.0)
}

/// `(frac, lo, hi)` of an integer stream; error while still f32.
fn int_range(f: BtFmt, node: &str, what: &str) -> Result<(i32, i64, i64)> {
    match f {
        BtFmt::Int { frac, lo, hi, .. } => Ok((frac, lo, hi)),
        BtFmt::Float => bail!(
            "bit-true annotate: node {node}: {what} is still f32 — the ingress quantizer must precede it"
        ),
    }
}

/// Output code range of a threshold unit: `q in [0, K]` thresholds
/// crossed, scaled by the (odd, possibly negative) multiplier and offset.
fn threshold_range(k: i64, m: i64, add: i64) -> (i64, i64) {
    let a = add;
    let b = k * m + add;
    (a.min(b), a.max(b))
}

/// Split a float scale factor into `(odd multiplier m, fractional bits k)`
/// with `s = m * 2^-k` exactly.  Power-of-two scales — the entire Table-II
/// family — give `m = 1`, which is what makes the integer path *exactly*
/// equal to the f32 reference.
fn scale_to_mul_frac(s: f64, what: &str) -> Result<(i64, i32)> {
    let (mut m, mut e) =
        pow2_decompose(s).ok_or_else(|| anyhow!("{what}: scale {s} must be finite and nonzero"))?;
    while e > 0 {
        m <<= 1;
        e -= 1;
        if m.abs() > 1 << 30 {
            bail!("{what}: scale {s} too large for the integer datapath");
        }
    }
    if m.abs() > 1 << 24 {
        bail!(
            "{what}: scale {s} needs integer multiplier {m} — beyond the i32 datapath; use a (near-)dyadic scale"
        );
    }
    Ok((m, -e))
}

/// `out_bias` as an integer code on the output grid (must be exact).
fn bias_to_add(bias: f64, frac: i32, what: &str) -> Result<i64> {
    let scale = (2.0f64).powi(frac);
    let code = (bias * scale).round();
    if code / scale != bias {
        bail!("{what}: out_bias {bias} is off the 2^-{frac} output grid");
    }
    if code.abs() > i32::MAX as f64 {
        bail!("{what}: out_bias code {code} overflows i32");
    }
    Ok(code as i64)
}

/// Smallest frac putting every value of an initializer on an integer
/// grid (zero needs none; any f32 is a dyadic rational, so this always
/// exists — the guard rejects absurdly fine grids, i.e. unquantized data).
fn init_min_frac(t: &Tensor, what: &str) -> Result<i32> {
    let mut frac = 0i32;
    for &v in t.data() {
        if v == 0.0 {
            continue;
        }
        let Some((_, e)) = pow2_decompose(v as f64) else {
            bail!("{what}: initializer value {v} is not finite");
        };
        frac = frac.max(-e);
    }
    if frac > 24 {
        bail!("{what}: initializer needs a 2^-{frac} grid — requantize the graph before bit-true annotation");
    }
    Ok(frac)
}

/// Annotate a fully-lowered HW graph for bit-true integer execution.
///
/// The paper's premise is that the FPGA computes integer fixed-point
/// codes; the f32 executors only *simulate* that.  This pass walks the
/// graph ingress -> egress, propagates a fixed-point format per tensor,
/// and writes per-node `bt_*` attributes that
/// `plan::ExecutionPlan::compile_with(_, Datapath::BitTrue)` resolves
/// into typed slots and integer kernels:
///
/// * every float scale (a threshold unit's `out_scale`, the channelwise
///   scalar) is decomposed as `m * 2^-k` with odd `m` — exact, and `m = 1`
///   for the power-of-two scales the whole Table-II family produces;
/// * MVAU weight/bias grids are derived from the (requantized)
///   initializers; the accumulator format is `in_frac + w_frac`
///   fractional bits, chosen so bias codes are integral;
/// * ingress contract: feeds stay f32 through the (single) layout
///   Transpose and are quantized ONCE by the first threshold unit
///   (`bt_in_f32 = 1` — float *comparisons*, no float arithmetic);
/// * egress contract: graph outputs are integer codes carrying
///   `bt_out_frac` fractional bits; only the caller dequantizes;
/// * container selection: a conservative code range `[lo, hi]` is
///   propagated alongside the frac (threshold units emit `q in [0, K]`
///   scaled by `m` and offset by the bias code; GlobalAccPool multiplies
///   the range by the spatial extent; AddStreams sums the shifted
///   ranges; a raw MVAU accumulator spans the full i32 window), and
///   `bt_container` records the narrowest of {1, 4, 8, 16, 32} bits that
///   covers it — the storage width `plan` allocates and the packed
///   kernels stream.  `bt_bipolar` distinguishes the {-1, +1} 1-bit
///   code set (XNOR datapath) from binary {0, 1}.
///
/// Idempotent; fails on graphs that are not fully lowered or whose
/// scales/initializers cannot be represented on the integer datapath.
pub fn annotate_bit_true_formats(graph: &mut Graph) -> Result<()> {
    let order = graph.toposort_order()?;
    let mut fmt: HashMap<String, BtFmt> = HashMap::new();
    for input in &graph.inputs {
        fmt.insert(input.clone(), BtFmt::Float);
    }
    for &ni in &order {
        let (sets, out_fmt, out_name) = annotate_node(graph, ni, &fmt)?;
        let node = &mut graph.nodes[ni];
        for (key, val) in sets {
            node.attrs.set(key, AttrVal::Int(val));
        }
        fmt.insert(out_name, out_fmt);
    }
    Ok(())
}

/// The per-node annotation rules; returns the attrs to set, the output
/// format and the output tensor name (read-only phase — the caller
/// mutates).
fn annotate_node(
    graph: &Graph,
    ni: usize,
    fmt: &HashMap<String, BtFmt>,
) -> Result<(Vec<(&'static str, i64)>, BtFmt, String)> {
    let node = &graph.nodes[ni];
    if node.outputs.len() != 1 {
        bail!(
            "bit-true annotate: node {} has {} outputs; only single-output nodes are executable",
            node.name,
            node.outputs.len()
        );
    }
    let out_name = node.outputs[0].clone();
    let name = node.name.as_str();
    let mut sets: Vec<(&'static str, i64)> = Vec::new();
    let out_fmt = match node.op.as_str() {
        "Transpose" => {
            let f = stream_fmt(fmt, &node.inputs[0], name)?;
            match f {
                BtFmt::Float => sets.push(("bt_out_f32", 1)),
                BtFmt::Int {
                    frac, lo, hi, bipolar,
                } => {
                    sets.push(("bt_out_f32", 0));
                    sets.push(("bt_out_frac", frac as i64));
                    sets.push(("bt_container", container_for(lo, hi, bipolar)));
                    sets.push(("bt_bipolar", bipolar as i64));
                }
            }
            f
        }
        "MultiThreshold" | "Thresholding" => {
            let f_in = stream_fmt(fmt, &node.inputs[0], name)?;
            let thr = graph.initializers.get(&node.inputs[1]).ok_or_else(|| {
                anyhow!("bit-true annotate: {name}: threshold matrix must be an initializer")
            })?;
            let (m, f_out) = scale_to_mul_frac(node.attrs.float_or("out_scale", 1.0), name)?;
            let add = bias_to_add(node.attrs.float_or("out_bias", 0.0), f_out, name)?;
            let (lo, hi) = threshold_range(thr.shape()[1] as i64, m, add);
            let bipolar = bipolar_threshold(thr.shape()[1] as i64, m, add);
            sets.push(("bt_out_mul", m));
            sets.push(("bt_out_add", add));
            sets.push(("bt_out_frac", f_out as i64));
            sets.push(("bt_out_f32", 0));
            sets.push(("bt_container", container_for(lo, hi, bipolar)));
            sets.push(("bt_bipolar", bipolar as i64));
            match f_in {
                BtFmt::Float => sets.push(("bt_in_f32", 1)),
                BtFmt::Int { frac, .. } => {
                    sets.push(("bt_in_f32", 0));
                    sets.push(("bt_in_frac", frac as i64));
                }
            }
            BtFmt::Int {
                frac: f_out,
                lo,
                hi,
                bipolar,
            }
        }
        "MVAU" => {
            let fx = int_frac(stream_fmt(fmt, &node.inputs[0], name)?, name, "MVAU input")?;
            let w = graph.initializers.get(&node.inputs[1]).ok_or_else(|| {
                anyhow!("bit-true annotate: {name}: MVAU weight must be an initializer")
            })?;
            let bias_name = node
                .inputs
                .get(2)
                .ok_or_else(|| anyhow!("bit-true annotate: {name}: MVAU needs a bias input"))?;
            let bias = graph.initializers.get(bias_name).ok_or_else(|| {
                anyhow!("bit-true annotate: {name}: MVAU bias must be an initializer")
            })?;
            let w_min = init_min_frac(w, name)?;
            let b_min = init_min_frac(bias, name)?;
            // The accumulator grid (in_frac + w_frac) must also cover the
            // bias grid, or bias codes would be fractional.
            let w_frac = w_min.max(b_min - fx).max(0);
            let acc_frac = fx + w_frac;
            let apply_act = node.attrs.int_or("apply_act", 1) != 0;
            sets.push(("bt_in_frac", fx as i64));
            sets.push(("bt_w_frac", w_frac as i64));
            sets.push(("bt_acc_frac", acc_frac as i64));
            sets.push(("bt_out_f32", 0));
            if apply_act {
                let thr = node
                    .inputs
                    .get(3)
                    .and_then(|t| graph.initializers.get(t))
                    .ok_or_else(|| {
                        anyhow!(
                            "bit-true annotate: {name}: fused activation needs a threshold initializer"
                        )
                    })?;
                let (m, f_out) = scale_to_mul_frac(node.attrs.float_or("out_scale", 1.0), name)?;
                let add = bias_to_add(node.attrs.float_or("out_bias", 0.0), f_out, name)?;
                let (lo, hi) = threshold_range(thr.shape()[1] as i64, m, add);
                let bipolar = bipolar_threshold(thr.shape()[1] as i64, m, add);
                sets.push(("bt_out_mul", m));
                sets.push(("bt_out_add", add));
                sets.push(("bt_out_frac", f_out as i64));
                sets.push(("bt_container", container_for(lo, hi, bipolar)));
                sets.push(("bt_bipolar", bipolar as i64));
                BtFmt::Int {
                    frac: f_out,
                    lo,
                    hi,
                    bipolar,
                }
            } else {
                // Raw accumulator egress: the full i32 window.
                let (lo, hi) = (i32::MIN as i64, i32::MAX as i64);
                sets.push(("bt_out_mul", 1));
                sets.push(("bt_out_add", 0));
                sets.push(("bt_out_frac", acc_frac as i64));
                sets.push(("bt_container", 32));
                sets.push(("bt_bipolar", 0));
                BtFmt::Int {
                    frac: acc_frac,
                    lo,
                    hi,
                    bipolar: false,
                }
            }
        }
        "Im2Col" | "ConvolutionInputGenerator" => {
            let f_in = stream_fmt(fmt, &node.inputs[0], name)?;
            let (frac, lo, hi) = int_range(f_in, name, "stream input")?;
            // Zero padding injects code 0 into the stream — and breaks
            // bipolarity, since {-1, +1} has no zero code.  An unpadded
            // window preserves the incoming code set exactly.
            let padded = node
                .attrs
                .ints("pad")
                .map(|p| p.iter().any(|&v| v != 0))
                .unwrap_or(true);
            let (lo, hi) = if padded {
                (lo.min(0), hi.max(0))
            } else {
                (lo, hi)
            };
            let bipolar = !padded && matches!(f_in, BtFmt::Int { bipolar: true, .. });
            sets.push(("bt_out_f32", 0));
            sets.push(("bt_out_frac", frac as i64));
            sets.push(("bt_container", container_for(lo, hi, bipolar)));
            sets.push(("bt_bipolar", bipolar as i64));
            BtFmt::Int {
                frac,
                lo,
                hi,
                bipolar,
            }
        }
        "MaxPoolNHWC" | "StreamingMaxPool" => {
            let f_in = stream_fmt(fmt, &node.inputs[0], name)?;
            let (frac, lo, hi) = int_range(f_in, name, "stream input")?;
            // Max over a window picks an existing code: set-preserving.
            let bipolar = matches!(f_in, BtFmt::Int { bipolar: true, .. });
            sets.push(("bt_out_f32", 0));
            sets.push(("bt_out_frac", frac as i64));
            sets.push(("bt_container", container_for(lo, hi, bipolar)));
            sets.push(("bt_bipolar", bipolar as i64));
            BtFmt::Int {
                frac,
                lo,
                hi,
                bipolar,
            }
        }
        "GlobalAccPool" | "GlobalAccPool_hw" => {
            let (frac, lo, hi) = int_range(
                stream_fmt(fmt, &node.inputs[0], name)?,
                name,
                "stream input",
            )?;
            // Cumulative sum over the spatial extent scales the range.
            let in_shape = graph.shape_of(&node.inputs[0])?;
            if in_shape.len() != 4 {
                bail!("bit-true annotate: {name}: GlobalAccPool input must be 4-D NHWC");
            }
            let spatial = (in_shape[1] * in_shape[2]) as i64;
            let (lo, hi) = (lo.saturating_mul(spatial), hi.saturating_mul(spatial));
            sets.push(("bt_out_f32", 0));
            sets.push(("bt_out_frac", frac as i64));
            sets.push(("bt_container", container_for(lo, hi, false)));
            BtFmt::Int {
                frac,
                lo,
                hi,
                bipolar: false,
            }
        }
        "Add" | "AddStreams" => {
            let (fa, la, ha) = int_range(stream_fmt(fmt, &node.inputs[0], name)?, name, "lhs")?;
            let (fb, lb, hb) = int_range(stream_fmt(fmt, &node.inputs[1], name)?, name, "rhs")?;
            let f_out = fa.max(fb);
            let (sa, sb) = (f_out - fa, f_out - fb);
            if sa > 24 || sb > 24 {
                bail!("bit-true annotate: {name}: frac alignment shift {sa}/{sb} too large");
            }
            let (lo, hi) = ((la << sa) + (lb << sb), (ha << sa) + (hb << sb));
            sets.push(("bt_shift_a", sa as i64));
            sets.push(("bt_shift_b", sb as i64));
            sets.push(("bt_out_f32", 0));
            sets.push(("bt_out_frac", f_out as i64));
            sets.push(("bt_container", container_for(lo, hi, false)));
            BtFmt::Int {
                frac: f_out,
                lo,
                hi,
                bipolar: false,
            }
        }
        "Mul" | "ChannelwiseMul" => {
            if node.inputs.len() != 2 {
                bail!("bit-true annotate: {name}: Mul must have exactly 2 inputs");
            }
            let scalar_idx = node
                .inputs
                .iter()
                .position(|t| {
                    graph
                        .initializers
                        .get(t)
                        .map(|i| i.numel() == 1)
                        .unwrap_or(false)
                })
                .ok_or_else(|| {
                    anyhow!("bit-true annotate: {name}: Mul without a scalar initializer operand")
                })?;
            let data_idx = 1 - scalar_idx;
            let (f_in, la, ha) = int_range(
                stream_fmt(fmt, &node.inputs[data_idx], name)?,
                name,
                "Mul data input",
            )?;
            let s = graph.initializers[&node.inputs[scalar_idx]].data()[0] as f64;
            let (m, k) = scale_to_mul_frac(s, name)?;
            let (e1, e2) = (la.saturating_mul(m), ha.saturating_mul(m));
            let (lo, hi) = (e1.min(e2), e1.max(e2));
            sets.push(("bt_mul", m));
            sets.push(("bt_data_input", data_idx as i64));
            sets.push(("bt_out_f32", 0));
            sets.push(("bt_out_frac", (f_in + k) as i64));
            sets.push(("bt_container", container_for(lo, hi, false)));
            BtFmt::Int {
                frac: f_in + k,
                lo,
                hi,
                bipolar: false,
            }
        }
        other => bail!(
            "bit-true annotate: op {other} ({name}) has no integer-datapath mapping — is the graph fully lowered?"
        ),
    };
    Ok((sets, out_fmt, out_name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Attrs;
    use crate::tensor::Tensor;
    use crate::transforms::run_to_fixpoint;
    use std::collections::HashMap;

    /// NHWC: x -> MatMul(w) -> Add(b) -> MultiThreshold -> y
    fn mvau_pattern() -> Graph {
        let mut g = Graph::new("m");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["y".into()];
        g.shapes.insert("x".into(), vec![1, 2, 2, 3]);
        g.shapes.insert("w".into(), vec![3, 4]);
        g.shapes.insert("b".into(), vec![4]);
        g.shapes.insert("mm".into(), vec![1, 2, 2, 4]);
        g.shapes.insert("biased".into(), vec![1, 2, 2, 4]);
        g.shapes.insert("thr".into(), vec![1, 3]);
        g.shapes.insert("y".into(), vec![1, 2, 2, 4]);
        let mut rng = crate::rng::Rng::new(14);
        g.initializers
            .insert("w".into(), Tensor::from_fn(vec![3, 4], |_| rng.normal()));
        g.initializers
            .insert("b".into(), Tensor::from_fn(vec![4], |_| rng.normal()));
        g.initializers.insert(
            "thr".into(),
            Tensor::new(vec![1, 3], vec![0.25, 0.75, 1.25]).unwrap(),
        );
        g.nodes.push(Node::new(
            "MatMul",
            "l0_matmul",
            vec!["x".into(), "w".into()],
            vec!["mm".into()],
        ));
        g.nodes.push(Node::new(
            "Add",
            "l0_bias",
            vec!["mm".into(), "b".into()],
            vec!["biased".into()],
        ));
        g.nodes.push(
            Node::new(
                "MultiThreshold",
                "l0_quant",
                vec!["biased".into(), "thr".into()],
                vec!["y".into()],
            )
            .with_attrs(
                Attrs::new()
                    .with("data_layout", AttrVal::Str("NHWC".into()))
                    .with("out_scale", AttrVal::Float(0.25)),
            ),
        );
        g
    }

    #[test]
    fn fuses_matmul_bias_mt_into_mvau() {
        let mut g = mvau_pattern();
        let mut rng = crate::rng::Rng::new(31);
        let mut feeds = HashMap::new();
        feeds.insert(
            "x".to_string(),
            Tensor::from_fn(vec![1, 2, 2, 3], |_| rng.normal()),
        );
        let want = crate::ops::execute(&g, &feeds).unwrap()["y"].clone();
        run_to_fixpoint(&mut g, &ConvertToHwLayers).unwrap();
        assert_eq!(g.count_op("MVAU"), 1);
        assert_eq!(g.count_op("MatMul"), 0);
        assert_eq!(g.count_op("Add"), 0);
        assert_eq!(g.count_op("MultiThreshold"), 0);
        let mvau = g.nodes.iter().find(|n| n.op == "MVAU").unwrap();
        assert_eq!(mvau.attrs.int("apply_act").unwrap(), 1);
        assert_eq!(mvau.inputs.len(), 4);
        let got = crate::ops::execute(&g, &feeds).unwrap()["y"].clone();
        assert_eq!(got, want);
        g.validate().unwrap();
    }

    #[test]
    fn matmul_bias_without_mt_becomes_raw_mvau() {
        let mut g = mvau_pattern();
        // Cut the MT off: route graph output from `biased`.
        g.nodes.pop();
        g.outputs = vec!["biased".into()];
        g.shapes.remove(&"y".to_string());
        let mut rng = crate::rng::Rng::new(32);
        let mut feeds = HashMap::new();
        feeds.insert(
            "x".to_string(),
            Tensor::from_fn(vec![1, 2, 2, 3], |_| rng.normal()),
        );
        let want = crate::ops::execute(&g, &feeds).unwrap()["biased"].clone();
        run_to_fixpoint(&mut g, &ConvertToHwLayers).unwrap();
        let mvau = g.nodes.iter().find(|n| n.op == "MVAU").unwrap();
        assert_eq!(mvau.attrs.int("apply_act").unwrap(), 0);
        assert_eq!(mvau.inputs.len(), 3);
        let got = crate::ops::execute(&g, &feeds).unwrap()["biased"].clone();
        assert_eq!(got, want);
    }

    #[test]
    fn renames_and_hw_predicate() {
        let mut g = Graph::new("r");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["y".into()];
        g.shapes.insert("x".into(), vec![1, 4, 4, 2]);
        g.shapes.insert("p".into(), vec![1, 2, 2, 2]);
        g.shapes.insert("y".into(), vec![1, 2]);
        g.nodes.push(
            Node::new("MaxPoolNHWC", "mp", vec!["x".into()], vec!["p".into()]).with_attrs(
                Attrs::new()
                    .with("kernel", AttrVal::Ints(vec![2, 2]))
                    .with("stride", AttrVal::Ints(vec![2, 2])),
            ),
        );
        g.nodes.push(Node::new(
            "GlobalAccPool",
            "gap",
            vec!["p".into()],
            vec!["y".into()],
        ));
        assert!(!is_fully_hw(&g));
        run_to_fixpoint(&mut g, &ConvertToHwLayers).unwrap();
        assert_eq!(g.count_op("StreamingMaxPool"), 1);
        assert_eq!(g.count_op("GlobalAccPool_hw"), 1);
        assert!(is_fully_hw(&g));
    }

    #[test]
    fn annotate_bit_true_sets_formats_on_lowered_backbone() {
        let mut g = crate::build::synth_backbone_graph([4, 8, 8, 16], 16, 4, 2);
        crate::build::requantize_graph(&mut g, &crate::fixedpoint::headline_config()).unwrap();
        crate::transforms::run_default_pipeline(&mut g, None, 0.0).unwrap();
        assert!(is_fully_hw(&g));
        annotate_bit_true_formats(&mut g).unwrap();

        // Every node carries an output format; exactly one threshold unit
        // is the f32 ingress quantizer (the input u8.8 quantizer).
        let mut ingress = 0;
        for n in &g.nodes {
            assert!(
                n.attrs.int("bt_out_f32").is_ok(),
                "node {} ({}) not annotated",
                n.name,
                n.op
            );
            if n.attrs.int_or("bt_out_f32", 0) == 0 {
                let cont = n.attrs.int("bt_container").unwrap_or_else(|_| {
                    panic!("node {} ({}) lacks bt_container", n.name, n.op)
                });
                assert!(
                    [1, 4, 8, 16, 32].contains(&cont),
                    "{}: container {cont}",
                    n.name
                );
            }
            if n.op == "Thresholding" && n.attrs.int_or("bt_in_f32", 0) != 0 {
                ingress += 1;
                // The camera quantizer emits u8.8 codes: frac 8, q = code,
                // range [0, 255] -> an i16 container.
                assert_eq!(n.attrs.int("bt_out_frac").unwrap(), 8);
                assert_eq!(n.attrs.int("bt_out_mul").unwrap(), 1);
                assert_eq!(n.attrs.int("bt_container").unwrap(), 16);
            }
            if n.op == "MVAU" {
                let fx = n.attrs.int("bt_in_frac").unwrap();
                let fw = n.attrs.int("bt_w_frac").unwrap();
                assert_eq!(n.attrs.int("bt_acc_frac").unwrap(), fx + fw);
                // Headline config: s6.5 weights -> at most 5 frac bits.
                assert!(fw <= 5, "MVAU {} w_frac {fw}", n.name);
                // u4.2 activations: q in [0, 15] -> a packed u4 container,
                // two codes per byte.
                if n.attrs.int_or("apply_act", 1) != 0 {
                    assert_eq!(
                        n.attrs.int("bt_container").unwrap(),
                        4,
                        "MVAU {} activation codes should pack into u4",
                        n.name
                    );
                    assert_eq!(n.attrs.int("bt_bipolar").unwrap(), 0);
                }
            }
        }
        assert_eq!(ingress, 1, "exactly one ingress quantizer expected");

        // Idempotent: a second pass computes identical attrs.
        let before: Vec<_> = g.nodes.iter().map(|n| n.attrs.clone()).collect();
        annotate_bit_true_formats(&mut g).unwrap();
        let after: Vec<_> = g.nodes.iter().map(|n| n.attrs.clone()).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn annotate_bit_true_rejects_unlowered_graph() {
        let mut g = crate::build::synth_backbone_graph([4, 8, 8, 16], 16, 4, 2);
        let err = annotate_bit_true_formats(&mut g).unwrap_err().to_string();
        assert!(err.contains("no integer-datapath mapping"), "{err}");
    }

    #[test]
    fn scale_decomposition_handles_dyadic_and_odd_scales() {
        assert_eq!(scale_to_mul_frac(0.25, "t").unwrap(), (1, 2));
        assert_eq!(scale_to_mul_frac(1.0, "t").unwrap(), (1, 0));
        assert_eq!(scale_to_mul_frac(6.0, "t").unwrap(), (6, 0));
        let (m, k) = scale_to_mul_frac(0.75, "t").unwrap();
        assert_eq!((m, k), (3, 2));
        assert!(scale_to_mul_frac(0.0, "t").is_err());
        // out_bias must land on the output grid exactly.
        assert_eq!(bias_to_add(-0.5, 1, "t").unwrap(), -1);
        assert!(bias_to_add(0.3, 1, "t").is_err());
    }

    #[test]
    fn container_selection_rule() {
        assert_eq!(container_for(0, 1, false), 1);
        assert_eq!(container_for(0, 15, false), 4);
        assert_eq!(container_for(-8, 7, false), 8);
        assert_eq!(container_for(-128, 127, false), 8);
        assert_eq!(container_for(0, 16, false), 8);
        assert_eq!(container_for(0, 128, false), 16);
        assert_eq!(container_for(-129, 0, false), 16);
        assert_eq!(container_for(0, 255, false), 16);
        assert_eq!(container_for(-32768, 32767, false), 16);
        assert_eq!(container_for(0, 32768, false), 32);
        assert_eq!(container_for(i32::MIN as i64, i32::MAX as i64, false), 32);
        // Beyond-i32 ranges still report 32 (the plan's checked stores
        // reject them at conversion, exactly as the i32 datapath did).
        assert_eq!(container_for(0, 1 << 40, false), 32);
        // Bipolar overrides the range rule ([-1, 1] spans 0, but the
        // code set does not contain it).
        assert_eq!(container_for(-1, 1, false), 8);
        assert_eq!(container_for(-1, 1, true), 1);
        // Threshold output ranges, including a negative multiplier.
        assert_eq!(threshold_range(15, 1, 0), (0, 15));
        assert_eq!(threshold_range(3, -5, 2), (-13, 2));
        // The bipolar quantizer shape: one threshold, q*2 - 1.
        assert!(bipolar_threshold(1, 2, -1));
        assert!(!bipolar_threshold(2, 2, -1));
        assert!(!bipolar_threshold(1, 1, 0));
    }

    #[test]
    fn non_dyadic_scale_count_flags_odd_multipliers() {
        let mut g = mvau_pattern();
        // out_scale 0.25 is dyadic: nothing flagged.
        assert_eq!(non_dyadic_scale_count(&g), 0);
        // 0.75 = 3 * 2^-2 needs m = 3: exact on the integer path, f32-
        // divergent by design — flagged.
        let mt = g
            .nodes
            .iter_mut()
            .find(|n| n.op == "MultiThreshold")
            .unwrap();
        mt.attrs.set("out_scale", AttrVal::Float(0.75));
        assert_eq!(non_dyadic_scale_count(&g), 1);
        // A non-dyadic scalar Mul initializer counts too.
        g.shapes.insert("odd_s".into(), vec![]);
        g.initializers
            .insert("odd_s".into(), Tensor::scalar(3.0));
        g.shapes.insert("z".into(), vec![1, 2, 2, 4]);
        g.nodes.push(Node::new(
            "Mul",
            "oddmul",
            vec!["y".into(), "odd_s".into()],
            vec!["z".into()],
        ));
        assert_eq!(non_dyadic_scale_count(&g), 2);
    }

    #[test]
    fn stream_add_becomes_addstreams_but_bias_add_does_not() {
        let mut g = Graph::new("a");
        g.inputs = vec!["a".into(), "b".into()];
        g.outputs = vec!["y".into()];
        for t in ["a", "b", "y"] {
            g.shapes.insert(t.into(), vec![1, 4]);
        }
        g.nodes.push(Node::new(
            "Add",
            "resadd",
            vec!["a".into(), "b".into()],
            vec!["y".into()],
        ));
        run_to_fixpoint(&mut g, &ConvertToHwLayers).unwrap();
        assert_eq!(g.count_op("AddStreams"), 1);
    }
}
