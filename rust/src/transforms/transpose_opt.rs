//! Transpose-node optimization — the paper's §III-C contribution (Fig. 4).
//!
//! Conv lowering leaves the graph littered with NCHW<->NHWC Transposes:
//! the conv-lowered MatMul outputs NHWC while the following MultiThreshold
//! (and MaxPool / residual Add / ReduceMean) still expects NCHW.  In the
//! paper this mismatch "prevented the proper transfer of weights to the
//! MVAU"; the fix is `AbsorbTransposeIntoMultiThreshold`: merge the
//! Transpose into the MultiThreshold (re-typing it to NHWC) and re-insert
//! a Transpose *after* it.  The companion move/compose/cancel passes then
//! push every re-inserted Transpose down the graph until adjacent pairs
//! annihilate, leaving a single layout conversion at the graph input.

use anyhow::Result;

use super::lower_conv::{TO_NCHW, TO_NHWC};
use super::Transform;
use crate::graph::{AttrVal, Attrs, Graph, Node};

fn perm_of(node: &Node) -> Option<Vec<i64>> {
    node.attrs.ints("perm").ok()
}

fn is_to_nchw(node: &Node) -> bool {
    node.op == "Transpose" && perm_of(node).as_deref() == Some(&TO_NCHW)
}

fn is_to_nhwc(node: &Node) -> bool {
    node.op == "Transpose" && perm_of(node).as_deref() == Some(&TO_NHWC)
}

/// Permute a shape by a transpose perm.
fn permute(shape: &[usize], perm: &[i64]) -> Vec<usize> {
    perm.iter().map(|&p| shape[p as usize]).collect()
}

/// §III-C: `Transpose(NHWC->NCHW) -> MultiThreshold(NCHW)` ==>
/// `MultiThreshold(NHWC) -> Transpose(NHWC->NCHW)`.
///
/// The MultiThreshold itself is layout-agnostic up to the channel-axis
/// attribute, so absorbing the Transpose is exact; the re-inserted
/// Transpose keeps downstream NCHW consumers working until the move
/// passes clean them up (paper: "inserting a Transpose node afterward").
pub struct AbsorbTransposeIntoMultiThreshold;

impl Transform for AbsorbTransposeIntoMultiThreshold {
    fn name(&self) -> &'static str {
        "AbsorbTransposeIntoMultiThreshold"
    }

    fn apply(&self, graph: &mut Graph) -> Result<bool> {
        for t_idx in 0..graph.nodes.len() {
            if !is_to_nchw(&graph.nodes[t_idx]) {
                continue;
            }
            let t_out = graph.nodes[t_idx].outputs[0].clone();
            let consumers = graph.consumers(&t_out);
            if consumers.len() != 1 {
                continue;
            }
            let mt_idx = consumers[0];
            if graph.nodes[mt_idx].op != "MultiThreshold"
                || graph.nodes[mt_idx].attrs.str_or("data_layout", "NCHW") != "NCHW"
            {
                continue;
            }
            let x_nhwc = graph.nodes[t_idx].inputs[0].clone();
            let thresh = graph.nodes[mt_idx].inputs[1].clone();
            let mt_out = graph.nodes[mt_idx].outputs[0].clone();
            let nhwc_shape = graph.shape_of(&x_nhwc)?.to_vec();
            let mt_name = graph.nodes[mt_idx].name.clone();
            let mut attrs = graph.nodes[mt_idx].attrs.clone();
            attrs.set("data_layout", AttrVal::Str("NHWC".into()));

            let new_out = graph.fresh_tensor(&format!("{mt_name}_nhwc"), nhwc_shape);
            let new_mt = Node::new(
                "MultiThreshold",
                &mt_name,
                vec![x_nhwc, thresh],
                vec![new_out.clone()],
            )
            .with_attrs(attrs);
            let new_t = Node::new(
                "Transpose",
                &format!("{mt_name}_to_nchw"),
                vec![new_out],
                vec![mt_out],
            )
            .with_attrs(Attrs::new().with("perm", AttrVal::Ints(TO_NCHW.to_vec())));

            graph.remove_nodes(vec![t_idx, mt_idx]);
            graph.shapes.remove(&t_out);
            graph.nodes.push(new_mt);
            graph.nodes.push(new_t);
            graph.toposort()?;
            return Ok(true);
        }
        Ok(false)
    }
}

/// `MultiThreshold(NCHW) -> Transpose(NCHW->NHWC)` ==>
/// `Transpose -> MultiThreshold(NHWC)` — floats the input-quantizer's
/// layout conversion to the very top of the graph.
pub struct MoveTransposePastMultiThreshold;

impl Transform for MoveTransposePastMultiThreshold {
    fn name(&self) -> &'static str {
        "MoveTransposePastMultiThreshold"
    }

    fn apply(&self, graph: &mut Graph) -> Result<bool> {
        for mt_idx in 0..graph.nodes.len() {
            if graph.nodes[mt_idx].op != "MultiThreshold"
                || graph.nodes[mt_idx].attrs.str_or("data_layout", "NCHW") != "NCHW"
            {
                continue;
            }
            let mt_out = graph.nodes[mt_idx].outputs[0].clone();
            let consumers = graph.consumers(&mt_out);
            if consumers.len() != 1 || !is_to_nhwc(&graph.nodes[consumers[0]]) {
                continue;
            }
            let t_idx = consumers[0];
            let x_nchw = graph.nodes[mt_idx].inputs[0].clone();
            let thresh = graph.nodes[mt_idx].inputs[1].clone();
            let t_out = graph.nodes[t_idx].outputs[0].clone();
            let mt_name = graph.nodes[mt_idx].name.clone();
            let nchw_shape = graph.shape_of(&x_nchw)?.to_vec();
            let nhwc_shape = permute(&nchw_shape, &TO_NHWC);
            let mut attrs = graph.nodes[mt_idx].attrs.clone();
            attrs.set("data_layout", AttrVal::Str("NHWC".into()));

            let x_nhwc = graph.fresh_tensor(&format!("{mt_name}_in_nhwc"), nhwc_shape);
            let new_t = Node::new(
                "Transpose",
                &format!("{mt_name}_to_nhwc"),
                vec![x_nchw],
                vec![x_nhwc.clone()],
            )
            .with_attrs(Attrs::new().with("perm", AttrVal::Ints(TO_NHWC.to_vec())));
            let new_mt =
                Node::new("MultiThreshold", &mt_name, vec![x_nhwc, thresh], vec![t_out])
                    .with_attrs(attrs);

            graph.remove_nodes(vec![mt_idx, t_idx]);
            graph.shapes.remove(&mt_out);
            graph.nodes.push(new_t);
            graph.nodes.push(new_mt);
            graph.toposort()?;
            return Ok(true);
        }
        Ok(false)
    }
}

/// `Transpose(NHWC->NCHW) -> MaxPool(NCHW)` ==>
/// `MaxPoolNHWC -> Transpose(NHWC->NCHW)`.
pub struct MoveTransposePastMaxPool;

impl Transform for MoveTransposePastMaxPool {
    fn name(&self) -> &'static str {
        "MoveTransposePastMaxPool"
    }

    fn apply(&self, graph: &mut Graph) -> Result<bool> {
        for t_idx in 0..graph.nodes.len() {
            if !is_to_nchw(&graph.nodes[t_idx]) {
                continue;
            }
            let t_out = graph.nodes[t_idx].outputs[0].clone();
            let consumers = graph.consumers(&t_out);
            if consumers.len() != 1 || graph.nodes[consumers[0]].op != "MaxPool" {
                continue;
            }
            let mp_idx = consumers[0];
            let x_nhwc = graph.nodes[t_idx].inputs[0].clone();
            let mp_out = graph.nodes[mp_idx].outputs[0].clone();
            let mp_name = graph.nodes[mp_idx].name.clone();
            let mp_attrs = graph.nodes[mp_idx].attrs.clone();
            let out_nchw_shape = graph.shape_of(&mp_out)?.to_vec();
            let out_nhwc_shape = permute(&out_nchw_shape, &TO_NHWC);

            let pooled = graph.fresh_tensor(&format!("{mp_name}_nhwc"), out_nhwc_shape);
            let new_mp = Node::new("MaxPoolNHWC", &mp_name, vec![x_nhwc], vec![pooled.clone()])
                .with_attrs(mp_attrs);
            let new_t = Node::new(
                "Transpose",
                &format!("{mp_name}_to_nchw"),
                vec![pooled],
                vec![mp_out],
            )
            .with_attrs(Attrs::new().with("perm", AttrVal::Ints(TO_NCHW.to_vec())));

            graph.remove_nodes(vec![t_idx, mp_idx]);
            graph.shapes.remove(&t_out);
            graph.nodes.push(new_mp);
            graph.nodes.push(new_t);
            graph.toposort()?;
            return Ok(true);
        }
        Ok(false)
    }
}

/// `Add(Transpose(a), Transpose(b))` with equal perms ==>
/// `Transpose(Add(a, b))` — the residual-connection case.  The original
/// Transposes stay if they feed other consumers (DeadNodeElimination
/// sweeps them otherwise).
pub struct MoveTransposePastEltwiseAdd;

impl Transform for MoveTransposePastEltwiseAdd {
    fn name(&self) -> &'static str {
        "MoveTransposePastEltwiseAdd"
    }

    fn apply(&self, graph: &mut Graph) -> Result<bool> {
        for add_idx in 0..graph.nodes.len() {
            if graph.nodes[add_idx].op != "Add" || graph.nodes[add_idx].inputs.len() != 2 {
                continue;
            }
            let a_t = graph.nodes[add_idx].inputs[0].clone();
            let b_t = graph.nodes[add_idx].inputs[1].clone();
            let (Some(pa_idx), Some(pb_idx)) = (graph.producer(&a_t), graph.producer(&b_t))
            else {
                continue;
            };
            if !is_to_nchw(&graph.nodes[pa_idx]) || !is_to_nchw(&graph.nodes[pb_idx]) {
                continue;
            }
            let a = graph.nodes[pa_idx].inputs[0].clone();
            let b = graph.nodes[pb_idx].inputs[0].clone();
            let add_out = graph.nodes[add_idx].outputs[0].clone();
            let add_name = graph.nodes[add_idx].name.clone();
            let nhwc_shape = graph.shape_of(&a)?.to_vec();

            let sum_nhwc = graph.fresh_tensor(&format!("{add_name}_nhwc"), nhwc_shape);
            let new_add = Node::new("Add", &add_name, vec![a, b], vec![sum_nhwc.clone()]);
            let new_t = Node::new(
                "Transpose",
                &format!("{add_name}_to_nchw"),
                vec![sum_nhwc],
                vec![add_out],
            )
            .with_attrs(Attrs::new().with("perm", AttrVal::Ints(TO_NCHW.to_vec())));

            graph.remove_nodes(vec![add_idx]);
            graph.nodes.push(new_add);
            graph.nodes.push(new_t);
            graph.toposort()?;
            return Ok(true);
        }
        Ok(false)
    }
}

/// Compose `Transpose -> Transpose` into one Transpose (when the
/// intermediate tensor has no other consumer).
pub struct ComposeAdjacentTransposes;

impl Transform for ComposeAdjacentTransposes {
    fn name(&self) -> &'static str {
        "ComposeAdjacentTransposes"
    }

    fn apply(&self, graph: &mut Graph) -> Result<bool> {
        for i in 0..graph.nodes.len() {
            if graph.nodes[i].op != "Transpose" {
                continue;
            }
            let mid = graph.nodes[i].outputs[0].clone();
            let consumers = graph.consumers(&mid);
            if consumers.len() != 1 || graph.nodes[consumers[0]].op != "Transpose" {
                continue;
            }
            let j = consumers[0];
            let p1 = perm_of(&graph.nodes[i]).unwrap();
            let p2 = perm_of(&graph.nodes[j]).unwrap();
            // Output axis a of the pair reads input axis p1[p2[a]].
            let composed: Vec<i64> = p2.iter().map(|&a| p1[a as usize]).collect();
            let x = graph.nodes[i].inputs[0].clone();
            let y = graph.nodes[j].outputs[0].clone();
            let name = graph.nodes[j].name.clone();
            let new_t = Node::new("Transpose", &name, vec![x], vec![y])
                .with_attrs(Attrs::new().with("perm", AttrVal::Ints(composed)));
            graph.remove_nodes(vec![i, j]);
            graph.shapes.remove(&mid);
            graph.nodes.push(new_t);
            graph.toposort()?;
            return Ok(true);
        }
        Ok(false)
    }
}

/// Remove identity-perm Transposes by rewiring consumers (kept if the
/// output is a graph output — names must stay stable).
pub struct RemoveIdentityTranspose;

impl Transform for RemoveIdentityTranspose {
    fn name(&self) -> &'static str {
        "RemoveIdentityTranspose"
    }

    fn apply(&self, graph: &mut Graph) -> Result<bool> {
        for i in 0..graph.nodes.len() {
            if graph.nodes[i].op != "Transpose" {
                continue;
            }
            let perm = perm_of(&graph.nodes[i]).unwrap_or_default();
            if !perm.iter().enumerate().all(|(a, &p)| a as i64 == p) {
                continue;
            }
            let out = graph.nodes[i].outputs[0].clone();
            if graph.outputs.contains(&out) {
                continue;
            }
            let x = graph.nodes[i].inputs[0].clone();
            for c in graph.consumers(&out) {
                for input in &mut graph.nodes[c].inputs {
                    if *input == out {
                        *input = x.clone();
                    }
                }
            }
            graph.remove_nodes(vec![i]);
            graph.shapes.remove(&out);
            return Ok(true);
        }
        Ok(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::transforms::run_to_fixpoint;
    use std::collections::HashMap;

    fn feeds_nhwc() -> HashMap<String, Tensor> {
        let mut rng = crate::rng::Rng::new(3);
        let mut feeds = HashMap::new();
        feeds.insert(
            "x".to_string(),
            Tensor::from_fn(vec![1, 4, 4, 2], |_| rng.normal() + 1.0),
        );
        feeds
    }

    /// x(NHWC) -> Transpose(NCHW) -> MultiThreshold(NCHW) -> y(NCHW)
    fn absorb_graph() -> Graph {
        let mut g = Graph::new("a");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["y".into()];
        g.shapes.insert("x".into(), vec![1, 4, 4, 2]);
        g.shapes.insert("xt".into(), vec![1, 2, 4, 4]);
        g.shapes.insert("thr".into(), vec![2, 3]);
        g.shapes.insert("y".into(), vec![1, 2, 4, 4]);
        g.initializers.insert(
            "thr".into(),
            Tensor::new(vec![2, 3], vec![0.25, 0.5, 1.0, 0.5, 1.0, 2.0]).unwrap(),
        );
        g.nodes.push(
            Node::new("Transpose", "t0", vec!["x".into()], vec!["xt".into()]).with_attrs(
                Attrs::new().with("perm", AttrVal::Ints(TO_NCHW.to_vec())),
            ),
        );
        g.nodes.push(
            Node::new(
                "MultiThreshold",
                "mt0",
                vec!["xt".into(), "thr".into()],
                vec!["y".into()],
            )
            .with_attrs(
                Attrs::new()
                    .with("data_layout", AttrVal::Str("NCHW".into()))
                    .with("out_scale", AttrVal::Float(0.5)),
            ),
        );
        g
    }

    #[test]
    fn absorb_transpose_into_multithreshold() {
        // The paper's Fig. 4 rewrite, checked for exact semantics.
        let mut g = absorb_graph();
        let feeds = feeds_nhwc();
        let want = crate::ops::execute(&g, &feeds).unwrap()["y"].clone();
        let n = run_to_fixpoint(&mut g, &AbsorbTransposeIntoMultiThreshold).unwrap();
        assert_eq!(n, 1);
        // MT is now NHWC and comes BEFORE the (re-inserted) Transpose.
        let mt = g.node_by_name("mt0").unwrap();
        assert_eq!(mt.attrs.str("data_layout").unwrap(), "NHWC");
        let mt_pos = g.nodes.iter().position(|n| n.name == "mt0").unwrap();
        let t_pos = g.nodes.iter().position(|n| n.op == "Transpose").unwrap();
        assert!(mt_pos < t_pos);
        let got = crate::ops::execute(&g, &feeds).unwrap()["y"].clone();
        assert_eq!(got, want);
        g.validate().unwrap();
    }

    #[test]
    fn absorb_requires_single_consumer() {
        let mut g = absorb_graph();
        // Second consumer of the transposed tensor blocks the rewrite.
        g.shapes.insert("z".into(), vec![1, 2, 4, 4]);
        g.shapes.insert("s".into(), vec![]);
        g.initializers.insert("s".into(), Tensor::scalar(2.0));
        g.nodes.push(Node::new(
            "Mul",
            "m",
            vec!["xt".into(), "s".into()],
            vec!["z".into()],
        ));
        g.outputs.push("z".into());
        let n = run_to_fixpoint(&mut g, &AbsorbTransposeIntoMultiThreshold).unwrap();
        assert_eq!(n, 0);
    }

    #[test]
    fn compose_and_remove_identity() {
        // NHWC->NCHW then NCHW->NHWC composes to identity and disappears.
        let mut g = Graph::new("c");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["y".into()];
        g.shapes.insert("x".into(), vec![1, 4, 4, 2]);
        g.shapes.insert("t1".into(), vec![1, 2, 4, 4]);
        g.shapes.insert("t2".into(), vec![1, 4, 4, 2]);
        g.shapes.insert("s".into(), vec![]);
        g.shapes.insert("y".into(), vec![1, 4, 4, 2]);
        g.initializers.insert("s".into(), Tensor::scalar(3.0));
        g.nodes.push(
            Node::new("Transpose", "a", vec!["x".into()], vec!["t1".into()])
                .with_attrs(Attrs::new().with("perm", AttrVal::Ints(TO_NCHW.to_vec()))),
        );
        g.nodes.push(
            Node::new("Transpose", "b", vec!["t1".into()], vec!["t2".into()])
                .with_attrs(Attrs::new().with("perm", AttrVal::Ints(TO_NHWC.to_vec()))),
        );
        g.nodes.push(Node::new(
            "Mul",
            "m",
            vec!["t2".into(), "s".into()],
            vec!["y".into()],
        ));
        let feeds = feeds_nhwc();
        let want = crate::ops::execute(&g, &feeds).unwrap()["y"].clone();
        run_to_fixpoint(&mut g, &ComposeAdjacentTransposes).unwrap();
        assert_eq!(g.count_op("Transpose"), 1);
        run_to_fixpoint(&mut g, &RemoveIdentityTranspose).unwrap();
        assert_eq!(g.count_op("Transpose"), 0);
        let got = crate::ops::execute(&g, &feeds).unwrap()["y"].clone();
        assert_eq!(got, want);
        g.validate().unwrap();
    }

    #[test]
    fn move_transpose_past_maxpool() {
        let mut g = Graph::new("p");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["y".into()];
        g.shapes.insert("x".into(), vec![1, 4, 4, 2]);
        g.shapes.insert("xt".into(), vec![1, 2, 4, 4]);
        g.shapes.insert("y".into(), vec![1, 2, 2, 2]);
        g.nodes.push(
            Node::new("Transpose", "t", vec!["x".into()], vec!["xt".into()])
                .with_attrs(Attrs::new().with("perm", AttrVal::Ints(TO_NCHW.to_vec()))),
        );
        g.nodes.push(
            Node::new("MaxPool", "mp", vec!["xt".into()], vec!["y".into()]).with_attrs(
                Attrs::new()
                    .with("kernel", AttrVal::Ints(vec![2, 2]))
                    .with("stride", AttrVal::Ints(vec![2, 2])),
            ),
        );
        let feeds = feeds_nhwc();
        let want = crate::ops::execute(&g, &feeds).unwrap()["y"].clone();
        let n = run_to_fixpoint(&mut g, &MoveTransposePastMaxPool).unwrap();
        assert_eq!(n, 1);
        assert_eq!(g.count_op("MaxPoolNHWC"), 1);
        let got = crate::ops::execute(&g, &feeds).unwrap()["y"].clone();
        assert_eq!(got, want);
        g.validate().unwrap();
    }

    #[test]
    fn move_transpose_past_residual_add() {
        let mut g = Graph::new("r");
        g.inputs = vec!["a".into(), "b".into()];
        g.outputs = vec!["y".into()];
        for t in ["a", "b"] {
            g.shapes.insert(t.into(), vec![1, 4, 4, 2]);
        }
        g.shapes.insert("at".into(), vec![1, 2, 4, 4]);
        g.shapes.insert("bt".into(), vec![1, 2, 4, 4]);
        g.shapes.insert("y".into(), vec![1, 2, 4, 4]);
        for (n, (i, o)) in [("ta", ("a", "at")), ("tb", ("b", "bt"))] {
            g.nodes.push(
                Node::new("Transpose", n, vec![i.into()], vec![o.into()]).with_attrs(
                    Attrs::new().with("perm", AttrVal::Ints(TO_NCHW.to_vec())),
                ),
            );
        }
        g.nodes.push(Node::new(
            "Add",
            "add",
            vec!["at".into(), "bt".into()],
            vec!["y".into()],
        ));
        let mut rng = crate::rng::Rng::new(8);
        let mut feeds = HashMap::new();
        feeds.insert("a".to_string(), Tensor::from_fn(vec![1, 4, 4, 2], |_| rng.normal()));
        feeds.insert("b".to_string(), Tensor::from_fn(vec![1, 4, 4, 2], |_| rng.normal()));
        let want = crate::ops::execute(&g, &feeds).unwrap()["y"].clone();
        let n = run_to_fixpoint(&mut g, &MoveTransposePastEltwiseAdd).unwrap();
        assert_eq!(n, 1);
        // Add now operates NHWC; old transposes become dead.
        run_to_fixpoint(&mut g, &crate::transforms::streamline::DeadNodeElimination).unwrap();
        assert_eq!(g.count_op("Transpose"), 1); // only the re-inserted one
        let got = crate::ops::execute(&g, &feeds).unwrap()["y"].clone();
        assert_eq!(got, want);
        g.validate().unwrap();
    }

    #[test]
    fn move_transpose_past_multithreshold_floats_input_conversion() {
        // MT(NCHW) -> Transpose(->NHWC) becomes Transpose -> MT(NHWC).
        let mut g = Graph::new("m");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["y".into()];
        g.shapes.insert("x".into(), vec![1, 2, 4, 4]);
        g.shapes.insert("q".into(), vec![1, 2, 4, 4]);
        g.shapes.insert("thr".into(), vec![1, 2]);
        g.shapes.insert("y".into(), vec![1, 4, 4, 2]);
        g.initializers
            .insert("thr".into(), Tensor::new(vec![1, 2], vec![0.5, 1.5]).unwrap());
        g.nodes.push(
            Node::new(
                "MultiThreshold",
                "mt",
                vec!["x".into(), "thr".into()],
                vec!["q".into()],
            )
            .with_attrs(Attrs::new().with("data_layout", AttrVal::Str("NCHW".into()))),
        );
        g.nodes.push(
            Node::new("Transpose", "t", vec!["q".into()], vec!["y".into()])
                .with_attrs(Attrs::new().with("perm", AttrVal::Ints(TO_NHWC.to_vec()))),
        );
        let mut rng = crate::rng::Rng::new(4);
        let mut feeds = HashMap::new();
        feeds.insert("x".to_string(), Tensor::from_fn(vec![1, 2, 4, 4], |_| rng.normal() + 1.0));
        let want = crate::ops::execute(&g, &feeds).unwrap()["y"].clone();
        let n = run_to_fixpoint(&mut g, &MoveTransposePastMultiThreshold).unwrap();
        assert_eq!(n, 1);
        assert_eq!(g.nodes[0].op, "Transpose"); // conversion floated to top
        let got = crate::ops::execute(&g, &feeds).unwrap()["y"].clone();
        assert_eq!(got, want);
        g.validate().unwrap();
    }
}
