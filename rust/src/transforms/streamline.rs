//! Streamlining passes (FINN's "Streamline" step, paper Fig. 3).
//!
//! These collapse the float scale factors the quantized export leaves
//! behind (MultiThreshold -> Mul chains) into MultiThreshold attributes,
//! so the later HW conversion sees pure integer-threshold units — exactly
//! what FINN's streamlining does before MVAU mapping.

use anyhow::Result;

use super::Transform;
use crate::graph::{AttrVal, Graph};

/// Get the scalar value of an initializer tensor if it is one element.
fn scalar_init(graph: &Graph, tensor: &str) -> Option<f32> {
    let t = graph.initializers.get(tensor)?;
    if t.numel() == 1 {
        Some(t.data()[0])
    } else {
        None
    }
}

/// `MultiThreshold -> Mul(scalar)` ==> MultiThreshold with scaled
/// out_scale/out_bias.  (FINN: AbsorbMulIntoMultiThreshold.)
pub struct CollapseMulIntoMultiThreshold;

impl Transform for CollapseMulIntoMultiThreshold {
    fn name(&self) -> &'static str {
        "CollapseMulIntoMultiThreshold"
    }

    fn apply(&self, graph: &mut Graph) -> Result<bool> {
        for mt_idx in 0..graph.nodes.len() {
            if graph.nodes[mt_idx].op != "MultiThreshold" {
                continue;
            }
            let mt_out = graph.nodes[mt_idx].outputs[0].clone();
            let consumers = graph.consumers(&mt_out);
            if consumers.len() != 1 {
                continue;
            }
            let mul_idx = consumers[0];
            if graph.nodes[mul_idx].op != "Mul" {
                continue;
            }
            // Which input is the scalar?
            let mul = &graph.nodes[mul_idx];
            let other: Vec<&String> = mul.inputs.iter().filter(|i| **i != mt_out).collect();
            if other.len() != 1 {
                continue;
            }
            let Some(scale) = scalar_init(graph, other[0]) else {
                continue;
            };
            let mul_out = graph.nodes[mul_idx].outputs[0].clone();
            // Fold: out = scale * (s*q + b) = (scale*s) q + scale*b.
            let s = graph.nodes[mt_idx].attrs.float_or("out_scale", 1.0);
            let b = graph.nodes[mt_idx].attrs.float_or("out_bias", 0.0);
            graph.nodes[mt_idx]
                .attrs
                .set("out_scale", AttrVal::Float(s * scale as f64));
            graph.nodes[mt_idx]
                .attrs
                .set("out_bias", AttrVal::Float(b * scale as f64));
            graph.nodes[mt_idx].outputs[0] = mul_out;
            graph.remove_nodes(vec![mul_idx]);
            // mt_out tensor is now orphaned; drop its shape entry.
            graph.shapes.remove(&mt_out);
            return Ok(true);
        }
        Ok(false)
    }
}

/// `Mul(scalar) -> Mul(scalar)` ==> single Mul with the product.
pub struct CollapseRepeatedMul;

impl Transform for CollapseRepeatedMul {
    fn name(&self) -> &'static str {
        "CollapseRepeatedMul"
    }

    fn apply(&self, graph: &mut Graph) -> Result<bool> {
        for i in 0..graph.nodes.len() {
            if graph.nodes[i].op != "Mul" {
                continue;
            }
            let out1 = graph.nodes[i].outputs[0].clone();
            let consumers = graph.consumers(&out1);
            if consumers.len() != 1 || graph.nodes[consumers[0]].op != "Mul" {
                continue;
            }
            let j = consumers[0];
            let s1 = graph.nodes[i]
                .inputs
                .iter()
                .find_map(|t| scalar_init(graph, t));
            let s2 = graph.nodes[j]
                .inputs
                .iter()
                .find_map(|t| scalar_init(graph, t));
            let (Some(s1), Some(s2)) = (s1, s2) else {
                continue;
            };
            // Data input of the first Mul.
            let data_in = graph.nodes[i]
                .inputs
                .iter()
                .find(|t| scalar_init(graph, t).is_none())
                .cloned();
            let Some(data_in) = data_in else { continue };
            let out2 = graph.nodes[j].outputs[0].clone();
            let combined = graph.fresh_tensor("mul_scale", vec![]);
            graph
                .initializers
                .insert(combined.clone(), crate::tensor::Tensor::scalar(s1 * s2));
            let node = &mut graph.nodes[i];
            node.inputs = vec![data_in, combined];
            node.outputs = vec![out2];
            graph.remove_nodes(vec![j]);
            graph.shapes.remove(&out1);
            return Ok(true);
        }
        Ok(false)
    }
}

/// Remove `Mul` by exactly 1.0.
pub struct RemoveIdentityMul;

impl Transform for RemoveIdentityMul {
    fn name(&self) -> &'static str {
        "RemoveIdentityMul"
    }

    fn apply(&self, graph: &mut Graph) -> Result<bool> {
        for i in 0..graph.nodes.len() {
            if graph.nodes[i].op != "Mul" {
                continue;
            }
            let scalar = graph.nodes[i]
                .inputs
                .iter()
                .find_map(|t| scalar_init(graph, t).map(|s| (t.clone(), s)));
            let Some((_, s)) = scalar else { continue };
            if s != 1.0 {
                continue;
            }
            let data_in = graph.nodes[i]
                .inputs
                .iter()
                .find(|t| scalar_init(graph, t).is_none())
                .cloned();
            let Some(data_in) = data_in else { continue };
            let out = graph.nodes[i].outputs[0].clone();
            if graph.outputs.contains(&out) {
                continue; // keep graph output names stable
            }
            for c in graph.consumers(&out) {
                for input in &mut graph.nodes[c].inputs {
                    if *input == out {
                        *input = data_in.clone();
                    }
                }
            }
            graph.remove_nodes(vec![i]);
            graph.shapes.remove(&out);
            return Ok(true);
        }
        Ok(false)
    }
}

/// Remove nodes whose outputs nobody consumes (and that aren't graph
/// outputs) — transposes orphaned by the §III-C rewrites, dead scale
/// initializer chains, etc.
pub struct DeadNodeElimination;

impl Transform for DeadNodeElimination {
    fn name(&self) -> &'static str {
        "DeadNodeElimination"
    }

    fn apply(&self, graph: &mut Graph) -> Result<bool> {
        for i in 0..graph.nodes.len() {
            let dead = graph.nodes[i].outputs.iter().all(|out| {
                !graph.outputs.contains(out) && graph.consumers(out).is_empty()
            });
            if dead {
                for out in graph.nodes[i].outputs.clone() {
                    graph.shapes.remove(&out);
                }
                graph.remove_nodes(vec![i]);
                return Ok(true);
            }
        }
        Ok(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Attrs, Node};
    use crate::tensor::Tensor;
    use crate::transforms::run_to_fixpoint;
    use std::collections::HashMap;

    /// MT -> Mul -> out graph with given scale.
    fn mt_mul_graph(scale: f32) -> Graph {
        let mut g = Graph::new("t");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["y".into()];
        g.shapes.insert("x".into(), vec![1, 4]);
        g.shapes.insert("t".into(), vec![1, 3]);
        g.shapes.insert("q".into(), vec![1, 4]);
        g.shapes.insert("s".into(), vec![]);
        g.shapes.insert("y".into(), vec![1, 4]);
        g.initializers.insert(
            "t".into(),
            Tensor::new(vec![1, 3], vec![0.5, 1.5, 2.5]).unwrap(),
        );
        g.initializers.insert("s".into(), Tensor::scalar(scale));
        g.nodes.push(
            Node::new("MultiThreshold", "mt", vec!["x".into(), "t".into()], vec!["q".into()])
                .with_attrs(
                    Attrs::new().with("data_layout", crate::graph::AttrVal::Str("NC".into())),
                ),
        );
        g.nodes
            .push(Node::new("Mul", "mul", vec!["q".into(), "s".into()], vec!["y".into()]));
        g
    }

    fn run(g: &Graph) -> Vec<f32> {
        let mut feeds = HashMap::new();
        feeds.insert(
            "x".to_string(),
            Tensor::new(vec![1, 4], vec![-1.0, 0.7, 1.6, 9.0]).unwrap(),
        );
        crate::ops::execute(g, &feeds).unwrap()["y"].data().to_vec()
    }

    #[test]
    fn collapse_mul_into_mt_preserves_semantics() {
        let mut g = mt_mul_graph(0.25);
        let want = run(&g);
        let n = run_to_fixpoint(&mut g, &CollapseMulIntoMultiThreshold).unwrap();
        assert_eq!(n, 1);
        assert_eq!(g.count_op("Mul"), 0);
        assert_eq!(g.count_op("MultiThreshold"), 1);
        assert_eq!(
            g.nodes[0].attrs.float("out_scale").unwrap(),
            0.25
        );
        assert_eq!(run(&g), want);
        g.validate().unwrap();
    }

    #[test]
    fn collapse_repeated_mul() {
        let mut g = mt_mul_graph(0.5);
        // Append a second Mul by 4.0.
        g.shapes.insert("s2".into(), vec![]);
        g.shapes.insert("y2".into(), vec![1, 4]);
        g.initializers.insert("s2".into(), Tensor::scalar(4.0));
        g.nodes
            .push(Node::new("Mul", "mul2", vec!["y".into(), "s2".into()], vec!["y2".into()]));
        g.outputs = vec!["y2".into()];
        let want = run_out(&g, "y2");
        let n = run_to_fixpoint(&mut g, &CollapseRepeatedMul).unwrap();
        assert_eq!(n, 1);
        assert_eq!(g.count_op("Mul"), 1);
        assert_eq!(run_out(&g, "y2"), want);
        g.validate().unwrap();
    }

    fn run_out(g: &Graph, out: &str) -> Vec<f32> {
        let mut feeds = HashMap::new();
        feeds.insert(
            "x".to_string(),
            Tensor::new(vec![1, 4], vec![-1.0, 0.7, 1.6, 9.0]).unwrap(),
        );
        crate::ops::execute(g, &feeds).unwrap()[out].data().to_vec()
    }

    #[test]
    fn remove_identity_mul() {
        let mut g = mt_mul_graph(1.0);
        // Add a consumer after the Mul so y isn't the graph output.
        g.shapes.insert("s2".into(), vec![]);
        g.shapes.insert("z".into(), vec![1, 4]);
        g.initializers.insert("s2".into(), Tensor::scalar(2.0));
        g.nodes
            .push(Node::new("Mul", "mul2", vec!["y".into(), "s2".into()], vec!["z".into()]));
        g.outputs = vec!["z".into()];
        let want = run_out(&g, "z");
        run_to_fixpoint(&mut g, &RemoveIdentityMul).unwrap();
        assert_eq!(g.count_op("Mul"), 1); // only the x2 one left
        assert_eq!(run_out(&g, "z"), want);
        g.validate().unwrap();
    }

    #[test]
    fn dead_node_elimination() {
        let mut g = mt_mul_graph(0.5);
        // Orphan node writing nowhere-consumed tensor.
        g.shapes.insert("dead".into(), vec![1, 4]);
        g.nodes
            .push(Node::new("Mul", "deadmul", vec!["x".into(), "s".into()], vec!["dead".into()]));
        let n = run_to_fixpoint(&mut g, &DeadNodeElimination).unwrap();
        assert_eq!(n, 1);
        assert!(g.node_by_name("deadmul").is_none());
        g.validate().unwrap();
    }

    #[test]
    fn collapse_ignores_tensor_scale_mul() {
        // Mul by a non-scalar must NOT be absorbed.
        let mut g = mt_mul_graph(0.5);
        g.initializers.insert(
            "s".into(),
            Tensor::new(vec![1, 4], vec![1.0, 2.0, 3.0, 4.0]).unwrap(),
        );
        g.shapes.insert("s".into(), vec![1, 4]);
        let n = run_to_fixpoint(&mut g, &CollapseMulIntoMultiThreshold).unwrap();
        assert_eq!(n, 0);
        assert_eq!(g.count_op("Mul"), 1);
    }
}
