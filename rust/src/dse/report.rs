//! Deterministic `EXPERIMENTS.md` writer: Table II- and Table III-shaped
//! markdown plus the Pareto set.
//!
//! The rendering depends only on the spec and the metrics — never on
//! cache state, worker count or wall-clock — so a cached re-sweep
//! reproduces the file byte for byte (the CI cache-reuse job `cmp`s it).

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

use super::{PointMetrics, SweepResult, SweepSpec};

/// Display label of a storage container: byte-and-wider containers are
/// signed (`i8`/`i16`/`i32`), the packed sub-byte ones are unsigned
/// code containers (`u4` nibbles, `u1` bits).
fn container_label(bits: u8) -> String {
    if bits < 8 {
        format!("u{bits}")
    } else {
        format!("i{bits}")
    }
}

/// Table III bandwidth-ceiling cell: BRAM-bound configs re-stream spilled
/// weights every frame, so the memory verdict rides along with the number.
fn bw_cell(m: &PointMetrics) -> String {
    if m.bram_bound {
        format!("{:.1} (BRAM-bound)", m.bw_fps_ceiling)
    } else {
        format!("{:.1}", m.bw_fps_ceiling)
    }
}

pub fn render_report(spec: &SweepSpec, result: &SweepResult) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "# EXPERIMENTS — design-space exploration");
    let _ = writeln!(s);
    let _ = writeln!(
        s,
        "Synthesized ResNet-9 backbone, widths {:?}, {}x{} input, on {}.",
        spec.widths, spec.img, spec.img, spec.device.name
    );
    let _ = writeln!(
        s,
        "Few-shot protocol: {}-way {}-shot, {} queries/class, {} episodes over a {}x{} synthetic bank (seed {:#x}).",
        spec.n_way,
        spec.k_shot,
        spec.n_query,
        spec.episodes,
        spec.num_classes,
        spec.per_class,
        spec.seed
    );
    let _ = writeln!(
        s,
        "Grid: {} quantization configs x {} utilization caps = {} design points; folding target: {}.",
        spec.configs.len(),
        spec.caps.len(),
        result.outcomes.len(),
        match spec.target_fps {
            Some(f) => format!("{f:.1} fps"),
            None => "fold until the cap stops paying".to_string(),
        }
    );
    let _ = writeln!(
        s,
        "Datapath: {} — {}.",
        spec.datapath.describe(),
        match spec.datapath {
            crate::plan::Datapath::F32 =>
                "accuracies from the f32 simulation of the quantized backbone",
            crate::plan::Datapath::BitTrue =>
                "accuracies from bit-exact integer execution of the lowered HW graph",
        }
    );
    let _ = writeln!(s);

    // ---- Table II shape: accuracy vs bit-width (cap-independent — the
    // first outcome per config speaks for the row).
    let _ = writeln!(s, "## Table II — few-shot accuracy vs bit-width");
    let _ = writeln!(s);
    let _ = writeln!(
        s,
        "| config | max bits | weights | acts | containers | datapath | acc [%] | ci95 [%] | KiB/frame | scales |"
    );
    let _ = writeln!(s, "|---|---|---|---|---|---|---|---|---|---|");
    let mut seen: Vec<&str> = Vec::new();
    let mut any_non_dyadic = false;
    for o in &result.outcomes {
        if seen.contains(&o.point.name.as_str()) {
            continue;
        }
        seen.push(&o.point.name);
        let scales = if o.metrics.non_dyadic_scales == 0 {
            "dyadic".to_string()
        } else {
            any_non_dyadic = true;
            format!("⚠ {} non-dyadic (m>1)", o.metrics.non_dyadic_scales)
        };
        let _ = writeln!(
            s,
            "| {} | {} | {} | {} | {}/{} | {} | {:.2} | {:.2} | {:.1} | {} |",
            o.point.name,
            o.point.quant.max_bits(),
            o.point.quant.weight.describe(),
            o.point.quant.act.describe(),
            container_label(o.point.quant.weight.container_bits()),
            container_label(o.point.quant.act.container_bits()),
            spec.datapath.describe(),
            o.metrics.acc_mean * 100.0,
            o.metrics.acc_ci95 * 100.0,
            o.metrics.bytes_per_frame as f64 / 1024.0,
            scales,
        );
    }
    let _ = writeln!(s);
    if any_non_dyadic {
        let _ = writeln!(
            s,
            "⚠ Rows flagged *non-dyadic* carry scale factors `s = m * 2^-k` with an odd \
             multiplier `|m| > 1`: the integer datapath executes them *exactly* (the \
             decomposition is lossless), but the f32 simulation rounds — such points are \
             exact-but-f32-divergent by design, so do not expect bitwise f32 agreement."
        );
        let _ = writeln!(s);
    }

    // ---- Table III shape: resources vs throughput, one row per point.
    let _ = writeln!(s, "## Table III — resources vs throughput");
    let _ = writeln!(s);
    let _ = writeln!(
        s,
        "| config | cap | datapath | LUT | FF | BRAM36 | DSP | util [%] | weights [KiB] | latency [ms] | fps | bw-ceiling fps | II [cyc] | Pareto |"
    );
    let _ = writeln!(s, "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|");
    for (i, o) in result.outcomes.iter().enumerate() {
        let m = &o.metrics;
        let _ = writeln!(
            s,
            "| {} | {:.2} | {} | {:.0} | {:.0} | {:.1} | {:.0} | {:.1} | {:.1} | {:.3} | {:.1} | {} | {} | {} |",
            o.point.name,
            o.point.max_utilization,
            spec.datapath.describe(),
            m.lut,
            m.ff,
            m.bram36,
            m.dsp,
            m.utilization * 100.0,
            m.weight_bits as f64 / 8192.0,
            m.latency_ms,
            m.fps,
            bw_cell(m),
            m.steady_cycles,
            if result.pareto.contains(&i) { "*" } else { "" },
        );
    }
    let _ = writeln!(s);

    // ---- The frontier itself.
    let _ = writeln!(
        s,
        "## Pareto frontier (accuracy up, fps up, utilization down)"
    );
    let _ = writeln!(s);
    let _ = writeln!(s, "| config | cap | acc [%] | fps | util [%] |");
    let _ = writeln!(s, "|---|---|---|---|---|");
    for &i in &result.pareto {
        let o = &result.outcomes[i];
        let _ = writeln!(
            s,
            "| {} | {:.2} | {:.2} | {:.1} | {:.1} |",
            o.point.name,
            o.point.max_utilization,
            o.metrics.acc_mean * 100.0,
            o.metrics.fps,
            o.metrics.utilization * 100.0,
        );
    }
    let _ = writeln!(s);
    let _ = writeln!(
        s,
        "{} of {} design points are non-dominated.",
        result.pareto.len(),
        result.outcomes.len()
    );
    s
}

/// Render and write the report.
pub fn write_report(path: &Path, spec: &SweepSpec, result: &SweepResult) -> Result<()> {
    std::fs::write(path, render_report(spec, result))
        .with_context(|| format!("writing report {}", path.display()))
}

/// The run-dependent telemetry footer (`## Sweep telemetry`): where the
/// wall clock went, cache hit/miss counts, per-phase timing.  Kept OUT
/// of [`render_report`] on purpose — the result tables stay byte-stable
/// across cached re-sweeps (the CI cache-reuse job strips everything
/// from this heading before `cmp`ing reports).
pub fn render_telemetry_footer(result: &SweepResult) -> String {
    let t = &result.timing;
    let mut s = String::new();
    let _ = writeln!(s);
    let _ = writeln!(s, "## Sweep telemetry");
    let _ = writeln!(s);
    let _ = writeln!(
        s,
        "This run: {} points evaluated, {} from cache; wall {:.1} s.",
        result.evaluated, result.cached, t.wall_s
    );
    if !t.prep_s.is_empty() {
        let total: f64 = t.prep_s.iter().map(|(_, s)| s).sum();
        let _ = writeln!(
            s,
            "Config prep (accuracy + lowering): {} configs, {total:.1} s total.",
            t.prep_s.len()
        );
    }
    if let Some((i, secs)) = t.max_point() {
        let o = &result.outcomes[i];
        let _ = writeln!(
            s,
            "Point builds (folding + sim): mean {:.2} s, slowest {:.2} s ({} @ cap {:.2}).",
            t.mean_point_s(),
            secs,
            o.point.name,
            o.point.max_utilization
        );
    }
    s
}

/// [`write_report`] plus the [`render_telemetry_footer`] appended — the
/// `bwade dse` output path.
pub fn write_report_with_telemetry(
    path: &Path,
    spec: &SweepSpec,
    result: &SweepResult,
) -> Result<()> {
    let md = render_report(spec, result) + &render_telemetry_footer(result);
    std::fs::write(path, md).with_context(|| format!("writing report {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{PointMetrics, PointOutcome};

    fn fake_result(spec: &SweepSpec) -> SweepResult {
        let outcomes: Vec<PointOutcome> = spec
            .points()
            .into_iter()
            .enumerate()
            .map(|(i, point)| PointOutcome {
                point,
                metrics: PointMetrics {
                    acc_mean: 0.4 + 0.01 * i as f64,
                    acc_ci95: 0.02,
                    fps: 100.0 + i as f64,
                    latency_ms: 10.0,
                    steady_cycles: 1000 + i as u64,
                    lut: 1000.0,
                    ff: 2000.0,
                    bram36: 10.0,
                    dsp: 4.0,
                    weight_bits: 8192,
                    utilization: 0.5,
                    hw_layers: 40,
                    bytes_per_frame: 100_000 + 1000 * i as u64,
                    bw_fps_ceiling: 1.0e9 / (100_000.0 + 1000.0 * i as f64),
                    bram_bound: false,
                    non_dyadic_scales: 0,
                },
                cached: i % 2 == 0,
            })
            .collect();
        let pareto = crate::dse::pareto::pareto_frontier(&outcomes);
        SweepResult {
            evaluated: outcomes.len(),
            cached: 0,
            outcomes,
            pareto,
            timing: crate::dse::SweepTiming::default(),
        }
    }

    #[test]
    fn report_has_all_sections_and_rows() {
        let spec = SweepSpec::default();
        let result = fake_result(&spec);
        let md = render_report(&spec, &result);
        assert!(md.contains("# EXPERIMENTS"));
        assert!(md.contains("## Table II"));
        assert!(md.contains("## Table III"));
        assert!(md.contains("## Pareto frontier"));
        for (name, _) in &spec.configs {
            assert!(md.contains(name.as_str()), "missing config row {name}");
        }
        // One Table-III row per design point.
        assert_eq!(
            md.matches("| 0.50 |").count() + md.matches("| 0.85 |").count(),
            result.outcomes.len() + result.pareto.len()
        );
    }

    #[test]
    fn report_records_datapath_per_row() {
        let mut spec = SweepSpec::default();
        spec.datapath = crate::plan::Datapath::BitTrue;
        let result = fake_result(&spec);
        let md = render_report(&spec, &result);
        assert!(md.contains("Datapath: bit-true"));
        // One marker per Table-II row and per Table-III row at least.
        assert!(
            md.matches("| bit-true |").count() >= spec.configs.len() + result.outcomes.len(),
            "datapath not recorded per row"
        );
        let f32_spec = SweepSpec::default();
        let f32_md = render_report(&f32_spec, &fake_result(&f32_spec));
        assert!(f32_md.contains("Datapath: f32"));
        assert!(!f32_md.contains("bit-true"));
    }

    #[test]
    fn report_flags_non_dyadic_configs() {
        let spec = SweepSpec::default();
        let mut result = fake_result(&spec);
        let clean = render_report(&spec, &result);
        assert!(!clean.contains("non-dyadic"), "dyadic sweep got flagged");
        assert!(clean.contains("| dyadic |"));
        // Containers are visible per row (headline: i8 weights, u4 acts).
        assert!(clean.contains("| i8/u4 |"), "{clean}");
        assert_eq!(container_label(1), "u1");
        assert_eq!(container_label(4), "u4");
        assert_eq!(container_label(16), "i16");
        assert!(clean.contains("KiB/frame"));
        // The bandwidth axis is a Table-III column.
        assert!(clean.contains("bw-ceiling fps"), "{clean}");
        assert!(clean.contains("| 10000.0 |"), "{clean}");
        // Flag one config: the marker and the footnote both appear.
        result.outcomes[2].metrics.non_dyadic_scales = 3;
        let flagged = render_report(&spec, &result);
        assert!(flagged.contains("⚠ 3 non-dyadic (m>1)"), "{flagged}");
        assert!(flagged.contains("exact-but-f32-divergent"));
    }

    #[test]
    fn report_marks_bram_bound_points() {
        let spec = SweepSpec::default();
        let mut result = fake_result(&spec);
        let clean = render_report(&spec, &result);
        assert!(!clean.contains("BRAM-bound"), "unspilled sweep got marked");
        result.outcomes[1].metrics.bram_bound = true;
        let marked = render_report(&spec, &result);
        assert!(marked.contains("(BRAM-bound)"), "{marked}");
    }

    #[test]
    fn telemetry_footer_is_separate_from_report() {
        let spec = SweepSpec::default();
        let mut result = fake_result(&spec);
        result.timing = crate::dse::SweepTiming {
            wall_s: 12.5,
            prep_s: vec![("b6_c1.5_r2.2".into(), 4.0)],
            point_s: (0..result.outcomes.len())
                .map(|i| if i == 0 { Some(2.0) } else { None })
                .collect(),
        };
        // The deterministic report never carries run timing...
        let md = render_report(&spec, &result);
        assert!(
            !md.contains("Sweep telemetry"),
            "footer leaked into the deterministic report"
        );
        // ...the footer does, and reflects the timing fields.
        let footer = render_telemetry_footer(&result);
        assert!(footer.contains("## Sweep telemetry"));
        assert!(footer.contains("wall 12.5 s"));
        assert!(footer.contains("slowest 2.00 s"), "{footer}");
    }

    #[test]
    fn report_ignores_cache_provenance() {
        let spec = SweepSpec::default();
        let mut a = fake_result(&spec);
        let mut b = a.clone();
        for o in &mut a.outcomes {
            o.cached = false;
        }
        for o in &mut b.outcomes {
            o.cached = true;
        }
        b.evaluated = 0;
        b.cached = b.outcomes.len();
        assert_eq!(render_report(&spec, &a), render_report(&spec, &b));
    }
}
