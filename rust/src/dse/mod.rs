//! Design-space exploration — the paper's headline artifact as a
//! subsystem, not a hand-run loop.
//!
//! Table II sweeps eight fixed-point configurations and Table III trades
//! resources against throughput; PEFSL (arXiv:2404.19354) and the
//! MLPerf-Tiny FPGA codesign line (arXiv:2206.11791) both show the value
//! of such pipelines is *systematic* co-exploration of quantization ×
//! parallelism under a device budget.  This module enumerates a
//! [`SweepSpec`] grid (quant configs × utilization caps on one device),
//! evaluates every [`DesignPoint`] on a hand-rolled `std::thread` worker
//! pool (offline crate set — no rayon), and prunes the results to a
//! Pareto frontier over (few-shot accuracy ↑, fps ↑, device utilization ↓).
//!
//! Every point runs the full design environment, split along the
//! cap-independence seam: once per config ([`prepare_config`]) the
//! synthesized backbone ([`crate::build::synth_backbone_graph`]) is
//! PTQ'd, scored for few-shot accuracy through the compiled plan engine
//! ([`crate::plan::PlanRunner`] + [`crate::fewshot::evaluate`]) on a
//! deterministic synthetic bank, and lowered through the streamline/
//! lower/§III-C/§III-D pipeline; once per point ([`build_hw_metrics`])
//! the lowered graph is folded against the cap and FIFO-sized-simulated
//! — no PJRT, no trained artifacts anywhere.  A content-hashed on-disk
//! cache ([`cache::ResultCache`]) makes re-sweeps incremental (successes
//! are stored from the workers, so interrupted sweeps resume), and
//! [`report`] renders a deterministic `EXPERIMENTS.md` (Table
//! II/III-shaped tables + the Pareto set).  CLI: `bwade dse`.

pub mod cache;
pub mod pareto;
pub mod report;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::build::{
    implement_lowered, lower_bit_true, requantize_graph, synth_backbone_graph, DesignConfig,
};
use crate::coordinator::FeatureExtractor;
use crate::fewshot::{evaluate, sample_episode, AccuracyReport, Episode};
use crate::fixedpoint::{table2_configs, QuantConfig};
use crate::graph::Graph;
use crate::plan::{Datapath, PlanRunner};
use crate::resources::Device;
use crate::rng::Rng;
use crate::transforms::{convert_to_hw, run_default_pipeline};

pub use cache::ResultCache;
pub use report::{render_report, render_telemetry_footer, write_report, write_report_with_telemetry};

/// The sweep grid plus everything that makes a point reproducible: one
/// synthesized backbone, one deterministic few-shot bank, one episode
/// set — shared by every design point so rows are comparable.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// (row name, quantization config) — Table II rows by default.
    pub configs: Vec<(String, QuantConfig)>,
    /// Per-resource utilization ceilings for the folding search.
    pub caps: Vec<f64>,
    /// Folding target; `None` folds until the cap stops paying (the
    /// resource/throughput trade axis of Table III).
    pub target_fps: Option<f64>,
    pub device: Device,
    /// Backbone widths [c0, c1, c2, c3] of the synthesized ResNet-9.
    pub widths: [usize; 4],
    /// Square input image side.
    pub img: usize,
    /// Synthetic bank geometry (class-major, `per_class` images each).
    pub num_classes: usize,
    pub per_class: usize,
    /// Episode shape: n-way k-shot with n_query queries per class.
    pub n_way: usize,
    pub k_shot: usize,
    pub n_query: usize,
    pub episodes: usize,
    /// Seeds the bank, the episode sampler — and nothing else, so equal
    /// specs give bitwise-equal sweeps regardless of worker count.
    pub seed: u64,
    /// Which arithmetic scores accuracy: the f32 simulation of the
    /// quantized backbone, or the bit-true integer plan on the lowered
    /// HW graph (what the FPGA actually computes).  Recorded per result
    /// row and part of the cache key — f32 and bit-true sweeps never
    /// collide.
    pub datapath: Datapath,
}

impl Default for SweepSpec {
    fn default() -> Self {
        Self {
            configs: table2_configs(),
            caps: vec![0.5, 0.85],
            target_fps: None,
            device: Device::pynq_z1(),
            widths: [4, 8, 8, 16],
            img: 16,
            num_classes: 6,
            per_class: 20,
            n_way: 5,
            k_shot: 5,
            n_query: 15,
            episodes: 50,
            seed: 0xD5E,
            datapath: Datapath::F32,
        }
    }
}

impl SweepSpec {
    pub fn validate(&self) -> Result<()> {
        if self.configs.is_empty() {
            bail!("sweep has no quantization configs");
        }
        if self.caps.is_empty() {
            bail!("sweep has no utilization caps");
        }
        for &c in &self.caps {
            if !(c > 0.0 && c <= 1.0) {
                bail!("utilization cap {c} outside (0, 1]");
            }
        }
        if let Some(f) = self.target_fps {
            if !(f > 0.0 && f.is_finite()) {
                bail!("target fps {f} must be positive and finite");
            }
        }
        if self.n_way > self.num_classes {
            bail!("n_way {} > bank classes {}", self.n_way, self.num_classes);
        }
        if self.k_shot + self.n_query > self.per_class {
            bail!(
                "k_shot + n_query {} > per_class {}",
                self.k_shot + self.n_query,
                self.per_class
            );
        }
        if self.episodes == 0 {
            bail!("sweep needs at least one episode");
        }
        Ok(())
    }

    /// The grid in canonical order (config-major, caps inner) — the order
    /// of every result vector and of the report rows.
    pub fn points(&self) -> Vec<DesignPoint> {
        let mut pts = Vec::with_capacity(self.configs.len() * self.caps.len());
        for (name, quant) in &self.configs {
            for &cap in &self.caps {
                pts.push(DesignPoint {
                    name: name.clone(),
                    quant: *quant,
                    max_utilization: cap,
                });
            }
        }
        pts
    }

    /// Deterministic class-structured image bank (flat NHWC, values in
    /// [0, 1) — the camera-interface range the input quantizer expects).
    /// Images of one class share a prototype pattern plus per-image noise,
    /// so a deterministic backbone separates classes above chance and the
    /// separation degrades with quantization — the Table II shape.
    pub fn make_bank(&self) -> Vec<f32> {
        let per = self.img * self.img * 3;
        let mut rng = Rng::new(self.seed ^ 0xBA4B);
        let mut bank = Vec::with_capacity(self.num_classes * self.per_class * per);
        for _ in 0..self.num_classes {
            let mut crng = rng.fork();
            let proto: Vec<f32> = (0..per).map(|_| crng.next_f32()).collect();
            for _ in 0..self.per_class {
                for &p in &proto {
                    bank.push(0.7 * p + 0.3 * crng.next_f32());
                }
            }
        }
        bank
    }

    /// The shared episode set (same episodes for every design point, so
    /// accuracy differences are attributable to the config alone).
    pub fn make_episodes(&self) -> Result<Vec<Episode>> {
        let mut rng = Rng::new(self.seed ^ 0xE9);
        (0..self.episodes)
            .map(|_| {
                sample_episode(
                    &mut rng,
                    self.num_classes,
                    self.per_class,
                    self.n_way,
                    self.k_shot,
                    self.n_query,
                )
            })
            .collect()
    }
}

/// One point of the grid: a quantization config under a utilization cap.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub name: String,
    pub quant: QuantConfig,
    pub max_utilization: f64,
}

/// Everything the sweep measures about one design point.
#[derive(Debug, Clone, PartialEq)]
pub struct PointMetrics {
    pub acc_mean: f64,
    pub acc_ci95: f64,
    pub fps: f64,
    pub latency_ms: f64,
    pub steady_cycles: u64,
    pub lut: f64,
    pub ff: f64,
    pub bram36: f64,
    pub dsp: f64,
    /// BRAM-resident weight bits (Table I's row).
    pub weight_bits: u64,
    /// Worst-component utilization fraction against the device budget.
    pub utilization: f64,
    pub hw_layers: usize,
    /// Bytes one frame streams through the scoring plan's kernels at the
    /// containers' actual widths (packed on the bit-true datapath),
    /// including the f32 ingress/egress boundary traffic — the bandwidth
    /// the config's narrow formats buy.
    pub bytes_per_frame: u64,
    /// Memory-aware throughput ceiling ([`Device::memory_fps_ceiling`]):
    /// the DMA bound over activations plus any BRAM-spilled weight bytes
    /// that must re-stream every frame — sits alongside the II-derived
    /// `fps`; whichever is lower binds.
    pub bw_fps_ceiling: f64,
    /// True when the config's weight memory overflows the device's
    /// on-chip BRAM capacity (the ceiling above is then BRAM-bound, not
    /// merely DMA-bound).
    pub bram_bound: bool,
    /// Scale factors whose exact decomposition needs an odd multiplier
    /// `|m| > 1`: exact on the integer path, f32-divergent by design.
    /// Nonzero counts are flagged in the report.
    pub non_dyadic_scales: usize,
}

/// A point plus its metrics and provenance.
#[derive(Debug, Clone)]
pub struct PointOutcome {
    pub point: DesignPoint,
    pub metrics: PointMetrics,
    /// True when the metrics came from the on-disk cache.
    pub cached: bool,
}

/// The whole sweep: outcomes in grid order plus the Pareto frontier.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub outcomes: Vec<PointOutcome>,
    /// Points evaluated this run.
    pub evaluated: usize,
    /// Points answered from the cache.
    pub cached: usize,
    /// Ascending indices into `outcomes` of the non-dominated set over
    /// (accuracy ↑, fps ↑, utilization ↓).
    pub pareto: Vec<usize>,
    /// Wall-clock accounting of THIS run (cache-dependent by nature —
    /// rendered only into the report's telemetry footer, never into the
    /// deterministic result tables).
    pub timing: SweepTiming,
}

/// Where a sweep's wall clock went (DESIGN.md §11; the report's
/// `Sweep telemetry` footer).
#[derive(Debug, Clone, Default)]
pub struct SweepTiming {
    /// Whole-sweep wall time, seconds.
    pub wall_s: f64,
    /// Per distinct uncached config: (config name, prepare seconds) —
    /// accuracy scoring + lowering, the cap-independent phase.
    pub prep_s: Vec<(String, f64)>,
    /// Per outcome (grid order): hardware-build seconds, `None` for
    /// cache hits.
    pub point_s: Vec<Option<f64>>,
}

impl SweepTiming {
    /// Mean hardware-build time over freshly evaluated points.
    pub fn mean_point_s(&self) -> f64 {
        let fresh: Vec<f64> = self.point_s.iter().filter_map(|&s| s).collect();
        if fresh.is_empty() {
            0.0
        } else {
            fresh.iter().sum::<f64>() / fresh.len() as f64
        }
    }

    /// Slowest freshly evaluated point: (outcome index, seconds).
    pub fn max_point(&self) -> Option<(usize, f64)> {
        self.point_s
            .iter()
            .enumerate()
            .filter_map(|(i, &s)| s.map(|s| (i, s)))
            .max_by(|a, b| a.1.total_cmp(&b.1))
    }
}

/// Knobs for [`run_sweep_with`] beyond the spec itself.
#[derive(Debug, Clone, Copy, Default)]
pub struct SweepOptions {
    /// Print a throttled progress line with ETA to stderr as workers
    /// finish prep configs / grid points (`bwade dse`).
    pub progress: bool,
}

/// Throttled cross-worker progress meter: every completion ticks it; at
/// most one line per ~200 ms reaches stderr (plus the final one).
struct Progress {
    enabled: bool,
    label: &'static str,
    total: usize,
    done: AtomicUsize,
    started: Instant,
    last_ms: AtomicU64,
}

impl Progress {
    fn new(enabled: bool, label: &'static str, total: usize) -> Progress {
        Progress {
            enabled,
            label,
            total,
            done: AtomicUsize::new(0),
            started: Instant::now(),
            last_ms: AtomicU64::new(0),
        }
    }

    fn tick(&self) {
        let done = self.done.fetch_add(1, Ordering::Relaxed) + 1;
        if !self.enabled || self.total == 0 {
            return;
        }
        let elapsed = self.started.elapsed();
        let now_ms = elapsed.as_millis() as u64;
        let last = self.last_ms.load(Ordering::Relaxed);
        let due = done == self.total || now_ms.saturating_sub(last) >= 200;
        if due
            && self
                .last_ms
                .compare_exchange(last, now_ms, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
        {
            let eta = elapsed.as_secs_f64() / done as f64 * (self.total - done) as f64;
            eprintln!(
                "dse: {} {done}/{} done  elapsed {:.1}s  eta {eta:.1}s",
                self.label,
                self.total,
                elapsed.as_secs_f64()
            );
        }
    }
}

/// Cap-independent measurements of one prepared config, carried into
/// every grid point's [`PointMetrics`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConfigStats {
    /// Bytes per frame through the scoring plan (packed containers on
    /// the bit-true datapath; the f32 request path otherwise).
    pub bytes_per_frame: u64,
    /// Non-dyadic (`|m| > 1`) scale factors in the lowered graph.
    pub non_dyadic_scales: usize,
}

/// Everything cap-independent about one quantization config, done once
/// per config instead of once per grid point: few-shot accuracy
/// (synthesized backbone, rust-side PTQ, compiled-plan extraction over
/// the shared episodes) plus the lowered pre-folding HW graph (the
/// streamline/lower/§III-C/§III-D pipeline) and its [`ConfigStats`].
pub fn prepare_config(
    spec: &SweepSpec,
    quant: &QuantConfig,
    bank: &[f32],
    episodes: &[Episode],
) -> Result<(AccuracyReport, Graph, ConfigStats)> {
    let mut graph =
        synth_backbone_graph(spec.widths, spec.img, quant.act.bits, quant.act.frac_bits);
    let n_images = spec.num_classes * spec.per_class;
    let batch = n_images.clamp(1, 8);
    let (acc, bytes_per_frame, lowered_early) = match spec.datapath {
        Datapath::F32 => {
            // PTQ first so accuracy is scored on the exact grids the
            // build deploys (quantization is a projection — the pipeline
            // preserves it); lowering happens after scoring.
            requantize_graph(&mut graph, quant)?;
            let runner = PlanRunner::new(&graph, batch)?;
            let feats = runner.extract_all(bank, n_images)?;
            let bytes = runner.bytes_moved_per_frame();
            (evaluate(&feats, runner.feature_dim(), episodes)?, bytes, false)
        }
        Datapath::BitTrue => {
            // Lower + annotate first: bit-true accuracy is defined on
            // the HW graph's integer plan, so the score is exactly what
            // the deployed datapath produces — not a float approximation.
            // The plan packs every tensor into its annotated container,
            // so bytes-per-frame here is the width-native bandwidth.
            lower_bit_true(&mut graph, quant)?;
            let runner = PlanRunner::new_bit_true(&graph, batch)?;
            let feats = runner.extract_all(bank, n_images)?;
            let bytes = runner.bytes_moved_per_frame();
            (evaluate(&feats, runner.feature_dim(), episodes)?, bytes, true)
        }
    };

    if !lowered_early {
        run_default_pipeline(&mut graph, None, 0.0)?;
    }
    if !convert_to_hw::is_fully_hw(&graph) {
        bail!("pipeline left non-HW ops in the graph: {:?}", graph.op_census());
    }
    let stats = ConfigStats {
        bytes_per_frame,
        non_dyadic_scales: convert_to_hw::non_dyadic_scale_count(&graph),
    };
    Ok((acc, graph, stats))
}

/// Hardware metrics of one design point: the cap-dependent tail (folding
/// search + FIFO-sized sim via [`implement_lowered`]) on a clone of the
/// config's prepared graph, merged with its accuracy score.
pub fn build_hw_metrics(
    spec: &SweepSpec,
    point: &DesignPoint,
    acc: AccuracyReport,
    lowered: &Graph,
    stats: ConfigStats,
) -> Result<PointMetrics> {
    let mut graph = lowered.clone();
    let cfg = DesignConfig {
        quant: point.quant,
        target_fps: spec.target_fps,
        max_utilization: point.max_utilization,
        verify: false,
    };
    let report = implement_lowered(&mut graph, &cfg, &spec.device)?;
    let r = report.total_resources;
    let mem = spec.device.memory_fps_ceiling(stats.bytes_per_frame, report.weight_bits);
    Ok(PointMetrics {
        acc_mean: acc.mean,
        acc_ci95: acc.ci95,
        fps: report.fps,
        latency_ms: report.latency_ms,
        steady_cycles: report.steady_cycles,
        lut: r.lut,
        ff: r.ff,
        bram36: r.bram36,
        dsp: r.dsp,
        weight_bits: report.weight_bits,
        utilization: r.max_utilization(&spec.device),
        hw_layers: report.models.len(),
        bytes_per_frame: stats.bytes_per_frame,
        bw_fps_ceiling: mem.fps,
        bram_bound: mem.bram_bound,
        non_dyadic_scales: stats.non_dyadic_scales,
    })
}

/// Map `f` over `jobs` on a hand-rolled scoped worker pool (offline crate
/// set — no rayon): an atomic cursor hands out indices, results come back
/// in job order regardless of scheduling.
fn parallel_map<T, R, F>(jobs: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n_workers = workers.max(1).min(jobs.len().max(1));
    let cursor = AtomicUsize::new(0);
    let unordered: Vec<(usize, R)> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(n_workers);
        for _ in 0..n_workers {
            let cursor = &cursor;
            let f = &f;
            handles.push(scope.spawn(move || {
                let mut mine = Vec::new();
                loop {
                    let k = cursor.fetch_add(1, Ordering::Relaxed);
                    if k >= jobs.len() {
                        break;
                    }
                    mine.push((k, f(k, &jobs[k])));
                }
                mine
            }));
        }
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("dse worker panicked"))
            .collect()
    });
    let mut slots: Vec<Option<R>> = (0..jobs.len()).map(|_| None).collect();
    for (k, r) in unordered {
        slots[k] = Some(r);
    }
    slots
        .into_iter()
        .map(|s| s.expect("every job resolved"))
        .collect()
}

/// Run the sweep on `workers` OS threads.  Points already in `cache` are
/// not re-evaluated; fresh results are written back *per point*, so a
/// failing or interrupted sweep keeps everything that finished.  The
/// outcome order (and therefore the report) depends only on the spec,
/// never on worker scheduling.
pub fn run_sweep(
    spec: &SweepSpec,
    workers: usize,
    cache: Option<&ResultCache>,
) -> Result<SweepResult> {
    run_sweep_with(spec, workers, cache, SweepOptions::default())
}

/// [`run_sweep`] with [`SweepOptions`] (progress reporting).  Also
/// feeds the process-wide telemetry registry: `dse.cache_hits` /
/// `dse.cache_misses` counters and the `dse.point_eval_us` histogram.
pub fn run_sweep_with(
    spec: &SweepSpec,
    workers: usize,
    cache: Option<&ResultCache>,
    opts: SweepOptions,
) -> Result<SweepResult> {
    let sweep_start = Instant::now();
    spec.validate()?;
    let points = spec.points();
    let bank = spec.make_bank();
    let episodes = spec.make_episodes()?;

    // Cache probe — serial, it's a handful of small file reads.
    let mut outcomes: Vec<Option<PointOutcome>> = vec![None; points.len()];
    let mut todo: Vec<usize> = Vec::new();
    for (i, p) in points.iter().enumerate() {
        match cache.and_then(|c| c.lookup(spec, p)) {
            Some(metrics) => {
                outcomes[i] = Some(PointOutcome {
                    point: p.clone(),
                    metrics,
                    cached: true,
                })
            }
            None => todo.push(i),
        }
    }
    let cached = points.len() - todo.len();
    let evaluated = todo.len();
    let registry = crate::telemetry::Registry::global();
    registry.counter("dse.cache_hits").add(cached as u64);
    registry.counter("dse.cache_misses").add(evaluated as u64);
    let point_eval_us = registry.histogram("dse.point_eval_us");

    // Phase 1: once per distinct quant config among the uncached points —
    // accuracy scoring and graph lowering are cap-independent, so running
    // them per point would multiply the sweep's dominant cost by the caps
    // axis.  A failing config is recorded, not fatal: the healthy configs
    // still proceed to phase 2 (and the cache), then the error surfaces.
    let mut cfg_keys: Vec<String> = Vec::new();
    let mut cfg_quants: Vec<QuantConfig> = Vec::new();
    for &i in &todo {
        let key = points[i].quant.describe();
        if !cfg_keys.contains(&key) {
            cfg_keys.push(key);
            cfg_quants.push(points[i].quant);
        }
    }
    let prep_progress = Progress::new(opts.progress, "prep", cfg_quants.len());
    let prep_results = parallel_map(&cfg_quants, workers, |_, q| {
        let t0 = Instant::now();
        let r = prepare_config(spec, q, &bank, &episodes);
        prep_progress.tick();
        (r, t0.elapsed().as_secs_f64())
    });
    let mut first_err: Option<anyhow::Error> = None;
    let mut prepared: HashMap<String, (AccuracyReport, Graph, ConfigStats)> = HashMap::new();
    let mut prep_s: Vec<(String, f64)> = Vec::with_capacity(cfg_keys.len());
    for (key, (res, secs)) in cfg_keys.iter().zip(prep_results) {
        prep_s.push((key.clone(), secs));
        match res {
            Ok(p) => {
                prepared.insert(key.clone(), p);
            }
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(anyhow!("config {key}: {e}"));
                }
            }
        }
    }

    // Phase 2: the cap-dependent hardware build per grid point (for every
    // point whose config prepared).  Each success is written to the cache
    // from the worker itself, so an interrupted or partially failing
    // sweep keeps everything that finished.
    let ready: Vec<usize> = todo
        .iter()
        .copied()
        .filter(|&i| prepared.contains_key(&points[i].quant.describe()))
        .collect();
    let point_progress = Progress::new(opts.progress, "points", ready.len());
    let hw_results = parallel_map(&ready, workers, |_, &i| {
        let t0 = Instant::now();
        let res = (|| -> Result<PointMetrics> {
            let (acc, lowered, stats) = &prepared[&points[i].quant.describe()];
            let metrics = build_hw_metrics(spec, &points[i], *acc, lowered, *stats)?;
            if let Some(c) = cache {
                // A cache-write failure (disk full, dir removed mid-run)
                // must not discard a successfully computed point.
                if let Err(e) = c.store(spec, &points[i], &metrics) {
                    eprintln!(
                        "warning: cache write failed for {} @ cap {:.2}: {e:#}",
                        points[i].name, points[i].max_utilization
                    );
                }
            }
            Ok(metrics)
        })();
        let dt = t0.elapsed();
        point_eval_us.record(dt.as_micros() as u64);
        point_progress.tick();
        (res, dt.as_secs_f64())
    });
    let mut point_s: Vec<Option<f64>> = vec![None; points.len()];
    for (&i, (res, secs)) in ready.iter().zip(hw_results) {
        point_s[i] = Some(secs);
        match res {
            Ok(metrics) => {
                outcomes[i] = Some(PointOutcome {
                    point: points[i].clone(),
                    metrics,
                    cached: false,
                });
            }
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(anyhow!(
                        "design point {} @ cap {:.2}: {e}",
                        points[i].name,
                        points[i].max_utilization
                    ));
                }
            }
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }

    let outcomes: Vec<PointOutcome> = outcomes
        .into_iter()
        .map(|o| o.expect("every grid point resolved"))
        .collect();
    let pareto = pareto::pareto_frontier(&outcomes);
    Ok(SweepResult {
        outcomes,
        evaluated,
        cached,
        pareto,
        timing: SweepTiming {
            wall_s: sweep_start.elapsed().as_secs_f64(),
            prep_s,
            point_s,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_order_is_config_major() {
        let spec = SweepSpec {
            caps: vec![0.4, 0.8],
            ..SweepSpec::default()
        };
        let pts = spec.points();
        assert_eq!(pts.len(), spec.configs.len() * 2);
        assert_eq!(pts[0].name, spec.configs[0].0);
        assert_eq!(pts[0].max_utilization, 0.4);
        assert_eq!(pts[1].name, spec.configs[0].0);
        assert_eq!(pts[1].max_utilization, 0.8);
        assert_eq!(pts[2].name, spec.configs[1].0);
    }

    #[test]
    fn bank_and_episodes_are_deterministic() {
        let spec = SweepSpec::default();
        assert_eq!(spec.make_bank(), spec.make_bank());
        let a = spec.make_episodes().unwrap();
        let b = spec.make_episodes().unwrap();
        assert_eq!(a.len(), spec.episodes);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.support, y.support);
            assert_eq!(x.query, y.query);
        }
        // Bank values stay in the input quantizer's [0, 1) range.
        assert!(spec.make_bank().iter().all(|&v| (0.0..1.0).contains(&v)));
    }

    #[test]
    fn validation_rejects_bad_grids() {
        let ok = SweepSpec::default();
        ok.validate().unwrap();
        let mut s = ok.clone();
        s.caps.clear();
        assert!(s.validate().is_err());
        let mut s = ok.clone();
        s.caps = vec![1.5];
        assert!(s.validate().is_err());
        let mut s = ok.clone();
        s.n_way = s.num_classes + 1;
        assert!(s.validate().is_err());
        let mut s = ok.clone();
        s.per_class = s.k_shot + s.n_query - 1;
        assert!(s.validate().is_err());
        let mut s = ok.clone();
        s.target_fps = Some(0.0);
        assert!(s.validate().is_err());
        let mut s = ok;
        s.episodes = 0;
        assert!(s.validate().is_err());
    }
}
