//! Dominated-point pruning over (accuracy ↑, fps ↑, utilization ↓).
//!
//! The frontier is what the design environment is *for*: of the whole
//! quantization × parallelism grid, only the non-dominated points are
//! deployment candidates.  Returned indices are ascending (grid order),
//! so the frontier listing is deterministic for a given spec.

use super::PointOutcome;

/// Objective vector of one outcome, flipped to all-maximized orientation
/// (utilization is negated).
fn objectives(o: &PointOutcome) -> [f64; 3] {
    [o.metrics.acc_mean, o.metrics.fps, -o.metrics.utilization]
}

/// `a` dominates `b`: no worse on every objective, strictly better on at
/// least one.  Exact ties dominate in neither direction, so duplicated
/// points both survive (and keep the frontier deterministic).
fn dominates(a: &[f64; 3], b: &[f64; 3]) -> bool {
    let mut strictly_better = false;
    for (x, y) in a.iter().zip(b) {
        if x < y {
            return false;
        }
        if x > y {
            strictly_better = true;
        }
    }
    strictly_better
}

/// Non-dominated indices over all-maximized objective vectors, ascending.
pub fn pareto_indices(objs: &[[f64; 3]]) -> Vec<usize> {
    (0..objs.len())
        .filter(|&i| {
            !objs
                .iter()
                .enumerate()
                .any(|(j, o)| j != i && dominates(o, &objs[i]))
        })
        .collect()
}

/// The sweep's frontier: indices into `outcomes`, ascending.
pub fn pareto_frontier(outcomes: &[PointOutcome]) -> Vec<usize> {
    let objs: Vec<[f64; 3]> = outcomes.iter().map(objectives).collect();
    pareto_indices(&objs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominated_points_are_pruned() {
        // p1 dominates p0 (better everywhere); p2 trades off (kept).
        let objs = [
            [0.5, 100.0, -0.8],
            [0.6, 200.0, -0.7],
            [0.7, 50.0, -0.9],
        ];
        assert_eq!(pareto_indices(&objs), vec![1, 2]);
    }

    #[test]
    fn single_point_is_always_on_the_frontier() {
        assert_eq!(pareto_indices(&[[0.1, 1.0, -1.0]]), vec![0]);
        assert!(pareto_indices(&[]).is_empty());
    }

    #[test]
    fn ties_keep_both_and_order_is_ascending() {
        let objs = [
            [0.5, 100.0, -0.5],
            [0.5, 100.0, -0.5],
            [0.4, 100.0, -0.5], // dominated by both duplicates
        ];
        assert_eq!(pareto_indices(&objs), vec![0, 1]);
    }

    #[test]
    fn partial_improvement_does_not_dominate() {
        // Better accuracy but worse utilization: both survive.
        let objs = [[0.5, 100.0, -0.5], [0.6, 100.0, -0.9]];
        assert_eq!(pareto_indices(&objs), vec![0, 1]);
    }

    #[test]
    fn chain_of_dominance_leaves_one() {
        let objs = [
            [0.1, 1.0, -0.9],
            [0.2, 2.0, -0.8],
            [0.3, 3.0, -0.7],
        ];
        assert_eq!(pareto_indices(&objs), vec![2]);
    }
}
