//! Content-hashed on-disk result cache for the sweep.
//!
//! The cache key is an FNV-1a hash of a canonical description string that
//! names every input affecting a point's metrics: the quant config, the
//! utilization cap, the folding target, the device (name, clock, budget),
//! the backbone geometry, the bank/episode shape and the seed.  A second
//! sweep over an unchanged spec therefore re-evaluates zero points, while
//! touching any knob (or bumping [`CACHE_VERSION`] when the evaluation
//! pipeline itself changes meaning) silently misses and re-runs.
//!
//! Values are stored one JSON file per point via the hand-rolled
//! [`crate::json`] module (no serde offline); the stored description is
//! compared on load, so a hash collision or stale schema degrades to a
//! cache miss, never to wrong metrics.  f64 round-trips are exact (the
//! emitter prints shortest-roundtrip), so cache hits return bitwise-
//! identical points.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::json::{obj, Json};

use super::{DesignPoint, PointMetrics, SweepSpec};

/// Bump when the evaluation pipeline (`prepare_config` +
/// `build_hw_metrics`) changes meaning — invalidates every entry.
/// v2: the sweep gained the `datapath` axis (f32 vs bit-true accuracy).
/// v3: width-native packed storage — metrics grew bytes-per-frame and
/// the non-dyadic scale count, and the key names the weight/activation
/// container widths.
/// v4: sub-byte packed containers (widths 1 and 4 now reachable in the
/// key) plus honest boundary-byte accounting — bytes-per-frame changed
/// meaning and the metrics grew the bandwidth-ceiling fps.
pub const CACHE_VERSION: u32 = 4;

/// 64-bit FNV-1a — tiny, dependency-free, good enough for file naming
/// (the stored description string is the real collision guard).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Canonical description of one design point under one spec — the cache
/// key preimage.  Floats use `{:?}` (shortest-roundtrip), so specs that
/// differ by any representable amount never share a description.
pub fn point_desc(spec: &SweepSpec, point: &DesignPoint) -> String {
    let b = &spec.device.budget;
    format!(
        "v{CACHE_VERSION}|dp={}|quant={}|cont={}/{}|cap={:?}|fps={:?}|dev={}|clk={:?}|budget={:?}/{:?}/{:?}/{:?}|widths={:?}|img={}|bank={}x{}|ep={}x{}w{}s{}q|seed={}",
        spec.datapath.describe(),
        point.quant.describe(),
        point.quant.weight.container_bits(),
        point.quant.act.container_bits(),
        point.max_utilization,
        spec.target_fps,
        spec.device.name,
        spec.device.clock_mhz,
        b.lut,
        b.ff,
        b.bram36,
        b.dsp,
        spec.widths,
        spec.img,
        spec.num_classes,
        spec.per_class,
        spec.episodes,
        spec.n_way,
        spec.k_shot,
        spec.n_query,
        spec.seed,
    )
}

/// A directory of `<fnv1a64(desc)>.json` result files.
#[derive(Debug, Clone)]
pub struct ResultCache {
    dir: PathBuf,
}

impl ResultCache {
    /// Open (creating if needed) a cache directory.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating cache dir {}", dir.display()))?;
        Ok(Self { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path_for(&self, desc: &str) -> PathBuf {
        self.dir.join(format!("{:016x}.json", fnv1a64(desc.as_bytes())))
    }

    /// Cached metrics for a point, or `None` on miss / unreadable entry /
    /// description mismatch.
    pub fn lookup(&self, spec: &SweepSpec, point: &DesignPoint) -> Option<PointMetrics> {
        let desc = point_desc(spec, point);
        let doc = Json::parse_file(&self.path_for(&desc)).ok()?;
        if doc.opt("desc").and_then(|d| d.as_str().ok()) != Some(desc.as_str()) {
            return None;
        }
        metrics_from_json(doc.opt("metrics")?).ok()
    }

    /// Persist one evaluated point.
    pub fn store(
        &self,
        spec: &SweepSpec,
        point: &DesignPoint,
        metrics: &PointMetrics,
    ) -> Result<()> {
        let desc = point_desc(spec, point);
        let path = self.path_for(&desc);
        let doc = obj(vec![
            ("desc", Json::str(desc)),
            ("config", Json::str(point.name.clone())),
            ("metrics", metrics_to_json(metrics)),
        ]);
        std::fs::write(&path, doc.to_string_pretty())
            .with_context(|| format!("writing cache entry {}", path.display()))
    }
}

fn metrics_to_json(m: &PointMetrics) -> Json {
    obj(vec![
        ("acc_mean", Json::num(m.acc_mean)),
        ("acc_ci95", Json::num(m.acc_ci95)),
        ("fps", Json::num(m.fps)),
        ("latency_ms", Json::num(m.latency_ms)),
        ("steady_cycles", Json::num(m.steady_cycles as f64)),
        ("lut", Json::num(m.lut)),
        ("ff", Json::num(m.ff)),
        ("bram36", Json::num(m.bram36)),
        ("dsp", Json::num(m.dsp)),
        ("weight_bits", Json::num(m.weight_bits as f64)),
        ("utilization", Json::num(m.utilization)),
        ("hw_layers", Json::num(m.hw_layers as f64)),
        ("bytes_per_frame", Json::num(m.bytes_per_frame as f64)),
        ("bw_fps_ceiling", Json::num(m.bw_fps_ceiling)),
        ("bram_bound", Json::Bool(m.bram_bound)),
        ("non_dyadic_scales", Json::num(m.non_dyadic_scales as f64)),
    ])
}

fn metrics_from_json(j: &Json) -> Result<PointMetrics> {
    Ok(PointMetrics {
        acc_mean: j.get("acc_mean")?.as_f64()?,
        acc_ci95: j.get("acc_ci95")?.as_f64()?,
        fps: j.get("fps")?.as_f64()?,
        latency_ms: j.get("latency_ms")?.as_f64()?,
        steady_cycles: j.get("steady_cycles")?.as_f64()? as u64,
        lut: j.get("lut")?.as_f64()?,
        ff: j.get("ff")?.as_f64()?,
        bram36: j.get("bram36")?.as_f64()?,
        dsp: j.get("dsp")?.as_f64()?,
        weight_bits: j.get("weight_bits")?.as_f64()? as u64,
        utilization: j.get("utilization")?.as_f64()?,
        hw_layers: j.get("hw_layers")?.as_usize()?,
        bytes_per_frame: j.get("bytes_per_frame")?.as_f64()? as u64,
        bw_fps_ceiling: j.get("bw_fps_ceiling")?.as_f64()?,
        bram_bound: j.get("bram_bound")?.as_bool()?,
        non_dyadic_scales: j.get("non_dyadic_scales")?.as_usize()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_metrics() -> PointMetrics {
        PointMetrics {
            acc_mean: 0.59703125,
            acc_ci95: 0.0123456789,
            fps: 61.53e3 / 1000.7,
            latency_ms: 16.3000001,
            steady_cycles: 2_031_250,
            lut: 37_263.25,
            ff: 44_617.0,
            bram36: 131.5,
            dsp: 22.0,
            weight_bits: 1_234_567,
            utilization: 0.8533,
            hw_layers: 40,
            bytes_per_frame: 987_654,
            bw_fps_ceiling: 1012.5000001,
            bram_bound: true,
            non_dyadic_scales: 1,
        }
    }

    #[test]
    fn fnv_is_stable_and_spread() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv1a64(b"a"), fnv1a64(b"b"));
    }

    #[test]
    fn metrics_round_trip_bitwise() {
        let m = sample_metrics();
        let j = metrics_to_json(&m);
        let back = metrics_from_json(&Json::parse(&j.to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn desc_changes_with_every_knob() {
        let spec = SweepSpec::default();
        let pts = spec.points();
        let p = &pts[0];
        let base = point_desc(&spec, p);
        let mut p2 = p.clone();
        p2.max_utilization += 0.01;
        assert_ne!(base, point_desc(&spec, &p2));
        // Sub-rounding differences must still change the key (shortest-
        // roundtrip formatting, no fixed precision).
        let mut p3 = p.clone();
        p3.max_utilization += 1e-9;
        assert_ne!(base, point_desc(&spec, &p3));
        let mut s2 = spec.clone();
        s2.seed += 1;
        assert_ne!(base, point_desc(&s2, p));
        let mut s2 = spec.clone();
        s2.episodes += 1;
        assert_ne!(base, point_desc(&s2, p));
        let mut s2 = spec.clone();
        s2.target_fps = Some(60.0);
        assert_ne!(base, point_desc(&s2, p));
        // The datapath is part of the key: f32 and bit-true sweeps must
        // never answer each other's points.
        let mut s2 = spec.clone();
        s2.datapath = crate::plan::Datapath::BitTrue;
        assert_ne!(base, point_desc(&s2, p));
        // The container widths are named in the key (headline config:
        // s6.5 weights pack into i8, u4.2 acts into a u4 nibble).
        assert!(base.contains("|cont=8/4|"), "{base}");
    }

    #[test]
    fn store_lookup_and_mismatch_miss() {
        let dir = std::env::temp_dir().join(format!("bwade_cache_unit_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = ResultCache::open(&dir).unwrap();
        let spec = SweepSpec::default();
        let p = spec.points()[0].clone();
        assert!(cache.lookup(&spec, &p).is_none());
        let m = sample_metrics();
        cache.store(&spec, &p, &m).unwrap();
        assert_eq!(cache.lookup(&spec, &p), Some(m));
        // A different spec misses even though the directory has entries.
        let mut s2 = spec.clone();
        s2.seed ^= 1;
        assert!(cache.lookup(&s2, &p).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
