//! FINN-style hardware layer models: cycle counts, stream rates and
//! resource estimates for every HW op the compiler emits.
//!
//! Each node of the fully-lowered graph ([`crate::transforms::convert_to_hw`])
//! is annotated with an [`HwNodeModel`]: how many stream elements it
//! consumes/produces per frame, how many cycles a frame takes at its
//! current folding (PE/SIMD), and what it costs in LUT/FF/BRAM/DSP.
//!
//! The analytical forms follow FINN-R (Blott et al., TRETS'18) and the
//! FINN cost model as characterized by Ducasse et al. (the paper's [12]):
//!
//! * MVAU cycles/frame = M * ceil(K/SIMD) * ceil(N/PE)
//! * weight memory = K*N*Wbits packed into BRAM36 geometry
//! * LUT-based multipliers for small bit-widths, DSP48 when either
//!   operand exceeds 8 bits (this is why the paper's Table III shows the
//!   DSP column collapsing and LUT/FF growing when moving Tensil->FINN)
//!
//! Constants are calibrated to reproduce the *shape* of Table III, not
//! Vivado-exact numbers (DESIGN.md §2).

use anyhow::{anyhow, bail, Result};

use crate::fixedpoint::QuantConfig;
use crate::graph::{Graph, Node};
use crate::resources::{bram36_for, Resources};

/// Stream/timing/resource model of one HW node.
#[derive(Debug, Clone)]
pub struct HwNodeModel {
    /// Node name (matches the graph node).
    pub name: String,
    pub op: String,
    /// Stream inputs (tensor names; initializers excluded).
    pub stream_inputs: Vec<String>,
    /// Elements consumed per frame, per stream input (same order).
    pub in_elems: Vec<u64>,
    /// Stream output tensor name.
    pub output: String,
    /// Elements produced per frame.
    pub out_elems: u64,
    /// Cycles per frame at the current folding.
    pub cycles: u64,
    pub resources: Resources,
    /// Weight memory bits (MVAU only; BRAM-resident, Table I's row).
    pub weight_bits: u64,
}

/// Folding (parallelism) attributes of a node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Folding {
    pub pe: u64,
    pub simd: u64,
}

pub fn folding_of(node: &Node) -> Folding {
    Folding {
        pe: node.attrs.int_or("pe", 1).max(1) as u64,
        simd: node.attrs.int_or("simd", 1).max(1) as u64,
    }
}

fn numel(shape: &[usize]) -> u64 {
    shape.iter().product::<usize>() as u64
}

/// Accumulator bit-width for a K-deep dot product.
pub fn acc_bits(cfg: &QuantConfig, k: u64) -> u64 {
    let growth = (k.max(1) as f64).log2().ceil() as u64;
    (cfg.weight.bits as u64 + cfg.act.bits as u64 + growth).min(32)
}

/// Build the model for one HW node.
pub fn model_node(graph: &Graph, node: &Node, cfg: &QuantConfig) -> Result<HwNodeModel> {
    let stream_inputs: Vec<String> = node
        .inputs
        .iter()
        .filter(|t| !graph.is_initializer(t))
        .cloned()
        .collect();
    let output = node
        .outputs
        .first()
        .ok_or_else(|| anyhow!("node {} has no output", node.name))?
        .clone();
    let out_shape = graph.shape_of(&output)?.to_vec();
    let out_elems = numel(&out_shape);
    let in_shapes: Vec<Vec<usize>> = stream_inputs
        .iter()
        .map(|t| graph.shape_of(t).map(|s| s.to_vec()))
        .collect::<Result<_>>()?;
    let in_elems: Vec<u64> = in_shapes.iter().map(|s| numel(s)).collect();
    let fold = folding_of(node);
    let abits = cfg.act.bits as u64;
    let wbits = cfg.weight.bits as u64;

    let (cycles, resources, weight_bits): (u64, Resources, u64) = match node.op.as_str() {
        "MVAU" => {
            // x: [..., K] @ w: [K, N]; M = spatial rows.
            let w_name = &node.inputs[1];
            let w_shape = graph.shape_of(w_name)?;
            let (k, n) = (w_shape[0] as u64, w_shape[1] as u64);
            let m = in_elems[0] / k;
            let pe = fold.pe.min(n);
            let simd = fold.simd.min(k);
            let cycles = m * k.div_ceil(simd) * n.div_ceil(pe);
            let acc = acc_bits(cfg, k);
            let use_dsp = wbits > 8 || abits > 8;
            let mut r = Resources::ZERO;
            let lanes = (pe * simd) as f64;
            if use_dsp {
                r.dsp += lanes;
                r.lut += lanes * 12.0; // operand routing
            } else {
                // LUT multiplier + per-lane add (FINN-R style scaling).
                r.lut += lanes * (0.65 * (wbits * abits) as f64 + 4.0);
            }
            // Adder tree + accumulator per PE.
            r.lut += pe as f64 * (simd.saturating_sub(1) as f64) * acc as f64 * 0.5;
            r.ff += pe as f64 * acc as f64 * 2.0;
            // Pipeline regs on the input SIMD lanes.
            r.ff += lanes * abits as f64;
            // Control.
            r.lut += 120.0;
            r.ff += 150.0;
            // Weight memory in BRAM (the FINN column of Table I).
            let depth = k.div_ceil(simd) * n.div_ceil(pe);
            let width = pe * simd * wbits;
            r.bram36 += bram36_for(depth, width);
            let mut weight_bits_total = k * n * wbits;
            // Fused thresholding stage.
            if node.attrs.int_or("apply_act", 1) == 1 && node.inputs.len() >= 4 {
                let t_shape = graph.shape_of(&node.inputs[3])?;
                let t_count = t_shape[1] as u64;
                let stages = (t_count.max(1) as f64).log2().ceil().max(1.0);
                r.lut += pe as f64 * acc as f64 * stages;
                // Threshold storage (distributed RAM).
                let t_bits = n * t_count * acc;
                r.lut += t_bits as f64 / 64.0;
                weight_bits_total += t_bits;
            }
            (cycles, r, weight_bits_total)
        }
        "ConvolutionInputGenerator" => {
            let kernel = node.attrs.ints("kernel")?;
            let (kh, kw) = (kernel[0] as u64, kernel[1] as u64);
            let in_shape = &in_shapes[0]; // NHWC
            let (h, w, c) = (in_shape[1] as u64, in_shape[2] as u64, in_shape[3] as u64);
            let simd = fold.simd.min(c);
            // Output-driven: every output element leaves once.
            let cycles = out_elems / simd.max(1);
            let mut r = Resources::ZERO;
            // Line buffer: (kh-1) image lines + kw pixels, in BRAM.
            let buf_words = ((kh - 1) * w + kw) * c / simd.max(1);
            r.bram36 += bram36_for(buf_words.max(1), simd * abits);
            // Window registers.
            r.ff += (kh * kw * simd * abits) as f64;
            r.lut += 150.0 + 12.0 * simd as f64;
            let _ = h;
            (cycles, r, 0)
        }
        "Thresholding" => {
            let pe = fold.pe;
            let cycles = out_elems / pe.max(1);
            let t_shape = graph.shape_of(&node.inputs[1])?;
            let t_count = t_shape[1] as u64;
            let stages = (t_count.max(1) as f64).log2().ceil().max(1.0);
            let mut r = Resources::ZERO;
            r.lut += pe as f64 * abits as f64 * stages + 60.0;
            r.ff += pe as f64 * abits as f64 + 60.0;
            r.lut += (t_shape[0] as u64 * t_count * 16) as f64 / 64.0;
            (cycles, r, 0)
        }
        "StreamingMaxPool" => {
            let pe = fold.pe;
            let cycles = in_elems[0] / pe.max(1);
            let in_shape = &in_shapes[0];
            let (w, c) = (in_shape[2] as u64, in_shape[3] as u64);
            let mut r = Resources::ZERO;
            // One line of partial maxima.
            r.bram36 += bram36_for(w * c / 2, abits);
            r.lut += 80.0 + 2.0 * abits as f64 * pe as f64;
            r.ff += 100.0;
            (cycles, r, 0)
        }
        "GlobalAccPool_hw" => {
            let simd = fold.simd;
            let cycles = in_elems[0] / simd.max(1);
            let in_shape = &in_shapes[0];
            let c = *in_shape.last().unwrap() as u64;
            let acc = acc_bits(cfg, in_elems[0] / c.max(1));
            let mut r = Resources::ZERO;
            r.lut += 60.0 + (acc * simd) as f64;
            r.ff += (c * acc) as f64; // per-channel accumulators
            (cycles, r, 0)
        }
        "AddStreams" => {
            let pe = fold.pe;
            let cycles = out_elems / pe.max(1);
            let mut r = Resources::ZERO;
            r.lut += 40.0 + (abits + 1) as f64 * pe as f64;
            r.ff += 60.0;
            (cycles, r, 0)
        }
        "ChannelwiseMul" => {
            let pe = fold.pe;
            let cycles = out_elems / pe.max(1);
            let mut r = Resources::ZERO;
            r.dsp += pe as f64; // scalar multiplier
            r.lut += 40.0;
            r.ff += 40.0;
            (cycles, r, 0)
        }
        "Transpose" => {
            // Host-side DMA layout conversion (FINN driver does NCHW->NHWC
            // on the ARM core); modeled as a pass-through stream.
            (in_elems[0], Resources::ZERO, 0)
        }
        other => bail!("no HW model for op {other}"),
    };

    Ok(HwNodeModel {
        name: node.name.clone(),
        op: node.op.clone(),
        stream_inputs,
        in_elems,
        output,
        out_elems,
        cycles: cycles.max(1),
        resources,
        weight_bits,
    })
}

/// Model every node of a fully-lowered graph (topological order).
pub fn model_graph(graph: &Graph, cfg: &QuantConfig) -> Result<Vec<HwNodeModel>> {
    let mut sorted = graph.clone();
    sorted.toposort()?;
    sorted
        .nodes
        .iter()
        .map(|n| model_node(&sorted, n, cfg))
        .collect()
}

/// Aggregate resources (plus `extra` for FIFOs etc.).
pub fn total_resources(models: &[HwNodeModel]) -> Resources {
    models
        .iter()
        .fold(Resources::ZERO, |acc, m| acc + m.resources)
}

/// Total BRAM-resident weight bits (Table I: "weights stored in BRAM").
pub fn total_weight_bits(models: &[HwNodeModel]) -> u64 {
    models.iter().map(|m| m.weight_bits).sum()
}

/// The steady-state initiation interval: max layer cycles (the paper's
/// throughput bound; Fig. 5's fps = clock / II).
pub fn initiation_interval(models: &[HwNodeModel]) -> u64 {
    models.iter().map(|m| m.cycles).max().unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::headline_config;
    use crate::graph::{AttrVal, Attrs};
    use crate::tensor::Tensor;

    /// x[1,4,4,8] -> MVAU(K=8 -> N=16, thresholds) -> y[1,4,4,16]
    fn mvau_graph(pe: i64, simd: i64) -> Graph {
        let mut g = Graph::new("m");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["y".into()];
        g.shapes.insert("x".into(), vec![1, 4, 4, 8]);
        g.shapes.insert("w".into(), vec![8, 16]);
        g.shapes.insert("b".into(), vec![16]);
        g.shapes.insert("t".into(), vec![16, 15]);
        g.shapes.insert("y".into(), vec![1, 4, 4, 16]);
        g.initializers.insert("w".into(), Tensor::zeros(vec![8, 16]));
        g.initializers.insert("b".into(), Tensor::zeros(vec![16]));
        g.initializers.insert("t".into(), Tensor::zeros(vec![16, 15]));
        g.nodes.push(
            Node::new(
                "MVAU",
                "mvau0",
                vec!["x".into(), "w".into(), "b".into(), "t".into()],
                vec!["y".into()],
            )
            .with_attrs(
                Attrs::new()
                    .with("apply_act", AttrVal::Int(1))
                    .with("pe", AttrVal::Int(pe))
                    .with("simd", AttrVal::Int(simd)),
            ),
        );
        g
    }

    #[test]
    fn mvau_cycles_follow_folding() {
        let cfg = headline_config();
        let g1 = mvau_graph(1, 1);
        let m1 = model_graph(&g1, &cfg).unwrap();
        // M=16 rows, K=8, N=16 -> 16*8*16 = 2048 cycles at PE=SIMD=1.
        assert_eq!(m1[0].cycles, 2048);
        let g2 = mvau_graph(4, 2);
        let m2 = model_graph(&g2, &cfg).unwrap();
        // 16 * ceil(8/2) * ceil(16/4) = 16*4*4 = 256.
        assert_eq!(m2[0].cycles, 256);
        // More parallel => more resources.
        assert!(m2[0].resources.lut > m1[0].resources.lut);
    }

    #[test]
    fn mvau_weight_bits_counted() {
        let cfg = headline_config(); // W6
        let g = mvau_graph(1, 1);
        let m = model_graph(&g, &cfg).unwrap();
        // 8*16 weights * 6 bits, plus thresholds.
        assert!(m[0].weight_bits >= 8 * 16 * 6);
    }

    #[test]
    fn dsp_used_only_for_wide_widths() {
        let g = mvau_graph(2, 2);
        let narrow = model_node(&g, &g.nodes[0], &headline_config()).unwrap();
        assert_eq!(narrow.resources.dsp, 0.0);
        let wide = model_node(&g, &g.nodes[0], &crate::fixedpoint::baseline16_config()).unwrap();
        assert_eq!(wide.resources.dsp, 4.0); // PE*SIMD lanes
    }

    #[test]
    fn stream_elems_balance() {
        let cfg = headline_config();
        let g = mvau_graph(1, 1);
        let m = &model_graph(&g, &cfg).unwrap()[0];
        assert_eq!(m.in_elems, vec![1 * 4 * 4 * 8]);
        assert_eq!(m.out_elems, 4 * 4 * 16);
        assert_eq!(m.stream_inputs, vec!["x".to_string()]);
    }

    #[test]
    fn acc_bits_grows_with_k() {
        let cfg = headline_config();
        assert_eq!(acc_bits(&cfg, 1), 10);
        assert!(acc_bits(&cfg, 512) > acc_bits(&cfg, 8));
        assert!(acc_bits(&cfg, 1 << 40) <= 32);
    }

    #[test]
    fn initiation_interval_is_max() {
        let cfg = headline_config();
        let g = mvau_graph(1, 1);
        let models = model_graph(&g, &cfg).unwrap();
        assert_eq!(initiation_interval(&models), 2048);
    }
}
