//! Tensil-baseline simulator: a systolic-array accelerator with
//! DRAM-resident weights and activations — the architecture of the
//! paper's Table I "Tensil" column and Table III "PEFSL [2]" row.
//!
//! Model (matching Tensil's published architecture for the PYNQ-Z1
//! target and the behaviour the paper attributes to it):
//!
//! * an `rows x cols` MAC array (16-bit fixed-point datapath -> DSP48 per
//!   MAC), weights loaded column-wise from DRAM before each tile;
//! * activations stream DRAM -> local buffer -> array -> DRAM per layer
//!   (Table I: "Weights stored in DRAM", "Can be higher [latency] due to
//!   DRAM access overhead");
//! * layers execute **sequentially** (no inter-layer pipelining) — the
//!   paper contrasts this with FINN's dataflow streaming.
//!
//! Convolution is lowered to tiled matmul exactly as the FINN path does
//! (same M/K/N per layer), so the two simulators disagree only in
//! *architecture*, which is the comparison Table III makes.


use crate::fixedpoint::QuantConfig;
use crate::resources::{bram36_for, Resources};

/// Systolic accelerator configuration (Tensil-for-PYNQ-Z1 defaults).
#[derive(Debug, Clone)]
pub struct SystolicConfig {
    pub rows: u64,
    pub cols: u64,
    /// Datapath width in bits (Tensil: fixed 16 or 32).
    pub data_bits: u64,
    /// DRAM bytes per fabric cycle (64-bit AXI HP port on the Zynq).
    pub dram_bytes_per_cycle: f64,
    /// DRAM burst setup latency in cycles.
    pub dram_latency: u64,
    /// Local activation/weight buffer size in bytes.
    pub buffer_bytes: u64,
    /// Per-instruction decode overhead in cycles.
    pub instr_overhead: u64,
}

impl SystolicConfig {
    /// Tensil's PYNQ-Z1 build as used by PEFSL: a 12x12 array (144 MAC
    /// DSPs + DMA/post-processing ~ the paper's 159 DSP row; 16x16 would
    /// not fit the Zynq-7020's 220 DSPs), 16-bit datapath.
    ///
    /// DRAM constants are calibrated to the *effective* utilization the
    /// paper's own Table III implies (35.9 ms at 125 MHz for PEFSL's
    /// backbone ~ <10% MAC utilization — Tensil's DRAM-resident weights
    /// and per-tile instruction issue dominate): 4 bytes/cycle sustained
    /// on the shared HP port, 64-cycle burst setup, ~96 cycles of
    /// instruction issue per tile.  DESIGN.md §2 records this as a
    /// documented calibration, not a measured Tensil build.
    pub fn tensil_pynq_z1() -> Self {
        Self {
            rows: 12,
            cols: 12,
            data_bits: 16,
            dram_bytes_per_cycle: 4.0,
            dram_latency: 64,
            buffer_bytes: 96 * 1024,
            instr_overhead: 96,
        }
    }
}

/// One conv layer as a matmul workload (shared with the FINN path).
#[derive(Debug, Clone)]
pub struct MatmulLayer {
    pub name: String,
    /// Output spatial positions (Ho*Wo).
    pub m: u64,
    /// Reduction depth (kh*kw*Cin).
    pub k: u64,
    /// Output channels.
    pub n: u64,
}

/// Per-layer simulation breakdown.
#[derive(Debug, Clone)]
pub struct LayerTiming {
    pub name: String,
    pub compute_cycles: u64,
    pub weight_dram_cycles: u64,
    pub act_dram_cycles: u64,
    pub total_cycles: u64,
    pub dram_bytes: u64,
}

/// Whole-network result.
#[derive(Debug, Clone)]
pub struct SystolicResult {
    pub layers: Vec<LayerTiming>,
    pub total_cycles: u64,
    pub total_dram_bytes: u64,
    pub resources: Resources,
}

/// Simulate the sequential execution of all layers.
pub fn simulate(cfg: &SystolicConfig, quant: &QuantConfig, layers: &[MatmulLayer]) -> SystolicResult {
    let bytes_per_elem = (cfg.data_bits.max(quant.weight.bits as u64) as f64 / 8.0).ceil() as u64;
    let mut out_layers = Vec::new();
    let mut total = 0u64;
    let mut total_dram = 0u64;

    for layer in layers {
        let tiles_k = layer.k.div_ceil(cfg.rows);
        let tiles_n = layer.n.div_ceil(cfg.cols);
        let n_tiles = tiles_k * tiles_n;

        // Weight tile load: rows*cols elements over the DRAM port.
        let w_tile_bytes = cfg.rows * cfg.cols * bytes_per_elem;
        let w_cycles_per_tile =
            cfg.dram_latency + (w_tile_bytes as f64 / cfg.dram_bytes_per_cycle).ceil() as u64;
        let weight_dram_cycles = n_tiles * w_cycles_per_tile;

        // Compute: M rows streamed through the array per tile, plus
        // array fill/drain (rows + cols pipeline depth).
        let compute_cycles =
            n_tiles * (layer.m + cfg.rows + cfg.cols) + cfg.instr_overhead * n_tiles;

        // Partial-sum traffic: every K-tile beyond the first re-reads and
        // re-writes the M x N_tile accumulators through the local SRAM at
        // one row per cycle (Tensil's accumulate instructions).
        let partial_cycles = tiles_k.saturating_sub(1) * tiles_n * 2 * layer.m;

        // Activations: read M*K once per K-tile-column sweep (input reuse
        // across N tiles is limited by the local buffer), write M*N once.
        let act_in_bytes = layer.m * layer.k * bytes_per_elem;
        let reread = if act_in_bytes <= cfg.buffer_bytes {
            1 // fits on-chip: single DRAM read
        } else {
            tiles_n.max(1) // must re-stream per output tile column
        };
        let act_bytes = act_in_bytes * reread + layer.m * layer.n * bytes_per_elem;
        let act_dram_cycles = (act_bytes as f64 / cfg.dram_bytes_per_cycle).ceil() as u64
            + cfg.dram_latency * (reread + 1);

        // Sequential engine: DRAM phases and compute do not overlap
        // (Table I: "Can be higher [latency] due to DRAM access
        // overhead" — Tensil issues load/compute/store per instruction).
        let total_cycles =
            compute_cycles + partial_cycles + act_dram_cycles + weight_dram_cycles;
        let dram_bytes = act_bytes + n_tiles * w_tile_bytes;

        total += total_cycles;
        total_dram += dram_bytes;
        out_layers.push(LayerTiming {
            name: layer.name.clone(),
            compute_cycles,
            weight_dram_cycles,
            act_dram_cycles,
            total_cycles,
            dram_bytes,
        });
    }

    SystolicResult {
        layers: out_layers,
        total_cycles: total,
        total_dram_bytes: total_dram,
        resources: resources(cfg),
    }
}

/// Resource estimate for the systolic accelerator itself (independent of
/// the model it runs — the array is a fixed engine, Table I).
pub fn resources(cfg: &SystolicConfig) -> Resources {
    let macs = (cfg.rows * cfg.cols) as f64;
    let mut r = Resources::ZERO;
    // One DSP48 per 16-bit MAC, plus ~15 in the DMA/post-processing path
    // (the paper's Table III: 159 DSPs for PEFSL's 16-bit 12x12 build).
    r.dsp = macs * (cfg.data_bits as f64 / 16.0).max(1.0).min(2.0) + 15.0;
    // Control, AXI DMA engines, instruction decode.
    r.lut = 9_000.0 + macs * 22.0 * (cfg.data_bits as f64 / 16.0);
    r.ff = 5_500.0 + macs * 14.0;
    // Local buffers (activations + accumulators), BRAM.
    r.bram36 = bram36_for(cfg.buffer_bytes / 8, 64)
        + bram36_for((cfg.rows * cfg.cols * 32) / 32, 32);
    r
}

/// Extract matmul workloads from backbone layer metadata (shared with the
/// FINN path so both simulators run the identical network).
pub fn layers_from_meta(layers: &[crate::artifacts::LayerMeta], img: usize) -> Vec<MatmulLayer> {
    let mut out = Vec::new();
    let mut h = img as u64;
    for l in layers {
        out.push(MatmulLayer {
            name: l.name.clone(),
            m: h * h,
            k: 9 * l.cin as u64,
            n: l.cout as u64,
        });
        if l.pool {
            h /= 2;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::baseline16_config;

    fn tiny_layers() -> Vec<MatmulLayer> {
        vec![
            MatmulLayer {
                name: "a".into(),
                m: 1024,
                k: 27,
                n: 8,
            },
            MatmulLayer {
                name: "b".into(),
                m: 1024,
                k: 72,
                n: 16,
            },
        ]
    }

    #[test]
    fn cycles_positive_and_sum() {
        let cfg = SystolicConfig::tensil_pynq_z1();
        let r = simulate(&cfg, &baseline16_config(), &tiny_layers());
        assert_eq!(r.layers.len(), 2);
        assert_eq!(
            r.total_cycles,
            r.layers.iter().map(|l| l.total_cycles).sum::<u64>()
        );
        assert!(r.total_dram_bytes > 0);
    }

    #[test]
    fn bigger_array_fewer_compute_cycles() {
        let small = SystolicConfig {
            rows: 8,
            cols: 8,
            ..SystolicConfig::tensil_pynq_z1()
        };
        let big = SystolicConfig {
            rows: 32,
            cols: 32,
            ..SystolicConfig::tensil_pynq_z1()
        };
        let quant = baseline16_config();
        let layers = vec![MatmulLayer {
            name: "x".into(),
            m: 4096,
            k: 256,
            n: 256,
        }];
        let rs = simulate(&small, &quant, &layers);
        let rb = simulate(&big, &quant, &layers);
        assert!(
            rb.layers[0].compute_cycles < rs.layers[0].compute_cycles,
            "{} vs {}",
            rb.layers[0].compute_cycles,
            rs.layers[0].compute_cycles
        );
    }

    #[test]
    fn dram_traffic_includes_weights_every_tile() {
        let cfg = SystolicConfig::tensil_pynq_z1();
        let quant = baseline16_config();
        let layers = vec![MatmulLayer {
            name: "x".into(),
            m: 16,
            k: 64,
            n: 64,
        }];
        let r = simulate(&cfg, &quant, &layers);
        // 4 K-tiles x 4 N-tiles x 16x16x2 bytes of weights minimum.
        assert!(r.layers[0].dram_bytes >= 16 * 64 * 64 / 16 * 2);
        assert!(r.layers[0].weight_dram_cycles > 0);
    }

    #[test]
    fn dsp_heavy_lut_light_vs_finn_shape() {
        // Table III architecture shape: systolic uses many DSPs.
        let r = resources(&SystolicConfig::tensil_pynq_z1());
        assert!(r.dsp >= 128.0);
        assert!(r.lut < 53_200.0 * 0.5); // well under half the device
    }

    #[test]
    fn layers_from_meta_tracks_pooling() {
        let metas = vec![
            crate::artifacts::LayerMeta {
                name: "stem".into(),
                cin: 3,
                cout: 8,
                pool: false,
                res_begin: false,
                res_add: false,
            },
            crate::artifacts::LayerMeta {
                name: "conv1".into(),
                cin: 8,
                cout: 16,
                pool: true,
                res_begin: false,
                res_add: false,
            },
            crate::artifacts::LayerMeta {
                name: "res1a".into(),
                cin: 16,
                cout: 16,
                pool: false,
                res_begin: true,
                res_add: false,
            },
        ];
        let ls = layers_from_meta(&metas, 32);
        assert_eq!(ls[0].m, 1024);
        assert_eq!(ls[1].m, 1024); // pool applies AFTER conv1
        assert_eq!(ls[2].m, 256); // halved spatial
        assert_eq!(ls[2].k, 144);
    }
}
