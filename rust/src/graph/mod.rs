//! ONNX-analogue graph IR — the compiler's working representation.
//!
//! The python exporter (compile/export_graph.py) writes the pre-streamline
//! NCHW graph; the transform passes in [`crate::transforms`] rewrite it the
//! way FINN's streamlining + HW-conversion steps do, and the hardware
//! models in [`crate::hw`] consume the final HW-layer graph.
//!
//! Design choices mirror FINN/qonnx where it matters:
//! * every value (activation or initializer) has a unique tensor name;
//! * nodes reference tensors by name, single producer per tensor (SSA);
//! * the node list is kept in topological order (transforms call
//!   [`Graph::toposort`] after structural edits);
//! * attributes are a small typed enum, not stringly JSON.

use std::collections::{HashMap, HashSet};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::artifacts::read_f32_slice;
use crate::json::{Json, JsonObj};
use crate::tensor::Tensor;

/// Typed node attribute.
#[derive(Debug, Clone, PartialEq)]
pub enum AttrVal {
    Int(i64),
    Ints(Vec<i64>),
    Float(f64),
    Str(String),
}

/// Ordered attribute map.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Attrs(Vec<(String, AttrVal)>);

impl Attrs {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(&mut self, key: &str, val: AttrVal) {
        if let Some(slot) = self.0.iter_mut().find(|(k, _)| k == key) {
            slot.1 = val;
        } else {
            self.0.push((key.to_string(), val));
        }
    }

    pub fn with(mut self, key: &str, val: AttrVal) -> Self {
        self.set(key, val);
        self
    }

    pub fn get(&self, key: &str) -> Option<&AttrVal> {
        self.0.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn int(&self, key: &str) -> Result<i64> {
        match self.get(key) {
            Some(AttrVal::Int(v)) => Ok(*v),
            Some(AttrVal::Float(v)) if v.fract() == 0.0 => Ok(*v as i64),
            other => bail!("attr {key}: expected int, got {other:?}"),
        }
    }

    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.int(key).unwrap_or(default)
    }

    pub fn ints(&self, key: &str) -> Result<Vec<i64>> {
        match self.get(key) {
            Some(AttrVal::Ints(v)) => Ok(v.clone()),
            other => bail!("attr {key}: expected int list, got {other:?}"),
        }
    }

    pub fn float(&self, key: &str) -> Result<f64> {
        match self.get(key) {
            Some(AttrVal::Float(v)) => Ok(*v),
            Some(AttrVal::Int(v)) => Ok(*v as f64),
            other => bail!("attr {key}: expected float, got {other:?}"),
        }
    }

    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.float(key).unwrap_or(default)
    }

    pub fn str(&self, key: &str) -> Result<&str> {
        match self.get(key) {
            Some(AttrVal::Str(s)) => Ok(s),
            other => bail!("attr {key}: expected string, got {other:?}"),
        }
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.str(key).unwrap_or(default)
    }

    pub fn iter(&self) -> impl Iterator<Item = &(String, AttrVal)> {
        self.0.iter()
    }
}

/// A graph node (operator instance).
#[derive(Debug, Clone)]
pub struct Node {
    pub op: String,
    pub name: String,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
    pub attrs: Attrs,
}

impl Node {
    pub fn new(op: &str, name: &str, inputs: Vec<String>, outputs: Vec<String>) -> Self {
        Self {
            op: op.to_string(),
            name: name.to_string(),
            inputs,
            outputs,
            attrs: Attrs::new(),
        }
    }

    pub fn with_attrs(mut self, attrs: Attrs) -> Self {
        self.attrs = attrs;
        self
    }
}

/// The graph: SSA over named tensors, topologically ordered node list.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub name: String,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
    pub nodes: Vec<Node>,
    pub shapes: HashMap<String, Vec<usize>>,
    pub initializers: HashMap<String, Tensor>,
    fresh_counter: u64,
}

impl Graph {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            ..Default::default()
        }
    }

    // ------------------------------------------------------------- loading

    /// Load graph.json + graph_weights.bin as written by export_graph.py.
    pub fn load(json_path: &Path, weights_path: &Path) -> Result<Self> {
        let doc = Json::parse_file(json_path)?;
        let blob = std::fs::read(weights_path)
            .with_context(|| format!("reading {}", weights_path.display()))?;
        Self::from_json(&doc, &blob)
    }

    pub fn from_json(doc: &Json, weights_blob: &[u8]) -> Result<Self> {
        let mut g = Graph::new(doc.get("name")?.as_str()?);
        for t in doc.get("tensors")?.as_arr()? {
            g.shapes.insert(
                t.get("name")?.as_str()?.to_string(),
                t.get("shape")?.as_usize_vec()?,
            );
        }
        for i in doc.get("inputs")?.as_arr()? {
            g.inputs.push(i.as_str()?.to_string());
        }
        for o in doc.get("outputs")?.as_arr()? {
            g.outputs.push(o.as_str()?.to_string());
        }
        let empty = Json::Arr(Vec::new());
        for init in doc.opt("initializers").unwrap_or(&empty).as_arr()? {
            let name = init.get("name")?.as_str()?.to_string();
            let shape = init.get("shape")?.as_usize_vec()?;
            let offset = init.get("offset")?.as_usize()?;
            let numel: usize = shape.iter().product();
            let end = offset + numel * 4;
            if end > weights_blob.len() {
                bail!("initializer {name} overruns weights blob");
            }
            let data = read_f32_slice(&weights_blob[offset..end]);
            g.initializers.insert(name, Tensor::new(shape, data)?);
        }
        for n in doc.get("nodes")?.as_arr()? {
            let mut node = Node::new(
                n.get("op")?.as_str()?,
                n.get("name")?.as_str()?,
                n.get("inputs")?
                    .as_arr()?
                    .iter()
                    .map(|v| v.as_str().map(String::from))
                    .collect::<Result<_>>()?,
                n.get("outputs")?
                    .as_arr()?
                    .iter()
                    .map(|v| v.as_str().map(String::from))
                    .collect::<Result<_>>()?,
            );
            for (key, val) in n.get("attrs")?.as_obj()?.iter() {
                let attr = match val {
                    Json::Num(f) => {
                        if f.fract() == 0.0 {
                            AttrVal::Int(*f as i64)
                        } else {
                            AttrVal::Float(*f)
                        }
                    }
                    Json::Str(s) => AttrVal::Str(s.clone()),
                    Json::Arr(a) => AttrVal::Ints(
                        a.iter().map(|v| v.as_i64()).collect::<Result<_>>()?,
                    ),
                    other => bail!("unsupported attr value {other:?}"),
                };
                node.attrs.set(key, attr);
            }
            g.nodes.push(node);
        }
        g.validate()?;
        Ok(g)
    }

    /// Serialize back to JSON (round-trip + report tooling).
    pub fn to_json(&self) -> Json {
        let mut tensors = Vec::new();
        for (name, shape) in self.shapes_sorted() {
            let mut o = JsonObj::new();
            o.insert("name", Json::str(name));
            o.insert(
                "shape",
                Json::Arr(shape.iter().map(|&d| Json::num(d as f64)).collect()),
            );
            tensors.push(Json::Obj(o));
        }
        let mut nodes = Vec::new();
        for n in &self.nodes {
            let mut o = JsonObj::new();
            o.insert("op", Json::str(&n.op));
            o.insert("name", Json::str(&n.name));
            o.insert(
                "inputs",
                Json::Arr(n.inputs.iter().map(|s| Json::str(s.clone())).collect()),
            );
            o.insert(
                "outputs",
                Json::Arr(n.outputs.iter().map(|s| Json::str(s.clone())).collect()),
            );
            let mut attrs = JsonObj::new();
            for (k, v) in n.attrs.iter() {
                let jv = match v {
                    AttrVal::Int(i) => Json::num(*i as f64),
                    AttrVal::Float(f) => Json::num(*f),
                    AttrVal::Str(s) => Json::str(s.clone()),
                    AttrVal::Ints(v) => {
                        Json::Arr(v.iter().map(|&i| Json::num(i as f64)).collect())
                    }
                };
                attrs.insert(k, jv);
            }
            o.insert("attrs", Json::Obj(attrs));
            nodes.push(Json::Obj(o));
        }
        crate::json::obj(vec![
            ("name", Json::str(&self.name)),
            (
                "inputs",
                Json::Arr(self.inputs.iter().map(|s| Json::str(s.clone())).collect()),
            ),
            (
                "outputs",
                Json::Arr(self.outputs.iter().map(|s| Json::str(s.clone())).collect()),
            ),
            ("tensors", Json::Arr(tensors)),
            ("nodes", Json::Arr(nodes)),
        ])
    }

    fn shapes_sorted(&self) -> Vec<(&String, &Vec<usize>)> {
        let mut v: Vec<_> = self.shapes.iter().collect();
        v.sort_by(|a, b| a.0.cmp(b.0));
        v
    }

    // ------------------------------------------------------------- queries

    pub fn shape_of(&self, tensor: &str) -> Result<&[usize]> {
        self.shapes
            .get(tensor)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow!("unknown tensor {tensor:?}"))
    }

    pub fn is_initializer(&self, tensor: &str) -> bool {
        self.initializers.contains_key(tensor)
    }

    /// Index of the node producing `tensor` (activations only).
    pub fn producer(&self, tensor: &str) -> Option<usize> {
        self.nodes
            .iter()
            .position(|n| n.outputs.iter().any(|o| o == tensor))
    }

    /// Indices of nodes consuming `tensor`.
    pub fn consumers(&self, tensor: &str) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.inputs.iter().any(|i| i == tensor))
            .map(|(i, _)| i)
            .collect()
    }

    pub fn node_by_name(&self, name: &str) -> Option<&Node> {
        self.nodes.iter().find(|n| n.name == name)
    }

    /// Census of op types (used by Fig. 4 reporting and tests).
    pub fn op_census(&self) -> HashMap<String, usize> {
        let mut census = HashMap::new();
        for n in &self.nodes {
            *census.entry(n.op.clone()).or_insert(0) += 1;
        }
        census
    }

    pub fn count_op(&self, op: &str) -> usize {
        self.nodes.iter().filter(|n| n.op == op).count()
    }

    // ------------------------------------------------------------ mutation

    /// A fresh tensor name with the given prefix, registered with `shape`.
    pub fn fresh_tensor(&mut self, prefix: &str, shape: Vec<usize>) -> String {
        loop {
            let name = format!("{prefix}__{}", self.fresh_counter);
            self.fresh_counter += 1;
            if !self.shapes.contains_key(&name) {
                self.shapes.insert(name.clone(), shape);
                return name;
            }
        }
    }

    pub fn set_shape(&mut self, tensor: &str, shape: Vec<usize>) {
        self.shapes.insert(tensor.to_string(), shape);
    }

    /// Remove nodes by index set (descending-safe).
    pub fn remove_nodes(&mut self, mut idxs: Vec<usize>) {
        idxs.sort_unstable();
        idxs.dedup();
        for i in idxs.into_iter().rev() {
            self.nodes.remove(i);
        }
    }

    /// Topological order of the node indices, without mutating or cloning
    /// the graph — the plan compiler's entry point, and the backing of
    /// [`Graph::toposort`].
    pub fn toposort_order(&self) -> Result<Vec<usize>> {
        let n = self.nodes.len();
        // tensor -> producing node index
        let mut producer: HashMap<&str, usize> = HashMap::new();
        for (i, node) in self.nodes.iter().enumerate() {
            for out in &node.outputs {
                if producer.insert(out.as_str(), i).is_some() {
                    bail!("tensor {out} has multiple producers");
                }
            }
        }
        let mut deps: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut indegree = vec![0usize; n];
        for (i, node) in self.nodes.iter().enumerate() {
            for input in &node.inputs {
                if let Some(&p) = producer.get(input.as_str()) {
                    deps[p].push(i);
                    indegree[i] += 1;
                }
            }
        }
        let mut queue: Vec<usize> = (0..n).filter(|&i| indegree[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        let mut head = 0;
        while head < queue.len() {
            let i = queue[head];
            head += 1;
            order.push(i);
            for &j in &deps[i] {
                indegree[j] -= 1;
                if indegree[j] == 0 {
                    queue.push(j);
                }
            }
        }
        if order.len() != n {
            bail!("graph has a cycle");
        }
        Ok(order)
    }

    /// Topologically sort nodes by tensor dependencies (in place; nodes
    /// are moved, not cloned).
    pub fn toposort(&mut self) -> Result<()> {
        let order = self.toposort_order()?;
        let mut slots: Vec<Option<Node>> = self.nodes.drain(..).map(Some).collect();
        self.nodes = order
            .into_iter()
            .map(|i| slots[i].take().expect("order is a permutation"))
            .collect();
        Ok(())
    }

    /// Structural validation: unique producers, defined inputs, known shapes.
    pub fn validate(&self) -> Result<()> {
        let mut produced: HashSet<&str> = HashSet::new();
        for node in &self.nodes {
            for out in &node.outputs {
                if !produced.insert(out.as_str()) {
                    bail!("tensor {out} produced twice");
                }
                if !self.shapes.contains_key(out.as_str()) {
                    bail!("output tensor {out} has no shape entry");
                }
            }
        }
        let mut available: HashSet<&str> = self.inputs.iter().map(|s| s.as_str()).collect();
        for init in self.initializers.keys() {
            available.insert(init.as_str());
        }
        // Must be checkable in topological order (cycle detection), but
        // no clone is needed: producer() is order-independent.
        let order = self.toposort_order()?;
        for &i in &order {
            let node = &self.nodes[i];
            for input in &node.inputs {
                if !available.contains(input.as_str()) && self.producer(input).is_none() {
                    bail!("node {} reads undefined tensor {input}", node.name);
                }
            }
        }
        for out in &self.outputs {
            if self.producer(out).is_none() && !available.contains(out.as_str()) {
                bail!("graph output {out} is never produced");
            }
        }
        for (name, t) in &self.initializers {
            match self.shapes.get(name) {
                Some(s) if s == t.shape() => {}
                Some(s) => bail!("initializer {name} shape {s:?} != tensor {:?}", t.shape()),
                None => bail!("initializer {name} missing from tensor list"),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Graph {
        // in -> A -> t1 -> B -> t2 ; t1 -> C -> t3 ; (t2,t3) -> D -> out
        let mut g = Graph::new("diamond");
        g.inputs = vec!["in".into()];
        g.outputs = vec!["out".into()];
        for t in ["in", "t1", "t2", "t3", "out"] {
            g.shapes.insert(t.into(), vec![1]);
        }
        g.nodes = vec![
            Node::new("Relu", "A", vec!["in".into()], vec!["t1".into()]),
            Node::new("Relu", "B", vec!["t1".into()], vec!["t2".into()]),
            Node::new("Relu", "C", vec!["t1".into()], vec!["t3".into()]),
            Node::new("Add", "D", vec!["t2".into(), "t3".into()], vec!["out".into()]),
        ];
        g
    }

    #[test]
    fn validate_ok_and_census() {
        let g = diamond();
        g.validate().unwrap();
        assert_eq!(g.count_op("Relu"), 3);
        assert_eq!(g.count_op("Add"), 1);
    }

    #[test]
    fn toposort_recovers_order() {
        let mut g = diamond();
        g.nodes.reverse();
        g.toposort().unwrap();
        let pos = |name: &str| g.nodes.iter().position(|n| n.name == name).unwrap();
        assert!(pos("A") < pos("B"));
        assert!(pos("A") < pos("C"));
        assert!(pos("B") < pos("D"));
        assert!(pos("C") < pos("D"));
    }

    #[test]
    fn toposort_detects_cycle() {
        let mut g = diamond();
        g.nodes[0].inputs = vec!["out".into()]; // A now reads D's output
        assert!(g.toposort().is_err());
    }

    #[test]
    fn validate_rejects_double_producer() {
        let mut g = diamond();
        g.nodes[2].outputs = vec!["t2".into()];
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_rejects_undefined_input() {
        let mut g = diamond();
        g.nodes[3].inputs[1] = "ghost".into();
        assert!(g.validate().is_err());
    }

    #[test]
    fn producer_consumer_queries() {
        let g = diamond();
        assert_eq!(g.producer("t1"), Some(0));
        assert_eq!(g.consumers("t1"), vec![1, 2]);
        assert_eq!(g.producer("in"), None);
    }

    #[test]
    fn fresh_tensor_unique() {
        let mut g = diamond();
        let a = g.fresh_tensor("tmp", vec![2]);
        let b = g.fresh_tensor("tmp", vec![3]);
        assert_ne!(a, b);
        assert_eq!(g.shape_of(&a).unwrap(), &[2]);
    }

    #[test]
    fn attrs_typed_access() {
        let mut attrs = Attrs::new();
        attrs.set("kernel", AttrVal::Ints(vec![3, 3]));
        attrs.set("out_scale", AttrVal::Float(0.25));
        attrs.set("layout", AttrVal::Str("NCHW".into()));
        assert_eq!(attrs.ints("kernel").unwrap(), vec![3, 3]);
        assert_eq!(attrs.float("out_scale").unwrap(), 0.25);
        assert_eq!(attrs.str("layout").unwrap(), "NCHW");
        assert!(attrs.int("kernel").is_err());
    }

    #[test]
    fn json_round_trip() {
        let g = diamond();
        let j = g.to_json();
        let g2 = Graph::from_json(&j, &[]).unwrap();
        assert_eq!(g2.nodes.len(), 4);
        assert_eq!(g2.inputs, g.inputs);
        assert_eq!(g2.count_op("Relu"), 3);
    }
}
