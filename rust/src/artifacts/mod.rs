//! Artifact access — the rust side of the `make artifacts` contract.
//!
//! python/compile/aot.py writes everything the request path needs into
//! ./artifacts (override with `BWADE_ARTIFACTS`):
//!
//! * `graph.json` + `graph_weights.bin` — the pre-streamlining NCHW
//!   compiler graph ([`crate::graph::Graph::load`]);
//! * `model_manifest.json` + `model_weights.bin` — folded float weights
//!   in HLO argument order ([`ModelBundle`]); the rust side PTQs them per
//!   bit-width config ([`ModelBundle::quantized_args`]);
//! * `fewshot_bank.bin` — the novel-class image bank ([`FewshotBank`]);
//! * `backbone_b{1,8}.hlo.txt` / `test_mvau.hlo.txt` — AOT-lowered HLO
//!   for the PJRT runtime;
//! * `.stamp` — the completion sentinel [`ArtifactPaths::exists`] checks.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::fixedpoint::FxpFormat;
use crate::json::Json;
use crate::tensor::Tensor;

/// Reinterpret a little-endian byte slice as f32 values.
pub fn read_f32_slice(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Well-known locations of the exported artifacts.
#[derive(Debug, Clone)]
pub struct ArtifactPaths {
    pub dir: PathBuf,
}

impl ArtifactPaths {
    /// `./artifacts`, overridden by `BWADE_ARTIFACTS`.
    pub fn default_dir() -> Self {
        let dir = std::env::var("BWADE_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self {
            dir: PathBuf::from(dir),
        }
    }

    pub fn at(dir: impl Into<PathBuf>) -> Self {
        Self { dir: dir.into() }
    }

    /// True when `make artifacts` completed (the sentinel file exists).
    pub fn exists(&self) -> bool {
        self.dir.join(".stamp").exists()
    }

    pub fn graph_json(&self) -> PathBuf {
        self.dir.join("graph.json")
    }

    pub fn graph_weights(&self) -> PathBuf {
        self.dir.join("graph_weights.bin")
    }

    pub fn model_manifest(&self) -> PathBuf {
        self.dir.join("model_manifest.json")
    }

    pub fn model_weights(&self) -> PathBuf {
        self.dir.join("model_weights.bin")
    }

    pub fn fewshot_bank(&self) -> PathBuf {
        self.dir.join("fewshot_bank.bin")
    }

    pub fn backbone_hlo(&self, batch: usize) -> PathBuf {
        self.dir.join(format!("backbone_b{batch}.hlo.txt"))
    }

    pub fn test_mvau_hlo(&self) -> PathBuf {
        self.dir.join("test_mvau.hlo.txt")
    }

    /// Load the model bundle (manifest + weights blob).
    pub fn model_bundle(&self) -> Result<ModelBundle> {
        ModelBundle::load(&self.model_manifest(), &self.model_weights())
    }
}

/// One backbone conv layer's metadata (aot.py `meta["layers"]`).
#[derive(Debug, Clone)]
pub struct LayerMeta {
    pub name: String,
    pub cin: usize,
    pub cout: usize,
    pub pool: bool,
    pub res_begin: bool,
    pub res_add: bool,
}

/// One HLO argument's metadata (model_manifest.json `args`).
#[derive(Debug, Clone)]
pub struct ArgMeta {
    pub name: String,
    /// "weight" (HWIO conv kernel) or "bias".
    pub kind: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub elems: usize,
}

/// The deployed model: folded float weights in HLO argument order plus
/// the architecture metadata the serving side needs.
#[derive(Debug, Clone)]
pub struct ModelBundle {
    pub widths: Vec<usize>,
    pub feature_dim: usize,
    pub img: usize,
    pub batch_sizes: Vec<usize>,
    pub layers: Vec<LayerMeta>,
    pub args: Vec<ArgMeta>,
    /// Float tensors aligned with `args` (pre-quantization).
    pub arg_data: Vec<Tensor>,
}

impl ModelBundle {
    pub fn load(manifest_path: &Path, weights_path: &Path) -> Result<Self> {
        let doc = Json::parse_file(manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let blob = std::fs::read(weights_path)
            .with_context(|| format!("reading {}", weights_path.display()))?;

        let mut args = Vec::new();
        let mut arg_data = Vec::new();
        for a in doc.get("args")?.as_arr()? {
            let meta = ArgMeta {
                name: a.get("name")?.as_str()?.to_string(),
                kind: a.get("kind")?.as_str()?.to_string(),
                shape: a.get("shape")?.as_usize_vec()?,
                offset: a.get("offset")?.as_usize()?,
                elems: a.get("elems")?.as_usize()?,
            };
            let end = meta.offset + meta.elems * 4;
            if end > blob.len() {
                bail!("arg {} overruns weights blob", meta.name);
            }
            let data = read_f32_slice(&blob[meta.offset..end]);
            arg_data.push(Tensor::new(meta.shape.clone(), data)?);
            args.push(meta);
        }

        let mut layers = Vec::new();
        for l in doc.get("layers")?.as_arr()? {
            layers.push(LayerMeta {
                name: l.get("name")?.as_str()?.to_string(),
                cin: l.get("cin")?.as_usize()?,
                cout: l.get("cout")?.as_usize()?,
                pool: l.get("pool")?.as_bool()?,
                res_begin: l.get("res_begin")?.as_bool()?,
                res_add: l.get("res_add")?.as_bool()?,
            });
        }

        Ok(Self {
            widths: doc.get("widths")?.as_usize_vec()?,
            feature_dim: doc.get("feature_dim")?.as_usize()?,
            img: doc.get("img")?.as_usize()?,
            batch_sizes: doc.get("batch_sizes")?.as_usize_vec()?,
            layers,
            args,
            arg_data,
        })
    }

    /// Total parameter count of the deployed backbone.
    pub fn param_count(&self) -> usize {
        self.args.iter().map(|a| a.elems).sum()
    }

    /// PTQ the float args for one bit-width config: conv weights onto
    /// `weight_fmt`, biases onto the (wide) accumulator format — exactly
    /// what python's `model.ptq` does at export time.
    pub fn quantized_args(&self, weight_fmt: FxpFormat, acc_fmt: FxpFormat) -> Vec<Tensor> {
        self.args
            .iter()
            .zip(&self.arg_data)
            .map(|(meta, tensor)| {
                let fmt = if meta.kind == "weight" { weight_fmt } else { acc_fmt };
                let mut t = tensor.clone();
                fmt.quantize_slice(t.data_mut());
                t
            })
            .collect()
    }
}

/// The novel-class image bank (fewshot_bank.bin, dataset.py format):
/// class-major NHWC f32 images; image `i` belongs to class `i / per_class`.
#[derive(Debug, Clone)]
pub struct FewshotBank {
    pub num_classes: usize,
    pub per_class: usize,
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    /// Flat `[num_images, h, w, c]` pixel data.
    pub images: Vec<f32>,
}

const BANK_MAGIC: u32 = 0x4257_5A46;
const BANK_VERSION: u32 = 1;

impl FewshotBank {
    pub fn load(path: &Path) -> Result<Self> {
        let bytes =
            std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() < 28 {
            bail!("fewshot bank {} truncated", path.display());
        }
        let u32_at = |i: usize| {
            u32::from_le_bytes([bytes[i * 4], bytes[i * 4 + 1], bytes[i * 4 + 2], bytes[i * 4 + 3]])
        };
        if u32_at(0) != BANK_MAGIC || u32_at(1) != BANK_VERSION {
            bail!("bad fewshot bank header in {}", path.display());
        }
        let (nc, per, h, w, c) = (
            u32_at(2) as usize,
            u32_at(3) as usize,
            u32_at(4) as usize,
            u32_at(5) as usize,
            u32_at(6) as usize,
        );
        let images = read_f32_slice(&bytes[28..]);
        if images.len() != nc * per * h * w * c {
            bail!(
                "fewshot bank data length {} != {}x{}x{}x{}x{}",
                images.len(),
                nc,
                per,
                h,
                w,
                c
            );
        }
        Ok(Self {
            num_classes: nc,
            per_class: per,
            height: h,
            width: w,
            channels: c,
            images,
        })
    }

    pub fn num_images(&self) -> usize {
        self.num_classes * self.per_class
    }

    /// Pixels of one image (flat HWC).
    pub fn image(&self, i: usize) -> &[f32] {
        let per = self.height * self.width * self.channels;
        &self.images[i * per..(i + 1) * per]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_slice_round_trips() {
        let vals = [1.5f32, -2.25, 0.0, f32::MAX];
        let mut bytes = Vec::new();
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        assert_eq!(read_f32_slice(&bytes), vals);
    }

    #[test]
    fn default_dir_respects_env() {
        // Don't mutate the env (tests run in parallel) — just shape checks.
        let p = ArtifactPaths::at("/tmp/xyz");
        assert_eq!(p.backbone_hlo(8), PathBuf::from("/tmp/xyz/backbone_b8.hlo.txt"));
        assert_eq!(p.graph_json(), PathBuf::from("/tmp/xyz/graph.json"));
        assert!(!ArtifactPaths::at("/nonexistent_bwade").exists());
    }

    #[test]
    fn bank_rejects_garbage() {
        let dir = std::env::temp_dir().join("bwade_bank_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, [0u8; 32]).unwrap();
        assert!(FewshotBank::load(&path).is_err());
    }

    #[test]
    fn bank_parses_valid_header() {
        let dir = std::env::temp_dir().join("bwade_bank_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ok.bin");
        let (nc, per, h, w, c) = (2u32, 3u32, 2u32, 2u32, 1u32);
        let mut bytes = Vec::new();
        for v in [BANK_MAGIC, BANK_VERSION, nc, per, h, w, c] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let n = (nc * per * h * w * c) as usize;
        for i in 0..n {
            bytes.extend_from_slice(&(i as f32).to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let bank = FewshotBank::load(&path).unwrap();
        assert_eq!(bank.num_images(), 6);
        assert_eq!(bank.image(1)[0], 4.0); // second image starts at elem 4
    }
}
