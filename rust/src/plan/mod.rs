//! Compiled execution plans — the engine behind graph execution.
//!
//! The original executor (`ops::execute_interpreted`) re-cloned and
//! re-toposorted the whole [`Graph`] on every call and resolved every
//! tensor through `HashMap<String, Tensor>` lookups — per node, per call.
//! PEFSL (arXiv:2404.19354) and the MLPerf-Tiny FPGA codesign line both
//! show that deployment-pipeline overhead, not kernel math, dominates
//! small-model latency on edge SoCs; the ROADMAP's "fast as the hardware
//! allows" requires the same discipline on the software request path.
//!
//! [`ExecutionPlan::compile`] does all graph-shaped work ONCE:
//!
//! * topological order resolved at compile time (`Graph::toposort_order`,
//!   no clone);
//! * every tensor name interned to a dense slot id — the run loop indexes
//!   arrays, it never hashes a string;
//! * initializers bound to their slots once, not looked up per node per
//!   call;
//! * per-step output shapes resolved and cross-checked against
//!   [`crate::ops::infer_output_shape`] (stale shape annotations fail at
//!   compile, not as corrupted buffers at run time);
//! * node attributes (kernel/stride/pad/perm/shape/axes/layout) resolved
//!   into a typed [`crate::ops::OpSpec`] per step — the run loop never
//!   calls `Attrs::ints()` (string scan + `Vec` clone) again, and a
//!   malformed attribute fails at compile, not mid-frame;
//! * a liveness analysis records each activation's last use; the run loop
//!   returns dead buffers to a reusable arena ([`PlanScratch`]) instead of
//!   dropping them, and steals a dying input's buffer outright for
//!   elementwise/reshape steps (`ops::supports_inplace`).
//!
//! [`ExecutionPlan::run`] then touches no graph structure at all: slots
//! in, slots out.  [`ExecutionPlan::run_batch`] / [`run_with`] amortize
//! the arena across frames — the serving coordinator's path.
//!
//! ## Datapaths
//!
//! A plan compiles for one of two [`Datapath`]s.  `F32` is the float
//! simulation the transform pipeline verifies against.  `BitTrue`
//! compiles a *fully-lowered, format-annotated* HW graph
//! ([`crate::transforms::annotate_bit_true_formats`]) into typed slots:
//! activations are **packed** fixed-point code tensors stored in the
//! narrowest container their annotated code range permits (`bt_container`
//! -> i8 / i16 / i32), initializers are converted to width-native integer
//! codes ONCE at compile (weights/biases checked onto their grids,
//! thresholds via the exact `ceil(t * 2^frac)` rule; MVAU bias/threshold
//! codes stay on the wide i32 accumulator grid), and every step
//! dispatches a container-monomorphized integer kernel
//! ([`crate::ops::IntOpSpec`]).  The buffer arena keeps one pool per
//! container width, so an i8 activation costs a quarter of the bandwidth
//! its i32 predecessor did — the narrow-datapath story of the paper on
//! the CPU side, measured by [`ExecutionPlan::bytes_moved_per_frame`].
//! [`ExecutionPlan::compile_bit_true_wide`] forces every container to
//! i32: the differential oracle packed plans are tested against.
//!
//! The only steps allowed to touch f32 are the ingress layout Transpose
//! and the ingress quantizer (float *comparisons*, no arithmetic);
//! [`ExecutionPlan::kernel_variants`] is the audit hook tests use to
//! prove it — and it reports the container width each integer step ran
//! at ("int8" / "int16" / "int32").  Outputs are integer codes with
//! [`ExecutionPlan::output_frac`] fractional bits — the [`PlanRunner`]
//! dequantizes once at egress, straight from the packed codes.
//!
//! [`run_with`]: ExecutionPlan::run_with

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::graph::{Graph, Node};
use crate::ops;
use crate::tensor::{DType, Tensor, TensorData};

pub mod elastic;
pub mod pipeline;

/// Which arithmetic a compiled plan executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Datapath {
    /// f32 kernels — the float simulation of the quantized network.
    #[default]
    F32,
    /// Integer kernels over fixed-point codes — bit-exactly what the
    /// FPGA dataflow accelerator computes.
    BitTrue,
}

impl Datapath {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" | "float" => Ok(Datapath::F32),
            "bit-true" | "bittrue" | "int" => Ok(Datapath::BitTrue),
            other => bail!("unknown datapath {other:?} (use f32 or bit-true)"),
        }
    }

    pub fn describe(self) -> &'static str {
        match self {
            Datapath::F32 => "f32",
            Datapath::BitTrue => "bit-true",
        }
    }
}

/// Kernel selected for one step: the float spec or its integer twin.
#[derive(Debug, Clone)]
enum StepKind {
    F32(ops::OpSpec),
    Int(ops::IntOpSpec),
}

/// One compiled step: a node with its IO resolved to dense slot ids and
/// its attributes resolved to a typed kernel spec.
#[derive(Debug, Clone)]
struct PlanStep {
    /// Node name (diagnostics only).
    name: String,
    /// Op name (diagnostics + in-place eligibility at compile).
    op: String,
    /// Kernel pre-resolved from `Attrs` (and, for the bit-true datapath,
    /// from the `bt_*` format annotations) at compile time — the run
    /// loop never scans an attribute string or clones an attr list.
    kind: StepKind,
    /// Output element type (i32 codes on the bit-true datapath).
    out_dtype: DType,
    /// Input slot per node input, in node order.
    inputs: Vec<u32>,
    /// The (single) output slot.
    output: u32,
    /// Resolved output shape (from the graph's shape table, verified
    /// against shape inference at compile time).
    out_shape: Vec<usize>,
    /// Activation slots whose last use is this step — their buffers go
    /// back to the arena right after execution.
    release: Vec<u32>,
    /// Steal `inputs[0]`'s buffer and mutate it in place instead of
    /// allocating an output (elementwise/reshape steps whose first input
    /// dies here; f32 datapath only).
    inplace: bool,
}

impl PlanStep {
    /// Kernel-variant label: "f32", the ingress labels, or the container
    /// width an integer step stores its output at (shared by
    /// [`ExecutionPlan::kernel_variants`] and [`PlanProfile`]).
    fn variant_label(&self) -> &'static str {
        match &self.kind {
            StepKind::F32(_) => "f32",
            StepKind::Int(spec) => match spec.variant() {
                "int" => match self.out_dtype {
                    DType::I8 => "int8",
                    DType::I16 => "int16",
                    DType::I32 => "int32",
                    DType::U4 => "int4",
                    DType::U1 | DType::B1 => "int1",
                    DType::F32 => "int-f32-bug",
                },
                ingress => ingress,
            },
        }
    }
}

/// A graph input: where its tensor goes and what shape it must have.
#[derive(Debug, Clone)]
struct FeedSpec {
    name: String,
    slot: u32,
    /// Expected shape when the graph records one (checked at run time).
    shape: Option<Vec<usize>>,
}

/// Reusable per-run state: the slot environment and the buffer arena.
///
/// Keep one of these alive across calls (`run_with` / `run_batch`) and
/// steady-state execution performs no heap allocation for activations —
/// every output buffer is recycled from a prior frame.
#[derive(Debug, Default)]
pub struct PlanScratch {
    /// Materialized activations, slot-indexed.
    act: Vec<Option<Tensor>>,
    /// Free f32 buffers returned by dead activations.
    pool_f: Vec<Vec<f32>>,
    /// Free packed code buffers, one pool per container width — an i8
    /// activation never borrows (or pays for) an i32-sized allocation.
    pool_i8: Vec<Vec<i8>>,
    pool_i16: Vec<Vec<i16>>,
    pool_i32: Vec<Vec<i32>>,
    /// Sub-byte containers pool raw byte buffers: u4 nibble pairs in one
    /// pool, u1/b1 bit buffers in the other (`Tensor::packed_from_buf`
    /// zero-fills on reuse, so stale tail bits never leak).
    pool_u4: Vec<Vec<u8>>,
    pool_u1: Vec<Vec<u8>>,
    pub stats: ArenaStats,
}

/// Arena instrumentation (exposed for tests and the §Perf bench).
#[derive(Debug, Default, Clone, Copy)]
pub struct ArenaStats {
    /// Buffers allocated fresh from the system allocator.
    pub fresh_allocs: usize,
    /// Buffers recycled from the arena pool.
    pub reuses: usize,
    /// Steps that stole their input's buffer in place.
    pub inplace_steps: usize,
    /// Peak number of live activation buffers in any single run.
    pub peak_live: usize,
    live: usize,
}

/// Carve a buffer of `numel` elements out of a pool: the smallest pooled
/// buffer whose capacity fits, else the largest (it grows once and then
/// fits forever).  The buffer is NOT zeroed — every kernel behind the
/// into-executors either fully overwrites or zero-fills before
/// accumulating, so steady-state same-size reuse writes nothing here.
fn carve<T: Copy + Default>(
    pool: &mut Vec<Vec<T>>,
    stats: &mut ArenaStats,
    numel: usize,
) -> Vec<T> {
    if pool.is_empty() {
        stats.fresh_allocs += 1;
        return vec![T::default(); numel];
    }
    let mut best = 0usize;
    for i in 1..pool.len() {
        let (c, b) = (pool[i].capacity(), pool[best].capacity());
        let better = if c >= numel { b < numel || c < b } else { b < numel && c > b };
        if better {
            best = i;
        }
    }
    stats.reuses += 1;
    let mut buf = pool.swap_remove(best);
    buf.resize(numel, T::default());
    buf
}

impl PlanScratch {
    fn pool_back(&mut self, data: TensorData) {
        match data {
            TensorData::F32(v) => self.pool_f.push(v),
            TensorData::I8(v) => self.pool_i8.push(v),
            TensorData::I16(v) => self.pool_i16.push(v),
            TensorData::I32(v) => self.pool_i32.push(v),
            TensorData::U4(p) => self.pool_u4.push(p.into_bytes()),
            TensorData::U1(p) | TensorData::B1(p) => self.pool_u1.push(p.into_bytes()),
        }
    }

    fn reset(&mut self, n_slots: usize) {
        for i in 0..self.act.len() {
            if let Some(t) = self.act[i].take() {
                self.pool_back(t.into_raw_data());
            }
        }
        self.act.resize(n_slots, None);
        self.stats.live = 0;
    }

    /// Return a dead activation's buffer to the matching pool.
    fn recycle(&mut self, t: Tensor) {
        self.pool_back(t.into_raw_data());
    }

    fn alloc(&mut self, shape: &[usize]) -> Result<Tensor> {
        let numel: usize = shape.iter().product();
        Tensor::new(shape.to_vec(), carve(&mut self.pool_f, &mut self.stats, numel))
    }

    fn alloc_typed(&mut self, shape: &[usize], dtype: DType) -> Result<Tensor> {
        let numel: usize = shape.iter().product();
        match dtype {
            DType::F32 => self.alloc(shape),
            DType::I8 => Tensor::new_i8(
                shape.to_vec(),
                carve(&mut self.pool_i8, &mut self.stats, numel),
            ),
            DType::I16 => Tensor::new_i16(
                shape.to_vec(),
                carve(&mut self.pool_i16, &mut self.stats, numel),
            ),
            DType::I32 => Tensor::new_i32(
                shape.to_vec(),
                carve(&mut self.pool_i32, &mut self.stats, numel),
            ),
            DType::U4 | DType::U1 | DType::B1 => {
                let pool = if dtype == DType::U4 {
                    &mut self.pool_u4
                } else {
                    &mut self.pool_u1
                };
                let bytes = carve(pool, &mut self.stats, dtype.bytes_for(numel));
                Tensor::packed_from_buf(shape.to_vec(), bytes, dtype)
            }
        }
    }
}

/// Opt-in per-step profile of a compiled plan: wall time, invocation
/// counts, and bytes moved per step and per kernel variant (DESIGN.md
/// §11).  Built from a plan ([`ExecutionPlan::new_profile`]) and filled
/// by [`ExecutionPlan::run_with_profile`]; the unprofiled entry points
/// never touch it — the run loop is monomorphized over a `const PROF:
/// bool`, so the disabled path compiles to exactly the pre-profiling
/// code (the zero-overhead-when-disabled guarantee, asserted by
/// `hotpath_micro`).
#[derive(Debug, Clone, Default)]
pub struct PlanProfile {
    steps: Vec<StepProfile>,
    runs: u64,
}

/// One step's accumulated profile.
#[derive(Debug, Clone)]
pub struct StepProfile {
    /// Node name (matches the lowered graph / `HwNodeModel` name — the
    /// measured-vs-predicted join key of `bwade profile`).
    pub name: String,
    pub op: String,
    /// Kernel-variant label (same vocabulary as
    /// [`ExecutionPlan::kernel_variants`]).
    pub variant: &'static str,
    /// Bytes one invocation streams (inputs read + output written).
    pub bytes_per_call: u64,
    pub calls: u64,
    /// Accumulated wall time executing this step's kernel.
    pub nanos: u64,
}

/// Per-kernel-variant aggregate of a [`PlanProfile`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VariantProfile {
    pub variant: &'static str,
    /// Number of plan steps with this variant.
    pub steps: usize,
    pub calls: u64,
    pub nanos: u64,
    pub bytes: u64,
}

impl PlanProfile {
    pub fn steps(&self) -> &[StepProfile] {
        &self.steps
    }

    /// Completed profiled runs.
    pub fn runs(&self) -> u64 {
        self.runs
    }

    pub fn total_nanos(&self) -> u64 {
        self.steps.iter().map(|s| s.nanos).sum()
    }

    /// Total bytes streamed across all profiled calls.
    pub fn total_bytes(&self) -> u64 {
        self.steps.iter().map(|s| s.calls * s.bytes_per_call).sum()
    }

    /// Aggregate by kernel variant, sorted by variant label.
    pub fn by_variant(&self) -> Vec<VariantProfile> {
        let mut agg: std::collections::BTreeMap<&'static str, VariantProfile> =
            std::collections::BTreeMap::new();
        for s in &self.steps {
            let e = agg.entry(s.variant).or_insert(VariantProfile {
                variant: s.variant,
                steps: 0,
                calls: 0,
                nanos: 0,
                bytes: 0,
            });
            e.steps += 1;
            e.calls += s.calls;
            e.nanos += s.nanos;
            e.bytes += s.calls * s.bytes_per_call;
        }
        agg.into_values().collect()
    }
}

/// A graph compiled for repeated execution.
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    name: String,
    datapath: Datapath,
    n_slots: usize,
    /// Number of slots produced by steps (activations).
    n_activations: usize,
    steps: Vec<PlanStep>,
    feeds: Vec<FeedSpec>,
    /// Graph outputs: (name, slot).
    outputs: Vec<(String, u32)>,
    /// Per-output fractional bits on the bit-true datapath (None for f32
    /// outputs / the f32 datapath) — the egress dequantization contract.
    out_fracs: Vec<Option<i32>>,
    /// Initializer tensors bound to their slots at compile time (already
    /// converted to packed integer codes on the bit-true datapath).
    init: Vec<Option<Tensor>>,
    /// Slot -> tensor name (diagnostics only).
    slot_names: Vec<String>,
    /// Bytes every run streams through the kernels: per step, the bytes
    /// of every input read plus the output written, at the slots' actual
    /// container widths, plus `egress_bytes` (DESIGN.md §9 bytes-moved
    /// accounting).
    bytes_moved: u64,
    /// Egress boundary traffic per frame: integer output codes read plus
    /// the f32 features written by the caller's dequantize.  Zero on the
    /// f32 datapath; included in `bytes_moved` but in no step's
    /// `step_bytes` — the dequantize is not a plan step.
    egress_bytes: u64,
    /// The same accounting, per step (same order as `steps`) — the
    /// bytes-per-call column of a [`PlanProfile`].
    step_bytes: Vec<u64>,
}

fn intern<'g>(
    name: &'g str,
    slot_of: &mut HashMap<&'g str, u32>,
    names: &mut Vec<String>,
) -> u32 {
    if let Some(&s) = slot_of.get(name) {
        return s;
    }
    let s = names.len() as u32;
    names.push(name.to_string());
    slot_of.insert(name, s);
    s
}

/// How a bit-true initializer is converted to integer codes.
#[derive(Debug, Clone, Copy, PartialEq)]
enum ConvMode {
    /// Values must sit exactly on the 2^-frac grid (weights, biases).
    Exact,
    /// Thresholds: `ceil(t * 2^frac)` — exact w.r.t. the comparison
    /// semantics even for off-grid thresholds.
    Ceil,
}

/// Convert an f32 initializer to integer codes at `frac` fractional
/// bits.  With `narrow`, the codes land in the narrowest container that
/// holds them (width-native weight / threshold copies — the BRAM-model
/// bandwidth story on the CPU side); without it they stay i32 (MVAU
/// bias/threshold data on the wide accumulator grid, and every
/// conversion of a [`ExecutionPlan::compile_bit_true_wide`] oracle plan).
fn quantize_init(
    t: &Tensor,
    frac: i32,
    mode: ConvMode,
    narrow: bool,
    name: &str,
) -> Result<Tensor> {
    let scale = (2.0f64).powi(frac);
    let mut codes = Vec::with_capacity(t.numel());
    let (mut lo, mut hi) = (0i64, 0i64);
    for &v in t.data() {
        let exact = v as f64 * scale;
        let code = match mode {
            ConvMode::Exact => {
                let c = exact.round();
                if (c / scale) as f32 != v {
                    bail!(
                        "initializer {name}: value {v} is off the 2^-{frac} grid — requantize the graph before bit-true compilation"
                    );
                }
                c
            }
            ConvMode::Ceil => exact.ceil(),
        };
        if code > i32::MAX as f64 || code < i32::MIN as f64 {
            bail!("initializer {name}: code {code} overflows the i32 datapath");
        }
        let code = code as i64;
        lo = lo.min(code);
        hi = hi.max(code);
        codes.push(code);
    }
    let shape = t.shape().to_vec();
    if narrow {
        // Same container-selection rule as the bt_container annotation —
        // plus the code-set-aware bipolar case the range rule cannot
        // see: weights spanning exactly {-1, +1} with no zero code pack
        // into the 1-bit B1 container (the XNOR datapath operand).
        if lo == -1 && hi == 1 && codes.iter().all(|&c| c != 0) {
            let c32: Vec<i32> = codes.iter().map(|&c| c as i32).collect();
            return Tensor::from_codes_packed(shape, &c32, DType::B1);
        }
        match crate::fixedpoint::container_bits_for_range(lo, hi) {
            1 => {
                let c32: Vec<i32> = codes.iter().map(|&c| c as i32).collect();
                return Tensor::from_codes_packed(shape, &c32, DType::U1);
            }
            4 => {
                let c32: Vec<i32> = codes.iter().map(|&c| c as i32).collect();
                return Tensor::from_codes_packed(shape, &c32, DType::U4);
            }
            8 => return Tensor::new_i8(shape, codes.into_iter().map(|c| c as i8).collect()),
            16 => return Tensor::new_i16(shape, codes.into_iter().map(|c| c as i16).collect()),
            _ => {}
        }
    }
    Tensor::new_i32(shape, codes.into_iter().map(|c| c as i32).collect())
}

/// Read a `bt_*` annotation, with a helpful error when it is missing.
fn bt_attr(node: &Node, key: &str) -> Result<i64> {
    node.attrs.int(key).map_err(|_| {
        anyhow!(
            "node {} ({}) lacks bit-true annotation {key} — run transforms::annotate_bit_true_formats on the lowered graph first",
            node.name,
            node.op
        )
    })
}

/// One initializer conversion a bit-true step needs: input index, frac,
/// rounding mode, and whether the codes may pack into a narrow container
/// (weights and standalone threshold matrices) or must stay on the wide
/// i32 accumulator grid (MVAU bias/threshold data).
#[derive(Debug, Clone, Copy, PartialEq)]
struct ConvJob {
    input: usize,
    frac: i32,
    mode: ConvMode,
    narrow: bool,
}

/// Resolve a node into its integer kernel spec plus the initializer
/// conversions it needs.
fn resolve_int_step(node: &Node) -> Result<(ops::IntOpSpec, Vec<ConvJob>)> {
    let mut conv: Vec<ConvJob> = Vec::new();
    let spec = match node.op.as_str() {
        "Transpose" => ops::IntOpSpec::Transpose {
            perm: node.attrs.ints("perm")?.iter().map(|&p| p as usize).collect(),
            float_ingress: node.attrs.int_or("bt_out_f32", 0) != 0,
        },
        "MultiThreshold" | "Thresholding" => {
            let layout = ops::ChanLayout::parse(node.attrs.str_or("data_layout", "NCHW"))?;
            let out_mul = bt_attr(node, "bt_out_mul")?;
            let out_add = bt_attr(node, "bt_out_add")?;
            if node.attrs.int_or("bt_in_f32", 0) != 0 {
                // Ingress quantizer: float thresholds stay float.
                ops::IntOpSpec::QuantizeThreshold { layout, out_mul, out_add }
            } else {
                conv.push(ConvJob {
                    input: 1,
                    frac: bt_attr(node, "bt_in_frac")? as i32,
                    mode: ConvMode::Ceil,
                    narrow: true,
                });
                ops::IntOpSpec::Threshold { layout, out_mul, out_add }
            }
        }
        "MVAU" => {
            let apply_act = node.attrs.int_or("apply_act", 1) != 0;
            let acc_frac = bt_attr(node, "bt_acc_frac")? as i32;
            conv.push(ConvJob {
                input: 1,
                frac: bt_attr(node, "bt_w_frac")? as i32,
                mode: ConvMode::Exact,
                narrow: true,
            });
            conv.push(ConvJob {
                input: 2,
                frac: acc_frac,
                mode: ConvMode::Exact,
                narrow: false,
            });
            if apply_act {
                conv.push(ConvJob {
                    input: 3,
                    frac: acc_frac,
                    mode: ConvMode::Ceil,
                    narrow: false,
                });
            }
            ops::IntOpSpec::Mvau {
                apply_act,
                out_mul: bt_attr(node, "bt_out_mul")?,
                out_add: bt_attr(node, "bt_out_add")?,
            }
        }
        "Im2Col" | "ConvolutionInputGenerator" => ops::IntOpSpec::Im2Col {
            kernel: ops::attr_pair(node.attrs.ints("kernel")?, "kernel")?,
            stride: ops::attr_pair(node.attrs.ints("stride")?, "stride")?,
            pad: ops::attr_pair(node.attrs.ints("pad")?, "pad")?,
        },
        "MaxPoolNHWC" | "StreamingMaxPool" => ops::IntOpSpec::MaxPoolNhwc,
        "Add" | "AddStreams" => ops::IntOpSpec::AddStreams {
            shift: [
                bt_attr(node, "bt_shift_a")? as u32,
                bt_attr(node, "bt_shift_b")? as u32,
            ],
        },
        "Mul" | "ChannelwiseMul" => ops::IntOpSpec::MulScalar {
            m: bt_attr(node, "bt_mul")?,
            data_input: bt_attr(node, "bt_data_input")? as usize,
        },
        "GlobalAccPool" | "GlobalAccPool_hw" => ops::IntOpSpec::GlobalAccPool,
        other => bail!("op {other} has no bit-true executor"),
    };
    Ok((spec, conv))
}

impl ExecutionPlan {
    /// Compile a graph for the f32 datapath: one toposort, one interning
    /// pass, one liveness pass.  The graph is not modified and not
    /// needed afterwards.
    pub fn compile(graph: &Graph) -> Result<Self> {
        Self::compile_with(graph, Datapath::F32)
    }

    /// Compile a fully-lowered, format-annotated HW graph for the
    /// bit-true integer datapath (see the module docs' ingress/egress
    /// contract): activations and weight/threshold initializers are
    /// packed into the narrowest containers their annotations permit.
    pub fn compile_bit_true(graph: &Graph) -> Result<Self> {
        Self::compile_with(graph, Datapath::BitTrue)
    }

    /// Compile the bit-true datapath with every container forced to i32
    /// — the differential oracle packed plans are verified against (and
    /// the "before" side of the packed-vs-i32 bench).  Same kernels,
    /// same codes, 4x the bytes for sub-8-bit formats.
    pub fn compile_bit_true_wide(graph: &Graph) -> Result<Self> {
        Self::compile_impl(graph, Datapath::BitTrue, true)
    }

    /// Compile for an explicit datapath.
    pub fn compile_with(graph: &Graph, datapath: Datapath) -> Result<Self> {
        Self::compile_impl(graph, datapath, false)
    }

    fn compile_impl(graph: &Graph, datapath: Datapath, wide: bool) -> Result<Self> {
        let order = graph.toposort_order()?;
        let mut slot_of: HashMap<&str, u32> = HashMap::new();
        let mut slot_names: Vec<String> = Vec::new();

        // Feeds first so graph inputs get stable low slots.
        let mut feeds = Vec::with_capacity(graph.inputs.len());
        for name in &graph.inputs {
            let slot = intern(name, &mut slot_of, &mut slot_names);
            feeds.push(FeedSpec {
                name: name.clone(),
                slot,
                shape: graph.shapes.get(name).cloned(),
            });
        }

        // Steps in topological order, with slot-resolved IO.
        let mut steps: Vec<PlanStep> = Vec::with_capacity(order.len());
        // slot -> step index that produces it
        let mut produced_by: Vec<Option<usize>> = vec![None; slot_names.len()];
        // slot -> shape, where known (feeds + annotations + initializers)
        let mut known: Vec<Option<Vec<usize>>> = vec![None; slot_names.len()];
        // slot -> fractional bits (bit-true datapath egress bookkeeping)
        let mut slot_frac: Vec<Option<i32>> = vec![None; slot_names.len()];
        // bit-true initializer conversions: (slot, job)
        let mut conv_jobs: Vec<(u32, ConvJob)> = Vec::new();
        // initializer slots an ingress kernel must keep as raw f32
        let mut f32_init_slots: Vec<u32> = Vec::new();
        for f in &feeds {
            known[f.slot as usize] = f.shape.clone();
        }

        for (si, &ni) in order.iter().enumerate() {
            let node = &graph.nodes[ni];
            if node.outputs.len() != 1 {
                bail!(
                    "plan: node {} has {} outputs; only single-output nodes are executable",
                    node.name,
                    node.outputs.len()
                );
            }
            let inputs: Vec<u32> = node
                .inputs
                .iter()
                .map(|t| intern(t, &mut slot_of, &mut slot_names))
                .collect();
            let output = intern(&node.outputs[0], &mut slot_of, &mut slot_names);
            produced_by.resize(slot_names.len(), None);
            known.resize(slot_names.len(), None);
            slot_frac.resize(slot_names.len(), None);
            if produced_by[output as usize].is_some() {
                bail!("plan: tensor {} produced twice", node.outputs[0]);
            }
            produced_by[output as usize] = Some(si);

            // Fill input shapes from initializers on first sight.
            for (&slot, name) in inputs.iter().zip(&node.inputs) {
                if known[slot as usize].is_none() {
                    if let Some(t) = graph.initializers.get(name) {
                        known[slot as usize] = Some(t.shape().to_vec());
                    }
                }
            }

            let out_shape = graph.shape_of(&node.outputs[0])?.to_vec();
            // Cross-check the annotation against shape inference when all
            // input shapes are known — a stale annotation dies here, not
            // as a corrupted buffer at run time.
            let in_shapes: Option<Vec<&[usize]>> = inputs
                .iter()
                .map(|&s| known[s as usize].as_deref())
                .collect();
            if let Some(in_shapes) = in_shapes {
                let inferred = ops::infer_output_shape(node, &in_shapes)
                    .map_err(|e| anyhow!("plan: node {} ({}): {e}", node.name, node.op))?;
                if inferred != out_shape {
                    bail!(
                        "plan: node {} ({}): graph annotates output {:?} but inference says {:?} — stale shape annotation",
                        node.name,
                        node.op,
                        out_shape,
                        inferred
                    );
                }
            }
            known[output as usize] = Some(out_shape.clone());

            let (kind, out_dtype) = match datapath {
                Datapath::F32 => {
                    let spec = ops::OpSpec::resolve(node)
                        .map_err(|e| anyhow!("plan: node {} ({}): {e}", node.name, node.op))?;
                    (StepKind::F32(spec), DType::F32)
                }
                Datapath::BitTrue => {
                    let (spec, conv) = resolve_int_step(node)
                        .map_err(|e| anyhow!("plan: node {} ({}): {e}", node.name, node.op))?;
                    for mut job in conv {
                        let slot = *inputs.get(job.input).ok_or_else(|| {
                            anyhow!("plan: node {}: missing input {}", node.name, job.input)
                        })?;
                        if wide {
                            job.narrow = false;
                        }
                        conv_jobs.push((slot, job));
                    }
                    // The ingress quantizer reads its threshold matrix as
                    // raw f32 — that slot must never also be converted.
                    if let ops::IntOpSpec::QuantizeThreshold { .. } = &spec {
                        f32_init_slots.push(inputs[1]);
                    }
                    let dtype = if node.attrs.int_or("bt_out_f32", 0) != 0 {
                        DType::F32
                    } else {
                        slot_frac[output as usize] = Some(bt_attr(node, "bt_out_frac")? as i32);
                        if wide {
                            DType::I32
                        } else {
                            match bt_attr(node, "bt_container")? {
                                // Container 1 is two code sets: bipolar
                                // {-1, +1} (the XNOR datapath) vs binary
                                // {0, 1} — the annotation disambiguates.
                                1 => {
                                    if node.attrs.int_or("bt_bipolar", 0) != 0 {
                                        DType::B1
                                    } else {
                                        DType::U1
                                    }
                                }
                                4 => DType::U4,
                                8 => DType::I8,
                                16 => DType::I16,
                                32 => DType::I32,
                                other => bail!(
                                    "plan: node {} ({}): bad bt_container {other} (want 1/4/8/16/32)",
                                    node.name,
                                    node.op
                                ),
                            }
                        }
                    };
                    (StepKind::Int(spec), dtype)
                }
            };
            steps.push(PlanStep {
                name: node.name.clone(),
                op: node.op.clone(),
                kind,
                out_dtype,
                inputs,
                output,
                out_shape,
                release: Vec::new(),
                inplace: false,
            });
        }

        // Graph outputs (produced, fed, or initializer-passthrough).
        let mut outputs = Vec::with_capacity(graph.outputs.len());
        for name in &graph.outputs {
            let slot = intern(name, &mut slot_of, &mut slot_names);
            produced_by.resize(slot_names.len(), None);
            known.resize(slot_names.len(), None);
            slot_frac.resize(slot_names.len(), None);
            let resolvable = produced_by[slot as usize].is_some()
                || graph.inputs.contains(name)
                || graph.initializers.contains_key(name);
            if !resolvable {
                bail!("plan: graph output {name} is never produced");
            }
            outputs.push((name.clone(), slot));
        }
        let out_fracs: Vec<Option<i32>> = outputs
            .iter()
            .map(|(_, slot)| slot_frac[*slot as usize])
            .collect();

        let n_slots = slot_names.len();

        // Bind initializers once.
        let mut init: Vec<Option<Tensor>> = vec![None; n_slots];
        for (name, tensor) in &graph.initializers {
            if let Some(&slot) = slot_of.get(name.as_str()) {
                init[slot as usize] = Some(tensor.clone());
            }
        }

        // Bit-true datapath: convert the initializers integer kernels
        // read — weights/biases exactly onto their grids, thresholds via
        // the ceil rule, weights/standalone-threshold matrices packed
        // into their narrowest containers — ONCE, into the plan's private
        // copies (the graph keeps its f32 initializers for folding/BRAM
        // modeling).
        if datapath == Datapath::BitTrue {
            let mut converted: HashMap<u32, ConvJob> = HashMap::new();
            for (slot, job) in conv_jobs {
                // Shared with an f32-retaining ingress consumer: reject at
                // compile (the run loop would otherwise hit the typed
                // accessor panic instead of a Result error).
                if f32_init_slots.contains(&slot) {
                    bail!(
                        "plan: initializer {} is read as raw f32 by an ingress quantizer and as integer codes by another step — duplicate the tensor in the graph",
                        slot_names[slot as usize]
                    );
                }
                if let Some(prev) = converted.get(&slot) {
                    // A second consumer must agree on frac, rounding mode
                    // AND container policy — a threshold-style Ceil
                    // conversion silently standing in for an Exact
                    // weight/bias grid check (or a narrow copy for a
                    // wide-grid consumer) would corrupt codes, not error.
                    if (prev.frac, prev.mode, prev.narrow) != (job.frac, job.mode, job.narrow) {
                        bail!(
                            "plan: initializer {} shared across incompatible bit-true conversions ({prev:?} vs {job:?})",
                            slot_names[slot as usize]
                        );
                    }
                    continue;
                }
                let src = init[slot as usize].as_ref().ok_or_else(|| {
                    anyhow!(
                        "plan: bit-true conversion target {} is not an initializer",
                        slot_names[slot as usize]
                    )
                })?;
                init[slot as usize] = Some(quantize_init(
                    src,
                    job.frac,
                    job.mode,
                    job.narrow,
                    &slot_names[slot as usize],
                )?);
                converted.insert(slot, job);
            }
        }

        // Liveness: last step reading each activation slot; graph outputs
        // are pinned (never recycled).
        let mut last_use: Vec<usize> = (0..n_slots)
            .map(|s| produced_by[s].unwrap_or(0))
            .collect();
        for (si, step) in steps.iter().enumerate() {
            for &s in &step.inputs {
                if produced_by[s as usize].is_some() {
                    last_use[s as usize] = si;
                }
            }
        }
        for (_, slot) in &outputs {
            last_use[*slot as usize] = usize::MAX;
        }

        // In-place marking: elementwise/reshape steps whose first input is
        // an activation that dies right here (and is not read twice).
        // f32 datapath only — integer steps always run into-buffer; the
        // typed arena still recycles everything.
        if datapath == Datapath::F32 {
            for (si, step) in steps.iter_mut().enumerate() {
                if !ops::supports_inplace(&step.op) || step.inputs.is_empty() {
                    continue;
                }
                let in0 = step.inputs[0];
                let eligible = produced_by[in0 as usize].is_some()
                    && last_use[in0 as usize] == si
                    && !step.inputs[1..].contains(&in0)
                    && match step.op.as_str() {
                        "Reshape" => known[in0 as usize]
                            .as_ref()
                            .map(|s| {
                                s.iter().product::<usize>() == step.out_shape.iter().product()
                            })
                            .unwrap_or(false),
                        _ => known[in0 as usize].as_deref() == Some(step.out_shape.as_slice()),
                    };
                step.inplace = eligible;
            }
        }

        // Release lists: after step si, recycle activations whose last use
        // was si — except a buffer stolen in place (it lives on as the
        // output).
        for s in 0..n_slots {
            if produced_by[s].is_none() || last_use[s] == usize::MAX {
                continue;
            }
            let si = last_use[s];
            if steps[si].inplace && steps[si].inputs[0] as usize == s {
                continue;
            }
            steps[si].release.push(s as u32);
        }

        let n_activations = produced_by.iter().filter(|p| p.is_some()).count();

        // Bytes-moved-per-frame: what each step reads (feeds at f32,
        // initializers and activations at their actual container widths)
        // plus what it writes.  Computed once at compile; the run loop
        // never re-measures.
        let mut bytes_moved = 0u64;
        let mut step_bytes = Vec::with_capacity(steps.len());
        for step in &steps {
            let mut step_total = 0u64;
            for &s in &step.inputs {
                let s = s as usize;
                let bytes = if let Some(t) = init[s].as_ref() {
                    t.dtype().bytes_for(t.numel())
                } else if let Some(p) = produced_by[s] {
                    steps[p]
                        .out_dtype
                        .bytes_for(steps[p].out_shape.iter().product())
                } else {
                    known[s].as_ref().map(|sh| sh.iter().product()).unwrap_or(0) * 4
                };
                step_total += bytes as u64;
            }
            step_total += step
                .out_dtype
                .bytes_for(step.out_shape.iter().product::<usize>())
                as u64;
            step_bytes.push(step_total);
            bytes_moved += step_total;
        }

        // Boundary traffic the bandwidth model must see: a bit-true
        // plan's caller feeds f32 frames in and reads f32 features out.
        // The ingress quantize read is already counted above (feed
        // slots are read at f32 width by their consuming step); the
        // egress dequantize — integer codes read + f32 features written
        // by the PlanRunner — is not a plan step, so add it here.  Not
        // part of `step_bytes`: the per-step profile measures kernel
        // execution only.
        let mut egress_bytes = 0u64;
        for ((_, slot), frac) in outputs.iter().zip(&out_fracs) {
            if frac.is_none() {
                continue;
            }
            if let Some(p) = produced_by[*slot as usize] {
                let numel: usize = steps[p].out_shape.iter().product();
                egress_bytes += steps[p].out_dtype.bytes_for(numel) as u64 + 4 * numel as u64;
            }
        }
        bytes_moved += egress_bytes;

        Ok(Self {
            name: graph.name.clone(),
            datapath,
            n_slots,
            n_activations,
            steps,
            feeds,
            outputs,
            out_fracs,
            init,
            slot_names,
            bytes_moved,
            egress_bytes,
            step_bytes,
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Which arithmetic this plan executes.
    pub fn datapath(&self) -> Datapath {
        self.datapath
    }

    /// Fractional bits of a named graph output on the bit-true datapath
    /// (None for f32 outputs / the f32 datapath) — dequantize egress
    /// codes as `code * 2^-frac`.
    pub fn output_frac(&self, name: &str) -> Option<i32> {
        self.outputs
            .iter()
            .position(|(n, _)| n == name)
            .and_then(|i| self.out_fracs[i])
    }

    /// `(op, kernel variant)` per step — the bit-true audit hook: a
    /// bit-true plan must contain no "f32" variant, exactly one
    /// "ingress-quant" and at most one "ingress-f32" layout conversion;
    /// every steady-state step reports the container width its output is
    /// stored at ("int1" / "int4" / "int8" / "int16" / "int32"), so tests
    /// can audit not just *that* a step ran integer kernels but *how
    /// wide*.
    pub fn kernel_variants(&self) -> Vec<(String, &'static str)> {
        self.steps
            .iter()
            .map(|s| (s.op.clone(), s.variant_label()))
            .collect()
    }

    /// Bytes one frame streams through the kernels (inputs read + outputs
    /// written, at actual container widths).  On the packed bit-true
    /// datapath this is the narrow-container bandwidth the paper's
    /// arbitrary-width datapaths save; compare against
    /// [`ExecutionPlan::compile_bit_true_wide`] for the i32 baseline.
    pub fn bytes_moved_per_frame(&self) -> u64 {
        self.bytes_moved
    }

    /// The egress-boundary share of [`Self::bytes_moved_per_frame`]:
    /// integer output codes read plus f32 features written when the
    /// caller dequantizes a bit-true plan's outputs (zero on the f32
    /// datapath).  A [`PlanProfile`] measures kernel steps only, so
    /// `profile.total_bytes() == runs * (bytes_moved - egress_bytes)`.
    pub fn egress_bytes_per_frame(&self) -> u64 {
        self.egress_bytes
    }

    pub fn num_steps(&self) -> usize {
        self.steps.len()
    }

    pub fn num_slots(&self) -> usize {
        self.n_slots
    }

    /// Number of step-produced (activation) tensors.
    pub fn num_activation_slots(&self) -> usize {
        self.n_activations
    }

    /// Steps compiled to mutate their input in place.
    pub fn num_inplace_steps(&self) -> usize {
        self.steps.iter().filter(|s| s.inplace).count()
    }

    fn resolve<'a>(
        &'a self,
        slot: u32,
        act: &'a [Option<Tensor>],
        ext: &[Option<&'a Tensor>],
    ) -> Result<&'a Tensor> {
        let s = slot as usize;
        if let Some(t) = act[s].as_ref() {
            return Ok(t);
        }
        if let Some(t) = ext[s] {
            return Ok(t);
        }
        if let Some(t) = self.init[s].as_ref() {
            return Ok(t);
        }
        bail!("tensor {} unavailable", self.slot_names[s])
    }

    /// Execute once with a fresh arena.
    pub fn run(&self, feeds: &HashMap<String, Tensor>) -> Result<HashMap<String, Tensor>> {
        let mut scratch = PlanScratch::default();
        self.run_with(feeds, &mut scratch)
    }

    /// Execute a batch of feed sets, amortizing the arena: frame k's
    /// activations are carved out of frame k-1's recycled buffers.
    pub fn run_batch(
        &self,
        feeds: &[HashMap<String, Tensor>],
    ) -> Result<Vec<HashMap<String, Tensor>>> {
        let mut scratch = PlanScratch::default();
        feeds
            .iter()
            .map(|f| self.run_with(f, &mut scratch))
            .collect()
    }

    /// Execute once, reusing `scratch` across calls.  This is the steady-
    /// state entry point: zero graph work, zero string hashing on the node
    /// path, and (after warmup) zero activation allocation.
    pub fn run_with(
        &self,
        feeds: &HashMap<String, Tensor>,
        scratch: &mut PlanScratch,
    ) -> Result<HashMap<String, Tensor>> {
        self.run_inner::<false>(feeds, scratch, None)
    }

    /// Fresh profile sized to this plan (per-step names, variants, and
    /// bytes-per-call pre-resolved; counters zero).
    pub fn new_profile(&self) -> PlanProfile {
        PlanProfile {
            steps: self
                .steps
                .iter()
                .zip(&self.step_bytes)
                .map(|(s, &b)| StepProfile {
                    name: s.name.clone(),
                    op: s.op.clone(),
                    variant: s.variant_label(),
                    bytes_per_call: b,
                    calls: 0,
                    nanos: 0,
                })
                .collect(),
            runs: 0,
        }
    }

    /// [`run_with`], accumulating per-step wall time into `profile` (a
    /// [`ExecutionPlan::new_profile`] of THIS plan).  The instrumented
    /// loop is a separate monomorphization — the unprofiled entry points
    /// pay nothing for its existence.
    ///
    /// [`run_with`]: ExecutionPlan::run_with
    pub fn run_with_profile(
        &self,
        feeds: &HashMap<String, Tensor>,
        scratch: &mut PlanScratch,
        profile: &mut PlanProfile,
    ) -> Result<HashMap<String, Tensor>> {
        if profile.steps.len() != self.steps.len() {
            bail!(
                "profile has {} steps but plan {} has {} — build it with new_profile() on this plan",
                profile.steps.len(),
                self.name,
                self.steps.len()
            );
        }
        self.run_inner::<true>(feeds, scratch, Some(profile))
    }

    fn run_inner<const PROF: bool>(
        &self,
        feeds: &HashMap<String, Tensor>,
        scratch: &mut PlanScratch,
        mut profile: Option<&mut PlanProfile>,
    ) -> Result<HashMap<String, Tensor>> {
        scratch.reset(self.n_slots);

        // Resolve feeds: the only name lookups in the whole run.
        let mut ext: Vec<Option<&Tensor>> = vec![None; self.n_slots];
        for spec in &self.feeds {
            let t = feeds
                .get(&spec.name)
                .ok_or_else(|| anyhow!("missing feed for graph input {}", spec.name))?;
            if let Some(shape) = &spec.shape {
                if t.shape() != shape.as_slice() {
                    bail!(
                        "feed {} has shape {:?}, graph expects {:?}",
                        spec.name,
                        t.shape(),
                        shape
                    );
                }
            }
            ext[spec.slot as usize] = Some(t);
        }

        for (si, step) in self.steps.iter().enumerate() {
            let t0 = if PROF {
                Some(std::time::Instant::now())
            } else {
                None
            };
            if step.inplace {
                let StepKind::F32(spec) = &step.kind else {
                    bail!("plan bug: in-place integer step {}", step.name);
                };
                let mut buf = scratch.act[step.inputs[0] as usize].take().ok_or_else(|| {
                    anyhow!(
                        "plan bug: in-place input of {} not materialized",
                        step.name
                    )
                })?;
                {
                    let rest: Vec<&Tensor> = step.inputs[1..]
                        .iter()
                        .map(|&s| self.resolve(s, &scratch.act, &ext))
                        .collect::<Result<_>>()?;
                    ops::execute_spec_inplace(spec, &mut buf, &rest).map_err(|e| {
                        anyhow!("executing {} ({}): {e}", step.name, step.op)
                    })?;
                }
                scratch.stats.inplace_steps += 1;
                scratch.act[step.output as usize] = Some(buf);
            } else {
                let mut out = scratch.alloc_typed(&step.out_shape, step.out_dtype)?;
                {
                    let inputs: Vec<&Tensor> = step
                        .inputs
                        .iter()
                        .map(|&s| self.resolve(s, &scratch.act, &ext))
                        .collect::<Result<_>>()?;
                    match &step.kind {
                        StepKind::F32(spec) => ops::execute_spec_into(spec, &inputs, &mut out),
                        StepKind::Int(spec) => ops::execute_int_spec_into(spec, &inputs, &mut out),
                    }
                    .map_err(|e| anyhow!("executing {} ({}): {e}", step.name, step.op))?;
                }
                scratch.stats.live += 1;
                scratch.stats.peak_live = scratch.stats.peak_live.max(scratch.stats.live);
                scratch.act[step.output as usize] = Some(out);
            }
            if PROF {
                if let (Some(p), Some(t0)) = (profile.as_mut(), t0) {
                    let sp = &mut p.steps[si];
                    sp.calls += 1;
                    sp.nanos += t0.elapsed().as_nanos() as u64;
                }
            }
            for &dead in &step.release {
                if let Some(t) = scratch.act[dead as usize].take() {
                    scratch.stats.live -= 1;
                    scratch.recycle(t);
                }
            }
        }

        let mut result = HashMap::with_capacity(self.outputs.len());
        for (name, slot) in &self.outputs {
            let s = *slot as usize;
            let t = if let Some(t) = scratch.act[s].take() {
                scratch.stats.live = scratch.stats.live.saturating_sub(1);
                t
            } else if let Some(t) = ext[s] {
                t.clone()
            } else if let Some(t) = self.init[s].as_ref() {
                t.clone()
            } else {
                bail!("graph output {name} not produced");
            };
            result.insert(name.clone(), t);
        }
        if PROF {
            if let Some(p) = profile {
                p.runs += 1;
            }
        }
        Ok(result)
    }
}

// ---------------------------------------------------------------------------
// PlanRunner — the plan engine as a serving feature extractor
// ---------------------------------------------------------------------------

/// Backbone feature extraction over a compiled plan: the python-free,
/// PJRT-free request path.  Accepts flat NHWC image batches (the same
/// contract as the PJRT `BackboneRunner`), converts to the graph's NCHW
/// import layout, and runs the plan once per frame with a shared arena —
/// the batch amortizes plan lookup and buffer allocation.
///
/// On the bit-true datapath ([`PlanRunner::new_bit_true`]) the plan
/// computes integer codes end to end; this runner dequantizes ONLY the
/// final feature vector (`code * 2^-frac`) at egress, so the features it
/// serves are exactly what the FPGA would produce.
///
/// Compiled plans are compile-once/run-many: the plan (steps, interned
/// slots, converted width-native weights) is immutable after compile and
/// sits behind an [`Arc`], while all per-run mutable state lives in the
/// [`PlanScratch`] arena.  [`PlanRunner::replicate`] exploits that split
/// to stamp out serving replicas that share one compiled plan but own
/// private scratch arenas — the substrate of the multi-replica pool
/// (`coordinator::pool`).
pub struct PlanRunner {
    plan: Arc<ExecutionPlan>,
    input: String,
    output: String,
    img: usize,
    feature_dim: usize,
    batch: usize,
    /// Egress dequantization scale (bit-true datapath only).
    out_scale: Option<f64>,
    scratch: RefCell<PlanScratch>,
}

impl PlanRunner {
    /// Compile `graph` (an NCHW import with input [1, 3, img, img] and
    /// output [1, feat]) into a batched f32 extractor.
    pub fn new(graph: &Graph, batch: usize) -> Result<Self> {
        Self::with_datapath(graph, batch, Datapath::F32)
    }

    /// Compile a *lowered, annotated* HW graph into a bit-true integer
    /// extractor (see [`crate::build::lower_bit_true`]).
    pub fn new_bit_true(graph: &Graph, batch: usize) -> Result<Self> {
        Self::with_datapath(graph, batch, Datapath::BitTrue)
    }

    pub fn with_datapath(graph: &Graph, batch: usize, datapath: Datapath) -> Result<Self> {
        if graph.inputs.len() != 1 || graph.outputs.len() != 1 {
            bail!(
                "PlanRunner needs a single-input single-output graph, got {} in / {} out",
                graph.inputs.len(),
                graph.outputs.len()
            );
        }
        let in_shape = graph.shape_of(&graph.inputs[0])?.to_vec();
        if in_shape.len() != 4 || in_shape[0] != 1 || in_shape[1] != 3 {
            bail!("PlanRunner expects NCHW input [1, 3, H, W], got {in_shape:?}");
        }
        if in_shape[2] != in_shape[3] {
            bail!("PlanRunner expects square images, got {in_shape:?}");
        }
        let out_shape = graph.shape_of(&graph.outputs[0])?.to_vec();
        let feature_dim = *out_shape
            .last()
            .ok_or_else(|| anyhow!("scalar graph output"))?;
        let plan = Arc::new(ExecutionPlan::compile_with(graph, datapath)?);
        let out_scale = match datapath {
            Datapath::F32 => None,
            Datapath::BitTrue => {
                let frac = plan.output_frac(&graph.outputs[0]).ok_or_else(|| {
                    anyhow!("bit-true plan has no egress format for {}", graph.outputs[0])
                })?;
                Some((2.0f64).powi(frac))
            }
        };
        Ok(Self {
            plan,
            input: graph.inputs[0].clone(),
            output: graph.outputs[0].clone(),
            img: in_shape[2],
            feature_dim,
            batch: batch.max(1),
            out_scale,
            scratch: RefCell::new(PlanScratch::default()),
        })
    }

    /// Which arithmetic the backbone runs.
    pub fn datapath(&self) -> Datapath {
        self.plan.datapath()
    }

    /// A new runner over the SAME compiled plan (`Arc` clone — no graph
    /// work, no weight conversion) with a fresh, empty scratch arena.
    /// Replicas are independent executors: each `extract` call touches
    /// only its own arena, so replicas may run on different threads
    /// concurrently while the plan is shared read-only.
    pub fn replicate(&self) -> PlanRunner {
        PlanRunner {
            plan: Arc::clone(&self.plan),
            input: self.input.clone(),
            output: self.output.clone(),
            img: self.img,
            feature_dim: self.feature_dim,
            batch: self.batch,
            out_scale: self.out_scale,
            scratch: RefCell::new(PlanScratch::default()),
        }
    }

    /// True when `other` executes the same compiled plan instance (the
    /// replicas of one [`PlanRunner::replicate`] family).
    pub fn shares_plan_with(&self, other: &PlanRunner) -> bool {
        Arc::ptr_eq(&self.plan, &other.plan)
    }

    /// Arena statistics accumulated over every extract call so far.
    pub fn arena_stats(&self) -> ArenaStats {
        self.scratch.borrow().stats
    }

    /// Bytes one frame streams through the backbone's kernels (see
    /// [`ExecutionPlan::bytes_moved_per_frame`]).
    pub fn bytes_moved_per_frame(&self) -> u64 {
        self.plan.bytes_moved_per_frame()
    }

    /// The compiled plan this runner executes (read-only — the profile
    /// command joins its step names against `DataflowSim` actors).
    pub fn plan(&self) -> &ExecutionPlan {
        &self.plan
    }

    /// Fresh per-step profile matching this runner's compiled plan.
    pub fn new_profile(&self) -> PlanProfile {
        self.plan.new_profile()
    }

    /// Run `frames` flat NHWC frames (`frames * img*img*3` elements)
    /// through the plan with per-step profiling, discarding features —
    /// the measurement loop of `bwade profile`.
    pub fn profile_frames(
        &self,
        images: &[f32],
        frames: usize,
        profile: &mut PlanProfile,
    ) -> Result<()> {
        let per = self.img * self.img * 3;
        if images.len() != frames * per {
            bail!(
                "expected {} input elements for {frames} frames, got {}",
                frames * per,
                images.len()
            );
        }
        let mut scratch = self.scratch.borrow_mut();
        let mut feeds = HashMap::with_capacity(1);
        for i in 0..frames {
            let x_nhwc = Tensor::new(
                vec![1, self.img, self.img, 3],
                images[i * per..(i + 1) * per].to_vec(),
            )?;
            feeds.insert(self.input.clone(), x_nhwc.nhwc_to_nchw()?);
            self.plan.run_with_profile(&feeds, &mut scratch, profile)?;
        }
        Ok(())
    }

    /// Run the plan for the first `live` frames of a full batch buffer —
    /// padded filler frames are never executed (the plan is per-frame,
    /// unlike a fixed-batch PJRT executable).
    fn extract_frames(&self, images: &[f32], live: usize) -> Result<Vec<f32>> {
        let per = self.img * self.img * 3;
        if images.len() != self.batch * per {
            bail!(
                "expected {} input elements, got {}",
                self.batch * per,
                images.len()
            );
        }
        let live = live.min(self.batch);
        let mut feats = Vec::with_capacity(live * self.feature_dim);
        let mut scratch = self.scratch.borrow_mut();
        let mut feeds = HashMap::with_capacity(1);
        for i in 0..live {
            let x_nhwc = Tensor::new(
                vec![1, self.img, self.img, 3],
                images[i * per..(i + 1) * per].to_vec(),
            )?;
            feeds.insert(self.input.clone(), x_nhwc.nhwc_to_nchw()?);
            let mut out = self.plan.run_with(&feeds, &mut scratch)?;
            let t = out
                .remove(&self.output)
                .ok_or_else(|| anyhow!("plan produced no {}", self.output))?;
            dequantize_egress(&t, self.out_scale, &mut feats)?;
        }
        Ok(feats)
    }
}

/// Egress dequantization shared by [`PlanRunner::extract_live`] and the
/// streaming executor ([`pipeline::PlanPipeline`]): f32 features pass
/// through, integer codes dequantize `code * 2^-frac` straight from the
/// packed container (the ONLY dequantization on the bit-true path).  One
/// implementation, so both execution modes are bitwise-identical at
/// egress by construction.
fn dequantize_egress(t: &Tensor, out_scale: Option<f64>, feats: &mut Vec<f32>) -> Result<()> {
    if let TensorData::F32(v) = t.raw_data() {
        feats.extend_from_slice(v);
        return Ok(());
    }
    let scale = out_scale.ok_or_else(|| anyhow!("integer output from an f32 plan"))?;
    match t.raw_data() {
        TensorData::I8(codes) => {
            feats.extend(codes.iter().map(|&c| (c as f64 / scale) as f32))
        }
        TensorData::I16(codes) => {
            feats.extend(codes.iter().map(|&c| (c as f64 / scale) as f32))
        }
        TensorData::I32(codes) => {
            feats.extend(codes.iter().map(|&c| (c as f64 / scale) as f32))
        }
        TensorData::U4(_) | TensorData::U1(_) | TensorData::B1(_) => {
            let view = t.code_view().expect("packed tensor has a code view");
            feats.extend((0..t.numel()).map(|i| (view.get(i) as f64 / scale) as f32));
        }
        TensorData::F32(_) => unreachable!("handled above"),
    }
    Ok(())
}

impl crate::coordinator::FeatureExtractor for PlanRunner {
    fn batch(&self) -> usize {
        self.batch
    }

    fn bytes_moved_per_frame(&self) -> Option<u64> {
        Some(self.plan.bytes_moved_per_frame())
    }

    fn img(&self) -> usize {
        self.img
    }

    fn feature_dim(&self) -> usize {
        self.feature_dim
    }

    fn extract(&self, images: &[f32]) -> Result<Vec<f32>> {
        self.extract_frames(images, self.batch)
    }

    fn extract_live(&self, images: &[f32], live: usize) -> Result<Vec<f32>> {
        self.extract_frames(images, live)
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::graph::{AttrVal, Attrs, Node};

    /// in -> Mul(s) -> t1 ; t1 -> Add(t1, b) -> t2 ; t2 -> Reshape -> out
    fn chain_graph() -> Graph {
        let mut g = Graph::new("chain");
        g.inputs = vec!["in".into()];
        g.outputs = vec!["out".into()];
        g.shapes.insert("in".into(), vec![2, 3]);
        g.shapes.insert("s".into(), vec![]);
        g.shapes.insert("b".into(), vec![3]);
        g.shapes.insert("t1".into(), vec![2, 3]);
        g.shapes.insert("t2".into(), vec![2, 3]);
        g.shapes.insert("out".into(), vec![3, 2]);
        g.initializers.insert("s".into(), Tensor::scalar(2.0));
        g.initializers
            .insert("b".into(), Tensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap());
        g.nodes.push(Node::new("Mul", "m", vec!["in".into(), "s".into()], vec!["t1".into()]));
        g.nodes.push(Node::new("Add", "a", vec!["t1".into(), "b".into()], vec!["t2".into()]));
        g.nodes.push(
            Node::new("Reshape", "r", vec!["t2".into()], vec!["out".into()])
                .with_attrs(Attrs::new().with("shape", AttrVal::Ints(vec![3, 2]))),
        );
        g
    }

    fn chain_feeds() -> HashMap<String, Tensor> {
        let mut feeds = HashMap::new();
        feeds.insert(
            "in".to_string(),
            Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap(),
        );
        feeds
    }

    #[test]
    fn plan_matches_interpreter_on_chain() {
        let g = chain_graph();
        let feeds = chain_feeds();
        let plan = ExecutionPlan::compile(&g).unwrap();
        let got = plan.run(&feeds).unwrap();
        let want = crate::ops::execute_interpreted(&g, &feeds).unwrap();
        assert_eq!(got["out"], want["out"]);
    }

    #[test]
    fn chain_runs_in_place_after_first_alloc() {
        // Step 0's input is the (borrowed) graph input — it must NOT be
        // stolen; it allocates one buffer.  The Add and Reshape then
        // steal that buffer: one allocation for the whole chain.
        let g = chain_graph();
        let plan = ExecutionPlan::compile(&g).unwrap();
        assert_eq!(plan.num_inplace_steps(), 2);
        let mut scratch = PlanScratch::default();
        let out = plan.run_with(&chain_feeds(), &mut scratch).unwrap();
        assert_eq!(scratch.stats.fresh_allocs, 1);
        assert_eq!(scratch.stats.inplace_steps, 2);
        assert_eq!(scratch.stats.peak_live, 1);
        assert_eq!(out["out"].shape(), &[3, 2]);
        // [1..6] * 2, + bias [1,2,3] per row: [[3,6,9],[9,12,15]].
        assert_eq!(out["out"].data(), &[3., 6., 9., 9., 12., 15.]);
    }

    #[test]
    fn arena_reuses_buffers_across_batch() {
        let g = chain_graph();
        let plan = ExecutionPlan::compile(&g).unwrap();
        let mut scratch = PlanScratch::default();
        for _ in 0..5 {
            plan.run_with(&chain_feeds(), &mut scratch).unwrap();
        }
        // One fresh buffer per frame for the first frame's alloc; later
        // frames recycle the... outputs are moved to the caller, so each
        // frame allocates one buffer but nothing accumulates beyond that.
        assert!(scratch.stats.fresh_allocs <= 5);
        assert_eq!(scratch.stats.peak_live, 1);
    }

    #[test]
    fn diamond_releases_skip_only_after_join() {
        // in -> A(Mul s) -> t1 ; t1 -> B(Mul s) -> t2 ; t1,t2 -> Add -> out
        // t1 must stay live until the Add, then be recycled.
        let mut g = Graph::new("diamond");
        g.inputs = vec!["in".into()];
        g.outputs = vec!["out".into()];
        for t in ["in", "t1", "t2", "out"] {
            g.shapes.insert(t.into(), vec![4]);
        }
        g.shapes.insert("s".into(), vec![]);
        g.initializers.insert("s".into(), Tensor::scalar(3.0));
        g.nodes.push(Node::new("Mul", "A", vec!["in".into(), "s".into()], vec!["t1".into()]));
        g.nodes.push(Node::new("Mul", "B", vec!["t1".into(), "s".into()], vec!["t2".into()]));
        g.nodes.push(Node::new("Add", "C", vec!["t1".into(), "t2".into()], vec!["out".into()]));
        let plan = ExecutionPlan::compile(&g).unwrap();
        // B cannot steal t1 (C still reads it); C can steal t1.
        let mut feeds = HashMap::new();
        feeds.insert("in".to_string(), Tensor::new(vec![4], vec![1., 2., 3., 4.]).unwrap());
        let mut scratch = PlanScratch::default();
        let out = plan.run_with(&feeds, &mut scratch).unwrap();
        assert_eq!(out["out"].data(), &[12., 24., 36., 48.]);
        assert!(scratch.stats.peak_live <= 2);
        let want = crate::ops::execute_interpreted(&g, &feeds).unwrap();
        assert_eq!(out["out"], want["out"]);
    }

    #[test]
    fn missing_feed_and_bad_shape_error() {
        let g = chain_graph();
        let plan = ExecutionPlan::compile(&g).unwrap();
        let err = plan.run(&HashMap::new()).unwrap_err().to_string();
        assert!(err.contains("missing feed"), "{err}");
        let mut feeds = HashMap::new();
        feeds.insert("in".to_string(), Tensor::zeros(vec![3, 2]));
        let err = plan.run(&feeds).unwrap_err().to_string();
        assert!(err.contains("shape"), "{err}");
    }

    #[test]
    fn attr_resolution_happens_at_compile() {
        // A malformed attribute (unknown data_layout) dies when the plan
        // is compiled — the run loop only ever sees typed OpSpecs.
        let mut g = Graph::new("badattr");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["y".into()];
        g.shapes.insert("x".into(), vec![1, 2]);
        g.shapes.insert("t".into(), vec![1, 1]);
        g.shapes.insert("y".into(), vec![1, 2]);
        g.initializers
            .insert("t".into(), Tensor::new(vec![1, 1], vec![0.5]).unwrap());
        g.nodes.push(
            Node::new(
                "MultiThreshold",
                "q",
                vec!["x".into(), "t".into()],
                vec!["y".into()],
            )
            .with_attrs(Attrs::new().with("data_layout", AttrVal::Str("XYZW".into()))),
        );
        let err = ExecutionPlan::compile(&g).unwrap_err().to_string();
        assert!(err.contains("data_layout"), "{err}");
        assert!(err.contains("plan: node q"), "{err}");
    }

    #[test]
    fn stale_shape_annotation_fails_at_compile() {
        let mut g = chain_graph();
        g.shapes.insert("t1".into(), vec![6, 1]); // stale: Mul keeps [2,3]
        let err = ExecutionPlan::compile(&g).unwrap_err().to_string();
        assert!(err.contains("stale"), "{err}");
    }

    #[test]
    fn feed_passthrough_output() {
        // A graph output that is directly a graph input.
        let mut g = Graph::new("pass");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["x".into()];
        g.shapes.insert("x".into(), vec![2]);
        let plan = ExecutionPlan::compile(&g).unwrap();
        let mut feeds = HashMap::new();
        feeds.insert("x".to_string(), Tensor::new(vec![2], vec![7.0, 8.0]).unwrap());
        let out = plan.run(&feeds).unwrap();
        assert_eq!(out["x"].data(), &[7.0, 8.0]);
    }

    #[test]
    fn compile_rejects_unproduced_output() {
        let mut g = chain_graph();
        g.outputs = vec!["ghost".into()];
        assert!(ExecutionPlan::compile(&g).is_err());
    }

    fn bt_threshold_graph() -> Graph {
        // x (f32 NHWC) -> MultiThreshold(out_scale 0.25) -> q1 ->
        // MultiThreshold(out_scale 0.5) -> y: ingress quantization, one
        // steady-state integer threshold, and an integer egress format.
        let mut g = Graph::new("bt_chain");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["y".into()];
        g.shapes.insert("x".into(), vec![1, 2, 2, 3]);
        g.shapes.insert("t".into(), vec![1, 3]);
        g.shapes.insert("t2".into(), vec![1, 2]);
        g.shapes.insert("q1".into(), vec![1, 2, 2, 3]);
        g.shapes.insert("y".into(), vec![1, 2, 2, 3]);
        g.initializers.insert(
            "t".into(),
            Tensor::new(vec![1, 3], vec![0.125, 0.375, 0.625]).unwrap(),
        );
        g.initializers
            .insert("t2".into(), Tensor::new(vec![1, 2], vec![0.3, 0.8]).unwrap());
        g.nodes.push(
            Node::new(
                "MultiThreshold",
                "q",
                vec!["x".into(), "t".into()],
                vec!["q1".into()],
            )
            .with_attrs(
                Attrs::new()
                    .with("data_layout", AttrVal::Str("NHWC".into()))
                    .with("out_scale", AttrVal::Float(0.25)),
            ),
        );
        g.nodes.push(
            Node::new(
                "MultiThreshold",
                "q2",
                vec!["q1".into(), "t2".into()],
                vec!["y".into()],
            )
            .with_attrs(
                Attrs::new()
                    .with("data_layout", AttrVal::Str("NHWC".into()))
                    .with("out_scale", AttrVal::Float(0.5)),
            ),
        );
        g
    }

    #[test]
    fn bit_true_chain_quantizes_at_ingress_and_matches_f32() {
        let mut g = bt_threshold_graph();
        crate::transforms::annotate_bit_true_formats(&mut g).unwrap();
        let f32_plan = ExecutionPlan::compile(&g).unwrap();
        let int_plan = ExecutionPlan::compile_bit_true(&g).unwrap();
        assert_eq!(f32_plan.datapath(), Datapath::F32);
        assert_eq!(int_plan.datapath(), Datapath::BitTrue);
        assert_eq!(int_plan.output_frac("y"), Some(1)); // out_scale 2^-1
        assert_eq!(f32_plan.output_frac("y"), None);

        let mut feeds = HashMap::new();
        feeds.insert(
            "x".to_string(),
            Tensor::from_fn(vec![1, 2, 2, 3], |i| i as f32 * 0.09),
        );
        let want = f32_plan.run(&feeds).unwrap();
        let got = int_plan.run(&feeds).unwrap();
        let codes = got["y"].codes_i32();
        assert_eq!(codes.len(), want["y"].numel());
        for (c, v) in codes.iter().zip(want["y"].data()) {
            assert_eq!((*c as f64 / 2.0) as f32, *v);
        }
        // Ingress quantizer + one steady-state integer threshold — no
        // "f32" kernel anywhere; the second threshold's codes span
        // [0, 2], so they pack into a u4 nibble container.
        let variants = int_plan.kernel_variants();
        assert_eq!(
            variants,
            vec![
                ("MultiThreshold".to_string(), "ingress-quant"),
                ("MultiThreshold".to_string(), "int4"),
            ]
        );
    }

    #[test]
    fn packed_plan_matches_wide_oracle_and_moves_fewer_bytes() {
        let mut g = bt_threshold_graph();
        crate::transforms::annotate_bit_true_formats(&mut g).unwrap();
        let packed = ExecutionPlan::compile_bit_true(&g).unwrap();
        let wide = ExecutionPlan::compile_bit_true_wide(&g).unwrap();
        // The wide oracle runs everything in i32 containers.
        assert!(wide
            .kernel_variants()
            .iter()
            .all(|(_, v)| *v != "int8" && *v != "int16" && *v != "int4" && *v != "int1"));
        let mut feeds = HashMap::new();
        feeds.insert(
            "x".to_string(),
            Tensor::from_fn(vec![1, 2, 2, 3], |i| i as f32 * 0.11),
        );
        let a = packed.run(&feeds).unwrap();
        let b = wide.run(&feeds).unwrap();
        assert_eq!(a["y"].codes_i32(), b["y"].codes_i32());
        assert_eq!(a["y"].dtype(), DType::U4);
        assert_eq!(b["y"].dtype(), DType::I32);
        assert!(
            packed.bytes_moved_per_frame() < wide.bytes_moved_per_frame(),
            "packed {} !< wide {}",
            packed.bytes_moved_per_frame(),
            wide.bytes_moved_per_frame()
        );
        assert_eq!(packed.output_frac("y"), wide.output_frac("y"));
    }

    #[test]
    fn bit_true_compile_requires_annotations() {
        let g = bt_threshold_graph();
        let err = ExecutionPlan::compile_bit_true(&g).unwrap_err().to_string();
        assert!(err.contains("bit-true annotation"), "{err}");
    }

    #[test]
    fn bit_true_arena_recycles_i32_buffers() {
        let mut g = bt_threshold_graph();
        crate::transforms::annotate_bit_true_formats(&mut g).unwrap();
        let plan = ExecutionPlan::compile_bit_true(&g).unwrap();
        let mut feeds = HashMap::new();
        feeds.insert("x".to_string(), Tensor::from_fn(vec![1, 2, 2, 3], |_| 0.3));
        let mut scratch = PlanScratch::default();
        for _ in 0..4 {
            let out = plan.run_with(&feeds, &mut scratch).unwrap();
            assert!(out["y"].is_int());
        }
        assert!(
            scratch.stats.reuses >= 3,
            "packed arena not recycled: {:?}",
            scratch.stats
        );
    }

    #[test]
    fn datapath_parse_round_trips() {
        assert_eq!(Datapath::parse("f32").unwrap(), Datapath::F32);
        assert_eq!(Datapath::parse("bit-true").unwrap(), Datapath::BitTrue);
        assert_eq!(Datapath::parse("bittrue").unwrap(), Datapath::BitTrue);
        assert!(Datapath::parse("fp64").is_err());
        assert_eq!(Datapath::BitTrue.describe(), "bit-true");
        assert_eq!(Datapath::default(), Datapath::F32);
    }

    /// Tiny NCHW "backbone": input quant-free, one Conv + ReduceMean.
    /// `pub(crate)` so the pipeline executor's unit tests reuse it.
    pub(crate) fn tiny_bb_graph() -> Graph {
        let mut g = Graph::new("tiny_bb");
        g.inputs = vec!["global_in".into()];
        g.outputs = vec!["global_out".into()];
        g.shapes.insert("global_in".into(), vec![1, 3, 4, 4]);
        g.shapes.insert("w".into(), vec![5, 3, 3, 3]);
        g.shapes.insert("c".into(), vec![1, 5, 4, 4]);
        g.shapes.insert("global_out".into(), vec![1, 5]);
        let mut rng = crate::rng::Rng::new(9);
        g.initializers
            .insert("w".into(), Tensor::from_fn(vec![5, 3, 3, 3], |_| rng.normal()));
        g.nodes.push(
            Node::new("Conv", "c0", vec!["global_in".into(), "w".into()], vec!["c".into()])
                .with_attrs(
                    Attrs::new()
                        .with("kernel", AttrVal::Ints(vec![3, 3]))
                        .with("stride", AttrVal::Ints(vec![1, 1]))
                        .with("pad", AttrVal::Ints(vec![1, 1])),
                ),
        );
        g.nodes.push(
            Node::new("ReduceMean", "gap", vec!["c".into()], vec!["global_out".into()])
                .with_attrs(
                    Attrs::new()
                        .with("axes", AttrVal::Ints(vec![2, 3]))
                        .with("keepdims", AttrVal::Int(0)),
                ),
        );
        g
    }

    #[test]
    fn plan_runner_shapes_and_determinism() {
        let g = tiny_bb_graph();
        let runner = PlanRunner::new(&g, 2).unwrap();
        use crate::coordinator::FeatureExtractor;
        assert_eq!(runner.img(), 4);
        assert_eq!(runner.feature_dim(), 5);
        assert_eq!(runner.batch(), 2);
        let images: Vec<f32> = (0..runner.input_elems()).map(|i| (i % 7) as f32 * 0.1).collect();
        let f1 = runner.extract(&images).unwrap();
        let f2 = runner.extract(&images).unwrap();
        assert_eq!(f1.len(), 2 * 5);
        assert_eq!(f1, f2, "plan extraction must be deterministic");
        assert!(f1.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn replicated_runner_shares_the_compiled_plan() {
        use crate::coordinator::FeatureExtractor;
        let g = tiny_bb_graph();
        let base = PlanRunner::new(&g, 2).unwrap();
        let fresh = PlanRunner::new(&g, 2).unwrap();
        let rep = base.replicate();
        // Replicas share ONE compiled plan; an independent compile does not.
        assert!(base.shares_plan_with(&rep));
        assert!(!base.shares_plan_with(&fresh));
        assert_eq!(rep.img(), base.img());
        assert_eq!(rep.feature_dim(), base.feature_dim());
        assert_eq!(rep.batch(), base.batch());
        // Scratch arenas are private: both extract, identical features,
        // and the replica's arena accumulates its own stats from zero.
        let images: Vec<f32> = (0..base.input_elems()).map(|i| (i % 5) as f32 * 0.2).collect();
        let a = base.extract(&images).unwrap();
        let b = rep.extract(&images).unwrap();
        assert_eq!(a, b, "replicas must be bitwise-identical executors");
        assert!(rep.arena_stats().fresh_allocs > 0);

        // A replica is Send: it may move onto a pool thread.
        fn assert_send<T: Send>(_: &T) {}
        assert_send(&rep);
    }
}
