//! Compiled execution plans — the engine behind graph execution.
//!
//! The original executor (`ops::execute_interpreted`) re-cloned and
//! re-toposorted the whole [`Graph`] on every call and resolved every
//! tensor through `HashMap<String, Tensor>` lookups — per node, per call.
//! PEFSL (arXiv:2404.19354) and the MLPerf-Tiny FPGA codesign line both
//! show that deployment-pipeline overhead, not kernel math, dominates
//! small-model latency on edge SoCs; the ROADMAP's "fast as the hardware
//! allows" requires the same discipline on the software request path.
//!
//! [`ExecutionPlan::compile`] does all graph-shaped work ONCE:
//!
//! * topological order resolved at compile time (`Graph::toposort_order`,
//!   no clone);
//! * every tensor name interned to a dense slot id — the run loop indexes
//!   arrays, it never hashes a string;
//! * initializers bound to their slots once, not looked up per node per
//!   call;
//! * per-step output shapes resolved and cross-checked against
//!   [`crate::ops::infer_output_shape`] (stale shape annotations fail at
//!   compile, not as corrupted buffers at run time);
//! * node attributes (kernel/stride/pad/perm/shape/axes/layout) resolved
//!   into a typed [`crate::ops::OpSpec`] per step — the run loop never
//!   calls `Attrs::ints()` (string scan + `Vec` clone) again, and a
//!   malformed attribute fails at compile, not mid-frame;
//! * a liveness analysis records each activation's last use; the run loop
//!   returns dead buffers to a reusable arena ([`PlanScratch`]) instead of
//!   dropping them, and steals a dying input's buffer outright for
//!   elementwise/reshape steps (`ops::supports_inplace`).
//!
//! [`ExecutionPlan::run`] then touches no graph structure at all: slots
//! in, slots out.  [`ExecutionPlan::run_batch`] / [`run_with`] amortize
//! the arena across frames — the serving coordinator's path.
//!
//! [`run_with`]: ExecutionPlan::run_with

use std::cell::RefCell;
use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::graph::Graph;
use crate::ops;
use crate::tensor::Tensor;

/// One compiled step: a node with its IO resolved to dense slot ids and
/// its attributes resolved to a typed kernel spec.
#[derive(Debug, Clone)]
struct PlanStep {
    /// Node name (diagnostics only).
    name: String,
    /// Op name (diagnostics + in-place eligibility at compile).
    op: String,
    /// Kernel parameters pre-resolved from `Attrs` at compile time — the
    /// run loop never scans an attribute string or clones an attr list.
    spec: ops::OpSpec,
    /// Input slot per node input, in node order.
    inputs: Vec<u32>,
    /// The (single) output slot.
    output: u32,
    /// Resolved output shape (from the graph's shape table, verified
    /// against shape inference at compile time).
    out_shape: Vec<usize>,
    /// Activation slots whose last use is this step — their buffers go
    /// back to the arena right after execution.
    release: Vec<u32>,
    /// Steal `inputs[0]`'s buffer and mutate it in place instead of
    /// allocating an output (elementwise/reshape steps whose first input
    /// dies here).
    inplace: bool,
}

/// A graph input: where its tensor goes and what shape it must have.
#[derive(Debug, Clone)]
struct FeedSpec {
    name: String,
    slot: u32,
    /// Expected shape when the graph records one (checked at run time).
    shape: Option<Vec<usize>>,
}

/// Reusable per-run state: the slot environment and the buffer arena.
///
/// Keep one of these alive across calls (`run_with` / `run_batch`) and
/// steady-state execution performs no heap allocation for activations —
/// every output buffer is recycled from a prior frame.
#[derive(Debug, Default)]
pub struct PlanScratch {
    /// Materialized activations, slot-indexed.
    act: Vec<Option<Tensor>>,
    /// Free buffers returned by dead activations.
    pool: Vec<Vec<f32>>,
    pub stats: ArenaStats,
}

/// Arena instrumentation (exposed for tests and the §Perf bench).
#[derive(Debug, Default, Clone, Copy)]
pub struct ArenaStats {
    /// Buffers allocated fresh from the system allocator.
    pub fresh_allocs: usize,
    /// Buffers recycled from the arena pool.
    pub reuses: usize,
    /// Steps that stole their input's buffer in place.
    pub inplace_steps: usize,
    /// Peak number of live activation buffers in any single run.
    pub peak_live: usize,
    live: usize,
}

impl PlanScratch {
    fn reset(&mut self, n_slots: usize) {
        for slot in self.act.iter_mut() {
            if let Some(t) = slot.take() {
                self.pool.push(t.into_data());
            }
        }
        self.act.resize(n_slots, None);
        self.stats.live = 0;
    }

    /// Carve a buffer of `numel(shape)` out of the pool: the smallest
    /// pooled buffer whose capacity fits, else the largest (it grows
    /// once and then fits forever).  The buffer is NOT zeroed — every
    /// kernel behind `ops::execute_node_into` either fully overwrites or
    /// zero-fills before accumulating, so steady-state same-size reuse
    /// writes nothing here at all.
    fn alloc(&mut self, shape: &[usize]) -> Result<Tensor> {
        let numel: usize = shape.iter().product();
        let data = if self.pool.is_empty() {
            self.stats.fresh_allocs += 1;
            vec![0.0f32; numel]
        } else {
            let mut best = 0usize;
            for i in 1..self.pool.len() {
                let (c, b) = (self.pool[i].capacity(), self.pool[best].capacity());
                let better = if c >= numel { b < numel || c < b } else { b < numel && c > b };
                if better {
                    best = i;
                }
            }
            self.stats.reuses += 1;
            let mut buf = self.pool.swap_remove(best);
            buf.resize(numel, 0.0);
            buf
        };
        Tensor::new(shape.to_vec(), data)
    }
}

/// A graph compiled for repeated execution.
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    name: String,
    n_slots: usize,
    /// Number of slots produced by steps (activations).
    n_activations: usize,
    steps: Vec<PlanStep>,
    feeds: Vec<FeedSpec>,
    /// Graph outputs: (name, slot).
    outputs: Vec<(String, u32)>,
    /// Initializer tensors bound to their slots at compile time.
    init: Vec<Option<Tensor>>,
    /// Slot -> tensor name (diagnostics only).
    slot_names: Vec<String>,
}

fn intern<'g>(
    name: &'g str,
    slot_of: &mut HashMap<&'g str, u32>,
    names: &mut Vec<String>,
) -> u32 {
    if let Some(&s) = slot_of.get(name) {
        return s;
    }
    let s = names.len() as u32;
    names.push(name.to_string());
    slot_of.insert(name, s);
    s
}

impl ExecutionPlan {
    /// Compile a graph: one toposort, one interning pass, one liveness
    /// pass.  The graph is not modified and not needed afterwards.
    pub fn compile(graph: &Graph) -> Result<Self> {
        let order = graph.toposort_order()?;
        let mut slot_of: HashMap<&str, u32> = HashMap::new();
        let mut slot_names: Vec<String> = Vec::new();

        // Feeds first so graph inputs get stable low slots.
        let mut feeds = Vec::with_capacity(graph.inputs.len());
        for name in &graph.inputs {
            let slot = intern(name, &mut slot_of, &mut slot_names);
            feeds.push(FeedSpec {
                name: name.clone(),
                slot,
                shape: graph.shapes.get(name).cloned(),
            });
        }

        // Steps in topological order, with slot-resolved IO.
        let mut steps: Vec<PlanStep> = Vec::with_capacity(order.len());
        // slot -> step index that produces it
        let mut produced_by: Vec<Option<usize>> = vec![None; slot_names.len()];
        // slot -> shape, where known (feeds + annotations + initializers)
        let mut known: Vec<Option<Vec<usize>>> = vec![None; slot_names.len()];
        for f in &feeds {
            known[f.slot as usize] = f.shape.clone();
        }

        for (si, &ni) in order.iter().enumerate() {
            let node = &graph.nodes[ni];
            if node.outputs.len() != 1 {
                bail!(
                    "plan: node {} has {} outputs; only single-output nodes are executable",
                    node.name,
                    node.outputs.len()
                );
            }
            let inputs: Vec<u32> = node
                .inputs
                .iter()
                .map(|t| intern(t, &mut slot_of, &mut slot_names))
                .collect();
            let output = intern(&node.outputs[0], &mut slot_of, &mut slot_names);
            produced_by.resize(slot_names.len(), None);
            known.resize(slot_names.len(), None);
            if produced_by[output as usize].is_some() {
                bail!("plan: tensor {} produced twice", node.outputs[0]);
            }
            produced_by[output as usize] = Some(si);

            // Fill input shapes from initializers on first sight.
            for (&slot, name) in inputs.iter().zip(&node.inputs) {
                if known[slot as usize].is_none() {
                    if let Some(t) = graph.initializers.get(name) {
                        known[slot as usize] = Some(t.shape().to_vec());
                    }
                }
            }

            let out_shape = graph.shape_of(&node.outputs[0])?.to_vec();
            // Cross-check the annotation against shape inference when all
            // input shapes are known — a stale annotation dies here, not
            // as a corrupted buffer at run time.
            let in_shapes: Option<Vec<&[usize]>> = inputs
                .iter()
                .map(|&s| known[s as usize].as_deref())
                .collect();
            if let Some(in_shapes) = in_shapes {
                let inferred = ops::infer_output_shape(node, &in_shapes)
                    .map_err(|e| anyhow!("plan: node {} ({}): {e}", node.name, node.op))?;
                if inferred != out_shape {
                    bail!(
                        "plan: node {} ({}): graph annotates output {:?} but inference says {:?} — stale shape annotation",
                        node.name,
                        node.op,
                        out_shape,
                        inferred
                    );
                }
            }
            known[output as usize] = Some(out_shape.clone());

            let spec = ops::OpSpec::resolve(node)
                .map_err(|e| anyhow!("plan: node {} ({}): {e}", node.name, node.op))?;
            steps.push(PlanStep {
                name: node.name.clone(),
                op: node.op.clone(),
                spec,
                inputs,
                output,
                out_shape,
                release: Vec::new(),
                inplace: false,
            });
        }

        // Graph outputs (produced, fed, or initializer-passthrough).
        let mut outputs = Vec::with_capacity(graph.outputs.len());
        for name in &graph.outputs {
            let slot = intern(name, &mut slot_of, &mut slot_names);
            produced_by.resize(slot_names.len(), None);
            known.resize(slot_names.len(), None);
            let resolvable = produced_by[slot as usize].is_some()
                || graph.inputs.contains(name)
                || graph.initializers.contains_key(name);
            if !resolvable {
                bail!("plan: graph output {name} is never produced");
            }
            outputs.push((name.clone(), slot));
        }

        let n_slots = slot_names.len();

        // Bind initializers once.
        let mut init: Vec<Option<Tensor>> = vec![None; n_slots];
        for (name, tensor) in &graph.initializers {
            if let Some(&slot) = slot_of.get(name.as_str()) {
                init[slot as usize] = Some(tensor.clone());
            }
        }

        // Liveness: last step reading each activation slot; graph outputs
        // are pinned (never recycled).
        let mut last_use: Vec<usize> = (0..n_slots)
            .map(|s| produced_by[s].unwrap_or(0))
            .collect();
        for (si, step) in steps.iter().enumerate() {
            for &s in &step.inputs {
                if produced_by[s as usize].is_some() {
                    last_use[s as usize] = si;
                }
            }
        }
        for (_, slot) in &outputs {
            last_use[*slot as usize] = usize::MAX;
        }

        // In-place marking: elementwise/reshape steps whose first input is
        // an activation that dies right here (and is not read twice).
        for (si, step) in steps.iter_mut().enumerate() {
            if !ops::supports_inplace(&step.op) || step.inputs.is_empty() {
                continue;
            }
            let in0 = step.inputs[0];
            let eligible = produced_by[in0 as usize].is_some()
                && last_use[in0 as usize] == si
                && !step.inputs[1..].contains(&in0)
                && match step.op.as_str() {
                    "Reshape" => known[in0 as usize]
                        .as_ref()
                        .map(|s| s.iter().product::<usize>() == step.out_shape.iter().product())
                        .unwrap_or(false),
                    _ => known[in0 as usize].as_deref() == Some(step.out_shape.as_slice()),
                };
            step.inplace = eligible;
        }

        // Release lists: after step si, recycle activations whose last use
        // was si — except a buffer stolen in place (it lives on as the
        // output).
        for s in 0..n_slots {
            if produced_by[s].is_none() || last_use[s] == usize::MAX {
                continue;
            }
            let si = last_use[s];
            if steps[si].inplace && steps[si].inputs[0] as usize == s {
                continue;
            }
            steps[si].release.push(s as u32);
        }

        let n_activations = produced_by.iter().filter(|p| p.is_some()).count();
        Ok(Self {
            name: graph.name.clone(),
            n_slots,
            n_activations,
            steps,
            feeds,
            outputs,
            init,
            slot_names,
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn num_steps(&self) -> usize {
        self.steps.len()
    }

    pub fn num_slots(&self) -> usize {
        self.n_slots
    }

    /// Number of step-produced (activation) tensors.
    pub fn num_activation_slots(&self) -> usize {
        self.n_activations
    }

    /// Steps compiled to mutate their input in place.
    pub fn num_inplace_steps(&self) -> usize {
        self.steps.iter().filter(|s| s.inplace).count()
    }

    fn resolve<'a>(
        &'a self,
        slot: u32,
        act: &'a [Option<Tensor>],
        ext: &[Option<&'a Tensor>],
    ) -> Result<&'a Tensor> {
        let s = slot as usize;
        if let Some(t) = act[s].as_ref() {
            return Ok(t);
        }
        if let Some(t) = ext[s] {
            return Ok(t);
        }
        if let Some(t) = self.init[s].as_ref() {
            return Ok(t);
        }
        bail!("tensor {} unavailable", self.slot_names[s])
    }

    /// Execute once with a fresh arena.
    pub fn run(&self, feeds: &HashMap<String, Tensor>) -> Result<HashMap<String, Tensor>> {
        let mut scratch = PlanScratch::default();
        self.run_with(feeds, &mut scratch)
    }

    /// Execute a batch of feed sets, amortizing the arena: frame k's
    /// activations are carved out of frame k-1's recycled buffers.
    pub fn run_batch(
        &self,
        feeds: &[HashMap<String, Tensor>],
    ) -> Result<Vec<HashMap<String, Tensor>>> {
        let mut scratch = PlanScratch::default();
        feeds
            .iter()
            .map(|f| self.run_with(f, &mut scratch))
            .collect()
    }

    /// Execute once, reusing `scratch` across calls.  This is the steady-
    /// state entry point: zero graph work, zero string hashing on the node
    /// path, and (after warmup) zero activation allocation.
    pub fn run_with(
        &self,
        feeds: &HashMap<String, Tensor>,
        scratch: &mut PlanScratch,
    ) -> Result<HashMap<String, Tensor>> {
        scratch.reset(self.n_slots);

        // Resolve feeds: the only name lookups in the whole run.
        let mut ext: Vec<Option<&Tensor>> = vec![None; self.n_slots];
        for spec in &self.feeds {
            let t = feeds
                .get(&spec.name)
                .ok_or_else(|| anyhow!("missing feed for graph input {}", spec.name))?;
            if let Some(shape) = &spec.shape {
                if t.shape() != shape.as_slice() {
                    bail!(
                        "feed {} has shape {:?}, graph expects {:?}",
                        spec.name,
                        t.shape(),
                        shape
                    );
                }
            }
            ext[spec.slot as usize] = Some(t);
        }

        for step in &self.steps {
            if step.inplace {
                let mut buf = scratch.act[step.inputs[0] as usize].take().ok_or_else(|| {
                    anyhow!(
                        "plan bug: in-place input of {} not materialized",
                        step.name
                    )
                })?;
                {
                    let rest: Vec<&Tensor> = step.inputs[1..]
                        .iter()
                        .map(|&s| self.resolve(s, &scratch.act, &ext))
                        .collect::<Result<_>>()?;
                    ops::execute_spec_inplace(&step.spec, &mut buf, &rest).map_err(|e| {
                        anyhow!("executing {} ({}): {e}", step.name, step.op)
                    })?;
                }
                scratch.stats.inplace_steps += 1;
                scratch.act[step.output as usize] = Some(buf);
            } else {
                let mut out = scratch.alloc(&step.out_shape)?;
                {
                    let inputs: Vec<&Tensor> = step
                        .inputs
                        .iter()
                        .map(|&s| self.resolve(s, &scratch.act, &ext))
                        .collect::<Result<_>>()?;
                    ops::execute_spec_into(&step.spec, &inputs, &mut out).map_err(|e| {
                        anyhow!("executing {} ({}): {e}", step.name, step.op)
                    })?;
                }
                scratch.stats.live += 1;
                scratch.stats.peak_live = scratch.stats.peak_live.max(scratch.stats.live);
                scratch.act[step.output as usize] = Some(out);
            }
            for &dead in &step.release {
                if let Some(t) = scratch.act[dead as usize].take() {
                    scratch.stats.live -= 1;
                    scratch.pool.push(t.into_data());
                }
            }
        }

        let mut result = HashMap::with_capacity(self.outputs.len());
        for (name, slot) in &self.outputs {
            let s = *slot as usize;
            let t = if let Some(t) = scratch.act[s].take() {
                scratch.stats.live = scratch.stats.live.saturating_sub(1);
                t
            } else if let Some(t) = ext[s] {
                t.clone()
            } else if let Some(t) = self.init[s].as_ref() {
                t.clone()
            } else {
                bail!("graph output {name} not produced");
            };
            result.insert(name.clone(), t);
        }
        Ok(result)
    }
}

// ---------------------------------------------------------------------------
// PlanRunner — the plan engine as a serving feature extractor
// ---------------------------------------------------------------------------

/// Backbone feature extraction over a compiled plan: the python-free,
/// PJRT-free request path.  Accepts flat NHWC image batches (the same
/// contract as the PJRT `BackboneRunner`), converts to the graph's NCHW
/// import layout, and runs the plan once per frame with a shared arena —
/// the batch amortizes plan lookup and buffer allocation.
pub struct PlanRunner {
    plan: ExecutionPlan,
    input: String,
    output: String,
    img: usize,
    feature_dim: usize,
    batch: usize,
    scratch: RefCell<PlanScratch>,
}

impl PlanRunner {
    /// Compile `graph` (an NCHW import with input [1, 3, img, img] and
    /// output [1, feat]) into a batched extractor.
    pub fn new(graph: &Graph, batch: usize) -> Result<Self> {
        if graph.inputs.len() != 1 || graph.outputs.len() != 1 {
            bail!(
                "PlanRunner needs a single-input single-output graph, got {} in / {} out",
                graph.inputs.len(),
                graph.outputs.len()
            );
        }
        let in_shape = graph.shape_of(&graph.inputs[0])?.to_vec();
        if in_shape.len() != 4 || in_shape[0] != 1 || in_shape[1] != 3 {
            bail!("PlanRunner expects NCHW input [1, 3, H, W], got {in_shape:?}");
        }
        if in_shape[2] != in_shape[3] {
            bail!("PlanRunner expects square images, got {in_shape:?}");
        }
        let out_shape = graph.shape_of(&graph.outputs[0])?.to_vec();
        let feature_dim = *out_shape
            .last()
            .ok_or_else(|| anyhow!("scalar graph output"))?;
        Ok(Self {
            plan: ExecutionPlan::compile(graph)?,
            input: graph.inputs[0].clone(),
            output: graph.outputs[0].clone(),
            img: in_shape[2],
            feature_dim,
            batch: batch.max(1),
            scratch: RefCell::new(PlanScratch::default()),
        })
    }

    /// Arena statistics accumulated over every extract call so far.
    pub fn arena_stats(&self) -> ArenaStats {
        self.scratch.borrow().stats
    }

    /// Run the plan for the first `live` frames of a full batch buffer —
    /// padded filler frames are never executed (the plan is per-frame,
    /// unlike a fixed-batch PJRT executable).
    fn extract_frames(&self, images: &[f32], live: usize) -> Result<Vec<f32>> {
        let per = self.img * self.img * 3;
        if images.len() != self.batch * per {
            bail!(
                "expected {} input elements, got {}",
                self.batch * per,
                images.len()
            );
        }
        let live = live.min(self.batch);
        let mut feats = Vec::with_capacity(live * self.feature_dim);
        let mut scratch = self.scratch.borrow_mut();
        let mut feeds = HashMap::with_capacity(1);
        for i in 0..live {
            let x_nhwc = Tensor::new(
                vec![1, self.img, self.img, 3],
                images[i * per..(i + 1) * per].to_vec(),
            )?;
            feeds.insert(self.input.clone(), x_nhwc.nhwc_to_nchw()?);
            let mut out = self.plan.run_with(&feeds, &mut scratch)?;
            let t = out
                .remove(&self.output)
                .ok_or_else(|| anyhow!("plan produced no {}", self.output))?;
            feats.extend_from_slice(t.data());
        }
        Ok(feats)
    }
}

impl crate::coordinator::FeatureExtractor for PlanRunner {
    fn batch(&self) -> usize {
        self.batch
    }

    fn img(&self) -> usize {
        self.img
    }

    fn feature_dim(&self) -> usize {
        self.feature_dim
    }

    fn extract(&self, images: &[f32]) -> Result<Vec<f32>> {
        self.extract_frames(images, self.batch)
    }

    fn extract_live(&self, images: &[f32], live: usize) -> Result<Vec<f32>> {
        self.extract_frames(images, live)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{AttrVal, Attrs, Node};

    /// in -> Mul(s) -> t1 ; t1 -> Add(t1, b) -> t2 ; t2 -> Reshape -> out
    fn chain_graph() -> Graph {
        let mut g = Graph::new("chain");
        g.inputs = vec!["in".into()];
        g.outputs = vec!["out".into()];
        g.shapes.insert("in".into(), vec![2, 3]);
        g.shapes.insert("s".into(), vec![]);
        g.shapes.insert("b".into(), vec![3]);
        g.shapes.insert("t1".into(), vec![2, 3]);
        g.shapes.insert("t2".into(), vec![2, 3]);
        g.shapes.insert("out".into(), vec![3, 2]);
        g.initializers.insert("s".into(), Tensor::scalar(2.0));
        g.initializers
            .insert("b".into(), Tensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap());
        g.nodes.push(Node::new("Mul", "m", vec!["in".into(), "s".into()], vec!["t1".into()]));
        g.nodes.push(Node::new("Add", "a", vec!["t1".into(), "b".into()], vec!["t2".into()]));
        g.nodes.push(
            Node::new("Reshape", "r", vec!["t2".into()], vec!["out".into()])
                .with_attrs(Attrs::new().with("shape", AttrVal::Ints(vec![3, 2]))),
        );
        g
    }

    fn chain_feeds() -> HashMap<String, Tensor> {
        let mut feeds = HashMap::new();
        feeds.insert(
            "in".to_string(),
            Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap(),
        );
        feeds
    }

    #[test]
    fn plan_matches_interpreter_on_chain() {
        let g = chain_graph();
        let feeds = chain_feeds();
        let plan = ExecutionPlan::compile(&g).unwrap();
        let got = plan.run(&feeds).unwrap();
        let want = crate::ops::execute_interpreted(&g, &feeds).unwrap();
        assert_eq!(got["out"], want["out"]);
    }

    #[test]
    fn chain_runs_in_place_after_first_alloc() {
        // Step 0's input is the (borrowed) graph input — it must NOT be
        // stolen; it allocates one buffer.  The Add and Reshape then
        // steal that buffer: one allocation for the whole chain.
        let g = chain_graph();
        let plan = ExecutionPlan::compile(&g).unwrap();
        assert_eq!(plan.num_inplace_steps(), 2);
        let mut scratch = PlanScratch::default();
        let out = plan.run_with(&chain_feeds(), &mut scratch).unwrap();
        assert_eq!(scratch.stats.fresh_allocs, 1);
        assert_eq!(scratch.stats.inplace_steps, 2);
        assert_eq!(scratch.stats.peak_live, 1);
        assert_eq!(out["out"].shape(), &[3, 2]);
        // [1..6] * 2, + bias [1,2,3] per row: [[3,6,9],[9,12,15]].
        assert_eq!(out["out"].data(), &[3., 6., 9., 9., 12., 15.]);
    }

    #[test]
    fn arena_reuses_buffers_across_batch() {
        let g = chain_graph();
        let plan = ExecutionPlan::compile(&g).unwrap();
        let mut scratch = PlanScratch::default();
        for _ in 0..5 {
            plan.run_with(&chain_feeds(), &mut scratch).unwrap();
        }
        // One fresh buffer per frame for the first frame's alloc; later
        // frames recycle the... outputs are moved to the caller, so each
        // frame allocates one buffer but nothing accumulates beyond that.
        assert!(scratch.stats.fresh_allocs <= 5);
        assert_eq!(scratch.stats.peak_live, 1);
    }

    #[test]
    fn diamond_releases_skip_only_after_join() {
        // in -> A(Mul s) -> t1 ; t1 -> B(Mul s) -> t2 ; t1,t2 -> Add -> out
        // t1 must stay live until the Add, then be recycled.
        let mut g = Graph::new("diamond");
        g.inputs = vec!["in".into()];
        g.outputs = vec!["out".into()];
        for t in ["in", "t1", "t2", "out"] {
            g.shapes.insert(t.into(), vec![4]);
        }
        g.shapes.insert("s".into(), vec![]);
        g.initializers.insert("s".into(), Tensor::scalar(3.0));
        g.nodes.push(Node::new("Mul", "A", vec!["in".into(), "s".into()], vec!["t1".into()]));
        g.nodes.push(Node::new("Mul", "B", vec!["t1".into(), "s".into()], vec!["t2".into()]));
        g.nodes.push(Node::new("Add", "C", vec!["t1".into(), "t2".into()], vec!["out".into()]));
        let plan = ExecutionPlan::compile(&g).unwrap();
        // B cannot steal t1 (C still reads it); C can steal t1.
        let mut feeds = HashMap::new();
        feeds.insert("in".to_string(), Tensor::new(vec![4], vec![1., 2., 3., 4.]).unwrap());
        let mut scratch = PlanScratch::default();
        let out = plan.run_with(&feeds, &mut scratch).unwrap();
        assert_eq!(out["out"].data(), &[12., 24., 36., 48.]);
        assert!(scratch.stats.peak_live <= 2);
        let want = crate::ops::execute_interpreted(&g, &feeds).unwrap();
        assert_eq!(out["out"], want["out"]);
    }

    #[test]
    fn missing_feed_and_bad_shape_error() {
        let g = chain_graph();
        let plan = ExecutionPlan::compile(&g).unwrap();
        let err = plan.run(&HashMap::new()).unwrap_err().to_string();
        assert!(err.contains("missing feed"), "{err}");
        let mut feeds = HashMap::new();
        feeds.insert("in".to_string(), Tensor::zeros(vec![3, 2]));
        let err = plan.run(&feeds).unwrap_err().to_string();
        assert!(err.contains("shape"), "{err}");
    }

    #[test]
    fn attr_resolution_happens_at_compile() {
        // A malformed attribute (unknown data_layout) dies when the plan
        // is compiled — the run loop only ever sees typed OpSpecs.
        let mut g = Graph::new("badattr");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["y".into()];
        g.shapes.insert("x".into(), vec![1, 2]);
        g.shapes.insert("t".into(), vec![1, 1]);
        g.shapes.insert("y".into(), vec![1, 2]);
        g.initializers
            .insert("t".into(), Tensor::new(vec![1, 1], vec![0.5]).unwrap());
        g.nodes.push(
            Node::new(
                "MultiThreshold",
                "q",
                vec!["x".into(), "t".into()],
                vec!["y".into()],
            )
            .with_attrs(Attrs::new().with("data_layout", AttrVal::Str("XYZW".into()))),
        );
        let err = ExecutionPlan::compile(&g).unwrap_err().to_string();
        assert!(err.contains("data_layout"), "{err}");
        assert!(err.contains("plan: node q"), "{err}");
    }

    #[test]
    fn stale_shape_annotation_fails_at_compile() {
        let mut g = chain_graph();
        g.shapes.insert("t1".into(), vec![6, 1]); // stale: Mul keeps [2,3]
        let err = ExecutionPlan::compile(&g).unwrap_err().to_string();
        assert!(err.contains("stale"), "{err}");
    }

    #[test]
    fn feed_passthrough_output() {
        // A graph output that is directly a graph input.
        let mut g = Graph::new("pass");
        g.inputs = vec!["x".into()];
        g.outputs = vec!["x".into()];
        g.shapes.insert("x".into(), vec![2]);
        let plan = ExecutionPlan::compile(&g).unwrap();
        let mut feeds = HashMap::new();
        feeds.insert("x".to_string(), Tensor::new(vec![2], vec![7.0, 8.0]).unwrap());
        let out = plan.run(&feeds).unwrap();
        assert_eq!(out["x"].data(), &[7.0, 8.0]);
    }

    #[test]
    fn compile_rejects_unproduced_output() {
        let mut g = chain_graph();
        g.outputs = vec!["ghost".into()];
        assert!(ExecutionPlan::compile(&g).is_err());
    }

    #[test]
    fn plan_runner_shapes_and_determinism() {
        // Tiny NCHW "backbone": input quant-free, one Conv + ReduceMean.
        let mut g = Graph::new("tiny_bb");
        g.inputs = vec!["global_in".into()];
        g.outputs = vec!["global_out".into()];
        g.shapes.insert("global_in".into(), vec![1, 3, 4, 4]);
        g.shapes.insert("w".into(), vec![5, 3, 3, 3]);
        g.shapes.insert("c".into(), vec![1, 5, 4, 4]);
        g.shapes.insert("global_out".into(), vec![1, 5]);
        let mut rng = crate::rng::Rng::new(9);
        g.initializers
            .insert("w".into(), Tensor::from_fn(vec![5, 3, 3, 3], |_| rng.normal()));
        g.nodes.push(
            Node::new("Conv", "c0", vec!["global_in".into(), "w".into()], vec!["c".into()])
                .with_attrs(
                    Attrs::new()
                        .with("kernel", AttrVal::Ints(vec![3, 3]))
                        .with("stride", AttrVal::Ints(vec![1, 1]))
                        .with("pad", AttrVal::Ints(vec![1, 1])),
                ),
        );
        g.nodes.push(
            Node::new("ReduceMean", "gap", vec!["c".into()], vec!["global_out".into()])
                .with_attrs(
                    Attrs::new()
                        .with("axes", AttrVal::Ints(vec![2, 3]))
                        .with("keepdims", AttrVal::Int(0)),
                ),
        );
        let runner = PlanRunner::new(&g, 2).unwrap();
        use crate::coordinator::FeatureExtractor;
        assert_eq!(runner.img(), 4);
        assert_eq!(runner.feature_dim(), 5);
        assert_eq!(runner.batch(), 2);
        let images: Vec<f32> = (0..runner.input_elems()).map(|i| (i % 7) as f32 * 0.1).collect();
        let f1 = runner.extract(&images).unwrap();
        let f2 = runner.extract(&images).unwrap();
        assert_eq!(f1.len(), 2 * 5);
        assert_eq!(f1, f2, "plan extraction must be deterministic");
        assert!(f1.iter().any(|&v| v != 0.0));
    }
}
