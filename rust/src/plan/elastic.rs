//! Telemetry-driven topology rebalancing for the composed pipeline
//! (DESIGN.md §13).
//!
//! The DataflowSim DP partition (`plan::pipeline::PipelineSpec`) seeds
//! WHERE the stage cuts fall; this module decides HOW MANY workers each
//! stage deserves.  Two inputs exist for that decision:
//!
//! 1. **Predicted**: the per-stage cycle estimates the partition was
//!    balanced against — [`seed_replicas`] water-fills a worker budget
//!    onto the predicted bottleneck before anything has run (the
//!    reproducible `--topology` path, and the seed for `--elastic`).
//! 2. **Measured**: the `pipeline.stage{i}.{recv_stall_us,send_stall_us}`
//!    counters PR 7/9 already export.  A stage whose workers barely
//!    stall is compute-bound — the true bottleneck; a stage that mostly
//!    waits is over-provisioned.  [`rebalance`] reads a warmup window's
//!    snapshot and promotes the busiest stage by one worker
//!    ([`Decision`]), which `bwade serve --pipeline --elastic` applies
//!    via `PlanPipeline::with_replicas` before serving the remainder of
//!    the stream.
//!
//! The policy is deliberately a single deterministic step per window,
//! not a feedback controller: a promotion is applied only when the
//! worker budget allows it, the busiest stage is the unique argmax of
//! the measured busy share (ties break to the earliest stage), and the
//! decision is fully explained by the printed
//! `before -> after (bottleneck stage i, busy N%)` line — so a CI run
//! can assert a nonzero rebalance happened and a human can audit why.

use std::time::Duration;

use crate::telemetry::RegistrySnapshot;

/// Ceiling mirrored from `plan::pipeline` (a `with_replicas` call clamps
/// there too); keep the two in sync.
const MAX_STAGE_REPLICAS: usize = 16;

/// When and how far the rebalancer may move a topology.
#[derive(Debug, Clone, Copy)]
pub struct ElasticPolicy {
    /// Frames served on the seeded topology before the stall counters
    /// are considered meaningful.
    pub warmup_frames: usize,
    /// Total worker budget across stages (ΣR); a promotion that would
    /// exceed it is refused.
    pub max_workers: usize,
}

impl ElasticPolicy {
    /// Default window: enough frames that per-frame jitter averages out,
    /// with a budget of one worker per host core.
    pub fn new(max_workers: usize) -> ElasticPolicy {
        ElasticPolicy {
            warmup_frames: 32,
            max_workers: max_workers.max(1),
        }
    }
}

/// One stage's measured warmup window.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageSample {
    /// Frames the stage processed in the window.
    pub frames: u64,
    /// Total µs its workers spent blocked on an empty ingress ring.
    pub recv_stall_us: u64,
    /// Total µs its workers spent blocked on a full egress ring.
    pub send_stall_us: u64,
    /// Workers the stage ran with during the window.
    pub replicas: usize,
}

impl StageSample {
    /// Fraction of the window the stage's workers spent computing rather
    /// than stalled, averaged over its replicas.  `window` is the wall
    /// time of the warmup; each of R workers had `window` of budget, so
    /// busy = 1 − stalls/(R·window), clamped to [0, 1].  An empty window
    /// reads as fully busy — the conservative default (never demote on
    /// no data).
    pub fn busy_share(&self, window: Duration) -> f64 {
        let budget_us = window.as_micros() as f64 * self.replicas.max(1) as f64;
        if budget_us <= 0.0 {
            return 1.0;
        }
        let stalled = (self.recv_stall_us + self.send_stall_us) as f64;
        (1.0 - stalled / budget_us).clamp(0.0, 1.0)
    }
}

/// Read the per-stage pipeline counters out of a registry snapshot.
/// Missing counters read as zero stall (fully busy) — a stage that never
/// got telemetry is never the reason to starve another.
pub fn sample_stages(
    snap: &RegistrySnapshot,
    stages: usize,
    replicas: &[usize],
) -> Vec<StageSample> {
    let get = |name: &str| snap.counters.get(name).copied().unwrap_or(0);
    (0..stages)
        .map(|s| StageSample {
            frames: get(&format!("pipeline.stage{s}.frames")),
            recv_stall_us: get(&format!("pipeline.stage{s}.recv_stall_us")),
            send_stall_us: get(&format!("pipeline.stage{s}.send_stall_us")),
            replicas: replicas.get(s).copied().unwrap_or(1).max(1),
        })
        .collect()
}

/// A rebalance step: the topology served during the window and the one
/// to adopt for the rest of the stream.
#[derive(Debug, Clone)]
pub struct Decision {
    pub before: Vec<usize>,
    pub after: Vec<usize>,
    /// Stage the measurement named the bottleneck.
    pub bottleneck: usize,
    /// That stage's measured busy share in the window.
    pub busy_share: f64,
}

impl Decision {
    /// Did the measurement actually move the topology?
    pub fn changed(&self) -> bool {
        self.before != self.after
    }

    /// The audit line `bwade serve` prints:
    /// `[1, 1, 1] -> [2, 1, 1] (bottleneck stage 0, busy 82%)`.
    pub fn describe(&self) -> String {
        format!(
            "{:?} -> {:?} (bottleneck stage {}, busy {:.0}%)",
            self.before,
            self.after,
            self.bottleneck,
            self.busy_share * 100.0
        )
    }
}

/// Promote the measured bottleneck stage by one worker, budget and
/// per-stage ceiling permitting.  `window` is the warmup wall time the
/// samples cover.
pub fn rebalance(policy: &ElasticPolicy, samples: &[StageSample], window: Duration) -> Decision {
    let before: Vec<usize> = samples.iter().map(|s| s.replicas.max(1)).collect();
    let mut bottleneck = 0usize;
    let mut busy = f64::MIN;
    for (s, sample) in samples.iter().enumerate() {
        let b = sample.busy_share(window);
        if b > busy {
            busy = b;
            bottleneck = s;
        }
    }
    let mut after = before.clone();
    let total: usize = before.iter().sum();
    if total < policy.max_workers && before[bottleneck] < MAX_STAGE_REPLICAS {
        after[bottleneck] += 1;
    }
    Decision {
        before,
        after,
        bottleneck,
        busy_share: busy.max(0.0),
    }
}

/// Water-fill a worker budget onto predicted per-stage cycles: start at
/// one worker each, then repeatedly give a worker to the stage with the
/// highest effective load `cycles/R` (ties to the earliest stage) until
/// the budget is spent.  With no cycle model every stage weighs the
/// same, so the fill round-robins from stage 0 — still deterministic.
pub fn seed_replicas(stage_cycles: &[u64], max_workers: usize) -> Vec<usize> {
    let stages = stage_cycles.len();
    if stages == 0 {
        return Vec::new();
    }
    let mut reps = vec![1usize; stages];
    let mut budget = max_workers.saturating_sub(stages);
    while budget > 0 {
        let mut pick = 0usize;
        let mut load = f64::MIN;
        for (s, &c) in stage_cycles.iter().enumerate() {
            if reps[s] >= MAX_STAGE_REPLICAS {
                continue;
            }
            let l = c.max(1) as f64 / reps[s] as f64;
            if l > load {
                load = l;
                pick = s;
            }
        }
        if load == f64::MIN {
            break;
        }
        reps[pick] += 1;
        budget -= 1;
    }
    reps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Registry;

    #[test]
    fn seed_fills_the_predicted_bottleneck_first() {
        // Stage 1 is 3x the load of the others: the first two extra
        // workers both land there.
        assert_eq!(seed_replicas(&[100, 300, 100], 5), vec![1, 3, 1]);
        // Budget below one-per-stage degrades to all-1.
        assert_eq!(seed_replicas(&[100, 300, 100], 2), vec![1, 1, 1]);
        // Unweighted stages round-robin deterministically.
        assert_eq!(seed_replicas(&[0, 0], 4), vec![2, 2]);
        assert_eq!(seed_replicas(&[], 4), Vec::<usize>::new());
    }

    #[test]
    fn busy_share_reads_stalls_against_replica_budget() {
        let window = Duration::from_micros(1000);
        let idle = StageSample {
            frames: 10,
            recv_stall_us: 900,
            send_stall_us: 0,
            replicas: 1,
        };
        assert!(idle.busy_share(window) < 0.2);
        // The same stall total across 2 replicas is half as idle.
        let duo = StageSample {
            replicas: 2,
            ..idle
        };
        assert!((duo.busy_share(window) - 0.55).abs() < 1e-9);
        // Zero window: conservatively fully busy.
        assert_eq!(idle.busy_share(Duration::ZERO), 1.0);
    }

    #[test]
    fn rebalance_promotes_the_busiest_stage() {
        let window = Duration::from_micros(1000);
        let samples = vec![
            StageSample {
                frames: 10,
                recv_stall_us: 800,
                send_stall_us: 0,
                replicas: 1,
            },
            StageSample {
                frames: 10,
                recv_stall_us: 10,
                send_stall_us: 20,
                replicas: 1,
            },
        ];
        let d = rebalance(&ElasticPolicy::new(4), &samples, window);
        assert_eq!(d.bottleneck, 1, "the least-stalled stage is the bottleneck");
        assert_eq!(d.before, vec![1, 1]);
        assert_eq!(d.after, vec![1, 2]);
        assert!(d.changed());
        let line = d.describe();
        assert!(line.contains("->"), "describe must show the transition: {line}");
        assert!(line.contains("bottleneck stage 1"), "got: {line}");
    }

    #[test]
    fn rebalance_respects_the_worker_budget() {
        let samples = vec![StageSample {
            frames: 5,
            recv_stall_us: 0,
            send_stall_us: 0,
            replicas: 3,
        }];
        let d = rebalance(&ElasticPolicy::new(3), &samples, Duration::from_micros(100));
        assert_eq!(d.before, d.after, "at budget the topology must not move");
        assert!(!d.changed());
    }

    #[test]
    fn sample_stages_reads_the_pipeline_counters() {
        let reg = Registry::new();
        reg.counter("pipeline.stage0.frames").add(32);
        reg.counter("pipeline.stage0.recv_stall_us").add(120);
        reg.counter("pipeline.stage1.frames").add(32);
        reg.counter("pipeline.stage1.send_stall_us").add(7);
        let samples = sample_stages(&reg.snapshot(), 2, &[1, 2]);
        assert_eq!(samples.len(), 2);
        assert_eq!(samples[0].frames, 32);
        assert_eq!(samples[0].recv_stall_us, 120);
        assert_eq!(samples[0].replicas, 1);
        assert_eq!(samples[1].send_stall_us, 7);
        assert_eq!(samples[1].replicas, 2);
        // Stages past the recorded set read as zero-stall.
        let extra = sample_stages(&reg.snapshot(), 3, &[1, 1, 1]);
        assert_eq!(extra[2].recv_stall_us, 0);
    }
}
