//! Streaming pipelined executor — per-stage workers on bounded FIFOs.
//!
//! The FINN-style dataflow claim the repo's `DataflowSim` makes — fps is
//! set by the slowest actor's initiation interval, not the sum of layer
//! latencies — is only falsifiable if the emulator can actually run
//! *frames in flight across layers*.  [`PlanPipeline`] partitions a
//! compiled [`ExecutionPlan`] into contiguous stage ranges (balanced by
//! the DataflowSim per-actor cycle estimates so no stage dominates), runs
//! worker threads per stage, and connects the stages with bounded
//! ring-buffer channels whose frame capacities derive from the same
//! `size_fifos` folding-search output the simulator uses.  Stage *k*
//! executes frame *n* while stage *k+1* executes frame *n−1*: the
//! steady-state inter-frame interval becomes a measured quantity that
//! `bwade profile` joins against the simulator's predicted II
//! (DESIGN.md §12).
//!
//! Since PR 10 a stage may be **replicated** (DESIGN.md §13): R workers
//! pull frames from the stage's shared ingress ring and an in-order
//! [`Reorder`] gate at the stage egress buffers out-of-order completions,
//! forwarding the contiguous run so everything downstream observes the
//! exact frame order a single worker would have produced.  Replication
//! multiplies a bottleneck stage's throughput without touching the cuts —
//! the elastic rebalancer (`plan::elastic`) picks per-stage R from the
//! measured stall telemetry.
//!
//! Correctness contract: every frame executes the exact same kernel
//! sequence as [`ExecutionPlan::run_with`], in the same (topological)
//! step order, on tensors owned by the frame's message — so pipeline
//! output is **bitwise-identical** to the sequential runner on both
//! datapaths, and the sink additionally *verifies* in-order delivery
//! (an egress sequence gap is an error, not a silent reorder).  Each
//! worker owns a private [`PlanScratch`] buffer arena; channel
//! capacities ≥ 2 give every stage a double-buffered hand-off.
//!
//! Shutdown is drain-based: the feeder closes the first channel, each
//! stage drains its input, and the LAST live replica of a stage closes
//! the stage's output — every frame in flight is conserved.  A poisoned
//! worker (kernel error) stores the first error and poisons **all**
//! channels and reorder gates, waking every blocked sender/receiver —
//! the workers join without deadlock and the error propagates to the
//! caller.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{Classified, Frame, Metrics};
use crate::fewshot::NcmClassifier;
use crate::hw::HwNodeModel;
use crate::ops;
use crate::telemetry::{Counter, Gauge, Registry};
use crate::tensor::Tensor;

use super::{dequantize_egress, ExecutionPlan, PlanRunner, PlanScratch, StepKind};

/// Hard per-stage replication ceiling — a thread-count guard, far above
/// anything a sane topology asks for.
const MAX_STAGE_REPLICAS: usize = 16;

// ---------------------------------------------------------------------------
// Bounded ring-buffer channel
// ---------------------------------------------------------------------------

/// Outcome of a blocking [`RingChannel::send`] / [`Reorder::put`].
enum SendState {
    /// Enqueued; `stalled` is the time spent blocked on a full ring.
    Sent { stalled: Duration },
    /// The pipeline failed elsewhere — the value was dropped.
    Poisoned,
}

/// Outcome of a blocking [`RingChannel::recv`].
enum RecvState<T> {
    /// A message, the ring occupancy observed at dequeue (including this
    /// message), and the time spent blocked on an empty ring.
    Msg {
        msg: T,
        occupancy: usize,
        stalled: Duration,
    },
    /// Sender closed and the ring is drained — clean end of stream.
    Closed,
    /// The pipeline failed elsewhere — stop immediately, drop in-flight.
    Poisoned,
}

struct RingInner<T> {
    /// Fixed-capacity ring storage: allocated once at `cap`, never grown
    /// (`send` blocks instead), so steady state is a true circular buffer.
    buf: VecDeque<T>,
    closed: bool,
    poisoned: bool,
}

/// A bounded channel with close and poison semantics.  Capacity is fixed
/// at construction — backpressure is the point: a full ring blocks the
/// producer, which is exactly how the sized FIFOs of the hardware
/// dataflow behave.  Safe under multiple producers AND multiple
/// consumers (a replicated stage's workers share their ingress ring):
/// both sides re-check the guarded condition in a loop, and each send /
/// each freed slot wakes exactly one counterpart, so wakeups are never
/// lost — at worst a woken thread finds another already took its turn
/// and waits again.
struct RingChannel<T> {
    cap: usize,
    inner: Mutex<RingInner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
}

impl<T> RingChannel<T> {
    fn new(cap: usize) -> RingChannel<T> {
        let cap = cap.max(1);
        RingChannel {
            cap,
            inner: Mutex::new(RingInner {
                buf: VecDeque::with_capacity(cap),
                closed: false,
                poisoned: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        }
    }

    /// Block until there is space (or the channel is poisoned), then
    /// enqueue.
    fn send(&self, v: T) -> SendState {
        let mut g = self.inner.lock().unwrap();
        let mut stalled = Duration::ZERO;
        loop {
            if g.poisoned {
                return SendState::Poisoned;
            }
            if g.buf.len() < self.cap {
                break;
            }
            let t0 = Instant::now();
            g = self.not_full.wait(g).unwrap();
            stalled += t0.elapsed();
        }
        g.buf.push_back(v);
        drop(g);
        self.not_empty.notify_one();
        SendState::Sent { stalled }
    }

    /// Block until a message arrives, the sender closes, or the channel
    /// is poisoned.
    fn recv(&self) -> RecvState<T> {
        let mut g = self.inner.lock().unwrap();
        let mut stalled = Duration::ZERO;
        loop {
            if g.poisoned {
                return RecvState::Poisoned;
            }
            if let Some(msg) = g.buf.pop_front() {
                let occupancy = g.buf.len() + 1;
                drop(g);
                self.not_full.notify_one();
                return RecvState::Msg {
                    msg,
                    occupancy,
                    stalled,
                };
            }
            if g.closed {
                return RecvState::Closed;
            }
            let t0 = Instant::now();
            g = self.not_empty.wait(g).unwrap();
            stalled += t0.elapsed();
        }
    }

    /// Producer-side end of stream: receivers drain what is buffered,
    /// then see [`RecvState::Closed`].  With a replicated upstream stage,
    /// only the LAST live replica calls this (see `run_stream`).
    fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Failure broadcast: wake everyone, drop everything in flight.
    fn poison(&self) {
        let mut g = self.inner.lock().unwrap();
        g.poisoned = true;
        g.buf.clear();
        drop(g);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

// ---------------------------------------------------------------------------
// In-order egress gate for replicated stages
// ---------------------------------------------------------------------------

struct ReorderInner<T> {
    /// The sequence number the downstream is owed next.
    next_seq: u64,
    /// Out-of-order completions parked until their turn.
    pending: BTreeMap<u64, T>,
    poisoned: bool,
}

/// The reorder buffer at a replicated stage's egress: R workers complete
/// frames out of order; [`Reorder::put`] parks the stragglers and
/// forwards the contiguous run starting at the next expected sequence
/// number into the downstream ring, so everything after the gate
/// observes the exact arrival order (and therefore the exact frame
/// stream) a single worker would have produced.
///
/// Invariants (tested below, documented in DESIGN.md §13):
/// - frames leave in strictly increasing `seq` with no gaps;
/// - the buffer is bounded: at most `cap` out-of-order frames are
///   admitted, but the next-expected frame ALWAYS enters — it is what
///   drains the run, so the bound cannot deadlock;
/// - the downstream send happens with the gate held: siblings carrying
///   later frames would have to queue behind the in-order run anyway,
///   and the consumer draining the ring never takes this lock, so the
///   wait is bounded by the consumer (capacity-1 backpressure works);
/// - poison (from the channel being forwarded into, or broadcast via
///   [`Reorder::poison`]) wakes every parked producer and drops the
///   pending frames — a poisoned-replica drain never hangs on a gap.
struct Reorder<'a, T> {
    out: &'a RingChannel<T>,
    cap: usize,
    inner: Mutex<ReorderInner<T>>,
    room: Condvar,
}

impl<'a, T> Reorder<'a, T> {
    fn new(out: &'a RingChannel<T>, cap: usize) -> Reorder<'a, T> {
        Reorder {
            out,
            cap: cap.max(1),
            inner: Mutex::new(ReorderInner {
                next_seq: 0,
                pending: BTreeMap::new(),
                poisoned: false,
            }),
            room: Condvar::new(),
        }
    }

    /// Hand a completed frame to the gate.  Returns like a send: the
    /// stalled time covers both waiting for buffer room and forwarding
    /// the in-order run into a full downstream ring.
    fn put(&self, seq: u64, v: T) -> SendState {
        let mut g = self.inner.lock().unwrap();
        let mut stalled = Duration::ZERO;
        loop {
            if g.poisoned {
                return SendState::Poisoned;
            }
            if seq == g.next_seq || g.pending.len() < self.cap {
                break;
            }
            let t0 = Instant::now();
            g = self.room.wait(g).unwrap();
            stalled += t0.elapsed();
        }
        g.pending.insert(seq, v);
        loop {
            let k = g.next_seq;
            let Some(v) = g.pending.remove(&k) else { break };
            match self.out.send(v) {
                SendState::Sent { stalled: s } => stalled += s,
                SendState::Poisoned => {
                    g.poisoned = true;
                    g.pending.clear();
                    drop(g);
                    self.room.notify_all();
                    return SendState::Poisoned;
                }
            }
            g.next_seq += 1;
        }
        drop(g);
        self.room.notify_all();
        SendState::Sent { stalled }
    }

    /// Failure broadcast: wake parked producers, drop pending frames.
    fn poison(&self) {
        let mut g = self.inner.lock().unwrap();
        g.poisoned = true;
        g.pending.clear();
        drop(g);
        self.room.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Stage partitioning
// ---------------------------------------------------------------------------

/// How to cut a plan into stages: the per-actor cycle model to balance
/// against, the `size_fifos` depths to derive channel capacities from,
/// and the per-stage worker replication.
#[derive(Debug, Clone, Default)]
pub struct PipelineSpec {
    /// Requested stage count (clamped to the plan's step count).
    pub stages: usize,
    /// DataflowSim per-actor cycles by node name ([`HwNodeModel::cycles`]).
    /// Plan steps with no entry (host-side ingress) weigh nothing.
    pub cycles: HashMap<String, u64>,
    /// `size_fifos` output: `"{tensor}->{consumer}"` -> element depth.
    pub fifo_depths: HashMap<String, u64>,
    /// Per-stage worker replication; entry `s` is stage `s`'s R.  Empty
    /// (the default) means one worker per stage; missing entries are 1.
    pub replicas: Vec<usize>,
}

impl PipelineSpec {
    /// No cycle model: stages balance on the plan's own bytes-moved
    /// accounting (or plain step count when that is empty too).
    pub fn uniform(stages: usize) -> PipelineSpec {
        PipelineSpec {
            stages,
            ..PipelineSpec::default()
        }
    }

    /// Balance against a folding-search result: the models and FIFO
    /// depths of a `BuildReport` over the SAME lowered graph the plan
    /// compiled (step names equal actor names, as in `bwade profile`).
    pub fn from_models(
        stages: usize,
        models: &[HwNodeModel],
        fifo_depths: &HashMap<String, u64>,
    ) -> PipelineSpec {
        let mut cycles = HashMap::with_capacity(models.len());
        for m in models {
            cycles.insert(m.name.clone(), m.cycles);
        }
        PipelineSpec {
            stages,
            cycles,
            fifo_depths: fifo_depths.clone(),
            replicas: Vec::new(),
        }
    }

    /// Set the per-stage worker replication (the R of an SxR topology).
    pub fn with_replicas(mut self, replicas: Vec<usize>) -> PipelineSpec {
        self.replicas = replicas;
        self
    }
}

/// Cut `weights` into `stages` contiguous non-empty parts minimizing the
/// maximum part sum (exact DP — plans are tens of steps, O(k·n²) is
/// free).  Returns the part bounds: part `s` is `bounds[s]..bounds[s+1]`.
fn partition_contiguous(weights: &[u64], stages: usize) -> Vec<usize> {
    let n = weights.len();
    let k = stages.clamp(1, n.max(1));
    let mut prefix = vec![0u64; n + 1];
    for i in 0..n {
        prefix[i + 1] = prefix[i] + weights[i];
    }
    // dp[j][i]: minimal max-part-sum over the first i steps in j parts.
    let mut dp = vec![vec![u64::MAX; n + 1]; k + 1];
    let mut cut = vec![vec![0usize; n + 1]; k + 1];
    for i in 1..=n {
        dp[1][i] = prefix[i];
    }
    for j in 2..=k {
        for i in j..=n {
            for m in (j - 1)..i {
                let cost = dp[j - 1][m].max(prefix[i] - prefix[m]);
                if cost < dp[j][i] {
                    dp[j][i] = cost;
                    cut[j][i] = m;
                }
            }
        }
    }
    let mut bounds = vec![0usize; k + 1];
    bounds[k] = n;
    let mut i = n;
    for j in (2..=k).rev() {
        i = cut[j][i];
        bounds[j - 1] = i;
    }
    bounds
}

// ---------------------------------------------------------------------------
// PlanPipeline
// ---------------------------------------------------------------------------

/// A frame travelling the pipeline: its slot environment, owned.  Feeds
/// sit in `acts` at their slots (messages own their tensors — there is
/// no cross-thread borrow), stages fill and release activation slots as
/// the sequential run loop would.  `seq` is the arrival order assigned
/// by the feeder — the reorder gates and the sink's in-order check key
/// on it (frame `id`s from concurrent sources are not arrival-ordered).
struct FrameMsg {
    seq: u64,
    id: u64,
    enqueued: Instant,
    acts: Vec<Option<Tensor>>,
}

/// A frame leaving the pipeline: dequantized features, in frame order.
struct OutMsg {
    seq: u64,
    id: u64,
    enqueued: Instant,
    feats: Vec<f32>,
}

/// Steady-state measurements of one streaming run.
#[derive(Debug, Clone, Copy, Default)]
pub struct PipelineStats {
    /// Frames that completed the pipeline.
    pub frames: usize,
    /// Wall time from first feed to last join.
    pub wall: Duration,
    /// First-frame fill latency (feed start -> first egress).
    pub first_frame_latency: Duration,
    /// Measured steady-state inter-frame interval at egress, averaged
    /// over the back of the stream (the pipeline-fill frames skipped) —
    /// the measured counterpart of DataflowSim's steady interval.
    pub steady_interval: Duration,
}

/// Per-stage telemetry handles, resolved once before the workers start
/// (the hot loop never hashes a metric name).  A replicated stage's
/// workers share the stage's handles: counters aggregate across
/// replicas, so `stage{i}.frames` still counts each frame exactly once.
struct StageTelemetry {
    frames: Arc<Counter>,
    recv_stall_us: Arc<Counter>,
    send_stall_us: Arc<Counter>,
    fifo_occupancy: Arc<Gauge>,
    fifo_peak: Arc<Gauge>,
}

impl StageTelemetry {
    fn resolve(reg: &Registry, stages: usize) -> Vec<StageTelemetry> {
        (0..stages)
            .map(|s| StageTelemetry {
                frames: reg.counter(&format!("pipeline.stage{s}.frames")),
                recv_stall_us: reg.counter(&format!("pipeline.stage{s}.recv_stall_us")),
                send_stall_us: reg.counter(&format!("pipeline.stage{s}.send_stall_us")),
                fifo_occupancy: reg.gauge(&format!("pipeline.stage{s}.fifo_occupancy")),
                fifo_peak: reg.gauge(&format!("pipeline.stage{s}.fifo_peak")),
            })
            .collect()
    }
}

/// One row of [`PlanPipeline::stage_table`].
#[derive(Debug, Clone)]
pub struct StageSummary {
    pub first_step: String,
    pub last_step: String,
    pub steps: usize,
    pub cycles: u64,
    /// Capacity (frames) of the channel feeding this stage.
    pub capacity: usize,
    /// Worker replication of this stage.
    pub replicas: usize,
}

/// A compiled plan partitioned for streaming execution: per-stage worker
/// threads over bounded ring channels, optionally replicated per stage.
/// Construction is cheap (the plan is `Arc`-shared with the
/// [`PlanRunner`] it came from); threads exist only for the duration of
/// a [`PlanPipeline::extract_stream`] / [`PlanPipeline::serve`] call.
pub struct PlanPipeline {
    plan: Arc<ExecutionPlan>,
    img: usize,
    feature_dim: usize,
    out_scale: Option<f64>,
    /// Stage `s` runs plan steps `bounds[s]..bounds[s+1]`.
    bounds: Vec<usize>,
    /// Predicted cycles per stage (sum of member actors; 0 for stages of
    /// pure host-ingress steps).
    stage_cycles: Vec<u64>,
    /// Channel frame-capacities: `capacities[s]` feeds stage `s`,
    /// `capacities[stages]` is the egress channel to the sink.
    capacities: Vec<usize>,
    /// Workers per stage (all 1 = the plain PR 9 pipeline).
    replicas: Vec<usize>,
}

impl PlanPipeline {
    /// Partition `runner`'s compiled plan per `spec`.  The runner is
    /// unchanged; the pipeline shares its plan (`Arc`) and egress
    /// contract, so pipeline features are bitwise-comparable to
    /// `runner.extract_all`.
    pub fn new(runner: &PlanRunner, spec: &PipelineSpec) -> Result<PlanPipeline> {
        let plan = Arc::clone(&runner.plan);
        let n = plan.steps.len();
        if n == 0 {
            bail!("cannot pipeline an empty plan");
        }
        if plan.feeds.len() != 1 || plan.outputs.len() != 1 {
            bail!(
                "PlanPipeline needs a single-input single-output plan, got {} in / {} out",
                plan.feeds.len(),
                plan.outputs.len()
            );
        }
        // Balance weights: DataflowSim cycles where the names join, the
        // plan's own bytes-moved accounting as the fallback proxy (a
        // non-lowered f32 plan shares no names with the HW models), and
        // plain step count last.
        let mut weights: Vec<u64> = Vec::with_capacity(n);
        for step in &plan.steps {
            weights.push(spec.cycles.get(&step.name).copied().unwrap_or(0));
        }
        if weights.iter().all(|&w| w == 0) {
            if plan.step_bytes.iter().any(|&b| b > 0) {
                weights = plan.step_bytes.clone();
            } else {
                weights = vec![1; n];
            }
        }
        let bounds = partition_contiguous(&weights, spec.stages);
        let stages = bounds.len() - 1;
        let mut stage_cycles = vec![0u64; stages];
        for (s, w) in stage_cycles.iter_mut().enumerate() {
            for step in bounds[s]..bounds[s + 1] {
                *w += spec.cycles.get(&plan.steps[step].name).copied().unwrap_or(0);
            }
        }
        let capacities = stage_capacities(&plan, &bounds, &spec.fifo_depths);
        let replicas: Vec<usize> = (0..stages)
            .map(|s| spec.replicas.get(s).copied().unwrap_or(1).clamp(1, MAX_STAGE_REPLICAS))
            .collect();
        Ok(PlanPipeline {
            plan,
            img: runner.img,
            feature_dim: runner.feature_dim,
            out_scale: runner.out_scale,
            bounds,
            stage_cycles,
            capacities,
            replicas,
        })
    }

    pub fn stages(&self) -> usize {
        self.bounds.len() - 1
    }

    pub fn feature_dim(&self) -> usize {
        self.feature_dim
    }

    pub fn img(&self) -> usize {
        self.img
    }

    pub fn stage_cycles(&self) -> &[u64] {
        &self.stage_cycles
    }

    pub fn capacities(&self) -> &[usize] {
        &self.capacities
    }

    /// Workers per stage.
    pub fn replicas(&self) -> &[usize] {
        &self.replicas
    }

    /// Total worker threads one streaming run spawns (excl. the feeder).
    pub fn workers(&self) -> usize {
        self.replicas.iter().sum()
    }

    /// The same cuts and capacities with a different per-stage worker
    /// replication — how the elastic rebalancer applies a decision
    /// without re-partitioning.
    pub fn with_replicas(&self, replicas: &[usize]) -> PlanPipeline {
        let mut p = self.shallow_clone();
        p.replicas = (0..self.stages())
            .map(|s| replicas.get(s).copied().unwrap_or(1).clamp(1, MAX_STAGE_REPLICAS))
            .collect();
        p
    }

    /// A cheap copy sharing the compiled plan — pool replicas
    /// (`coordinator::pool::PipelineReplica`) are stamped from one
    /// pipeline this way, like `PlanRunner::replicate`.
    pub fn replicate(&self) -> PlanPipeline {
        self.shallow_clone()
    }

    fn shallow_clone(&self) -> PlanPipeline {
        PlanPipeline {
            plan: Arc::clone(&self.plan),
            img: self.img,
            feature_dim: self.feature_dim,
            out_scale: self.out_scale,
            bounds: self.bounds.clone(),
            stage_cycles: self.stage_cycles.clone(),
            capacities: self.capacities.clone(),
            replicas: self.replicas.clone(),
        }
    }

    /// Run-length `SxR` encoding of the per-stage replication — the same
    /// shape the CLI `--topology` flag accepts (e.g. `[1,2,1,1]` prints
    /// as `1x1,1x2,2x1`).
    pub fn topology(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        let mut i = 0;
        while i < self.replicas.len() {
            let r = self.replicas[i];
            let mut j = i;
            while j < self.replicas.len() && self.replicas[j] == r {
                j += 1;
            }
            parts.push(format!("{}x{}", j - i, r));
            i = j;
        }
        parts.join(",")
    }

    /// Predicted share of the total cycle budget held by the slowest
    /// stage — the pipeline's theoretical steady-interval fraction of the
    /// sequential per-frame time (perfect overlap assumed).  Replication
    /// divides a stage's effective cycles by its worker count.
    pub fn predicted_bottleneck_share(&self) -> f64 {
        let total: u64 = self.stage_cycles.iter().sum();
        if total == 0 {
            return 1.0 / self.stages() as f64;
        }
        let max = self
            .stage_cycles
            .iter()
            .zip(&self.replicas)
            .map(|(&c, &r)| c as f64 / r as f64)
            .fold(0.0f64, f64::max);
        max / total as f64
    }

    /// Stage map for reports: step ranges, predicted cycles, channel
    /// capacities, replication.
    pub fn stage_table(&self) -> Vec<StageSummary> {
        (0..self.stages())
            .map(|s| {
                let (lo, hi) = (self.bounds[s], self.bounds[s + 1]);
                StageSummary {
                    first_step: self.plan.steps[lo].name.clone(),
                    last_step: self.plan.steps[hi - 1].name.clone(),
                    steps: hi - lo,
                    cycles: self.stage_cycles[s],
                    capacity: self.capacities[s],
                    replicas: self.replicas[s],
                }
            })
            .collect()
    }

    /// Build one frame's message: NHWC pixels -> the graph's NCHW import
    /// layout at the plan's feed slot (exactly what the sequential runner
    /// feeds).  `seq` is assigned by the feeder.
    fn ingress_msg(&self, id: u64, pixels: &[f32], enqueued: Instant) -> Result<FrameMsg> {
        let spec = &self.plan.feeds[0];
        let x = Tensor::new(vec![1, self.img, self.img, 3], pixels.to_vec())?.nhwc_to_nchw()?;
        if let Some(shape) = &spec.shape {
            if x.shape() != shape.as_slice() {
                bail!(
                    "feed {} has shape {:?}, graph expects {:?}",
                    spec.name,
                    x.shape(),
                    shape
                );
            }
        }
        let mut acts: Vec<Option<Tensor>> = vec![None; self.plan.n_slots];
        acts[spec.slot as usize] = Some(x);
        Ok(FrameMsg {
            seq: 0,
            id,
            enqueued,
            acts,
        })
    }

    /// Final-stage egress: take the output tensor out of the message and
    /// dequantize exactly as the sequential runner does.
    fn egress_msg(&self, mut msg: FrameMsg) -> Result<OutMsg> {
        let (name, slot) = &self.plan.outputs[0];
        let s = *slot as usize;
        let t = match msg.acts[s].take() {
            Some(t) => t,
            None => match self.plan.init[s].as_ref() {
                Some(t) => t.clone(),
                None => bail!("graph output {name} not produced"),
            },
        };
        let mut feats = Vec::with_capacity(self.feature_dim);
        dequantize_egress(&t, self.out_scale, &mut feats)?;
        Ok(OutMsg {
            seq: msg.seq,
            id: msg.id,
            enqueued: msg.enqueued,
            feats,
        })
    }

    /// Stream flat NHWC frames through the stage workers; returns the
    /// concatenated features (frame order, bitwise-identical to
    /// `runner.extract_all`) and the steady-state measurements.
    pub fn extract_stream(
        &self,
        images: &[f32],
        frames: usize,
        reg: Option<&Registry>,
    ) -> Result<(Vec<f32>, PipelineStats)> {
        let per = self.img * self.img * 3;
        if images.len() < frames * per {
            bail!(
                "expected {} input elements for {frames} frames, got {}",
                frames * per,
                images.len()
            );
        }
        let inputs = (0..frames)
            .map(|i| self.ingress_msg(i as u64, &images[i * per..(i + 1) * per], Instant::now()));
        let mut feats: Vec<f32> = Vec::with_capacity(frames * self.feature_dim);
        let stats = self.run_stream(inputs, reg, |out| {
            feats.extend_from_slice(&out.feats);
            Ok(())
        })?;
        Ok((feats, stats))
    }

    /// Serve a frame stream: classify each feature vector against `ncm`
    /// as it leaves the pipeline.  The streaming analogue of
    /// `coordinator::serve` — frames overlap across stages instead of
    /// batching within one.
    pub fn serve(
        &self,
        ncm: &NcmClassifier,
        rx: Receiver<Frame>,
        reg: Option<&Registry>,
    ) -> Result<(Metrics, Vec<Classified>, PipelineStats)> {
        let per = self.img * self.img * 3;
        let t0 = Instant::now();
        let inputs = rx.into_iter().map(|f| {
            if f.pixels.len() != per {
                bail!("frame {} has {} pixels, expected {per}", f.id, f.pixels.len());
            }
            self.ingress_msg(f.id, &f.pixels, f.enqueued)
        });
        let mut metrics = Metrics::default();
        let mut results: Vec<Classified> = Vec::new();
        let stats = self.run_stream(inputs, reg, |out| {
            let done = Instant::now();
            let class = ncm.predict(&out.feats);
            let latency = done.duration_since(out.enqueued);
            metrics.latencies_us.push(latency.as_micros() as u64);
            metrics.frames += 1;
            metrics.batches += 1;
            results.push(Classified {
                id: out.id,
                class,
                latency,
            });
            Ok(())
        })?;
        metrics.wall = t0.elapsed();
        Ok((metrics, results, stats))
    }

    /// The streaming core: feeder thread -> stage workers (R per stage,
    /// reorder-gated where R > 1) -> verified in-order sink on the
    /// calling thread.  All threads are scoped — by the time this
    /// returns, every worker has joined, error or not.
    fn run_stream<I, F>(
        &self,
        inputs: I,
        reg: Option<&Registry>,
        mut sink: F,
    ) -> Result<PipelineStats>
    where
        I: Iterator<Item = Result<FrameMsg>> + Send,
        F: FnMut(OutMsg) -> Result<()>,
    {
        let stages = self.stages();
        let chans: Vec<RingChannel<FrameMsg>> =
            (0..stages).map(|s| RingChannel::new(self.capacities[s])).collect();
        let egress: RingChannel<OutMsg> = RingChannel::new(self.capacities[stages]);
        // Reorder gates where a stage is replicated: interior stages gate
        // the next stage's ingress ring, the final stage gates the egress
        // ring.  Gate capacity 2R: every sibling can park one straggler
        // and still leave headroom before backpressure.
        let gates: Vec<Option<Reorder<FrameMsg>>> = (0..stages)
            .map(|s| {
                (self.replicas[s] > 1 && s + 1 < stages)
                    .then(|| Reorder::new(&chans[s + 1], self.replicas[s] * 2))
            })
            .collect();
        let out_gate: Option<Reorder<OutMsg>> = (self.replicas[stages - 1] > 1)
            .then(|| Reorder::new(&egress, self.replicas[stages - 1] * 2));
        // Live-replica counters: the LAST worker of a stage to drain its
        // ingress closes the stage's output, after every sibling's final
        // put has been forwarded — frames in flight are conserved.
        let live: Vec<AtomicUsize> = self.replicas.iter().map(|&r| AtomicUsize::new(r)).collect();
        let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        let tel = reg.map(|r| StageTelemetry::resolve(r, stages));

        // Failure broadcast: record the first error, then poison the
        // channels BEFORE the gates — a gate holder blocked inside a
        // downstream send wakes from the channel poison, releases the
        // gate lock, and only then can the gate poison land.
        let fail = |e: anyhow::Error| {
            let mut g = first_err.lock().unwrap();
            if g.is_none() {
                *g = Some(e);
            }
            drop(g);
            for c in &chans {
                c.poison();
            }
            egress.poison();
            for gate in gates.iter().flatten() {
                gate.poison();
            }
            if let Some(gate) = &out_gate {
                gate.poison();
            }
        };
        let fail = &fail;

        let t_start = Instant::now();
        let mut emit: Vec<Instant> = Vec::new();

        std::thread::scope(|scope| {
            // Feeder: pull frames from the input iterator into stage 0's
            // ring, stamping the arrival sequence the reorder gates and
            // the sink's order check key on.  Closing the ring at
            // end-of-stream starts the drain cascade.
            let chans_ref = &chans;
            scope.spawn(move || {
                for (seq, item) in inputs.enumerate() {
                    let mut msg = match item {
                        Ok(m) => m,
                        Err(e) => {
                            fail(e);
                            return;
                        }
                    };
                    msg.seq = seq as u64;
                    match chans_ref[0].send(msg) {
                        SendState::Sent { .. } => {}
                        SendState::Poisoned => return,
                    }
                }
                chans_ref[0].close();
            });

            // R workers per stage, each with a private scratch arena, all
            // pulling from the stage's shared ingress ring.
            for s in 0..stages {
                let (lo, hi) = (self.bounds[s], self.bounds[s + 1]);
                for _ in 0..self.replicas[s] {
                    let in_ch = &chans[s];
                    let out_ch = if s + 1 < stages {
                        Some(&chans[s + 1])
                    } else {
                        None
                    };
                    let gate = gates[s].as_ref();
                    let out_gate_ref = if s + 1 == stages {
                        out_gate.as_ref()
                    } else {
                        None
                    };
                    let egress_ref = &egress;
                    let live_s = &live[s];
                    let stage_tel = tel.as_ref().map(|v| &v[s]);
                    scope.spawn(move || {
                        let mut scratch = PlanScratch::default();
                        let mut peak = 0usize;
                        loop {
                            let mut msg = match in_ch.recv() {
                                RecvState::Poisoned => return,
                                RecvState::Closed => break,
                                RecvState::Msg { msg, occupancy, stalled } => {
                                    if let Some(t) = stage_tel {
                                        t.frames.inc();
                                        t.recv_stall_us.add(stalled.as_micros() as u64);
                                        t.fifo_occupancy.set(occupancy as i64);
                                        if occupancy > peak {
                                            peak = occupancy;
                                            t.fifo_peak.set(peak as i64);
                                        }
                                    }
                                    msg
                                }
                            };
                            let ran = run_steps(&self.plan, lo, hi, &mut msg.acts, &mut scratch);
                            if let Err(e) = ran {
                                fail(e);
                                return;
                            }
                            let sent = match out_ch {
                                Some(next) => match gate {
                                    Some(g) => {
                                        let seq = msg.seq;
                                        g.put(seq, msg)
                                    }
                                    None => next.send(msg),
                                },
                                None => match self.egress_msg(msg) {
                                    Ok(out) => match out_gate_ref {
                                        Some(g) => {
                                            let seq = out.seq;
                                            g.put(seq, out)
                                        }
                                        None => egress_ref.send(out),
                                    },
                                    Err(e) => {
                                        fail(e);
                                        return;
                                    }
                                },
                            };
                            match sent {
                                SendState::Sent { stalled } => {
                                    if let Some(t) = stage_tel {
                                        t.send_stall_us.add(stalled.as_micros() as u64);
                                    }
                                }
                                SendState::Poisoned => return,
                            }
                        }
                        // Clean drain: every sibling that exited before us
                        // completed its final put first, so the gate (if
                        // any) has forwarded everything — the last replica
                        // out may close the stage's output.
                        if live_s.fetch_sub(1, Ordering::AcqRel) == 1 {
                            match out_ch {
                                Some(next) => next.close(),
                                None => egress_ref.close(),
                            }
                        }
                    });
                }
            }

            // Sink on the calling thread: VERIFIED frame order — a
            // sequence gap at egress is a pipeline bug, never silently
            // reordered output.
            let mut expect_seq = 0u64;
            loop {
                match egress.recv() {
                    RecvState::Closed | RecvState::Poisoned => break,
                    RecvState::Msg { msg, .. } => {
                        if msg.seq != expect_seq {
                            fail(anyhow!(
                                "pipeline egress out of order: frame seq {} arrived, expected {}",
                                msg.seq,
                                expect_seq
                            ));
                            break;
                        }
                        expect_seq += 1;
                        if let Err(e) = sink(msg) {
                            fail(e);
                            break;
                        }
                        emit.push(Instant::now());
                    }
                }
            }
        });

        if let Some(e) = first_err.into_inner().unwrap() {
            return Err(e);
        }

        let frames = emit.len();
        let wall = t_start.elapsed();
        let first_frame_latency = emit
            .first()
            .map(|t| t.duration_since(t_start))
            .unwrap_or_default();
        let steady_interval = if frames >= 2 {
            // Skip the pipeline-fill frames: the steady interval is the
            // egress spacing once every stage holds a frame.
            let skip = stages.max(frames / 4).min(frames - 2);
            let span = emit[frames - 1].duration_since(emit[skip]);
            span / (frames - 1 - skip) as u32
        } else {
            wall
        };
        Ok(PipelineStats {
            frames,
            wall,
            first_frame_latency,
            steady_interval,
        })
    }
}

/// Channel frame-capacities from the `size_fifos` element depths: for
/// every tensor crossing a stage cut, the deepest sized FIFO on a
/// crossing edge is converted from elements to whole frames
/// (`ceil(depth / tensor_numel)`).  Clamped to [2, 8]: at least double-
/// buffered (stage overlap needs one slot filling while one drains),
/// at most a small bounded burst — the simulator's FIFOs absorb beats
/// within a frame, the pipeline's rings absorb whole frames.
///
/// The egress ring (index `stages`) decouples the final stage worker
/// from the host-side dequantize/classify sink.  `size_fifos` names that
/// channel `"{out}->sink"`, but the simulator's sink drains every cycle,
/// so the sized depth is a within-frame beat buffer: whenever the output
/// tensor's numel exceeds it, `ceil(depth / numel)` is one frame and the
/// egress capacity used to fall silently to the clamp floor no matter
/// how deeply the folding search buffered the design.  Whole frames are
/// what cross the dequantize boundary here, so the egress inherits the
/// final stage's ingress capacity (keeping the boundary at least as
/// decoupled as the interior edges feeding it) and the sink depth only
/// ever deepens it further.
fn stage_capacities(
    plan: &ExecutionPlan,
    bounds: &[usize],
    fifo_depths: &HashMap<String, u64>,
) -> Vec<usize> {
    let stages = bounds.len() - 1;
    // Producing step and numel per slot.
    let mut produced_at: HashMap<u32, usize> = HashMap::new();
    let mut numel: HashMap<u32, u64> = HashMap::new();
    for (i, step) in plan.steps.iter().enumerate() {
        produced_at.insert(step.output, i);
        numel.insert(step.output, step.out_shape.iter().product::<usize>() as u64);
    }
    for spec in &plan.feeds {
        if let Some(shape) = &spec.shape {
            numel.insert(spec.slot, shape.iter().product::<usize>() as u64);
        }
    }

    let mut caps = vec![2usize; stages + 1];
    for (ci, cap) in caps.iter_mut().take(stages).enumerate() {
        let mut frames = 2u64;
        let b = bounds[ci];
        for step in plan.steps.iter().skip(b) {
            for &s in &step.inputs {
                let crosses = match produced_at.get(&s) {
                    Some(&p) => p < b,
                    // Feeds cross the ingress cut only.
                    None => b == 0 && plan.feeds.iter().any(|f| f.slot == s),
                };
                if !crosses {
                    continue;
                }
                let key = format!("{}->{}", plan.slot_names[s as usize], step.name);
                if let Some(&depth) = fifo_depths.get(&key) {
                    let ne = numel.get(&s).copied().unwrap_or(0).max(1);
                    frames = frames.max(depth.div_ceil(ne));
                }
            }
        }
        *cap = frames.clamp(2, 8) as usize;
    }
    // Egress: final-stage ingress as the floor (the dequantize boundary
    // inherits the stage's frame decoupling), deepened by the sink depth
    // only when that depth genuinely covers whole output frames.
    let mut frames = caps[stages - 1] as u64;
    for (name, slot) in &plan.outputs {
        let key = format!("{name}->sink");
        if let Some(&depth) = fifo_depths.get(&key) {
            let ne = numel.get(slot).copied().unwrap_or(0).max(1);
            if depth >= ne {
                frames = frames.max(depth.div_ceil(ne));
            }
        }
    }
    caps[stages] = frames.clamp(2, 8) as usize;
    caps
}

/// Execute plan steps `lo..hi` against a message-owned slot environment —
/// the pipelined twin of the body of `ExecutionPlan::run_inner`, byte for
/// byte the same kernel calls in the same order.  Allocations come from
/// (and releases return to) the stage's private `scratch` arena.
fn run_steps(
    plan: &ExecutionPlan,
    lo: usize,
    hi: usize,
    acts: &mut [Option<Tensor>],
    scratch: &mut PlanScratch,
) -> Result<()> {
    for step in &plan.steps[lo..hi] {
        if step.inplace {
            let StepKind::F32(spec) = &step.kind else {
                bail!("plan bug: in-place integer step {}", step.name);
            };
            let mut buf = acts[step.inputs[0] as usize].take().ok_or_else(|| {
                anyhow!("plan bug: in-place input of {} not materialized", step.name)
            })?;
            {
                let rest: Vec<&Tensor> = step.inputs[1..]
                    .iter()
                    .map(|&s| resolve_msg(plan, s, acts))
                    .collect::<Result<_>>()?;
                ops::execute_spec_inplace(spec, &mut buf, &rest).map_err(|e| {
                    anyhow!("executing {} ({}): {e}", step.name, step.op)
                })?;
            }
            scratch.stats.inplace_steps += 1;
            acts[step.output as usize] = Some(buf);
        } else {
            let mut out = scratch.alloc_typed(&step.out_shape, step.out_dtype)?;
            {
                let inputs: Vec<&Tensor> = step
                    .inputs
                    .iter()
                    .map(|&s| resolve_msg(plan, s, acts))
                    .collect::<Result<_>>()?;
                match &step.kind {
                    StepKind::F32(spec) => ops::execute_spec_into(spec, &inputs, &mut out),
                    StepKind::Int(spec) => ops::execute_int_spec_into(spec, &inputs, &mut out),
                }
                .map_err(|e| anyhow!("executing {} ({}): {e}", step.name, step.op))?;
            }
            acts[step.output as usize] = Some(out);
        }
        for &dead in &step.release {
            if let Some(t) = acts[dead as usize].take() {
                scratch.recycle(t);
            }
        }
    }
    Ok(())
}

/// Resolve a slot against the message's owned environment: activation (or
/// feed, which the ingress placed in `acts`) first, then compile-time
/// initializers.
fn resolve_msg<'a>(
    plan: &'a ExecutionPlan,
    slot: u32,
    acts: &'a [Option<Tensor>],
) -> Result<&'a Tensor> {
    let s = slot as usize;
    if let Some(t) = acts[s].as_ref() {
        return Ok(t);
    }
    if let Some(t) = plan.init[s].as_ref() {
        return Ok(t);
    }
    bail!("tensor {} unavailable", plan.slot_names[s])
}

#[cfg(test)]
mod tests {
    use super::super::tests::tiny_bb_graph;
    use super::*;
    use crate::build::{lower_bit_true, synth_backbone_graph};
    use crate::coordinator::FeatureExtractor;
    use crate::fixedpoint::headline_config;
    use crate::rng::Rng;

    fn random_frames(runner: &PlanRunner, frames: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..frames * runner.img() * runner.img() * 3).map(|_| rng.next_f32()).collect()
    }

    #[test]
    fn partition_balances_cycle_weights() {
        let w = [10u64, 1, 1, 10, 1, 1];
        let bounds = partition_contiguous(&w, 2);
        assert_eq!(bounds, vec![0, 3, 6], "12/12 split beats any alternative");
        assert_eq!(partition_contiguous(&w, 1), vec![0, 6]);
        // More stages than steps clamps to one step per stage.
        assert_eq!(partition_contiguous(&[5, 5], 4), vec![0, 1, 2]);
    }

    #[test]
    fn partition_uniform_when_unweighted() {
        let bounds = partition_contiguous(&[1u64; 6], 3);
        assert_eq!(bounds, vec![0, 2, 4, 6]);
    }

    #[test]
    fn ring_capacity_one_makes_progress() {
        let ch = RingChannel::new(1);
        std::thread::scope(|s| {
            s.spawn(|| {
                for i in 0..100u32 {
                    match ch.send(i) {
                        SendState::Sent { .. } => {}
                        SendState::Poisoned => panic!("unexpected poison"),
                    }
                }
                ch.close();
            });
            let mut got = Vec::new();
            loop {
                match ch.recv() {
                    RecvState::Msg { msg, occupancy, .. } => {
                        assert!(occupancy <= 1, "capacity-1 ring never holds more than 1");
                        got.push(msg);
                    }
                    RecvState::Closed => break,
                    RecvState::Poisoned => panic!("unexpected poison"),
                }
            }
            assert_eq!(got, (0..100).collect::<Vec<u32>>());
        });
    }

    #[test]
    fn ring_poison_unblocks_blocked_sender() {
        let ch = RingChannel::new(1);
        match ch.send(0u32) {
            SendState::Sent { .. } => {}
            SendState::Poisoned => panic!("fresh ring not poisoned"),
        }
        std::thread::scope(|s| {
            let h = s.spawn(|| ch.send(1u32));
            // The sender is (or will be) blocked on the full ring; poison
            // must wake it with SendState::Poisoned, not deadlock.
            std::thread::sleep(Duration::from_millis(20));
            ch.poison();
            match h.join().unwrap() {
                SendState::Poisoned => {}
                SendState::Sent { .. } => panic!("send succeeded after poison"),
            }
        });
        match ch.recv() {
            RecvState::Poisoned => {}
            _ => panic!("poisoned ring must report poison to receivers"),
        }
    }

    #[test]
    fn ring_multi_consumer_conserves_messages() {
        // A replicated stage's workers share one ingress ring: every
        // message is delivered exactly once across consumers.
        let ch = RingChannel::new(2);
        let taken: Mutex<Vec<u32>> = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| loop {
                    match ch.recv() {
                        RecvState::Msg { msg, .. } => taken.lock().unwrap().push(msg),
                        RecvState::Closed => break,
                        RecvState::Poisoned => panic!("unexpected poison"),
                    }
                });
            }
            for i in 0..200u32 {
                match ch.send(i) {
                    SendState::Sent { .. } => {}
                    SendState::Poisoned => panic!("unexpected poison"),
                }
            }
            ch.close();
        });
        let mut got = taken.into_inner().unwrap();
        got.sort_unstable();
        assert_eq!(got, (0..200).collect::<Vec<u32>>());
    }

    #[test]
    fn reorder_restores_adversarial_completion_order() {
        // Completions arrive in an adversarial permutation; the gate must
        // emit the exact sequence with no gaps.
        let ch: RingChannel<u64> = RingChannel::new(16);
        let ro = Reorder::new(&ch, 8);
        for &seq in &[3u64, 1, 2, 0, 6, 5, 4] {
            match ro.put(seq, seq) {
                SendState::Sent { .. } => {}
                SendState::Poisoned => panic!("unexpected poison"),
            }
        }
        ch.close();
        let mut got = Vec::new();
        loop {
            match ch.recv() {
                RecvState::Msg { msg, .. } => got.push(msg),
                RecvState::Closed => break,
                RecvState::Poisoned => panic!("unexpected poison"),
            }
        }
        assert_eq!(got, (0..7).collect::<Vec<u64>>(), "strict frame order with no gaps");
    }

    #[test]
    fn reorder_capacity_one_downstream_backpressures_without_deadlock() {
        // Three "replicas" complete in reverse order into a capacity-1
        // ring: the gate forwards 0,1,2 while blocked on the consumer's
        // pace — backpressure, not deadlock, not reordering.
        let ch: RingChannel<u64> = RingChannel::new(1);
        let ro = Reorder::new(&ch, 4);
        std::thread::scope(|s| {
            for seq in (0..3u64).rev() {
                let ro = &ro;
                s.spawn(move || match ro.put(seq, seq) {
                    SendState::Sent { .. } => {}
                    SendState::Poisoned => panic!("unexpected poison"),
                });
            }
            let mut got = Vec::new();
            while got.len() < 3 {
                match ch.recv() {
                    RecvState::Msg { msg, .. } => got.push(msg),
                    _ => panic!("stream ended early"),
                }
            }
            assert_eq!(got, vec![0, 1, 2]);
        });
    }

    #[test]
    fn reorder_pending_cap_blocks_stragglers_only() {
        // With a 1-slot gate, a second out-of-order frame must wait —
        // but the next-expected frame always enters and drains the run.
        let ch: RingChannel<u64> = RingChannel::new(8);
        let ro = Reorder::new(&ch, 1);
        std::thread::scope(|s| {
            match ro.put(1, 1u64) {
                SendState::Sent { .. } => {} // parked
                SendState::Poisoned => panic!("unexpected poison"),
            }
            let straggler = s.spawn(|| ro.put(2, 2u64));
            std::thread::sleep(Duration::from_millis(20));
            // seq 0 is next-expected: it bypasses the full buffer and
            // drains 0,1 — freeing room so the straggler lands as 2.
            match ro.put(0, 0u64) {
                SendState::Sent { .. } => {}
                SendState::Poisoned => panic!("unexpected poison"),
            }
            match straggler.join().unwrap() {
                SendState::Sent { .. } => {}
                SendState::Poisoned => panic!("straggler must complete after room frees"),
            }
            ch.close();
            let mut got = Vec::new();
            loop {
                match ch.recv() {
                    RecvState::Msg { msg, .. } => got.push(msg),
                    RecvState::Closed => break,
                    RecvState::Poisoned => panic!("unexpected poison"),
                }
            }
            assert_eq!(got, vec![0, 1, 2]);
        });
    }

    #[test]
    fn reorder_downstream_poison_unblocks_forwarding_put() {
        // A put blocked forwarding into a full poisoned-later ring must
        // wake with Poisoned, and the gate stays refused afterwards.
        let ch: RingChannel<u64> = RingChannel::new(1);
        let ro = Reorder::new(&ch, 2);
        match ro.put(0, 0u64) {
            SendState::Sent { .. } => {} // fills the ring
            SendState::Poisoned => panic!("unexpected poison"),
        }
        std::thread::scope(|s| {
            let h = s.spawn(|| ro.put(1, 1u64)); // next-expected, ring full -> blocks in send
            std::thread::sleep(Duration::from_millis(20));
            ch.poison();
            match h.join().unwrap() {
                SendState::Poisoned => {}
                SendState::Sent { .. } => panic!("put succeeded after poison"),
            }
        });
        match ro.put(5, 5u64) {
            SendState::Poisoned => {}
            SendState::Sent { .. } => panic!("poisoned gate must refuse further puts"),
        }
    }

    #[test]
    fn reorder_poison_unblocks_parked_straggler() {
        let ch: RingChannel<u64> = RingChannel::new(8);
        let ro = Reorder::new(&ch, 1);
        match ro.put(1, 1u64) {
            SendState::Sent { .. } => {} // parked, buffer now full
            SendState::Poisoned => panic!("unexpected poison"),
        }
        std::thread::scope(|s| {
            let h = s.spawn(|| ro.put(2, 2u64)); // waits for room
            std::thread::sleep(Duration::from_millis(20));
            ro.poison();
            match h.join().unwrap() {
                SendState::Poisoned => {}
                SendState::Sent { .. } => panic!("parked put survived poison"),
            }
        });
    }

    #[test]
    fn pipeline_matches_runner_f32() {
        let g = tiny_bb_graph();
        let frames = 7;
        let runner = PlanRunner::new(&g, frames).unwrap();
        let images = random_frames(&runner, frames, 42);
        let seq = runner.extract_all(&images, frames).unwrap();
        let pipe = PlanPipeline::new(&runner, &PipelineSpec::uniform(2)).unwrap();
        assert_eq!(pipe.stages(), 2);
        let (feats, stats) = pipe.extract_stream(&images, frames, None).unwrap();
        assert_eq!(feats, seq, "pipeline features must be bitwise-identical");
        assert_eq!(stats.frames, frames);
    }

    #[test]
    fn pipeline_matches_runner_bit_true() {
        let quant = headline_config();
        let mut g = synth_backbone_graph([4, 8, 8, 16], 16, quant.act.bits, quant.act.frac_bits);
        lower_bit_true(&mut g, &quant).unwrap();
        let frames = 4;
        let runner = PlanRunner::new_bit_true(&g, frames).unwrap();
        let images = random_frames(&runner, frames, 7);
        let seq = runner.extract_all(&images, frames).unwrap();
        let pipe = PlanPipeline::new(&runner, &PipelineSpec::uniform(3)).unwrap();
        assert_eq!(pipe.stages(), 3);
        let (feats, _) = pipe.extract_stream(&images, frames, None).unwrap();
        assert_eq!(feats, seq, "bit-true pipeline must match the sequential plan");
    }

    #[test]
    fn replicated_stages_match_runner_f32() {
        let g = tiny_bb_graph();
        let frames = 16;
        let runner = PlanRunner::new(&g, frames).unwrap();
        let images = random_frames(&runner, frames, 21);
        let seq = runner.extract_all(&images, frames).unwrap();
        let pipe = PlanPipeline::new(
            &runner,
            &PipelineSpec::uniform(2).with_replicas(vec![2, 3]),
        )
        .unwrap();
        assert_eq!(pipe.replicas(), &[2, 3]);
        assert_eq!(pipe.workers(), 5);
        assert_eq!(pipe.topology(), "1x2,1x3");
        let (feats, stats) = pipe.extract_stream(&images, frames, None).unwrap();
        assert_eq!(feats, seq, "replicated stages must stay bitwise-identical and in order");
        assert_eq!(stats.frames, frames);
    }

    #[test]
    fn replicated_stages_match_runner_bit_true() {
        let quant = headline_config();
        let mut g = synth_backbone_graph([4, 8, 8, 16], 16, quant.act.bits, quant.act.frac_bits);
        lower_bit_true(&mut g, &quant).unwrap();
        let frames = 12;
        let runner = PlanRunner::new_bit_true(&g, frames).unwrap();
        let images = random_frames(&runner, frames, 23);
        let seq = runner.extract_all(&images, frames).unwrap();
        let pipe = PlanPipeline::new(
            &runner,
            &PipelineSpec::uniform(3).with_replicas(vec![2, 2, 2]),
        )
        .unwrap();
        let (feats, stats) = pipe.extract_stream(&images, frames, None).unwrap();
        assert_eq!(feats, seq, "bit-true replicated pipeline must match the sequential plan");
        assert_eq!(stats.frames, frames);
    }

    #[test]
    fn replicated_capacity_one_channels_conserve_frames() {
        let g = tiny_bb_graph();
        let frames = 9;
        let runner = PlanRunner::new(&g, frames).unwrap();
        let images = random_frames(&runner, frames, 31);
        let seq = runner.extract_all(&images, frames).unwrap();
        let mut pipe = PlanPipeline::new(
            &runner,
            &PipelineSpec::uniform(2).with_replicas(vec![2, 2]),
        )
        .unwrap();
        // Backpressure at its tightest: every hand-off is a rendezvous,
        // and the reorder gates forward through capacity-1 rings.
        for c in pipe.capacities.iter_mut() {
            *c = 1;
        }
        let (feats, stats) = pipe.extract_stream(&images, frames, None).unwrap();
        assert_eq!(feats, seq);
        assert_eq!(stats.frames, frames, "shutdown must conserve frames in flight");
    }

    #[test]
    fn capacity_one_channels_still_stream_every_frame() {
        let g = tiny_bb_graph();
        let frames = 9;
        let runner = PlanRunner::new(&g, frames).unwrap();
        let images = random_frames(&runner, frames, 3);
        let seq = runner.extract_all(&images, frames).unwrap();
        let mut pipe = PlanPipeline::new(&runner, &PipelineSpec::uniform(2)).unwrap();
        // Backpressure at its tightest: every hand-off is a rendezvous.
        for c in pipe.capacities.iter_mut() {
            *c = 1;
        }
        let (feats, stats) = pipe.extract_stream(&images, frames, None).unwrap();
        assert_eq!(feats, seq);
        assert_eq!(stats.frames, frames, "shutdown must conserve frames in flight");
    }

    #[test]
    fn telemetry_counts_frames_per_stage() {
        let g = tiny_bb_graph();
        let frames = 5;
        let runner = PlanRunner::new(&g, frames).unwrap();
        let images = random_frames(&runner, frames, 11);
        let pipe = PlanPipeline::new(&runner, &PipelineSpec::uniform(2)).unwrap();
        let reg = Registry::new();
        pipe.extract_stream(&images, frames, Some(&reg)).unwrap();
        let snap = reg.snapshot();
        assert_eq!(snap.counters.get("pipeline.stage0.frames"), Some(&(frames as u64)));
        assert_eq!(snap.counters.get("pipeline.stage1.frames"), Some(&(frames as u64)));
        assert!(snap.gauges.contains_key("pipeline.stage0.fifo_peak"));
    }

    #[test]
    fn replicated_telemetry_counts_each_frame_once() {
        // R workers share the stage's counters: frames aggregate to
        // exactly the stream length, not R times it.
        let g = tiny_bb_graph();
        let frames = 8;
        let runner = PlanRunner::new(&g, frames).unwrap();
        let images = random_frames(&runner, frames, 13);
        let pipe = PlanPipeline::new(
            &runner,
            &PipelineSpec::uniform(2).with_replicas(vec![2, 2]),
        )
        .unwrap();
        let reg = Registry::new();
        pipe.extract_stream(&images, frames, Some(&reg)).unwrap();
        let snap = reg.snapshot();
        assert_eq!(snap.counters.get("pipeline.stage0.frames"), Some(&(frames as u64)));
        assert_eq!(snap.counters.get("pipeline.stage1.frames"), Some(&(frames as u64)));
    }

    #[test]
    fn poisoned_stage_propagates_and_joins() {
        let g = tiny_bb_graph();
        let runner = PlanRunner::new(&g, 4).unwrap();
        let images = random_frames(&runner, 6, 5);
        let pipe = PlanPipeline::new(&runner, &PipelineSpec::uniform(2)).unwrap();
        let per = pipe.img() * pipe.img() * 3;
        // Frame 2 carries an integer tensor into the f32 Conv: the stage
        // kernel errors mid-stream with frames in flight behind it.
        let inputs = (0..6usize).map(|i| {
            if i == 2 {
                let bad = Tensor::new_i32(vec![1, 3, 4, 4], vec![0; 48]).unwrap();
                let mut acts: Vec<Option<Tensor>> = vec![None; pipe.plan.n_slots];
                acts[pipe.plan.feeds[0].slot as usize] = Some(bad);
                Ok(FrameMsg {
                    seq: 0,
                    id: i as u64,
                    enqueued: Instant::now(),
                    acts,
                })
            } else {
                pipe.ingress_msg(i as u64, &images[i * per..(i + 1) * per], Instant::now())
            }
        });
        let mut seen = 0usize;
        let err = pipe
            .run_stream(inputs, None, |_| {
                seen += 1;
                Ok(())
            })
            .expect_err("a failing kernel must poison the pipeline");
        assert!(
            format!("{err:#}").contains("executing"),
            "error should name the failing step, got: {err:#}"
        );
        assert!(seen <= 2, "frames behind the poison must not be emitted");
    }

    #[test]
    fn poisoned_replica_drains_and_joins() {
        // Same failure, but on a REPLICATED stage: the sibling replica
        // may be mid-frame when the poison lands, and the egress gate
        // must never emit a frame past the gap the dead frame leaves.
        let g = tiny_bb_graph();
        let runner = PlanRunner::new(&g, 4).unwrap();
        let images = random_frames(&runner, 8, 17);
        let pipe = PlanPipeline::new(
            &runner,
            &PipelineSpec::uniform(2).with_replicas(vec![2, 2]),
        )
        .unwrap();
        let per = pipe.img() * pipe.img() * 3;
        let inputs = (0..8usize).map(|i| {
            if i == 2 {
                let bad = Tensor::new_i32(vec![1, 3, 4, 4], vec![0; 48]).unwrap();
                let mut acts: Vec<Option<Tensor>> = vec![None; pipe.plan.n_slots];
                acts[pipe.plan.feeds[0].slot as usize] = Some(bad);
                Ok(FrameMsg {
                    seq: 0,
                    id: i as u64,
                    enqueued: Instant::now(),
                    acts,
                })
            } else {
                pipe.ingress_msg(i as u64, &images[i * per..(i + 1) * per], Instant::now())
            }
        });
        let mut seen = 0usize;
        let err = pipe
            .run_stream(inputs, None, |_| {
                seen += 1;
                Ok(())
            })
            .expect_err("a failing replica must poison the pipeline");
        assert!(format!("{err:#}").contains("executing"), "got: {err:#}");
        assert!(
            seen <= 2,
            "the in-order gate must not emit frames past the poisoned frame's gap (saw {seen})"
        );
    }

    #[test]
    fn feeder_error_propagates() {
        let g = tiny_bb_graph();
        let runner = PlanRunner::new(&g, 4).unwrap();
        let images = random_frames(&runner, 2, 9);
        let pipe = PlanPipeline::new(&runner, &PipelineSpec::uniform(2)).unwrap();
        let per = pipe.img() * pipe.img() * 3;
        let inputs = (0..3usize).map(|i| {
            if i == 2 {
                Err(anyhow!("camera died"))
            } else {
                pipe.ingress_msg(i as u64, &images[i * per..(i + 1) * per], Instant::now())
            }
        });
        let err = pipe.run_stream(inputs, None, |_| Ok(())).expect_err("feeder error propagates");
        assert!(format!("{err:#}").contains("camera died"));
    }

    #[test]
    fn fifo_depths_deepen_channels_within_clamp() {
        let g = tiny_bb_graph();
        let runner = PlanRunner::new(&g, 2).unwrap();
        // tiny_bb: c0 produces "c" (numel 80) consumed by gap.  A sized
        // depth of 400 elements = 5 frames in flight.
        let mut spec = PipelineSpec::uniform(2);
        spec.fifo_depths.insert("c->gap".to_string(), 400);
        let pipe = PlanPipeline::new(&runner, &spec).unwrap();
        let caps = pipe.capacities();
        assert!(
            caps.contains(&5),
            "a 5-frame fifo depth must deepen the crossing channel, got {caps:?}"
        );
        // And an absurd depth clamps at 8.
        let mut spec = PipelineSpec::uniform(2);
        spec.fifo_depths.insert("c->gap".to_string(), 80 * 1000);
        let pipe = PlanPipeline::new(&runner, &spec).unwrap();
        assert!(pipe.capacities().iter().all(|&c| c <= 8));
    }

    #[test]
    fn egress_capacity_inherits_final_stage_depth() {
        // Regression: the sized "{out}->sink" depth is the simulator's
        // per-cycle drain buffer — on tiny_bb, 4 elements against
        // global_out's numel 5 ("boundary numel exceeds the folding
        // depth").  The egress used to fall to the clamp floor (2) even
        // when the folding search buffered the interior 5 frames deep;
        // it must inherit the final stage's ingress capacity instead.
        let g = tiny_bb_graph();
        let runner = PlanRunner::new(&g, 2).unwrap();
        let mut spec = PipelineSpec::uniform(2);
        spec.fifo_depths.insert("c->gap".to_string(), 400);
        spec.fifo_depths.insert("global_out->sink".to_string(), 4);
        let pipe = PlanPipeline::new(&runner, &spec).unwrap();
        let caps = pipe.capacities();
        assert_eq!(
            caps[caps.len() - 2],
            5,
            "final stage ingress sized from c->gap, got {caps:?}"
        );
        assert_eq!(
            *caps.last().unwrap(),
            5,
            "egress must inherit the final stage's decoupling, got {caps:?}"
        );
        // A sink depth that genuinely covers whole frames still deepens
        // the egress beyond the inherited floor.
        let mut spec = PipelineSpec::uniform(2);
        spec.fifo_depths.insert("global_out->sink".to_string(), 5 * 6);
        let pipe = PlanPipeline::new(&runner, &spec).unwrap();
        assert_eq!(*pipe.capacities().last().unwrap(), 6);
    }

    #[test]
    fn stage_table_covers_all_steps() {
        let g = tiny_bb_graph();
        let runner = PlanRunner::new(&g, 2).unwrap();
        let pipe = PlanPipeline::new(&runner, &PipelineSpec::uniform(2)).unwrap();
        let table = pipe.stage_table();
        assert_eq!(table.len(), 2);
        let steps: usize = table.iter().map(|s| s.steps).sum();
        assert_eq!(steps, pipe.plan.num_steps());
        assert!(table.iter().all(|s| s.capacity >= 2));
        assert!(table.iter().all(|s| s.replicas == 1));
    }

    #[test]
    fn with_replicas_rebuilds_topology_cheaply() {
        let g = tiny_bb_graph();
        let runner = PlanRunner::new(&g, 2).unwrap();
        let pipe = PlanPipeline::new(&runner, &PipelineSpec::uniform(2)).unwrap();
        assert_eq!(pipe.topology(), "2x1");
        let boosted = pipe.with_replicas(&[1, 4]);
        assert_eq!(boosted.replicas(), &[1, 4]);
        assert_eq!(boosted.topology(), "1x1,1x4");
        assert_eq!(boosted.stages(), pipe.stages());
        assert_eq!(boosted.capacities(), pipe.capacities());
        // Replication can only shrink the predicted bottleneck share.
        assert!(boosted.predicted_bottleneck_share() <= pipe.predicted_bottleneck_share());
    }
}
