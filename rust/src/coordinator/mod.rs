//! Serving coordinator — the deployment runtime of the paper's Fig. 5.
//!
//! A camera-like frame source feeds a bounded queue; the batcher groups
//! frames (up to the executable's batch size, with a max-wait deadline);
//! the backbone worker extracts features via PJRT; the NCM classifier
//! (CPU side, [`crate::fewshot`]) produces the class decision; metrics
//! record per-frame latency and end-to-end throughput — the numbers the
//! paper reports as 16.3 ms / 61.5 fps.
//!
//! Threading: each frame source runs on its own std thread (no tokio in
//! the offline crate set — DESIGN.md §2); the backbone executor stays on
//! the coordinator thread ([`serve`]), or fans out across N replica
//! threads behind the work-stealing [`pool`] ([`serve_pool`]) —
//! DESIGN.md §10.  Frames are plain `Vec<f32>` so nothing non-Send
//! crosses threads.
//!
//! The backbone is abstracted behind [`FeatureExtractor`] so the same
//! serving loop drives either the PJRT executable
//! (`runtime::BackboneRunner`) or the compiled-plan engine
//! (`plan::PlanRunner`) — the python-free fallback that needs no XLA at
//! all.  The plan runner comes in two datapaths: the f32 simulation and
//! the bit-true integer engine (`PlanRunner::new_bit_true`), which
//! serves features computed exactly as the FPGA dataflow design would
//! (CLI: `--datapath f32|bit-true`).

use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::fewshot::NcmClassifier;
use crate::rng::Rng;

pub mod pool;

pub use pool::{serve_pool, serve_pool_with, PipelineReplica, PoolReport};

/// A deployed backbone: turns flat NHWC image batches into features.
///
/// `extract` consumes exactly `input_elems()` floats (`batch()` frames,
/// zero-padded by the caller when short) and returns
/// `batch() * feature_dim()` features.
pub trait FeatureExtractor {
    /// Frames per `extract` invocation.
    fn batch(&self) -> usize;

    /// Square image side length.
    fn img(&self) -> usize;

    /// Features per frame.
    fn feature_dim(&self) -> usize;

    /// Elements of one input batch.
    fn input_elems(&self) -> usize {
        self.batch() * self.img() * self.img() * 3
    }

    /// Bytes one frame streams through the backbone's kernels, when the
    /// engine can account for them (the plan engine does; a compiled
    /// PJRT executable cannot).
    fn bytes_moved_per_frame(&self) -> Option<u64> {
        None
    }

    /// Run one batch of NHWC images (flat, `input_elems()` long).
    fn extract(&self, images: &[f32]) -> Result<Vec<f32>>;

    /// Extract features for the first `live` frames of a full batch
    /// buffer (the rest is zero padding).  The default runs the whole
    /// batch — correct for fixed-batch engines like a compiled PJRT
    /// executable; batch-flexible engines (the plan runner) override it
    /// to skip the padding entirely.
    fn extract_live(&self, images: &[f32], live: usize) -> Result<Vec<f32>> {
        let mut feats = self.extract(images)?;
        feats.truncate(live.min(self.batch()) * self.feature_dim());
        Ok(feats)
    }

    /// Extract features for an arbitrary number of images, batching and
    /// zero-padding the tail.
    fn extract_all(&self, images: &[f32], count: usize) -> Result<Vec<f32>> {
        let per = self.img() * self.img() * 3;
        if images.len() != count * per {
            bail!("image buffer size mismatch");
        }
        let dim = self.feature_dim();
        let mut feats = Vec::with_capacity(count * dim);
        let mut batch_buf = vec![0.0f32; self.input_elems()];
        let mut i = 0;
        while i < count {
            let take = (count - i).min(self.batch());
            batch_buf[..take * per].copy_from_slice(&images[i * per..(i + take) * per]);
            batch_buf[take * per..].fill(0.0);
            let out = self.extract_live(&batch_buf, take)?;
            feats.extend_from_slice(&out[..take * dim]);
            i += take;
        }
        Ok(feats)
    }
}

/// One frame entering the pipeline.
#[derive(Clone)]
pub struct Frame {
    pub id: u64,
    pub pixels: Vec<f32>,
    pub enqueued: Instant,
}

/// Classified result leaving the pipeline.
#[derive(Debug, Clone)]
pub struct Classified {
    pub id: u64,
    pub class: usize,
    pub latency: Duration,
}

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Max frames per backbone invocation (<= executable batch).
    pub max_batch: usize,
    /// Max time the first frame of a batch may wait.
    pub max_wait: Duration,
}

/// Latency/throughput metrics.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub latencies_us: Vec<u64>,
    pub frames: usize,
    pub batches: usize,
    pub wall: Duration,
}

impl Metrics {
    pub fn fps(&self) -> f64 {
        if self.wall.as_secs_f64() == 0.0 {
            return 0.0;
        }
        self.frames as f64 / self.wall.as_secs_f64()
    }

    pub fn mean_latency_ms(&self) -> f64 {
        if self.latencies_us.is_empty() {
            return 0.0;
        }
        self.latencies_us.iter().sum::<u64>() as f64 / self.latencies_us.len() as f64 / 1e3
    }

    /// Nearest-rank latency percentile in milliseconds.  Empty samples
    /// report 0 (never index out of bounds); `p` is clamped to
    /// [0, 100], so p=0 is the minimum and p=100 is exactly the maximum
    /// — see [`crate::benchutil::nearest_rank_index`] for the shared
    /// convention.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        let Some(idx) = crate::benchutil::nearest_rank_index(self.latencies_us.len(), p) else {
            return 0.0;
        };
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        v[idx] as f64 / 1e3
    }

    /// Merge per-replica metrics into pool-level totals: latencies
    /// concatenated (percentiles then rank over EVERY frame served),
    /// frames and batches summed.  `wall` is set to the longest part;
    /// callers with a pool-level wall clock overwrite it so fps reflects
    /// aggregate throughput, not a per-replica one.
    pub fn merge(parts: &[Metrics]) -> Metrics {
        let mut m = Metrics::default();
        for p in parts {
            m.latencies_us.extend_from_slice(&p.latencies_us);
            m.frames += p.frames;
            m.batches += p.batches;
            m.wall = m.wall.max(p.wall);
        }
        m
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.frames as f64 / self.batches as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "frames {:>5}  fps {:>7.1}  latency mean {:>7.2} ms  p50 {:>7.2}  p95 {:>7.2}  p99 {:>7.2}  mean batch {:.2}",
            self.frames,
            self.fps(),
            self.mean_latency_ms(),
            self.percentile_ms(50.0),
            self.percentile_ms(95.0),
            self.percentile_ms(99.0),
            self.mean_batch_size()
        )
    }
}

/// A frame source: emits `count` frames, optionally rate-limited.
pub struct FrameSource {
    pub count: usize,
    /// Frames per second; None = as fast as the queue accepts (offered
    /// load regime — measures pipeline capacity, Fig. 5's fps).
    pub rate_fps: Option<f64>,
    pub img: usize,
    pub seed: u64,
}

impl FrameSource {
    /// Spawn the source thread; returns the frame receiver.
    pub fn spawn(self, queue_depth: usize) -> mpsc::Receiver<Frame> {
        let (tx, rx) = mpsc::sync_channel::<Frame>(queue_depth);
        self.spawn_into(tx, 0);
        rx
    }

    /// Spawn the source thread onto a shared bounded channel — one of M
    /// concurrent camera streams feeding a single serving tier.  Frame
    /// ids are `id_base .. id_base + count`, so streams given disjoint
    /// base blocks never collide and frame conservation stays checkable
    /// end to end.
    ///
    /// Rate limiting sleeps until each frame's ABSOLUTE deadline
    /// (`start + id/rate`, re-checked after every wakeup) rather than a
    /// fixed per-frame interval, so per-sleep overshoot never
    /// accumulates and long runs hold the requested fps.
    pub fn spawn_into(self, tx: mpsc::SyncSender<Frame>, id_base: u64) {
        std::thread::spawn(move || {
            let mut rng = Rng::new(self.seed);
            let per = self.img * self.img * 3;
            let start = Instant::now();
            for k in 0..self.count {
                if let Some(rate) = self.rate_fps {
                    let due = start + Duration::from_secs_f64(k as f64 / rate);
                    loop {
                        let now = Instant::now();
                        if now >= due {
                            break;
                        }
                        std::thread::sleep(due - now);
                    }
                }
                let pixels: Vec<f32> = (0..per).map(|_| rng.next_f32()).collect();
                let frame = Frame {
                    id: id_base + k as u64,
                    pixels,
                    enqueued: Instant::now(),
                };
                if tx.send(frame).is_err() {
                    return;
                }
            }
        });
    }
}

/// Execute one batch of frames through backbone + NCM, recording
/// per-frame latency into `metrics` and the classifications into
/// `results`.  Both the single-runner [`serve`] loop and every pool
/// replica ([`pool::serve_pool`]) funnel through this ONE function, so
/// the two paths are bitwise-identical by construction — the basis of
/// the pool's differential guarantee.
fn classify_batch(
    runner: &dyn FeatureExtractor,
    ncm: &NcmClassifier,
    batch: &[Frame],
    batch_buf: &mut [f32],
    metrics: &mut Metrics,
    results: &mut Vec<Classified>,
) -> Result<()> {
    let per = runner.img() * runner.img() * 3;
    for (i, f) in batch.iter().enumerate() {
        batch_buf[i * per..(i + 1) * per].copy_from_slice(&f.pixels);
    }
    batch_buf[batch.len() * per..].fill(0.0);
    let feats = runner.extract_live(batch_buf, batch.len())?;
    let done = Instant::now();
    let dim = runner.feature_dim();
    for (i, f) in batch.iter().enumerate() {
        let class = ncm.predict(&feats[i * dim..(i + 1) * dim]);
        let latency = done.duration_since(f.enqueued);
        metrics.latencies_us.push(latency.as_micros() as u64);
        results.push(Classified {
            id: f.id,
            class,
            latency,
        });
    }
    metrics.frames += batch.len();
    metrics.batches += 1;
    Ok(())
}

/// Serve frames through backbone + NCM until the source is exhausted.
///
/// Returns (metrics, classifications).  Takes any [`FeatureExtractor`]
/// (PJRT backbone or compiled-plan engine).  Batches close
/// deadline-driven: at `max_batch`, or when the OLDEST pending frame's
/// `max_wait` budget is spent, whichever comes first — the same policy
/// the pool replicas apply ([`pool::serve_pool`]).
pub fn serve(
    runner: &dyn FeatureExtractor,
    ncm: &NcmClassifier,
    rx: mpsc::Receiver<Frame>,
    policy: BatchPolicy,
) -> Result<(Metrics, Vec<Classified>)> {
    let mut metrics = Metrics::default();
    let mut results = Vec::new();
    let mut batch_buf = vec![0.0f32; runner.input_elems()];
    let mut pending: VecDeque<Frame> = VecDeque::new();
    let start = Instant::now();
    let max_batch = policy.max_batch.min(runner.batch()).max(1);

    'outer: loop {
        // Block for the first frame of the batch.
        if pending.is_empty() {
            match rx.recv() {
                Ok(f) => pending.push_back(f),
                Err(_) => break 'outer,
            }
        }
        // Greedily drain whatever is already queued (frames that arrived
        // while the previous batch was executing batch up immediately).
        while pending.len() < max_batch {
            match rx.try_recv() {
                Ok(f) => pending.push_back(f),
                Err(_) => break,
            }
        }
        // Still short: wait for stragglers until the oldest frame's wait
        // budget is spent.  The budget runs from ENQUEUE, not from now —
        // a frame that already aged in the queue closes its batch sooner.
        let deadline = pending[0].enqueued + policy.max_wait;
        while pending.len() < max_batch {
            let timeout = deadline.saturating_duration_since(Instant::now());
            if timeout.is_zero() {
                break;
            }
            match rx.recv_timeout(timeout) {
                Ok(f) => pending.push_back(f),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // Execute one batch.
        let take = pending.len().min(max_batch);
        let batch: Vec<Frame> = pending.drain(..take).collect();
        classify_batch(runner, ncm, &batch, &mut batch_buf, &mut metrics, &mut results)?;
    }

    metrics.wall = start.elapsed();
    Ok((metrics, results))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_math() {
        let m = Metrics {
            latencies_us: vec![1000, 2000, 3000, 4000, 100_000],
            frames: 5,
            batches: 2,
            wall: Duration::from_secs(1),
        };
        assert_eq!(m.fps(), 5.0);
        assert!((m.mean_latency_ms() - 22.0).abs() < 1e-9);
        assert_eq!(m.percentile_ms(50.0), 3.0);
        assert_eq!(m.percentile_ms(99.0), 100.0);
        assert_eq!(m.mean_batch_size(), 2.5);
    }

    #[test]
    fn percentile_edge_cases() {
        // Empty latency vector: every percentile reports 0, no indexing.
        let empty = Metrics::default();
        assert_eq!(empty.percentile_ms(0.0), 0.0);
        assert_eq!(empty.percentile_ms(50.0), 0.0);
        assert_eq!(empty.percentile_ms(100.0), 0.0);

        // Single sample: every p maps to it.
        let one = Metrics {
            latencies_us: vec![7000],
            frames: 1,
            batches: 1,
            wall: Duration::from_secs(1),
        };
        assert_eq!(one.percentile_ms(0.0), 7.0);
        assert_eq!(one.percentile_ms(1.0), 7.0);
        assert_eq!(one.percentile_ms(100.0), 7.0);

        // Nearest rank: p=100 is exactly the max (no off-by-one past the
        // end), p=0 the min, and out-of-range p clamps instead of
        // indexing out of bounds.
        let m = Metrics {
            latencies_us: vec![1000, 2000, 3000, 4000],
            frames: 4,
            batches: 1,
            wall: Duration::from_secs(1),
        };
        assert_eq!(m.percentile_ms(0.0), 1.0);
        assert_eq!(m.percentile_ms(1.0), 1.0);
        assert_eq!(m.percentile_ms(100.0), 4.0);
        assert_eq!(m.percentile_ms(250.0), 4.0);
        assert_eq!(m.percentile_ms(-5.0), 1.0);
        // ceil(0.5 * 4) = rank 2 -> second-smallest.
        assert_eq!(m.percentile_ms(50.0), 2.0);
    }

    #[test]
    fn metrics_merge_concatenates_parts() {
        let a = Metrics {
            latencies_us: vec![1000, 5000],
            frames: 2,
            batches: 1,
            wall: Duration::from_millis(10),
        };
        let b = Metrics {
            latencies_us: vec![3000],
            frames: 1,
            batches: 1,
            wall: Duration::from_millis(30),
        };
        let m = Metrics::merge(&[a, b]);
        assert_eq!(m.frames, 3);
        assert_eq!(m.batches, 2);
        assert_eq!(m.wall, Duration::from_millis(30));
        assert_eq!(m.percentile_ms(100.0), 5.0);
        assert_eq!(m.percentile_ms(50.0), 3.0);
    }

    #[test]
    fn frame_source_emits_all_frames() {
        let src = FrameSource {
            count: 17,
            rate_fps: None,
            img: 4,
            seed: 1,
        };
        let rx = src.spawn(4);
        let frames: Vec<Frame> = rx.iter().collect();
        assert_eq!(frames.len(), 17);
        assert_eq!(frames[0].pixels.len(), 4 * 4 * 3);
        assert!(frames.iter().enumerate().all(|(i, f)| f.id == i as u64));
    }

    #[test]
    fn frame_source_rate_limited() {
        let src = FrameSource {
            count: 5,
            rate_fps: Some(1000.0),
            img: 2,
            seed: 2,
        };
        let t0 = Instant::now();
        let rx = src.spawn(8);
        let n = rx.iter().count();
        let dt = t0.elapsed();
        assert_eq!(n, 5);
        assert!(dt >= Duration::from_millis(3), "{dt:?}");
    }

    #[test]
    fn frame_source_rate_holds_over_long_runs() {
        // Absolute-deadline pacing: total elapsed tracks the schedule
        // (count-1)/rate, and per-sleep overshoot must NOT accumulate
        // the way fixed per-frame sleeps would over hundreds of frames.
        let count = 120;
        let rate = 2000.0;
        let src = FrameSource {
            count,
            rate_fps: Some(rate),
            img: 2,
            seed: 3,
        };
        let t0 = Instant::now();
        // Queue deeper than the run: the consumer never throttles the
        // source, so elapsed time measures the pacer alone.
        let rx = src.spawn(count);
        let n = rx.iter().count();
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(n, count);
        let ideal = (count - 1) as f64 / rate;
        assert!(dt >= ideal, "{dt:.4}s faster than the rate allows ({ideal:.4}s)");
        assert!(
            dt < ideal * 2.0 + 0.25,
            "{dt:.4}s drifted far beyond the {ideal:.4}s schedule — sleep error accumulated"
        );
    }

    #[test]
    fn frame_sources_share_channel_with_disjoint_ids() {
        // M streams -> one channel: ids from disjoint base blocks, every
        // frame delivered exactly once.
        let (tx, rx) = mpsc::sync_channel(8);
        let mut id_base = 0u64;
        for s in 0..3u64 {
            let src = FrameSource {
                count: 5,
                rate_fps: None,
                img: 2,
                seed: 10 + s,
            };
            src.spawn_into(tx.clone(), id_base);
            id_base += 5;
        }
        drop(tx);
        let mut ids: Vec<u64> = rx.iter().map(|f| f.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..15).collect::<Vec<_>>());
    }

    #[test]
    fn frame_source_deterministic_content() {
        let mk = || FrameSource {
            count: 3,
            rate_fps: None,
            img: 4,
            seed: 42,
        };
        let a: Vec<Vec<f32>> = mk().spawn(4).iter().map(|f| f.pixels).collect();
        let b: Vec<Vec<f32>> = mk().spawn(4).iter().map(|f| f.pixels).collect();
        assert_eq!(a, b);
    }
}
