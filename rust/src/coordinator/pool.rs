//! Multi-replica serving tier — DESIGN.md §10.
//!
//! N replicas of a [`FeatureExtractor`] (in practice `PlanRunner`s that
//! share ONE compiled plan behind an `Arc` — `PlanRunner::replicate`)
//! drain a work-stealing request queue fed by M concurrent camera
//! streams.  The layout:
//!
//! ```text
//!  M x FrameSource ──> mpsc ──> dispatcher ──> per-replica deques
//!                               (least-loaded      │ owner pops front
//!                                placement,        │ thieves pop back
//!                                backpressure)     v
//!                                             N replica threads
//!                                             (deadline batching ->
//!                                              classify_batch -> NCM)
//! ```
//!
//! * **Work stealing** — each replica owns a deque; the dispatcher
//!   pushes to the shortest one.  An owner pops the FRONT (oldest frame
//!   first, which is what deadline batching wants); an idle replica
//!   steals from a sibling's BACK (the youngest frame, leaving the
//!   near-deadline front work with its owner).  Parking is bounded by a
//!   short poll so stealable backlog on queues that never notify us is
//!   still noticed.
//! * **Deadline-driven batching** — a batch closes at `max_batch` OR
//!   when the oldest frame's `max_wait` budget (measured from ENQUEUE)
//!   is spent, whichever comes first.  Same policy, same
//!   `classify_batch` kernel as the single-runner [`super::serve`], so
//!   pool output is bitwise-identical to the single path.
//! * **Backpressure** — at most `2 * max_batch` frames per replica sit
//!   in the deques; beyond that the dispatcher blocks, which in turn
//!   throttles the bounded source channel — sources never balloon
//!   memory faster than the pool serves.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::{classify_batch, BatchPolicy, Classified, FeatureExtractor, Frame, Metrics};
use crate::fewshot::NcmClassifier;
use crate::plan::pipeline::PlanPipeline;
use crate::telemetry::{Counter, Gauge, Histogram, Registry};

/// How long an idle replica parks before re-scanning sibling deques for
/// stealable frames (its own deque wakes it immediately via condvar).
const STEAL_POLL: Duration = Duration::from_micros(500);

/// A whole [`PlanPipeline`] hosted as ONE pool replica — the pipeline ×
/// pool composition (DESIGN.md §13): the pool gives across-frame
/// parallelism (P pipelines, work-stealing deques, deadline batching),
/// each replica's pipeline gives within-frame parallelism (S stages ×
/// per-stage R workers).  Batches flow through
/// [`PlanPipeline::extract_stream`], whose output is bitwise-identical
/// and in-order to the sequential runner, so the pool's existing
/// differential guarantee (same `classify_batch` funnel as single-runner
/// `serve`) carries over to composed topologies unchanged.
pub struct PipelineReplica {
    pipe: PlanPipeline,
    batch: usize,
    /// Shared across replicas: the per-stage pipeline counters aggregate
    /// over the whole pool (P replicas × stage set).
    registry: Option<&'static Registry>,
}

impl PipelineReplica {
    pub fn new(
        pipe: PlanPipeline,
        batch: usize,
        registry: Option<&'static Registry>,
    ) -> PipelineReplica {
        PipelineReplica {
            pipe,
            batch: batch.max(1),
            registry,
        }
    }
}

impl FeatureExtractor for PipelineReplica {
    fn batch(&self) -> usize {
        self.batch
    }

    fn img(&self) -> usize {
        self.pipe.img()
    }

    fn feature_dim(&self) -> usize {
        self.pipe.feature_dim()
    }

    fn extract(&self, images: &[f32]) -> Result<Vec<f32>> {
        let per = self.pipe.img() * self.pipe.img() * 3;
        let frames = images.len() / per.max(1);
        let (feats, _) = self.pipe.extract_stream(images, frames, self.registry)?;
        Ok(feats)
    }
}

/// Per-replica and aggregate measurements of one pool run.
#[derive(Debug, Clone)]
pub struct PoolReport {
    /// Per-replica serving metrics (index = replica id).
    pub replicas: Vec<Metrics>,
    /// Frames each replica stole from a sibling's deque.
    pub stolen: Vec<usize>,
    /// Pool-level metrics: latencies merged across replicas, frames and
    /// batches summed, wall = the POOL's wall clock (so `fps()` is
    /// aggregate throughput, not a per-replica figure).
    pub aggregate: Metrics,
}

impl PoolReport {
    pub fn total_stolen(&self) -> usize {
        self.stolen.iter().sum()
    }
}

/// Telemetry handles for one pool run, resolved from a
/// [`Registry`] ONCE at [`serve_pool_with`] entry — the serving loops
/// record through `Arc` handles and never touch the registry lock.
/// Metric names are documented in DESIGN.md §11.
struct PoolTelemetry {
    /// `pool.frames_dispatched`: frames placed into replica deques.
    dispatched: Arc<Counter>,
    /// `pool.queue_depth`: target deque length sampled at each dispatch.
    queue_depth: Arc<Histogram>,
    /// `pool.steals`: frames taken from a sibling's deque.
    steals: Arc<Counter>,
    /// `pool.batch_close.deadline` / `.max_batch` / `.drained`: why each
    /// batch stopped filling.
    close_deadline: Arc<Counter>,
    close_max_batch: Arc<Counter>,
    close_drained: Arc<Counter>,
    /// `pool.replica<i>.busy_us` / `.idle_us` per replica.
    per_replica: Vec<ReplicaTelemetry>,
}

struct ReplicaTelemetry {
    busy_us: Arc<Counter>,
    idle_us: Arc<Counter>,
}

impl PoolTelemetry {
    fn resolve(reg: &Registry, replicas: usize) -> PoolTelemetry {
        PoolTelemetry {
            dispatched: reg.counter("pool.frames_dispatched"),
            queue_depth: reg.histogram("pool.queue_depth"),
            steals: reg.counter("pool.steals"),
            close_deadline: reg.counter("pool.batch_close.deadline"),
            close_max_batch: reg.counter("pool.batch_close.max_batch"),
            close_drained: reg.counter("pool.batch_close.drained"),
            per_replica: (0..replicas)
                .map(|i| ReplicaTelemetry {
                    busy_us: reg.counter(&format!("pool.replica{i}.busy_us")),
                    idle_us: reg.counter(&format!("pool.replica{i}.idle_us")),
                })
                .collect(),
        }
    }
}

/// One replica's injector deque.  The owner pops the front; thieves pop
/// the back.  `len` mirrors the deque length so placement and steal
/// scans read it without taking the lock.
struct ReplicaQueue {
    q: Mutex<VecDeque<Frame>>,
    cv: Condvar,
    len: AtomicUsize,
}

/// What a blocking [`Shared::next`] call yielded.
enum Next {
    /// A frame, and whether it was stolen from a sibling.
    Frame(Frame, bool),
    /// The batching deadline passed with no frame available.
    TimedOut,
    /// Source exhausted, every deque empty: the replica may exit.
    Drained,
}

struct Shared {
    queues: Vec<ReplicaQueue>,
    /// Frames currently sitting in deques (the backpressure gauge).
    queued: AtomicUsize,
    /// Set once the source channel is exhausted and fully dispatched.
    closed: AtomicBool,
    /// Set when a replica failed; unblocks the dispatcher early.
    failed: AtomicBool,
    /// The dispatcher parks here when the pool is saturated; replicas
    /// notify after taking frames.
    space: Mutex<()>,
    space_cv: Condvar,
    /// Mirror of `queued` exported as the `pool.inflight` gauge (None
    /// when the pool runs without telemetry).
    inflight: Option<Arc<Gauge>>,
}

impl Shared {
    fn new(replicas: usize, inflight: Option<Arc<Gauge>>) -> Shared {
        Shared {
            queues: (0..replicas)
                .map(|_| ReplicaQueue {
                    q: Mutex::new(VecDeque::new()),
                    cv: Condvar::new(),
                    len: AtomicUsize::new(0),
                })
                .collect(),
            queued: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
            failed: AtomicBool::new(false),
            space: Mutex::new(()),
            space_cv: Condvar::new(),
            inflight,
        }
    }

    /// Dispatcher side: enqueue onto replica `i` and wake it.
    fn push(&self, i: usize, frame: Frame) {
        let mut q = self.queues[i].q.lock().unwrap();
        q.push_back(frame);
        self.queues[i].len.fetch_add(1, Ordering::Release);
        let queued = self.queued.fetch_add(1, Ordering::Release) + 1;
        if let Some(g) = &self.inflight {
            g.set(queued as i64);
        }
        self.queues[i].cv.notify_one();
    }

    /// A frame left the deques: update the gauge, wake the dispatcher.
    fn took(&self) {
        let queued = self.queued.fetch_sub(1, Ordering::Release) - 1;
        if let Some(g) = &self.inflight {
            g.set(queued as i64);
        }
        let _guard = self.space.lock().unwrap();
        self.space_cv.notify_one();
    }

    /// Non-blocking take: own deque front first, then steal a sibling's
    /// back.  Returns the frame and whether it was stolen.
    fn take(&self, me: usize) -> Option<(Frame, bool)> {
        {
            let mut q = self.queues[me].q.lock().unwrap();
            if let Some(f) = q.pop_front() {
                self.queues[me].len.fetch_sub(1, Ordering::Release);
                drop(q);
                self.took();
                return Some((f, false));
            }
        }
        let n = self.queues.len();
        for k in 1..n {
            let victim = (me + k) % n;
            if self.queues[victim].len.load(Ordering::Acquire) == 0 {
                continue;
            }
            let mut q = self.queues[victim].q.lock().unwrap();
            if let Some(f) = q.pop_back() {
                self.queues[victim].len.fetch_sub(1, Ordering::Release);
                drop(q);
                self.took();
                return Some((f, true));
            }
        }
        None
    }

    /// Blocking take with an optional batching deadline.  With no
    /// deadline, blocks until a frame arrives or the pool drains; with
    /// one, additionally gives up at the deadline ([`Next::TimedOut`]).
    fn next(&self, me: usize, deadline: Option<Instant>) -> Next {
        loop {
            if let Some((f, stolen)) = self.take(me) {
                return Next::Frame(f, stolen);
            }
            if self.closed.load(Ordering::Acquire) {
                // Re-scan AFTER observing closed: a frame dispatched just
                // before close cannot slip past this replica's exit.
                return match self.take(me) {
                    Some((f, stolen)) => Next::Frame(f, stolen),
                    None => Next::Drained,
                };
            }
            let wait = match deadline {
                Some(d) => {
                    let left = d.saturating_duration_since(Instant::now());
                    if left.is_zero() {
                        return Next::TimedOut;
                    }
                    left.min(STEAL_POLL)
                }
                None => STEAL_POLL,
            };
            let guard = self.queues[me].q.lock().unwrap();
            if guard.is_empty() {
                let (guard, _) = self.queues[me].cv.wait_timeout(guard, wait).unwrap();
                drop(guard);
            }
        }
    }

    /// Dispatcher: wake every replica so blocked ones re-check `closed`.
    fn close(&self) {
        self.closed.store(true, Ordering::Release);
        for rq in &self.queues {
            let _guard = rq.q.lock().unwrap();
            rq.cv.notify_all();
        }
    }
}

struct ReplicaOutput {
    metrics: Metrics,
    results: Vec<Classified>,
    stolen: usize,
}

/// One replica thread: pull frames (own deque, else steal), close each
/// batch at `max_batch` or the oldest frame's deadline, execute through
/// the shared [`classify_batch`] kernel.
fn run_replica(
    shared: &Shared,
    me: usize,
    runner: &dyn FeatureExtractor,
    ncm: &NcmClassifier,
    policy: BatchPolicy,
    telem: Option<&PoolTelemetry>,
) -> Result<ReplicaOutput> {
    let max_batch = policy.max_batch.min(runner.batch()).max(1);
    let mut batch_buf = vec![0.0f32; runner.input_elems()];
    let mut metrics = Metrics::default();
    let mut results = Vec::new();
    let mut stolen = 0usize;
    let mut busy = Duration::ZERO;
    let mut batch: Vec<Frame> = Vec::with_capacity(max_batch);
    let start = Instant::now();
    loop {
        batch.clear();
        // Block indefinitely for the batch's first frame.
        match shared.next(me, None) {
            Next::Frame(f, s) => {
                stolen += usize::from(s);
                if s {
                    if let Some(t) = telem {
                        t.steals.inc();
                    }
                }
                batch.push(f);
            }
            Next::Drained => break,
            Next::TimedOut => unreachable!("no deadline on the first frame"),
        }
        // Fill until full or the OLDEST frame's wait budget (from its
        // enqueue, not from now) is spent.  Frames already queued are
        // taken greedily — `next` only waits when the deques are empty.
        // The oldest frame is not necessarily the first one taken: a
        // steal pops a sibling's BACK, and the sibling's front — older
        // still — can land here next via the dispatcher or another
        // steal.  So the deadline tracks min(enqueued) over the batch
        // and SHRINKS whenever an older frame joins mid-fill; computing
        // it once from batch[0] silently overshoots that frame's wait
        // budget.
        let mut oldest = batch[0].enqueued;
        let mut drained_mid_fill = false;
        let mut deadline_close = false;
        while batch.len() < max_batch {
            match shared.next(me, Some(oldest + policy.max_wait)) {
                Next::Frame(f, s) => {
                    stolen += usize::from(s);
                    if s {
                        if let Some(t) = telem {
                            t.steals.inc();
                        }
                    }
                    oldest = oldest.min(f.enqueued);
                    batch.push(f);
                }
                Next::TimedOut => {
                    deadline_close = true;
                    break;
                }
                Next::Drained => {
                    drained_mid_fill = true;
                    break;
                }
            }
        }
        if let Some(t) = telem {
            if deadline_close {
                t.close_deadline.inc();
            } else if drained_mid_fill {
                t.close_drained.inc();
            } else {
                t.close_max_batch.inc();
            }
        }
        let t0 = Instant::now();
        classify_batch(runner, ncm, &batch, &mut batch_buf, &mut metrics, &mut results)?;
        busy += t0.elapsed();
    }
    metrics.wall = start.elapsed();
    if let Some(t) = telem {
        let r = &t.per_replica[me];
        r.busy_us.add(busy.as_micros() as u64);
        r.idle_us
            .add(metrics.wall.saturating_sub(busy).as_micros() as u64);
    }
    Ok(ReplicaOutput {
        metrics,
        results,
        stolen,
    })
}

/// Serve frames through an N-replica pool until the source is exhausted.
///
/// `runners` is the replica set (for the plan engine: ONE compiled plan
/// shared via `PlanRunner::replicate`, each box owning only its scratch
/// arena).  Returns the per-replica + aggregate [`PoolReport`] and every
/// classification; frame conservation (each source frame classified
/// exactly once) holds across stealing by construction — frames live in
/// exactly one deque or one replica's in-flight batch at any time.
pub fn serve_pool(
    runners: Vec<Box<dyn FeatureExtractor + Send>>,
    ncm: &NcmClassifier,
    rx: mpsc::Receiver<Frame>,
    policy: BatchPolicy,
) -> Result<(PoolReport, Vec<Classified>)> {
    serve_pool_with(runners, ncm, rx, policy, None)
}

/// [`serve_pool`], additionally exporting pool telemetry into
/// `registry`: queue-depth samples, steal and batch-close-reason
/// counters, the in-flight gauge, and per-replica busy/idle time
/// (metric names in DESIGN.md §11).  All handles are resolved once up
/// front; with `None` the serving loops skip every recording site.
pub fn serve_pool_with(
    runners: Vec<Box<dyn FeatureExtractor + Send>>,
    ncm: &NcmClassifier,
    rx: mpsc::Receiver<Frame>,
    policy: BatchPolicy,
    registry: Option<&Registry>,
) -> Result<(PoolReport, Vec<Classified>)> {
    if runners.is_empty() {
        bail!("serve_pool needs at least one replica");
    }
    let img = runners[0].img();
    let dim = runners[0].feature_dim();
    if runners.iter().any(|r| r.img() != img || r.feature_dim() != dim) {
        bail!("pool replicas disagree on image size or feature dim");
    }
    let n = runners.len();
    let cap = n * policy.max_batch.max(1) * 2;
    let telem = registry.map(|reg| PoolTelemetry::resolve(reg, n));
    let shared = Shared::new(n, registry.map(|reg| reg.gauge("pool.inflight")));
    let start = Instant::now();

    let outs: Vec<Result<ReplicaOutput>> = std::thread::scope(|scope| {
        let shared = &shared;
        let telem = telem.as_ref();
        let mut handles = Vec::with_capacity(n);
        for (i, runner) in runners.into_iter().enumerate() {
            handles.push(scope.spawn(move || {
                let out = run_replica(shared, i, &*runner, ncm, policy, telem);
                if out.is_err() {
                    // Drain so the dispatcher and sibling replicas are
                    // never wedged behind a dead replica's backlog.
                    shared.failed.store(true, Ordering::Release);
                    while !matches!(shared.next(i, None), Next::Drained) {}
                }
                out
            }));
        }

        // Dispatcher (this thread): drain the merged source channel into
        // the shortest deque, blocking while the pool is saturated.
        for frame in rx {
            if shared.failed.load(Ordering::Acquire) {
                break;
            }
            {
                let mut guard = shared.space.lock().unwrap();
                while shared.queued.load(Ordering::Acquire) >= cap
                    && !shared.failed.load(Ordering::Acquire)
                {
                    guard = shared.space_cv.wait(guard).unwrap();
                }
            }
            let mut best = 0usize;
            let mut best_len = usize::MAX;
            for (k, rq) in shared.queues.iter().enumerate() {
                let len = rq.len.load(Ordering::Acquire);
                if len < best_len {
                    best = k;
                    best_len = len;
                }
            }
            shared.push(best, frame);
            if let Some(t) = telem {
                t.dispatched.inc();
                t.queue_depth
                    .record(shared.queues[best].len.load(Ordering::Acquire) as u64);
            }
        }
        shared.close();
        handles
            .into_iter()
            .map(|h| h.join().expect("pool replica panicked"))
            .collect()
    });
    let wall = start.elapsed();

    let mut replicas = Vec::with_capacity(n);
    let mut stolen = Vec::with_capacity(n);
    let mut results = Vec::new();
    for out in outs {
        let out = out?;
        replicas.push(out.metrics);
        stolen.push(out.stolen);
        results.extend(out.results);
    }
    let mut aggregate = Metrics::merge(&replicas);
    aggregate.wall = wall;
    Ok((
        PoolReport {
            replicas,
            stolen,
            aggregate,
        },
        results,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::FrameSource;

    /// Deterministic stand-in backbone: feature = (pixel sum) * (d+1),
    /// with a configurable per-batch delay to shape pool timing.
    struct StubExtractor {
        batch: usize,
        img: usize,
        dim: usize,
        delay: Duration,
    }

    impl FeatureExtractor for StubExtractor {
        fn batch(&self) -> usize {
            self.batch
        }

        fn img(&self) -> usize {
            self.img
        }

        fn feature_dim(&self) -> usize {
            self.dim
        }

        fn extract(&self, images: &[f32]) -> Result<Vec<f32>> {
            if !self.delay.is_zero() {
                std::thread::sleep(self.delay);
            }
            let per = self.img * self.img * 3;
            let mut feats = Vec::with_capacity(self.batch * self.dim);
            for f in 0..self.batch {
                let s: f32 = images[f * per..(f + 1) * per].iter().sum();
                for d in 0..self.dim {
                    feats.push(s * (d as f32 + 1.0));
                }
            }
            Ok(feats)
        }
    }

    fn stub(delay_ms: u64) -> Box<dyn FeatureExtractor + Send> {
        Box::new(StubExtractor {
            batch: 8,
            img: 2,
            dim: 2,
            delay: Duration::from_millis(delay_ms),
        })
    }

    /// Two prototypes along feature dims so predictions are non-trivial.
    fn ncm() -> NcmClassifier {
        let feats = vec![1.0, 0.0, 0.0, 1.0];
        NcmClassifier::fit(&feats, 2, &[0, 1], 2).unwrap()
    }

    fn source(count: usize, rate_fps: Option<f64>) -> mpsc::Receiver<Frame> {
        FrameSource {
            count,
            rate_fps,
            img: 2,
            seed: 1,
        }
        .spawn(16)
    }

    fn assert_conserved(results: &[Classified], count: usize) {
        let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(
            ids,
            (0..count as u64).collect::<Vec<_>>(),
            "frames dropped or duplicated"
        );
    }

    #[test]
    fn pool_conserves_frames_across_replicas() {
        // 4 replicas with a small per-batch delay: deques back up, the
        // dispatcher balances, idle replicas steal — and still every
        // frame is classified exactly once.
        let runners = vec![stub(1), stub(1), stub(1), stub(1)];
        let ncm = ncm();
        let policy = BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
        };
        let (report, results) = serve_pool(runners, &ncm, source(200, None), policy).unwrap();
        assert_eq!(report.aggregate.frames, 200);
        assert_eq!(results.len(), 200);
        assert_conserved(&results, 200);
        assert_eq!(report.replicas.len(), 4);
        assert_eq!(
            report.replicas.iter().map(|m| m.frames).sum::<usize>(),
            200,
            "per-replica frames must partition the source"
        );
        assert!(report.aggregate.fps() > 0.0);
    }

    #[test]
    fn deadline_close_under_slow_source() {
        // Source gaps (10 ms) dwarf the wait budget (1 ms): every batch
        // must close at the deadline with ~1 frame, far below max_batch.
        let runners = vec![stub(0)];
        let ncm = ncm();
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
        };
        let (report, results) =
            serve_pool(runners, &ncm, source(20, Some(100.0)), policy).unwrap();
        assert_conserved(&results, 20);
        assert!(
            report.aggregate.mean_batch_size() < 1.5,
            "batches should close at max_wait, got mean batch {:.2}",
            report.aggregate.mean_batch_size()
        );
    }

    #[test]
    fn max_batch_close_under_fast_source() {
        // Unthrottled source against a slow replica: backlog builds, so
        // batches fill to max_batch instead of waiting out the deadline.
        let runners = vec![stub(2)];
        let ncm = ncm();
        let max_wait = Duration::from_millis(250);
        let policy = BatchPolicy {
            max_batch: 4,
            max_wait,
        };
        let t0 = Instant::now();
        let (report, results) = serve_pool(runners, &ncm, source(64, None), policy).unwrap();
        let dt = t0.elapsed();
        assert_conserved(&results, 64);
        assert!(
            report.aggregate.mean_batch_size() > 2.0,
            "full deques should batch up, got mean batch {:.2}",
            report.aggregate.mean_batch_size()
        );
        // Full batches must close immediately — nowhere near the
        // per-batch deadline budget.
        assert!(
            dt < max_wait * 16,
            "{dt:?}: full batches appear to have waited out max_wait"
        );
    }

    #[test]
    fn idle_replica_steals_from_busy_sibling() {
        // Replica 0 is 100x slower.  Ties in least-loaded placement go
        // to it, so its deque backs up while replica 1 idles — stealing
        // must shift most of the work to the fast replica.
        let runners = vec![stub(5), stub(0)];
        let ncm = ncm();
        let policy = BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_micros(200),
        };
        let (report, results) = serve_pool(runners, &ncm, source(120, None), policy).unwrap();
        assert_conserved(&results, 120);
        assert!(
            report.total_stolen() > 0,
            "idle replica never stole: {:?}",
            report.stolen
        );
        assert!(
            report.replicas[1].frames > report.replicas[0].frames,
            "fast replica served less than the slow one: {} vs {}",
            report.replicas[1].frames,
            report.replicas[0].frames
        );
    }

    #[test]
    fn steal_of_older_frame_shrinks_batch_deadline() {
        // Regression: the batch-close deadline must track min(enqueued)
        // over the batch, not batch[0].  Replica 0 takes a fresh frame
        // from its own deque, then steals a much older one from its
        // sibling's back; the batch must close on the OLDER frame's
        // remaining wait budget, not the fresh frame's full one.
        let max_wait = Duration::from_millis(400);
        let policy = BatchPolicy {
            max_batch: 3,
            max_wait,
        };
        let shared = Shared::new(2, None);
        let now = Instant::now();
        let old = now - Duration::from_millis(300);
        shared.push(
            0,
            Frame {
                id: 0,
                pixels: vec![0.0; 12],
                enqueued: now,
            },
        );
        shared.push(
            1,
            Frame {
                id: 1,
                pixels: vec![0.0; 12],
                enqueued: old,
            },
        );

        struct Probe {
            executed: Arc<Mutex<Option<Instant>>>,
        }
        impl FeatureExtractor for Probe {
            fn batch(&self) -> usize {
                8
            }
            fn img(&self) -> usize {
                2
            }
            fn feature_dim(&self) -> usize {
                2
            }
            fn extract(&self, images: &[f32]) -> Result<Vec<f32>> {
                let mut g = self.executed.lock().unwrap();
                if g.is_none() {
                    *g = Some(Instant::now());
                }
                StubExtractor {
                    batch: 8,
                    img: 2,
                    dim: 2,
                    delay: Duration::ZERO,
                }
                .extract(images)
            }
        }

        let executed: Arc<Mutex<Option<Instant>>> = Arc::new(Mutex::new(None));
        let probe = Probe {
            executed: Arc::clone(&executed),
        };
        let ncm = ncm();
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            let shared = &shared;
            let probe = &probe;
            let ncm = &ncm;
            let h = scope.spawn(move || run_replica(shared, 0, probe, ncm, policy, None));
            loop {
                if executed.lock().unwrap().is_some() {
                    break;
                }
                assert!(
                    t0.elapsed() < Duration::from_secs(5),
                    "batch never executed"
                );
                std::thread::sleep(Duration::from_millis(1));
            }
            shared.close();
            let out = h.join().expect("replica thread").unwrap();
            assert_eq!(out.metrics.frames, 2);
            assert_eq!(out.stolen, 1, "the old sibling frame must be stolen");
        });
        let waited = executed.lock().unwrap().unwrap() - t0;
        // The stolen frame had ~100 ms of its 400 ms budget left.  The
        // buggy once-computed deadline (from the fresh batch[0]) waits
        // the full 400 ms; the min-tracking one closes around 100 ms.
        assert!(
            waited < Duration::from_millis(250),
            "batch overshot the stolen older frame's wait budget: {waited:?}"
        );
    }

    #[test]
    fn pool_exports_telemetry() {
        // Fresh (non-global) registry so the test is isolated; frame
        // accounting must reconcile with the pool's own report.
        let reg = Registry::new();
        let runners = vec![stub(1), stub(1)];
        let ncm = ncm();
        let policy = BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
        };
        let (report, results) =
            serve_pool_with(runners, &ncm, source(80, None), policy, Some(&reg)).unwrap();
        assert_conserved(&results, 80);
        let snap = reg.snapshot();
        assert_eq!(snap.counters["pool.frames_dispatched"], 80);
        assert_eq!(snap.histograms["pool.queue_depth"].count, 80);
        let closes = snap.counters["pool.batch_close.deadline"]
            + snap.counters["pool.batch_close.max_batch"]
            + snap.counters["pool.batch_close.drained"];
        assert_eq!(closes as usize, report.aggregate.batches);
        assert_eq!(snap.counters["pool.steals"] as usize, report.total_stolen());
        // Every queued frame was taken by the time the pool drained.
        assert_eq!(snap.gauges["pool.inflight"], 0);
        for i in 0..2 {
            let busy = snap.counters[&format!("pool.replica{i}.busy_us")];
            let idle = snap.counters[&format!("pool.replica{i}.idle_us")];
            assert!(busy > 0, "replica {i} recorded no busy time");
            let wall_us = report.replicas[i].wall.as_micros() as u64;
            assert!(
                busy + idle <= wall_us + 2_000,
                "replica {i}: busy {busy} + idle {idle} exceeds wall {wall_us}"
            );
        }
    }

    #[test]
    fn pool_rejects_mismatched_replicas() {
        let runners: Vec<Box<dyn FeatureExtractor + Send>> = vec![
            stub(0),
            Box::new(StubExtractor {
                batch: 8,
                img: 4,
                dim: 2,
                delay: Duration::ZERO,
            }),
        ];
        let err = serve_pool(
            runners,
            &ncm(),
            source(4, None),
            BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_millis(1),
            },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("disagree"), "{err}");
    }

    #[test]
    fn empty_pool_is_an_error() {
        let (_tx, rx) = mpsc::sync_channel::<Frame>(1);
        assert!(serve_pool(
            Vec::new(),
            &ncm(),
            rx,
            BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
            },
        )
        .is_err());
    }

    #[test]
    fn pipeline_replicas_compose_with_the_pool() {
        // Pipeline × pool end to end on the tiny backbone: P=2 hosted
        // pipelines (each S=2 stages × R=2 workers) must classify the
        // exact same stream identically to the PR 6 plan-runner pool —
        // frame conservation AND bitwise-equal classes by frame id.
        use crate::plan::pipeline::{PipelineSpec, PlanPipeline};
        use crate::plan::tests::tiny_bb_graph;
        use crate::plan::PlanRunner;

        let g = tiny_bb_graph();
        let batch = 4;
        let count = 48;
        let runner = PlanRunner::new(&g, batch).unwrap();
        #[rustfmt::skip]
        let proto = vec![
            1.0, 0.0, 0.0, 0.0, 0.0,
            0.0, 1.0, 0.0, 0.0, 0.0,
        ];
        let ncm = NcmClassifier::fit(&proto, 5, &[0, 1], 2).unwrap();
        let policy = BatchPolicy {
            max_batch: batch,
            max_wait: Duration::from_millis(2),
        };
        let src = || {
            FrameSource {
                count,
                rate_fps: None,
                img: 4,
                seed: 1,
            }
            .spawn(16)
        };

        // Oracle: the plain plan-runner pool over the identical stream.
        let plain: Vec<Box<dyn FeatureExtractor + Send>> =
            vec![Box::new(runner.replicate()), Box::new(runner.replicate())];
        let (_, want) = serve_pool(plain, &ncm, src(), policy).unwrap();

        let pipe = PlanPipeline::new(
            &runner,
            &PipelineSpec::uniform(2).with_replicas(vec![2, 2]),
        )
        .unwrap();
        let composed: Vec<Box<dyn FeatureExtractor + Send>> = vec![
            Box::new(PipelineReplica::new(pipe.replicate(), batch, None)),
            Box::new(PipelineReplica::new(pipe, batch, None)),
        ];
        let (report, got) = serve_pool(composed, &ncm, src(), policy).unwrap();
        assert_conserved(&got, count);
        assert_eq!(report.aggregate.frames, count);

        let by_id = |rs: &[Classified]| {
            let mut v: Vec<(u64, usize)> = rs.iter().map(|r| (r.id, r.class)).collect();
            v.sort_unstable();
            v
        };
        assert_eq!(
            by_id(&got),
            by_id(&want),
            "composed topology must classify identically to the runner pool"
        );
    }
}
