//! Minimal JSON parser/emitter.
//!
//! The offline crate cache has no `serde`/`serde_json` (DESIGN.md §2), so
//! the graph/manifest interchange with the python build path uses this
//! self-contained implementation.  It supports the full JSON grammar the
//! exporters emit (objects, arrays, numbers incl. scientific notation,
//! strings with escapes, bools, null) and preserves object key order.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.  Objects keep insertion order (`Vec` of pairs) with
/// an index for O(log n) lookup on large objects.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(JsonObj),
}

/// Order-preserving JSON object.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct JsonObj {
    pairs: Vec<(String, Json)>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: Json) {
        self.pairs.push((key.into(), value));
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn iter(&self) -> impl Iterator<Item = &(String, Json)> {
        self.pairs.iter()
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }
}

impl Json {
    // ---------------------------------------------------------------- parse

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    // ------------------------------------------------------------ accessors

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(o) => o
                .get(key)
                .ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("expected object while looking up {key:?}"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_f32(&self) -> Result<f32> {
        Ok(self.as_f64()? as f32)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            bail!("expected integer, got {n}");
        }
        Ok(n as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_i64()?;
        if n < 0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&JsonObj> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => bail!("expected object"),
        }
    }

    /// `[1, 2, 3]` -> `vec![1usize, 2, 3]` — shapes/axes show up everywhere.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn as_i64_vec(&self) -> Result<Vec<i64>> {
        self.as_arr()?.iter().map(|v| v.as_i64()).collect()
    }

    // ----------------------------------------------------------------- emit

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s, 0, true);
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s, 0, false);
        s
    }

    fn emit(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => emit_string(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    v.emit(out, indent, pretty);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        for _ in 0..indent + 1 {
                            out.push(' ');
                        }
                    }
                    emit_string(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.emit(out, indent + 1, pretty);
                }
                if pretty && !o.is_empty() {
                    out.push('\n');
                    for _ in 0..indent {
                        out.push(' ');
                    }
                }
                out.push('}');
            }
        }
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

fn emit_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek()? as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(obj));
                }
                c => bail!("expected ',' or '}}', found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                c => bail!("expected ',' or ']', found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let esc = self.peek()?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad \\u escape {code:x}"))?,
                            );
                        }
                        c => bail!("bad escape \\{}", c as char),
                    }
                }
                _ => {
                    // Re-sync to char boundary for multi-byte UTF-8.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    s.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text
            .parse()
            .map_err(|_| anyhow!("bad number {text:?} at byte {start}"))?;
        Ok(Json::Num(n))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Convenience builder used by report emitters.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    let mut o = JsonObj::new();
    for (k, v) in pairs {
        o.insert(k, v);
    }
    Json::Obj(o)
}

/// Sorted-key object from a map (stable report output).
pub fn obj_sorted(map: BTreeMap<String, Json>) -> Json {
    let mut o = JsonObj::new();
    for (k, v) in map {
        o.insert(k, v);
    }
    Json::Obj(o)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-4.25e2").unwrap(), Json::Num(-425.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"Aé");
    }

    #[test]
    fn object_key_order_preserved() {
        let v = Json::parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<&str> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn round_trip() {
        let src = r#"{"name": "g", "shape": [1, 3, 32, 32], "f": 0.5, "ok": true}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.to_string_compact();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn rejects_truncated() {
        assert!(Json::parse(r#"{"a": [1, 2"#).is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn integer_check() {
        assert!(Json::parse("1.5").unwrap().as_i64().is_err());
        assert_eq!(Json::parse("7").unwrap().as_usize().unwrap(), 7);
        assert!(Json::parse("-7").unwrap().as_usize().is_err());
    }

    #[test]
    fn emits_small_ints_without_exponent() {
        assert_eq!(Json::Num(32.0).to_string_compact(), "32");
        assert_eq!(Json::Num(0.25).to_string_compact(), "0.25");
    }
}
