//! Integration: the multi-replica serving tier on the compiled-plan
//! engine (synthetic backbone, no artifacts needed).  The load-bearing
//! property is the differential guarantee — pool-served classifications
//! are bitwise-identical to the single-runner `serve` path for the same
//! frames — plus frame conservation under work stealing and shared-plan
//! replication end to end.

use std::sync::mpsc;
use std::time::Duration;

use bwade::build::{lower_bit_true, requantize_graph, synth_backbone_graph};
use bwade::coordinator::{
    serve, serve_pool, BatchPolicy, Classified, FeatureExtractor, Frame, FrameSource,
};
use bwade::dse::SweepSpec;
use bwade::fewshot::{sample_episode, NcmClassifier};
use bwade::fixedpoint::headline_config;
use bwade::plan::pipeline::{PipelineSpec, PlanPipeline};
use bwade::plan::{Datapath, PlanRunner};
use bwade::rng::Rng;

/// Compile the dse's synthetic backbone on the requested datapath with
/// the 4-bit headline config.
fn make_runner(datapath: Datapath, batch: usize) -> PlanRunner {
    let spec = SweepSpec::default();
    let cfg = headline_config();
    let mut graph = synth_backbone_graph(spec.widths, spec.img, cfg.act.bits, cfg.act.frac_bits);
    match datapath {
        Datapath::F32 => {
            requantize_graph(&mut graph, &cfg).unwrap();
            PlanRunner::new(&graph, batch).unwrap()
        }
        Datapath::BitTrue => {
            lower_bit_true(&mut graph, &cfg).unwrap();
            PlanRunner::new_bit_true(&graph, batch).unwrap()
        }
    }
}

/// 5-way prototypes from the synthetic bank through `runner`.
fn make_ncm(runner: &PlanRunner) -> NcmClassifier {
    let spec = SweepSpec::default();
    let bank = spec.make_bank();
    let mut rng = Rng::new(7);
    let ep = sample_episode(&mut rng, spec.num_classes, spec.per_class, 5, 5, 1).unwrap();
    let per = spec.img * spec.img * 3;
    let mut sup = Vec::new();
    for &i in &ep.support {
        sup.extend_from_slice(&bank[i * per..(i + 1) * per]);
    }
    let sup_feats = runner.extract_all(&sup, ep.support.len()).unwrap();
    NcmClassifier::fit(&sup_feats, runner.feature_dim(), &ep.support_labels, 5).unwrap()
}

/// Materialize a deterministic frame set so the SAME frames can be
/// replayed through both serving paths.
fn capture_frames(count: usize) -> Vec<Frame> {
    FrameSource {
        count,
        rate_fps: None,
        img: SweepSpec::default().img,
        seed: 5,
    }
    .spawn(count)
    .iter()
    .collect()
}

fn replay(frames: &[Frame]) -> mpsc::Receiver<Frame> {
    let (tx, rx) = mpsc::sync_channel(frames.len());
    for f in frames {
        tx.send(f.clone()).unwrap();
    }
    rx
}

fn classes_by_id(mut results: Vec<Classified>) -> Vec<(u64, usize)> {
    results.sort_by_key(|r| r.id);
    results.into_iter().map(|r| (r.id, r.class)).collect()
}

#[test]
fn pool_matches_single_runner_bitwise() {
    let policy = BatchPolicy {
        max_batch: 4,
        max_wait: Duration::from_millis(2),
    };
    for datapath in [Datapath::F32, Datapath::BitTrue] {
        let base = make_runner(datapath, 4);
        let ncm = make_ncm(&base);
        let frames = capture_frames(48);

        let (single_metrics, single) = serve(&base, &ncm, replay(&frames), policy).unwrap();
        assert_eq!(single_metrics.frames, 48);

        let runners: Vec<Box<dyn FeatureExtractor + Send>> =
            (0..4).map(|_| Box::new(base.replicate()) as _).collect();
        let (report, pooled) = serve_pool(runners, &ncm, replay(&frames), policy).unwrap();
        assert_eq!(report.aggregate.frames, 48);
        assert_eq!(report.replicas.len(), 4);

        // Order-independent bitwise agreement: the pool may serve frames
        // in any interleaving across replicas, but every frame id gets
        // exactly the class the single runner produced.
        assert_eq!(
            classes_by_id(single),
            classes_by_id(pooled),
            "pool diverged from the single runner on the {} datapath",
            datapath.describe()
        );
    }
}

#[test]
fn pipeline_serve_matches_single_runner() {
    // The streaming executor's serving path: same frames, same NCM,
    // class-for-class identical to the sequential `serve`, with every
    // frame conserved through the stage workers on both datapaths.
    let policy = BatchPolicy {
        max_batch: 4,
        max_wait: Duration::from_millis(2),
    };
    for datapath in [Datapath::F32, Datapath::BitTrue] {
        let base = make_runner(datapath, 4);
        let ncm = make_ncm(&base);
        let frames = capture_frames(40);

        let (single_metrics, single) = serve(&base, &ncm, replay(&frames), policy).unwrap();
        assert_eq!(single_metrics.frames, 40);

        let pipe = PlanPipeline::new(&base, &PipelineSpec::uniform(3)).unwrap();
        let (metrics, piped, stats) = pipe.serve(&ncm, replay(&frames), None).unwrap();
        assert_eq!(metrics.frames, 40);
        assert_eq!(stats.frames, 40, "frames lost inside the stage workers");
        assert!(metrics.fps() > 0.0);
        assert_eq!(
            classes_by_id(single),
            classes_by_id(piped),
            "pipeline serve diverged from the single runner on the {} datapath",
            datapath.describe()
        );
    }
}

#[test]
fn pool_conserves_frames_from_concurrent_streams() {
    // 4 rate-limited streams feeding a 3-replica bit-true pool through
    // one bounded channel: disjoint id blocks, nothing dropped or
    // duplicated, nonzero aggregate throughput.
    let base = make_runner(Datapath::BitTrue, 4);
    let ncm = make_ncm(&base);
    let img = SweepSpec::default().img;
    let frames = 60usize;
    let streams = 4usize;
    let (tx, rx) = mpsc::sync_channel(32);
    let mut id_base = 0u64;
    for s in 0..streams {
        let count = frames / streams + usize::from(s < frames % streams);
        FrameSource {
            count,
            rate_fps: Some(500.0),
            img,
            seed: 20 + s as u64,
        }
        .spawn_into(tx.clone(), id_base);
        id_base += count as u64;
    }
    drop(tx);

    let runners: Vec<Box<dyn FeatureExtractor + Send>> =
        (0..3).map(|_| Box::new(base.replicate()) as _).collect();
    let (report, results) = serve_pool(
        runners,
        &ncm,
        rx,
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        },
    )
    .unwrap();

    let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(
        ids,
        (0..frames as u64).collect::<Vec<_>>(),
        "frames dropped or duplicated across replicas"
    );
    assert_eq!(report.aggregate.frames, frames);
    assert!(report.aggregate.fps() > 0.0);
    assert!(results.iter().all(|r| r.class < 5));
    // Per-replica counts partition the source.
    assert_eq!(report.replicas.iter().map(|m| m.frames).sum::<usize>(), frames);
}

#[test]
fn replicas_share_one_plan_and_agree_feature_for_feature() {
    // The Arc split end to end: replicate() shares the compiled plan,
    // and a replica's features are bitwise those of the base runner on
    // the bit-true datapath (integer codes leave no rounding slack).
    let base = make_runner(Datapath::BitTrue, 2);
    let rep = base.replicate();
    assert!(base.shares_plan_with(&rep));

    let per = base.img() * base.img() * 3;
    let mut rng = Rng::new(33);
    let images: Vec<f32> = (0..2 * per).map(|_| rng.next_f32()).collect();
    let a = base.extract_all(&images, 2).unwrap();
    let b = rep.extract_all(&images, 2).unwrap();
    assert_eq!(a, b, "replica features must be bitwise-identical");
}
