//! Integration: PJRT artifact execution, and the cross-layer contract —
//! the rust graph executor, the rust fixed-point semantics, and the
//! python-lowered HLO must agree on the same numbers.

mod common;

use bwade::coordinator::FeatureExtractor;
use bwade::fixedpoint::{headline_config, FxpFormat};
use bwade::graph::Graph;
use bwade::runtime::{run_test_mvau, BackboneRunner, Runtime};
use bwade::tensor::Tensor;

#[test]
fn test_mvau_artifact_matches_rust_semantics_exactly() {
    let Some(paths) = common::artifacts() else { return };
    let runtime = Runtime::new().expect("pjrt");
    let mut rng = bwade::rng::Rng::new(99);
    let x: Vec<f32> = (0..8 * 12).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..12 * 5).map(|_| rng.normal()).collect();
    let b: Vec<f32> = (0..5).map(|_| rng.normal()).collect();
    let fmt = FxpFormat::unsigned(4, 2).unwrap();
    let got = run_test_mvau(
        &runtime,
        &paths.test_mvau_hlo(),
        &x,
        &w,
        &b,
        fmt.scale() as f32,
        fmt.qmax() as f32,
    )
    .expect("mvau artifact");

    // Rust-side oracle: y = clip(floor((x@w + b) * s + 0.5), 0, q) / s.
    let mut want = vec![0.0f32; 8 * 5];
    for i in 0..8 {
        for j in 0..5 {
            let mut acc = b[j];
            for k in 0..12 {
                acc += x[i * 12 + k] * w[k * 5 + j];
            }
            let q = (acc as f64 * fmt.scale() + 0.5)
                .floor()
                .clamp(0.0, fmt.qmax() as f64);
            want[i * 5 + j] = (q / fmt.scale()) as f32;
        }
    }
    assert_eq!(got, want, "pallas-lowered HLO != rust fixed-point semantics");
}

#[test]
fn backbone_runner_shapes_and_determinism() {
    let Some(paths) = common::artifacts() else { return };
    let runtime = Runtime::new().expect("pjrt");
    let bundle = paths.model_bundle().expect("bundle");
    let runner = BackboneRunner::new(
        &runtime,
        &bundle,
        &paths.backbone_hlo(1),
        1,
        headline_config(),
    )
    .expect("runner");
    let images = common::random_images(1, bundle.img, 3);
    let f1 = runner.extract(&images).expect("extract");
    let f2 = runner.extract(&images).expect("extract");
    assert_eq!(f1.len(), bundle.feature_dim);
    assert_eq!(f1, f2, "feature extraction must be deterministic");
    assert!(f1.iter().any(|&v| v != 0.0), "features must be non-trivial");
}

#[test]
fn batch1_and_batch8_agree() {
    let Some(paths) = common::artifacts() else { return };
    let runtime = Runtime::new().expect("pjrt");
    let bundle = paths.model_bundle().expect("bundle");
    let cfg = headline_config();
    let r1 = BackboneRunner::new(&runtime, &bundle, &paths.backbone_hlo(1), 1, cfg).unwrap();
    let r8 = BackboneRunner::new(&runtime, &bundle, &paths.backbone_hlo(8), 8, cfg).unwrap();
    let images = common::random_images(8, bundle.img, 17);
    let f8 = r8.extract(&images).unwrap();
    for i in 0..3 {
        let per = bundle.img * bundle.img * 3;
        let f1 = r1.extract(&images[i * per..(i + 1) * per]).unwrap();
        assert_eq!(
            f1,
            f8[i * bundle.feature_dim..(i + 1) * bundle.feature_dim].to_vec(),
            "image {i}: batch-1 and batch-8 disagree"
        );
    }
}

#[test]
fn extract_all_handles_ragged_tail() {
    let Some(paths) = common::artifacts() else { return };
    let runtime = Runtime::new().expect("pjrt");
    let bundle = paths.model_bundle().expect("bundle");
    let runner = BackboneRunner::new(
        &runtime,
        &bundle,
        &paths.backbone_hlo(8),
        8,
        headline_config(),
    )
    .unwrap();
    let images = common::random_images(11, bundle.img, 5); // 8 + 3 tail
    let all = runner.extract_all(&images, 11).unwrap();
    assert_eq!(all.len(), 11 * bundle.feature_dim);
    // Tail features equal a fresh batched run of the same images.
    let per = bundle.img * bundle.img * 3;
    let mut tail_batch = vec![0.0f32; runner.input_elems()];
    tail_batch[..3 * per].copy_from_slice(&images[8 * per..]);
    let tail = runner.extract(&tail_batch).unwrap();
    assert_eq!(
        &all[8 * bundle.feature_dim..],
        &tail[..3 * bundle.feature_dim]
    );
}

/// THE cross-layer contract: the rust graph executor running the exported
/// compiler graph (with rust-side PTQ) must reproduce the PJRT backbone's
/// features for the same image and config.
#[test]
fn graph_executor_matches_pjrt_backbone() {
    let Some(paths) = common::artifacts() else { return };
    let runtime = Runtime::new().expect("pjrt");
    let bundle = paths.model_bundle().expect("bundle");
    let cfg = headline_config();
    let runner =
        BackboneRunner::new(&runtime, &bundle, &paths.backbone_hlo(1), 1, cfg).unwrap();

    let mut graph = Graph::load(&paths.graph_json(), &paths.graph_weights()).unwrap();
    bwade::build::requantize_graph(&mut graph, &cfg).unwrap();

    let images = common::random_images(1, bundle.img, 23);
    let pjrt_feats = runner.extract(&images).unwrap();

    // NHWC -> NCHW for the imported graph.
    let img = bundle.img;
    let x_nhwc = Tensor::new(vec![1, img, img, 3], images).unwrap();
    let x_nchw = x_nhwc.nhwc_to_nchw().unwrap();
    let mut feeds = std::collections::HashMap::new();
    feeds.insert("global_in".to_string(), x_nchw);
    let out = bwade::ops::execute(&graph, &feeds).expect("graph execution");
    let graph_feats = out["global_out"].data();

    assert_eq!(graph_feats.len(), pjrt_feats.len());
    let max_diff = graph_feats
        .iter()
        .zip(&pjrt_feats)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_diff < 2e-4,
        "rust graph executor and PJRT disagree by {max_diff}"
    );
}
