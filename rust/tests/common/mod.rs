//! Shared helpers for integration tests.
//!
//! Tests that need `make artifacts` output skip gracefully (with a
//! message) when it is missing, so `cargo test` works on a fresh clone.

use bwade::artifacts::ArtifactPaths;

pub fn artifacts() -> Option<ArtifactPaths> {
    let paths = ArtifactPaths::default_dir();
    if paths.exists() {
        Some(paths)
    } else {
        eprintln!("NOTE: artifacts missing — run `make artifacts`; test skipped");
        None
    }
}

/// Deterministic [0,1) image batch.
pub fn random_images(count: usize, img: usize, seed: u64) -> Vec<f32> {
    let mut rng = bwade::rng::Rng::new(seed);
    (0..count * img * img * 3).map(|_| rng.next_f32()).collect()
}
