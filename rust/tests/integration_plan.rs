//! Integration: the compiled ExecutionPlan engine vs the legacy
//! interpreter on realistic graphs — bitwise equality on the imported and
//! fully-lowered ResNet-9, buffer-arena behaviour, and the plan-backed
//! serving path (no PJRT, no artifacts needed).

mod common;

use std::collections::HashMap;
use std::time::Duration;

use bwade::build::{lower_bit_true, requantize_graph, synth_backbone_graph};
use bwade::coordinator::{serve, BatchPolicy, FeatureExtractor, FrameSource};
use bwade::fewshot::NcmClassifier;
use bwade::fixedpoint::{headline_config, table2_configs, FxpFormat};
use bwade::graph::Graph;
use bwade::ops::execute_interpreted;
use bwade::plan::pipeline::{PipelineSpec, PlanPipeline};
use bwade::plan::{Datapath, ExecutionPlan, PlanRunner, PlanScratch};
use bwade::rng::Rng;
use bwade::tensor::Tensor;
use bwade::transforms::run_default_pipeline;

fn probe_feeds(graph: &Graph, seed: u64) -> HashMap<String, Tensor> {
    let name = graph.inputs[0].clone();
    let shape = graph.shape_of(&name).unwrap().to_vec();
    let mut rng = Rng::new(seed);
    let mut feeds = HashMap::new();
    feeds.insert(name, Tensor::from_fn(shape, |_| rng.next_f32()));
    feeds
}

/// The acceptance criterion: plan output is bitwise identical to the
/// legacy interpreter on the imported NCHW backbone AND on the fully
/// lowered HW graph.
#[test]
fn plan_matches_interpreter_on_imported_and_lowered_resnet9() {
    let mut graph = synth_backbone_graph([4, 8, 8, 16], 16, 4, 2);
    requantize_graph(&mut graph, &headline_config()).unwrap();
    let feeds = probe_feeds(&graph, 42);

    // Imported (pre-streamlining) graph.
    let want = execute_interpreted(&graph, &feeds).unwrap();
    let plan = ExecutionPlan::compile(&graph).unwrap();
    let got = plan.run(&feeds).unwrap();
    for (name, w) in &want {
        assert_eq!(&got[name], w, "imported graph: output {name} differs");
    }

    // Fully lowered HW graph (after the whole Fig.-3 pipeline).
    run_default_pipeline(&mut graph, None, 0.0).unwrap();
    let want = execute_interpreted(&graph, &feeds).unwrap();
    let plan = ExecutionPlan::compile(&graph).unwrap();
    let got = plan.run(&feeds).unwrap();
    for (name, w) in &want {
        assert_eq!(&got[name], w, "lowered graph: output {name} differs");
    }
}

/// The arena must actually reuse memory: the peak number of live
/// activation buffers stays well below the total activation tensor count,
/// and elementwise steps run in place.
#[test]
fn plan_arena_reuses_buffers_on_lowered_graph() {
    let mut graph = synth_backbone_graph([4, 8, 8, 16], 16, 4, 2);
    requantize_graph(&mut graph, &headline_config()).unwrap();
    run_default_pipeline(&mut graph, None, 0.0).unwrap();
    let plan = ExecutionPlan::compile(&graph).unwrap();
    let feeds = probe_feeds(&graph, 7);

    let mut scratch = PlanScratch::default();
    plan.run_with(&feeds, &mut scratch).unwrap();
    let stats = scratch.stats;
    assert!(
        stats.peak_live < plan.num_activation_slots(),
        "peak live {} should be below total activations {}",
        stats.peak_live,
        plan.num_activation_slots()
    );
    assert!(
        stats.inplace_steps > 0,
        "lowered graph has thresholding steps that must run in place"
    );

    // Second frame: activations come from the arena, not the allocator.
    let fresh_before = stats.fresh_allocs;
    plan.run_with(&feeds, &mut scratch).unwrap();
    assert!(
        scratch.stats.fresh_allocs <= fresh_before + 1,
        "second frame allocated {} fresh buffers (arena not reused)",
        scratch.stats.fresh_allocs - fresh_before
    );
    assert!(scratch.stats.reuses > 0);
}

#[test]
fn plan_errors_on_missing_feed_at_run_time() {
    let graph = synth_backbone_graph([4, 8, 8, 16], 16, 4, 2);
    let plan = ExecutionPlan::compile(&graph).unwrap();
    // Compilation succeeded; the missing feed is a *run-time* error.
    let err = plan.run(&HashMap::new()).unwrap_err().to_string();
    assert!(
        err.contains("missing feed for graph input global_in"),
        "unexpected error: {err}"
    );
}

#[test]
fn run_batch_amortizes_one_arena_across_frames() {
    let mut graph = synth_backbone_graph([4, 8, 8, 16], 16, 4, 2);
    requantize_graph(&mut graph, &headline_config()).unwrap();
    let plan = ExecutionPlan::compile(&graph).unwrap();
    let frames: Vec<HashMap<String, Tensor>> =
        (0..3).map(|i| probe_feeds(&graph, 100 + i)).collect();
    let outs = plan.run_batch(&frames).unwrap();
    assert_eq!(outs.len(), 3);
    // Frames are independent: batch results equal one-shot results.
    for (feeds, out) in frames.iter().zip(&outs) {
        let solo = plan.run(feeds).unwrap();
        assert_eq!(solo["global_out"], out["global_out"]);
    }
}

/// The Fig.-5 serving pipeline end to end on the plan engine: frame
/// source -> batcher -> compiled plan backbone -> NCM — python-free,
/// XLA-free, artifact-free.
#[test]
fn serving_pipeline_runs_on_plan_engine() {
    let mut graph = synth_backbone_graph([4, 8, 8, 16], 16, 4, 2);
    requantize_graph(&mut graph, &headline_config()).unwrap();
    let runner = PlanRunner::new(&graph, 4).unwrap();
    assert_eq!(runner.img(), 16);
    assert_eq!(runner.feature_dim(), 16);

    // Synthetic 3-way support set: distinct constant-ish images.
    let per = 16 * 16 * 3;
    let mut sup = Vec::new();
    let mut labels = Vec::new();
    let mut rng = Rng::new(5);
    for class in 0..3usize {
        for _ in 0..2 {
            for _ in 0..per {
                sup.push(class as f32 * 0.3 + 0.1 * rng.next_f32());
            }
            labels.push(class);
        }
    }
    let sup_feats = runner.extract_all(&sup, 6).unwrap();
    assert_eq!(sup_feats.len(), 6 * 16);
    let ncm = NcmClassifier::fit(&sup_feats, 16, &labels, 3).unwrap();

    let rx = FrameSource {
        count: 20,
        rate_fps: None,
        img: 16,
        seed: 2,
    }
    .spawn(8);
    let (metrics, results) = serve(
        &runner,
        &ncm,
        rx,
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        },
    )
    .expect("serve");
    assert_eq!(metrics.frames, 20);
    assert_eq!(results.len(), 20);
    assert!(results.iter().all(|r| r.class < 3));
    let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..20).collect::<Vec<_>>());
    // The batch amortized the arena: far fewer fresh allocations than
    // frames x activations.
    let stats = runner.arena_stats();
    assert!(stats.reuses > stats.fresh_allocs, "{stats:?}");
}

// ---------------------------------------------------------------------------
// Bit-true integer datapath
// ---------------------------------------------------------------------------

/// Lowered + annotated ResNet-9 for one quant config.
fn lowered_bit_true_graph(quant: &bwade::fixedpoint::QuantConfig) -> Graph {
    let mut graph =
        synth_backbone_graph([4, 8, 8, 16], 16, quant.act.bits, quant.act.frac_bits);
    lower_bit_true(&mut graph, quant).expect("lower + annotate");
    graph
}

/// THE acceptance criterion: on the fully-lowered ResNet-9, for every
/// Table-II config, the **packed** (i8/i16 width-native) plan's output
/// codes are bitwise identical to the all-i32 bit-true oracle's, and
/// both equal `FxpFormat::quantize_int` of the f32 reference exactly.
/// (All Table-II scales are powers of two and every accumulator stays
/// within f32's exact-integer range at these widths, so the float
/// simulation is itself exact — which is precisely what makes code
/// equality the right oracle.)
#[test]
fn packed_codes_equal_i32_plan_and_quantized_f32_across_table2() {
    for (name, quant) in table2_configs() {
        let graph = lowered_bit_true_graph(&quant);
        let f32_plan = ExecutionPlan::compile(&graph).unwrap();
        let packed_plan = ExecutionPlan::compile_bit_true(&graph).unwrap();
        let wide_plan = ExecutionPlan::compile_bit_true_wide(&graph).unwrap();
        // Packing narrows storage, never the numbers: same egress format.
        assert_eq!(
            packed_plan.output_frac("global_out"),
            wide_plan.output_frac("global_out"),
            "{name}: packed egress format diverged from the i32 oracle"
        );
        // The wide oracle stores every step in i32; sub-8-bit configs
        // must actually pack (the whole point of width-native storage).
        assert!(
            wide_plan
                .kernel_variants()
                .iter()
                .all(|(_, v)| *v != "int8" && *v != "int16" && *v != "int4" && *v != "int1"),
            "{name}: wide oracle leaked a narrow container"
        );
        assert!(
            packed_plan.bytes_moved_per_frame() <= wide_plan.bytes_moved_per_frame(),
            "{name}: packed plan moves more bytes than the i32 oracle"
        );
        if quant.act.container_bits() < 32 {
            assert!(
                packed_plan.bytes_moved_per_frame() < wide_plan.bytes_moved_per_frame(),
                "{name}: packing saved no bandwidth"
            );
        }

        let feeds = probe_feeds(&graph, 0xC0DE);
        let want = f32_plan.run(&feeds).unwrap();
        let got_packed = packed_plan.run(&feeds).unwrap();
        let got_wide = wide_plan.run(&feeds).unwrap();
        for (out_name, w) in &want {
            let frac = packed_plan
                .output_frac(out_name)
                .unwrap_or_else(|| panic!("{name}: no egress format for {out_name}"));
            let fmt = FxpFormat::new(32, frac as u8, true).unwrap();
            let codes = got_packed[out_name].codes_i32();
            assert_eq!(
                codes,
                got_wide[out_name].codes_i32(),
                "{name}: packed and i32 bit-true codes differ for {out_name}"
            );
            assert_eq!(codes.len(), w.numel(), "{name}: {out_name} size");
            for (i, (&c, &v)) in codes.iter().zip(w.data()).enumerate() {
                assert_eq!(
                    c as i64,
                    fmt.quantize_int(v),
                    "{name}: output {out_name}[{i}]: code {c} != quantize_int({v}) at frac {frac}"
                );
            }
        }
    }
}

/// Kernel-variant audit — the "zero f32 arithmetic in integer steps"
/// guarantee, now width-aware: a bit-true plan contains no f32 kernel at
/// all; the only boundary steps are ONE ingress quantizer (float
/// comparisons) and at most one f32 layout Transpose feeding it; and at
/// the headline config (u4.2 activations) the bulk of the steady-state
/// steps store their codes in sub-byte u4 containers (two per byte).
#[test]
fn bit_true_plan_has_zero_float_kernels_and_packs_narrow() {
    let graph = lowered_bit_true_graph(&headline_config());
    let plan = ExecutionPlan::compile_bit_true(&graph).unwrap();
    let variants = plan.kernel_variants();
    assert!(
        variants.iter().all(|(_, v)| *v != "f32"),
        "float kernel in bit-true plan: {variants:?}"
    );
    assert_eq!(
        variants.iter().filter(|(_, v)| *v == "ingress-quant").count(),
        1,
        "exactly one ingress quantizer expected: {variants:?}"
    );
    assert!(
        variants.iter().filter(|(_, v)| *v == "ingress-f32").count() <= 1,
        "more than one f32 ingress transpose: {variants:?}"
    );
    let steady = variants
        .iter()
        .filter(|(_, v)| v.starts_with("int"))
        .count();
    assert!(
        steady > 20,
        "lowered ResNet-9 should have >20 steady-state integer steps, got {steady}: {variants:?}"
    );
    let packed4 = variants.iter().filter(|(_, v)| *v == "int4").count();
    assert!(
        packed4 * 2 > steady,
        "u4.2 activations should put most steps in u4 containers, got {packed4}/{steady}: {variants:?}"
    );
    // Every MVAU's activation codes pack into a u4 nibble at this config.
    assert!(
        variants
            .iter()
            .filter(|(op, _)| op == "MVAU")
            .all(|(_, v)| *v == "int4"),
        "MVAU outputs not packed: {variants:?}"
    );
}

/// The bandwidth story of DESIGN.md §9 end to end: holding the headline
/// weight format (s6.5 -> i8) fixed and sweeping the activation
/// container down the packing rungs — i32 wide oracle (32), u7.4 acts
/// (8), u4.2 acts (4, the headline), u1.1 acts (1) — the bytes one
/// frame streams strictly decreases at every step.
#[test]
fn bytes_per_frame_strictly_decrease_down_the_container_rungs() {
    use bwade::fixedpoint::QuantConfig;
    // (act int bits, act frac bits) -> act container 8 / 4 / 1.
    let act8 = QuantConfig::from_split(1, 5, 3, 4).unwrap();
    let act4 = headline_config();
    let act1 = QuantConfig::from_split(1, 5, 0, 1).unwrap();
    assert_eq!(act8.act.container_bits(), 8);
    assert_eq!(act4.act.container_bits(), 4);
    assert_eq!(act1.act.container_bits(), 1);

    let wide = ExecutionPlan::compile_bit_true_wide(&lowered_bit_true_graph(&act4))
        .unwrap()
        .bytes_moved_per_frame();
    let b8 = ExecutionPlan::compile_bit_true(&lowered_bit_true_graph(&act8))
        .unwrap()
        .bytes_moved_per_frame();
    let b4 = ExecutionPlan::compile_bit_true(&lowered_bit_true_graph(&act4))
        .unwrap()
        .bytes_moved_per_frame();
    let b1 = ExecutionPlan::compile_bit_true(&lowered_bit_true_graph(&act1))
        .unwrap()
        .bytes_moved_per_frame();
    assert!(
        wide > b8 && b8 > b4 && b4 > b1,
        "bytes/frame must fall down the rungs: i32 {wide} > 8b {b8} > 4b {b4} > 1b {b1}"
    );

    // The 1-bit plan is not just cheaper on paper — it runs, and its
    // steady-state MVAUs store single-bit codes.
    let g1 = lowered_bit_true_graph(&act1);
    let p1 = ExecutionPlan::compile_bit_true(&g1).unwrap();
    assert!(
        p1.kernel_variants().iter().any(|(_, v)| *v == "int1"),
        "u1.1 acts should reach the 1-bit container: {:?}",
        p1.kernel_variants()
    );
    let out = p1.run(&probe_feeds(&g1, 0xB17)).unwrap();
    assert_eq!(
        out["global_out"].codes_i32(),
        ExecutionPlan::compile_bit_true_wide(&g1)
            .unwrap()
            .run(&probe_feeds(&g1, 0xB17))
            .unwrap()["global_out"]
            .codes_i32(),
        "1-bit packed plan diverged from the i32 oracle"
    );
}

/// `run_batch` agrees with per-frame `run` on the integer plan (the
/// typed arena must not leak state across frames).
#[test]
fn bit_true_run_batch_agrees_with_per_frame_run() {
    let graph = lowered_bit_true_graph(&headline_config());
    let plan = ExecutionPlan::compile_bit_true(&graph).unwrap();
    let frames: Vec<HashMap<String, Tensor>> =
        (0..3).map(|i| probe_feeds(&graph, 500 + i)).collect();
    let outs = plan.run_batch(&frames).unwrap();
    assert_eq!(outs.len(), 3);
    for (feeds, out) in frames.iter().zip(&outs) {
        let solo = plan.run(feeds).unwrap();
        assert_eq!(
            solo["global_out"].codes_i32(),
            out["global_out"].codes_i32(),
            "batch and per-frame integer codes differ"
        );
        assert_eq!(solo["global_out"].dtype(), out["global_out"].dtype());
    }
}

/// The serving pipeline end to end on the bit-true extractor: the
/// coordinator drives the integer datapath exactly like the f32 one.
#[test]
fn serving_pipeline_runs_bit_true() {
    let graph = lowered_bit_true_graph(&headline_config());
    let runner = PlanRunner::new_bit_true(&graph, 4).unwrap();
    assert_eq!(runner.datapath(), Datapath::BitTrue);
    assert_eq!(runner.img(), 16);
    assert_eq!(runner.feature_dim(), 16);

    let per = 16 * 16 * 3;
    let mut sup = Vec::new();
    let mut labels = Vec::new();
    let mut rng = Rng::new(6);
    for class in 0..3usize {
        for _ in 0..2 {
            for _ in 0..per {
                sup.push(class as f32 * 0.3 + 0.1 * rng.next_f32());
            }
            labels.push(class);
        }
    }
    let sup_feats = runner.extract_all(&sup, 6).unwrap();
    assert_eq!(sup_feats.len(), 6 * 16);
    assert!(sup_feats.iter().any(|&v| v != 0.0), "all-zero features");
    let ncm = NcmClassifier::fit(&sup_feats, 16, &labels, 3).unwrap();

    let rx = FrameSource {
        count: 12,
        rate_fps: None,
        img: 16,
        seed: 3,
    }
    .spawn(8);
    let (metrics, results) = serve(
        &runner,
        &ncm,
        rx,
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        },
    )
    .expect("serve bit-true");
    assert_eq!(metrics.frames, 12);
    assert_eq!(results.len(), 12);
    assert!(results.iter().all(|r| r.class < 3));
}

/// Bit-true features equal the dequantized f32 features (the egress
/// contract as the extractor sees it), so NCM decisions — which depend
/// only on feature geometry — match between datapaths at these widths.
#[test]
fn bit_true_runner_features_match_f32_runner_quantized() {
    let quant = headline_config();
    let graph = lowered_bit_true_graph(&quant);
    let f32_runner = PlanRunner::new(&graph, 2).unwrap();
    let int_runner = PlanRunner::new_bit_true(&graph, 2).unwrap();
    let images = common::random_images(2, 16, 23);
    let f_feats = f32_runner.extract(&images).unwrap();
    let i_feats = int_runner.extract(&images).unwrap();
    assert_eq!(f_feats.len(), i_feats.len());
    // The f32 lowered graph is exact at these widths, so dequantized
    // integer features are bitwise equal to the float features.
    assert_eq!(f_feats, i_feats);
}

/// The streaming executor's acceptance criterion: for every Table-II
/// config, on BOTH datapaths, the staged pipeline's features are bitwise
/// identical to the sequential `PlanRunner` on the same frames.
#[test]
fn pipeline_bitwise_equals_runner_across_table2() {
    for (name, quant) in table2_configs() {
        for datapath in [Datapath::F32, Datapath::BitTrue] {
            let mut graph =
                synth_backbone_graph([4, 8, 8, 16], 16, quant.act.bits, quant.act.frac_bits);
            let runner = match datapath {
                Datapath::F32 => {
                    requantize_graph(&mut graph, &quant).unwrap();
                    PlanRunner::new(&graph, 2).unwrap()
                }
                Datapath::BitTrue => {
                    lower_bit_true(&mut graph, &quant).unwrap();
                    PlanRunner::new_bit_true(&graph, 2).unwrap()
                }
            };
            let pipe = PlanPipeline::new(&runner, &PipelineSpec::uniform(3)).unwrap();
            let images = common::random_images(4, 16, 0xF1F0);
            let want = runner.extract_all(&images, 4).unwrap();
            let (got, stats) = pipe.extract_stream(&images, 4, None).unwrap();
            assert_eq!(stats.frames, 4, "{name}: pipeline dropped frames");
            assert_eq!(
                want,
                got,
                "{name}/{}: pipeline diverged from the sequential runner",
                datapath.describe()
            );
        }
    }
}

/// Deterministic extraction and batch-size independence on the plan path
/// (mirrors the PJRT batch1-vs-batch8 contract test, no artifacts needed).
#[test]
fn plan_runner_batch_sizes_agree() {
    let mut graph = synth_backbone_graph([4, 8, 8, 16], 16, 4, 2);
    requantize_graph(&mut graph, &headline_config()).unwrap();
    let r1 = PlanRunner::new(&graph, 1).unwrap();
    let r4 = PlanRunner::new(&graph, 4).unwrap();
    let images = common::random_images(4, 16, 17);
    let f4 = r4.extract(&images).unwrap();
    let per = 16 * 16 * 3;
    for i in 0..4 {
        let f1 = r1.extract(&images[i * per..(i + 1) * per]).unwrap();
        assert_eq!(
            f1,
            f4[i * 16..(i + 1) * 16].to_vec(),
            "image {i}: batch-1 and batch-4 disagree"
        );
    }
}
