//! Integration: the Fig.-5 serving pipeline against the real PJRT
//! backbone — classification plumbing, batching policy, episode-level
//! accuracy through the full python-free request path.

mod common;

use std::time::Duration;

use bwade::artifacts::FewshotBank;
use bwade::coordinator::{serve, BatchPolicy, FeatureExtractor, FrameSource};
use bwade::fewshot::{evaluate, sample_episode, NcmClassifier};
use bwade::fixedpoint::{headline_config, table2_configs};
use bwade::rng::Rng;
use bwade::runtime::{BackboneRunner, Runtime};

#[test]
fn serving_classifies_every_frame() {
    let Some(paths) = common::artifacts() else { return };
    let runtime = Runtime::new().expect("pjrt");
    let bundle = paths.model_bundle().expect("bundle");
    let bank = FewshotBank::load(&paths.fewshot_bank()).expect("bank");
    let runner = BackboneRunner::new(
        &runtime,
        &bundle,
        &paths.backbone_hlo(8),
        8,
        headline_config(),
    )
    .expect("runner");

    let mut rng = Rng::new(3);
    let ep = sample_episode(&mut rng, bank.num_classes, bank.per_class, 5, 5, 1).unwrap();
    let mut sup = Vec::new();
    for &i in &ep.support {
        sup.extend_from_slice(bank.image(i));
    }
    let sup_feats = runner.extract_all(&sup, ep.support.len()).unwrap();
    let ncm = NcmClassifier::fit(&sup_feats, bundle.feature_dim, &ep.support_labels, 5).unwrap();

    let rx = FrameSource {
        count: 40,
        rate_fps: None,
        img: bundle.img,
        seed: 2,
    }
    .spawn(16);
    let (metrics, results) = serve(
        &runner,
        &ncm,
        rx,
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        },
    )
    .expect("serve");

    assert_eq!(metrics.frames, 40);
    assert_eq!(results.len(), 40);
    // Every frame id classified exactly once, classes within range.
    let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..40).collect::<Vec<_>>());
    assert!(results.iter().all(|r| r.class < 5));
    assert!(metrics.fps() > 0.0);
    assert!(metrics.mean_batch_size() > 1.5, "batching never engaged");
}

#[test]
fn batch_policy_cap_respected() {
    let Some(paths) = common::artifacts() else { return };
    let runtime = Runtime::new().expect("pjrt");
    let bundle = paths.model_bundle().expect("bundle");
    let bank = FewshotBank::load(&paths.fewshot_bank()).expect("bank");
    let runner = BackboneRunner::new(
        &runtime,
        &bundle,
        &paths.backbone_hlo(8),
        8,
        headline_config(),
    )
    .unwrap();
    let mut rng = Rng::new(4);
    let ep = sample_episode(&mut rng, bank.num_classes, bank.per_class, 5, 5, 1).unwrap();
    let mut sup = Vec::new();
    for &i in &ep.support {
        sup.extend_from_slice(bank.image(i));
    }
    let sup_feats = runner.extract_all(&sup, ep.support.len()).unwrap();
    let ncm = NcmClassifier::fit(&sup_feats, bundle.feature_dim, &ep.support_labels, 5).unwrap();

    let rx = FrameSource {
        count: 24,
        rate_fps: None,
        img: bundle.img,
        seed: 6,
    }
    .spawn(32);
    let (metrics, _) = serve(
        &runner,
        &ncm,
        rx,
        BatchPolicy {
            max_batch: 2, // cap below the executable batch
            max_wait: Duration::from_millis(1),
        },
    )
    .unwrap();
    assert!(metrics.mean_batch_size() <= 2.0 + 1e-9);
    assert_eq!(metrics.frames, 24);
}

/// Few-shot accuracy through the serving path must beat chance by a wide
/// margin and degrade monotonically-ish from 16-bit to the bad 5-bit
/// split — the Table-II signal surviving the full system.
#[test]
fn episode_accuracy_through_full_path() {
    let Some(paths) = common::artifacts() else { return };
    let runtime = Runtime::new().expect("pjrt");
    let bundle = paths.model_bundle().expect("bundle");
    let bank = FewshotBank::load(&paths.fewshot_bank()).expect("bank");
    let configs = table2_configs();
    let mut rng = Rng::new(0xAB);
    let eps: Vec<_> = (0..40)
        .map(|_| sample_episode(&mut rng, bank.num_classes, bank.per_class, 5, 5, 15).unwrap())
        .collect();

    let acc_of = |cfg| {
        let runner =
            BackboneRunner::new(&runtime, &bundle, &paths.backbone_hlo(8), 8, cfg).unwrap();
        let feats = runner.extract_all(&bank.images, bank.num_images()).unwrap();
        evaluate(&feats, bundle.feature_dim, &eps).unwrap().mean
    };

    let acc16 = acc_of(configs[7].1);
    let acc5 = acc_of(configs[0].1);
    assert!(acc16 > 0.5, "16-bit accuracy {acc16} too low");
    assert!(acc16 > acc5 + 0.02, "no degradation: 16b {acc16} vs 5b {acc5}");
}
