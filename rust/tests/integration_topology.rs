//! Integration: the composed pipeline×pool topology — P whole pipelines
//! behind the work-stealing pool, S stages each, per-stage worker
//! replication R (DESIGN.md §13) — against the single-runner `serve`
//! oracle.  The load-bearing property is the differential guarantee for
//! ALL EIGHT Table-II configs on both datapaths: same frames, same NCM,
//! class-for-class bitwise agreement, every frame conserved.  The
//! replicated first stage routes every frame through the reorder gate,
//! so the in-order egress invariant is on the tested path (the stage
//! sink hard-errors on any sequence gap).

use std::sync::mpsc;
use std::time::Duration;

use bwade::build::{lower_bit_true, requantize_graph, synth_backbone_graph};
use bwade::coordinator::{
    serve, serve_pool, BatchPolicy, Classified, FeatureExtractor, Frame, FrameSource,
    PipelineReplica,
};
use bwade::dse::SweepSpec;
use bwade::fewshot::{sample_episode, NcmClassifier};
use bwade::fixedpoint::{table2_configs, QuantConfig};
use bwade::plan::pipeline::{PipelineSpec, PlanPipeline};
use bwade::plan::{Datapath, PlanRunner};
use bwade::rng::Rng;

/// Compile the dse's synthetic backbone on the requested datapath and
/// quantization config.
fn make_runner(datapath: Datapath, cfg: &QuantConfig, batch: usize) -> PlanRunner {
    let spec = SweepSpec::default();
    let mut graph = synth_backbone_graph(spec.widths, spec.img, cfg.act.bits, cfg.act.frac_bits);
    match datapath {
        Datapath::F32 => {
            requantize_graph(&mut graph, cfg).unwrap();
            PlanRunner::new(&graph, batch).unwrap()
        }
        Datapath::BitTrue => {
            lower_bit_true(&mut graph, cfg).unwrap();
            PlanRunner::new_bit_true(&graph, batch).unwrap()
        }
    }
}

/// 5-way prototypes from the synthetic bank through `runner`.
fn make_ncm(runner: &PlanRunner) -> NcmClassifier {
    let spec = SweepSpec::default();
    let bank = spec.make_bank();
    let mut rng = Rng::new(7);
    let ep = sample_episode(&mut rng, spec.num_classes, spec.per_class, 5, 5, 1).unwrap();
    let per = spec.img * spec.img * 3;
    let mut sup = Vec::new();
    for &i in &ep.support {
        sup.extend_from_slice(&bank[i * per..(i + 1) * per]);
    }
    let sup_feats = runner.extract_all(&sup, ep.support.len()).unwrap();
    NcmClassifier::fit(&sup_feats, runner.feature_dim(), &ep.support_labels, 5).unwrap()
}

/// Materialize a deterministic frame set so the SAME frames can be
/// replayed through both serving paths.
fn capture_frames(count: usize) -> Vec<Frame> {
    FrameSource {
        count,
        rate_fps: None,
        img: SweepSpec::default().img,
        seed: 5,
    }
    .spawn(count)
    .iter()
    .collect()
}

fn replay(frames: &[Frame]) -> mpsc::Receiver<Frame> {
    let (tx, rx) = mpsc::sync_channel(frames.len());
    for f in frames {
        tx.send(f.clone()).unwrap();
    }
    rx
}

fn classes_by_id(mut results: Vec<Classified>) -> Vec<(u64, usize)> {
    results.sort_by_key(|r| r.id);
    results.into_iter().map(|r| (r.id, r.class)).collect()
}

#[test]
fn composed_topology_matches_single_runner_on_all_table2_configs() {
    let policy = BatchPolicy {
        max_batch: 4,
        max_wait: Duration::from_millis(2),
    };
    for (name, cfg) in table2_configs() {
        for datapath in [Datapath::F32, Datapath::BitTrue] {
            let base = make_runner(datapath, &cfg, 4);
            let ncm = make_ncm(&base);
            let frames = capture_frames(24);

            let (single_metrics, single) = serve(&base, &ncm, replay(&frames), policy).unwrap();
            assert_eq!(single_metrics.frames, 24);

            // P=2 pipelines × S=2 stages × R=[2,1]: the replicated
            // first stage pushes every frame through the reorder gate.
            let spec = PipelineSpec::uniform(2).with_replicas(vec![2, 1]);
            let pipe = PlanPipeline::new(&base, &spec).unwrap();
            assert_eq!(pipe.workers(), 3, "topology [2,1] runs 3 stage workers");
            let runners: Vec<Box<dyn FeatureExtractor + Send>> = vec![
                Box::new(PipelineReplica::new(pipe.replicate(), 4, None)),
                Box::new(PipelineReplica::new(pipe, 4, None)),
            ];
            let (report, composed) = serve_pool(runners, &ncm, replay(&frames), policy).unwrap();
            assert_eq!(
                report.aggregate.frames,
                24,
                "composed topology dropped frames (config {name}, {} datapath)",
                datapath.describe()
            );
            assert_eq!(
                classes_by_id(single),
                classes_by_id(composed),
                "composed topology diverged from the single runner (config {name}, {} datapath)",
                datapath.describe()
            );
        }
    }
}
