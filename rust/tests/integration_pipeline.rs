//! Integration: the design environment end-to-end on the exported graph
//! (requires `make artifacts`) and on synthesized graphs at several
//! scales/configs.

mod common;

use bwade::build::{build, requantize_graph, synth_backbone_graph, DesignConfig};
use bwade::fixedpoint::{table2_configs, QuantConfig};
use bwade::graph::Graph;
use bwade::resources::Device;
use bwade::transforms::convert_to_hw::is_fully_hw;

fn load_exported() -> Option<Graph> {
    let paths = common::artifacts()?;
    Some(Graph::load(&paths.graph_json(), &paths.graph_weights()).expect("graph load"))
}

#[test]
fn exported_graph_builds_fully_hw_with_verification() {
    let Some(mut graph) = load_exported() else { return };
    let report = build(
        &mut graph,
        &DesignConfig {
            verify: true,
            ..DesignConfig::default()
        },
        &Device::pynq_z1(),
    )
    .expect("build");
    assert!(is_fully_hw(&graph), "census: {:?}", graph.op_census());
    // Every verified stage must be numerically silent.
    for s in &report.stages {
        if let Some(d) = s.max_divergence {
            assert!(d <= 2e-3, "stage {} diverged by {d}", s.transform);
        }
    }
    assert!(report.fps > 0.0 && report.latency_ms > 0.0);
    assert!(report.weight_bits > 0);
}

#[test]
fn exported_graph_structure_matches_fig3_flow() {
    let Some(graph) = load_exported() else { return };
    // Pre-compilation census: the Brevitas-export analogue.
    assert_eq!(graph.count_op("Conv"), 8);
    assert_eq!(graph.count_op("MultiThreshold"), 9);
    assert_eq!(graph.count_op("ReduceMean"), 1);
    assert_eq!(graph.count_op("Add"), 2);
    assert_eq!(graph.count_op("MaxPool"), 3);
    graph.validate().expect("valid");
}

#[test]
fn bitwidth_changes_resources_monotonically() {
    let Some(paths) = common::artifacts() else { return };
    let device = Device::pynq_z1();
    let mut brams = Vec::new();
    for (_, quant) in [
        ("w4", QuantConfig::from_split(1, 3, 2, 2).unwrap()),
        ("w6", QuantConfig::from_split(1, 5, 2, 2).unwrap()),
        ("w16", QuantConfig::from_split(8, 8, 8, 8).unwrap()),
    ] {
        let mut g = Graph::load(&paths.graph_json(), &paths.graph_weights()).unwrap();
        let report = build(
            &mut g,
            &DesignConfig {
                quant,
                target_fps: Some(60.0),
                max_utilization: 0.85,
                verify: false,
            },
            &device,
        )
        .expect("build");
        brams.push(report.weight_bits);
    }
    // Weight memory grows with weight bit-width: 4 < 6 < 16.
    assert!(brams[0] < brams[1] && brams[1] < brams[2], "{brams:?}");
}

#[test]
fn all_table2_configs_build_on_synth_graph() {
    // Tensil can't do any of these except the 16-bit row — FINN's
    // arbitrary-bit-width support is the paper's core claim.
    let device = Device::pynq_z1();
    for (name, quant) in table2_configs() {
        let mut g = synth_backbone_graph([4, 8, 8, 16], 16, quant.act.bits, quant.act.frac_bits);
        let report = build(
            &mut g,
            &DesignConfig {
                quant,
                target_fps: Some(100.0),
                max_utilization: 0.85,
                verify: false,
            },
            &device,
        )
        .unwrap_or_else(|e| panic!("config {name} failed: {e}"));
        assert!(is_fully_hw(&g), "{name}");
        assert!(report.fps > 0.0, "{name}");
    }
}

#[test]
fn fifo_sizing_prevents_deadlock_on_residual_graph() {
    let mut g = synth_backbone_graph([4, 8, 8, 16], 16, 4, 2);
    let report = build(&mut g, &DesignConfig::default(), &Device::pynq_z1()).expect("build");
    // The residual skip FIFO must have been sized beyond trivial depth.
    let max_depth = report.fifo_depths.values().max().copied().unwrap_or(0);
    assert!(max_depth >= 8, "depths: {:?}", report.fifo_depths);
    // The bounded simulation completed 3 frames (checked inside build),
    // so steady_cycles is a real steady-state measurement.
    assert!(report.steady_cycles > 0);
    assert!(report.latency_cycles >= report.steady_cycles);
}

#[test]
fn requantize_is_idempotent() {
    let mut a = synth_backbone_graph([4, 8, 8, 16], 16, 4, 2);
    let quant = QuantConfig::from_split(1, 5, 2, 2).unwrap();
    requantize_graph(&mut a, &quant).unwrap();
    let mut b = a.clone();
    requantize_graph(&mut b, &quant).unwrap();
    for (name, t) in &a.initializers {
        assert_eq!(t, &b.initializers[name], "initializer {name} changed");
    }
}

#[test]
fn folding_search_respects_cap() {
    let mut g = synth_backbone_graph([8, 16, 32, 64], 32, 4, 2);
    let device = Device::pynq_z1();
    let cfg = DesignConfig {
        target_fps: None,
        max_utilization: 0.30, // tight cap
        verify: false,
        ..DesignConfig::default()
    };
    requantize_graph(&mut g, &cfg.quant).unwrap();
    bwade::transforms::run_default_pipeline(&mut g, None, 0.0).unwrap();
    let models = bwade::build::folding_search(&mut g, &cfg, &device).expect("folding");
    let total = bwade::hw::total_resources(&models);
    // LUT/FF/DSP within the cap (BRAM may exceed at minimal folding —
    // the relaxation documented in build::folding_search).
    assert!(total.lut <= device.budget.lut * 0.30 + 1.0, "{total}");
    assert!(total.dsp <= device.budget.dsp * 0.30 + 1.0, "{total}");
}
