//! Integration: the design-space exploration subsystem — folding-search
//! invariants, parallel sweep determinism, Pareto extraction and cache
//! reuse.  Everything runs offline (synthesized backbone + plan engine).

use std::path::PathBuf;

use bwade::build::{
    folding_search_traced, requantize_graph, synth_backbone_graph, DesignConfig,
};
use bwade::dse::cache::point_desc;
use bwade::dse::{render_report, run_sweep, PointMetrics, ResultCache, SweepSpec};
use bwade::fixedpoint::table2_configs;
use bwade::hw::total_resources;
use bwade::plan::Datapath;
use bwade::resources::Device;
use bwade::transforms::run_default_pipeline;

/// A 2-config x 2-cap grid with a small bank — the smallest sweep that
/// still exercises parallelism, caching and the Pareto trade-off.
fn tiny_spec(episodes: usize) -> SweepSpec {
    let all = table2_configs();
    SweepSpec {
        configs: vec![all[1].clone(), all[7].clone()], // headline 6b + 16b baseline
        caps: vec![0.4, 0.8],
        episodes,
        num_classes: 5,
        per_class: 6,
        n_way: 3,
        k_shot: 2,
        n_query: 3,
        ..SweepSpec::default()
    }
}

fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("bwade_dse_{}_{}", tag, std::process::id()))
}

/// Folding satisfies the LUT/FF/DSP utilization cap, and the greedy search
/// never makes the initiation interval worse at any step.
#[test]
fn folding_search_respects_cap_and_never_increases_ii() {
    let device = Device::pynq_z1();
    let mut g = synth_backbone_graph([4, 8, 8, 16], 16, 4, 2);
    let cfg = DesignConfig {
        quant: table2_configs()[1].1,
        target_fps: None, // fold until the cap stops paying
        max_utilization: 0.5,
        verify: false,
    };
    requantize_graph(&mut g, &cfg.quant).unwrap();
    run_default_pipeline(&mut g, None, 0.0).unwrap();

    let (models, trace) = folding_search_traced(&mut g, &cfg, &device).unwrap();
    let total = total_resources(&models);
    let b = &device.budget;
    assert!(
        total.lut <= b.lut * cfg.max_utilization,
        "LUT {} over cap {}",
        total.lut,
        b.lut * cfg.max_utilization
    );
    assert!(total.ff <= b.ff * cfg.max_utilization, "FF over cap");
    assert!(total.dsp <= b.dsp * cfg.max_utilization, "DSP over cap");

    // One loop-top entry per iteration plus the final II: >= 3 entries
    // means at least one greedy bump actually happened.
    assert!(trace.len() >= 3, "search took no greedy steps: {trace:?}");
    for w in trace.windows(2) {
        assert!(w[1] <= w[0], "II increased during search: {trace:?}");
    }
    // With no fps target the search actually folds something.
    assert!(
        trace.last().unwrap() < trace.first().unwrap(),
        "search improved nothing: {trace:?}"
    );
}

/// A cached re-sweep evaluates zero points and returns bitwise-identical
/// outcomes, frontier and report.
#[test]
fn sweep_cache_hits_return_identical_points() {
    let spec = tiny_spec(4);
    let dir = temp_dir("cache");
    let _ = std::fs::remove_dir_all(&dir);
    let cache = ResultCache::open(&dir).unwrap();

    let first = run_sweep(&spec, 2, Some(&cache)).unwrap();
    assert_eq!(first.outcomes.len(), 4);
    assert_eq!(first.evaluated, 4);
    assert_eq!(first.cached, 0);
    assert!(!first.pareto.is_empty(), "empty Pareto frontier");
    assert!(first.outcomes.iter().all(|o| !o.cached));

    let second = run_sweep(&spec, 2, Some(&cache)).unwrap();
    assert_eq!(second.evaluated, 0, "cached sweep re-evaluated points");
    assert_eq!(second.cached, 4);
    assert!(second.outcomes.iter().all(|o| o.cached));
    for (a, b) in first.outcomes.iter().zip(&second.outcomes) {
        assert_eq!(a.point.name, b.point.name);
        assert_eq!(a.metrics, b.metrics, "cache changed point {}", a.point.name);
    }
    assert_eq!(first.pareto, second.pareto);
    // The report never encodes cache provenance: byte-identical files.
    assert_eq!(
        render_report(&spec, &first),
        render_report(&spec, &second)
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The same spec gives the same sweep regardless of how many workers ran
/// it — outcomes are merged by grid index, not completion order.
#[test]
fn sweep_is_deterministic_across_worker_counts() {
    let spec = tiny_spec(3);
    let a = run_sweep(&spec, 1, None).unwrap();
    let b = run_sweep(&spec, 3, None).unwrap();
    assert_eq!(a.outcomes.len(), b.outcomes.len());
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.point.name, y.point.name);
        assert_eq!(x.point.max_utilization, y.point.max_utilization);
        assert_eq!(x.metrics, y.metrics, "point {} differs", x.point.name);
    }
    assert_eq!(a.pareto, b.pareto);
    assert_eq!(render_report(&spec, &a), render_report(&spec, &b));

    // Sanity on the metrics themselves: the sweep produced real numbers.
    for o in &a.outcomes {
        assert!(o.metrics.fps > 0.0);
        assert!(o.metrics.latency_ms > 0.0);
        assert!(o.metrics.weight_bits > 0);
        assert!((0.0..=1.0).contains(&o.metrics.acc_mean));
        assert!(o.metrics.utilization > 0.0);
        assert!(o.metrics.bytes_per_frame > 0, "no bytes accounting");
        // The synthesized backbone's scales are all powers of two.
        assert_eq!(o.metrics.non_dyadic_scales, 0);
    }
    // The cap is an exploration axis: the looser cap never yields a
    // meaningfully *slower* build for the same config (tiny slack for the
    // FIFO-sized simulator's achieved-vs-analytic II).
    for pair in a.outcomes.chunks(2) {
        assert!(
            pair[1].metrics.fps >= pair[0].metrics.fps * 0.999,
            "cap 0.8 slower than cap 0.4 for {}",
            pair[0].point.name
        );
    }
}

/// f32 and bit-true sweeps must never answer each other's points: the
/// datapath is part of the cache key preimage, so a cache populated by
/// one datapath misses for the other and the second sweep re-evaluates.
#[test]
fn cache_separates_f32_and_bit_true_datapaths() {
    let spec_f = tiny_spec(2);
    let mut spec_b = tiny_spec(2);
    spec_b.datapath = Datapath::BitTrue;
    let p = spec_f.points()[0].clone();
    assert_ne!(
        point_desc(&spec_f, &p),
        point_desc(&spec_b, &p),
        "datapath missing from the cache key preimage"
    );

    let dir = temp_dir("datapath");
    let _ = std::fs::remove_dir_all(&dir);
    let cache = ResultCache::open(&dir).unwrap();
    let metrics = PointMetrics {
        acc_mean: 0.5,
        acc_ci95: 0.01,
        fps: 100.0,
        latency_ms: 10.0,
        steady_cycles: 1000,
        lut: 1.0,
        ff: 2.0,
        bram36: 3.0,
        dsp: 4.0,
        weight_bits: 64,
        utilization: 0.5,
        hw_layers: 7,
        bytes_per_frame: 4096,
        non_dyadic_scales: 0,
    };
    cache.store(&spec_f, &p, &metrics).unwrap();
    assert_eq!(cache.lookup(&spec_f, &p), Some(metrics.clone()));
    assert!(
        cache.lookup(&spec_b, &p).is_none(),
        "bit-true lookup answered by an f32 entry"
    );
    cache.store(&spec_b, &p, &metrics).unwrap();
    assert!(cache.lookup(&spec_b, &p).is_some());
    let _ = std::fs::remove_dir_all(&dir);
}

/// A real (tiny) bit-true sweep: accuracy comes from integer execution
/// of the lowered graph, the report records the datapath, and the cache
/// reuses bit-true points only for bit-true specs.
#[test]
fn bit_true_sweep_runs_and_reports_datapath() {
    let mut spec = tiny_spec(2);
    spec.configs.truncate(1); // headline config only
    spec.caps.truncate(1);
    spec.datapath = Datapath::BitTrue;

    let dir = temp_dir("btsweep");
    let _ = std::fs::remove_dir_all(&dir);
    let cache = ResultCache::open(&dir).unwrap();

    let first = run_sweep(&spec, 2, Some(&cache)).unwrap();
    assert_eq!(first.outcomes.len(), 1);
    assert_eq!(first.evaluated, 1);
    let m = &first.outcomes[0].metrics;
    assert!((0.0..=1.0).contains(&m.acc_mean));
    assert!(m.fps > 0.0 && m.weight_bits > 0);
    assert!(
        m.bytes_per_frame > 0,
        "bit-true sweep must record packed bytes/frame"
    );
    let md = render_report(&spec, &first);
    assert!(md.contains("Datapath: bit-true"));
    assert!(md.contains("| bit-true |"));

    // Same spec: full cache hit.  f32 twin of the spec: zero hits.
    let second = run_sweep(&spec, 2, Some(&cache)).unwrap();
    assert_eq!(second.evaluated, 0);
    assert_eq!(second.cached, 1);
    let mut f32_spec = spec.clone();
    f32_spec.datapath = Datapath::F32;
    let f32_run = run_sweep(&f32_spec, 2, Some(&cache)).unwrap();
    assert_eq!(
        f32_run.evaluated, 1,
        "f32 sweep must not reuse bit-true cache entries"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sweep_rejects_invalid_specs() {
    let mut s = tiny_spec(2);
    s.caps.clear();
    assert!(run_sweep(&s, 1, None).is_err());
    let mut s = tiny_spec(2);
    s.n_way = 99;
    assert!(run_sweep(&s, 1, None).is_err());
}
