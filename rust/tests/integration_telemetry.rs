//! Telemetry integration (PR 7): histogram edge cases, registry
//! snapshot determinism, and PlanProfile accounting checked against
//! known compiled plans on both datapaths.

use std::collections::HashMap;

use bwade::build::{lower_bit_true, requantize_graph, synth_backbone_graph};
use bwade::fixedpoint::headline_config;
use bwade::plan::{Datapath, ExecutionPlan, PlanScratch};
use bwade::rng::Rng;
use bwade::telemetry::{Histogram, HistogramSnapshot, Registry, HIST_BUCKETS};
use bwade::tensor::Tensor;

// ---------------------------------------------------------------------------
// Histogram edge cases
// ---------------------------------------------------------------------------

#[test]
fn histogram_empty() {
    let s = Histogram::new().snapshot();
    assert_eq!(s.count, 0);
    assert_eq!(s.sum, 0);
    assert_eq!(s.mean(), 0.0);
    assert_eq!(s.quantile(50.0), 0);
    assert_eq!(s.quantile(100.0), 0);
    assert_eq!(s.overflow(), 0);
    assert_eq!(s, HistogramSnapshot::default());
}

#[test]
fn histogram_single_sample() {
    let h = Histogram::new();
    h.record(37);
    let s = h.snapshot();
    assert_eq!(s.count, 1);
    assert_eq!(s.sum, 37);
    assert_eq!(s.mean(), 37.0);
    // 37 has bit length 6 → bucket [32, 63]; every quantile of a
    // one-sample histogram reports that bucket's inclusive upper bound.
    for p in [0.0, 50.0, 95.0, 100.0] {
        assert_eq!(s.quantile(p), 63, "quantile p{p}");
    }
}

#[test]
fn histogram_overflow_bucket() {
    let h = Histogram::new();
    h.record(u64::MAX);
    h.record(1u64 << 38);
    // Bit length 38 — the last finite bucket, NOT overflow.
    h.record((1u64 << 38) - 1);
    let s = h.snapshot();
    assert_eq!(s.count, 3);
    assert_eq!(s.overflow(), 2);
    assert_eq!(s.buckets[HIST_BUCKETS - 1], 2);
    assert_eq!(s.buckets[HIST_BUCKETS - 2], 1);
    // The overflow bucket's quantile estimate saturates.
    assert_eq!(s.quantile(100.0), u64::MAX);
}

#[test]
fn histogram_merge_is_associative_and_commutative() {
    let mk = |vals: &[u64]| {
        let h = Histogram::new();
        for &v in vals {
            h.record(v);
        }
        h.snapshot()
    };
    let a = mk(&[0, 1, 5]);
    let b = mk(&[2, 1 << 20]);
    let c = mk(&[7, 7, 7, 1 << 35]);
    assert_eq!(a.merge(&b), b.merge(&a));
    assert_eq!(a.merge(&b).merge(&c), a.merge(&b.merge(&c)));
    // Merged parts equal the histogram that saw every sample.
    let all = mk(&[0, 1, 5, 2, 1 << 20, 7, 7, 7, 1 << 35]);
    assert_eq!(a.merge(&b).merge(&c), all);
}

// ---------------------------------------------------------------------------
// Registry snapshot determinism
// ---------------------------------------------------------------------------

#[test]
fn registry_snapshot_is_insertion_order_independent() {
    let a = Registry::new();
    a.counter("z.last").add(9);
    a.counter("a.first").add(1);
    a.gauge("m.depth").set(-4);
    a.histogram("lat").record(100);
    a.histogram("lat").record(200);

    let b = Registry::new();
    b.histogram("lat").record(100);
    b.gauge("m.depth").set(-4);
    b.counter("a.first").add(1);
    b.counter("z.last").add(9);
    b.histogram("lat").record(200);

    let da = a.snapshot().to_json().to_string_pretty();
    let db = b.snapshot().to_json().to_string_pretty();
    assert_eq!(da, db, "same metrics, different insert order → same document");
    assert!(da.contains("bwade/telemetry/v1"));
    // Metric names appear sorted within each section.
    assert!(da.find("a.first").unwrap() < da.find("z.last").unwrap());
}

// ---------------------------------------------------------------------------
// PlanProfile accounting vs known plans
// ---------------------------------------------------------------------------

fn profile_matches_plan(datapath: Datapath) {
    let quant = headline_config();
    let mut graph = synth_backbone_graph([8, 16, 32, 64], 32, 4, 2);
    match datapath {
        Datapath::F32 => requantize_graph(&mut graph, &quant).unwrap(),
        Datapath::BitTrue => lower_bit_true(&mut graph, &quant).unwrap(),
    }
    let plan = ExecutionPlan::compile_with(&graph, datapath).unwrap();

    let mut rng = Rng::new(9);
    let shape = graph.shape_of(&graph.inputs[0]).unwrap().to_vec();
    let mut feeds = HashMap::new();
    feeds.insert(graph.inputs[0].clone(), Tensor::from_fn(shape, |_| rng.next_f32()));

    let k = 3u64;
    let mut profile = plan.new_profile();
    let mut scratch = PlanScratch::default();
    let mut prof_out = None;
    for _ in 0..k {
        prof_out = Some(plan.run_with_profile(&feeds, &mut scratch, &mut profile).unwrap());
    }

    assert_eq!(profile.runs(), k);
    for s in profile.steps() {
        assert_eq!(s.calls, k, "step {} runs once per frame", s.name);
    }
    // The profile measures kernel steps only; bytes_moved_per_frame
    // additionally counts the egress dequantize boundary (codes read +
    // f32 features written by the caller), which is zero on f32 plans.
    match datapath {
        Datapath::F32 => assert_eq!(plan.egress_bytes_per_frame(), 0),
        Datapath::BitTrue => assert!(plan.egress_bytes_per_frame() > 0),
    }
    assert_eq!(
        profile.total_bytes() + k * plan.egress_bytes_per_frame(),
        k * plan.bytes_moved_per_frame()
    );
    // Per-step (op, variant) labels are exactly the plan's audit labels.
    let vars: Vec<(String, &'static str)> =
        profile.steps().iter().map(|s| (s.op.clone(), s.variant)).collect();
    assert_eq!(vars, plan.kernel_variants());
    // The by-variant aggregate conserves steps, calls, and bytes.
    let agg = profile.by_variant();
    assert_eq!(agg.iter().map(|v| v.steps).sum::<usize>(), plan.num_steps());
    assert_eq!(agg.iter().map(|v| v.calls).sum::<u64>(), k * plan.num_steps() as u64);
    assert_eq!(agg.iter().map(|v| v.bytes).sum::<u64>(), profile.total_bytes());
    assert_eq!(agg.iter().map(|v| v.nanos).sum::<u64>(), profile.total_nanos());

    // Profiled and unprofiled execution produce bitwise-identical
    // outputs — the instrumentation only reads the clock.
    let mut scratch2 = PlanScratch::default();
    let plain = plan.run_with(&feeds, &mut scratch2).unwrap();
    let prof_out = prof_out.unwrap();
    assert_eq!(plain.len(), prof_out.len());
    for (name, t) in &plain {
        let p = &prof_out[name];
        assert_eq!(t.shape(), p.shape(), "output {name} shape");
        match datapath {
            // The bit-true plan's outputs are integer codes (the runner
            // dequantizes at egress); compare on the right domain.
            Datapath::F32 => assert_eq!(t.data(), p.data(), "output {name} values"),
            Datapath::BitTrue => assert_eq!(t.codes_i32(), p.codes_i32(), "output {name} codes"),
        }
    }
}

#[test]
fn plan_profile_accounts_f32_datapath() {
    profile_matches_plan(Datapath::F32);
}

#[test]
fn plan_profile_accounts_bit_true_datapath() {
    profile_matches_plan(Datapath::BitTrue);
}

#[test]
fn plan_profile_rejects_mismatched_plan() {
    let quant = headline_config();
    let mut graph = synth_backbone_graph([8, 16, 32, 64], 32, 4, 2);
    requantize_graph(&mut graph, &quant).unwrap();
    let plan = ExecutionPlan::compile(&graph).unwrap();

    let mut rng = Rng::new(3);
    let shape = graph.shape_of(&graph.inputs[0]).unwrap().to_vec();
    let mut feeds = HashMap::new();
    feeds.insert(graph.inputs[0].clone(), Tensor::from_fn(shape, |_| rng.next_f32()));

    // A profile with the wrong step count is refused, not silently
    // misattributed.
    let mut wrong = bwade::plan::PlanProfile::default();
    let mut scratch = PlanScratch::default();
    let err = plan.run_with_profile(&feeds, &mut scratch, &mut wrong);
    assert!(err.is_err(), "mismatched profile must be rejected");
}
